"""Space-size table (paper Sec. IV-B), SA/evaluator throughput, kernel
micro-benchmarks (interpret-mode correctness + measured wall time)."""

from __future__ import annotations

import time
from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core.encoding import space_size_lower_bound, tangram_space_upper_bound
from repro.core.evaluator import Evaluator
from repro.core.graph_partition import partition_graph
from repro.core.hw import simba_arch
from repro.core.sa import SAConfig, sa_optimize
from repro.core.tangram import tangram_map
from repro.core.workloads import transformer

from .common import cached


def space_size() -> Dict:
    import math
    rows = []
    for n, m in ((4, 16), (8, 36), (12, 64), (16, 100)):
        ours = space_size_lower_bound(n, m)       # arbitrary-precision int
        theirs = tangram_space_upper_bound(n, m)
        lo, lt = math.log10(ours), math.log10(theirs)
        rows.append({"N": n, "M": m, "ours_log10": lo, "tangram_log10": lt})
        print(f"[space] N={n:3d} M={m:3d}: ours 1e{lo:.0f} "
              f"vs tangram 1e{lt:.1f}")
    return {"rows": rows}


def sa_throughput() -> Dict:
    arch = simba_arch()
    g = transformer()
    groups = partition_graph(g, arch, 64)
    ev = Evaluator(arch, g)
    init = tangram_map(groups, g, arch)
    # warm caches
    sa_optimize(g, arch, groups, 64, SAConfig(iters=50, seed=0),
                init=init, evaluator=ev)
    iters = 1000
    t0 = time.time()
    sa_optimize(g, arch, groups, 64, SAConfig(iters=iters, seed=1),
                init=init, evaluator=ev)
    dt = time.time() - t0
    print(f"[sa] {iters / dt:.0f} SA iters/s ({dt / iters * 1e3:.2f} ms/iter) "
          f"on {g.name} x {arch.label()}")
    return {"iters_per_s": iters / dt, "ms_per_iter": dt / iters * 1e3}


def kernel_bench() -> Dict:
    from repro.kernels import ops, ref
    out = {}
    rng = np.random.default_rng(0)
    # flash attention (interpret mode on CPU: correctness-grade timing only)
    B, H, S, D = 1, 4, 256, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    o = ops.flash_attention(q, k, v, bq=128, bk=128)
    o.block_until_ready()
    t0 = time.time()
    for _ in range(3):
        ops.flash_attention(q, k, v, bq=128, bk=128).block_until_ready()
    flops = 4 * B * H * S * S * D
    dt = (time.time() - t0) / 3
    out["flash_attention"] = {"us": dt * 1e6, "gflops_workload": flops / 1e9}
    print(f"[kern] flash_attention interp: {dt*1e3:.1f} ms "
          f"({flops/1e9:.2f} GFLOP workload)")
    # tiled matmul
    a = jnp.asarray(rng.normal(size=(512, 512)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(512, 512)), jnp.float32)
    ops.matmul(a, b).block_until_ready()
    t0 = time.time()
    for _ in range(3):
        ops.matmul(a, b).block_until_ready()
    dt = (time.time() - t0) / 3
    out["tiled_matmul"] = {"us": dt * 1e6,
                           "gflops_workload": 2 * 512**3 / 1e9}
    print(f"[kern] tiled_matmul interp: {dt*1e3:.1f} ms")
    return out


def main(force: bool = False) -> Dict:
    return cached("misc", lambda: {"space": space_size(),
                                   "sa": sa_throughput(),
                                   "kernels": kernel_bench()}, force)


if __name__ == "__main__":
    main()
