"""Space-size table (paper Sec. IV-B), SA/evaluator/DSE throughput, kernel
micro-benchmarks (interpret-mode correctness + measured wall time).

``python -m benchmarks.misc_bench --smoke`` runs only a tiny end-to-end
exercise of the exploration engine (screening + parallel workers + replica
exchange + checkpoint resume + Pareto frontier) sized for CI.
"""

from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dse import DSEConfig, grid_candidates, run_dse
from repro.core.encoding import space_size_lower_bound, tangram_space_upper_bound
from repro.core.evaluator import CachedEvaluator, Evaluator
from repro.core.explore import merge_checkpoints, pareto_frontier
from repro.core.graph_partition import partition_graph
from repro.core.hw import simba_arch
from repro.core.sa import SAConfig, sa_optimize
from repro.core.tangram import tangram_map
from repro.core.workloads import transformer

from .common import RESULTS, cached


def space_size() -> Dict:
    import math
    rows = []
    for n, m in ((4, 16), (8, 36), (12, 64), (16, 100)):
        ours = space_size_lower_bound(n, m)       # arbitrary-precision int
        theirs = tangram_space_upper_bound(n, m)
        lo, lt = math.log10(ours), math.log10(theirs)
        rows.append({"N": n, "M": m, "ours_log10": lo, "tangram_log10": lt})
        print(f"[space] N={n:3d} M={m:3d}: ours 1e{lo:.0f} "
              f"vs tangram 1e{lt:.1f}")
    return {"rows": rows}


def sa_throughput() -> Dict:
    arch = simba_arch()
    g = transformer()
    groups = partition_graph(g, arch, 64)
    ev = Evaluator(arch, g)
    init = tangram_map(groups, g, arch)
    # warm caches
    sa_optimize(g, arch, groups, 64, SAConfig(iters=50, seed=0),
                init=init, evaluator=ev)
    iters = 1000
    t0 = time.time()
    sa_optimize(g, arch, groups, 64, SAConfig(iters=iters, seed=1),
                init=init, evaluator=ev)
    dt = time.time() - t0
    print(f"[sa] {iters / dt:.0f} SA iters/s ({dt / iters * 1e3:.2f} ms/iter) "
          f"on {g.name} x {arch.label()}")
    return {"iters_per_s": iters / dt, "ms_per_iter": dt / iters * 1e3}


def evaluator_throughput() -> Dict:
    """Evals/sec of the vectorized+cached engine vs the seed scalar engine.

    The seed engine is preserved verbatim in ``repro.core.seed_reference``
    and timed IN THE SAME PROCESS, so the reported speedup is a property of
    the code, not of the machine's load when the benchmark ran.  Regimes:

      * ``sa_iters_per_s`` / ``seed_sa_iters_per_s`` — the SA iteration
        microbenchmark: identical fresh 6000-iteration chains (the paper's
        default SA budget; one touched-group eval per proposal) for both
        engines, interleaved, best of two rounds each;
      * ``cold_evals_per_s``  — ``eval_group`` over a stream of novel SA
        candidates on a fresh evaluator (no content-cache hits);
      * ``cached_evals_per_s`` — repeated mappings through CachedEvaluator
        (the MC-sampling / re-anneal regime, pure cache hits).
    """
    from repro.core.sa import _Op
    from repro.core.seed_reference import ReferenceEvaluator

    arch = simba_arch()
    g = transformer()
    groups = partition_graph(g, arch, 64)
    init = tangram_map(groups, g, arch)

    # --- SA iteration microbenchmark: seed vs new, interleaved -----------
    # identical 6000-iteration chains (the engines walk the same trajectory
    # because their costs are bit-identical); alternating them and keeping
    # the best of two rounds cancels machine-load drift between the timed
    # sections.  Fresh evaluator per round; the module-level intra-core
    # memo warms across rounds for BOTH engines symmetrically.
    def time_chain(evaluator, iters):
        t0 = time.time()
        sa_optimize(g, arch, groups, 64, SAConfig(iters=iters, seed=1),
                    init=init, evaluator=evaluator)
        return iters / (time.time() - t0)

    seed_rate = sa_rate = 0.0
    for _ in range(2):
        seed_rate = max(seed_rate, time_chain(ReferenceEvaluator(arch, g), 6000))
        sa_rate = max(sa_rate, time_chain(CachedEvaluator(arch, g), 6000))

    # --- cold eval_group stream (novel candidates, fresh evaluator) ------
    rng = np.random.default_rng(0)
    ops = _Op(g, arch, rng)
    stream = []
    for grp, lms in init:
        cur = lms
        for _ in range(40):
            cand = ops.op1(grp, cur) or ops.op2(grp, cur) or cur
            stream.append((grp, cand))
            cur = cand
    ev_cold = Evaluator(arch, g)
    t0 = time.time()
    for grp, lms in stream:
        ev_cold.eval_group(grp, lms, 64)
    cold_rate = len(stream) / (time.time() - t0)
    ref_cold = ReferenceEvaluator(arch, g)
    t0 = time.time()
    for grp, lms in stream:
        ref_cold.eval_group(grp, lms, 64)
    seed_cold_rate = len(stream) / (time.time() - t0)

    # --- content-cache hits (repeated mappings) --------------------------
    ev_hot = CachedEvaluator(arch, g)
    ev_hot.evaluate(init, 64)
    reps = 200
    t0 = time.time()
    for _ in range(reps):
        ev_hot.evaluate(init, 64)
    hot_rate = reps * len(init) / (time.time() - t0)

    sa_speedup = sa_rate / seed_rate
    cold_speedup = cold_rate / seed_cold_rate
    print(f"[eval] SA microbenchmark: {sa_rate:.0f} iters/s vs seed "
          f"{seed_rate:.0f} iters/s -> {sa_speedup:.1f}x")
    print(f"[eval] cold eval_group:   {cold_rate:.0f} evals/s vs seed "
          f"{seed_cold_rate:.0f} evals/s -> {cold_speedup:.1f}x")
    print(f"[eval] cached eval_group: {hot_rate:.0f} evals/s "
          f"(cache {ev_hot.cache_info()})")
    return {"sa_iters_per_s": sa_rate,
            "seed_sa_iters_per_s": seed_rate,
            "sa_speedup_vs_seed": sa_speedup,
            "cold_evals_per_s": cold_rate,
            "seed_cold_evals_per_s": seed_cold_rate,
            "cold_speedup_vs_seed": cold_speedup,
            "cached_evals_per_s": hot_rate}


def _dse_grid(n: int):
    """First ``n`` candidates of a trimmed Table-I-style 72-TOPS grid."""
    cands = grid_candidates(
        72.0, mac_options=(512, 1024, 2048), cut_options=(1, 2, 3, 6),
        dram_per_tops=(1.0, 2.0), noc_options=(16, 32), d2d_ratio=(0.5, 1.0),
        glb_options=(1024, 2048))
    assert len(cands) >= n, f"grid too small: {len(cands)} < {n}"
    return cands[:n]


def dse_throughput(n_candidates: int = 64, n_workers: int = 4,
                   iters: int = 1500, n_workloads: int = 1) -> Dict:
    """Wall-clock of a >=64-task SA sweep: serial vs ``n_workers``.

    Screening is OFF, so the speedup is attributable to process parallelism
    alone; the bit-identical check confirms the parallel path computes the
    exact same points.  The SA budget is the Table-I refinement default
    (1500 iters), so per-task work dominates the one-time worker startup as
    it does in a real sweep.  The speedup ceiling is min(n_workers,
    effective cores): on the paper's 80-thread Xeon the same sweep spreads
    over every core; a cgroup-throttled container can sit well below its
    nominal nproc (the CI container measured 1.12x at nproc=2 because only
    ~1.3 cores of capacity were actually grantable), which is why
    cpu_count is recorded next to the ratio.

    ``n_workloads > 1`` is the **(candidate x workload) fan-out mode**: the
    engine's unit of work is one (candidate, workload) pair, so a sweep of
    ``n_candidates`` over ``n_workloads`` schedules their product as
    independently-stealable tasks — with many workloads the pool load-
    balances within a candidate, not just across candidates (a single
    slow candidate no longer serializes its workload list).
    """
    import os
    workloads = {
        f"TF{i}": transformer(n_layers=2, d_model=256, d_ff=512,
                              seq=96 + 32 * i, name=f"tf-m{i}")
        for i in range(n_workloads)}
    cands = _dse_grid(n_candidates)
    cfg = DSEConfig(batch=64, sa=SAConfig(iters=iters, seed=0))
    n_tasks = n_candidates * n_workloads

    t0 = time.time()
    serial = run_dse(cands, workloads, cfg)
    t_serial = time.time() - t0
    t0 = time.time()
    par = run_dse(cands, workloads, cfg, n_workers=n_workers)
    t_parallel = time.time() - t0
    identical = ([(p.arch, p.objective, p.energy_j, p.delay_s) for p in serial]
                 == [(p.arch, p.objective, p.energy_j, p.delay_s) for p in par])
    speedup = t_serial / t_parallel
    print(f"[dse] {n_candidates} candidates x {n_workloads} workloads "
          f"({n_tasks} tasks) x {iters} SA iters: "
          f"serial {t_serial:.1f}s vs {n_workers} workers {t_parallel:.1f}s "
          f"-> {speedup:.2f}x (cores={os.cpu_count()}, "
          f"bit-identical={identical})")
    return {"n_candidates": n_candidates, "sa_iters": iters,
            "n_workloads": n_workloads, "n_tasks": n_tasks,
            "n_workers": n_workers, "cpu_count": os.cpu_count(),
            "serial_s": t_serial, "parallel_s": t_parallel,
            "speedup": speedup, "identical": identical}


def dse_smoke() -> Dict:
    """CI smoke: exercise every engine feature end-to-end on a tiny grid.

    Tiny budget (8 candidates, SA iters <= 200) so it runs on every push:
    (candidate x workload) fan-out, screening, multiprocess workers,
    bit-identical check, replica-exchange SA, checkpoint + resume, sharded
    sweeps + merge, and the Pareto frontier.  Checkpoints are written under
    ``results/smoke_*.jsonl`` (recreated each run) so a failing CI job can
    upload them for post-mortem instead of losing a tempdir.
    """
    g = transformer(n_layers=2, d_model=128, d_ff=256, seq=64, name="tf-s")
    cands = _dse_grid(8)
    workloads = {"TF": g}
    cfg = DSEConfig(batch=8, sa=SAConfig(iters=150, seed=0))
    RESULTS.mkdir(exist_ok=True)
    smoke_files = []

    def _ckpt(name):
        p = RESULTS / f"smoke_{name}.ckpt.jsonl"
        if p.exists():
            p.unlink()                   # smoke always measures from scratch
        smoke_files.append(p)
        return p

    t0 = time.time()
    serial = run_dse(cands, workloads, cfg)
    par = run_dse(cands, workloads, cfg, n_workers=2)
    identical = [p.objective for p in serial] == [p.objective for p in par]
    assert identical, "parallel DSE diverged from serial"
    screened = run_dse(cands, workloads, cfg, screen_keep=0.5)
    assert len(screened) == 4
    ck = _ckpt("resume")
    run_dse(cands, workloads, cfg, checkpoint=ck)
    resumed = run_dse(cands, workloads, cfg, checkpoint=ck)
    assert [p.objective for p in resumed] == [p.objective for p in serial]
    # sharded sweep: 2 shards into independent checkpoints, merged, and the
    # merged checkpoint reconstructs the full sweep bit-identically
    shard_paths = []
    for i in range(2):
        sck = _ckpt(f"shard{i}of2")
        run_dse(cands, workloads, cfg, shard=(i, 2), checkpoint=sck)
        shard_paths.append(sck)
    merged = _ckpt("merged")
    report = merge_checkpoints(shard_paths, merged)
    assert report.n_records == len(cands) and not report.skipped
    remerged = run_dse(cands, workloads, cfg, checkpoint=merged)
    assert [p.objective for p in remerged] == [p.objective for p in serial]
    # n_chains=3 so the swap ladder has two chains and exchanges actually
    # execute (n_chains=2 degenerates and is auto-bumped by sa_optimize)
    re_cfg = DSEConfig(batch=8, sa=SAConfig(iters=150, seed=0, n_chains=3))
    re_pts = run_dse(cands[:2], workloads, re_cfg)
    frontier = pareto_frontier(serial)
    out = {"n_candidates": len(cands), "identical": identical,
           "n_screened": len(screened), "n_frontier": len(frontier),
           "n_merged_records": report.n_records,
           "re_best": re_pts[0].objective, "best": serial[0].objective,
           "_wall_s": time.time() - t0}
    print(f"[smoke] engine end-to-end OK: {out}")
    return out


def _quick_grid():
    """The Table-I --quick grid (benchmarks.table1_dse._setup(quick=True))."""
    return grid_candidates(
        72.0, mac_options=(512, 1024), cut_options=(1, 2),
        dram_per_tops=(2.0,), noc_options=(16, 32), d2d_ratio=(0.5,),
        glb_options=(1024, 2048))


def _tf_quick():
    return transformer(n_layers=2, d_model=128, d_ff=256, seq=64, name="tf-s")


def screening_throughput(rounds: int = 6) -> Dict:
    """Batched vs per-candidate T-Map screening on the Table-I quick grid.

    The reference leg is the engine's per-(candidate x workload) task loop
    (``batched_screen=False`` — the pre-batching code path, still used for
    checkpointed no-SA runs); the batched leg computes one analysis per
    bandwidth-sibling signature group and vectorizes the delay math over
    its candidates.  Interleaved best-of-``rounds`` after a symmetric
    warmup (registry cleared once up front): both legs run against warm
    per-process evaluator state, exactly how the committed
    ``pr4_baseline.json`` screening number was measured, so the
    steady-state screening algorithms are what is compared.  Scores are
    asserted bit-identical.
    """
    from repro.core.evaluator import _REGISTRY
    from repro.core.explore import ExplorationEngine

    cands = _quick_grid()
    g = _tf_quick()
    cfg = DSEConfig(batch=8, sa=SAConfig(iters=150, seed=0))
    _REGISTRY.clear()

    def leg(batched: bool):
        with ExplorationEngine({"TF": g}, cfg, batched_screen=batched) as eng:
            t0 = time.time()
            pts = eng.screen(cands)
        return time.time() - t0, pts

    leg(True); leg(False)                      # symmetric warmup
    tb = tr = 1e9
    for _ in range(rounds):
        t, pr = leg(False); tr = min(tr, t)
        # the reference leg needs 12 evaluators and cannot keep them in
        # the 8-slot registry (every round rebuilds, exactly as PR 4
        # did); the batched leg's 6 signature evaluators DO fit — that
        # registry fit is part of the batched design, so its steady
        # state is the second consecutive run after the reference
        # thrashed the registry
        leg(True)
        t, pb = leg(True); tb = min(tb, t)
    sig = lambda pts: [(p.arch, p.objective, p.energy_j, p.delay_s)
                       for p in pts]
    identical = sig(pb) == sig(pr)
    assert identical, "batched screening diverged from the reference loop"
    print(f"[screen] {len(cands)} candidates: reference {tr*1e3:.0f} ms "
          f"({len(cands)/tr:.0f} cands/s) vs batched {tb*1e3:.0f} ms "
          f"({len(cands)/tb:.0f} cands/s) -> {tr/tb:.1f}x (bit-identical)")
    return {"n_candidates": len(cands), "reference_s": tr, "batched_s": tb,
            "reference_cands_per_s": len(cands) / tr,
            "batched_cands_per_s": len(cands) / tb,
            "speedup": tr / tb, "identical": identical}


def lockstep_sa_throughput(iters: int = 400, rounds: int = 8) -> Dict:
    """Serial-loop vs lockstep n_chains=4 replica exchange, quick-grid arch.

    Same-process A/B of the stepping strategy alone: both legs use
    today's analyzer/evaluator (the serial loop therefore already includes
    this PR's shared cost-model speedups — it is a CONSERVATIVE stand-in
    for the PR-4 engine; see ``pr4_baseline.json`` for the cross-tree
    measurement).  Fresh ``CachedEvaluator`` per run, interleaved
    best-of-``rounds`` (this container's effective CPU fluctuates),
    results asserted identical.
    """
    from dataclasses import replace as _replace

    from repro.core.evaluator import CachedEvaluator
    from repro.core.explore import replica_exchange_sa
    from repro.core.graph_partition import partition_graph

    arch = _quick_grid()[0]
    g = _tf_quick()
    groups = partition_graph(g, arch, 8)
    cfg = SAConfig(iters=iters, seed=3, n_chains=4)

    def leg(lockstep: bool, backend: str = "numpy"):
        t0 = time.time()
        r = replica_exchange_sa(g, arch, groups, 8,
                                _replace(cfg, lockstep=lockstep,
                                         backend=backend),
                                evaluator=CachedEvaluator(arch, g))
        return time.time() - t0, r
    leg(True); leg(False)
    ts = tl = 1e9
    for _ in range(rounds):
        t, rs = leg(False); ts = min(ts, t)
        t, rl = leg(True); tl = min(tl, t)
    identical = (rl.cost == rs.cost and rl.energy_j == rs.energy_j
                 and rl.proposed == rs.proposed
                 and rl.accepted == rs.accepted)
    assert identical, "lockstep trajectory diverged from the serial loop"
    # opt-in fused (backend="jax") leg: parity-grade objectives, exact
    # finalize — measured for the trajectory, never identity-asserted.
    # On a CPU-only container the jit dispatch usually makes this leg
    # SLOWER than the exact engine (recorded honestly); it exists for
    # accelerator runs.
    tf = 1e9
    leg(True, backend="jax")                 # jit warm-up outside timing
    for _ in range(min(rounds, 2)):
        t, _rf = leg(True, backend="jax"); tf = min(tf, t)
    print(f"[sa-n4] {iters} iters x 4 chains: serial loop {ts:.2f}s "
          f"({iters/ts:.0f} iters/s) vs lockstep {tl:.2f}s "
          f"({iters/tl:.0f} iters/s) -> {ts/tl:.2f}x (bit-identical); "
          f"fused-jax leg {tf:.2f}s ({iters/tf:.0f} iters/s)")
    return {"iters": iters, "n_chains": 4,
            "serial_s": ts, "lockstep_s": tl, "fused_s": tf,
            "serial_iters_per_s": iters / ts,
            "lockstep_iters_per_s": iters / tl,
            "fused_iters_per_s": iters / tf,
            "speedup": ts / tl, "identical": identical}


def sweep_n4_throughput(rounds: int = 4) -> Dict:
    """Quick-grid n_chains=4 DSE wall clock (screen 0.5 + lockstep SA).

    The end-to-end figure the Table-I quick run actually pays: batched
    screening + per-candidate n_chains=4 replica-exchange refinement with
    lockstep stepping and the shared geometry caches.  Compare against
    ``pr4_baseline.json`` (same config measured at the PR-4 tree on this
    container) for the before/after of the whole batched engine.
    """
    cands = _quick_grid()
    g = _tf_quick()
    cfg = DSEConfig(batch=8, sa=SAConfig(iters=150, seed=0, n_chains=4))
    best = 1e9
    for _ in range(rounds):
        t0 = time.time()
        pts = run_dse(cands, {"TF": g}, cfg, screen_keep=0.5)
        best = min(best, time.time() - t0)
    print(f"[sweep-n4] quick grid ({len(cands)} candidates, screen 0.5, "
          f"SA 150 x 4 chains): {best:.2f}s")
    return {"n_candidates": len(cands), "wall_s": best,
            "best_objective": pts[0].objective}


def batched_parity(n_random: int = 24) -> Dict:
    """Tiny-grid batched-vs-scalar parity gate (CI bench-smoke).

    Asserts, on the quick grid workload: (1) ``eval_group_batch`` /
    ``eval_requests_batch`` rows bit-identical to scalar ``eval_group``
    over random SA proposal chains (incl. a pack/unpack round-trip);
    (2) batched screening == per-candidate screening; (3) lockstep
    replica exchange == serial loop; (4) the opt-in jax backend replays
    within float32 parity.
    """
    from repro.core.encoding import pack_lms_batch, unpack_lms_batch
    from repro.core.evaluator import CachedEvaluator, Evaluator
    from repro.core.explore import ExplorationEngine, replica_exchange_sa
    from repro.core.graph_partition import partition_graph
    from repro.core.sa import _Op

    arch = _quick_grid()[0]
    g = _tf_quick()
    groups = partition_graph(g, arch, 8)
    init = tangram_map(groups, g, arch)
    rng = np.random.default_rng(0)
    ops = _Op(g, arch, rng)
    reqs = []
    for grp, lms in init:
        cur = lms
        for _ in range(n_random // max(1, len(init))):
            cand = (ops.op1(grp, cur) or ops.op2(grp, cur)
                    or ops.op5(grp, cur) or cur)
            reqs.append((grp, cand))
            cur = cand
    ev_b = Evaluator(arch, g)
    rows = ev_b.eval_requests_batch(reqs, 8)
    ev_s = Evaluator(arch, g)
    for (grp, lms), (geb, anb) in zip(reqs, rows):
        ges, ans = ev_s.eval_group(grp, lms, 8)
        assert (ges.delay_s, ges.energy_j) == (geb.delay_s, geb.energy_j)
        assert ges.energy_breakdown == geb.energy_breakdown
        assert np.array_equal(ans.edge_bytes, anb.edge_bytes)
    grp = reqs[0][0]
    only = [lms for gg, lms in reqs if gg is grp]
    rt = unpack_lms_batch(pack_lms_batch(only, names=grp.names))
    assert [l.cache_key() for l in rt] == [l.cache_key() for l in only]

    cands = _quick_grid()[:6]
    cfg = DSEConfig(batch=8, sa=SAConfig(iters=60, seed=0))
    with ExplorationEngine({"TF": g}, cfg, batched_screen=True) as eng:
        pb = eng.screen(cands)
    with ExplorationEngine({"TF": g}, cfg, batched_screen=False) as eng:
        pr = eng.screen(cands)
    assert [(p.arch, p.objective) for p in pb] \
        == [(p.arch, p.objective) for p in pr]

    from dataclasses import replace as _replace
    re_cfg = SAConfig(iters=120, seed=5, n_chains=4)
    rl = replica_exchange_sa(g, arch, groups, 8, re_cfg,
                             evaluator=CachedEvaluator(arch, g))
    rs = replica_exchange_sa(g, arch, groups, 8,
                             _replace(re_cfg, lockstep=False),
                             evaluator=CachedEvaluator(arch, g))
    assert (rl.cost, rl.proposed, rl.accepted) \
        == (rs.cost, rs.proposed, rs.accepted)

    an = ev_b.analyzer
    ab_np = an.analyze_batch(grp, only, 8, backend="numpy")
    ab_jx = an.analyze_batch(grp, only, 8, backend="jax")
    np.testing.assert_allclose(ab_jx.buf, ab_np.buf, rtol=2e-4, atol=1e-2)

    out = {"n_requests": len(reqs), "n_screen": len(cands),
           "re_cost": rl.cost, "checks": ["batch_rows", "pack_roundtrip",
                                          "screen", "lockstep",
                                          "jax_backend"]}
    print(f"[parity] batched == scalar on {len(reqs)} rows, screening, "
          "lockstep RE and jax backend: OK")
    return out


def fused_parity(tol: float = 1e-4, n_random: int = 4,
                 seed: int = 0) -> Dict:
    """Fused jitted pass vs exact engine parity gate (CI bench-smoke).

    Runs ``eval_requests_batch(..., backend="jax")`` — one jitted
    construction→segment-sum-replay→delay/energy pass in float32 — next
    to the exact float64 numpy engine over random mappings of the
    tf/moe/mla quick workloads and asserts every objective
    (delay / energy / stage time) agrees within the documented relative
    envelope (default 1e-4; see DESIGN.md "Fused jitted pass") and that
    the argmax bottleneck stage matches.  This is the contract that lets
    SA score proposals with the fused path while winners are re-scored
    exactly.
    """
    from repro.core.encoding import random_lms
    from repro.core.evaluator import Evaluator
    from repro.core.graph_partition import partition_graph
    from repro.core.workloads import make_workload

    arch = _quick_grid()[0]
    rng = np.random.default_rng(seed)
    worst = 0.0
    n_rows = 0
    for spec in ("tf-quick", "moe-quick", "mla-quick"):
        g = make_workload(spec)
        groups = partition_graph(g, arch, 8)
        ev = Evaluator(arch, g)
        reqs = []
        for grp in groups:
            for k in range(n_random):
                reqs.append((grp, random_lms(grp, g, arch.n_cores,
                                             arch.n_dram, rng)))
        exact = ev.eval_requests_batch(reqs, 8)
        fused = ev.eval_requests_batch(reqs, 8, backend="jax")
        for (ge, an), (gf, anf) in zip(exact, fused):
            assert anf is None, "fused rows must not carry analyses"
            for a, b in ((ge.delay_s, gf.delay_s),
                         (ge.energy_j, gf.energy_j),
                         (ge.stage_time_s, gf.stage_time_s)):
                rel = abs(a - b) / max(abs(a), 1e-30)
                worst = max(worst, rel)
                assert rel < tol, (
                    f"fused parity violation on {spec}: "
                    f"{a!r} vs {b!r} (rel {rel:.2e} >= {tol:g})")
            assert ge.bottleneck == gf.bottleneck, (
                f"fused bottleneck mismatch on {spec}: "
                f"{ge.bottleneck} vs {gf.bottleneck}")
        n_rows += len(reqs)
    print(f"[fused-parity] {n_rows} rows across tf/moe/mla quick: "
          f"worst rel err {worst:.2e} < {tol:g}: OK")
    return {"n_rows": n_rows, "worst_rel_err": worst, "tol": tol}


def moe_throughput(iters: int = 300, rounds: int = 4) -> Dict:
    """Routed-MoE graph analyze/eval cost vs its equal-expected-FLOP dense
    collapse.

    Same (arch, SA budget, seed) on two lm_graph exports of
    granite-moe-3b-a800m (one block, seq=256): ``family="moe"`` — the real
    expected-traffic graph, 40 expert branches at ``traffic_scale = 8/40``
    — and the legacy ``family="moe-dense"`` collapse into one fat FFN.
    Their total expected MACs agree to <1% (the router is the only extra
    work), so the iters/s ratio isolates what the E-way branch structure
    costs the analyzer/evaluator per SA iteration: the MoE graph has ~6x
    the layers (hence bigger groups, wider contribution streams and more
    NoC flows), which is the price of modeling expert-parallel mappings at
    all.  Recorded in BENCH_dse.json (``moe_eval``).
    """
    from repro.configs import get_config
    from repro.core.workloads.lm_graph import lm_graph

    arch = _quick_grid()[0]
    base = get_config("granite-moe-3b-a800m")
    legs: Dict[str, Dict] = {}
    for fam in ("moe", "moe-dense"):
        g = lm_graph(base.replace(family=fam), seq=256, n_layers=1)
        groups = partition_graph(g, arch, 8)
        ev = CachedEvaluator(arch, g)
        init = tangram_map(groups, g, arch)
        sa_optimize(g, arch, groups, 8, SAConfig(iters=50, seed=0),
                    init=init, evaluator=ev)               # warm caches
        best = 1e9
        for _ in range(rounds):
            t0 = time.time()
            sa_optimize(g, arch, groups, 8, SAConfig(iters=iters, seed=1),
                        init=init, evaluator=ev)
            best = min(best, time.time() - t0)
        legs[fam] = {"n_layers": len(g.layers), "n_groups": len(groups),
                     "expected_macs": float(g.total_expected_macs()),
                     "iters_per_s": iters / best}
    slowdown = (legs["moe-dense"]["iters_per_s"]
                / legs["moe"]["iters_per_s"])
    macs_ratio = (legs["moe"]["expected_macs"]
                  / legs["moe-dense"]["expected_macs"])
    print(f"[moe-eval] routed graph ({legs['moe']['n_layers']} layers): "
          f"{legs['moe']['iters_per_s']:.0f} SA iters/s vs dense collapse "
          f"({legs['moe-dense']['n_layers']} layers): "
          f"{legs['moe-dense']['iters_per_s']:.0f} iters/s -> "
          f"{slowdown:.1f}x branch-structure cost "
          f"(expected-MAC parity {macs_ratio:.4f})")
    return {"iters": iters, "moe": legs["moe"],
            "dense": legs["moe-dense"],
            "dense_over_moe_iters_ratio": slowdown,
            "expected_macs_ratio": macs_ratio}


def serving_throughput(rounds: int = 4) -> Dict:
    """Discrete-event replay throughput of the serving harness.

    T-Map-screens the Table-I quick grid (deterministic), converts the
    best candidate's delay into a per-token service model, and replays
    the registered ``chat-quick`` trace under both scheduling modes —
    wave batching (the ``serve_loop`` policy) and continuous slotting
    (the ``slo`` DSE objective's model).  Reports simulated requests per
    wall-second (how cheap an SLO prediction is inside a sweep) plus the
    predicted p99s, which double as a drift canary for the queueing
    model.  Recorded in BENCH_dse.json (``serving``).
    """
    from repro.serve import (make_trace, replay, resolve_traffic,
                             service_model_from_delay)

    delay = run_dse(_quick_grid(), {"TF": _tf_quick()},
                    DSEConfig(batch=8, sa=SAConfig(iters=150, seed=0)),
                    use_sa=False)[0].delay_s
    model = service_model_from_delay(delay, batch=8, seq_ref=64)
    tm = resolve_traffic("chat-quick")
    trace = make_trace(tm.trace_spec, seed=0)
    out: Dict = {"delay_s": delay, "trace": tm.trace_spec,
                 "n_requests": len(trace.requests)}
    for mode in ("wave", "continuous"):
        rep = replay(trace, model, mode=mode, max_batch=tm.max_batch)
        best = 1e9
        for _ in range(rounds):
            t0 = time.time()
            rep = replay(trace, model, mode=mode, max_batch=tm.max_batch)
            best = min(best, time.time() - t0)
        out[mode] = {"replay_s": best,
                     "req_per_wall_s": len(trace.requests) / best,
                     "p99_ttft_s": rep.p99_ttft_s,
                     "p99_e2e_s": rep.p99_e2e_s}
        print(f"[serving] {mode}: {len(trace.requests) / best:.0f} "
              f"simulated req/s wall ({best * 1e3:.2f} ms/replay), "
              f"p99 e2e {rep.p99_e2e_s:.4g}s")
    return out


def dse_bench(quick: bool = False) -> Dict:
    """The BENCH_dse.json payload: screening / SA / sweep before-vs-after.

    ``quick`` shrinks round counts for CI.  The ``pr4_baseline`` block is
    loaded from ``benchmarks/pr4_baseline.json`` — the same configs
    measured at the PR-4 tree on this container (see its _provenance) —
    and the derived ``vs_pr4`` ratios compare against it.  The
    same-process reference legs are conservative: they already contain
    this PR's shared cost-model speedups.
    """
    import json as _json
    import os as _os
    import platform as _platform
    import sys as _sys
    from pathlib import Path

    rounds = 2 if quick else 6
    out: Dict = {
        "schema": "bench_dse/v1",
        "grid": "table1 --quick (72 TOPS, 12 candidates)",
        # container provenance: throughput numbers are only comparable
        # across runs when these match (this is a 1-CPU container)
        "provenance": {
            "cpu_count": _os.cpu_count(),
            "platform": _platform.platform(),
            "python": _sys.version.split()[0],
            "jax": getattr(jax, "__version__", None),
        },
        "screening": screening_throughput(rounds=rounds),
        "lockstep_sa": lockstep_sa_throughput(rounds=2 if quick else 8),
        "sweep_n4": sweep_n4_throughput(rounds=1 if quick else 4),
        "evaluator": sa_throughput(),
        "moe_eval": moe_throughput(rounds=2 if quick else 4),
        "serving": serving_throughput(rounds=2 if quick else 4),
    }
    base_path = Path(__file__).resolve().parent / "pr4_baseline.json"
    if base_path.exists():
        base = _json.loads(base_path.read_text())
        out["pr4_baseline"] = base
        out["vs_pr4"] = {
            "screening_speedup":
                base["screening"]["wall_s"] / out["screening"]["batched_s"],
            "sa_chain_n4_speedup":
                base["sa_chain_n4"]["wall_s"]
                / out["lockstep_sa"]["lockstep_s"],
            "sweep_n4_speedup":
                base["sweep_n4"]["wall_s"] / out["sweep_n4"]["wall_s"],
        }
        v = out["vs_pr4"]
        print(f"[bench-dse] vs PR4: screening {v['screening_speedup']:.1f}x, "
              f"n_chains=4 chain {v['sa_chain_n4_speedup']:.2f}x, "
              f"n_chains=4 quick-grid sweep {v['sweep_n4_speedup']:.2f}x")
    return out


def re_tuning(iters: int = 600, n_chains: int = 4,
              n_candidates: int = 3) -> Dict:
    """Replica-exchange knob sweep (ROADMAP): ``t_ladder`` x ``swap_every``
    on the --quick Table-I grid, reporting per-pair swap-acceptance rates
    and the best cost found.

    Healthy parallel tempering wants ~20-40% acceptance per adjacent pair:
    near 0% the ladder decouples into independent restarts, near 100% the
    rungs are so close that tempering adds nothing over one chain.  The
    ``core/sa.py`` defaults are set from this sweep (see SAConfig).
    """
    from repro.core.evaluator import evaluator_for
    from repro.core.explore import replica_exchange_sa

    cands = _dse_grid(n_candidates)
    g = transformer(n_layers=2, d_model=128, d_ff=256, seq=64, name="tf-s")
    rows = []
    for t_ladder in (1.5, 2.0, 3.0, 5.0):
        for swap_every in (10, 25, 50, 100):
            rates, costs = [], []
            for arch in cands:
                groups = partition_graph(g, arch, 8)
                cfg = SAConfig(iters=iters, seed=0, n_chains=n_chains,
                               t_ladder=t_ladder, swap_every=swap_every)
                res = replica_exchange_sa(g, arch, groups, 8, cfg,
                                          evaluator=evaluator_for(arch, g))
                rates.extend(res.swap_rates())
                costs.append(res.cost)
            mean_rate = float(np.mean(rates)) if rates else 0.0
            geo_cost = float(np.exp(np.mean(np.log(costs))))
            in_band = 0.20 <= mean_rate <= 0.40
            rows.append({"t_ladder": t_ladder, "swap_every": swap_every,
                         "swap_rate": mean_rate, "geo_cost": geo_cost,
                         "in_band": in_band})
            print(f"[retune] t_ladder={t_ladder:<4g} swap_every="
                  f"{swap_every:<4d} swap-accept={mean_rate:5.1%} "
                  f"geo-cost={geo_cost:.4e}{'  <- 20-40% band' if in_band else ''}")
    best = min(rows, key=lambda r: r["geo_cost"])
    print(f"[retune] best cost at t_ladder={best['t_ladder']} "
          f"swap_every={best['swap_every']} "
          f"(swap-accept {best['swap_rate']:.1%})")
    return {"rows": rows, "best": best}


def kernel_bench() -> Dict:
    from repro.kernels import ops, ref
    out = {}
    rng = np.random.default_rng(0)
    # flash attention (interpret mode on CPU: correctness-grade timing only)
    B, H, S, D = 1, 4, 256, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    o = ops.flash_attention(q, k, v, bq=128, bk=128)
    o.block_until_ready()
    t0 = time.time()
    for _ in range(3):
        ops.flash_attention(q, k, v, bq=128, bk=128).block_until_ready()
    flops = 4 * B * H * S * S * D
    dt = (time.time() - t0) / 3
    out["flash_attention"] = {"us": dt * 1e6, "gflops_workload": flops / 1e9}
    print(f"[kern] flash_attention interp: {dt*1e3:.1f} ms "
          f"({flops/1e9:.2f} GFLOP workload)")
    # tiled matmul
    a = jnp.asarray(rng.normal(size=(512, 512)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(512, 512)), jnp.float32)
    ops.matmul(a, b).block_until_ready()
    t0 = time.time()
    for _ in range(3):
        ops.matmul(a, b).block_until_ready()
    dt = (time.time() - t0) / 3
    out["tiled_matmul"] = {"us": dt * 1e6,
                           "gflops_workload": 2 * 512**3 / 1e9}
    print(f"[kern] tiled_matmul interp: {dt*1e3:.1f} ms")
    return out


def main(force: bool = False) -> Dict:
    return cached("misc", lambda: {"space": space_size(),
                                   "sa": sa_throughput(),
                                   "evaluator": evaluator_throughput(),
                                   "dse_throughput": dse_throughput(),
                                   "kernels": kernel_bench()}, force)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny uncached end-to-end engine exercise (CI)")
    ap.add_argument("--fanout", action="store_true",
                    help="uncached (candidate x workload) fan-out "
                    "throughput run (16 candidates x 4 workloads)")
    ap.add_argument("--retune", action="store_true",
                    help="replica-exchange t_ladder/swap_every sweep on "
                    "the quick Table-I grid (sets core/sa.py defaults)")
    ap.add_argument("--parity", action="store_true",
                    help="batched-vs-scalar parity gate on the tiny grid "
                    "(CI bench-smoke job)")
    ap.add_argument("--fused-parity", action="store_true",
                    help="fused jitted pass vs exact engine objective "
                    "parity across the quick workload zoo (CI bench-smoke "
                    "job; asserts the documented ~1e-4 envelope)")
    ap.add_argument("--dse-bench", action="store_true",
                    help="screening/SA/sweep before-vs-after measurement "
                    "(the BENCH_dse.json payload; see benchmarks/run.py "
                    "--json)")
    ap.add_argument("--quick", action="store_true",
                    help="with --dse-bench: fewer timing rounds (CI)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        dse_smoke()
    elif args.parity:
        batched_parity()
    elif args.fused_parity:
        fused_parity()
    elif args.dse_bench:
        dse_bench(quick=args.quick)
    elif args.fanout:
        dse_throughput(n_candidates=16, n_workers=4, iters=600,
                       n_workloads=4)
    elif args.retune:
        re_tuning()
    else:
        main(force=args.force)
