"""Fig. 8: reuse a single chiplet across accelerators of different scales.

Four construction schemes for two compute targets (72 & 288 TOPS here —
trimmed from the paper's 128/512 to keep the 1-core runtime sane; the ratio
between scales, 4x, matches the paper's):
  1. built from Simba chiplets,
  2. built from the other scale's optimal chiplet,
  3. joint-optimal single chiplet for both scales,
  4. per-scale individual optimal.
Claim to validate: 1 and 2 are clearly worse; the joint optimum sits within
a modest gap (paper: ~34% on MC*E*D) of the individual optima.
"""

from __future__ import annotations

import math
from typing import Dict, List

from repro.core.dse import DSEConfig, evaluate_candidate, grid_candidates
from repro.core.hw import ArchConfig, simba_arch
from repro.core.sa import SAConfig
from repro.core.workloads import transformer

from .common import cached

SCALES = {"72T": 1, "288T": 4}     # chiplet-count multipliers of the base


def _tile(base: ArchConfig, s: int) -> ArchConfig:
    sx = int(math.isqrt(s))
    while s % sx:
        sx -= 1
    sy = s // sx
    return base.replace(x_cores=base.x_cores * sx, y_cores=base.y_cores * sy,
                        xcut=base.xcut * sx, ycut=base.ycut * sy,
                        dram_bw=base.dram_bw * s)


def _run() -> Dict:
    workloads = {"TF": transformer()}
    cfg = DSEConfig(batch=64, sa=SAConfig(iters=1000, seed=0))
    # base (single-chiplet) candidates at 72 TOPS
    bases: List[ArchConfig] = []
    for x, y, macs in ((6, 6, 1024), (6, 3, 2048), (4, 4, 2048)):
        for glb in (1024, 2048):
            bases.append(ArchConfig(x_cores=x, y_cores=y, xcut=1, ycut=1,
                                    noc_bw=32, d2d_bw=16, dram_bw=144,
                                    glb_kb=glb, macs_per_core=macs))
    # individual optimal per scale
    out: Dict = {"schemes": {}}
    indiv: Dict[str, Dict] = {}
    for sname, s in SCALES.items():
        best = None
        for b in bases:
            pt = evaluate_candidate(_tile(b, s), workloads, cfg)
            if best is None or pt.objective < best[1].objective:
                best = (b, pt)
        indiv[sname] = {"base": best[0].label(), "obj": best[1].objective,
                        "mc": best[1].mc, "E": best[1].energy_j,
                        "D": best[1].delay_s}
        print(f"[fig8] individual optimal {sname}: {best[0].label()}",
              flush=True)
    out["schemes"]["individual"] = indiv

    # joint: one base minimizing the product across scales
    joint_best = None
    for b in bases:
        prod = 1.0
        for s in SCALES.values():
            prod *= evaluate_candidate(_tile(b, s), workloads, cfg).objective
        if joint_best is None or prod < joint_best[1]:
            joint_best = (b, prod)
    jb = joint_best[0]
    joint = {}
    for sname, s in SCALES.items():
        pt = evaluate_candidate(_tile(jb, s), workloads, cfg)
        joint[sname] = {"obj": pt.objective, "mc": pt.mc,
                        "E": pt.energy_j, "D": pt.delay_s}
    out["schemes"]["joint"] = {"base": jb.label(), **joint}
    print(f"[fig8] joint optimal base: {jb.label()}", flush=True)

    # Simba chiplets tiled to each scale (Simba chiplet = 1 core, 2 TOPS)
    simba = {}
    sb = simba_arch().replace(xcut=1, ycut=1, x_cores=1, y_cores=1,
                              dram_bw=4.0)
    for sname, s in SCALES.items():
        n = 36 * s
        import math as m
        x = int(m.isqrt(n))
        while n % x:
            x -= 1
        arch = sb.replace(x_cores=x, y_cores=n // x, xcut=x, ycut=n // x,
                          dram_bw=2.0 * 72 * s)
        pt = evaluate_candidate(arch, workloads, cfg)
        simba[sname] = {"obj": pt.objective, "mc": pt.mc,
                        "E": pt.energy_j, "D": pt.delay_s}
    out["schemes"]["simba"] = simba
    return out


def main(force: bool = False) -> Dict:
    data = cached("fig8_reuse", _run, force)
    gaps = []
    for sname in SCALES:
        ind = data["schemes"]["individual"][sname]["obj"]
        jnt = data["schemes"]["joint"][sname]["obj"]
        sim = data["schemes"]["simba"][sname]["obj"]
        gaps.append(jnt / ind)
        print(f"[fig8] {sname}: joint/individual objective = {jnt/ind:.2f}x "
              f"(paper ~1.34x avg); simba/individual = {sim/ind:.2f}x "
              f"(paper: much worse)")
    import math
    print(f"[fig8] avg joint gap: {math.prod(gaps)**(1/len(gaps)):.2f}x")
    return data


if __name__ == "__main__":
    main()
