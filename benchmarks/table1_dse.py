"""Table I / Sec. VI-B: the 72-TOPS architecture DSE.

Runs through the exploration engine (``repro.core.explore``): the T-Map
screening stage scores every Table-I candidate analytically and only the
best dozen proceed to the SA mapper (the paper's 80-thread exhaustive SA,
traded for screening on this container), candidates fan out over worker
processes, and the sweep checkpoints to ``results/table1_dse.ckpt.jsonl``
so an interrupted run resumes where it stopped.  Expected outcome: a small
chiplet count (1-4), NoC >= 32 GB/s, GLB >= 2 MB — the neighborhood of the
paper's (2, 36, 144GB/s, 32GB/s, 16GB/s, 2MB, 1024).
"""

from __future__ import annotations

import os
from typing import Dict

from repro.core.dse import DSEConfig, grid_candidates
from repro.core.explore import ExplorationEngine, pareto_frontier
from repro.core.sa import SAConfig
from repro.core.workloads import transformer

from .common import RESULTS, cached

TOPS = 72.0
N_REFINE = 12


def _run(force: bool = False) -> Dict:
    ckpt = RESULTS / "table1_dse.ckpt.jsonl"
    if force and ckpt.exists():
        # the sweep fingerprint versions cfg+workloads, not the cost model:
        # a forced re-measure must not replay checkpointed numbers
        ckpt.unlink()
    workloads = {"TF": transformer()}
    cands = grid_candidates(
        TOPS,
        mac_options=(512, 1024, 2048),
        cut_options=(1, 2, 3, 6),
        dram_per_tops=(1.0, 2.0),
        noc_options=(16, 32, 64),
        d2d_ratio=(0.5, 1.0),
        glb_options=(1024, 2048, 4096))
    print(f"[table1] {len(cands)} candidates (trimmed Table-I grid)")
    cfg = DSEConfig(batch=64, sa=SAConfig(iters=1500, seed=0))
    n_workers = max(1, min(4, os.cpu_count() or 1))
    RESULTS.mkdir(exist_ok=True)
    with ExplorationEngine(workloads, cfg, n_workers=n_workers,
                           checkpoint=ckpt, progress=True) as eng:
        refined = eng.run(cands, use_sa=True,
                          screen_keep=N_REFINE / len(cands))
        screen = eng.last_screen or []
    best = refined[0]
    frontier = pareto_frontier(refined)
    return {
        "n_candidates": len(cands),
        "n_workers": n_workers,
        "screen_top5": [[p.arch.label(), p.objective] for p in screen[:5]],
        "best_arch": best.arch.label(),
        "best": {"mc": best.mc, "E": best.energy_j, "D": best.delay_s,
                 "objective": best.objective},
        "best_params": {
            "chiplets": best.arch.n_chiplets, "cores": best.arch.n_cores,
            "dram_bw": best.arch.dram_bw, "noc_bw": best.arch.noc_bw,
            "d2d_bw": best.arch.d2d_bw, "glb_kb": best.arch.glb_kb,
            "macs": best.arch.macs_per_core},
        "refined": [[p.arch.label(), p.objective] for p in refined],
        "pareto_mc_e_d": [[p.arch.label(), p.mc, p.energy_j, p.delay_s]
                          for p in frontier],
    }


def main(force: bool = False) -> Dict:
    data = cached("table1_dse", lambda: _run(force), force)
    bp = data["best_params"]
    print(f"[table1] best 72-TOPS arch: {data['best_arch']} "
          f"(paper: (2, 36, 144GB/s, 32GB/s, 16GB/s, 2MB, 1024))")
    ok_granularity = bp["chiplets"] <= 4
    print(f"[table1] moderate chiplet granularity found: {ok_granularity} "
          f"({bp['chiplets']} chiplets)")
    print(f"[table1] (MC, E, D) Pareto frontier of the refined set: "
          f"{len(data['pareto_mc_e_d'])} points")
    return data


if __name__ == "__main__":
    main()
