"""Table I / Sec. VI-B: the 72-TOPS architecture DSE.

Two-phase acceleration for the 1-core container (deviation from the paper's
80-thread exhaustive SA): phase 1 screens every Table-I candidate with T-Map
(fast analytic evaluation), phase 2 refines the best 12 with the SA mapper.
Expected outcome: a small chiplet count (1-4), NoC >= 32 GB/s, GLB >= 2 MB —
the neighborhood of the paper's (2, 36, 144GB/s, 32GB/s, 16GB/s, 2MB, 1024).
"""

from __future__ import annotations

from typing import Dict

from repro.core.dse import DSEConfig, grid_candidates, run_dse
from repro.core.sa import SAConfig
from repro.core.workloads import transformer

from .common import cached

TOPS = 72.0


def _run() -> Dict:
    workloads = {"TF": transformer()}
    cands = grid_candidates(
        TOPS,
        mac_options=(512, 1024, 2048),
        cut_options=(1, 2, 3, 6),
        dram_per_tops=(1.0, 2.0),
        noc_options=(16, 32, 64),
        d2d_ratio=(0.5, 1.0),
        glb_options=(1024, 2048, 4096))
    print(f"[table1] {len(cands)} candidates (trimmed Table-I grid)")
    cfg = DSEConfig(batch=64, sa=SAConfig(iters=1500, seed=0))
    screen = run_dse(cands, workloads, cfg, use_sa=False)
    short = [p.arch for p in screen[:12]]
    refined = run_dse(short, workloads, cfg, use_sa=True, progress=True)
    best = refined[0]
    return {
        "n_candidates": len(cands),
        "screen_top5": [[p.arch.label(), p.objective] for p in screen[:5]],
        "best_arch": best.arch.label(),
        "best": {"mc": best.mc, "E": best.energy_j, "D": best.delay_s,
                 "objective": best.objective},
        "best_params": {
            "chiplets": best.arch.n_chiplets, "cores": best.arch.n_cores,
            "dram_bw": best.arch.dram_bw, "noc_bw": best.arch.noc_bw,
            "d2d_bw": best.arch.d2d_bw, "glb_kb": best.arch.glb_kb,
            "macs": best.arch.macs_per_core},
        "refined": [[p.arch.label(), p.objective] for p in refined],
    }


def main(force: bool = False) -> Dict:
    data = cached("table1_dse", _run, force)
    bp = data["best_params"]
    print(f"[table1] best 72-TOPS arch: {data['best_arch']} "
          f"(paper: (2, 36, 144GB/s, 32GB/s, 16GB/s, 2MB, 1024))")
    ok_granularity = bp["chiplets"] <= 4
    print(f"[table1] moderate chiplet granularity found: {ok_granularity} "
          f"({bp['chiplets']} chiplets)")
    return data


if __name__ == "__main__":
    main()
