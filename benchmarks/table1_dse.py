"""Table I / Sec. VI-B: the 72-TOPS architecture DSE.

Runs through the exploration engine (``repro.core.explore``): the T-Map
screening stage scores every Table-I candidate analytically and only the
best dozen proceed to the SA mapper (the paper's 80-thread exhaustive SA,
traded for screening on this container), (candidate x workload) tasks fan
out over worker processes, and the sweep checkpoints to a
``ResumableSweep`` JSONL so an interrupted run resumes where it stopped.
Expected outcome: a small chiplet count (1-4), NoC >= 32 GB/s, GLB >= 2 MB
— the neighborhood of the paper's (2, 36, 144GB/s, 32GB/s, 16GB/s, 2MB,
1024).

The sweep also shards (``--shard i/n`` evaluates candidates with
``index % n == i`` into an independent checkpoint) and merges
(``--merge shard1.jsonl shard2.jsonl ... --checkpoint merged.jsonl``), so
CI runs the real DSE as a matrix of shard jobs whose merged result is
bit-identical to the unsharded sweep:

  python -m benchmarks.table1_dse --quick --shard 0/3     # one matrix job
  python -m benchmarks.table1_dse --quick --merge results/*.shard*of3.ckpt.jsonl \
      --checkpoint results/merged.ckpt.jsonl              # merge job
  python -m benchmarks.table1_dse --quick --checkpoint results/merged.ckpt.jsonl \
      --out results/merged.json --expect results/fresh.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
from pathlib import Path
from typing import Dict, Optional, Tuple, Union

from repro.core.dse import DSEConfig, grid_candidates
from repro.core.explore import (ExplorationEngine, merge_checkpoints,
                                pareto_frontier, parse_shard_spec)
from repro.core.sa import SAConfig
from repro.core.workloads import make_workload, transformer
from repro.launch.cli import add_workload_args, parse_kv, workload_bindings

from .common import RESULTS, cached

TOPS = 72.0
N_REFINE = 12


def _setup(quick: bool):
    """(candidates, workloads, cfg, screen_keep) for the two run modes."""
    if quick:
        cands = grid_candidates(
            TOPS, mac_options=(512, 1024), cut_options=(1, 2),
            dram_per_tops=(2.0,), noc_options=(16, 32), d2d_ratio=(0.5,),
            glb_options=(1024, 2048))
        wl = {"TF": transformer(n_layers=2, d_model=128, d_ff=256, seq=64,
                                name="tf-s")}
        cfg = DSEConfig(batch=8, sa=SAConfig(iters=150, seed=0))
        return cands, wl, cfg, 0.5
    cands = grid_candidates(
        TOPS,
        mac_options=(512, 1024, 2048),
        cut_options=(1, 2, 3, 6),
        dram_per_tops=(1.0, 2.0),
        noc_options=(16, 32, 64),
        d2d_ratio=(0.5, 1.0),
        glb_options=(1024, 2048, 4096))
    wl = {"TF": transformer()}
    cfg = DSEConfig(batch=64, sa=SAConfig(iters=1500, seed=0))
    return cands, wl, cfg, None            # None -> N_REFINE / len(cands)


def default_checkpoint(quick: bool, shard: Tuple[int, int]) -> Path:
    tag = "table1_quick" if quick else "table1_dse"
    si, sn = shard
    suffix = f".shard{si}of{sn}" if sn > 1 else ""
    return RESULTS / f"{tag}{suffix}.ckpt.jsonl"


def _run(quick: bool = False, shard: Tuple[int, int] = (0, 1),
         checkpoint: Optional[Path] = None, force: bool = False,
         n_workers: Optional[int] = None,
         screen: Union[None, float, str] = None,
         workloads_cli: Optional[Dict[str, str]] = None,
         weights: Optional[Dict[str, float]] = None,
         objective: Optional[str] = None,
         traffic: Optional[str] = None) -> Dict:
    cands, workloads, cfg, keep = _setup(quick)
    if workloads_cli:
        # --workload NAME=SPEC replaces the default workload set entirely:
        # mixing defaults with explicit portfolios invites half-specified
        # sweeps whose fingerprints surprise
        workloads = {name: make_workload(spec)
                     for name, spec in workloads_cli.items()}
    if weights:
        cfg = dataclasses.replace(cfg, workload_weights=dict(weights))
    if objective:
        cfg = dataclasses.replace(cfg, objective=objective)
    if traffic:
        cfg = dataclasses.replace(cfg, traffic=traffic)
    ckpt = Path(checkpoint) if checkpoint else default_checkpoint(quick, shard)
    if force and ckpt.exists():
        # the sweep fingerprint versions cfg+workloads, not the cost model:
        # a forced re-measure must not replay checkpointed numbers
        ckpt.unlink()
    if screen is not None:
        # explicit --screen: a fraction, or 'auto' for the adaptive gap
        # rule (unsharded runs only — see ExplorationEngine.run)
        keep = screen
    elif keep is None:
        keep = N_REFINE / len(cands)
    if n_workers is None:
        n_workers = max(1, min(4, os.cpu_count() or 1))
    si, sn = shard
    print(f"[table1] {len(cands)} candidates "
          f"({'quick' if quick else 'trimmed Table-I'} grid), "
          f"shard {si}/{sn}, checkpoint {ckpt}")
    RESULTS.mkdir(exist_ok=True)
    with ExplorationEngine(workloads, cfg, n_workers=n_workers,
                           checkpoint=ckpt, progress=True) as eng:
        refined = eng.run(cands, use_sa=True, screen_keep=keep, shard=shard)
        screen = eng.last_screen or []
    # a shard can legitimately own zero of the screened-kept candidates;
    # its contribution is then just the (empty) checkpoint
    best = refined[0] if refined else None
    frontier = pareto_frontier(refined)
    return {
        "n_candidates": len(cands),
        "n_workers": n_workers,
        "shard": f"{si}/{sn}",
        "quick": quick,
        **({"objective": cfg.objective, "traffic": str(cfg.traffic),
            "best_slo": best.slo if best else None}
           if cfg.objective != "geomean" else {}),
        "screen_top5": [[p.arch.label(), p.objective] for p in screen[:5]],
        "best_arch": best.arch.label() if best else None,
        "best": ({"mc": best.mc, "E": best.energy_j, "D": best.delay_s,
                  "objective": best.objective} if best else None),
        "best_params": ({
            "chiplets": best.arch.n_chiplets, "cores": best.arch.n_cores,
            "dram_bw": best.arch.dram_bw, "noc_bw": best.arch.noc_bw,
            "d2d_bw": best.arch.d2d_bw, "glb_kb": best.arch.glb_kb,
            "macs": best.arch.macs_per_core} if best else None),
        "refined": [[p.arch.label(), p.objective] for p in refined],
        "pareto_mc_e_d": [[p.arch.label(), p.mc, p.energy_j, p.delay_s]
                          for p in frontier],
    }


def _check_expected(data: Dict, expect_path: str) -> None:
    """Assert this run's best/Pareto set is bit-identical to a previous
    run's JSON output (the CI merge job's merged-vs-fresh comparison).
    A normalizing JSON round-trip makes fresh floats comparable to loaded
    ones (repr round-trips doubles exactly)."""
    expect = json.loads(Path(expect_path).read_text())
    got = json.loads(json.dumps(data))
    mismatches = [k for k in ("best_arch", "best", "refined", "pareto_mc_e_d")
                  if got[k] != expect[k]]
    if mismatches:
        for k in mismatches:
            print(f"[table1] MISMATCH {k}:\n  got      {got[k]}\n"
                  f"  expected {expect[k]}")
        raise SystemExit(f"[table1] run diverges from {expect_path} "
                         f"on {mismatches}")
    print(f"[table1] bit-identical to {expect_path} "
          "(best, refined set, Pareto frontier)")


def main(force: bool = False) -> Dict:
    """Programmatic entry (benchmarks/run.py): cached full-grid sweep."""
    data = cached("table1_dse", lambda: _run(force=force), force)
    bp = data["best_params"]
    print(f"[table1] best 72-TOPS arch: {data['best_arch']} "
          f"(paper: (2, 36, 144GB/s, 32GB/s, 16GB/s, 2MB, 1024))")
    ok_granularity = bp["chiplets"] <= 4
    print(f"[table1] moderate chiplet granularity found: {ok_granularity} "
          f"({bp['chiplets']} chiplets)")
    print(f"[table1] (MC, E, D) Pareto frontier of the refined set: "
          f"{len(data['pareto_mc_e_d'])} points")
    return data


def cli() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="tiny grid + short SA, sized for a CI matrix job")
    ap.add_argument("--shard", default="0/1", metavar="i/n",
                    help="evaluate only candidates with index %% n == i")
    ap.add_argument("--checkpoint", default=None,
                    help="sweep checkpoint path (default derives from "
                    "--quick/--shard); with --merge: the merge output")
    ap.add_argument("--merge", nargs="+", metavar="SHARD.jsonl",
                    help="merge shard checkpoints into --checkpoint and exit")
    ap.add_argument("--out", default=None,
                    help="write the run's result JSON here (bypasses the "
                    "bench_table1_dse.json cache)")
    ap.add_argument("--expect", default=None,
                    help="assert best/refined/Pareto match this result JSON")
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--screen", default=None,
                    help="screening mode: a keep fraction (0..1] or 'auto' "
                    "for the adaptive gap rule (unsharded runs only); "
                    "default derives from --quick / N_REFINE")
    add_workload_args(ap, help_extra="Replaces the default workload set "
                      "entirely.")
    ap.add_argument("--weight", action="append", metavar="NAME=W",
                    help="portfolio traffic-share weight for workload NAME "
                    "(repeatable); turns the reduction into the weighted "
                    "geomean and stamps the weights into the sweep "
                    "fingerprint")
    ap.add_argument("--objective", choices=("geomean", "slo"), default=None,
                    help="candidate scoring: historical MC^a*E^b*D^g "
                    "geomean, or 'slo' — predicted p99 e2e latency under "
                    "--traffic replaces the raw delay term (stamped into "
                    "the sweep fingerprint)")
    ap.add_argument("--traffic", default=None, metavar="MODEL",
                    help="traffic model for --objective slo: a registered "
                    "name (chat-quick, diurnal-quick) or a trace spec — "
                    "see repro.serve.slo")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    screen: Union[None, float, str] = None
    if args.screen is not None:
        screen = "auto" if args.screen == "auto" else float(args.screen)
    workloads_cli = workload_bindings(args.workload) or None
    weights = parse_kv(args.weight, float, "--weight")

    if args.merge:
        if not args.checkpoint:
            raise SystemExit("--merge needs --checkpoint for the output")
        merge_checkpoints(args.merge, out=args.checkpoint)
        return

    shard = parse_shard_spec(args.shard)
    if args.quick or shard != (0, 1) or args.out or args.checkpoint \
            or screen is not None or workloads_cli or weights \
            or args.objective or args.traffic:
        data = _run(quick=args.quick, shard=shard,
                    checkpoint=args.checkpoint, force=args.force,
                    n_workers=args.workers, screen=screen,
                    workloads_cli=workloads_cli, weights=weights,
                    objective=args.objective, traffic=args.traffic)
        if data["best"] is not None:
            print(f"[table1] shard best: {data['best_arch']} "
                  f"obj={data['best']['objective']:.3e} "
                  f"({len(data['refined'])} refined, "
                  f"{len(data['pareto_mc_e_d'])} Pareto)")
        else:
            print("[table1] shard owned no screened-kept candidates "
                  "(checkpoint written; nothing to refine)")
        if args.out:
            out = Path(args.out)
            out.parent.mkdir(parents=True, exist_ok=True)
            out.write_text(json.dumps(data, indent=1, default=float))
            print(f"[table1] results -> {out}")
        if args.expect:
            _check_expected(data, args.expect)
    else:
        data = main(force=args.force)
        if args.expect:
            _check_expected(data, args.expect)


if __name__ == "__main__":
    cli()
