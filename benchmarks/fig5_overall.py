"""Fig. 5: overall comparison — S-Arch+T-Map vs S-Arch+G-Map vs G-Arch+G-Map
across the five DNNs and two batch sizes.

Paper claims (72 TOPS): G-Arch+G-Map achieves 1.98x performance and 1.41x
energy efficiency over S-Arch+T-Map at +14.3% MC; S-Arch+G-Map alone already
beats S-Arch+T-Map.  This reproduction validates the DIRECTION and rough
magnitude with our re-derived constants (exact C++-evaluator numbers are not
bit-portable).
"""

from __future__ import annotations

import math
from typing import Dict

from repro.core.evaluator import Evaluator
from repro.core.explore import (ResumableSweep, candidate_key,
                                graph_fingerprint)
from repro.core.graph_partition import partition_graph
from repro.core.hw import gemini_arch_72t, simba_arch
from repro.core.mc import evaluate_mc
from repro.core.sa import SAConfig, sa_optimize
from repro.core.tangram import tangram_map
from repro.core.workloads import PAPER_WORKLOADS

from .common import RESULTS, cached

SA_ITERS = 4000
BATCHES = (1, 64)


def _cell(g, batch) -> Dict:
    cell = {}
    for arch_name, arch in (("S-Arch", simba_arch()),
                            ("G-Arch", gemini_arch_72t())):
        groups = partition_graph(g, arch, batch)
        ev = Evaluator(arch, g)
        tmap = tangram_map(groups, g, arch)
        rt = ev.evaluate(tmap, batch)
        cell[f"{arch_name}+T-Map"] = {"E": rt.energy_j,
                                      "D": rt.delay_s}
        res = sa_optimize(g, arch, groups, batch,
                          SAConfig(iters=SA_ITERS, seed=0),
                          init=tmap, evaluator=ev)
        cell[f"{arch_name}+G-Map"] = {"E": res.energy_j,
                                      "D": res.delay_s}
    return cell


def _run(force: bool = False) -> Dict:
    # per-cell resumable sweep: the 10 (DNN x batch) cells each cost one
    # 4000-iteration SA per arch, so a killed run resumes at the cell that
    # was in flight instead of recomputing finished DNNs from scratch
    graphs = {wname: wfn() for wname, wfn in PAPER_WORKLOADS.items()}
    fp = ("fig5:v1:iters{}:b{}:archs({},{}):wl={}".format(
        SA_ITERS, ",".join(map(str, BATCHES)),
        candidate_key(simba_arch()), candidate_key(gemini_arch_72t()),
        ",".join(f"{n}:{graph_fingerprint(g)}"
                 for n, g in sorted(graphs.items()))))
    RESULTS.mkdir(exist_ok=True)
    sweep = ResumableSweep(RESULTS / "fig5_overall.ckpt.jsonl", fp,
                           resume=not force)
    out: Dict = {"cells": {}}
    for wname, g in graphs.items():
        for batch in BATCHES:
            key = f"{wname}/b{batch}"
            cell = sweep.get(key)
            if cell is None:
                cell = _cell(g, batch)
                sweep.add(key, cell)
            out["cells"][key] = cell
            print(f"[fig5] {key}: "
                  f"perf x{cell['S-Arch+T-Map']['D'] / cell['G-Arch+G-Map']['D']:.2f} "
                  f"eff x{cell['S-Arch+T-Map']['E'] / cell['G-Arch+G-Map']['E']:.2f}",
                  flush=True)
    out["mc"] = {"S-Arch": evaluate_mc(simba_arch()).total,
                 "G-Arch": evaluate_mc(gemini_arch_72t()).total}
    return out


def summarize(data: Dict) -> Dict[str, float]:
    lp = le = lgm_p = lgm_e = 0.0
    n = 0
    for cell in data["cells"].values():
        base = cell["S-Arch+T-Map"]
        best = cell["G-Arch+G-Map"]
        smap = cell["S-Arch+G-Map"]
        lp += math.log(base["D"] / best["D"])
        le += math.log(base["E"] / best["E"])
        lgm_p += math.log(base["D"] / smap["D"])
        lgm_e += math.log(base["E"] / smap["E"])
        n += 1
    mc_ratio = data["mc"]["G-Arch"] / data["mc"]["S-Arch"]
    return {
        "perf_x": math.exp(lp / n),
        "eff_x": math.exp(le / n),
        "gmap_only_perf_x": math.exp(lgm_p / n),
        "gmap_only_eff_x": math.exp(lgm_e / n),
        "mc_increase_pct": (mc_ratio - 1) * 100,
    }


def main(force: bool = False) -> Dict:
    data = cached("fig5_overall", lambda: _run(force), force)
    s = summarize(data)
    print(f"[fig5] GEOMEAN: G-Arch+G-Map vs S-Arch+T-Map: "
          f"perf x{s['perf_x']:.2f} (paper 1.98x), "
          f"energy eff x{s['eff_x']:.2f} (paper 1.41x), "
          f"MC {s['mc_increase_pct']:+.1f}% (paper +14.3%)")
    print(f"[fig5] S-Arch+G-Map alone: perf x{s['gmap_only_perf_x']:.2f}, "
          f"eff x{s['gmap_only_eff_x']:.2f} (paper: 'significant')")
    return {**data, "summary": s}


if __name__ == "__main__":
    main()
