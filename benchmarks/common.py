"""Shared benchmark scaffolding: result caching + trimmed DSE settings.

The paper's DSEs ran on 80-100 Xeon threads; this container has ONE core,
so benchmarks use (a) cached results under results/bench_*.json, (b) a
two-phase DSE (T-Map screening pass over the full grid, SA refinement on
the shortlist) and (c) reduced SA iteration counts.  Every deviation is
printed with the result it affects.
"""

from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Callable, Dict

RESULTS = Path(__file__).resolve().parent.parent / "results"


def cached(name: str, fn: Callable[[], Dict], force: bool = False) -> Dict:
    RESULTS.mkdir(exist_ok=True)
    path = RESULTS / f"bench_{name}.json"
    if path.exists() and not force:
        return json.loads(path.read_text())
    t0 = time.time()
    out = fn()
    out["_wall_s"] = time.time() - t0
    path.write_text(json.dumps(out, indent=1, default=float))
    return out


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
