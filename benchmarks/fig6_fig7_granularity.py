"""Fig. 6 + Fig. 7: chiplet-granularity and core-granularity sweeps at a
fixed compute budget, plus optima under four optimization objectives.

Paper insights to validate:
  (6a) moderate chiplet partitioning ~= monolithic EDP at lower/similar MC;
       overly fine partitions hurt MC *and* EDP simultaneously.
  (6b) EDP improves as cores shrink (more cores) then regresses; MC rises
       monotonically with core count.
  (7)  optima under MC/E/D exponent variations differ in cores + chiplets.
"""

from __future__ import annotations

from typing import Dict, List

from repro.core.dse import DSEConfig, evaluate_candidate, grid_candidates
from repro.core.hw import ArchConfig
from repro.core.sa import SAConfig
from repro.core.workloads import transformer

from .common import cached

TOPS = 128.0


def _chiplet_sweep() -> List[Dict]:
    """Fix a good 64-core config; sweep the cut granularity."""
    rows = []
    workloads = {"TF": transformer()}
    cfg = DSEConfig(batch=64, sa=SAConfig(iters=1200, seed=0))
    for xcut, ycut in ((1, 1), (2, 1), (2, 2), (4, 2), (4, 4), (8, 8)):
        arch = ArchConfig(x_cores=8, y_cores=8, xcut=xcut, ycut=ycut,
                          noc_bw=32, d2d_bw=16, dram_bw=128, glb_kb=2048,
                          macs_per_core=1024)
        pt = evaluate_candidate(arch, workloads, cfg)
        rows.append({"chiplets": arch.n_chiplets, "mc": pt.mc,
                     "E": pt.energy_j, "D": pt.delay_s, "edp": pt.edp,
                     "label": arch.label()})
        print(f"[fig6a] {arch.n_chiplets:3d} chiplets: MC=${pt.mc:.0f} "
              f"EDP={pt.edp:.3e}", flush=True)
    return rows


def _core_sweep() -> List[Dict]:
    """Fix total TOPS; sweep MAC/core (fewer, fatter cores <-> many thin)."""
    rows = []
    workloads = {"TF": transformer()}
    cfg = DSEConfig(batch=64, sa=SAConfig(iters=1200, seed=0))
    for macs, (x, y) in ((8192, (4, 2)), (4096, (4, 4)), (2048, (8, 4)),
                         (1024, (8, 8)), (512, (16, 8))):
        arch = ArchConfig(x_cores=x, y_cores=y, xcut=2, ycut=1,
                          noc_bw=32, d2d_bw=16, dram_bw=128, glb_kb=2048,
                          macs_per_core=macs)
        pt = evaluate_candidate(arch, workloads, cfg)
        rows.append({"cores": arch.n_cores, "macs": macs, "mc": pt.mc,
                     "E": pt.energy_j, "D": pt.delay_s, "edp": pt.edp})
        print(f"[fig6b] {arch.n_cores:3d} cores x {macs:5d} MACs: "
              f"MC=${pt.mc:.0f} EDP={pt.edp:.3e}", flush=True)
    return rows


def _objective_sweep() -> List[Dict]:
    """Fig. 7: best arch under four (alpha, beta, gamma) objectives."""
    workloads = {"TF": transformer()}
    cands = grid_candidates(
        TOPS, mac_options=(1024, 2048, 4096), cut_options=(1, 2, 4),
        dram_per_tops=(1.0,), noc_options=(32, 64), d2d_ratio=(0.5,),
        glb_options=(2048, 4096))
    rows = []
    for name, (a, b, c) in (("MC*E*D", (1, 1, 1)), ("E*D", (0, 1, 1)),
                            ("MC*E", (1, 1, 0)), ("MC*D", (1, 0, 1))):
        cfg = DSEConfig(alpha=a, beta=b, gamma=c, batch=64,
                        sa=SAConfig(iters=800, seed=0))
        from repro.core.dse import run_dse
        # engine screening: seeds stay tied to the original candidate
        # index, so a reordered screen can't shift which seed an arch gets
        refined = run_dse(cands, workloads, cfg, use_sa=True,
                          screen_keep=6 / len(cands))
        best = refined[0]
        rows.append({"objective": name, "arch": best.arch.label(),
                     "chiplets": best.arch.n_chiplets,
                     "cores": best.arch.n_cores, "mc": best.mc,
                     "E": best.energy_j, "D": best.delay_s})
        print(f"[fig7] {name:8s} -> {best.arch.label()}", flush=True)
    return rows


def _run() -> Dict:
    return {"chiplet_sweep": _chiplet_sweep(),
            "core_sweep": _core_sweep(),
            "objectives": _objective_sweep()}


def main(force: bool = False) -> Dict:
    data = cached("fig6_fig7", _run, force)
    ch = data["chiplet_sweep"]
    mono = next(r for r in ch if r["chiplets"] == 1)
    moderate = min((r for r in ch if 2 <= r["chiplets"] <= 4),
                   key=lambda r: r["edp"])
    finest = max(ch, key=lambda r: r["chiplets"])
    print(f"[fig6a] monolithic EDP={mono['edp']:.3e} MC=${mono['mc']:.0f} | "
          f"moderate({moderate['chiplets']}) EDP={moderate['edp']:.3e} "
          f"MC=${moderate['mc']:.0f} | finest({finest['chiplets']}) "
          f"EDP={finest['edp']:.3e} MC=${finest['mc']:.0f}")
    print(f"[fig6a] moderate-vs-mono EDP penalty: "
          f"{(moderate['edp'] / mono['edp'] - 1) * 100:+.1f}% "
          f"(paper: 'nearly no loss'); finest is worse on BOTH axes: "
          f"{finest['edp'] > moderate['edp'] and finest['mc'] > moderate['mc']}")
    cs = data["core_sweep"]
    mcs = [r["mc"] for r in sorted(cs, key=lambda r: r["cores"])]
    print(f"[fig6b] MC rises with cores: {all(b >= a * 0.98 for a, b in zip(mcs, mcs[1:]))}")
    best_cores = min(cs, key=lambda r: r["edp"])["cores"]
    print(f"[fig6b] EDP-optimal core count: {best_cores} "
          f"(U-shape: interior optimum = "
          f"{best_cores not in (min(r['cores'] for r in cs), max(r['cores'] for r in cs))})")
    return data


if __name__ == "__main__":
    main()
