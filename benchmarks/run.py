"""Benchmark orchestrator: one entry per paper table/figure.

``python -m benchmarks.run [--force] [--only fig5,...]``
prints a ``name,us_per_call,derived`` CSV summary at the end.  Results are
cached under results/bench_*.json (delete or --force to recompute).

``python -m benchmarks.run --json [PATH] [--quick]`` instead measures the
DSE perf trajectory — evaluator / SA / screening throughput, before and
after the batched evaluation engine (the "before" legs are the preserved
per-candidate / serial-loop code paths plus the committed
``benchmarks/pr4_baseline.json`` cross-tree measurement) — and writes it
as machine-readable JSON (default ``BENCH_dse.json`` at the repo root).
CI uploads the file as an artifact on every bench-smoke run.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from .common import csv_line

BENCH_JSON_DEFAULT = Path(__file__).resolve().parent.parent / "BENCH_dse.json"


def write_bench_json(path: Path, quick: bool = False) -> None:
    from . import misc_bench

    t0 = time.time()
    data = misc_bench.dse_bench(quick=quick)
    data["quick_rounds"] = quick
    data["_wall_s"] = time.time() - t0
    path.write_text(json.dumps(data, indent=1, default=float) + "\n")
    print(f"[bench] DSE perf trajectory -> {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--json", nargs="?", const=str(BENCH_JSON_DEFAULT),
                    default=None, metavar="PATH",
                    help="measure the DSE perf trajectory and write "
                    "BENCH_dse.json instead of running the figure suite")
    ap.add_argument("--quick", action="store_true",
                    help="with --json: fewer timing rounds (CI bench-smoke)")
    args = ap.parse_args()
    if args.json is not None:
        write_bench_json(Path(args.json), quick=args.quick)
        return
    only = set(args.only.split(",")) if args.only else None

    lines = []

    def run(name, fn, derived_fn):
        if only and name not in only:
            return
        t0 = time.time()
        data = fn(force=args.force)
        us = data.get("_wall_s", time.time() - t0) * 1e6
        lines.append(csv_line(name, us, derived_fn(data)))

    from . import (fig5_overall, fig6_fig7_granularity, fig8_reuse,
                   fig9_heatmap, misc_bench, table1_dse)

    run("fig5_overall", fig5_overall.main,
        lambda d: (f"perf_x={d['summary']['perf_x']:.2f};"
                   f"eff_x={d['summary']['eff_x']:.2f};"
                   f"mc_pct={d['summary']['mc_increase_pct']:.1f}"))
    run("table1_dse", table1_dse.main,
        lambda d: f"best={d['best_arch'].replace(',', ';')}")
    run("fig6_fig7", fig6_fig7_granularity.main,
        lambda d: f"chiplet_rows={len(d['chiplet_sweep'])};"
                  f"objectives={len(d['objectives'])}")
    run("fig8_reuse", fig8_reuse.main,
        lambda d: "schemes=" + ";".join(sorted(d["schemes"])))
    run("fig9_heatmap", fig9_heatmap.main,
        lambda d: (f"hops_pct={d['hops_reduction_pct']:.1f};"
                   f"d2d_pct={d['d2d_reduction_pct']:.1f}"))
    run("misc", misc_bench.main,
        lambda d: f"sa_iters_per_s={d['sa']['iters_per_s']:.0f}")

    print("\nname,us_per_call,derived")
    for ln in lines:
        print(ln)


if __name__ == "__main__":
    main()
