"""Benchmark orchestrator: one entry per paper table/figure.

``python -m benchmarks.run [--force] [--only fig5,...]``
prints a ``name,us_per_call,derived`` CSV summary at the end.  Results are
cached under results/bench_*.json (delete or --force to recompute).

``python -m benchmarks.run --json [PATH] [--quick]`` instead measures the
DSE perf trajectory — evaluator / SA / screening throughput, before and
after the batched evaluation engine (the "before" legs are the preserved
per-candidate / serial-loop code paths plus the committed
``benchmarks/pr4_baseline.json`` cross-tree measurement) — and writes it
as machine-readable JSON (default ``BENCH_dse.json`` at the repo root).
The document is ``bench_dse/v2``: the top-level snapshot is overwritten
each run, while the ``trajectory`` array is append-only — one headline
row (commit, date, CPU count, iters/s figures) per measurement, with v1
documents migrated in place on the first v2 write.  ``--check-floor``
asserts ``lockstep_sa.speedup`` against the committed regression floor.
CI uploads the file as an artifact on every bench-smoke run.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from .common import csv_line

BENCH_JSON_DEFAULT = Path(__file__).resolve().parent.parent / "BENCH_dse.json"

# Committed regression floor for the lockstep-vs-serial stepping speedup
# (``lockstep_sa.speedup`` in BENCH_dse.json).  Full-rounds measurement on
# this 1-CPU container is ~1.15x; the quick-rounds CI leg is noisier, so
# the floor only asserts lockstep never regresses below the serial loop.
LOCKSTEP_SPEEDUP_FLOOR = 1.0


def make_trajectory_entry(data: dict, commit: str, date: str) -> dict:
    """Pure projection of one dse_bench() snapshot onto a trajectory row.

    Only headline figures — the full snapshot lives at the document's top
    level and is overwritten each run; the trajectory rows are append-only
    so the perf history across commits survives regeneration.
    """
    ls = data.get("lockstep_sa", {})
    return {
        "commit": commit,
        "date": date,
        "cpus": data.get("provenance", {}).get("cpu_count"),
        "screening_cands_per_s":
            data.get("screening", {}).get("batched_cands_per_s"),
        "serial_iters_per_s": ls.get("serial_iters_per_s"),
        "lockstep_iters_per_s": ls.get("lockstep_iters_per_s"),
        "fused_iters_per_s": ls.get("fused_iters_per_s"),
        "lockstep_speedup": ls.get("speedup"),
        "sa_chain_n4_speedup_vs_pr4":
            data.get("vs_pr4", {}).get("sa_chain_n4_speedup"),
        "sweep_n4_wall_s": data.get("sweep_n4", {}).get("wall_s"),
        "serve_replay_req_per_s":
            data.get("serving", {}).get("continuous", {}).get(
                "req_per_wall_s"),
    }


def migrate_bench_doc(doc: dict) -> dict:
    """Migrate a bench_dse/v1 document to v2 (pure; v2 passes through).

    v1 had no ``trajectory``: its single snapshot becomes the first
    trajectory row, tagged ``pre-v2`` since v1 recorded no commit.
    """
    if doc.get("schema") == "bench_dse/v2":
        return doc
    out = dict(doc)
    out["schema"] = "bench_dse/v2"
    out["trajectory"] = [make_trajectory_entry(doc, commit="pre-v2",
                                               date="unknown")]
    return out


def _git_head(repo: Path) -> str:
    try:
        from repro.obs.manifest import git_head
        return git_head(repo)
    except ImportError:
        import subprocess
        try:
            return subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"], cwd=repo,
                capture_output=True, text=True, timeout=10,
            ).stdout.strip() or "unknown"
        except Exception:
            return "unknown"


def write_bench_json(path: Path, quick: bool = False) -> None:
    from datetime import datetime, timezone

    from . import misc_bench

    trajectory = []
    if path.exists():
        try:
            old = migrate_bench_doc(json.loads(path.read_text()))
            trajectory = list(old.get("trajectory", []))
        except (ValueError, OSError):
            pass                     # corrupt/unreadable: start fresh
    t0 = time.time()
    data = misc_bench.dse_bench(quick=quick)
    data["schema"] = "bench_dse/v2"
    data["quick_rounds"] = quick
    data["_wall_s"] = time.time() - t0
    entry = make_trajectory_entry(
        data, commit=_git_head(path.resolve().parent),
        date=datetime.now(timezone.utc).strftime("%Y-%m-%dT%H:%M:%SZ"))
    data["trajectory"] = trajectory + [entry]
    path.write_text(json.dumps(data, indent=1, default=float) + "\n")
    print(f"[bench] DSE perf trajectory -> {path} "
          f"({len(data['trajectory'])} trajectory rows)")


def check_floor(path: Path) -> None:
    """CI regression guard: fail if the freshly measured lockstep stepping
    speedup fell below the committed floor."""
    doc = migrate_bench_doc(json.loads(path.read_text()))
    speedup = doc["lockstep_sa"]["speedup"]
    if speedup < LOCKSTEP_SPEEDUP_FLOOR:
        raise SystemExit(
            f"[bench] FAIL: lockstep_sa.speedup {speedup:.3f} < committed "
            f"floor {LOCKSTEP_SPEEDUP_FLOOR} ({path})")
    print(f"[bench] lockstep_sa.speedup {speedup:.3f} >= floor "
          f"{LOCKSTEP_SPEEDUP_FLOOR}: OK")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--json", nargs="?", const=str(BENCH_JSON_DEFAULT),
                    default=None, metavar="PATH",
                    help="measure the DSE perf trajectory and write "
                    "BENCH_dse.json instead of running the figure suite")
    ap.add_argument("--quick", action="store_true",
                    help="with --json: fewer timing rounds (CI bench-smoke)")
    ap.add_argument("--check-floor", nargs="?", const=str(BENCH_JSON_DEFAULT),
                    default=None, metavar="PATH",
                    help="assert lockstep_sa.speedup in an existing "
                    "BENCH_dse.json meets the committed floor "
                    f"({LOCKSTEP_SPEEDUP_FLOOR}); exits nonzero otherwise")
    args = ap.parse_args()
    if args.check_floor is not None:
        check_floor(Path(args.check_floor))
        return
    if args.json is not None:
        write_bench_json(Path(args.json), quick=args.quick)
        return
    only = set(args.only.split(",")) if args.only else None

    lines = []

    def run(name, fn, derived_fn):
        if only and name not in only:
            return
        t0 = time.time()
        data = fn(force=args.force)
        us = data.get("_wall_s", time.time() - t0) * 1e6
        lines.append(csv_line(name, us, derived_fn(data)))

    from . import (fig5_overall, fig6_fig7_granularity, fig8_reuse,
                   fig9_heatmap, misc_bench, table1_dse)

    run("fig5_overall", fig5_overall.main,
        lambda d: (f"perf_x={d['summary']['perf_x']:.2f};"
                   f"eff_x={d['summary']['eff_x']:.2f};"
                   f"mc_pct={d['summary']['mc_increase_pct']:.1f}"))
    run("table1_dse", table1_dse.main,
        lambda d: f"best={d['best_arch'].replace(',', ';')}")
    run("fig6_fig7", fig6_fig7_granularity.main,
        lambda d: f"chiplet_rows={len(d['chiplet_sweep'])};"
                  f"objectives={len(d['objectives'])}")
    run("fig8_reuse", fig8_reuse.main,
        lambda d: "schemes=" + ";".join(sorted(d["schemes"])))
    run("fig9_heatmap", fig9_heatmap.main,
        lambda d: (f"hops_pct={d['hops_reduction_pct']:.1f};"
                   f"d2d_pct={d['d2d_reduction_pct']:.1f}"))
    run("misc", misc_bench.main,
        lambda d: f"sa_iters_per_s={d['sa']['iters_per_s']:.0f}")

    print("\nname,us_per_call,derived")
    for ln in lines:
        print(ln)


if __name__ == "__main__":
    main()
