"""Benchmark orchestrator: one entry per paper table/figure.

``python -m benchmarks.run [--force] [--only fig5,...]``
prints a ``name,us_per_call,derived`` CSV summary at the end.  Results are
cached under results/bench_*.json (delete or --force to recompute).
"""

from __future__ import annotations

import argparse
import time

from .common import csv_line


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    lines = []

    def run(name, fn, derived_fn):
        if only and name not in only:
            return
        t0 = time.time()
        data = fn(force=args.force)
        us = data.get("_wall_s", time.time() - t0) * 1e6
        lines.append(csv_line(name, us, derived_fn(data)))

    from . import (fig5_overall, fig6_fig7_granularity, fig8_reuse,
                   fig9_heatmap, misc_bench, table1_dse)

    run("fig5_overall", fig5_overall.main,
        lambda d: (f"perf_x={d['summary']['perf_x']:.2f};"
                   f"eff_x={d['summary']['eff_x']:.2f};"
                   f"mc_pct={d['summary']['mc_increase_pct']:.1f}"))
    run("table1_dse", table1_dse.main,
        lambda d: f"best={d['best_arch'].replace(',', ';')}")
    run("fig6_fig7", fig6_fig7_granularity.main,
        lambda d: f"chiplet_rows={len(d['chiplet_sweep'])};"
                  f"objectives={len(d['objectives'])}")
    run("fig8_reuse", fig8_reuse.main,
        lambda d: "schemes=" + ";".join(sorted(d["schemes"])))
    run("fig9_heatmap", fig9_heatmap.main,
        lambda d: (f"hops_pct={d['hops_reduction_pct']:.1f};"
                   f"d2d_pct={d['d2d_reduction_pct']:.1f}"))
    run("misc", misc_bench.main,
        lambda d: f"sa_iters_per_s={d['sa']['iters_per_s']:.0f}")

    print("\nname,us_per_call,derived")
    for ln in lines:
        print(ln)


if __name__ == "__main__":
    main()
