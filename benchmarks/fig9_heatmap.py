"""Fig. 9: network-traffic heatmap — T-Map vs G-Map on the 72-TOPS G-Arch.

Reports total hop-bytes and D2D hop-bytes for both mappings on a Transformer
(the paper's workload), plus an ASCII rendering of per-link load.  Paper
numbers: total hops -34.2%, D2D hops -74%, red/orange hot links eliminated.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.analyzer import d2d_hop_stats, router_grid
from repro.core.evaluator import Evaluator
from repro.core.explore import (ResumableSweep, candidate_key,
                                graph_fingerprint, mapping_from_jsonable,
                                mapping_to_jsonable)
from repro.core.graph_partition import partition_graph
from repro.core.hw import gemini_arch_72t
from repro.core.sa import SAConfig, sa_optimize
from repro.core.tangram import tangram_map
from repro.core.workloads import transformer

from .common import RESULTS, cached

SA_ITERS = 6000


def _ascii_heatmap(arch, edge_bytes: np.ndarray) -> str:
    grid = router_grid(arch)
    mx = edge_bytes.max() or 1.0
    chars = " .:-=+*#%@"
    lines = []
    gw, gh = arch.grid_w, arch.grid_h
    n_h = (gw - 1) * gh
    for y in range(gh):
        row = []
        for x in range(gw - 1):
            e = y * (gw - 1) + x            # eastbound edge
            load = (edge_bytes[e] + edge_bytes[n_h + e]) / (2 * mx)
            row.append(chars[min(int(load * 9.999), 9)])
        lines.append(" ".join(row))
    return "\n".join(lines)


def _run(force: bool = False) -> Dict:
    arch = gemini_arch_72t()
    g = transformer()
    batch = 64
    groups = partition_graph(g, arch, batch)
    ev = Evaluator(arch, g)
    tmap = tangram_map(groups, g, arch)
    rt = ev.evaluate(tmap, batch)
    t_stats = d2d_hop_stats(arch, rt.analyses)
    # the 6000-iteration SA dominates this figure's wall time; its winning
    # mapping checkpoints through the LMS serializer, so a resumed run
    # re-derives every downstream stat from the stored mapping exactly
    RESULTS.mkdir(exist_ok=True)
    sweep = ResumableSweep(
        RESULTS / "fig9_heatmap.ckpt.jsonl",
        f"fig9:v1:iters{SA_ITERS}:b{batch}:{candidate_key(arch)}:"
        f"wl={graph_fingerprint(g)}",
        resume=not force)
    rec = sweep.get("gmap_sa")
    if rec is not None:
        gmap = mapping_from_jsonable(rec["mapping"])
        print(f"[fig9] resumed G-Map SA mapping from {sweep.path}")
    else:
        res = sa_optimize(g, arch, groups, batch,
                          SAConfig(iters=SA_ITERS, seed=0),
                          init=tmap, evaluator=ev)
        gmap = res.mapping
        sweep.add("gmap_sa", {"mapping": mapping_to_jsonable(gmap),
                              "E": res.energy_j, "D": res.delay_s})
    rg = ev.evaluate(gmap, batch)
    g_stats = d2d_hop_stats(arch, rg.analyses)
    t_edges = sum(a.edge_bytes for a in rt.analyses)
    g_edges = sum(a.edge_bytes for a in rg.analyses)
    return {
        "tmap": t_stats, "gmap": g_stats,
        "hops_reduction_pct": 100 * (1 - g_stats["total_hop_bytes"]
                                     / t_stats["total_hop_bytes"]),
        "d2d_reduction_pct": 100 * (1 - g_stats["d2d_hop_bytes"]
                                    / t_stats["d2d_hop_bytes"]),
        "delay_ratio": rt.delay_s / rg.delay_s,
        "tmap_heat": _ascii_heatmap(arch, t_edges),
        "gmap_heat": _ascii_heatmap(arch, g_edges),
        "tmap_max_link": float(t_edges.max()),
        "gmap_max_link": float(g_edges.max()),
    }


def main(force: bool = False) -> Dict:
    d = cached("fig9_heatmap", lambda: _run(force), force)
    print(f"[fig9] total hop-bytes: {d['hops_reduction_pct']:+.1f}% "
          f"(paper -34.2%), D2D hop-bytes: {d['d2d_reduction_pct']:+.1f}% "
          f"(paper -74%), hottest link {d['tmap_max_link']/d['gmap_max_link']:.2f}x cooler")
    print("[fig9] T-Map east-link heat:")
    print(d["tmap_heat"])
    print("[fig9] G-Map east-link heat:")
    print(d["gmap_heat"])
    return d


if __name__ == "__main__":
    main()
