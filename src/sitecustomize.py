"""Process-wide compat hook, auto-imported wherever ``PYTHONPATH=src``.

The ``site`` module imports ``sitecustomize`` at interpreter startup when
one is importable, and the tier-1 test command plus every subprocess the
tests spawn run with ``src`` on ``PYTHONPATH`` — early enough to bridge
jax/hypothesis gaps BEFORE user code runs ``from jax import shard_map``
(see ``_repro_bootstrap`` for the hooks; ``REPRO_NO_JAX_COMPAT=1``
disables the jax one).

Because ``src`` precedes site-packages on ``sys.path``, this file shadows
any sitecustomize the Python distribution ships; after installing our
hooks we locate and execute that shadowed module so its startup
customization still runs.
"""

import os
import sys

import _repro_bootstrap

_repro_bootstrap.install()

# chain to a shadowed system/venv sitecustomize, if any
_here = os.path.dirname(os.path.abspath(__file__))
for _p in sys.path:
    try:
        if os.path.abspath(_p or ".") == _here:
            continue
        _cand = os.path.join(_p or ".", "sitecustomize.py")
        if os.path.exists(_cand):
            import importlib.util

            _spec = importlib.util.spec_from_file_location(
                "_shadowed_sitecustomize", _cand)
            _mod = importlib.util.module_from_spec(_spec)
            _spec.loader.exec_module(_mod)
            break
    except Exception:
        break  # never take the interpreter down from a startup hook
