"""Deterministic synthetic token pipeline.

Batches are a pure function of (seed, step, host) — counter-based hashing
(no stored RNG state), so the iterator is trivially checkpointable and
restart-exact: resuming at step k yields bit-identical batches regardless of
crash history or host count changes (elastic restarts re-derive their shard
from the new topology).  A background prefetch thread keeps the host busy.

The token stream mimics packed LM training data: documents of hash-derived
lengths, EOS-separated, next-token labels, loss mask off at padding.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    eos_id: int = 0
    mean_doc_len: int = 256
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def _hash_u64(x: np.ndarray, seed: int) -> np.ndarray:
    """SplitMix64 — counter-based, vectorized."""
    seed_mix = np.uint64((seed * 0x9E3779B97F4A7C15) % (1 << 64))
    z = (x.astype(np.uint64) + seed_mix) \
        + np.uint64(0x9E3779B97F4A7C15)
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def make_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Pure: (cfg, step) -> {"tokens", "labels", "mask"} for THIS host.

    Sequences are a noisy Markov chain: 75% of transitions follow the fixed
    affine map ``t -> (a*t + b) mod V`` and 25% jump to a hash-random token,
    with EOS document boundaries.  A model can therefore push its loss well
    below the uniform entropy floor (training tests rely on this), while
    every batch stays a pure function of (seed, step, host).
    """
    B, S = cfg.host_batch, cfg.seq_len
    V = max(2, cfg.vocab - 1)
    row0 = (step * cfg.global_batch + cfg.host_id * B)
    rows = row0 + np.arange(B, dtype=np.int64)
    cols = np.arange(S + 1, dtype=np.int64)
    grid = rows[:, None] * np.int64(1_000_003) + cols[None, :]
    rand = (_hash_u64(grid, cfg.seed) % np.uint64(V)).astype(np.int64)
    jump = (_hash_u64(grid * np.int64(104_729), cfg.seed + 3)
            % np.uint64(4)) == 0            # 25% random jumps
    bnd = (_hash_u64(grid * np.int64(7919), cfg.seed + 1)
           % np.uint64(cfg.mean_doc_len)) == 0
    a, b = 31, 17
    toks = np.empty((B, S + 1), dtype=np.int64)
    toks[:, 0] = rand[:, 0]
    for i in range(1, S + 1):
        det = (a * toks[:, i - 1] + b) % V
        toks[:, i] = np.where(jump[:, i], rand[:, i], det)
    toks = np.where(bnd, np.int64(cfg.eos_id), toks + 1)
    toks = np.minimum(toks, V).astype(np.int32)
    tokens = toks[:, :S]
    labels = toks[:, 1:S + 1]
    mask = np.ones((B, S), dtype=np.float32)
    return {"tokens": tokens, "labels": labels.astype(np.int32), "mask": mask}


def make_embeds_batch(cfg: DataConfig, step: int, d_model: int,
                      need_tokens: bool = False) -> Dict[str, np.ndarray]:
    """Frontend-stub variant: deterministic embeddings + labels."""
    base = make_batch(cfg, step)
    B, S = cfg.host_batch, cfg.seq_len
    flat = _hash_u64(
        (np.arange(B * S * 8, dtype=np.int64)
         + np.int64(step) * np.int64(B * S * 8)), cfg.seed + 2)
    u = (flat.astype(np.float64) / 2**64).astype(np.float32)
    proj = np.resize(u * 2 - 1, (B, S, d_model)) * 0.02
    out = {"embeds": proj, "labels": base["labels"], "mask": base["mask"]}
    if need_tokens:
        out["tokens"] = base["tokens"]
    return out


class Prefetcher:
    """Background thread that stays ``depth`` batches ahead."""

    def __init__(self, fn, start_step: int, depth: int = 2):
        self._fn = fn
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._error: BaseException | None = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        try:
            while not self._stop.is_set():
                batch = self._fn(step)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, batch), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1
        except BaseException as e:  # noqa: BLE001 — surfaced in next()
            self._error = e

    def next(self):
        """Blocking get that re-raises worker exceptions instead of hanging."""
        while True:
            try:
                return self._q.get(timeout=0.5)
            except queue.Empty:
                if self._error is not None:
                    raise RuntimeError("data pipeline worker died") \
                        from self._error
                if not self._thread.is_alive():
                    raise RuntimeError("data pipeline worker exited")

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
