import os
import sys


def _preparse_host_devices(default: int = 16) -> int:
    """--host-devices must take effect BEFORE the first jax import (jax
    locks the device count on first init), so it is pre-parsed from argv."""
    for i, a in enumerate(sys.argv):
        if a == "--host-devices" and i + 1 < len(sys.argv):
            return int(sys.argv[i + 1])
        if a.startswith("--host-devices="):
            return int(a.split("=", 1)[1])
    return default


if __name__ == "__main__" and "jax" not in sys.modules:
    _n = _preparse_host_devices()
    if _n > 0:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={_n}").strip()

"""Realization driver: DSE checkpoint -> compiled sharded programs ->
measured-vs-predicted report -> Tech overlay (paper loop closure).

Usage (CPU, interpret-mode Pallas):

  PYTHONPATH=src python -m repro.launch.realize \
      --ckpt results/table1_quick.ckpt.jsonl --workload TF=tf-quick \
      --top 2 --calibrate --out results/realize.jsonl

The sweep is resumable like every other driver: one JSONL record per
realized candidate, keyed by the checkpoint's task key; re-runs skip
records already measured (--force re-measures).  --calibrate fits the
Tech overlay from every record in the sweep (resumed ones included) and
writes it next to the report; feed it back with
``realize.calibrate.load_overlay`` + ``calibrated_candidates`` for the
measured-calibrated second DSE pass.
"""

import argparse
import time
from pathlib import Path
from typing import List


def _device_pool(mesh_spec: str):
    import jax
    from .mesh import DRYRUN_ENV_FIX, make_production_mesh
    if mesh_spec == "host":
        return list(jax.devices())
    if mesh_spec in ("production", "production2"):
        mesh = make_production_mesh(multi_pod=(mesh_spec == "production2"))
        return list(mesh.devices.flat)
    n = int(mesh_spec)
    devs = list(jax.devices())
    if len(devs) < n:
        raise SystemExit(
            f"--mesh {n} asks for {n} devices, host has {len(devs)} "
            f"(pass --host-devices >= {n}; {DRYRUN_ENV_FIX})")
    return devs[:n]


def _print_report(rep) -> None:
    print(f"[realize] {rep.arch_label} x {rep.workload} "
          f"(batch_unit={rep.batch_unit}, {len(rep.stages)} stages)")
    hdr = (f"  {'stage':5s} {'devs':>4s} {'route':14s} "
           f"{'GFLOP m/p':>16s} {'HBM m/p MB':>16s} "
           f"{'ICI/NoC m/p MB':>16s} {'DCI/D2D m/p MB':>16s}")
    print(hdr)
    for st in rep.stages:
        # flash-scores is the fused half of a flash pair — not a kernel
        kernels = sorted({r.split(":")[0] for r in st.routes.values()}
                         - {"add", "jnp", "flash-scores"})
        route = "+".join(kernels) if kernels else "add"
        print(f"  {st.index:5d} {st.n_devices:4d} {route:14s} "
              f"{st.flops/1e9:7.2f}/{st.pred_flops/1e9:<8.2f} "
              f"{st.hbm_bytes/1e6:7.2f}/{st.pred_dram_bytes/1e6:<8.2f} "
              f"{st.ici_bytes/1e6:7.2f}/{st.pred_noc_bytes/1e6:<8.2f} "
              f"{st.dci_bytes/1e6:7.2f}/{st.pred_d2d_bytes/1e6:<8.2f}")
    rs = rep.ratio_summary()
    if rs:
        print("  measured/predicted geomean: "
              + "  ".join(f"{k}={v:.3g}" for k, v in sorted(rs.items())))


def main() -> None:
    ap = argparse.ArgumentParser(
        description="realize DSE checkpoint mappings as sharded JAX "
                    "programs and calibrate the cost model")
    ap.add_argument("--ckpt", required=True,
                    help="schema-v2 keep_mappings sweep checkpoint")
    ap.add_argument("--workload", action="append", default=[],
                    metavar="NAME=SPEC",
                    help="workload graph binding (preset name, "
                    "'transformer:k=v,...' or 'lm:<config>'); bare SPEC ok "
                    "for single-workload checkpoints")
    ap.add_argument("--top", type=int, default=2,
                    help="realize the K best-EDP mapped records (0 = all)")
    ap.add_argument("--mesh", default="host",
                    help="device pool: 'host' (all devices), 'production' "
                    "(256-chip pod), 'production2' (512), or a count")
    ap.add_argument("--host-devices", type=int, default=16,
                    help="virtual host devices to force before jax init "
                    "(0 = leave the backend alone)")
    ap.add_argument("--out", default="results/realize.jsonl",
                    help="resumable measured-vs-predicted report (JSONL)")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit + write the Tech overlay from all records")
    ap.add_argument("--overlay-out", default=None,
                    help="overlay path (default: <out>.overlay.json)")
    ap.add_argument("--no-exec", action="store_true",
                    help="compile + measure only; skip execution")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.core.explore import ResumableSweep
    from repro.dist.retrying import RetryPolicy, retry_call
    from repro.launch.cli import resolve_workloads, workload_bindings
    from repro.realize.calibrate import fit_overlay, save_overlay
    from repro.realize.measure import measure_candidate
    from repro.realize.plan import (checkpoint_workload_fingerprints,
                                    graph_from_spec,
                                    load_realize_candidates, plans_for)
    from repro.realize.program import build_program

    ckpt = Path(args.ckpt)
    if not ckpt.exists():
        raise SystemExit(f"checkpoint {ckpt} not found")
    # parse the (potentially large) mapping checkpoint exactly once; the
    # open retries briefly — on shared filesystems the sweep artifact may
    # still be settling (NFS attribute-cache lag right after a merge)
    ckpt_retry = RetryPolicy(max_attempts=3, base_s=0.2, max_s=2.0,
                             retryable=(OSError,))
    ck_sweep = retry_call(ResumableSweep.read, ckpt, policy=ckpt_retry,
                          label="realize.read_ckpt")
    wl_names = sorted({rec["workload"]
                       for rec in ck_sweep.as_dict().values()
                       if "workload" in rec})
    if not args.workload:
        raise SystemExit(
            f"checkpoint has workload(s) {wl_names}; bind each with "
            f"--workload NAME=SPEC (e.g. --workload TF=tf-quick)")
    # shared NAME=SPEC grammar (launch.cli): a bare SPEC binds to the
    # checkpoint's single workload; several workloads need explicit names
    workloads = resolve_workloads(
        workload_bindings(args.workload, names=wl_names),
        builder=graph_from_spec)
    cands = load_realize_candidates(ckpt, workloads, top=args.top,
                                    sweep=ck_sweep)
    pool = _device_pool(args.mesh)
    print(f"[realize] {len(cands)} candidate(s) from {ckpt}, "
          f"device pool: {len(pool)} x {pool[0].platform}")

    fps = checkpoint_workload_fingerprints(ckpt)
    fp = ("realize:v1:" + ",".join(f"{n}:{fps.get(n, '?')}" for n in wl_names)
          + f":pool={len(pool)}:exec={int(not args.no_exec)}")
    out = Path(args.out)
    if args.force and out.exists():
        out.unlink()
    sweep = retry_call(ResumableSweep, out, fp, policy=ckpt_retry,
                       label="realize.open_out")

    t0 = time.time()
    for cand, plan in plans_for(cands, len(pool)):
        if cand.key in sweep:
            print(f"[realize] {cand.arch.label()} x {cand.workload}: "
                  f"resumed from {out}")
            continue
        prog = build_program(cand.graph, plan, devices=pool)
        prog.compile_all()
        rep = measure_candidate(cand, prog, execute=not args.no_exec)
        _print_report(rep)
        sweep.add(cand.key, rep.to_record())
    print(f"[realize] report -> {out} ({len(sweep)} records, "
          f"{time.time() - t0:.1f}s)")

    if args.calibrate:
        overlay = fit_overlay(list(sweep.as_dict().values()),
                              source=f"{ckpt.name}|pool={len(pool)}")
        op = Path(args.overlay_out) if args.overlay_out \
            else out.with_suffix(".overlay.json")
        save_overlay(overlay, op)
        print(f"[realize] Tech overlay (from {overlay.n_stages} stages): "
              f"f_d2d={overlay.f_d2d:.3g} f_noc={overlay.f_noc:.3g} "
              f"f_dram={overlay.f_dram:.3g} -> {op}")
        print("[realize] second pass: run_dse(calibrated_candidates("
              "cands, load_overlay(...)), ...) searches with "
              "measured-calibrated costs")


if __name__ == "__main__":
    main()
