"""Production meshes.  Functions only — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import)."""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds a leading 2-pod axis (512)."""
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} "
            f"(dry-run sets --xla_force_host_platform_device_count=512)")
    return jax.sharding.Mesh(
        np.asarray(devs[:n]).reshape(shape), axes)


def make_host_mesh(shape: Tuple[int, ...] = (1, 1),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh over whatever devices exist (tests / examples / CPU)."""
    import jax
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(f"need {n} devices, have {len(devs)}")
    return jax.sharding.Mesh(np.asarray(devs[:n]).reshape(shape), axes)
