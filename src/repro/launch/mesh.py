"""Production meshes.  Functions only — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import)."""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

# the fix for "not enough devices" on a CPU host: force virtual devices
# BEFORE the first jax import (jax locks the device count on first init)
DRYRUN_ENV_FIX = ("set XLA_FLAGS=--xla_force_host_platform_device_count=<N> "
                  "before the first jax import (launch/dryrun.py and "
                  "launch/realize.py do this at module top)")


def _device_pool(devices: Optional[Sequence], n: int, what: str):
    import jax
    devs = list(devices) if devices is not None else jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for {what}, have {len(devs)}"
            + ("" if devices is not None else f"; on a CPU host, "
               f"{DRYRUN_ENV_FIX}"))
    return devs


def make_production_mesh(*, multi_pod: bool = False,
                         devices: Optional[Sequence] = None):
    """16x16 = 256 chips/pod; multi_pod adds a leading 2-pod axis (512).

    ``devices`` overrides the global ``jax.devices()`` pool so callers
    (e.g. the realization driver) can carve sub-meshes out of an already
    partitioned device set without monkey-patching jax.
    """
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = _device_pool(devices, n, f"mesh {shape}")
    return jax.sharding.Mesh(
        np.asarray(devs[:n]).reshape(shape), axes)


def make_host_mesh(shape: Tuple[int, ...] = (1, 1),
                   axes: Tuple[str, ...] = ("data", "model"),
                   devices: Optional[Sequence] = None):
    """Small mesh over whatever devices exist (tests / examples / CPU)."""
    import jax
    n = int(np.prod(shape))
    devs = _device_pool(devices, n, f"mesh {shape}")
    return jax.sharding.Mesh(np.asarray(devs[:n]).reshape(shape), axes)
