"""Sweep post-mortem CLI over obs run dirs + shard checkpoints.

Usage::

    PYTHONPATH=src python -m repro.launch.obs_report --run results/obs/run-X
    PYTHONPATH=src python -m repro.launch.obs_report \\
        --run results/obs/run-X --ckpt results/sweep.shard*.ckpt.jsonl

``--run`` points at a directory written under ``REPRO_OBS=1`` (manifest,
metrics snapshot, per-process trace streams); ``--ckpt`` adds per-shard
liveness/progress (heartbeat records) and a Pareto-frontier snapshot
parsed straight from the checkpoint files — the latter works on a sweep
that is *still running*, which is the liveness view the ROADMAP's
multi-host driver polls.  ``--json`` emits the underlying tables as
machine-readable JSON instead of text.
"""

from __future__ import annotations

import argparse
import json
import sys

from ..obs import report as obs_report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="obs_report", description=__doc__.split("\n\n")[0])
    ap.add_argument("--run", default=None, metavar="DIR",
                    help="obs run directory (REPRO_OBS_DIR of the sweep)")
    ap.add_argument("--ckpt", nargs="*", default=[], metavar="PATH",
                    help="shard checkpoint file(s) for liveness + Pareto")
    ap.add_argument("--top", type=int, default=10,
                    help="rows in the slowest-tasks / Pareto tables")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output instead of text tables")
    args = ap.parse_args(argv)
    if args.run is None and not args.ckpt:
        ap.error("need --run and/or --ckpt")
    if args.json:
        data = (obs_report.load_run(args.run) if args.run is not None
                else {"manifest": None, "metrics": None, "events": []})
        doc = {
            "manifest": data["manifest"],
            "metrics": data["metrics"],
            "phases": obs_report.phase_rows(data["metrics"]),
            "top_tasks": obs_report.top_tasks(data["events"], k=args.top),
            "caches": obs_report.cache_rows(data["metrics"]),
            "shards": (obs_report.shard_progress(args.ckpt)
                       if args.ckpt else []),
            "pareto": (obs_report.pareto_snapshot(args.ckpt, top=args.top)
                       if args.ckpt else []),
        }
        json.dump(doc, sys.stdout, indent=1, default=str)
        sys.stdout.write("\n")
    else:
        sys.stdout.write(obs_report.render_report(
            run=args.run, ckpts=args.ckpt, top=args.top))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
