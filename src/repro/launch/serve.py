"""Serving launcher: batched wave serving of synthetic requests.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..models import model_api
from ..runtime.serve_loop import Request, Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=512)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = model_api(cfg)
    params, _ = api.init_params(jax.random.PRNGKey(0))
    srv = Server(cfg, params, max_batch=args.max_batch,
                 max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        srv.submit(Request(
            rid=i,
            prompt=rng.integers(1, cfg.vocab, size=int(rng.integers(4, 32))
                                ).astype(np.int32),
            max_new=args.max_new))
    results = srv.run_until_empty()
    dt = time.time() - t0
    n_tok = sum(len(r.tokens) for r in results)
    print(f"[serve] {len(results)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s)")
    for r in results[:4]:
        print(f"  rid={r.rid} tokens={r.tokens[:12].tolist()}...")


if __name__ == "__main__":
    main()
