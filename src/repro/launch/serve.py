"""Serving launcher: traffic-replay SLO reports + interactive wave demo.

Trace replay (the serving harness; deterministic for a fixed seed):

  PYTHONPATH=src python -m repro.launch.serve \
      --trace poisson:rate=8,n=32,plen=4..32,new=8..32 --report \
      --out results/serve_report.jsonl

emits p50/p95/p99 TTFT + end-to-end latency and a saturation-throughput
estimate for BOTH serving paths:

* ``serve_loop`` — the wave-batched scheduling policy of
  ``runtime/serve_loop.py``, timed on a nominal-throughput virtual clock
  derived from the model config (deterministic; add ``--measure`` to
  also replay against the real jitted model on wall clock);
* ``realized`` — continuous batch slotting over the service model of the
  best co-explored mapping (an inline Table-I quick screen by default,
  or the best record of a ``--ckpt`` DSE sweep), the program the
  ``realize/`` path would compile.

Interactive demo (no --trace): submits synthetic requests through the
``Server`` shim and prints per-request latencies.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Optional

from ..serve import (ServeReport, ServiceModel, make_trace, replay, respec,
                     saturation_sweep, service_model_from_delay)
from . import cli

# Virtual-clock throughput anchor for the serve_loop section: FLOPs per
# token from the model config over a nominal sustained rate.  The absolute
# scale is arbitrary (percentile *ratios* and the saturation knee are what
# the report is for); --measure replays the real model to calibrate it.
NOMINAL_FLOPS_PER_S = 1e12

# Rate ladder (x the trace's base rate) swept for the saturation estimate.
SAT_LADDER = (0.5, 1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)

# The realized path derives its per-token cost from the co-explored
# mapping's delay at the quick-DSE operating point.
DSE_BATCH = 8
SEQ_REF = 64


def _nominal_service_model(cfg) -> ServiceModel:
    """Deterministic per-token cost of the model config (virtual clock)."""
    per_tok_flops = 2.0 * (
        cfg.n_layers * (4 * cfg.d_model ** 2 + 2 * cfg.d_model * cfg.d_ff)
        + cfg.d_model * cfg.vocab)
    c = per_tok_flops / NOMINAL_FLOPS_PER_S
    return ServiceModel(prefill_s_per_token=c, decode_s_per_token=c)


def _coexplored_delay(workloads: Dict, seed: int,
                      ckpt: Optional[str]) -> float:
    """Geomean forward delay of the best co-explored mapping.

    With ``--ckpt``, the best-EDP record of the DSE sweep (the mapping
    ``realize/`` would compile); otherwise an inline T-Map screen of the
    Table-I quick grid — deterministic either way.
    """
    if ckpt:
        from ..realize.plan import load_realize_candidates
        cands = load_realize_candidates(ckpt, workloads, top=1)
        if not cands:
            raise SystemExit(f"--ckpt {ckpt}: no mapped records")
        return cands[0].delay_s
    from ..core.dse import DSEConfig, grid_candidates, run_dse
    from ..core.sa import SAConfig
    grid = grid_candidates(
        72.0, mac_options=(512, 1024), cut_options=(1, 2),
        dram_per_tops=(2.0,), noc_options=(16, 32), d2d_ratio=(0.5,),
        glb_options=(1024, 2048))
    cfg = DSEConfig(batch=DSE_BATCH, sa=SAConfig(iters=150, seed=seed))
    return run_dse(grid, workloads, cfg, use_sa=False)[0].delay_s


def _print_section(name: str, summary: Dict, sat: Dict) -> None:
    ttft, e2e = summary["ttft_s"], summary["e2e_s"]
    print(f"[serve:{name}] mode={summary['mode']} "
          f"timing={summary['timing']} "
          f"n={summary['trace']['n']} occ={summary['mean_occupancy']:.2f}")
    print(f"  TTFT s   p50={ttft['p50']:.4g} p95={ttft['p95']:.4g} "
          f"p99={ttft['p99']:.4g}")
    print(f"  e2e  s   p50={e2e['p50']:.4g} p95={e2e['p95']:.4g} "
          f"p99={e2e['p99']:.4g}")
    if sat:
        sr = sat["sat_rate_rps"]
        print(f"  saturation ~{sr:.4g} req/s "
              f"({sat['sat_throughput_tok_s']:.4g} tok/s, "
              f"knee at p99 > {sat['slo_mult']:g}x unloaded"
              f"{'' if sat['saturated'] else '; ladder never saturated'})")


def _section(name: str, rep: ServeReport, sat: Dict) -> Dict:
    doc = {"section": name, **rep.summary()}
    if sat:
        doc["saturation"] = sat
    return doc


def _replay_trace(args) -> List[Dict]:
    trace = make_trace(args.trace, seed=args.seed)
    print(f"[serve] trace {trace.name} n={len(trace.requests)} "
          f"seed={trace.seed} fp={trace.fingerprint()} "
          f"rate~{trace.arrival_rate():.3g} req/s")
    base_rate = trace.arrival_rate() or 1.0
    rates = [base_rate * m for m in SAT_LADDER]
    sections: List[Dict] = []

    def run_path(name: str, model: ServiceModel, mode: str) -> None:
        rep = replay(trace, model, mode=mode, max_batch=args.max_batch)
        sat = saturation_sweep(
            lambda r: make_trace(respec(args.trace, rate=r), seed=args.seed),
            lambda: model, rates, mode=mode, max_batch=args.max_batch)
        _print_section(name, rep.summary(), sat)
        sections.append(_section(name, rep, sat))

    # path 1: the serve_loop wave policy on the nominal virtual clock
    cfg = cli.model_config(args)
    run_path("serve_loop", _nominal_service_model(cfg), "wave")

    # path 2: continuous slotting over the best co-explored mapping
    bindings = cli.workload_bindings(args.workload or ["TF=tf-quick"])
    workloads = cli.resolve_workloads(bindings)
    delay = _coexplored_delay(workloads, args.seed, args.ckpt)
    model = service_model_from_delay(delay, DSE_BATCH, SEQ_REF)
    print(f"[serve] realized mapping delay {delay:.4g}s "
          f"-> {model.decode_s_per_token:.3e} s/token")
    run_path("realized", model, "continuous")

    if args.measure:
        # wall-clock validation of the virtual serve_loop section: same
        # trace, same wave policy, real jitted model.  Nondeterministic
        # by nature — reported alongside, never replacing, the virtual
        # sections (realize/measure.py's validate-don't-replace pattern).
        import jax
        from ..models import model_api
        from ..runtime.serve_loop import ModelWaveExecutor
        api = model_api(cfg)
        params, _ = api.init_params(jax.random.PRNGKey(args.seed))
        ex = ModelWaveExecutor(cfg, params, max_batch=args.max_batch,
                               max_seq=args.max_seq)
        t0 = time.time()
        rep = replay(trace, ex, mode="wave")
        rep.timing = "measured"
        print(f"[serve] measured replay in {time.time() - t0:.1f}s wall")
        _print_section("serve_loop_measured", rep.summary(), {})
        sections.append(_section("serve_loop_measured", rep, {}))
        virt = next(s for s in sections if s["section"] == "serve_loop")
        ratio = rep.summary()["e2e_s"]["p99"] / virt["e2e_s"]["p99"]
        print(f"[serve] measured/virtual p99 e2e ratio: {ratio:.3g} "
              "(calibration factor for the nominal clock)")
    return sections


def _demo(args) -> None:
    import jax
    import numpy as np

    from ..models import model_api
    from ..runtime.serve_loop import Request, Server
    cfg = cli.model_config(args)
    api = model_api(cfg)
    params, _ = api.init_params(jax.random.PRNGKey(args.seed))
    srv = Server(cfg, params, max_batch=args.max_batch,
                 max_seq=args.max_seq)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        srv.submit(Request(
            rid=i,
            prompt=rng.integers(1, cfg.vocab, size=int(rng.integers(4, 32))
                                ).astype(np.int32),
            max_new=args.max_new))
    results = srv.run_until_empty()
    dt = time.time() - t0
    n_tok = sum(len(r.tokens) for r in results)
    print(f"[serve] {len(results)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s)")
    for r in results[:4]:
        print(f"  rid={r.rid} latency={r.latency_s:.3f}s "
              f"tokens={r.tokens[:12].tolist()}...")


def main() -> None:
    ap = argparse.ArgumentParser(
        description="traffic-replay SLO reports / interactive wave serving")
    cli.add_arch_args(ap, required=False, default="smollm-135m")
    ap.add_argument("--trace", default=None, metavar="SPEC",
                    help="traffic trace spec, e.g. 'poisson:rate=8,n=32,"
                    "plen=4..32,new=8..32' or 'diurnal:...,period=120,"
                    "peak=3' (see repro.serve.trace.make_trace); omits "
                    "the trace -> interactive demo mode")
    ap.add_argument("--report", action="store_true",
                    help="print the full SLO report (implied by --out)")
    ap.add_argument("--measure", action="store_true",
                    help="also replay the trace against the real jitted "
                    "model (wall clock; nondeterministic) to validate the "
                    "virtual-clock sections")
    ap.add_argument("--ckpt", default=None,
                    help="keep_mappings DSE checkpoint; its best record "
                    "becomes the realized-path service model (default: "
                    "inline Table-I quick screen)")
    cli.add_workload_args(ap, help_extra="Default: TF=tf-quick "
                          "(the realized path's co-explored workload).")
    ap.add_argument("--requests", type=int, default=8,
                    help="demo mode: synthetic request count")
    ap.add_argument("--max-new", type=int, default=16,
                    help="demo mode: decode budget per request")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=512)
    cli.add_out_arg(ap, what="SLO report JSONL (one line per section)")
    cli.add_seed_arg(ap)
    args = ap.parse_args()

    if args.trace is None:
        if args.report or args.out or args.measure:
            raise SystemExit("--report/--out/--measure need --trace SPEC")
        _demo(args)
        return
    sections = _replay_trace(args)
    if args.out:
        out = Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text("".join(json.dumps(s, sort_keys=True) + "\n"
                               for s in sections))
        print(f"[serve] report -> {out} ({len(sections)} sections)")


if __name__ == "__main__":
    main()
