"""Step builders: jitted train / prefill / decode steps with explicit
in/out shardings, plus ``input_specs()`` — ShapeDtypeStruct stand-ins for
every model input (dry-run pattern: weak-type-correct, shardable, no
allocation).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import SHAPES, ModelConfig, ShapeConfig
from ..models import model_api
from ..nn.params import (Pytree, ShardingRules, default_rules, tree_sharding,
                         tree_spec)
from ..optim.adamw import AdamWConfig, adamw_update, init_opt_state, zero1_axes

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# input_specs: every model input as ShapeDtypeStruct
# ---------------------------------------------------------------------------

def batch_axes(cfg: ModelConfig, kind: str) -> Dict[str, Tuple]:
    a: Dict[str, Tuple] = {}
    if cfg.frontend in ("patch", "audio"):
        a["embeds"] = ("batch", "seq", "embed")
        if cfg.family == "encdec":
            a["tokens"] = ("batch", "seq")
    else:
        a["tokens"] = ("batch", "seq")
    if kind == "train":
        a["labels"] = ("batch", "seq")
    return a


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, SDS]:
    """ShapeDtypeStructs for the step-function *batch* argument."""
    B, S = shape.global_batch, shape.seq_len
    out: Dict[str, SDS] = {}
    if shape.kind == "decode":
        out["tokens"] = SDS((B, 1), jnp.int32)
        return out
    if cfg.frontend in ("patch", "audio"):
        out["embeds"] = SDS((B, S, cfg.d_model), jnp.bfloat16)
        if cfg.family == "encdec":
            out["tokens"] = SDS((B, S), jnp.int32)
    else:
        out["tokens"] = SDS((B, S), jnp.int32)
    if shape.kind == "train":
        out["labels"] = SDS((B, S), jnp.int32)
    return out


def get_param_axes(cfg: ModelConfig) -> Pytree:
    """Logical axes of the param tree (structure-only; uses reduced dims)."""
    api = model_api(cfg.reduced())
    _, axes = api.init_params(jax.random.PRNGKey(0))
    return axes


def param_structs(cfg: ModelConfig, serve_dtype: Optional[str] = None) -> Pytree:
    api = model_api(cfg)
    structs = jax.eval_shape(
        lambda k: api.init_params(k)[0], SDS((2,), jnp.uint32))
    if serve_dtype is not None:
        dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[serve_dtype]
        structs = jax.tree.map(
            lambda s: SDS(s.shape, dt) if jnp.issubdtype(s.dtype, jnp.floating)
            else s, structs)
    return structs


def cache_structs(cfg: ModelConfig, batch: int, max_seq: int,
                  enc_len: Optional[int] = None) -> Tuple[Pytree, Pytree]:
    api = model_api(cfg)
    structs = jax.eval_shape(
        lambda: api.init_cache(batch, max_seq, enc_len)[0])
    # axes come from a reduced-config concrete call (tiny)
    rapi = model_api(cfg.reduced())
    _, axes = rapi.init_cache(2, 8, 8)
    return structs, axes


# ---------------------------------------------------------------------------
# Cell bundles
# ---------------------------------------------------------------------------

@dataclass
class CellBundle:
    """Everything needed to .lower() one (arch x shape x mesh) cell."""
    name: str
    fn: Callable                    # jitted
    args: Tuple[Any, ...]           # ShapeDtypeStructs (abstract)
    static_desc: str = ""


def _shardings(tree_axes: Pytree, rules: ShardingRules, mesh: Mesh) -> Pytree:
    return tree_sharding(tree_axes, rules, mesh)


def derive_attn_rules(cfg: ModelConfig, mesh: Mesh, rules: ShardingRules,
                      kind: str) -> ShardingRules:
    """Pick the attention activation layout for this (arch x mesh):
      kv-shard   when n_kv divides the model axis,
      repeat-kv  when only n_heads divides it (Megatron GQA trick; transient
                 tensors only — never the cache; disabled for decode where
                 the cache's kv_seq sharding already balances),
      seq-shard  (context parallel) otherwise.
    MoE: when n_experts doesn't divide the model axis, shard the expert FFN
    hidden dim instead of the expert dim."""
    M = mesh.shape.get("model", 1)
    if cfg.n_experts and cfg.n_experts % M != 0:
        rules = rules.replace_rules(experts=None, expert_mlp="model")
    if cfg.family == "ssm":
        return rules
    if kind == "decode":
        return rules.replace_rules(act_kv=None, act_kv_seq="model")
    if cfg.n_kv % M == 0:
        return rules
    if cfg.n_heads % M == 0:
        return rules.replace_rules(repeat_kv=True)
    return rules.replace_rules(act_kv=None, act_seq="model")


def serve_param_rules(cfg: ModelConfig, mesh: Mesh, rules: ShardingRules,
                      kind: str = "decode") -> ShardingRules:
    """Serving default: drop the FSDP (data-axis) shard on params when the
    TP-sharded bf16 weights fit comfortably in HBM.  Confirmed in §Perf
    (qwen1.5-110b decode: collective term 552ms -> 2ms): static serving
    weights should not be re-gathered every step.  Exceptions kept 2-D:
    models that don't fit (<8 GB/dev rule) and SSM/hybrid *prefill* (the
    SSD einsums repartition poorly without the data axis — measured 0.63x
    regression on zamba2 prefill, so the rule backs off there)."""
    if kind == "prefill" and cfg.family in ("ssm", "hybrid"):
        return rules
    M = mesh.shape.get("model", 1)
    bytes_tp = cfg.param_count() * 2 / M
    if bytes_tp < 8e9:
        return rules.replace_rules(embed=None)
    return rules


def fit_batch_rules(rules: ShardingRules, global_batch: int,
                    mesh: Mesh) -> ShardingRules:
    """Shrink the 'batch' rule to the largest mesh-axis prefix whose product
    divides global_batch (batch=1 long-context cells stay unsharded)."""
    raw = rules.rules.get("batch")
    if raw is None:
        return rules
    names = [raw] if isinstance(raw, str) else list(raw)
    names = [n for n in names if n in mesh.axis_names]
    while names:
        prod = 1
        for n in names:
            prod *= mesh.shape[n]
        if global_batch % prod == 0:
            break
        names.pop()
    return rules.replace_rules(batch=tuple(names) if names else None)


def make_train_bundle(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      rules: Optional[ShardingRules] = None,
                      n_micro: int = 1, zero1: bool = False,
                      opt_cfg: Optional[AdamWConfig] = None) -> CellBundle:
    rules = fit_batch_rules(rules or default_rules(), shape.global_batch, mesh)
    rules = derive_attn_rules(cfg, mesh, rules, "train")
    opt_cfg = opt_cfg or AdamWConfig()
    api = model_api(cfg)
    p_axes = get_param_axes(cfg)
    p_structs = param_structs(cfg)
    o_structs = jax.eval_shape(init_opt_state, p_structs)
    if zero1:
        mv_axes = zero1_axes(p_axes, p_structs,
                             mesh_size=mesh.shape.get("data", 1))
        rules = rules.replace_rules(opt_shard="data")
    else:
        mv_axes = p_axes
    state_structs = {"params": p_structs, "opt": o_structs}
    state_shardings = {
        "params": _shardings(p_axes, rules, mesh),
        "opt": {"m": _shardings(mv_axes, rules, mesh),
                "v": _shardings(mv_axes, rules, mesh),
                "step": NamedSharding(mesh, P())},
    }
    b_axes = batch_axes(cfg, "train")
    b_structs = input_specs(cfg, shape)
    b_shardings = {k: NamedSharding(mesh, rules.spec(b_axes[k], mesh))
                   for k in b_structs}

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]

        def loss_of(p, b):
            return api.loss_fn(p, b, rules)

        if n_micro > 1:
            mb = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)

            def acc(carry, b):
                gsum, lsum = carry
                (l, m), g = jax.value_and_grad(loss_of, has_aux=True)(params, b)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + m["nll"]), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (gsum, lsum), _ = jax.lax.scan(acc, (g0, jnp.zeros(())), mb)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            nll = lsum / n_micro
        else:
            (l, m), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, batch)
            nll = m["nll"]
        new_p, new_opt, om = adamw_update(opt_cfg, params, grads, opt)
        metrics = {"loss": nll, **om}
        return {"params": new_p, "opt": new_opt}, metrics

    jitted = jax.jit(
        train_step,
        in_shardings=(state_shardings, b_shardings),
        out_shardings=(state_shardings,
                       {"loss": NamedSharding(mesh, P()),
                        "grad_norm": NamedSharding(mesh, P()),
                        "lr": NamedSharding(mesh, P())}),
        donate_argnums=(0,))
    return CellBundle(name=f"{cfg.name}/{shape.name}", fn=jitted,
                      args=(state_structs, b_structs),
                      static_desc=f"train micro={n_micro} zero1={zero1}")


def make_prefill_bundle(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                        rules: Optional[ShardingRules] = None) -> CellBundle:
    rules = fit_batch_rules(rules or default_rules(), shape.global_batch, mesh)
    rules = derive_attn_rules(cfg, mesh, rules, "prefill")
    rules = serve_param_rules(cfg, mesh, rules, "prefill")
    api = model_api(cfg)
    p_axes = get_param_axes(cfg)
    p_structs = param_structs(cfg, serve_dtype="bfloat16")
    c_structs, c_axes = cache_structs(cfg, shape.global_batch, shape.seq_len,
                                      enc_len=shape.seq_len)
    b_structs = input_specs(cfg, shape)
    b_axes = batch_axes(cfg, "prefill")

    def prefill_fn(params, batch, cache):
        return api.prefill(params, batch, cache, rules)

    jitted = jax.jit(
        prefill_fn,
        in_shardings=(_shardings(p_axes, rules, mesh),
                      {k: NamedSharding(mesh, rules.spec(b_axes[k], mesh))
                       for k in b_structs},
                      _shardings(c_axes, rules, mesh)),
        out_shardings=(NamedSharding(mesh, rules.spec(("batch", "vocab"), mesh)),
                       _shardings(c_axes, rules, mesh)),
        donate_argnums=(2,))
    return CellBundle(name=f"{cfg.name}/{shape.name}", fn=jitted,
                      args=(p_structs, b_structs, c_structs),
                      static_desc="prefill")


def make_decode_bundle(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       rules: Optional[ShardingRules] = None) -> CellBundle:
    rules = fit_batch_rules(rules or default_rules(), shape.global_batch, mesh)
    rules = derive_attn_rules(cfg, mesh, rules, "decode")
    rules = serve_param_rules(cfg, mesh, rules, "decode")
    api = model_api(cfg)
    p_axes = get_param_axes(cfg)
    p_structs = param_structs(cfg, serve_dtype="bfloat16")
    c_structs, c_axes = cache_structs(cfg, shape.global_batch, shape.seq_len,
                                      enc_len=min(shape.seq_len, 32768))
    tok = SDS((shape.global_batch, 1), jnp.int32)

    def decode_fn(params, tokens, cache):
        return api.decode_step(params, tokens, cache, rules)

    jitted = jax.jit(
        decode_fn,
        in_shardings=(_shardings(p_axes, rules, mesh),
                      NamedSharding(mesh, rules.spec(("batch", "seq"), mesh)),
                      _shardings(c_axes, rules, mesh)),
        out_shardings=(NamedSharding(mesh, rules.spec(("batch", "vocab"), mesh)),
                       _shardings(c_axes, rules, mesh)),
        donate_argnums=(2,))
    return CellBundle(name=f"{cfg.name}/{shape.name}", fn=jitted,
                      args=(p_structs, tok, c_structs),
                      static_desc="decode")


def make_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
              rules: Optional[ShardingRules] = None,
              **kw) -> CellBundle:
    if shape.kind == "train":
        big = cfg.param_count() > 5e9
        kw.setdefault("n_micro", 4 if big else 1)
        return make_train_bundle(cfg, shape, mesh, rules, **kw)
    if shape.kind == "prefill":
        return make_prefill_bundle(cfg, shape, mesh, rules)
    return make_decode_bundle(cfg, shape, mesh, rules)
