"""Shared launcher CLI surface.

Every driver that takes a model architecture, a workload set, or the
``--out``/``--seed`` conventions goes through these helpers instead of a
hand-rolled parser, so flags mean the same thing across
``launch/serve.py``, ``launch/realize.py`` and
``benchmarks/table1_dse.py``:

* ``--arch NAME`` + ``--reduced`` — a model config from
  ``repro.configs.get_config`` (``--reduced`` applies the CPU/CI-sized
  variant);
* ``--workload NAME=SPEC`` (repeatable) — workload graphs through the
  single ``repro.core.workloads.make_workload`` registry; a bare SPEC is
  allowed when the binding target has exactly one workload name.  Unknown
  specs raise ``make_workload``'s preset listing;
* ``--out PATH`` / ``--seed N`` — artifact path and base RNG seed.

Import-light on purpose: graph builders and model configs load inside
the resolver functions, not at module import (drivers pre-parse argv
before heavyweight imports).
"""

from __future__ import annotations

import argparse
from typing import Callable, Dict, List, Optional, Sequence

# ---------------------------------------------------------------------------
# --arch / --reduced
# ---------------------------------------------------------------------------


def add_arch_args(ap: argparse.ArgumentParser, required: bool = True,
                  default: Optional[str] = None) -> None:
    ap.add_argument("--arch", required=required, default=default,
                    help="model config name (repro.configs.get_config)")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced-size config variant (CPU / CI runs)")


def model_config(args: argparse.Namespace):
    """Resolve ``--arch``/``--reduced`` into a ModelConfig."""
    from ..configs import get_config
    cfg = get_config(args.arch)
    return cfg.reduced() if args.reduced else cfg


# ---------------------------------------------------------------------------
# --workload NAME=SPEC
# ---------------------------------------------------------------------------


def add_workload_args(ap: argparse.ArgumentParser,
                      help_extra: str = "") -> None:
    ap.add_argument(
        "--workload", action="append", default=[], metavar="NAME=SPEC",
        help="workload graph binding (repeatable); SPEC is a registry "
             "preset (tf-quick, moe-quick, mla-quick, ...) or a "
             "parameterized spec ('transformer:k=v,...', 'moe:...', "
             "'mla:...', 'lm:<config>') — see "
             "repro.core.workloads.make_workload. " + help_extra)


def workload_bindings(items: Sequence[str],
                      names: Optional[Sequence[str]] = None
                      ) -> Dict[str, str]:
    """Parse ``NAME=SPEC`` items into ``{name: spec}``.

    With ``names`` given (e.g. the workload names a checkpoint was swept
    over), a bare ``SPEC`` binds to the single name — including
    parameterized specs like ``transformer:k=v`` whose first ``=`` is
    part of the spec, not a binding — and every name must end up bound:
    half-specified portfolios fail loudly instead of silently dropping
    workloads.
    """
    out: Dict[str, str] = {}
    for s in items:
        name, sep, spec = s.partition("=")
        if sep and ":" not in name and "," not in name:
            pass                        # NAME=SPEC binding
        elif names is not None and len(names) == 1:
            # bare SPEC — including parameterized ones whose first '='
            # sits inside the k=v tail ('transformer:n_layers=1,...')
            name, spec = names[0], s
        elif names is not None:
            raise SystemExit(
                f"--workload {s!r}: target has workloads {list(names)}; "
                f"bind explicitly with NAME=SPEC")
        else:
            name, spec = s, s           # standalone: spec doubles as name
        out[name] = spec
    if names is not None:
        missing = [n for n in names if n not in out]
        if missing:
            raise SystemExit(
                f"no --workload binding for workload(s) {missing}")
    return out


def resolve_workloads(bindings: Dict[str, str],
                      builder: Optional[Callable] = None) -> Dict:
    """``{name: spec}`` -> ``{name: Graph}`` via the workload registry.

    Unknown specs raise ``make_workload``'s error listing the registered
    presets (every driver keeps that contract).
    """
    if builder is None:
        from ..core.workloads import make_workload as builder
    return {name: builder(spec) for name, spec in bindings.items()}


# ---------------------------------------------------------------------------
# NAME=VALUE option lists (--weight, etc.)
# ---------------------------------------------------------------------------


def parse_kv(items: Optional[Sequence[str]], cast: Callable = str,
             flag: str = "option") -> Optional[Dict[str, object]]:
    """Parse repeated ``NAME=VALUE`` flags; None when nothing was given."""
    if not items:
        return None
    out: Dict[str, object] = {}
    for item in items:
        name, sep, val = item.partition("=")
        if not sep:
            raise SystemExit(f"{flag} {item!r} is not NAME=VALUE")
        try:
            out[name] = cast(val)
        except ValueError as e:
            raise SystemExit(f"{flag} {item!r}: {e}")
    return out


# ---------------------------------------------------------------------------
# --out / --seed
# ---------------------------------------------------------------------------


def add_out_arg(ap: argparse.ArgumentParser, default: Optional[str] = None,
                what: str = "result artifact") -> None:
    ap.add_argument("--out", default=default,
                    help=f"write the {what} here"
                         + (f" (default {default})" if default else ""))


def add_seed_arg(ap: argparse.ArgumentParser, default: int = 0) -> None:
    ap.add_argument("--seed", type=int, default=default,
                    help=f"base RNG seed (default {default})")
