import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first — jax locks the device count on first
init.  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun.json

Results stream into the JSON after every cell so interrupted runs resume
(cells already present are skipped unless --force).
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from ..configs.base import SHAPES, all_archs, cells_for, get_config
from .mesh import make_production_mesh
from .roofline import (analyze_compiled, flash_kernel_adjustment,
                       model_flops_for)
from .steps import input_specs, make_cell  # noqa: F401  (input_specs is API)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             rules_overrides=None, cfg_overrides=None, **cell_kw) -> dict:
    """Lower + compile one cell; returns the roofline/memory record."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    from ..nn.params import default_rules
    rules = default_rules(**(rules_overrides or {}))
    t0 = time.time()
    with mesh:
        bundle = make_cell(cfg, shape, mesh, rules, **cell_kw)
        lowered = bundle.fn.lower(*bundle.args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
    rl = analyze_compiled(
        f"{arch}/{shape_name}/{mesh_kind}", compiled, None,
        model_flops_for(cfg, shape), n_dev, compile_s=t_compile)
    rec = rl.to_dict()
    from .roofline import flash_kernel_adjustment
    adj = flash_kernel_adjustment(cfg, shape,
                                  n_pod=2 if mesh_kind == "multi" else 1)
    rec["flash_adj_bytes"] = adj
    rec["t_memory_kernel"] = max(0.0, (rl.bytes_per_device - adj)) \
        / rl.chip.hbm_bw
    rec.update({"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "lower_s": t_lower, "desc": bundle.static_desc,
                "ok": True})
    # the proof-it-fits printout the dry-run spec requires
    ma = compiled.memory_analysis()
    print(f"  memory_analysis: args={ma.argument_size_in_bytes/1e9:.2f}GB "
          f"out={ma.output_size_in_bytes/1e9:.2f}GB "
          f"temp={ma.temp_size_in_bytes/1e9:.2f}GB per device")
    ca = compiled.cost_analysis()
    print(f"  cost_analysis: flops/dev={ca.get('flops', 0):.3e} "
          f"bytes/dev={ca.get('bytes accessed', 0):.3e}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--micro", type=int, default=0,
                    help="override microbatch count (0 = auto)")
    args = ap.parse_args()

    archs = list(all_archs()) if args.arch == "all" else args.arch.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    # --force re-runs the SELECTED cells only; cached results for other
    # cells are always preserved (a --force on a subset must not wipe the
    # rest of the table)
    results = {}
    if out_path.exists():
        results = json.loads(out_path.read_text())

    n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = list(cells_for(cfg)) if args.shape == "all" \
            else [s for s in args.shape.split(",") if s in cells_for(cfg)]
        for shape_name in shapes:
            for mesh_kind in meshes:
                key = f"{arch}|{shape_name}|{mesh_kind}"
                if key in results and results[key].get("ok") and not args.force:
                    print(f"[skip] {key} (cached)")
                    continue
                print(f"[cell] {key} ...", flush=True)
                t0 = time.time()
                kw = {}
                if args.micro and SHAPES[shape_name].kind == "train":
                    kw["n_micro"] = args.micro
                if args.zero1 and SHAPES[shape_name].kind == "train":
                    kw["zero1"] = True
                try:
                    rec = run_cell(arch, shape_name, mesh_kind, **kw)
                    print(f"[ok]   {key}  compute={rec['t_compute']*1e3:.2f}ms "
                          f"memory={rec['t_memory']*1e3:.2f}ms "
                          f"coll={rec['t_collective']*1e3:.2f}ms "
                          f"bneck={rec['bottleneck']} "
                          f"({time.time()-t0:.0f}s)", flush=True)
                except Exception as e:  # noqa: BLE001 - report, keep going
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "ok": False,
                           "error": f"{type(e).__name__}: {e}"}
                    n_fail += 1
                    print(f"[FAIL] {key}: {rec['error'][:200]}", flush=True)
                results[key] = rec
                out_path.write_text(json.dumps(results, indent=1))
    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed, "
          f"results -> {out_path}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
