"""Render results/dryrun.json (+ hillclimb.json) into the EXPERIMENTS.md
tables.  Usage:  PYTHONPATH=src python -m repro.launch.report > /tmp/tbl.md
"""

from __future__ import annotations

import json
from pathlib import Path


def fmt_s(x: float) -> str:
    return f"{x * 1e3:9.1f}m" if x < 100 else f"{x:9.1f}s"


def dryrun_table(path: str = "results/dryrun.json", mesh: str = "single") -> str:
    d = json.loads(Path(path).read_text())
    rows = sorted(((k, v) for k, v in d.items()
                   if v.get("ok") and v["mesh"] == mesh),
                  key=lambda kv: (kv[1]["arch"], kv[1]["shape"]))
    out = ["| cell | bneck | t_compute | t_memory | t_mem_kernel | "
           "t_collective | frac | useful | args GB/dev | temp GB/dev | "
           "coll GB/dev |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for k, v in rows:
        tmk = v.get("t_memory_kernel", v["t_memory"])
        out.append(
            f"| {v['arch']}/{v['shape']} | {v['bottleneck']} | "
            f"{v['t_compute']*1e3:.1f} ms | {v['t_memory']*1e3:.1f} ms | "
            f"{tmk*1e3:.1f} ms | "
            f"{v['t_collective']*1e3:.1f} ms | {v['roofline_fraction']:.3f} | "
            f"{v['useful_flops_ratio']:.2f} | "
            f"{v['argument_bytes']/1e9:.2f} | {v['temp_bytes']/1e9:.2f} | "
            f"{v['coll_bytes_per_device']/1e9:.2f} |")
    return "\n".join(out)


def multi_pod_table(path: str = "results/dryrun.json") -> str:
    d = json.loads(Path(path).read_text())
    rows = sorted(((k, v) for k, v in d.items()
                   if v.get("ok") and v["mesh"] == "multi"),
                  key=lambda kv: (kv[1]["arch"], kv[1]["shape"]))
    out = ["| cell | compiled | t_coll (multi) | coll GB/dev | "
           "args GB/dev | compile s |",
           "|---|---|---|---|---|---|"]
    for k, v in rows:
        out.append(
            f"| {v['arch']}/{v['shape']} | yes | "
            f"{v['t_collective']*1e3:.1f} ms | "
            f"{v['coll_bytes_per_device']/1e9:.2f} | "
            f"{v['argument_bytes']/1e9:.2f} | {v['compile_s']:.0f} |")
    return "\n".join(out)


def hillclimb_table(path: str = "results/hillclimb.jsonl") -> str:
    p = Path(path)
    legacy = p.with_suffix(".json")
    # merge legacy dict-format records under the JSONL ones, so "before"
    # rows recorded pre-migration stay in the comparison
    d = json.loads(legacy.read_text()) if legacy.exists() else {}
    # the base jsonl plus any per-shard siblings written by
    # hillclimb --shard i/n, merged last-wins in name order (corrupt
    # shards are set aside by merge_checkpoints, not fatal here)
    shards = sorted(p.parent.glob(f"{p.stem}.shard*of*{p.suffix}"))
    paths = ([p] if p.exists() else []) + shards
    if paths:
        from repro.core.explore import ResumableSweep, merge_checkpoints
        try:
            # in-memory, quiet: this function's output lands in tables
            report = merge_checkpoints(paths, verbose=False)
            d.update(report.records)
            skipped = [p for p, _ in report.skipped]
        except ValueError:              # no file usable / fps disagree
            skipped = paths
        # merge_checkpoints sets whole corrupt shards aside; a render-only
        # consumer still wants every parseable line (the pre-shard
        # behavior), so salvage set-aside files read-only
        for p in skipped:
            d.update(ResumableSweep.read(p).as_dict())
    if not d:
        return "(no hillclimb results yet)"
    out = ["| cell | variant | t_compute | t_memory | t_collective | "
           "bound | frac |", "|---|---|---|---|---|---|---|"]
    for k, v in sorted(d.items()):
        if not v.get("ok"):
            out.append(f"| {k} | FAILED: {v.get('error', '?')[:60]} | | | | | |")
            continue
        cell = k.rsplit("|", 1)[0]
        bound = max(v["t_compute"], v["t_memory"], v["t_collective"])
        out.append(
            f"| {cell} | {v['variant']} | {v['t_compute']*1e3:.1f} ms | "
            f"{v['t_memory']*1e3:.1f} ms | {v['t_collective']*1e3:.1f} ms | "
            f"{bound*1e3:.1f} ms | {v['roofline_fraction']:.3f} |")
    return "\n".join(out)


def main() -> None:
    print("## Single-pod roofline (16x16 = 256 chips)\n")
    print(dryrun_table())
    print("\n## Multi-pod pass (2x16x16 = 512 chips)\n")
    print(multi_pod_table())
    print("\n## Hillclimb variants\n")
    print(hillclimb_table())


if __name__ == "__main__":
    main()
