import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing driver: hypothesis -> change -> re-lower -> measure.

Each named VARIANT is a (rules/cfg/bundle)-override set applied to one
(arch x shape) cell on the single-pod mesh.  Results append to
results/hillclimb.jsonl keyed cell/variant, with the three roofline terms,
so EXPERIMENTS.md §Perf can show before/after per hypothesis.

The sweep is resumable through the same append-only JSON-lines artifact
the DSE checkpoints use (``repro.core.explore.ResumableSweep``):
completed-ok cells are skipped on re-run, failed cells are retried, and a
kill mid-measure loses at most the in-flight cell.  ``--shard i/n`` runs
only every n-th variant into a per-shard jsonl (parallel CI jobs /
hosts); ``launch/report.py`` merges the shard artifacts back into one
table via ``repro.core.explore.merge_checkpoints``.

  PYTHONPATH=src python -m repro.launch.hillclimb --cell \
      qwen1.5-110b/train_4k --variant baseline,no_fsdp ...
"""

import argparse
import json
import time
import traceback
from pathlib import Path
from typing import Dict

from repro.core.explore import ResumableSweep, parse_shard_spec

from .dryrun import run_cell

# variant name -> dict(rules_overrides=..., cfg_overrides=..., cell_kw=...)
VARIANTS: Dict[str, Dict] = {
    "baseline": {},
    # --- sharding-axis changes -------------------------------------------
    "no_fsdp": {        # pure 1-D TP params (kills per-layer all-gathers,
                        # pays replicated-param memory)
        "rules_overrides": {"embed": None}},
    "no_fsdp_zero1": {  # params replicated, optimizer state ZeRO-1 sharded
        "rules_overrides": {"embed": None}, "cell_kw": {"zero1": True}},
    "fsdp_zero1": {"cell_kw": {"zero1": True}},
    "seq_shard_act": {  # context-parallel attention activations
        "rules_overrides": {"act_kv": None, "act_seq": "model"}},
    "experts_on_data": {  # MoE: expert dim over the data axis
        "rules_overrides": {"experts": "data", "expert_mlp": "model"}},
    "moe_grouped16": {    # group-local dispatch aligned with data shards
        "cfg_overrides": {"moe_dispatch_groups": 16}},
    "moe_grouped32": {
        "cfg_overrides": {"moe_dispatch_groups": 32}},
    "moe_flat": {         # naive flat scatter (pre-optimization baseline)
        "cfg_overrides": {"moe_dispatch_groups": 0}},
    "moe_grouped16_micro2": {
        "cfg_overrides": {"moe_dispatch_groups": 16},
        "cell_kw": {"n_micro": 2}},
    # --- schedule / recompute changes ------------------------------------
    "micro1": {"cell_kw": {"n_micro": 1}},
    "micro2": {"cell_kw": {"n_micro": 2}},
    "micro8": {"cell_kw": {"n_micro": 8}},
    "micro16": {"cell_kw": {"n_micro": 16}},
    "no_remat": {"cfg_overrides": {"remat": False}},
    # --- serving-specific --------------------------------------------------
    "serve_tp_only": {  # decode/prefill: params pure-TP (no data-axis shard)
        "rules_overrides": {"embed": None}},
    "decode_batch_2d": {  # decode batch over (data x model), cache unsharded
                          # on seq (per-device full heads)
        "rules_overrides": {"batch": ("pod", "data", "model"),
                            "kv_seq": None, "act_kv_seq": None}},
    "cache_head_shard": {  # decode cache sharded on kv heads (when it fits)
        "rules_overrides": {"kv_seq": None, "act_kv_seq": None,
                            "kv_heads": "model", "act_kv": "model"}},
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    help="arch/shape, e.g. qwen1.5-110b/train_4k")
    ap.add_argument("--variant", required=True,
                    help="comma-separated variant names")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default="results/hillclimb.jsonl")
    ap.add_argument("--shard", default="0/1", metavar="i/n",
                    help="run only variants with list-index %% n == i, "
                    "into a .shardIofN.jsonl sibling of --out")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    arch, shape = args.cell.split("/")
    si, sn = parse_shard_spec(args.shard)
    # append-only sweep log; duplicate keys are last-wins, so --force simply
    # appends an overriding record without losing history
    out = Path(args.out)
    if out.suffix == ".json":
        # an old-style invocation (pre-JSONL default): never write JSONL
        # into a .json path — redirect to the sibling and migrate below
        print(f"[hillclimb] --out {out} is the legacy dict format; "
              f"writing to {out.with_suffix('.jsonl')} instead")
        out = out.with_suffix(".jsonl")
    if sn > 1:
        # per-shard artifact: report.py merges the shard files with the
        # base jsonl (last-wins), so shards never contend on one file
        out = out.with_name(f"{out.stem}.shard{si}of{sn}{out.suffix}")
    legacy = out.with_suffix(".json")
    migrate = sn == 1 and legacy.exists() and not out.exists()
    sweep = ResumableSweep(out)
    if migrate:
        # one-time carry-over of pre-JSONL records so the before/after
        # comparison keeps its "before" rows
        for key, rec in json.loads(legacy.read_text()).items():
            sweep.add(key, rec)
        print(f"[migrate] {len(sweep)} records from {legacy} -> {out}")

    variants = [v for j, v in enumerate(args.variant.split(","))
                if j % sn == si]
    if sn > 1:
        print(f"[hillclimb] shard {si}/{sn}: {len(variants)} variant(s) "
              f"-> {out}")
    for vname in variants:
        spec = VARIANTS[vname]
        key = f"{args.cell}|{args.mesh}|{vname}"
        prev = sweep.get(key)
        if prev is not None and prev.get("ok") and not args.force:
            print(f"[skip] {key}")
            continue
        print(f"[variant] {key} ...", flush=True)
        t0 = time.time()
        try:
            rec = run_cell(arch, shape, args.mesh,
                           rules_overrides=spec.get("rules_overrides"),
                           cfg_overrides=spec.get("cfg_overrides"),
                           **spec.get("cell_kw", {}))
            rec["variant"] = vname
            print(f"[ok] {key}: compute={rec['t_compute']*1e3:.1f}ms "
                  f"memory={rec['t_memory']*1e3:.1f}ms "
                  f"coll={rec['t_collective']*1e3:.1f}ms "
                  f"bound={rec['t_compute'] and max(rec['t_compute'], rec['t_memory'], rec['t_collective'])*1e3:.1f}ms "
                  f"frac={rec['roofline_fraction']:.3f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            rec = {"ok": False, "variant": vname,
                   "error": f"{type(e).__name__}: {e}"}
            print(f"[FAIL] {key}: {rec['error'][:160]}", flush=True)
        sweep.add(key, rec)


if __name__ == "__main__":
    main()
