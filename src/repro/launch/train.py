"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ck [--reduced]

Uses all local devices as a (data, 1) mesh; on a real TPU pod slice the same
entry point runs under the production mesh (the step builders are identical
to the dry-run ones).  Fault tolerance: resumes from the latest checkpoint
in --ckpt-dir automatically.
"""

from __future__ import annotations

import argparse

from ..configs import get_config
from ..data.pipeline import DataConfig
from ..optim.adamw import AdamWConfig
from ..runtime.train_loop import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) variant of the arch")
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    tcfg = TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir, log_every=10,
                       opt=AdamWConfig(lr=args.lr, warmup_steps=20,
                                       total_steps=args.steps))
    trainer = Trainer(cfg, data, tcfg)
    out = trainer.run(resume=not args.no_resume)
    print(f"[train] done: final loss {out['losses'][-1]:.4f}, "
          f"slow steps {out['slow_steps']}")


if __name__ == "__main__":
    main()
