"""Multi-host supervised sweep control CLI.

Usage::

    # CI-sized chaos run: 2 local shard children, injected kill fault,
    # result asserted bit-identical to a clean in-process run
    PYTHONPATH=src python -m repro.launch.sweep_ctl launch --quick \\
        --out /tmp/sweep --hosts 2 --fault kill --fault-seed 0 \\
        --verify-clean

    # real sweep from a spec file over SSH hosts
    PYTHONPATH=src python -m repro.launch.sweep_ctl launch \\
        --spec sweep.json --out results/sweep \\
        --host "ssh dse-01 {cmd}" --host "ssh dse-02 {cmd}"

    PYTHONPATH=src python -m repro.launch.sweep_ctl status --out results/sweep
    PYTHONPATH=src python -m repro.launch.sweep_ctl resume --out results/sweep
    PYTHONPATH=src python -m repro.launch.sweep_ctl merge  --out results/sweep

``launch`` screens once in the supervisor, dispatches explicit
candidate-index shards to the hosts, polls checkpoint heartbeats for
liveness, retries/re-shards failures, and merges under the sweep
fingerprint.  ``status`` renders per-shard progress and the mid-flight
Pareto frontier from whatever the journal says has been launched —
including while the sweep is still running under another process.
``resume`` continues a killed supervisor from its journal.  ``merge``
re-runs just the merge + completeness check over the journal's
checkpoints.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from ..dist.faults import FAULT_KINDS
from ..dist.hosts import parse_hosts
from ..dist.supervisor import (Supervisor, SupervisorError, SweepSpec,
                               quick_spec, read_state, supervised_results)
from ..obs import report as obs_report


def _sig(points):
    return [(p.arch, p.objective, p.energy_j, p.delay_s) for p in points]


def _load_spec(args) -> SweepSpec:
    if args.spec is not None:
        return SweepSpec.from_json(Path(args.spec).read_text())
    if args.quick:
        return quick_spec(seed=args.seed, n_shards=args.shards,
                          screen_keep=args.screen_keep)
    raise SystemExit("need --spec FILE or --quick")


def _supervisor(spec: SweepSpec, args, fault_kind=None,
                fault_k=None) -> Supervisor:
    hosts = parse_hosts(args.host, n_local=args.hosts)
    return Supervisor(spec, out_dir=args.out, hosts=hosts,
                      state_path=args.state, hb_timeout=args.hb_timeout,
                      poll_s=args.poll, max_attempts=args.max_attempts,
                      hb_every=args.hb_every, fault_kind=fault_kind,
                      fault_seed=args.fault_seed, fault_k=fault_k)


def _verify_clean(spec: SweepSpec, merged: Path) -> int:
    """Assert the supervised result is bit-identical to a failure-free
    unsharded in-process run of the same grid + seed."""
    got = _sig(supervised_results(spec, merged))
    from ..core.dse import run_dse
    want = _sig(run_dse(spec.build_candidates(), spec.build_workloads(),
                        spec.build_cfg(), use_sa=spec.use_sa,
                        screen_keep=spec.screen_keep))
    if got != want:
        print(f"verify-clean: MISMATCH ({len(got)} vs {len(want)} points)",
              file=sys.stderr)
        for g, w in zip(got, want):
            if g != w:
                print(f"  supervised: {g}\n  clean:      {w}",
                      file=sys.stderr)
                break
        return 1
    print(f"verify-clean: OK — {len(got)} points bit-identical to the "
          "clean unsharded run")
    return 0


def cmd_launch(args) -> int:
    spec = _load_spec(args)
    fault_kind = fault_k = None
    if args.fault:
        parts = args.fault.split(":")
        fault_kind = parts[0]
        if fault_kind not in FAULT_KINDS:
            raise SystemExit(f"unknown --fault {fault_kind!r}; "
                             f"one of {FAULT_KINDS}")
        fault_k = int(parts[1]) if len(parts) > 1 and parts[1] else None
    sup = _supervisor(spec, args, fault_kind=fault_kind, fault_k=fault_k)
    try:
        merged = sup.run()
    except SupervisorError as e:
        print(f"supervisor failed: {e}", file=sys.stderr)
        return 2
    print(f"merged: {merged}")
    if args.verify_clean:
        return _verify_clean(spec, merged)
    return 0


def cmd_resume(args) -> int:
    out = Path(args.out)
    spec_path = out / "spec.json"
    if args.spec is None and spec_path.exists():
        args.spec = str(spec_path)
    spec = _load_spec(args)
    sup = _supervisor(spec, args)
    try:
        merged = sup.resume()
    except SupervisorError as e:
        print(f"supervisor failed: {e}", file=sys.stderr)
        return 2
    print(f"merged: {merged}")
    if args.verify_clean:
        return _verify_clean(spec, merged)
    return 0


def cmd_status(args) -> int:
    state_path = Path(args.state) if args.state \
        else Path(args.out) / "supervisor_state.jsonl"
    if not state_path.exists():
        print(f"no supervisor journal at {state_path}", file=sys.stderr)
        return 1
    state = read_state(state_path)
    plan = state["plan"]
    counts = {}
    for e in state["events"]:
        counts[e["ev"]] = counts.get(e["ev"], 0) + 1
    if args.json:
        doc = {"plan": plan, "event_counts": counts,
               "checkpoints": state["checkpoints"],
               "merged": state["merged"],
               "shards": obs_report.shard_progress(
                   [p for p in state["checkpoints"] if Path(p).exists()]),
               "pareto": obs_report.pareto_snapshot(
                   [p for p in state["checkpoints"] if Path(p).exists()],
                   top=args.top)}
        json.dump(doc, sys.stdout, indent=1, default=str)
        sys.stdout.write("\n")
        return 0
    lines = [f"supervisor journal: {state_path}"]
    if plan is not None:
        lines.append(f"  fingerprint {plan['fingerprint']}")
        lines.append(f"  keep set: {len(plan['keep'])}/"
                     f"{plan['n_candidates']} candidates over "
                     f"{len(plan['shards'])} shard(s)")
        if plan.get("fault_kind"):
            lines.append(f"  chaos: fault={plan['fault_kind']} "
                         f"plan={plan.get('faults')}")
    lines.append("  events: " + ", ".join(
        f"{k}={v}" for k, v in sorted(counts.items())))
    if state["merged"] is not None:
        lines.append(f"  merged: {state['merged']['out']} "
                     f"({state['merged']['n_records']} records)")
    print("\n".join(lines))
    live = [p for p in state["checkpoints"] if Path(p).exists()]
    if live:
        print()
        print(obs_report.render_report(run=None, ckpts=live, top=args.top))
    return 0


def cmd_merge(args) -> int:
    out = Path(args.out)
    spec = SweepSpec.from_json((out / "spec.json").read_text())
    state = read_state(Path(args.state) if args.state
                       else out / "supervisor_state.jsonl")
    ckpts = [Path(p) for p in state["checkpoints"] if Path(p).exists()]
    if not ckpts:
        print("no shard checkpoints recorded in the journal",
              file=sys.stderr)
        return 1
    from ..core.explore import (merge_checkpoints,
                                remaining_candidate_indices)
    merged = out / "merged.jsonl"
    report = merge_checkpoints(ckpts, out=merged,
                               expect_fingerprint=spec.fingerprint(),
                               on_conflict=args.on_conflict)
    keep = (state["plan"]["keep"] if state["plan"] is not None
            else None)
    left = remaining_candidate_indices(
        spec.build_candidates(), spec.build_workloads(), spec.build_cfg(),
        merged, use_sa=spec.use_sa, indices=keep)
    status = "complete" if not left else f"INCOMPLETE ({len(left)} missing)"
    print(f"merged {report.n_records} records from {len(report.merged)} "
          f"shard(s) -> {merged} [{status}]")
    if report.conflicts:
        print(f"  {len(report.conflicts)} conflicting key(s): "
              f"{report.conflicts[:4]}", file=sys.stderr)
    return 0 if not left else 2


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="sweep_ctl", description=__doc__.split("\n\n")[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p, launchish=True):
        p.add_argument("--out", required=True, metavar="DIR",
                       help="supervisor output dir (journal, shard "
                            "checkpoints, merged.jsonl)")
        p.add_argument("--state", default=None,
                       help="journal path (default OUT/supervisor_state"
                            ".jsonl)")
        if not launchish:
            return
        p.add_argument("--spec", default=None, help="SweepSpec JSON file")
        p.add_argument("--quick", action="store_true",
                       help="built-in CI-sized sweep spec")
        p.add_argument("--seed", type=int, default=3)
        p.add_argument("--shards", type=int, default=2)
        p.add_argument("--screen-keep", type=float, default=1.0)
        p.add_argument("--hosts", type=int, default=0, metavar="N",
                       help="N local-process hosts")
        p.add_argument("--host", action="append", default=[],
                       metavar="TEMPLATE",
                       help="shell-command host template containing "
                            "{cmd}; repeatable")
        p.add_argument("--hb-timeout", type=float, default=60.0,
                       help="seconds without heartbeat progress before a "
                            "shard is declared dead")
        p.add_argument("--poll", type=float, default=0.5)
        p.add_argument("--hb-every", type=float, default=0.0,
                       help="child heartbeat period (0 = every task)")
        p.add_argument("--max-attempts", type=int, default=3)
        p.add_argument("--fault-seed", type=int, default=0)
        p.add_argument("--verify-clean", action="store_true",
                       help="after merge, assert bit-identity against a "
                            "clean unsharded in-process run")

    p = sub.add_parser("launch", help="screen, dispatch, supervise, merge")
    common(p)
    p.add_argument("--fault", default=None, metavar="KIND[:K]",
                   help=f"inject a deterministic fault ({FAULT_KINDS})")
    p.set_defaults(fn=cmd_launch)

    p = sub.add_parser("resume", help="continue a killed supervisor")
    common(p)
    p.set_defaults(fn=cmd_resume)

    p = sub.add_parser("status", help="render journal + shard progress")
    common(p, launchish=False)
    p.add_argument("--top", type=int, default=10)
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_status)

    p = sub.add_parser("merge", help="merge journal checkpoints now")
    common(p, launchish=False)
    p.add_argument("--on-conflict", default="report",
                   choices=("report", "error"))
    p.set_defaults(fn=cmd_merge)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
