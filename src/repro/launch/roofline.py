"""Roofline-term extraction from compiled XLA artifacts.

compute term    = per-device HLO FLOPs / peak_FLOPs          (197e12 bf16, v5e)
memory term     = per-device HLO bytes / HBM bw               (819e9 B/s)
collective term = per-device collective bytes / ICI link bw   (50e9 B/s)

``cost_analysis()`` on the SPMD-partitioned executable reports *per-device*
FLOPs/bytes (verified empirically: a 256-way-sharded matmul reports 1/256 of
the global FLOPs), so the terms below already match the prompt's
global/(chips x peak) formulas.  Collective bytes are parsed from the
compiled HLO text: the summed output-tensor sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute (+ their
async -start variants; -done ops are skipped to avoid double counting).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

from ..core.hw import TPU_V5E, TPUChip

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "e4m3": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Sum byte sizes of every dtype[dims] occurrence in a type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes from (post-SPMD) HLO text."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        typestr, op = m.group(1), m.group(2)
        base = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done"):
            continue
        if base in _COLLECTIVES:
            out[base] += _shape_bytes(typestr)
    return out


@dataclass
class Roofline:
    name: str
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_by_kind: Dict[str, int] = field(default_factory=dict)
    # memory proof
    argument_bytes: float = 0.0
    output_bytes: float = 0.0
    temp_bytes: float = 0.0
    model_flops: float = 0.0           # 6*N*D (or 2*N*D serve), GLOBAL
    n_devices: int = 256
    compile_s: float = 0.0
    chip: TPUChip = field(default_factory=lambda: TPU_V5E)

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / self.chip.peak_flops_bf16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / self.chip.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / self.chip.ici_bw

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs (remat/redundancy waste metric)."""
        hlo_global = self.flops_per_device * self.n_devices
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute share of the bound: (model-FLOPs time) / t_bound."""
        t_useful = (self.model_flops / self.n_devices
                    / self.chip.peak_flops_bf16)
        return t_useful / self.t_bound if self.t_bound else 0.0

    def to_dict(self) -> Dict:
        return {
            "name": self.name,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_by_kind": self.coll_by_kind,
            "argument_bytes": self.argument_bytes,
            "output_bytes": self.output_bytes,
            "temp_bytes": self.temp_bytes,
            "model_flops": self.model_flops,
            "n_devices": self.n_devices,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "compile_s": self.compile_s,
        }


def flash_kernel_adjustment(cfg, shape, data_ax: int = 16,
                            model_ax: int = 16, n_pod: int = 1,
                            block: int = 1024) -> float:
    """Bytes/device the Pallas flash kernel saves vs the jnp-lowered path.

    The dry-run lowers the jnp flash scan (Pallas cannot compile on the CPU
    backend); its per-kv-block score/prob tensors are materialized between
    fusions and show up as HBM traffic, but on TPU the kernel keeps them in
    VMEM.  This analytic adjustment = (scan-internal s/p traffic) minus
    (ideal kernel q/k/v/o traffic), with x4 for train (fwd + remat-fwd +
    2-pass bwd), x1 for prefill, 0 for decode (einsum path, no scan).
    Napkin math, reported alongside the as-lowered term — never替换 it.
    """
    if cfg.family == "ssm" or shape.kind == "decode":
        return 0.0
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    B, S = shape.global_batch, shape.seq_len
    if S * S <= 256 * 2048:
        return 0.0                              # einsum path, no scan
    bshard = 1
    for ax in (n_pod, data_ax):
        if B % (bshard * ax) == 0:
            bshard *= ax
    B_loc = B // bshard
    # attention layout (mirrors launch.steps.derive_attn_rules)
    if KV % model_ax == 0 or H % model_ax == 0:
        heads_loc = max(1, H // model_ax)
        Sq_loc = S
    else:
        heads_loc = H
        Sq_loc = max(1, S // model_ax)
    nblocks = -(-S // block)
    per_call = nblocks * 2 * B_loc * heads_loc * Sq_loc * block * 4 * 2
    ideal = B_loc * S * (H + 2 * KV) * hd * 2 * 2
    n_attn = cfg.n_layers if cfg.family != "hybrid" else cfg.n_shared_attn()
    if cfg.family == "encdec":
        n_attn = cfg.n_enc_layers + 2 * cfg.n_layers
    passes = 4.0 if shape.kind == "train" else 1.0
    return max(0.0, (per_call - ideal) * n_attn * passes)


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N*D for inference tokens."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n * tokens


def analyze_compiled(name: str, compiled, lowered_text: Optional[str],
                     model_flops: float, n_devices: int,
                     compile_s: float = 0.0) -> Roofline:
    """Roofline terms from the compiled per-device module.

    Primary source is the trip-count-aware HLO walker (hlo_analysis) —
    XLA's own cost_analysis counts while bodies once, which would be wrong
    by ~n_layers x n_micro for scanned models (verified; see
    hlo_analysis docstring).  The raw cost_analysis numbers are kept for
    cross-checking in the record.
    """
    from .hlo_analysis import analyze_hlo_text
    ca = compiled.cost_analysis()
    text = compiled.as_text()
    costs = analyze_hlo_text(text)
    ma = compiled.memory_analysis()
    return Roofline(
        name=name,
        flops_per_device=costs.flops,
        bytes_per_device=costs.bytes,
        coll_bytes_per_device=costs.coll_bytes,
        coll_by_kind={k: int(v) for k, v in costs.coll_by_kind.items()},
        argument_bytes=float(getattr(ma, "argument_size_in_bytes", 0)),
        output_bytes=float(getattr(ma, "output_size_in_bytes", 0)),
        temp_bytes=float(getattr(ma, "temp_size_in_bytes", 0)),
        model_flops=model_flops,
        n_devices=n_devices,
        compile_s=compile_s)
