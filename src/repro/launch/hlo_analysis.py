"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically: a scan of L matmuls reports ~1 matmul of FLOPs regardless of
L).  Since this framework deliberately scans over layers/microbatches to
keep compile times sane, all roofline terms would be wrong by ~L x micro.

This module re-derives the terms from ``compiled.as_text()``:

  * computations are parsed into instruction lists;
  * ``while`` trip counts come from the max s32 constant in the condition
    computation (lax.scan lowers to 0..N step-1 loops);
  * FLOPs: 2 * output_elems * contraction_size for every dot, recursing
    through fusions/whiles (x trip) and calls;
  * bytes: operand + output bytes per instruction at fusion granularity
    (XLA's own bytes-accessed convention), x trips inside loops;
  * collective bytes: output sizes of all-gather/all-reduce/reduce-scatter/
    all-to-all/collective-permute (+ async starts), x trips — FSDP
    all-gathers living inside the layer scan are the dominant term and are
    exactly what the once-counted version misses;
  * ``conditional`` branches are averaged (noted: zamba2's every-6-layers
    attention is overcounted by ~2.7x under this rule; the roofline stays
    conservative).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# instructions that move no real data
_BOOKKEEPING = {"parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "after-all", "iota", "partition-id", "replica-id"}


def _type_bytes(typestr: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(typestr):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _type_elems(typestr: str) -> int:
    m = _SHAPE_RE.search(typestr)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            n *= int(d)
    return n


@dataclass
class Instruction:
    name: str
    typestr: str
    op: str
    line: str
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instructions: List[Instruction] = field(default_factory=list)


_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|[\w\[\]\{\},: ]+?)\s+"
    r"([\w\-]+)\(")
_OPERAND = re.compile(r"%([\w\.\-]+)")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m and line.strip().endswith("{"):
                cur = Computation(name=m.group(2))
                if m.group(1):
                    entry = m.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        mi = _INSTR.match(line)
        if mi:
            name, typestr, op = mi.group(1), mi.group(2), mi.group(3)
            paren = line[mi.end() - 1:]
            # operands: %refs inside the first balanced paren group
            depth = 0
            end = 0
            for i, ch in enumerate(paren):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            ops = _OPERAND.findall(paren[:end + 1])
            cur.instructions.append(Instruction(
                name=name, typestr=typestr, op=op, line=line, operands=ops))
    return comps, entry


def _attr_comp(line: str, key: str) -> Optional[str]:
    m = re.search(rf"{key}=%?([\w\.\-]+)", line)
    return m.group(1) if m else None


def _branch_comps(line: str) -> List[str]:
    m = re.search(r"branch_computations=\{([^}]*)\}", line)
    if not m:
        return []
    return [x.strip().lstrip("%") for x in m.group(1).split(",")]


def _dot_flops(ins: Instruction, sizes: Dict[str, str]) -> float:
    out_elems = _type_elems(ins.typestr)
    lhs_t = sizes.get(ins.operands[0], "") if ins.operands else ""
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    k = 1
    if m and lhs_t:
        dims_m = _SHAPE_RE.search(lhs_t)
        if dims_m and dims_m.group(2):
            dims = [int(d) for d in dims_m.group(2).split(",")]
            for idx in (int(x) for x in m.group(1).split(",") if x):
                if idx < len(dims):
                    k *= dims[idx]
    return 2.0 * out_elems * k


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Costs", scale: float = 1.0) -> None:
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        self.coll_bytes += other.coll_bytes * scale
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * scale


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        # global result-type map (names are module-unique in practice)
        self.sizes: Dict[str, str] = {}
        for c in self.comps.values():
            for ins in c.instructions:
                self.sizes[ins.name] = ins.typestr
        self._memo: Dict[str, Costs] = {}

    def trip_count(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        best = 1
        for ins in comp.instructions:
            if ins.op == "constant":
                m = re.match(r"s32\[\]", ins.typestr)
                c = re.search(r"constant\((\d+)\)", ins.line)
                if m and c:
                    best = max(best, int(c.group(1)))
        return best

    def _dus_bytes(self, callee: Optional[str]) -> Optional[float]:
        """If a fusion updates a big buffer in place (dynamic-update-slice —
        scan stacking, KV-cache writes), charge the slice-sized work only:
        XLA aliases donated buffers, so the full-buffer passes (and the CPU
        backend's full-buffer f32<->bf16 converts) never touch HBM on TPU.
        Returns None when the fusion has no dus."""
        comp = self.comps.get(callee) if callee else None
        if comp is None:
            return None
        dus = [ci for ci in comp.instructions
               if ci.op == "dynamic-update-slice"]
        if not dus:
            return None
        target_b = max(_type_bytes(ci.typestr) for ci in dus)
        total = 0.0
        for ci in comp.instructions:
            if ci.op in _BOOKKEEPING:
                continue
            out_b = _type_bytes(ci.typestr)
            if out_b >= 0.5 * target_b:
                continue                    # buffer-sized op: aliased/in-place
            total += 2 * out_b
        return total

    _MOVEMENT_OPS = {"dynamic-slice", "slice", "convert", "copy",
                     "reshape", "transpose"}

    def _movement_bytes(self, callee: Optional[str]) -> Optional[float]:
        """Pure data-movement fusions (slice/convert/transpose chains):
        charge 2 x the narrowest tensor in the chain.  The CPU backend
        promotes bf16 params to f32 and re-materializes both widths; a TPU
        bf16 lowering moves the narrow version once."""
        comp = self.comps.get(callee) if callee else None
        if comp is None:
            return None
        sizes = []
        for ci in comp.instructions:
            if ci.op in _BOOKKEEPING:
                continue
            if ci.op not in self._MOVEMENT_OPS:
                return None
            sizes.append(_type_bytes(ci.typestr))
        if not sizes:
            return None
        return 2.0 * min(sizes)

    def _is_convert_only(self, callee: str) -> bool:
        comp = self.comps.get(callee)
        if comp is None:
            return False
        compute = [ci for ci in comp.instructions
                   if ci.op not in _BOOKKEEPING]
        return bool(compute) and all(ci.op in ("convert", "copy")
                                     for ci in compute)

    def _fusion_input_bytes(self, ins: Instruction,
                            callee: Optional[str]) -> float:
        """Bytes actually READ from each fusion operand.

        A scan body receives the full stacked (L, ...) parameter but only
        dynamic-slices one layer out — charging the full operand would
        overcount HBM traffic by ~L x trips.  If every consumer of a fusion
        parameter is a dynamic-slice, charge the slice outputs instead.
        """
        comp = self.comps.get(callee) if callee else None
        if comp is None:
            return float(sum(_type_bytes(self.sizes.get(o, ""))
                             for o in ins.operands))
        # parameter index -> instruction name, and name -> consumers
        param_names: Dict[int, str] = {}
        for ci in comp.instructions:
            if ci.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", ci.line)
                if m:
                    param_names[int(m.group(1))] = ci.name
        total = 0.0
        for i, operand in enumerate(ins.operands):
            full = _type_bytes(self.sizes.get(operand, ""))
            pname = param_names.get(i)
            if pname is None:
                total += full
                continue
            consumers = [ci for ci in comp.instructions
                         if pname in ci.operands]
            if consumers and all(ci.op == "dynamic-slice"
                                 for ci in consumers):
                total += sum(_type_bytes(ci.typestr) for ci in consumers)
            else:
                total += full
        return total

    def costs(self, comp_name: Optional[str] = None) -> Costs:
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            return self._memo[comp_name]
        total = Costs()
        comp = self.comps.get(comp_name)
        if comp is None:
            return total
        self._memo[comp_name] = total      # break cycles defensively
        for ins in comp.instructions:
            op = ins.op
            base = op[:-6] if op.endswith("-start") else op
            if op.endswith("-done") or op in _BOOKKEEPING:
                continue
            # data movement at this level (fusion-granular)
            out_b = _type_bytes(ins.typestr)
            in_b = sum(_type_bytes(self.sizes.get(o, ""))
                       for o in ins.operands)
            if op == "while":
                body = _attr_comp(ins.line, "body")
                cond = _attr_comp(ins.line, "condition")
                trips = self.trip_count(cond) if cond else 1
                if body:
                    total.add(self.costs(body), trips)
                if cond:
                    total.add(self.costs(cond), trips)
                continue
            if op == "conditional":
                branches = _branch_comps(ins.line)
                if branches:
                    sub = Costs()
                    for b in branches:
                        sub.add(self.costs(b), 1.0 / len(branches))
                    total.add(sub)
                continue
            if op == "dynamic-update-slice":
                # in-place update (XLA aliases donated buffers): traffic is
                # the updated slice, not the whole target buffer
                upd = _type_bytes(self.sizes.get(ins.operands[1], "")) \
                    if len(ins.operands) > 1 else out_b
                total.bytes += 2 * upd
                continue
            if op in ("fusion", "call", "custom-call", "map"):
                callee = _attr_comp(ins.line, "calls") \
                    or _attr_comp(ins.line, "to_apply")
                if callee and self._is_convert_only(callee):
                    # CPU-backend f32 promotion artifact: TPU bf16 lowering
                    # has no materialized convert — don't charge traffic.
                    continue
                dus_b = self._dus_bytes(callee)
                if dus_b is not None:
                    total.bytes += dus_b
                    continue
                mv_b = self._movement_bytes(callee)
                if mv_b is not None:
                    total.bytes += mv_b
                    continue
                total.bytes += out_b + self._fusion_input_bytes(ins, callee)
                if callee:
                    inner = self.costs(callee)
                    total.flops += inner.flops
                    total.coll_bytes += inner.coll_bytes
                    for k, v in inner.coll_by_kind.items():
                        total.coll_by_kind[k] = \
                            total.coll_by_kind.get(k, 0.0) + v
                continue
            if base in _COLLECTIVES:
                total.coll_bytes += out_b
                total.coll_by_kind[base] = \
                    total.coll_by_kind.get(base, 0.0) + out_b
                total.bytes += out_b + in_b
                continue
            if op in ("dot", "convolution"):
                total.flops += _dot_flops(ins, self.sizes)
            total.bytes += out_b + in_b
        self._memo[comp_name] = total
        return total


def analyze_hlo_text(text: str) -> Costs:
    return HloAnalyzer(text).costs()


def top_contributors(text: str, metric: str = "bytes",
                     k: int = 20) -> List[Tuple[float, str, str]]:
    """Profile: (weighted_cost, computation, instruction-line) heavy hitters.

    Walks the module like ``costs`` but attributes per-instruction costs
    multiplied by the enclosing loops' trip counts — the dry-run's
    stand-in for a wall-clock profile (per §Perf methodology).
    """
    az = HloAnalyzer(text)
    out: List[Tuple[float, str, str]] = []

    def walk(comp_name: str, scale: float, seen: tuple):
        comp = az.comps.get(comp_name)
        if comp is None or comp_name in seen:
            return
        seen = seen + (comp_name,)
        for ins in comp.instructions:
            op = ins.op
            if op.endswith("-done") or op in _BOOKKEEPING:
                continue
            if op == "while":
                body = _attr_comp(ins.line, "body")
                cond = _attr_comp(ins.line, "condition")
                trips = az.trip_count(cond) if cond else 1
                if body:
                    walk(body, scale * trips, seen)
                continue
            if op == "conditional":
                for b in _branch_comps(ins.line):
                    walk(b, scale * 0.5, seen)
                continue
            callee = _attr_comp(ins.line, "calls") \
                or _attr_comp(ins.line, "to_apply")
            if op in ("fusion", "call", "map") and callee:
                if az._is_convert_only(callee):
                    continue
                dus_b = az._dus_bytes(callee)
                if metric == "bytes":
                    if dus_b is not None:
                        cost = dus_b
                    else:
                        cost = _type_bytes(ins.typestr) \
                            + az._fusion_input_bytes(ins, callee)
                else:
                    cost = az.costs(callee).flops
                if cost:
                    out.append((cost * scale, comp_name, ins.line[:160]))
                continue
            if metric == "bytes":
                cost = _type_bytes(ins.typestr) + sum(
                    _type_bytes(az.sizes.get(o, "")) for o in ins.operands)
            else:
                cost = _dot_flops(ins, az.sizes) \
                    if op in ("dot", "convolution") else 0.0
            if cost:
                out.append((cost * scale, comp_name, ins.line[:160]))

    walk(az.entry, 1.0, ())
    out.sort(key=lambda t: -t[0])
    return out[:k]
