"""Fault-tolerant training loop.

Composes: synthetic data pipeline (+prefetch), sharded train step (from
launch.steps), checkpoint manager (atomic, keep-K, async), straggler
watchdog (step-time EWMA; slow steps are logged and counted — on real
multi-host topologies this is where you'd trigger hot-spare swaps), and
crash recovery: on start the loop restores the latest checkpoint and the
data pipeline resumes bit-exactly (batches are a pure function of step).
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from ..checkpoint.ckpt import CheckpointManager
from ..configs.base import ModelConfig
from ..data.pipeline import DataConfig, Prefetcher, make_batch, make_embeds_batch
from ..launch.steps import (batch_axes, derive_attn_rules, fit_batch_rules)
from ..models import model_api
from ..nn.params import default_rules, tree_sharding
from ..optim.adamw import AdamWConfig, adamw_update, init_opt_state
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    seed: int = 0
    straggler_factor: float = 3.0
    async_ckpt: bool = True
    opt: AdamWConfig = field(default_factory=AdamWConfig)


@dataclass
class StragglerWatchdog:
    """EWMA step-time monitor (straggler mitigation hook)."""
    factor: float = 3.0
    alpha: float = 0.2
    ewma: Optional[float] = None
    slow_steps: int = 0

    def observe(self, dt: float) -> bool:
        slow = self.ewma is not None and dt > self.factor * self.ewma
        if slow:
            self.slow_steps += 1
        # don't poison the EWMA with outliers
        self.ewma = dt if self.ewma is None else (
            self.ewma if slow else
            (1 - self.alpha) * self.ewma + self.alpha * dt)
        return slow


class Trainer:
    def __init__(self, cfg: ModelConfig, data: DataConfig, tcfg: TrainConfig,
                 mesh=None):
        self.cfg = cfg
        self.data = data
        self.tcfg = tcfg
        if mesh is None:
            from ..launch.mesh import make_host_mesh
            n = len(jax.devices())
            mesh = make_host_mesh((n, 1), ("data", "model"))
        self.mesh = mesh
        self.api = model_api(cfg)
        rules = fit_batch_rules(default_rules(), data.global_batch, mesh)
        self.rules = derive_attn_rules(cfg, mesh, rules, "train")
        self.mgr = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep,
                                     async_write=tcfg.async_ckpt)
        self.watchdog = StragglerWatchdog(factor=tcfg.straggler_factor)
        self.metrics_log: list = []
        self._build()

    # ------------------------------------------------------------------
    def _build(self):
        from ..launch.steps import get_param_axes
        cfg, mesh, rules = self.cfg, self.mesh, self.rules
        p_axes = get_param_axes(cfg)
        self.p_shardings = tree_sharding(p_axes, rules, mesh)
        opt_cfg = self.tcfg.opt

        def step_fn(state, batch):
            params, opt = state["params"], state["opt"]
            (loss, m), grads = jax.value_and_grad(
                lambda p, b: self.api.loss_fn(p, b, rules),
                has_aux=True)(params, batch)
            new_p, new_opt, om = adamw_update(opt_cfg, params, grads, opt)
            return ({"params": new_p, "opt": new_opt},
                    {"loss": m["nll"], **om})

        self.step_fn = jax.jit(step_fn, donate_argnums=(0,))

    def init_state(self) -> Dict[str, Any]:
        key = jax.random.PRNGKey(self.tcfg.seed)
        params, _ = self.api.init_params(key)
        params = jax.tree.map(lambda x, s: jax.device_put(x, s),
                              params, self.p_shardings)
        return {"params": params, "opt": init_opt_state(params)}

    def _batch_fn(self, step: int) -> Dict[str, np.ndarray]:
        if self.cfg.frontend in ("patch", "audio"):
            return make_embeds_batch(self.data, step, self.cfg.d_model,
                                     need_tokens=self.cfg.family == "encdec")
        return make_batch(self.data, step)

    # ------------------------------------------------------------------
    def run(self, resume: bool = True) -> Dict[str, Any]:
        state = self.init_state()
        start = 0
        if resume:
            restored, start = self.mgr.restore_latest(
                jax.tree.map(np.asarray, state))
            if restored is not None:
                state = jax.tree.map(
                    lambda x, ref: jax.device_put(np.asarray(x), ref.sharding),
                    restored, state)
                print(f"[trainer] resumed from step {start}")
        pf = Prefetcher(self._batch_fn, start_step=start, depth=2)
        losses = []
        try:
            for step in range(start, self.tcfg.steps):
                _, batch = pf.next()
                t0 = time.time()
                state, metrics = self.step_fn(state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                slow = self.watchdog.observe(dt)
                losses.append(loss)
                if slow:
                    print(f"[watchdog] step {step} took {dt:.2f}s "
                          f"(ewma {self.watchdog.ewma:.2f}s) — straggler")
                if step % self.tcfg.log_every == 0:
                    rec = {"step": step, "loss": loss, "dt": dt,
                           "grad_norm": float(metrics["grad_norm"]),
                           "lr": float(metrics["lr"])}
                    self.metrics_log.append(rec)
                    print(f"[trainer] {json.dumps(rec)}", flush=True)
                if (step + 1) % self.tcfg.ckpt_every == 0 \
                        or step + 1 == self.tcfg.steps:
                    self.mgr.save(state, step + 1)
            self.mgr.wait()
        finally:
            pf.close()
        return {"state": state, "losses": losses,
                "slow_steps": self.watchdog.slow_steps,
                "final_step": self.tcfg.steps}
