"""Execute a Gemini ``MeshPlan`` as a layer-pipelined forward pass.

Demonstration-grade executor for the dense family: stage s owns layers
[i0, i1) (a slice of the scan-stacked params) and a device subset from the
plan; activations hop stage-to-stage with ``jax.device_put`` (the D2D/ICI
transfer the Gemini evaluator priced).  Microbatches stream through the
stages in pipeline order; per-stage wall times are recorded so the schedule
is inspectable.  Real deployments would fuse this into one shard_map with
collective_permute — this executor is the readable reference used by
examples/map_to_mesh.py and the bridge tests.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.bridge import MeshPlan
from ..models import lm
from ..nn.layers import embed, unembed
from ..nn.params import default_rules


@dataclass
class PipelineExec:
    cfg: ModelConfig
    params: Any
    plan: MeshPlan
    devices: Optional[List] = None          # flat device list to index into
    stage_times: List[float] = field(default_factory=list)

    def __post_init__(self):
        self.devices = self.devices or jax.devices()
        # map plan stages -> contiguous layer ranges of the scan stack
        order: List[str] = []
        for st in self.plan.stages:
            order.extend(st.layers)
        self._ranges: List[Tuple[int, int]] = []
        count = 0
        for st in self.plan.stages:
            # layers per block: count actual transformer blocks in this stage
            n_blocks = sum(1 for name in st.layers if name.endswith("_add2")
                           or name.endswith("_add") and "_add1" not in name)
            n_blocks = max(1, n_blocks)
            # clamp BOTH ends: once earlier stages have consumed all layers,
            # count may exceed n_layers and an unclamped lo would invert the
            # slice (jnp.arange(hi - lo) with hi < lo)
            lo = min(count, self.cfg.n_layers)
            hi = min(count + n_blocks, self.cfg.n_layers)
            self._ranges.append((lo, hi))
            count += n_blocks
        # stretch the last stage to cover any remainder
        if self._ranges:
            lo, _ = self._ranges[-1]
            self._ranges[-1] = (lo, self.cfg.n_layers)
        self._stage_fns = [self._make_stage_fn(i)
                           for i in range(len(self.plan.stages))]

    def _stage_device(self, si: int):
        # plan core ids are flat device indices; a pool smaller than the
        # plan FOLDS (modulo) so the demonstration executor still runs on
        # a 1-device host — the realization subsystem is the strict path
        # (realize.plan.validate_plan refuses plans the pool cannot host)
        devs = self.plan.stages[si].devices
        return self.devices[devs[0] % len(self.devices)]

    def _make_stage_fn(self, si: int):
        lo, hi = self._ranges[si]
        cfg = self.cfg
        rules = default_rules()
        from ..models.lm import _dtype
        cdt = _dtype(cfg.compute_dtype)

        def stage(blocks, h):
            sl = jax.tree.map(lambda t: t[lo:hi], blocks)
            ids = jnp.arange(hi - lo)
            positions = jnp.arange(h.shape[1])[None, :]

            def body(carry, xs):
                li, bp = xs
                from ..nn.attention import attention_block
                from ..models.lm import apply_mlp, _norm_apply
                y, _ = attention_block(
                    bp["attn"], _norm_apply(cfg, bp["norm1"], carry),
                    n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
                    positions=positions, rope_theta=cfg.rope_theta,
                    compute_dtype=cdt)
                carry = carry + y
                carry = carry + apply_mlp(
                    cfg, bp["mlp"], _norm_apply(cfg, bp["norm2"], carry),
                    cdt)
                return carry, None

            h, _ = jax.lax.scan(body, h, (ids, sl))
            return h

        return jax.jit(stage, device=self._stage_device(si))

    def forward(self, tokens: jax.Array, n_micro: int = 1) -> jax.Array:
        """Pipelined forward -> logits.  tokens: (B, S)."""
        cfg = self.cfg
        from ..models.lm import _dtype
        cdt = _dtype(cfg.compute_dtype)
        h = embed(self.params["embed"], tokens, cdt)
        micro = jnp.split(h, n_micro, axis=0)
        outs = []
        self.stage_times = [0.0] * len(self._stage_fns)
        for mb in micro:
            x = mb
            for si, fn in enumerate(self._stage_fns):
                x = jax.device_put(x, self._stage_device(si))
                t0 = time.time()
                x = fn(self.params["blocks"], x)
                x.block_until_ready()
                self.stage_times[si] += time.time() - t0
            outs.append(x)
        h = jnp.concatenate(outs, axis=0)
        from ..models.lm import _norm_apply
        h = _norm_apply(cfg, self.params["final_norm"], h)
        if cfg.tie_embeddings:
            return unembed(self.params["embed"], h, cdt)
        from ..nn.layers import linear
        return linear(self.params["lm_head"], h, cdt).astype(jnp.float32)
