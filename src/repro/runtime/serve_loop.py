"""Batched serving loop: wave-style continuous batching.

Requests queue up; the server packs up to ``max_batch`` of them into a wave,
left-pads to a common length, prefills once, then decodes until every slot
hits EOS or its token budget.  Finished slots are masked out (their tokens
ignored) so stragglers don't produce garbage.  This is the paper-agnostic
serving substrate the Gemini-mapped pipeline executor (runtime.pipeline)
plugs into.

The transport-agnostic pieces are :class:`RequestQueue` (admission, FIFO,
enqueue timestamps) and :class:`ModelWaveExecutor` (the JAX model behind
the structural :class:`repro.serve.harness.WaveExecutor` protocol — it
reports a measured :class:`~repro.serve.harness.WaveCost` per wave, so the
traffic-replay harness can drive the real model path).  :class:`Server`
is the thin compat shim over both that `examples/serve_lm.py` uses.

Timing contract: ``Result.latency_s`` is the **per-request** queueing +
service time ``finish_t - enqueue_t``.  Slots in the same wave finish at
different decode steps, so latencies differ across a mixed-length wave —
the earlier API reported the shared wave duration for every request,
which silently corrupted every percentile downstream.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import model_api
from ..nn.params import default_rules
from ..serve.harness import WaveCost

# Decode-phase KV-cache length cap.  Prefill caches still size to
# ``max_seq``; the decode cache is capped so tiny serving configs don't
# allocate paper-scale caches (override via ``cache_len=``).
DEFAULT_DECODE_CACHE_LEN = 1500


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new: int = 32
    enqueue_t: float = 0.0        # stamped by RequestQueue.submit if unset


@dataclass
class Result:
    rid: int
    tokens: np.ndarray
    latency_s: float              # finish_t - enqueue_t, per request
    enqueue_t: float = 0.0
    start_t: float = 0.0          # wave admission (prefill launch)
    finish_t: float = 0.0         # this slot's last token, not wave end


class RequestQueue:
    """Transport-agnostic FIFO admission queue.

    Stamps ``enqueue_t`` at submit time (wall clock) unless the request
    already carries one (trace replay pre-stamps virtual arrival times).
    """

    def __init__(self) -> None:
        self._q: List[Request] = []

    def submit(self, req: Request) -> None:
        if req.enqueue_t == 0.0:
            req.enqueue_t = time.time()
        self._q.append(req)

    def next_wave(self, max_batch: int) -> List[Request]:
        wave, self._q = self._q[:max_batch], self._q[max_batch:]
        return wave

    def __len__(self) -> int:
        return len(self._q)

    @property
    def pending(self) -> Sequence[Request]:
        return tuple(self._q)


class ModelWaveExecutor:
    """Real-model serving backend: one jitted prefill + decode loop.

    Satisfies the ``repro.serve.harness.WaveExecutor`` protocol:
    ``execute(wave)`` accepts trace requests (prompt tokens synthesized
    deterministically from the rid, or supplied via ``prompt_fn``) and
    returns a measured :class:`WaveCost` — wall-clock prefill and
    per-decode-step durations with per-slot token counts — which is what
    lets the harness attribute distinct finish times to slots that stop
    at different steps.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_seq: int = 512, eos_id: int = 0, rules=None,
                 cache_len: Optional[int] = None,
                 prompt_fn: Optional[Callable[[object], np.ndarray]] = None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.cache_len = min(max_seq, cache_len or DEFAULT_DECODE_CACHE_LEN)
        self.rules = rules or default_rules()
        self.prompt_fn = prompt_fn
        self.api = model_api(cfg)
        self._decode = jax.jit(
            lambda p, t, c: self.api.decode_step(p, t, c, self.rules))
        self._prefill = jax.jit(
            lambda p, b, c: self.api.prefill(p, b, c, self.rules))

    # -- prompt materialization --------------------------------------
    def _prompt_of(self, req) -> np.ndarray:
        if getattr(req, "prompt", None) is not None:
            return np.asarray(req.prompt, np.int32)
        if self.prompt_fn is not None:
            return np.asarray(self.prompt_fn(req), np.int32)
        # Deterministic synthetic prompt from the rid (trace replay).
        rng = np.random.Generator(np.random.Philox(
            np.random.SeedSequence([0x544F4B53, int(req.rid)])))
        n = max(1, int(getattr(req, "prompt_len", 1)))
        vocab = int(self.cfg.vocab)
        return rng.integers(1, max(2, vocab), size=n, dtype=np.int64) \
                  .astype(np.int32)

    def _pad_wave(self, prompts: List[np.ndarray]) -> np.ndarray:
        L = max(len(p) for p in prompts)
        toks = np.full((len(prompts), L), self.eos_id, np.int32)
        for i, p in enumerate(prompts):
            toks[i, L - len(p):] = p                   # left-pad
        return toks

    # -- core wave execution -----------------------------------------
    def run_wave(self, wave: Sequence[object]
                 ) -> Tuple[np.ndarray, np.ndarray, WaveCost]:
        """Execute one wave; returns (out_tokens, n_tokens, cost).

        ``out_tokens`` is (B, max_budget) with finished slots masked
        (budget-exceeding steps are never written — the old loop wrote
        token ``t`` before applying the budget mask, so smaller-budget
        slots leaked one token past their budget and burned a decode
        step a single-request ``max_new=1`` wave never needed).
        """
        prompts = [self._prompt_of(r) for r in wave]
        budgets = np.array([int(r.max_new) for r in wave], np.int32)
        toks = self._pad_wave(prompts)
        B, L = toks.shape
        t0 = time.time()
        cache, _ = self.api.init_cache(B, self.max_seq, self.cache_len)
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend in ("patch", "audio"):
            batch["embeds"] = jnp.zeros((B, L, self.cfg.d_model),
                                        jnp.bfloat16)
        logits, cache = self._prefill(self.params, batch, cache)
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        cur.block_until_ready()
        prefill_s = time.time() - t0
        max_new = int(budgets.max())
        out = np.full((B, max_new), self.eos_id, np.int32)
        done = np.zeros((B,), bool)
        ntok = np.zeros((B,), np.int32)
        step_s: List[float] = []
        for t in range(max_new):
            tok = np.asarray(cur[:, 0])
            live = ~done
            out[live, t] = tok[live]
            ntok[live] += 1
            done |= tok == self.eos_id
            done |= (t + 1) >= budgets
            if done.all():
                break
            ts = time.time()
            logits, cache = self._decode(self.params, cur, cache)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            cur.block_until_ready()
            step_s.append(time.time() - ts)
        cost = WaveCost(prefill_s=prefill_s, step_s=step_s,
                        slot_tokens=[int(n) for n in ntok],
                        tokens=[out[i, :ntok[i]] for i in range(B)])
        return out, ntok, cost

    def execute(self, wave: Sequence[object]) -> WaveCost:
        """WaveExecutor protocol entry point (harness replay)."""
        _, _, cost = self.run_wave(wave)
        return cost


class Server:
    """Compat shim: RequestQueue + ModelWaveExecutor behind the old API."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_seq: int = 512, eos_id: int = 0, rules=None,
                 greedy: bool = True, cache_len: Optional[int] = None):
        del greedy                       # argmax decode is the only policy
        self.executor = ModelWaveExecutor(
            cfg, params, max_batch=max_batch, max_seq=max_seq,
            eos_id=eos_id, rules=rules, cache_len=cache_len)
        self.queue = RequestQueue()

    # Old surface, delegated.
    cfg = property(lambda self: self.executor.cfg)
    params = property(lambda self: self.executor.params)
    max_batch = property(lambda self: self.executor.max_batch)
    max_seq = property(lambda self: self.executor.max_seq)
    eos_id = property(lambda self: self.executor.eos_id)
    rules = property(lambda self: self.executor.rules)
    api = property(lambda self: self.executor.api)

    def submit(self, req: Request) -> None:
        self.queue.submit(req)

    def step(self) -> List[Result]:
        """Serve one wave; returns completed results (possibly empty)."""
        if not len(self.queue):
            return []
        wave = self.queue.next_wave(self.executor.max_batch)
        start_t = time.time()
        out, ntok, cost = self.executor.run_wave(wave)
        first = start_t + cost.prefill_s
        cum = np.concatenate([[0.0], np.cumsum(cost.step_s)])
        results = []
        for i, r in enumerate(wave):
            seq = out[i, :ntok[i]]
            stop = np.nonzero(seq == self.eos_id)[0]
            if len(stop):
                seq = seq[:stop[0] + 1]
            fin = first + float(cum[min(ntok[i] - 1, len(cost.step_s))])
            results.append(Result(
                rid=r.rid, tokens=seq, latency_s=fin - r.enqueue_t,
                enqueue_t=r.enqueue_t, start_t=start_t, finish_t=fin))
        return results

    def run_until_empty(self) -> List[Result]:
        results = []
        while len(self.queue):
            results.extend(self.step())
        return results
