"""Batched serving loop: wave-style continuous batching.

Requests queue up; the server packs up to ``max_batch`` of them into a wave,
left-pads to a common length, prefIlls once, then decodes until every slot
hits EOS or its token budget.  Finished slots are masked out (their tokens
ignored) so stragglers don't produce garbage.  This is the paper-agnostic
serving substrate the Gemini-mapped pipeline executor (runtime.pipeline)
plugs into.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import model_api
from ..nn.params import default_rules


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new: int = 32


@dataclass
class Result:
    rid: int
    tokens: np.ndarray
    latency_s: float


class Server:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_seq: int = 512, eos_id: int = 0, rules=None,
                 greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.rules = rules or default_rules()
        self.api = model_api(cfg)
        self._queue: List[Request] = []
        self._decode = jax.jit(
            lambda p, t, c: self.api.decode_step(p, t, c, self.rules))
        self._prefill = jax.jit(
            lambda p, b, c: self.api.prefill(p, b, c, self.rules))

    def submit(self, req: Request) -> None:
        self._queue.append(req)

    def _pad_wave(self, wave: List[Request]) -> np.ndarray:
        L = max(len(r.prompt) for r in wave)
        toks = np.full((len(wave), L), self.eos_id, np.int32)
        for i, r in enumerate(wave):
            toks[i, L - len(r.prompt):] = r.prompt     # left-pad
        return toks

    def step(self) -> List[Result]:
        """Serve one wave; returns completed results (possibly empty)."""
        if not self._queue:
            return []
        wave = self._queue[:self.max_batch]
        self._queue = self._queue[self.max_batch:]
        t0 = time.time()
        toks = self._pad_wave(wave)
        B, L = toks.shape
        cache, _ = self.api.init_cache(B, self.max_seq,
                                       min(self.max_seq, 1500))
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend in ("patch", "audio"):
            batch["embeds"] = jnp.zeros((B, L, self.cfg.d_model),
                                        jnp.bfloat16)
        logits, cache = self._prefill(self.params, batch, cache)
        max_new = max(r.max_new for r in wave)
        out = np.zeros((B, max_new), np.int32)
        done = np.zeros((B,), bool)
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        for t in range(max_new):
            out[:, t] = np.asarray(cur[:, 0])
            done |= out[:, t] == self.eos_id
            done |= np.array([t >= r.max_new for r in wave])
            if done.all():
                break
            logits, cache = self._decode(self.params, cur, cache)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        dt = time.time() - t0
        results = []
        for i, r in enumerate(wave):
            seq = out[i, :r.max_new]
            stop = np.nonzero(seq == self.eos_id)[0]
            if len(stop):
                seq = seq[:stop[0] + 1]
            results.append(Result(rid=r.rid, tokens=seq, latency_s=dt))
        return results

    def run_until_empty(self) -> List[Result]:
        results = []
        while self._queue:
            results.extend(self.step())
        return results
