"""Layer-centric LP spatial-mapping encoding (paper Sec. IV).

An ``LMS`` (LP spatial Mapping Scheme) of a layer group holds one ``MS`` per
layer: ``MS = (Part, CG, FD)``.

* ``Part = (ph, pw, pb, pk)`` — partition counts of the ofmap cube along
  H, W, B(atch-unit) and K.  Product == len(CG).
* ``CG`` — *ordered* tuple of core ids; cores may be anywhere on the grid
  (non-contiguous allowed).  CGs of different layers in one group are
  disjoint.
* ``FD = (IF, WGT, OF)`` — DRAM endpoints; -1 implicit/absent, 0 interleaved,
  d>0 a concrete DRAM port.

The Correspondence Rule maps the partitioned workload with 4-D id
``(h, w, b, k)`` to core ``CG[((h*pw + w)*pb + b)*pk + k]`` — row-major NID,
exactly the paper's ``h*W*B*K + w*B*K + b*K + k``.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from functools import lru_cache
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .workload import Graph, Layer, LayerGroup


Part = Tuple[int, int, int, int]          # (ph, pw, pb, pk)
FD = Tuple[int, int, int]                 # (IF, WGT, OF)


def split_points(dim: int, parts: int) -> np.ndarray:
    """Boundaries of an approximately-equal split (np.array_split semantics).

    Returns ``parts+1`` offsets; part i covers [off[i], off[i+1]).
    """
    if parts > dim:
        raise ValueError(f"cannot split dim {dim} into {parts} parts")
    base, extra = divmod(dim, parts)
    sizes = [base + (1 if i < extra else 0) for i in range(parts)]
    return np.concatenate([[0], np.cumsum(sizes)])


@dataclass(frozen=True)
class MS:
    """Mapping Scheme of one layer."""
    part: Part
    cg: Tuple[int, ...]
    fd: FD

    @property
    def nc(self) -> int:
        return len(self.cg)

    def __post_init__(self):
        ph, pw, pb, pk = self.part
        if ph * pw * pb * pk != len(self.cg):
            raise ValueError(
                f"Part {self.part} product {ph*pw*pb*pk} != |CG| {len(self.cg)}")
        if len(set(self.cg)) != len(self.cg):
            raise ValueError("CG has duplicate cores")
        if min(self.part) < 1:
            raise ValueError(f"Part must be >=1, got {self.part}")
        # MS is the key of every analyzer/evaluator memo table — hash once.
        # ``geo`` identifies everything except the DRAM endpoints: region
        # tables, NoC dependency traffic and intra-core dataflows are pure
        # functions of it, so FD-only changes (SA OP5) stay cache hits.
        object.__setattr__(self, "_hash",
                           hash((self.part, self.cg, self.fd)))
        object.__setattr__(self, "geo", (self.part, self.cg))

    def __hash__(self) -> int:
        return self._hash

    def part_index(self, h: int, w: int, b: int, k: int) -> int:
        ph, pw, pb, pk = self.part
        return ((h * pw + w) * pb + b) * pk + k

    def core_of(self, h: int, w: int, b: int, k: int) -> int:
        return self.cg[self.part_index(h, w, b, k)]


@dataclass(frozen=True)
class LMS:
    """LP Spatial Mapping Scheme of one layer group."""
    ms: Dict[str, MS]

    def cores_used(self) -> Tuple[int, ...]:
        out: List[int] = []
        for m in self.ms.values():
            out.extend(m.cg)
        return tuple(out)

    def cache_key(self) -> Tuple:
        """Stable hashable identity (the ``ms`` dict itself is unhashable).

        Sorted by layer name so two LMS with the same per-layer MS but
        different dict insertion order share one key.  Memoized: the
        evaluator keys every (cached) evaluation on it, and an LMS is
        frozen, so the key can never change after construction."""
        try:
            return self._cache_key
        except AttributeError:
            key = tuple(sorted((n, m.part, m.cg, m.fd)
                               for n, m in self.ms.items()))
            object.__setattr__(self, "_cache_key", key)
            return key

    def validate(self, group: LayerGroup, g: Graph, n_cores: int,
                 n_dram: int) -> None:
        if set(self.ms) != set(group.names):
            raise ValueError("LMS layers != layer-group layers")
        seen: set = set()
        for name in group.names:
            m = self.ms[name]
            lyr = g.layers[name]
            ph, pw, pb, pk = m.part
            if ph > lyr.H or pw > lyr.W or pb > group.batch_unit or pk > lyr.K:
                raise ValueError(
                    f"{name}: Part {m.part} exceeds dims "
                    f"(H={lyr.H},W={lyr.W},B={group.batch_unit},K={lyr.K})")
            for c in m.cg:
                if not (0 <= c < n_cores):
                    raise ValueError(f"{name}: core {c} out of range")
                if c in seen:
                    raise ValueError(f"{name}: core {c} used by two layers")
                seen.add(c)
            for v in m.fd:
                if not (-1 <= v <= n_dram):
                    raise ValueError(f"{name}: FD value {v} out of range")
            # FD structural rules (paper Sec. IV-A)
            if lyr.has_weight and m.fd[1] < 0:
                raise ValueError(f"{name}: weighted layer needs WGT >= 0")
            if not lyr.has_weight and m.fd[1] >= 0:
                raise ValueError(f"{name}: weightless layer must have WGT=-1")


# ---------------------------------------------------------------------------
# Packed structure-of-arrays LMS batches (batched evaluation engine)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LMSBatch:
    """B mappings of ONE layer group, packed as padded int arrays.

    Structure-of-arrays transport format of the batched evaluation engine:
    every per-layer field of every mapping lives in one int64 array with a
    leading batch axis, so a whole batch ships as five ndarrays instead of
    B dicts of frozen dataclasses.  Layer order is fixed (``names``); CG
    rows are right-padded with -1 to the batch-wide maximum (mappings of
    one group may give a layer different core counts — "ragged" batches).

    ``pack_lms_batch`` / ``unpack_lms_batch`` round-trip exactly;
    unpacking rebuilds real ``MS`` values, so ``MS.__post_init__``
    re-validates every row (Part product == |CG|, no duplicate cores) —
    a corrupted batch raises instead of analyzing garbage.
    """
    names: Tuple[str, ...]        # layer order of the rows below
    part: np.ndarray              # (B, L, 4) int64
    cg: np.ndarray                # (B, L, Cmax) int64, -1 padded
    cg_len: np.ndarray            # (B, L) int64 — valid prefix of each CG row
    fd: np.ndarray                # (B, L, 3) int64

    @property
    def batch_size(self) -> int:
        return int(self.part.shape[0])

    @property
    def n_layers(self) -> int:
        return len(self.names)

    def routing_tables(self) -> "RoutingTables":
        """Padded per-layer routing tables of this batch (memoized).

        Rectangularizes the ragged CG geometry so batched construction can
        gather core bindings without per-row Python: every table is a dense
        int/bool array over ``(B, L, Cmax)`` whose pad cells are routed to a
        *safe* real value (slot 0 / the row's last real core) and flagged
        off in ``slot_mask`` — the same trick the analyzer's packed
        multicast bitsets use (inactive members redirect to the empty
        ``(p, p)`` diagonal).  Consumers mask or slice by ``cg_len``;
        gathering through a pad cell is always in-bounds and never
        contributes.
        """
        try:
            return self._routes                      # type: ignore[attr-defined]
        except AttributeError:
            pass
        cg, cg_len = self.cg, self.cg_len
        B, L, cmax = cg.shape
        slot_mask = cg >= 0                          # (B, L, Cmax)
        cg_safe = np.where(slot_mask, cg, 0)
        # stable argsort over (real cores ascending, pads last): CG rows
        # hold distinct core ids, so this equals the analyzer's
        # np.argsort(cores) permutation on the valid prefix
        key = np.where(slot_mask, cg, np.iinfo(np.int64).max)
        order = np.argsort(key, axis=2, kind="stable")
        cg_sorted = np.take_along_axis(cg, order, axis=2)
        # pad slots -> the row's LAST real core (every row has >= 1 core:
        # Part products are >= 1), so sorted-order gathers stay in-bounds
        last = np.take_along_axis(
            cg_sorted, np.maximum(cg_len - 1, 0)[..., None], axis=2)
        cg_sorted = np.where(np.take_along_axis(slot_mask, order, axis=2),
                             cg_sorted, last)
        rt = RoutingTables(slot_mask=slot_mask, cg_safe=cg_safe,
                           order=order, cg_sorted=cg_sorted)
        object.__setattr__(self, "_routes", rt)
        return rt


@dataclass(frozen=True)
class RoutingTables:
    """Rectangular core-binding tables of one :class:`LMSBatch`.

    All arrays are ``(B, L, Cmax)``; see :meth:`LMSBatch.routing_tables`
    for the padding contract.  ``order`` maps correspondence order to
    sorted-core order per (mapping, layer) row — pad slots sort last, real
    slots reproduce ``np.argsort`` of the valid CG prefix exactly (core
    ids within a row are distinct, so the permutation is unique).
    """
    slot_mask: np.ndarray         # bool — True where the CG slot is real
    cg_safe: np.ndarray           # int64 — CG with pads replaced by 0
    order: np.ndarray             # int64 — correspondence -> sorted perm
    cg_sorted: np.ndarray         # int64 — cores ascending, pads = last core


def pack_lms_batch(lms_list: Sequence[LMS],
                   names: Optional[Sequence[str]] = None) -> LMSBatch:
    """Pack B same-group mappings into one :class:`LMSBatch`.

    ``names`` fixes the layer axis order (defaults to the first mapping's
    insertion order).  Every mapping must cover exactly that layer set.
    """
    if not lms_list:
        raise ValueError("cannot pack an empty LMS batch")
    if names is None:
        names = tuple(lms_list[0].ms)
    else:
        names = tuple(names)
    B, L = len(lms_list), len(names)
    for lms in lms_list:
        if set(lms.ms) != set(names):
            raise ValueError(
                f"LMS layers {sorted(lms.ms)} != batch layers {sorted(names)}")
    cmax = max(m.nc for lms in lms_list for m in lms.ms.values())
    part = np.empty((B, L, 4), dtype=np.int64)
    cg = np.full((B, L, cmax), -1, dtype=np.int64)
    cg_len = np.empty((B, L), dtype=np.int64)
    fd = np.empty((B, L, 3), dtype=np.int64)
    for b, lms in enumerate(lms_list):
        for l, name in enumerate(names):
            m = lms.ms[name]
            part[b, l] = m.part
            cg[b, l, :m.nc] = m.cg
            cg_len[b, l] = m.nc
            fd[b, l] = m.fd
    return LMSBatch(names=names, part=part, cg=cg, cg_len=cg_len, fd=fd)


def unpack_lms_batch(batch: LMSBatch) -> List[LMS]:
    """Rebuild the B ``LMS`` values of a packed batch (exact inverse of
    :func:`pack_lms_batch`; ``MS.__post_init__`` re-validates each row)."""
    out: List[LMS] = []
    part, cg, cg_len, fd = batch.part, batch.cg, batch.cg_len, batch.fd
    for b in range(batch.batch_size):
        ms: Dict[str, MS] = {}
        for l, name in enumerate(batch.names):
            n = int(cg_len[b, l])
            ms[name] = MS(part=tuple(int(v) for v in part[b, l]),
                          cg=tuple(int(v) for v in cg[b, l, :n]),
                          fd=tuple(int(v) for v in fd[b, l]))
        out.append(LMS(ms=ms))
    return out


# ---------------------------------------------------------------------------
# Region computation (parsing an MS into per-core ofmap regions)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Region:
    """Half-open ranges into the (H, W, B, K) ofmap cube of one layer part."""
    h0: int; h1: int
    w0: int; w1: int
    b0: int; b1: int
    k0: int; k1: int

    @property
    def elems(self) -> int:
        return ((self.h1 - self.h0) * (self.w1 - self.w0)
                * (self.b1 - self.b0) * (self.k1 - self.k0))

    def overlap(self, other: "Region") -> int:
        dh = min(self.h1, other.h1) - max(self.h0, other.h0)
        dw = min(self.w1, other.w1) - max(self.w0, other.w0)
        db = min(self.b1, other.b1) - max(self.b0, other.b0)
        dk = min(self.k1, other.k1) - max(self.k0, other.k0)
        if dh <= 0 or dw <= 0 or db <= 0 or dk <= 0:
            return 0
        return dh * dw * db * dk


@lru_cache(maxsize=65536)
def _split_cached(dim: int, parts: int) -> np.ndarray:
    return split_points(dim, parts)


def parse_regions_arrays(m: MS, layer: Layer,
                         batch_unit: int) -> Tuple[np.ndarray, np.ndarray]:
    """Correspondence Rule, vectorized: (cores (N,), regions (N,8)).

    Rows are [h0,h1,w0,w1,b0,b1,k0,k1] in *correspondence order* — the
    (h, w, b, k) C-order nesting of the Rule, under which row i belongs to
    core ``CG[i]`` — NOT sorted by core id."""
    ph, pw, pb, pk = m.part
    hs = _split_cached(layer.H, ph)
    ws = _split_cached(layer.W, pw)
    bs = _split_cached(batch_unit, pb)
    ks = _split_cached(layer.K, pk)
    ih, iw, ib, ik = np.indices((ph, pw, pb, pk)).reshape(4, -1)
    rarr = np.empty((len(ih), 8), dtype=np.int64)
    rarr[:, 0] = hs[ih]
    rarr[:, 1] = hs[ih + 1]
    rarr[:, 2] = ws[iw]
    rarr[:, 3] = ws[iw + 1]
    rarr[:, 4] = bs[ib]
    rarr[:, 5] = bs[ib + 1]
    rarr[:, 6] = ks[ik]
    rarr[:, 7] = ks[ik + 1]
    return np.asarray(m.cg, dtype=np.int64), rarr


def parse_regions(m: MS, layer: Layer, batch_unit: int) -> Dict[int, Region]:
    """Correspondence Rule: core id -> its ofmap Region (insertion order =
    correspondence order, which downstream accumulation relies on)."""
    cores, rarr = parse_regions_arrays(m, layer, batch_unit)
    return {c: Region(*row)
            for c, row in zip(cores.tolist(), rarr.tolist())}


def ifmap_region(layer: Layer, r: Region, in_K: int) -> Region:
    """Ifmap region a consumer part needs, in the *producer's ofmap* cube.

    conv/fc/matmul contract over all input channels: the K-range widens to
    the full producer K.  Spatial dims map through stride with an RxS halo.
    eltwise/pool/depthwise are channel-wise 1:1.
    """
    if layer.kind in ("eltwise",):
        return r
    if layer.kind in ("pool", "depthwise"):
        s = layer.stride
        return Region(r.h0 * s, min(r.h1 * s + layer.R - 1, layer.H * s),
                      r.w0 * s, min(r.w1 * s + layer.S - 1, layer.W * s),
                      r.b0, r.b1, r.k0, r.k1)
    # conv / fc / matmul: full channel contraction
    s = layer.stride
    h_in = layer.H * s
    w_in = layer.W * s
    return Region(min(r.h0 * s, h_in - 1), min(r.h1 * s + layer.R - 1, h_in),
                  min(r.w0 * s, w_in - 1), min(r.w1 * s + layer.S - 1, w_in),
                  r.b0, r.b1, 0, in_K)


# ---------------------------------------------------------------------------
# Generators: random LMS + valid Part enumeration
# ---------------------------------------------------------------------------

@lru_cache(maxsize=8192)
def _divisors_upto(n: int, cap: int) -> Tuple[int, ...]:
    return tuple(d for d in range(1, min(n, cap) + 1) if n % d == 0)


def factor_parts(n: int, dims: Tuple[int, int, int, int],
                 rng: np.random.Generator) -> Part:
    """Random 4-way factorization of ``n`` respecting per-dim caps."""
    for _ in range(64):
        rem = n
        out = []
        caps = list(dims)
        order = rng.permutation(4)
        ok = True
        for i, axis in enumerate(order):
            if i == 3:
                f = rem
            else:
                divs = _divisors_upto(rem, caps[axis])
                if not divs:
                    ok = False
                    break
                # index draw instead of rng.choice: choice() converts the
                # tuple to an ndarray on every call, which dominates the
                # proposal cost in tight SA loops
                f = divs[int(rng.integers(len(divs)))]
            if f > caps[axis]:
                ok = False
                break
            p_tmp = [1, 1, 1, 1]
            out.append((axis, f))
            rem //= f
        if ok and rem == 1:
            part = [1, 1, 1, 1]
            for axis, f in out:
                part[axis] = f
            return tuple(part)  # type: ignore[return-value]
    # fall back: all on the largest dim that fits
    for axis in np.argsort(dims)[::-1]:
        if dims[axis] >= n:
            part = [1, 1, 1, 1]
            part[axis] = n
            return tuple(part)  # type: ignore[return-value]
    raise ValueError(f"cannot split {n} parts over dims {dims}")


def default_fd(layer: Layer, g: Graph, group: LayerGroup,
               n_dram: int, rng: Optional[np.random.Generator] = None) -> FD:
    """Structurally-valid FD: explicit endpoints where the paper requires."""
    in_group = set(group.names)
    preds = g.preds(layer.name)
    succs = g.succs(layer.name)
    pick = (lambda: int(rng.integers(0, n_dram + 1))) if rng is not None else (lambda: 0)
    if_ = -1
    if not preds or not any(p in in_group for p in preds):
        if_ = pick()            # DNN input or fed from a previous group
    wgt = pick() if layer.has_weight else -1
    of = -1
    if not succs or not all(s in in_group for s in succs):
        of = pick()             # DNN output or consumed by a later group
    return (if_, wgt, of)


def random_lms(group: LayerGroup, g: Graph, n_cores: int, n_dram: int,
               rng: np.random.Generator) -> LMS:
    """Uniform-ish random point of the optimization space (for tests/SA)."""
    n = len(group.names)
    if n_cores < n:
        raise ValueError("fewer cores than layers")
    # random composition of cores over layers, each >= 1, total <= n_cores
    sizes = np.ones(n, dtype=int)
    budget = n_cores - n
    extra = rng.multinomial(budget, np.ones(n) / n) if budget else np.zeros(n, int)
    sizes = sizes + extra
    perm = rng.permutation(n_cores)
    ms: Dict[str, MS] = {}
    off = 0
    for name, nc in zip(group.names, sizes):
        lyr = g.layers[name]
        dims = (lyr.H, lyr.W, group.batch_unit, lyr.K)
        # shrink nc until it factorizes over the dims
        nc = int(nc)
        while nc > 1:
            try:
                part = factor_parts(nc, dims, rng)
                break
            except ValueError:
                nc -= 1
        else:
            part = (1, 1, 1, 1)
        cg = tuple(int(c) for c in perm[off:off + nc])
        off += nc
        ms[name] = MS(part=part, cg=cg, fd=default_fd(lyr, g, group, n_dram, rng))
    return LMS(ms=ms)


# ---------------------------------------------------------------------------
# Optimization-space size (paper Sec. IV-B)
# ---------------------------------------------------------------------------

def _binom(x: int, y: int) -> int:
    from math import comb
    if y < 0 or y > x:
        return 0
    return comb(x, y)


def space_size_lower_bound(n_layers: int, n_cores: int) -> int:
    """Paper's conservative lower bound: m! * sum_i C(N,i)*C(M-N-1,N-i-1)*4^(N-i)."""
    from math import factorial
    N, M = n_layers, n_cores
    total = 0
    for i in range(N):
        total += _binom(N, i) * _binom(M - N - 1, N - i - 1) * 4 ** (N - i)
    return factorial(M) * total


def tangram_space_upper_bound(n_layers: int, n_cores: int) -> int:
    """Tangram heuristic upper bound: N * part(M) (integer partitions)."""
    # partition function via Euler recurrence
    M = n_cores
    p = [1] + [0] * M
    for i in range(1, M + 1):
        for j in range(i, M + 1):
            p[j] += p[j - i]
    return n_layers * p[M]
