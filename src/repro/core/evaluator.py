"""Delay + energy evaluation of a mapped DNN (paper Sec. V-B2, SET-style).

A mapped DNN is a sequence of (LayerGroup, LMS).  Per group we take the
``GroupAnalysis`` traffic and compute

  delay  = stage_time * (n_passes + pipeline_depth - 1)
  stage_time = max( compute time on the busiest core,
                    busiest NoC link, busiest D2D link, busiest DRAM port )

(fine-grained pipelining over batch-unit passes, with fill/drain captured by
the depth term — the Tangram/SET model).  Energy sums MACs, GLB traffic
(from the intra-core exploration), NoC hop bytes, D2D crossing bytes and
DRAM bytes, each times its unit energy.  GLB overcommit is penalized softly
(spill traffic + delay multiplier) to keep the SA landscape smooth.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .analyzer import Analyzer, GroupAnalysis, router_grid
from .encoding import LMS
from .hw import ArchConfig
from .intra_core import explore_intra_core
from .workload import Graph, LayerGroup


@dataclass
class GroupEval:
    delay_s: float
    energy_j: float
    stage_time_s: float
    n_passes: int
    depth: int
    bottleneck: str
    glb_overflow_bytes: float
    energy_breakdown: Dict[str, float] = field(default_factory=dict)


@dataclass
class EvalResult:
    delay_s: float
    energy_j: float
    groups: List[GroupEval]
    analyses: List[GroupAnalysis]

    @property
    def edp(self) -> float:
        return self.delay_s * self.energy_j

    def cost(self, beta: float = 1.0, gamma: float = 1.0) -> float:
        return (self.energy_j ** beta) * (self.delay_s ** gamma)


def _pipeline_depth(g: Graph, group: LayerGroup) -> int:
    """Longest dependency chain within the group (fill/drain passes)."""
    names = set(group.names)
    depth: Dict[str, int] = {}
    for n in g.topo_order():
        if n not in names:
            continue
        preds = [p for p in g.preds(n) if p in names]
        depth[n] = 1 + max((depth[p] for p in preds), default=0)
    return max(depth.values(), default=1)


class Evaluator:
    """Per-(arch, graph) evaluator; reuses the Analyzer and its caches."""

    def __init__(self, arch: ArchConfig, g: Graph):
        self.arch = arch
        self.g = g
        self.analyzer = Analyzer(arch, g)
        self.grid = router_grid(arch)

    # ------------------------------------------------------------------
    def eval_group(self, group: LayerGroup, lms: LMS,
                   total_batch: int) -> Tuple[GroupEval, GroupAnalysis]:
        arch, g, tech = self.arch, self.g, self.arch.tech
        an = self.analyzer.analyze(group, lms, total_batch)
        bu = group.batch_unit
        n_passes = max(1, -(-total_batch // bu))
        depth = _pipeline_depth(g, group)

        # -- per-core compute time (uses intra-core utilization) -----------
        core_time = np.zeros(arch.n_cores)
        glb_rd = 0.0
        glb_wr = 0.0
        for name, regs in an.layer_parts.items():
            lyr = g.layers[name]
            mac_per_elem = lyr.macs(1) / max(1, lyr.ofmap_elems)
            for core, r in regs.items():
                rk = r.k1 - r.k0
                hwb = max(1, r.elems // max(1, rk))
                df = explore_intra_core(rk, lyr.C, hwb, lyr.R, lyr.S,
                                        lyr.bytes_per_elem, arch.core_glb_bytes,
                                        arch.macs_per_core, lyr.kind)
                macs = r.elems * mac_per_elem
                peak = arch.macs_per_core * arch.freq_ghz * 1e9
                core_time[core] += macs / (peak * max(df.utilization, 1e-3))
                glb_rd += df.glb_read_bytes
                glb_wr += df.glb_write_bytes

        # -- resource times per pass ---------------------------------------
        edge_tot = an.edge_bytes + an.edge_bytes_amortized
        is_d2d = self.grid.edge_is_d2d
        t_noc = float((edge_tot[~is_d2d] / (arch.noc_bw * 1e9)).max(initial=0.0))
        t_d2d = float((edge_tot[is_d2d] / (arch.d2d_bw * 1e9)).max(initial=0.0)) \
            if is_d2d.any() else 0.0
        dram_port_bw = arch.dram_bw / arch.n_dram * 1e9
        t_dram = float(((an.dram_bytes + an.dram_bytes_amortized)
                        / dram_port_bw).max(initial=0.0))
        t_comp = float(core_time.max(initial=0.0))
        stage = max(t_comp, t_noc, t_d2d, t_dram, 1e-12)
        bottleneck = ["compute", "noc", "d2d", "dram"][
            int(np.argmax([t_comp, t_noc, t_d2d, t_dram]))]

        # -- GLB overcommit: soft penalty -----------------------------------
        over = np.maximum(an.core_glb_need - arch.core_glb_bytes, 0.0)
        overflow = float(over.sum())
        spill_dram = overflow * 2.0          # write + re-read per pass
        stage *= 1.0 + overflow / (arch.core_glb_bytes * arch.n_cores)
        t_dram_spill = spill_dram / (arch.dram_bw * 1e9)
        stage += t_dram_spill

        delay = stage * (n_passes + depth - 1)

        # -- energy over the whole batch -------------------------------------
        noc_bytes = float(edge_tot[~is_d2d].sum()) * n_passes
        d2d_bytes = float(edge_tot[is_d2d].sum()) * n_passes
        dram_b = float(an.dram_bytes.sum()) * n_passes \
            + an.weight_dram_bytes_total + spill_dram * n_passes
        macs_total = float(an.core_macs.sum()) * n_passes
        e = {
            "mac": macs_total * tech.e_mac,
            "glb": (glb_rd + glb_wr + float(an.core_in_bytes.sum())) * n_passes
                   * tech.e_glb_byte,
            "noc": (noc_bytes + d2d_bytes) * tech.e_noc_hop_byte,
            "d2d": d2d_bytes * tech.e_d2d_byte,
            "dram": dram_b * tech.e_dram_byte,
        }
        ge = GroupEval(delay_s=delay, energy_j=sum(e.values()),
                       stage_time_s=stage, n_passes=n_passes, depth=depth,
                       bottleneck=bottleneck, glb_overflow_bytes=overflow,
                       energy_breakdown=e)
        return ge, an

    # ------------------------------------------------------------------
    def evaluate(self, mapping: Sequence[Tuple[LayerGroup, LMS]],
                 total_batch: int) -> EvalResult:
        groups: List[GroupEval] = []
        analyses: List[GroupAnalysis] = []
        for group, lms in mapping:
            ge, an = self.eval_group(group, lms, total_batch)
            groups.append(ge)
            analyses.append(an)
        return EvalResult(
            delay_s=sum(ge.delay_s for ge in groups),
            energy_j=sum(ge.energy_j for ge in groups),
            groups=groups, analyses=analyses)
