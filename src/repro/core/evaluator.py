"""Delay + energy evaluation of a mapped DNN (paper Sec. V-B2, SET-style).

A mapped DNN is a sequence of (LayerGroup, LMS).  Per group we take the
``GroupAnalysis`` traffic and compute

  delay  = stage_time * (n_passes + pipeline_depth - 1)
  stage_time = max( compute time on the busiest core,
                    busiest NoC link, busiest D2D link, busiest DRAM port )

(fine-grained pipelining over batch-unit passes, with fill/drain captured by
the depth term — the Tangram/SET model).  Energy sums MACs, GLB traffic
(from the intra-core exploration), NoC hop bytes, D2D crossing bytes and
DRAM bytes, each times its unit energy.  GLB overcommit is penalized softly
(spill traffic + delay multiplier) to keep the SA landscape smooth.

Hot path: every per-core intra-core signature is collected per layer and
resolved through the batch API (``explore_intra_core_many``, deduped +
memoized) inside the analyzer's cached contribution streams; core time and
GLB traffic arrive as ``np.add.at`` scatter-add replays — no Python triple
loops.  ``CachedEvaluator`` adds a
content-addressed ``GroupEval`` cache keyed on (group id, LMS key, batch):
SA operators produce *new* LMS values, so cached entries never go stale and
OP1-OP5 only ever pay for the group they touched (see DESIGN.md).
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import metrics as _obs_metrics
from .analyzer import (T_CORE_IN, T_CORE_MACS, T_CORE_TIME, T_DRAM,
                       T_DRAM_AM, T_EDGE, T_EDGE_AM, T_GLB, T_GLB_RW,
                       Analyzer, GroupAnalysis, router_grid)
from .encoding import LMS
from .hw import ArchConfig
from .workload import Graph, LayerGroup


def analysis_signature(arch: ArchConfig) -> Tuple:
    """The ArchConfig fields the traffic/compute ANALYSIS depends on.

    Everything except the three bandwidths (``noc_bw``, ``d2d_bw``,
    ``dram_bw``), which enter only the delay math of ``eval_group``.
    Candidates sharing a signature share ``partition_graph``,
    ``tangram_map`` and every ``GroupAnalysis`` bit-for-bit — the
    batched T-Map screening path exploits exactly this.
    """
    return (arch.x_cores, arch.y_cores, arch.xcut, arch.ycut, arch.glb_kb,
            arch.macs_per_core, arch.freq_ghz, arch.n_dram, arch.tech)


# Process-wide cache economics, summed over every CachedEvaluator this
# process ever built (the per-instance hits/misses reset with each
# candidate's evaluator; sweep-level rates need the union).  Plain-dict
# increments on the hit path cost nanoseconds against a cache lookup and
# keep the counters alive when instances are GC'd; the obs layer harvests
# them through a collector, so REPRO_OBS never touches this path.
CACHE_STATS: Dict[str, int] = {
    "group_eval.hits": 0, "group_eval.misses": 0, "group_eval.evictions": 0,
    "group_eval_fused.hits": 0, "group_eval_fused.misses": 0,
    "group_eval_fused.evictions": 0,
}
_obs_metrics.register_collector(lambda: dict(CACHE_STATS))


@dataclass
class GroupEval:
    delay_s: float
    energy_j: float
    stage_time_s: float
    n_passes: int
    depth: int
    bottleneck: str
    glb_overflow_bytes: float
    energy_breakdown: Dict[str, float] = field(default_factory=dict)


@dataclass
class EvalResult:
    delay_s: float
    energy_j: float
    groups: List[GroupEval]
    analyses: List[GroupAnalysis]

    @property
    def edp(self) -> float:
        return self.delay_s * self.energy_j

    def cost(self, beta: float = 1.0, gamma: float = 1.0) -> float:
        return (self.energy_j ** beta) * (self.delay_s ** gamma)


def _build_fused_fn(layout: Sequence[Tuple[int, int]], buf_len: int,
                    noc_mask: np.ndarray, d2d_mask: np.ndarray,
                    has_d2d: bool, arch: ArchConfig):
    """Compile the fused construct->replay->eval pass for one evaluator.

    Returns a jitted function ``(B, idx, vals, n_passes, depth,
    weight_totals) -> (delay, energy, stage, overflow, bottleneck_idx,
    energy_parts)`` where ``idx``/``vals`` are the batch's concatenated
    int32/float32 contribution streams (pad entries aimed at the
    ``B * buf_len`` dump cell).  The segment-sum replay and the whole
    delay/energy pipeline run inside ONE jit, so an accelerator sees a
    single fused kernel instead of a bincount plus a dozen NumPy ops.

    Float32 + unordered segment reduction make this parity-grade
    (~1e-4 relative), never bit-identical — the exact NumPy engine stays
    the default and re-scores every winner (DESIGN.md).
    """
    from functools import partial

    import jax
    import jax.numpy as jnp

    tech = arch.tech
    noc_m = jnp.asarray(noc_mask, dtype=jnp.float32)
    d2d_m = jnp.asarray(d2d_mask, dtype=jnp.float32)
    noc_bw = arch.noc_bw * 1e9
    d2d_bw = arch.d2d_bw * 1e9
    dram_bw = arch.dram_bw * 1e9
    dram_port_bw = arch.dram_bw / arch.n_dram * 1e9
    glb_cap = float(arch.core_glb_bytes)
    n_cores = arch.n_cores
    spans = tuple((int(lo), int(hi)) for lo, hi in layout)

    @partial(jax.jit, static_argnums=(0,))
    def fused(B, idx, vals, n_passes, depth, weight_totals):
        buf = jax.ops.segment_sum(vals, idx, num_segments=B * buf_len + 1)
        buf = buf[:-1].reshape(B, buf_len)

        def tgt(t):
            lo, hi = spans[t]
            return buf[:, lo:hi]

        core_time = tgt(T_CORE_TIME)
        glb_rw = tgt(T_GLB_RW)
        edge_tot = tgt(T_EDGE) + tgt(T_EDGE_AM)
        edge_noc = edge_tot * noc_m
        edge_d2d = edge_tot * d2d_m
        t_noc = edge_noc.max(axis=1, initial=0.0) / noc_bw
        if has_d2d:
            t_d2d = edge_d2d.max(axis=1, initial=0.0) / d2d_bw
        else:
            t_d2d = jnp.zeros_like(t_noc)
        dram_tot = tgt(T_DRAM) + tgt(T_DRAM_AM)
        t_dram = dram_tot.max(axis=1, initial=0.0) / dram_port_bw
        t_comp = core_time.max(axis=1, initial=0.0)
        times = jnp.stack([t_comp, t_noc, t_d2d, t_dram])
        stage = jnp.maximum(times.max(axis=0), 1e-12)
        b_idx = jnp.argmax(times, axis=0)

        over = jnp.maximum(tgt(T_GLB) - glb_cap, 0.0)
        overflow = over.sum(axis=1)
        spill = overflow * 2.0
        stage = stage * (1.0 + overflow / (glb_cap * n_cores))
        stage = stage + spill / dram_bw
        np_f = n_passes.astype(jnp.float32)
        delay = stage * (np_f + depth.astype(jnp.float32) - 1.0)

        noc_bytes = edge_noc.sum(axis=1) * np_f
        d2d_bytes = edge_d2d.sum(axis=1) * np_f
        dram_b = tgt(T_DRAM).sum(axis=1) * np_f + weight_totals \
            + spill * np_f
        macs = tgt(T_CORE_MACS).sum(axis=1) * np_f
        e_mac = macs * tech.e_mac
        e_glb = (glb_rw[:, 0] + glb_rw[:, 1] + tgt(T_CORE_IN).sum(axis=1)) \
            * np_f * tech.e_glb_byte
        e_noc = (noc_bytes + d2d_bytes) * tech.e_noc_hop_byte
        e_d2d = d2d_bytes * tech.e_d2d_byte
        e_dram = dram_b * tech.e_dram_byte
        energy = e_mac + e_glb + e_noc + e_d2d + e_dram
        return (delay, energy, stage, overflow, b_idx,
                jnp.stack([e_mac, e_glb, e_noc, e_d2d, e_dram]))

    return fused


def _pipeline_depth(g: Graph, group: LayerGroup) -> int:
    """Longest dependency chain within the group (fill/drain passes)."""
    names = set(group.names)
    depth: Dict[str, int] = {}
    for n in g.topo_order():
        if n not in names:
            continue
        preds = [p for p in g.preds(n) if p in names]
        depth[n] = 1 + max((depth[p] for p in preds), default=0)
    return max(depth.values(), default=1)


class Evaluator:
    """Per-(arch, graph) evaluator; reuses the Analyzer and its caches."""

    def __init__(self, arch: ArchConfig, g: Graph):
        self.arch = arch
        self.g = g
        self.analyzer = Analyzer(arch, g)
        self.grid = router_grid(arch)
        self._is_d2d = self.grid.edge_is_d2d
        self._not_d2d = ~self._is_d2d
        self._has_d2d = bool(self._is_d2d.any())
        # integer column indices: fancy-indexing (B, ne) rows is cheaper
        # than boolean masks and selects the same elements in the same
        # (ascending-position) order
        self._noc_idx = np.flatnonzero(self._not_d2d)
        self._d2d_idx = np.flatnonzero(self._is_d2d)
        self._depth_cache: Dict[Tuple[str, ...], int] = {}
        self._fused_fn = None            # built on first backend="jax" use

    # ------------------------------------------------------------------
    def _group_depth(self, group: LayerGroup) -> int:
        d = self._depth_cache.get(group.names)
        if d is None:
            d = self._depth_cache[group.names] = _pipeline_depth(self.g, group)
        return d

    # ------------------------------------------------------------------
    def eval_group(self, group: LayerGroup, lms: LMS,
                   total_batch: int) -> Tuple[GroupEval, GroupAnalysis]:
        arch, g, tech = self.arch, self.g, self.arch.tech
        an = self.analyzer.analyze(group, lms, total_batch)
        bu = group.batch_unit
        n_passes = max(1, -(-total_batch // bu))
        depth = self._group_depth(group)

        # -- per-core compute time + GLB traffic (intra-core engine) -------
        # resolved inside the analyzer's cached contribution streams via
        # the batch dataflow API (explore_intra_core_many)
        core_time = an.core_time_s
        glb_rd = float(an.glb_rw_bytes[0])
        glb_wr = float(an.glb_rw_bytes[1])

        # -- resource times per pass ---------------------------------------
        edge_tot = an.edge_bytes + an.edge_bytes_amortized
        is_d2d, not_d2d = self._is_d2d, self._not_d2d
        t_noc = float((edge_tot[not_d2d] / (arch.noc_bw * 1e9)).max(initial=0.0))
        t_d2d = float((edge_tot[is_d2d] / (arch.d2d_bw * 1e9)).max(initial=0.0)) \
            if self._has_d2d else 0.0
        dram_port_bw = arch.dram_bw / arch.n_dram * 1e9
        t_dram = float(((an.dram_bytes + an.dram_bytes_amortized)
                        / dram_port_bw).max(initial=0.0))
        t_comp = float(core_time.max(initial=0.0))
        stage = max(t_comp, t_noc, t_d2d, t_dram, 1e-12)
        # first-maximum pick, same tie-break as np.argmax over the four times
        bi, bv = 0, t_comp
        for i, v in enumerate((t_noc, t_d2d, t_dram), start=1):
            if v > bv:
                bi, bv = i, v
        bottleneck = ("compute", "noc", "d2d", "dram")[bi]

        # -- GLB overcommit: soft penalty -----------------------------------
        over = np.maximum(an.core_glb_need - arch.core_glb_bytes, 0.0)
        overflow = float(over.sum())
        spill_dram = overflow * 2.0          # write + re-read per pass
        stage *= 1.0 + overflow / (arch.core_glb_bytes * arch.n_cores)
        t_dram_spill = spill_dram / (arch.dram_bw * 1e9)
        stage += t_dram_spill

        delay = stage * (n_passes + depth - 1)

        # -- energy over the whole batch -------------------------------------
        noc_bytes = float(edge_tot[not_d2d].sum()) * n_passes
        d2d_bytes = float(edge_tot[is_d2d].sum()) * n_passes
        dram_b = float(an.dram_bytes.sum()) * n_passes \
            + an.weight_dram_bytes_total + spill_dram * n_passes
        macs_total = float(an.core_macs.sum()) * n_passes
        e = {
            "mac": macs_total * tech.e_mac,
            "glb": (glb_rd + glb_wr + float(an.core_in_bytes.sum())) * n_passes
                   * tech.e_glb_byte,
            "noc": (noc_bytes + d2d_bytes) * tech.e_noc_hop_byte,
            "d2d": d2d_bytes * tech.e_d2d_byte,
            "dram": dram_b * tech.e_dram_byte,
        }
        ge = GroupEval(delay_s=delay, energy_j=sum(e.values()),
                       stage_time_s=stage, n_passes=n_passes, depth=depth,
                       bottleneck=bottleneck, glb_overflow_bytes=overflow,
                       energy_breakdown=e)
        return ge, an

    # ------------------------------------------------------------------
    def eval_requests_batch(self, requests: Sequence[Tuple[LayerGroup, LMS]],
                            total_batch: int, backend: str = "numpy"
                            ) -> List[Tuple[GroupEval, GroupAnalysis]]:
        """Evaluate a mixed batch of (group, lms) requests in ONE pass.

        With the default ``backend="numpy"``, row ``b`` is bit-identical
        to ``eval_group(*requests[b], total_batch)``: the batched analyzer
        replays every request's contribution stream in the scalar order
        (disjoint buffer rows, one ``np.bincount``), and the delay/energy
        math below mirrors the scalar path operation for operation along a
        leading batch axis — masked 2-D row reductions see the same
        elements in the same order as the scalar 1-D reductions, so
        pairwise summation blocks identically, and the per-row
        ``n_passes``/``depth`` constants enter elementwise exactly where
        the scalar ints did.

        ``backend="jax"`` instead runs the opt-in FUSED pass: batched
        construction feeds one jitted segment-sum replay + delay/energy
        kernel (float32, ~1e-4 parity envelope, analyses are ``None`` in
        the returned tuples).  Winners must be re-scored by the exact
        engine — see DESIGN.md's fused-pass contract.
        """
        if backend == "jax":
            return self._eval_requests_fused(requests, total_batch)
        if backend != "numpy":
            raise ValueError(f"unknown eval batch backend {backend!r}")
        arch, tech = self.arch, self.arch.tech
        ab = self.analyzer.analyze_requests(requests, total_batch)
        n_passes = np.array([max(1, -(-total_batch // grp.batch_unit))
                             for grp, _ in requests], dtype=np.int64)
        depth = np.array([self._group_depth(grp) for grp, _ in requests],
                         dtype=np.int64)

        core_time = ab.target(T_CORE_TIME)                   # (B, nc)
        glb_rw = ab.target(T_GLB_RW)                         # (B, 2)
        edge_tot = ab.target(T_EDGE) + ab.target(T_EDGE_AM)  # (B, ne)
        edge_noc = edge_tot[:, self._noc_idx]
        edge_d2d = edge_tot[:, self._d2d_idx]
        t_noc = (edge_noc / (arch.noc_bw * 1e9)).max(axis=1, initial=0.0)
        if self._has_d2d:
            t_d2d = (edge_d2d / (arch.d2d_bw * 1e9)).max(axis=1, initial=0.0)
        else:
            t_d2d = np.zeros(len(t_noc))
        dram_port_bw = arch.dram_bw / arch.n_dram * 1e9
        dram_tot = ab.target(T_DRAM) + ab.target(T_DRAM_AM)
        t_dram = (dram_tot / dram_port_bw).max(axis=1, initial=0.0)
        t_comp = core_time.max(axis=1, initial=0.0)
        times = np.stack([t_comp, t_noc, t_d2d, t_dram])     # (4, B)
        stage = np.maximum(times.max(axis=0), 1e-12)
        # np.argmax picks the FIRST of tied maxima — same tie-break as the
        # scalar path's strict-greater update loop
        b_idx = np.argmax(times, axis=0)

        over = np.maximum(ab.target(T_GLB) - arch.core_glb_bytes, 0.0)
        overflow = over.sum(axis=1)
        spill_dram = overflow * 2.0
        stage = stage * (1.0 + overflow / (arch.core_glb_bytes * arch.n_cores))
        stage = stage + spill_dram / (arch.dram_bw * 1e9)
        delay = stage * (n_passes + depth - 1)

        noc_bytes = edge_noc.sum(axis=1) * n_passes
        d2d_bytes = edge_d2d.sum(axis=1) * n_passes
        dram_b = ab.target(T_DRAM).sum(axis=1) * n_passes \
            + ab.weight_totals + spill_dram * n_passes
        macs_total = ab.target(T_CORE_MACS).sum(axis=1) * n_passes
        e_mac = macs_total * tech.e_mac
        e_glb = (glb_rw[:, 0] + glb_rw[:, 1]
                 + ab.target(T_CORE_IN).sum(axis=1)) * n_passes \
            * tech.e_glb_byte
        e_noc = (noc_bytes + d2d_bytes) * tech.e_noc_hop_byte
        e_d2d = d2d_bytes * tech.e_d2d_byte
        e_dram = dram_b * tech.e_dram_byte
        # same association order as the scalar path's sum(e.values())
        energy = ((((e_mac + e_glb) + e_noc) + e_d2d) + e_dram)

        names = ("compute", "noc", "d2d", "dram")
        out: List[Tuple[GroupEval, GroupAnalysis]] = []
        for b, an in enumerate(ab.analyses):
            ge = GroupEval(
                delay_s=float(delay[b]), energy_j=float(energy[b]),
                stage_time_s=float(stage[b]), n_passes=int(n_passes[b]),
                depth=int(depth[b]),
                bottleneck=names[int(b_idx[b])],
                glb_overflow_bytes=float(overflow[b]),
                energy_breakdown={
                    "mac": float(e_mac[b]), "glb": float(e_glb[b]),
                    "noc": float(e_noc[b]), "d2d": float(e_d2d[b]),
                    "dram": float(e_dram[b])})
            out.append((ge, an))
        return out

    def _eval_requests_fused(self, requests: Sequence[Tuple[LayerGroup, LMS]],
                             total_batch: int
                             ) -> List[Tuple[GroupEval, GroupAnalysis]]:
        """The fused construct->replay->eval pass (``backend="jax"``).

        Construction is the same batched engine the exact path uses
        (``_prefetch_contribs`` + cached ``row_stream`` downcasts); the
        replay and the entire delay/energy pipeline then run as ONE jitted
        kernel.  Streams are padded to power-of-two lengths (pad entries
        scatter into a dump cell past the last row) so jit retraces stay
        rare and shapes stabilize quickly under SA stepping.

        Returns ``(GroupEval, None)`` tuples: the fused path never
        materializes per-row :class:`GroupAnalysis` views.  Results carry
        a ~1e-4 relative envelope vs the exact engine (float32 math,
        unordered segment reduction) — winners must be re-scored exactly.
        """
        if not requests:
            return []
        an = self.analyzer
        an._prefetch_contribs(requests, total_batch)
        B = len(requests)
        buf_len = an._buf_len
        idx_parts: List[np.ndarray] = []
        val_parts: List[np.ndarray] = []
        wts = np.empty(B, dtype=np.float32)
        npass = np.empty(B, dtype=np.int32)
        dep = np.empty(B, dtype=np.int32)
        for b, (grp, lms) in enumerate(requests):
            i, v, wt = an.row_stream(grp, lms, total_batch)
            idx_parts.append(i + np.int32(b * buf_len) if b else i)
            val_parts.append(v)
            wts[b] = wt
            npass[b] = max(1, -(-total_batch // grp.batch_unit))
            dep[b] = self._group_depth(grp)
        idx = np.concatenate(idx_parts)
        vals = np.concatenate(val_parts)
        n = idx.size
        n_pad = 1 << max(4, (max(n, 1) - 1).bit_length())
        if n_pad != n:
            dump = np.int32(B * buf_len)
            idx = np.concatenate([idx, np.full(n_pad - n, dump, np.int32)])
            vals = np.concatenate([vals, np.zeros(n_pad - n, np.float32)])
        if self._fused_fn is None:
            self._fused_fn = _build_fused_fn(
                an._layout, buf_len, self._not_d2d, self._is_d2d,
                self._has_d2d, self.arch)
        delay, energy, stage, overflow, b_idx, eparts = \
            self._fused_fn(B, idx, vals, npass, dep, wts)
        delay = np.asarray(delay)
        energy = np.asarray(energy)
        stage = np.asarray(stage)
        overflow = np.asarray(overflow)
        b_idx = np.asarray(b_idx)
        eparts = np.asarray(eparts)
        names = ("compute", "noc", "d2d", "dram")
        ekeys = ("mac", "glb", "noc", "d2d", "dram")
        out: List[Tuple[GroupEval, GroupAnalysis]] = []
        for b in range(B):
            ge = GroupEval(
                delay_s=float(delay[b]), energy_j=float(energy[b]),
                stage_time_s=float(stage[b]), n_passes=int(npass[b]),
                depth=int(dep[b]), bottleneck=names[int(b_idx[b])],
                glb_overflow_bytes=float(overflow[b]),
                energy_breakdown={k: float(eparts[j, b])
                                  for j, k in enumerate(ekeys)})
            out.append((ge, None))
        return out

    def eval_group_batch(self, group: LayerGroup, lms_list: Sequence[LMS],
                         total_batch: int, backend: str = "numpy"
                         ) -> List[Tuple[GroupEval, GroupAnalysis]]:
        """Evaluate B mappings of ONE group in a single vectorized pass
        (:meth:`eval_requests_batch` with a constant group); row ``b`` is
        bit-identical to ``eval_group(group, lms_list[b], total_batch)``
        on the default backend."""
        return self.eval_requests_batch([(group, lms) for lms in lms_list],
                                        total_batch, backend=backend)

    # ------------------------------------------------------------------
    def eval_groups_batched(self, requests: Sequence[Tuple[LayerGroup, LMS]],
                            total_batch: int, backend: str = "numpy"
                            ) -> List[Tuple[GroupEval, GroupAnalysis]]:
        """Evaluate a mixed batch of (group, lms) requests.

        Requests are deduplicated and run through ONE
        :meth:`eval_requests_batch` pass (layer groups may mix — the
        accumulator layout is per-arch).  Results are returned in request
        order and are bit-identical to per-request :meth:`eval_group`
        calls on the default backend; ``backend="jax"`` routes through the
        fused parity-grade pass instead.
        """
        keyed = [(grp.names, grp.batch_unit, lms.cache_key())
                 for grp, lms in requests]
        distinct: "OrderedDict[Tuple, Tuple[LayerGroup, LMS]]" = OrderedDict()
        for req, key in zip(requests, keyed):
            if key not in distinct:
                distinct[key] = req
        results = dict(zip(distinct,
                           self.eval_requests_batch(list(distinct.values()),
                                                    total_batch,
                                                    backend=backend)))
        return [results[key] for key in keyed]

    # ------------------------------------------------------------------
    def eval_mapping_archs(self, mapping: Sequence[Tuple[LayerGroup, LMS]],
                           total_batch: int, archs: Sequence[ArchConfig]
                           ) -> Tuple[np.ndarray, np.ndarray]:
        """(energy (C,), delay (C,)) of ONE mapping under C archs that share
        this evaluator's :func:`analysis_signature` (i.e. differ only in the
        noc/d2d/dram bandwidths).

        The analysis — and therefore the energy — is computed once; only
        the per-candidate delay terms are re-derived, vectorized over the
        bandwidth columns.  Each column is bit-identical to evaluating the
        mapping under that arch with its own scalar evaluator: traffic
        maxima are reduced BEFORE the bandwidth division, which is exact
        because the numerators are non-negative byte counts and float
        division by a positive constant is monotone non-decreasing, so
        ``max_i fl(a_i / c) == fl(max_i a_i / c)`` bit-for-bit.
        """
        sig = analysis_signature(self.arch)
        for arch in archs:
            if analysis_signature(arch) != sig:
                raise ValueError(
                    f"arch {arch.label()} does not share the analysis "
                    f"signature of {self.arch.label()}; only bandwidth "
                    "fields may differ")
        C = len(archs)
        noc_div = np.array([a.noc_bw * 1e9 for a in archs])
        d2d_div = np.array([a.d2d_bw * 1e9 for a in archs])
        dram_port_div = np.array([a.dram_bw / a.n_dram * 1e9 for a in archs])
        dram_div = np.array([a.dram_bw * 1e9 for a in archs])
        glb_pen_div = self.arch.core_glb_bytes * self.arch.n_cores
        E = np.zeros(C)
        D = np.zeros(C)
        for group, lms in mapping:
            ge, an = self.eval_group(group, lms, total_batch)
            n_passes = ge.n_passes
            depth = ge.depth
            edge_tot = an.edge_bytes + an.edge_bytes_amortized
            m_noc = float(edge_tot[self._not_d2d].max(initial=0.0))
            t_noc = m_noc / noc_div
            if self._has_d2d:
                t_d2d = float(edge_tot[self._is_d2d].max(initial=0.0)) \
                    / d2d_div
            else:
                t_d2d = np.zeros(C)
            m_dram = float((an.dram_bytes
                            + an.dram_bytes_amortized).max(initial=0.0))
            t_dram = m_dram / dram_port_div
            t_comp = float(an.core_time_s.max(initial=0.0))
            stage = np.maximum(
                np.maximum(np.maximum(np.maximum(t_comp, t_noc), t_d2d),
                           t_dram), 1e-12)
            overflow = ge.glb_overflow_bytes
            spill_dram = overflow * 2.0
            stage = stage * (1.0 + overflow / glb_pen_div)
            stage = stage + spill_dram / dram_div
            D = D + stage * (n_passes + depth - 1)
            E = E + ge.energy_j        # energy never reads a bandwidth
        return E, D

    # ------------------------------------------------------------------
    def traffic_summary(self, group: LayerGroup, lms: LMS,
                        total_batch: int) -> Dict[str, float]:
        """Per-pass traffic totals of one group, split by physical axis.

        The realization subsystem diffs these against the measured traffic
        of the compiled stage program (``repro.realize.measure``); the keys
        mirror the measured axes: MACs doubled to FLOPs, NoC vs D2D link
        bytes (amortized weight loads included), DRAM bytes per pass.
        """
        ge, an = self.eval_group(group, lms, total_batch)
        edge_tot = an.edge_bytes + an.edge_bytes_amortized
        return {
            "flops": 2.0 * float(an.core_macs.sum()),
            "noc_bytes": float(edge_tot[self._not_d2d].sum()),
            "d2d_bytes": float(edge_tot[self._is_d2d].sum()),
            "dram_bytes": float((an.dram_bytes
                                 + an.dram_bytes_amortized).sum()),
            "delay_s": ge.delay_s,
            "energy_j": ge.energy_j,
            "glb_overflow_bytes": ge.glb_overflow_bytes,
        }

    # ------------------------------------------------------------------
    def evaluate(self, mapping: Sequence[Tuple[LayerGroup, LMS]],
                 total_batch: int) -> EvalResult:
        groups: List[GroupEval] = []
        analyses: List[GroupAnalysis] = []
        for group, lms in mapping:
            ge, an = self.eval_group(group, lms, total_batch)
            groups.append(ge)
            analyses.append(an)
        return EvalResult(
            delay_s=sum(ge.delay_s for ge in groups),
            energy_j=sum(ge.energy_j for ge in groups),
            groups=groups, analyses=analyses)


class CachedEvaluator(Evaluator):
    """Content-addressed ``GroupEval`` cache on top of :class:`Evaluator`.

    Key: ``(group id, LMS cache key, total_batch)`` where the group id is the
    (names, batch_unit) pair.  SA operators OP1-OP5 build *new* LMS values
    rather than mutating in place, so a cached entry can never go stale for a
    fixed (arch, graph) — re-proposals, repeated MC scoring sweeps and the
    final exact re-evaluation of the best mapping all hit the cache.  Callers
    must treat the returned (GroupEval, GroupAnalysis) as immutable: the
    tuple is shared between cache hits.  If the arch or graph changes, build
    a new evaluator — there is deliberately no invalidation API (DESIGN.md).
    """

    def __init__(self, arch: ArchConfig, g: Graph, maxsize: int = 20_000):
        super().__init__(arch, g)
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self._cache: "OrderedDict[Tuple, Tuple[GroupEval, GroupAnalysis]]" \
            = OrderedDict()
        # fused (backend="jax") results live in their OWN cache: they are
        # parity-grade, so they must never satisfy an exact-path lookup
        self._fused_cache: "OrderedDict[Tuple, Tuple[GroupEval, None]]" \
            = OrderedDict()

    def eval_group(self, group: LayerGroup, lms: LMS,
                   total_batch: int) -> Tuple[GroupEval, GroupAnalysis]:
        key = (group.names, group.batch_unit, lms.cache_key(), total_batch)
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
            self.hits += 1
            CACHE_STATS["group_eval.hits"] += 1
            return hit
        self.misses += 1
        CACHE_STATS["group_eval.misses"] += 1
        out = super().eval_group(group, lms, total_batch)
        self._cache[key] = out
        if len(self._cache) > self.maxsize:
            self._cache.popitem(last=False)
            CACHE_STATS["group_eval.evictions"] += 1
        return out

    def eval_groups_batched(self, requests: Sequence[Tuple[LayerGroup, LMS]],
                            total_batch: int, backend: str = "numpy"
                            ) -> List[Tuple[GroupEval, GroupAnalysis]]:
        """Cache-aware batch: hits resolve from the content cache, misses
        run through the vectorized batch path and are inserted exactly as
        :meth:`eval_group` would insert them (bit-identical values), so
        interleaving batched and scalar calls can never diverge.  Fused
        (``backend="jax"``) results resolve against a separate cache —
        parity-grade values never leak into exact-path lookups."""
        cache = self._fused_cache if backend == "jax" else self._cache
        stats = "group_eval_fused" if backend == "jax" else "group_eval"
        keys = [(grp.names, grp.batch_unit, lms.cache_key(), total_batch)
                for grp, lms in requests]
        out: List[Optional[Tuple[GroupEval, GroupAnalysis]]] \
            = [None] * len(requests)
        fresh: Dict[Tuple, Tuple[GroupEval, GroupAnalysis]] = {}
        miss_reqs: List[Tuple[LayerGroup, LMS]] = []
        miss_keys: List[Tuple] = []
        n_hits = 0
        for i, key in enumerate(keys):
            hit = cache.get(key)
            if hit is not None:
                cache.move_to_end(key)
                n_hits += 1
                out[i] = hit
            elif key not in fresh:
                fresh[key] = None          # claimed; filled below
                miss_reqs.append(requests[i])
                miss_keys.append(key)
            else:
                n_hits += 1                # duplicate of an in-batch miss
        self.hits += n_hits
        CACHE_STATS[stats + ".hits"] += n_hits
        if miss_reqs:
            self.misses += len(miss_reqs)
            CACHE_STATS[stats + ".misses"] += len(miss_reqs)
            for key, res in zip(miss_keys,
                                self.eval_requests_batch(miss_reqs,
                                                         total_batch,
                                                         backend=backend)):
                fresh[key] = res
                cache[key] = res
                if len(cache) > self.maxsize:
                    cache.popitem(last=False)
                    CACHE_STATS[stats + ".evictions"] += 1
        for i, key in enumerate(keys):
            if out[i] is None:
                out[i] = fresh[key]
        return out

    def cache_info(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "size": len(self._cache)}


# ---------------------------------------------------------------------------
# Per-process evaluator registry
# ---------------------------------------------------------------------------

# (ArchConfig, id(graph)) -> CachedEvaluator.  Each entry holds its Graph
# strongly (Evaluator.g), so a live entry's id() can never be recycled; the
# key is only ever compared while the entry is alive.
_REGISTRY: "OrderedDict[Tuple[ArchConfig, int], CachedEvaluator]" \
    = OrderedDict()
_REGISTRY_MAX = 8


def evaluator_for(arch: ArchConfig, g: Graph,
                  maxsize: int = 20_000) -> CachedEvaluator:
    """Process-local LRU registry of :class:`CachedEvaluator` instances.

    Scope is deliberately narrow: a hit needs the same ``(arch, graph)``
    re-scored within the last ``_REGISTRY_MAX`` distinct architectures —
    the screen-then-refine flow of *small* sweeps (demo grids, tests, the
    CI smoke) and tight same-arch loops.  Large sweeps (table1's hundreds
    of candidates) evict entries long before the refinement stage returns
    to them and simply pay one evaluator build per candidate, as before
    this registry existed; sharing *within* one candidate (replica-exchange
    chains + the final exact re-evaluation) is by explicit argument passing
    in ``evaluate_candidate``/``sa_optimize``, not via this registry.
    Retention is bounded: at most ``_REGISTRY_MAX`` evaluators, each
    holding only the GroupEvals it actually computed (a few MB per typical
    candidate).  Reuse is pure memoization: values are identical whether or
    not an entry was found (DESIGN.md), so parallel-vs-serial determinism
    is unaffected.  Worker processes each have their own registry;
    evaluators are never shared across processes.
    """
    key = (arch, id(g))
    ev = _REGISTRY.get(key)
    if ev is None:
        ev = CachedEvaluator(arch, g, maxsize=maxsize)
        _REGISTRY[key] = ev
        if len(_REGISTRY) > _REGISTRY_MAX:
            _REGISTRY.popitem(last=False)
    else:
        _REGISTRY.move_to_end(key)
    return ev
