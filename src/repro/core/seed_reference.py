"""Seed (pre-vectorization) evaluation engine, kept verbatim as an oracle.

This module preserves the repository's original scalar evaluation path —
the per-region Python loops over ``explore_intra_core_reference`` and the
uncached per-call LP-SPM analysis — exactly as it shipped in the seed
commit (only class names and the intra-core entry point are renamed).

Two consumers:
  * ``tests/test_vectorized_engine.py`` pins the vectorized engine against
    this one: ``GroupEval`` results must match bit-for-bit on arbitrary
    mappings, not just stored golden numbers;
  * ``benchmarks/misc_bench.py::evaluator_throughput`` times both engines
    in the same process, so the reported speedup is independent of the
    machine's load at benchmark time.

Do not optimize this file; its value is that it never changes.
"""

from __future__ import annotations

from functools import lru_cache
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .analyzer import (GroupAnalysis, RouterGrid, _overlap_matrix,
                       _regions_to_array, router_grid)
from .encoding import LMS, MS, Region, ifmap_region, parse_regions
from .evaluator import EvalResult, GroupEval
from .hw import ArchConfig
from .intra_core import explore_intra_core_reference
from .workload import Graph, Layer, LayerGroup

# the seed memoized its intra-core search on the workload signature; mirror
# that here so throughput comparisons against this engine are fair
_explore_seed = lru_cache(maxsize=200_000)(explore_intra_core_reference)


class ReferenceAnalyzer:
    """Stateful per-(arch, graph) analyzer; reused across SA iterations."""

    def __init__(self, arch: ArchConfig, g: Graph):
        self.arch = arch
        self.g = g
        self.grid = router_grid(arch)
        self._core_nodes = np.array(
            [arch.core_node(c) for c in range(arch.n_cores)], dtype=np.int64)
        self._dram_nodes = np.array(
            [arch.dram_node(d) for d in range(1, arch.n_dram + 1)], dtype=np.int64)

    # -- routing helpers -----------------------------------------------------
    def _route(self, edge_bytes: np.ndarray, src_nodes: np.ndarray,
               dst_nodes: np.ndarray, vols: np.ndarray) -> None:
        """Accumulate unicast volumes onto edge loads (vectorized)."""
        mask = vols > 0
        if not mask.any():
            return
        s, d, v = src_nodes[mask], dst_nodes[mask], vols[mask]
        paths = self.grid.paths[s, d]            # (n, max_len)
        flat = paths.reshape(-1)
        keep = flat >= 0
        np.add.at(edge_bytes, flat[keep],
                  np.repeat(v, paths.shape[1])[keep])

    def _route_multicast(self, edge_bytes: np.ndarray, src_node: int,
                         dst_nodes: Sequence[int], vol: float) -> None:
        """One producer datum to many consumers: union of XY paths, counted once."""
        if vol <= 0 or not len(dst_nodes):
            return
        paths = self.grid.paths[src_node, np.asarray(dst_nodes, dtype=np.int64)]
        edges = np.unique(paths[paths >= 0])
        edge_bytes[edges] += vol

    # -- main entry ------------------------------------------------------------
    def analyze(self, group: LayerGroup, lms: LMS, total_batch: int) -> GroupAnalysis:
        arch, g = self.arch, self.g
        bu = group.batch_unit
        n_passes = max(1, -(-total_batch // bu))
        in_group = set(group.names)

        core_macs = np.zeros(arch.n_cores)
        edge_bytes = np.zeros(self.grid.n_edges)
        edge_amort = np.zeros(self.grid.n_edges)
        dram_bytes = np.zeros(arch.n_dram)
        dram_amort = np.zeros(arch.n_dram)
        glb_need = np.zeros(arch.n_cores)
        core_in = np.zeros(arch.n_cores)
        core_out = np.zeros(arch.n_cores)
        weight_total = 0.0

        regions_of: Dict[str, Dict[int, Region]] = {}
        for name in group.names:
            regions_of[name] = parse_regions(lms.ms[name], g.layers[name], bu)

        for name in group.names:
            lyr = g.layers[name]
            ms = lms.ms[name]
            regs = regions_of[name]
            cores, rarr = _regions_to_array(regs)
            nodes = self._core_nodes[cores]
            bpe = lyr.bytes_per_elem

            # compute: MACs proportional to ofmap share
            elems = (rarr[:, 1] - rarr[:, 0]) * (rarr[:, 3] - rarr[:, 2]) \
                * (rarr[:, 5] - rarr[:, 4]) * (rarr[:, 7] - rarr[:, 6])
            mac_per_elem = lyr.macs(1) / max(1, lyr.ofmap_elems)
            np.add.at(core_macs, cores, elems * mac_per_elem)

            # GLB footprint: weight slice + ofmap part (double-buffered fmaps)
            w_share = lyr.weight_bytes() / max(1, ms.part[3]) if lyr.has_weight else 0
            np.add.at(glb_need, cores, elems * bpe * 2 + w_share)

            # ---- weights: DRAM -> core, amortized over passes ----------------
            if lyr.has_weight:
                w_bytes_core = np.full(len(cores), 0.0)
                # each core holds the K-slice of its region (C,R,S full)
                k_span = (rarr[:, 7] - rarr[:, 6])
                w_bytes_core = k_span / max(1, lyr.K) * lyr.weight_bytes()
                weight_total += float(w_bytes_core.sum())
                self._dram_flow(edge_amort, dram_amort, ms.fd[1], nodes,
                                w_bytes_core / n_passes, to_core=True)

            # ---- ifmaps ------------------------------------------------------
            preds = [p for p in g.preds(name)]
            internal = [p for p in preds if p in in_group]
            external = (not preds) or any(p not in in_group for p in preds)
            for p in internal:
                self._dep_traffic(edge_bytes, core_in, core_out,
                                  g.layers[p], regions_of[p], lyr, regs, bu)
            if external and ms.fd[0] >= 0:
                # full needed ifmap from DRAM (input of DNN or previous group)
                if_bytes = self._external_ifmap_bytes(lyr, rarr, bu) * bpe
                self._dram_flow(edge_bytes, dram_bytes, ms.fd[0], nodes,
                                if_bytes, to_core=True)
                np.add.at(core_in, cores, if_bytes)

            # ---- ofmaps ------------------------------------------------------
            if ms.fd[2] >= 0:
                of_bytes = elems * bpe
                self._dram_flow(edge_bytes, dram_bytes, ms.fd[2], nodes,
                                of_bytes.astype(float), to_core=False)
                np.add.at(core_out, cores, of_bytes)

        return GroupAnalysis(
            arch=arch, batch_unit=bu, core_macs=core_macs,
            edge_bytes=edge_bytes, edge_bytes_amortized=edge_amort,
            dram_bytes=dram_bytes, dram_bytes_amortized=dram_amort,
            core_glb_need=glb_need, core_in_bytes=core_in,
            core_out_bytes=core_out, weight_dram_bytes_total=weight_total,
            layer_parts=regions_of)

    # -- pieces ---------------------------------------------------------------
    def _external_ifmap_bytes(self, lyr: Layer, rarr: np.ndarray,
                              bu: int) -> np.ndarray:
        """Elements of DNN-level input each core must fetch (halo included)."""
        s = lyr.stride
        dh = (rarr[:, 1] - rarr[:, 0]) * s + (lyr.R - 1)
        dw = (rarr[:, 3] - rarr[:, 2]) * s + (lyr.S - 1)
        db = rarr[:, 5] - rarr[:, 4]
        if lyr.kind in ("eltwise", "pool", "depthwise"):
            dk = (rarr[:, 7] - rarr[:, 6]) * (lyr.n_inputs if lyr.kind == "eltwise" else 1)
        elif lyr.kind == "matmul":
            # both operands streamed: rows of A for H-range + full B operand share
            dk = np.full(len(rarr), lyr.C, dtype=np.int64)
            return (rarr[:, 1] - rarr[:, 0]) * db * lyr.C \
                + (rarr[:, 7] - rarr[:, 6]) * db * lyr.C
        else:
            dk = np.full(len(rarr), max(1, lyr.C), dtype=np.int64)
        return dh * dw * db * dk

    def _dram_flow(self, edge_bytes: np.ndarray, dram_bytes: np.ndarray,
                   fd: int, nodes: np.ndarray, vols: np.ndarray,
                   to_core: bool) -> None:
        """Route core<->DRAM volumes.  fd==0 interleaves over all ports."""
        vols = np.asarray(vols, dtype=float)
        if np.ndim(vols) == 0:
            vols = np.full(len(nodes), float(vols))
        if fd == 0:
            share = vols / self.arch.n_dram
            for d in range(self.arch.n_dram):
                dn = np.full(len(nodes), self._dram_nodes[d])
                if to_core:
                    self._route(edge_bytes, dn, nodes, share)
                else:
                    self._route(edge_bytes, nodes, dn, share)
                dram_bytes[d] += float(share.sum())
        else:
            d = fd - 1
            dn = np.full(len(nodes), self._dram_nodes[d])
            if to_core:
                self._route(edge_bytes, dn, nodes, vols)
            else:
                self._route(edge_bytes, nodes, dn, vols)
            dram_bytes[d] += float(vols.sum())

    def _dep_traffic(self, edge_bytes: np.ndarray, core_in: np.ndarray,
                     core_out: np.ndarray, prod: Layer,
                     prod_regs: Dict[int, Region], cons: Layer,
                     cons_regs: Dict[int, Region], bu: int) -> None:
        """Producer->consumer on-chip flow with K-multicast grouping.

        Consumers whose needed region is identical (K-partition siblings for
        channel-contracting layers) form one multicast set per producer part.
        """
        p_cores, p_arr = _regions_to_array(prod_regs)
        c_cores, c_arr = _regions_to_array(cons_regs)
        bpe = prod.bytes_per_elem

        # needed region of each consumer part, in producer-ofmap coordinates
        need = np.empty_like(c_arr)
        for i, cc in enumerate(c_cores):
            r = cons_regs[cc]
            nr = ifmap_region(cons, r, prod.K)
            need[i] = [nr.h0, nr.h1, nr.w0, nr.w1, nr.b0, nr.b1, nr.k0, nr.k1]

        ov = _overlap_matrix(p_arr, need)        # (P, Q) elems
        if not ov.any():
            return
        p_nodes = self._core_nodes[p_cores]
        c_nodes = self._core_nodes[c_cores]

        contracting = cons.kind in ("conv", "fc", "matmul")
        if contracting:
            # group consumer parts by identical 'need' signature -> multicast
            sig = [tuple(row) for row in need]
            groups: Dict[Tuple, List[int]] = {}
            for qi, s in enumerate(sig):
                groups.setdefault(s, []).append(qi)
            for s, qis in groups.items():
                vols = ov[:, qis[0]].astype(float) * bpe   # same for all members
                for pi in np.nonzero(vols)[0]:
                    dsts = [int(c_nodes[q]) for q in qis
                            if c_nodes[q] != p_nodes[pi]]
                    self._route_multicast(edge_bytes, int(p_nodes[pi]),
                                          dsts, float(vols[pi]))
                    core_out[p_cores[pi]] += vols[pi] * (1 if dsts else 0)
                    for q in qis:
                        if c_nodes[q] != p_nodes[pi]:
                            core_in[c_cores[q]] += vols[pi]
        else:
            vols = ov.astype(float) * bpe
            same = p_nodes[:, None] == c_nodes[None, :]
            vols_off = np.where(same, 0.0, vols)
            P, Q = vols.shape
            self._route(edge_bytes,
                        np.repeat(p_nodes, Q), np.tile(c_nodes, P),
                        vols_off.reshape(-1))
            np.add.at(core_out, p_cores, vols_off.sum(axis=1))
            np.add.at(core_in, c_cores, vols_off.sum(axis=0))


def _pipeline_depth_ref(g: Graph, group: LayerGroup) -> int:
    """Longest dependency chain within the group (fill/drain passes)."""
    names = set(group.names)
    depth: Dict[str, int] = {}
    for n in g.topo_order():
        if n not in names:
            continue
        preds = [p for p in g.preds(n) if p in names]
        depth[n] = 1 + max((depth[p] for p in preds), default=0)
    return max(depth.values(), default=1)


class ReferenceEvaluator:
    """Per-(arch, graph) evaluator; reuses the Analyzer and its caches."""

    def __init__(self, arch: ArchConfig, g: Graph):
        self.arch = arch
        self.g = g
        self.analyzer = ReferenceAnalyzer(arch, g)
        self.grid = router_grid(arch)

    # ------------------------------------------------------------------
    def eval_group(self, group: LayerGroup, lms: LMS,
                   total_batch: int) -> Tuple[GroupEval, GroupAnalysis]:
        arch, g, tech = self.arch, self.g, self.arch.tech
        an = self.analyzer.analyze(group, lms, total_batch)
        bu = group.batch_unit
        n_passes = max(1, -(-total_batch // bu))
        depth = _pipeline_depth_ref(g, group)

        # -- per-core compute time (uses intra-core utilization) -----------
        core_time = np.zeros(arch.n_cores)
        glb_rd = 0.0
        glb_wr = 0.0
        for name, regs in an.layer_parts.items():
            lyr = g.layers[name]
            mac_per_elem = lyr.macs(1) / max(1, lyr.ofmap_elems)
            for core, r in regs.items():
                rk = r.k1 - r.k0
                hwb = max(1, r.elems // max(1, rk))
                df = _explore_seed(rk, lyr.C, hwb, lyr.R, lyr.S,
                                   lyr.bytes_per_elem, arch.core_glb_bytes,
                                   arch.macs_per_core, lyr.kind)
                macs = r.elems * mac_per_elem
                peak = arch.macs_per_core * arch.freq_ghz * 1e9
                core_time[core] += macs / (peak * max(df.utilization, 1e-3))
                glb_rd += df.glb_read_bytes
                glb_wr += df.glb_write_bytes

        # -- resource times per pass ---------------------------------------
        edge_tot = an.edge_bytes + an.edge_bytes_amortized
        is_d2d = self.grid.edge_is_d2d
        t_noc = float((edge_tot[~is_d2d] / (arch.noc_bw * 1e9)).max(initial=0.0))
        t_d2d = float((edge_tot[is_d2d] / (arch.d2d_bw * 1e9)).max(initial=0.0)) \
            if is_d2d.any() else 0.0
        dram_port_bw = arch.dram_bw / arch.n_dram * 1e9
        t_dram = float(((an.dram_bytes + an.dram_bytes_amortized)
                        / dram_port_bw).max(initial=0.0))
        t_comp = float(core_time.max(initial=0.0))
        stage = max(t_comp, t_noc, t_d2d, t_dram, 1e-12)
        bottleneck = ["compute", "noc", "d2d", "dram"][
            int(np.argmax([t_comp, t_noc, t_d2d, t_dram]))]

        # -- GLB overcommit: soft penalty -----------------------------------
        over = np.maximum(an.core_glb_need - arch.core_glb_bytes, 0.0)
        overflow = float(over.sum())
        spill_dram = overflow * 2.0          # write + re-read per pass
        stage *= 1.0 + overflow / (arch.core_glb_bytes * arch.n_cores)
        t_dram_spill = spill_dram / (arch.dram_bw * 1e9)
        stage += t_dram_spill

        delay = stage * (n_passes + depth - 1)

        # -- energy over the whole batch -------------------------------------
        noc_bytes = float(edge_tot[~is_d2d].sum()) * n_passes
        d2d_bytes = float(edge_tot[is_d2d].sum()) * n_passes
        dram_b = float(an.dram_bytes.sum()) * n_passes \
            + an.weight_dram_bytes_total + spill_dram * n_passes
        macs_total = float(an.core_macs.sum()) * n_passes
        e = {
            "mac": macs_total * tech.e_mac,
            "glb": (glb_rd + glb_wr + float(an.core_in_bytes.sum())) * n_passes
                   * tech.e_glb_byte,
            "noc": (noc_bytes + d2d_bytes) * tech.e_noc_hop_byte,
            "d2d": d2d_bytes * tech.e_d2d_byte,
            "dram": dram_b * tech.e_dram_byte,
        }
        ge = GroupEval(delay_s=delay, energy_j=sum(e.values()),
                       stage_time_s=stage, n_passes=n_passes, depth=depth,
                       bottleneck=bottleneck, glb_overflow_bytes=overflow,
                       energy_breakdown=e)
        return ge, an

    # ------------------------------------------------------------------
    def evaluate(self, mapping: Sequence[Tuple[LayerGroup, LMS]],
                 total_batch: int) -> EvalResult:
        groups: List[GroupEval] = []
        analyses: List[GroupAnalysis] = []
        for group, lms in mapping:
            ge, an = self.eval_group(group, lms, total_batch)
            groups.append(ge)
            analyses.append(an)
        return EvalResult(
            delay_s=sum(ge.delay_s for ge in groups),
            energy_j=sum(ge.energy_j for ge in groups),
            groups=groups, analyses=analyses)
