"""Workload IR: DNN layers as a DAG with 4-D ofmap cubes (paper Sec. IV).

Every layer exposes the paper's abstraction: an ofmap cube (B, K, H, W), a
contraction structure (C input channels, RxS kernel, stride) and a weight
flag.  This is enough for the encoding, the analyzer, the intra-core tiling
search and both evaluators.  Transformer / SSM / MoE ops are expressed in the
same cube language (see core/workloads/).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


LayerKind = str  # conv | fc | pool | eltwise | matmul | depthwise


@dataclass(frozen=True)
class Layer:
    """One DAG node.  Dims are per *sample*; B is filled by the batch unit."""
    name: str
    kind: LayerKind
    K: int                  # ofmap channels
    H: int = 1              # ofmap height (sequence length for LM layers)
    W: int = 1              # ofmap width
    C: int = 0              # contraction channels (0 for eltwise/pool)
    R: int = 1              # kernel height
    S: int = 1              # kernel width
    stride: int = 1
    groups: int = 1                 # grouped conv (ResNeXt); C is per-layer total
    bytes_per_elem: int = 1         # int8 inference default
    n_inputs: int = 1               # eltwise add has 2
    # 'matmul' layers contract activations with activations (attention):
    # their "weight" operand is itself a produced tensor, so has_weight=False.

    def __post_init__(self):
        if self.K <= 0 or self.H <= 0 or self.W <= 0:
            raise ValueError(f"bad ofmap dims for {self.name}")

    # -- sizes per sample, in elements ---------------------------------------
    @property
    def has_weight(self) -> bool:
        return self.kind in ("conv", "fc", "depthwise")

    @property
    def ofmap_elems(self) -> int:
        return self.K * self.H * self.W

    @property
    def ifmap_elems(self) -> int:
        if self.kind in ("eltwise",):
            return self.ofmap_elems * self.n_inputs
        if self.kind == "pool":
            return self.K * self.H * self.stride * self.W * self.stride
        if self.kind == "depthwise":
            return self.K * self.H * self.stride * self.W * self.stride
        if self.kind == "matmul":
            # ifmap = (H x C) activations; "weight-side" = (C x K) activations
            return self.H * self.C + self.C * self.K
        return self.C * self.H * self.stride * self.W * self.stride

    @property
    def weight_elems(self) -> int:
        if self.kind == "conv":
            return self.K * (self.C // self.groups) * self.R * self.S
        if self.kind == "fc":
            return self.K * self.C
        if self.kind == "depthwise":
            return self.K * self.R * self.S
        return 0

    def macs(self, batch: int = 1) -> int:
        """Multiply-accumulates per ``batch`` samples."""
        if self.kind in ("conv",):
            m = self.K * self.H * self.W * (self.C // self.groups) * self.R * self.S
        elif self.kind == "fc":
            m = self.K * self.H * self.W * self.C
        elif self.kind == "matmul":
            m = self.H * self.K * self.C
        elif self.kind == "depthwise":
            m = self.K * self.H * self.W * self.R * self.S
        elif self.kind == "pool":
            m = self.K * self.H * self.W * self.stride * self.stride
        else:  # eltwise
            m = self.ofmap_elems * self.n_inputs
        return m * batch

    def ofmap_bytes(self, batch: int = 1) -> int:
        return self.ofmap_elems * self.bytes_per_elem * batch

    def weight_bytes(self) -> int:
        return self.weight_elems * self.bytes_per_elem


@dataclass
class Graph:
    """DNN DAG.  Edges carry producer->consumer feature-map dependencies."""
    name: str
    layers: Dict[str, Layer] = field(default_factory=dict)
    edges: List[Tuple[str, str]] = field(default_factory=list)
    # graph inputs: layers whose ifmaps come from DRAM (the DNN input)
    input_layers: List[str] = field(default_factory=list)

    def add(self, layer: Layer, inputs: Sequence[str] = ()) -> Layer:
        if layer.name in self.layers:
            raise ValueError(f"duplicate layer {layer.name}")
        self.layers[layer.name] = layer
        for src in inputs:
            if src not in self.layers:
                raise ValueError(f"unknown input {src} for {layer.name}")
            self.edges.append((src, layer.name))
        if not inputs:
            self.input_layers.append(layer.name)
        return layer

    # -- queries --------------------------------------------------------------
    def preds(self, name: str) -> List[str]:
        return [s for s, d in self.edges if d == name]

    def succs(self, name: str) -> List[str]:
        return [d for s, d in self.edges if s == name]

    def topo_order(self) -> List[str]:
        indeg = {n: 0 for n in self.layers}
        for _, d in self.edges:
            indeg[d] += 1
        frontier = [n for n in self.layers if indeg[n] == 0]
        out: List[str] = []
        while frontier:
            n = frontier.pop(0)
            out.append(n)
            for d in self.succs(n):
                indeg[d] -= 1
                if indeg[d] == 0:
                    frontier.append(d)
        if len(out) != len(self.layers):
            raise ValueError(f"cycle in graph {self.name}")
        return out

    def output_layers(self) -> List[str]:
        return [n for n in self.layers if not self.succs(n)]

    def total_macs(self, batch: int = 1) -> int:
        return sum(l.macs(batch) for l in self.layers.values())

    def total_weight_bytes(self) -> int:
        return sum(l.weight_bytes() for l in self.layers.values())

    def subgraph(self, names: Sequence[str], name: Optional[str] = None) -> "Graph":
        keep = set(names)
        g = Graph(name or f"{self.name}[{len(keep)}]")
        g.layers = {n: self.layers[n] for n in names}
        g.edges = [(s, d) for s, d in self.edges if s in keep and d in keep]
        g.input_layers = [n for n in names
                          if not any(d == n and s in keep for s, d in self.edges)]
        return g

    def validate(self) -> None:
        self.topo_order()
        for s, d in self.edges:
            if s not in self.layers or d not in self.layers:
                raise ValueError(f"dangling edge {s}->{d}")


# ---------------------------------------------------------------------------
# Layer groups (output of graph partitioning, input to the mapping engine)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LayerGroup:
    """A contiguous-in-topo-order set of layers pipelined together."""
    names: Tuple[str, ...]
    batch_unit: int = 1          # samples processed per pipeline pass

    def __len__(self) -> int:
        return len(self.names)


def edge_volume(g: Graph, src: str, dst: str, batch: int = 1) -> int:
    """Bytes of feature map flowing src->dst per ``batch`` samples."""
    l = g.layers[src]
    return l.ofmap_bytes(batch)
