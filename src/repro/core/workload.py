"""Workload IR: DNN layers as a DAG with 4-D ofmap cubes (paper Sec. IV).

Every layer exposes the paper's abstraction: an ofmap cube (B, K, H, W), a
contraction structure (C input channels, RxS kernel, stride) and a weight
flag.  This is enough for the encoding, the analyzer, the intra-core tiling
search and both evaluators.  Transformer / SSM / MoE ops are expressed in the
same cube language (see core/workloads/).

Expected-traffic formulation (PR 6): the paper assumes every layer moves its
full dense volume each pass.  Data-dependent workloads (sparse MoE routing,
speculative paths) break that, so each layer carries *expected-traffic
scales* — ``traffic_scale`` for activations/compute and
``weight_traffic_scale`` for weight loads — and each edge may carry a
*multiplicity*.  Cube dims stay dense (they define the mapping space and
buffer provisioning); the scales multiply the analyzer's traffic/compute
contributions.  ``1.0`` everywhere is bit-identical to the dense model: all
consumers guard scaling behind ``scale != 1.0`` so the float-op sequence of
an unscaled graph is exactly the pre-refactor one.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union


LayerKind = str  # conv | fc | pool | eltwise | matmul | depthwise

# an edge input to Graph.add: a producer name, optionally with an
# expected-traffic multiplicity on the producer->consumer transfer
EdgeInput = Union[str, Tuple[str, float]]


@dataclass(frozen=True)
class Layer:
    """One DAG node.  Dims are per *sample*; B is filled by the batch unit."""
    name: str
    kind: LayerKind
    K: int                  # ofmap channels
    H: int = 1              # ofmap height (sequence length for LM layers)
    W: int = 1              # ofmap width
    C: int = 0              # contraction channels (0 for eltwise/pool)
    R: int = 1              # kernel height
    S: int = 1              # kernel width
    stride: int = 1
    groups: int = 1                 # grouped conv (ResNeXt); C is per-layer total
    bytes_per_elem: int = 1         # int8 inference default
    n_inputs: int = 1               # eltwise add has 2
    # expected fraction of the dense volume this layer computes/moves per
    # pass: activations+MACs (traffic_scale) and weight loads
    # (weight_traffic_scale).  A routed MoE expert with top_k of E experts
    # active carries traffic_scale = top_k / E.  repr=False keeps the
    # dataclass repr — and therefore explore.graph_fingerprint for dense
    # graphs — byte-identical to the pre-scale IR, so existing sweep
    # checkpoints stay resumable (eq/hash still include the fields, which
    # is what the analyzer's _GEO_CACHE keys rely on).
    traffic_scale: float = field(default=1.0, repr=False)
    weight_traffic_scale: float = field(default=1.0, repr=False)
    # 'matmul' layers contract activations with activations (attention):
    # their "weight" operand is itself a produced tensor, so has_weight=False.

    def __post_init__(self):
        if self.K <= 0 or self.H <= 0 or self.W <= 0:
            raise ValueError(f"bad ofmap dims for {self.name}")
        if self.traffic_scale <= 0 or self.weight_traffic_scale <= 0:
            raise ValueError(
                f"{self.name}: expected-traffic scales must be > 0 "
                f"(traffic_scale={self.traffic_scale}, "
                f"weight_traffic_scale={self.weight_traffic_scale})")

    # -- sizes per sample, in elements ---------------------------------------
    @property
    def has_weight(self) -> bool:
        return self.kind in ("conv", "fc", "depthwise")

    @property
    def is_scaled(self) -> bool:
        return self.traffic_scale != 1.0 or self.weight_traffic_scale != 1.0

    @property
    def ofmap_elems(self) -> int:
        return self.K * self.H * self.W

    @property
    def ifmap_elems(self) -> int:
        if self.kind in ("eltwise",):
            return self.ofmap_elems * self.n_inputs
        if self.kind == "pool":
            return self.K * self.H * self.stride * self.W * self.stride
        if self.kind == "depthwise":
            return self.K * self.H * self.stride * self.W * self.stride
        if self.kind == "matmul":
            # ifmap = (H x C) activations; "weight-side" = (C x K) activations
            return self.H * self.C + self.C * self.K
        return self.C * self.H * self.stride * self.W * self.stride

    @property
    def weight_elems(self) -> int:
        if self.kind == "conv":
            return self.K * (self.C // self.groups) * self.R * self.S
        if self.kind == "fc":
            return self.K * self.C
        if self.kind == "depthwise":
            return self.K * self.R * self.S
        return 0

    def macs(self, batch: int = 1) -> int:
        """Multiply-accumulates per ``batch`` samples (dense)."""
        if self.kind in ("conv",):
            m = self.K * self.H * self.W * (self.C // self.groups) * self.R * self.S
        elif self.kind == "fc":
            m = self.K * self.H * self.W * self.C
        elif self.kind == "matmul":
            m = self.H * self.K * self.C
        elif self.kind == "depthwise":
            m = self.K * self.H * self.W * self.R * self.S
        elif self.kind == "pool":
            m = self.K * self.H * self.W * self.stride * self.stride
        else:  # eltwise
            m = self.ofmap_elems * self.n_inputs
        return m * batch

    def ofmap_bytes(self, batch: int = 1) -> int:
        return self.ofmap_elems * self.bytes_per_elem * batch

    def weight_bytes(self) -> int:
        return self.weight_elems * self.bytes_per_elem

    # -- expected-traffic sizes (dense value when the scale is 1.0, so the
    # -- int type and bit pattern of unscaled graphs are untouched) ----------
    def expected_macs(self, batch: int = 1) -> Union[int, float]:
        m = self.macs(batch)
        return m if self.traffic_scale == 1.0 else m * self.traffic_scale

    def expected_ofmap_bytes(self, batch: int = 1) -> Union[int, float]:
        b = self.ofmap_bytes(batch)
        return b if self.traffic_scale == 1.0 else b * self.traffic_scale

    def expected_weight_bytes(self) -> Union[int, float]:
        b = self.weight_bytes()
        return b if self.weight_traffic_scale == 1.0 \
            else b * self.weight_traffic_scale


@dataclass
class Graph:
    """DNN DAG.  Edges carry producer->consumer feature-map dependencies;
    an entry in ``edge_mults`` multiplies the expected traffic of that edge
    (absent == 1.0, the dense transfer)."""
    name: str
    layers: Dict[str, Layer] = field(default_factory=dict)
    edges: List[Tuple[str, str]] = field(default_factory=list)
    # graph inputs: layers whose ifmaps come from DRAM (the DNN input)
    input_layers: List[str] = field(default_factory=list)
    edge_mults: Dict[Tuple[str, str], float] = field(default_factory=dict)

    def add(self, layer: Layer, inputs: Sequence[EdgeInput] = ()) -> Layer:
        if layer.name in self.layers:
            raise ValueError(f"duplicate layer {layer.name}")
        parsed = []
        for item in inputs:                    # validate BEFORE mutating
            src, mult = item if isinstance(item, tuple) else (item, 1.0)
            if src not in self.layers:
                raise ValueError(f"unknown input {src} for {layer.name}")
            if mult <= 0:
                raise ValueError(
                    f"edge {src}->{layer.name}: multiplicity must be "
                    f"> 0, got {mult}")
            parsed.append((src, mult))
        self.layers[layer.name] = layer
        for src, mult in parsed:
            self.edges.append((src, layer.name))
            if mult != 1.0:
                self.edge_mults[(src, layer.name)] = float(mult)
        if not inputs:
            self.input_layers.append(layer.name)
        return layer

    # -- queries --------------------------------------------------------------
    def preds(self, name: str) -> List[str]:
        return [s for s, d in self.edges if d == name]

    def succs(self, name: str) -> List[str]:
        return [d for s, d in self.edges if s == name]

    def edge_mult(self, src: str, dst: str) -> float:
        """Expected-traffic multiplicity of one edge (1.0 == dense)."""
        return self.edge_mults.get((src, dst), 1.0)

    def topo_order(self) -> List[str]:
        indeg = {n: 0 for n in self.layers}
        for _, d in self.edges:
            indeg[d] += 1
        frontier = [n for n in self.layers if indeg[n] == 0]
        out: List[str] = []
        while frontier:
            n = frontier.pop(0)
            out.append(n)
            for d in self.succs(n):
                indeg[d] -= 1
                if indeg[d] == 0:
                    frontier.append(d)
        if len(out) != len(self.layers):
            raise ValueError(f"cycle in graph {self.name}")
        return out

    def output_layers(self) -> List[str]:
        return [n for n in self.layers if not self.succs(n)]

    def total_macs(self, batch: int = 1) -> int:
        return sum(l.macs(batch) for l in self.layers.values())

    def total_expected_macs(self, batch: int = 1) -> float:
        """Expected MACs per ``batch`` samples (== total_macs when dense)."""
        return sum(l.expected_macs(batch) for l in self.layers.values())

    def total_weight_bytes(self) -> int:
        return sum(l.weight_bytes() for l in self.layers.values())

    @property
    def is_scaled(self) -> bool:
        """True when any expected-traffic scale or multiplicity != 1.0."""
        return bool(self.edge_mults) \
            or any(l.is_scaled for l in self.layers.values())

    def subgraph(self, names: Sequence[str], name: Optional[str] = None) -> "Graph":
        keep = set(names)
        g = Graph(name or f"{self.name}[{len(keep)}]")
        g.layers = {n: self.layers[n] for n in names}
        g.edges = [(s, d) for s, d in self.edges if s in keep and d in keep]
        g.edge_mults = {(s, d): m for (s, d), m in self.edge_mults.items()
                        if s in keep and d in keep}
        g.input_layers = [n for n in names
                          if not any(d == n and s in keep for s, d in self.edges)]
        return g

    def validate(self) -> None:
        self.topo_order()
        edge_set = set(self.edges)
        for s, d in self.edges:
            if s not in self.layers or d not in self.layers:
                raise ValueError(f"dangling edge {s}->{d}")
        for (s, d), m in self.edge_mults.items():
            if (s, d) not in edge_set:
                raise ValueError(f"multiplicity on non-edge {s}->{d}")
            if m <= 0:
                raise ValueError(f"edge {s}->{d}: multiplicity {m} <= 0")


def dense_twin(g: Graph) -> Graph:
    """The same DAG with every expected-traffic scale/multiplicity reset to
    1.0.  Returns ``g`` itself when it is already dense (the common case —
    no copy, so dense-path callers stay bit-identical and allocation-free).

    The realization subsystem diffs measured programs (which execute the
    dense cubes) against this twin's predictions to recover per-axis
    expected-traffic factors — see ``repro.realize.measure``.
    """
    if not g.is_scaled:
        return g
    out = Graph(g.name)
    out.layers = {
        n: (replace(l, traffic_scale=1.0, weight_traffic_scale=1.0)
            if l.is_scaled else l)
        for n, l in g.layers.items()}
    out.edges = list(g.edges)
    out.input_layers = list(g.input_layers)
    return out


# ---------------------------------------------------------------------------
# Layer groups (output of graph partitioning, input to the mapping engine)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LayerGroup:
    """A contiguous-in-topo-order set of layers pipelined together."""
    names: Tuple[str, ...]
    batch_unit: int = 1          # samples processed per pipeline pass

    def __len__(self) -> int:
        return len(self.names)


def edge_volume(g: Graph, src: str, dst: str,
                batch: int = 1) -> Union[int, float]:
    """Expected bytes of feature map flowing src->dst per ``batch`` samples:
    the producer's dense ofmap, scaled by its ``traffic_scale`` and the
    edge's multiplicity.  Dense graphs return the exact int of the old
    static-volume model."""
    l = g.layers[src]
    v = l.ofmap_bytes(batch)
    m = l.traffic_scale * g.edge_mult(src, dst)
    return v if m == 1.0 else v * m
