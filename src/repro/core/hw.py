"""Hardware template for Gemini (paper Sec. III) + technology constants.

The template is the paper's: a grid of ``x_cores x y_cores`` computing cores,
cut into ``xcut x ycut`` chiplets, flanked by two IO chiplets (west/east)
carrying the DRAM controllers.  A mesh NoC spans everything; links that cross
a chiplet boundary are D2D links with their own bandwidth/energy.

Two constant sets live here:
  * ``TECH_12NM``  — the paper's 12 nm inference-accelerator constants,
    calibrated against the publications the paper cites (GRS D2D 1.17 pJ/b
    [Poulton'19], on-chip lines <0.1 pJ/b, GDDR6 32 GB/s per $3.5 die,
    Yield_unit=0.9 per 40 mm^2 [Chiplet Actuary]).
  * ``TPU_V5E``    — roofline constants for the JAX/TPU side of this repo.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import cached_property
from typing import Tuple


# --------------------------------------------------------------------------
# Technology constants
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Tech:
    """Per-technology energy / area / cost constants (int8 inference)."""
    name: str
    # energy, joules
    e_mac: float            # per 8-bit MAC
    e_glb_byte: float       # per byte GLB (SRAM) access
    e_noc_hop_byte: float   # per byte per NoC hop (router+wire)
    e_d2d_byte: float       # per byte crossing one D2D interface
    e_dram_byte: float      # per byte of DRAM traffic
    # area, mm^2
    a_mac: float            # per MAC unit
    a_glb_kb: float         # per KB of GLB SRAM
    a_core_fixed: float     # router + DMA + control + vector unit
    a_d2d_fixed: float      # per D2D interface (PHY + controller), fixed part
    a_d2d_per_gbps: float   # per D2D interface, bandwidth-proportional part
    a_io_die_fixed: float   # per IO chiplet (PCIe, misc analog)
    a_dram_phy_per_gbps: float  # DDR PHY area per GB/s on the IO die
    # monetary cost
    c_silicon_mm2: float    # $ per mm^2 of (yielded) silicon
    yield_unit: float       # yield of one Area_unit die
    area_unit_mm2: float    # the unit area for the yield model
    c_dram_die: float       # $ per DRAM die
    dram_die_bw: float      # GB/s per DRAM die
    f_scale: float          # substrate area / total silicon area
    yield_package: float    # per-die mount yield (compounds with #dies)
    c_package_mono_mm2: float   # $/mm^2, plain fan-out substrate (monolithic)
    # chiplet-grade organic substrate tiers: (max_area_mm2, $/mm^2)
    c_package_tiers: Tuple[Tuple[float, float], ...] = (
        (1000.0, 0.020), (3000.0, 0.030), (float("inf"), 0.045))


TECH_12NM = Tech(
    name="tsmc12",
    e_mac=0.25e-12,
    e_glb_byte=1.2e-12,
    e_noc_hop_byte=0.8e-12,     # <0.1 pJ/bit on-chip
    e_d2d_byte=9.4e-12,         # GRS 1.17 pJ/bit
    e_dram_byte=60e-12,         # GDDR6 ~7.5 pJ/bit
    a_mac=3.0e-4,               # 1024 MACs ~ 0.31 mm^2
    a_glb_kb=1.0e-3,            # 1 MB ~ 1.0 mm^2 (6T SRAM + periphery);
                                # calibrated so S-Arch D2D area share lands
                                # at the paper's "nearly 40%"
    a_core_fixed=0.45,
    a_d2d_fixed=0.20,
    a_d2d_per_gbps=0.012,       # GRS ~25 GB/s interface ~ 0.5 mm^2
    a_io_die_fixed=12.0,
    a_dram_phy_per_gbps=0.04,
    c_silicon_mm2=0.09,
    yield_unit=0.9,
    area_unit_mm2=40.0,
    c_dram_die=3.5,
    dram_die_bw=32.0,
    f_scale=4.0,
    yield_package=0.99,
    c_package_mono_mm2=0.005,
)


@dataclass(frozen=True)
class TPUChip:
    """Roofline constants for one TPU chip (target hardware of the runtime)."""
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12     # FLOP/s
    hbm_bw: float = 819e9               # bytes/s
    hbm_bytes: float = 16e9             # capacity
    ici_bw: float = 50e9                # bytes/s per link
    dci_bw: float = 6.25e9              # bytes/s inter-pod (per host NIC-ish)


TPU_V5E = TPUChip()


# --------------------------------------------------------------------------
# Architecture configuration (paper Table I tuple)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchConfig:
    """One point of the paper's architecture space.

    Printed form follows the paper: (Chiplets, Cores, DRAM_BW, NoC_BW,
    D2D_BW, GLB/Core, MAC/Core).
    """
    x_cores: int
    y_cores: int
    xcut: int = 1
    ycut: int = 1
    noc_bw: float = 32.0          # GB/s per directed NoC link
    d2d_bw: float = 16.0          # GB/s per directed D2D interface
    dram_bw: float = 144.0        # GB/s aggregate
    glb_kb: int = 2048            # per core
    macs_per_core: int = 1024
    freq_ghz: float = 1.0
    n_dram: int = 2               # DRAM ports (one per IO chiplet by default)
    tech: Tech = TECH_12NM

    def __post_init__(self):
        if self.x_cores % self.xcut or self.y_cores % self.ycut:
            raise ValueError(
                f"cut ({self.xcut},{self.ycut}) must divide core grid "
                f"({self.x_cores},{self.y_cores})")
        if self.n_dram < 1:
            raise ValueError("need at least one DRAM port")

    # -- derived ------------------------------------------------------------
    @property
    def n_cores(self) -> int:
        return self.x_cores * self.y_cores

    @property
    def n_chiplets(self) -> int:
        return self.xcut * self.ycut

    @property
    def tops(self) -> float:
        """Peak int8 TOPS (2 ops per MAC)."""
        return self.n_cores * self.macs_per_core * 2 * self.freq_ghz / 1e3

    @property
    def core_glb_bytes(self) -> int:
        return self.glb_kb * 1024

    def label(self) -> str:
        return (f"({self.n_chiplets}, {self.n_cores}, {self.dram_bw:g}GB/s, "
                f"{self.noc_bw:g}GB/s, "
                f"{'None' if self.n_chiplets == 1 else f'{self.d2d_bw:g}GB/s'}, "
                f"{self.glb_kb // 1024}MB, {self.macs_per_core})")

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # -- grid geometry --------------------------------------------------------
    # Router-node grid: columns 0 and x_cores+1 are the west/east IO chiplets,
    # columns 1..x_cores hold the cores.  Node id = y * (x_cores+2) + x.
    @property
    def grid_w(self) -> int:
        return self.x_cores + 2

    @property
    def grid_h(self) -> int:
        return self.y_cores

    def core_node(self, core_id: int) -> int:
        """Router node of a core (cores are row-major over (y, x))."""
        y, x = divmod(core_id, self.x_cores)
        return y * self.grid_w + (x + 1)

    def core_xy(self, core_id: int) -> Tuple[int, int]:
        y, x = divmod(core_id, self.x_cores)
        return x, y

    def dram_node(self, dram_id: int) -> int:
        """Router node of a DRAM port (1-based id; spread over both IO dies)."""
        d = dram_id - 1
        side = d % 2                     # 0 -> west, 1 -> east
        row = (d // 2) * max(1, self.y_cores // max(1, (self.n_dram + 1) // 2))
        row = min(row, self.y_cores - 1)
        x = 0 if side == 0 else self.grid_w - 1
        return row * self.grid_w + x

    @cached_property
    def chiplet_of_core(self) -> Tuple[int, ...]:
        """Chiplet index of every core (row-major chiplet grid)."""
        cw = self.x_cores // self.xcut
        ch = self.y_cores // self.ycut
        out = []
        for cid in range(self.n_cores):
            x, y = self.core_xy(cid)
            out.append((y // ch) * self.xcut + (x // cw))
        return tuple(out)

    def node_chiplet(self, node: int) -> int:
        """Chiplet of a router node: -1 west IO die, -2 east IO die."""
        y, x = divmod(node, self.grid_w)
        if x == 0:
            return -1
        if x == self.grid_w - 1:
            return -2
        cw = self.x_cores // self.xcut
        ch = self.y_cores // self.ycut
        return (y // ch) * self.xcut + ((x - 1) // cw)

    @cached_property
    def d2d_interfaces_per_chiplet(self) -> float:
        """Average number of D2D interfaces per computing chiplet.

        Interfaces sit on both sides of every inter-chiplet boundary link,
        including the IO-die <-> core-array boundary (paper Fig. 2: the IO
        controllers join the same mesh through D2D).
        """
        n_ifaces = 0
        for y in range(self.grid_h):
            for x in range(self.grid_w):
                n = y * self.grid_w + x
                for nx, ny in ((x + 1, y), (x, y + 1)):
                    if nx >= self.grid_w or ny >= self.grid_h:
                        continue
                    m = ny * self.grid_w + nx
                    if self.node_chiplet(n) != self.node_chiplet(m):
                        n_ifaces += 2          # one TX/RX pair on each die
        return n_ifaces / max(1, self.n_chiplets)


# Paper reference architectures --------------------------------------------

def simba_arch() -> ArchConfig:
    """S-Arch: 36 chiplets x 1 core, 72 TOPS (paper Sec. VI-A4)."""
    return ArchConfig(x_cores=6, y_cores=6, xcut=6, ycut=6,
                      noc_bw=16.0, d2d_bw=8.0, dram_bw=144.0,
                      glb_kb=1024, macs_per_core=1024)


def gemini_arch_72t() -> ArchConfig:
    """G-Arch found by the paper's 72-TOPS DSE: (2, 36, 144, 32, 16, 2MB, 1024)."""
    return ArchConfig(x_cores=6, y_cores=6, xcut=2, ycut=1,
                      noc_bw=32.0, d2d_bw=16.0, dram_bw=144.0,
                      glb_kb=2048, macs_per_core=1024)


def tenstorrent_arch() -> ArchConfig:
    """T-Arch: 120-core monolithic Grayskull-like (paper Sec. VI-B2)."""
    return ArchConfig(x_cores=12, y_cores=10, xcut=1, ycut=1,
                      noc_bw=32.0, d2d_bw=32.0, dram_bw=192.0,
                      glb_kb=1024, macs_per_core=512)
