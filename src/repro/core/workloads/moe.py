"""Sparse Mixture-of-Experts blocks in the expected-traffic cube IR.

A routed MoE FFN with ``E`` experts and ``top_k`` active per token is
data-dependent: which expert a token visits is decided at runtime by the
router.  The expected-traffic IR models it exactly in expectation under the
standard uniform-load assumption (what capacity-factor training targets):

* ``router``  — a dense ``d -> E`` gate projection (tiny, always-on);
* each routed expert ``e`` — its up/down projections carry
  ``traffic_scale = top_k / E`` (the expected fraction of tokens it
  processes: MACs, activation DRAM fetches and emitted ofmap all scale),
  while its *weights* stay dense (``weight_traffic_scale = 1.0`` — the full
  expert must be resident/loaded regardless of routing);
* the dispatch edges (block input -> expert, router gates -> expert) carry
  edge multiplicity ``top_k / E`` — the producer is dense but each expert
  only reads its expected share;
* optional shared experts are plain dense FFNs;
* ``combine`` — an eltwise reduction whose ``n_inputs`` is the *expected*
  number of active contributions per token (``top_k`` + shared + residual),
  fed by all ``E`` expert outputs, each arriving pre-scaled through its
  producer's ``traffic_scale``.

Summing over experts, expected routed-FFN MACs equal a dense FFN of width
``top_k * d_ff`` — the legacy ``family="moe-dense"`` approximation in
``lm_graph`` — but the *graph* now exposes the real structure: E thin
parallel branches with dense-resident weights, which is what makes MoE
mappings (expert-parallel core allocation, weight-capacity pressure)
different from a fat dense FFN.
"""

from __future__ import annotations

from ..workload import Graph, Layer


def add_moe_ffn(g: Graph, t: str, src: str, d_model: int, d_ff: int,
                n_experts: int, top_k: int, seq: int,
                n_shared: int = 0, d_shared: int = 0, bpe: int = 2) -> str:
    """Append one routed-MoE FFN block to ``g``; returns the output layer.

    ``src`` is the block input (e.g. the post-attention residual add).
    ``n_shared`` dense shared experts of width ``d_shared or d_ff`` run
    always-on next to the routed ones (DeepSeek/Granite style).  Gated-MLP
    convention: ``up`` produces ``2 * d_ff`` (gate + value), ``down``
    contracts ``d_ff``.
    """
    if not 1 <= top_k <= n_experts:
        raise ValueError(f"top_k={top_k} must be in [1, n_experts={n_experts}]")
    frac = top_k / n_experts
    router = g.add(Layer(name=f"{t}_router", kind="fc", K=n_experts, H=seq,
                         C=d_model, bytes_per_elem=bpe), [src]).name
    combine_in = []
    for e in range(n_experts):
        up = g.add(Layer(name=f"{t}_e{e}_up", kind="fc", K=2 * d_ff, H=seq,
                         C=d_model, bytes_per_elem=bpe, traffic_scale=frac),
                   [(src, frac), (router, frac)]).name
        down = g.add(Layer(name=f"{t}_e{e}_down", kind="fc", K=d_model,
                           H=seq, C=d_ff, bytes_per_elem=bpe,
                           traffic_scale=frac), [up]).name
        combine_in.append(down)
    ds = d_shared or d_ff
    for s in range(n_shared):
        sup = g.add(Layer(name=f"{t}_s{s}_up", kind="fc", K=2 * ds, H=seq,
                          C=d_model, bytes_per_elem=bpe), [src]).name
        sdown = g.add(Layer(name=f"{t}_s{s}_down", kind="fc", K=d_model,
                            H=seq, C=ds, bytes_per_elem=bpe), [sup]).name
        combine_in.append(sdown)
    # expected active inputs per token: top_k routed + shared + residual
    out = g.add(Layer(name=f"{t}_combine", kind="eltwise", K=d_model, H=seq,
                      n_inputs=top_k + n_shared + 1, bytes_per_elem=bpe),
                combine_in + [src]).name
    return out


def moe_transformer(n_layers: int = 2, d_model: int = 512, d_ff: int = 1024,
                    n_experts: int = 8, top_k: int = 2, n_shared: int = 1,
                    seq: int = 512, name: str = "MoE", bpe: int = 2) -> Graph:
    """Transformer encoder stack with a routed-MoE FFN in every block."""
    g = Graph(name)
    prev = None
    for i in range(n_layers):
        t = f"l{i}"
        qkv = g.add(Layer(name=f"{t}_qkv", kind="fc", K=3 * d_model, H=seq,
                          C=d_model, bytes_per_elem=bpe),
                    [prev] if prev else ()).name
        qk = g.add(Layer(name=f"{t}_qk", kind="matmul", K=seq, H=seq,
                         C=d_model, bytes_per_elem=bpe), [qkv]).name
        av = g.add(Layer(name=f"{t}_av", kind="matmul", K=d_model, H=seq,
                         C=seq, bytes_per_elem=bpe), [qk]).name
        o = g.add(Layer(name=f"{t}_o", kind="fc", K=d_model, H=seq,
                        C=d_model, bytes_per_elem=bpe), [av]).name
        a1 = g.add(Layer(name=f"{t}_add1", kind="eltwise", K=d_model, H=seq,
                         n_inputs=2, bytes_per_elem=bpe),
                   [o, prev] if prev else [o]).name
        prev = add_moe_ffn(g, t, a1, d_model, d_ff, n_experts, top_k, seq,
                           n_shared=n_shared, bpe=bpe)
    g.validate()
    return g
