"""CNN workload DAGs: ResNet-50, ResNeXt-50 (32x4d), Inception-ResNet-v1,
PNASNet (representative cell structure).

All for 224x224 (299x299 for IRes) ImageNet inference, int8 feature maps.
PNASNet-5-large's full cell genotype is approximated with its five-branch
separable-conv cell skeleton at matching channel counts — the paper uses it
as a "complex dependency" workload, so dependency structure and op mix are
what matter (noted in DESIGN.md).
"""

from __future__ import annotations

from typing import List, Optional

from ..workload import Graph, Layer


def _conv(g: Graph, name: str, src: Optional[List[str]], K: int, H: int, W: int,
          C: int, R: int = 1, S: int = None, stride: int = 1,
          groups: int = 1) -> str:
    S = R if S is None else S
    g.add(Layer(name=name, kind="conv", K=K, H=H, W=W, C=C, R=R, S=S,
                stride=stride, groups=groups), src or ())
    return name


def _pool(g: Graph, name: str, src: str, K: int, H: int, W: int,
          stride: int = 2) -> str:
    g.add(Layer(name=name, kind="pool", K=K, H=H, W=W, stride=stride), [src])
    return name


def _add(g: Graph, name: str, srcs: List[str], K: int, H: int, W: int) -> str:
    g.add(Layer(name=name, kind="eltwise", K=K, H=H, W=W, n_inputs=len(srcs)),
          srcs)
    return name


def _fc(g: Graph, name: str, src: str, K: int, C: int) -> str:
    g.add(Layer(name=name, kind="fc", K=K, C=C), [src])
    return name


# ---------------------------------------------------------------------------
def _resnet_backbone(name: str, groups: int, width: int) -> Graph:
    """ResNet-50 skeleton; groups=32/width=4 gives ResNeXt-50 (32x4d)."""
    g = Graph(name)
    _conv(g, "conv1", None, 64, 112, 112, 3, R=7, stride=2)
    prev = _pool(g, "pool1", "conv1", 64, 56, 56, stride=2)

    stages = [  # (n_blocks, mid_channels, out_channels, spatial)
        (3, 64 * width // 4 if groups > 1 else 64, 256, 56),
        (4, 128 * width // 4 if groups > 1 else 128, 512, 28),
        (6, 256 * width // 4 if groups > 1 else 256, 1024, 14),
        (3, 512 * width // 4 if groups > 1 else 512, 2048, 7),
    ]
    in_ch = 64
    for si, (n_blocks, mid, out, hw) in enumerate(stages):
        for b in range(n_blocks):
            stride = 2 if (b == 0 and si > 0) else 1
            tag = f"s{si}b{b}"
            c1 = _conv(g, f"{tag}_c1", [prev], mid, hw, hw, in_ch)
            c2 = _conv(g, f"{tag}_c2", [c1], mid, hw, hw, mid, R=3,
                       stride=stride, groups=groups)
            c3 = _conv(g, f"{tag}_c3", [c2], out, hw, hw, mid)
            if b == 0:
                skip = _conv(g, f"{tag}_down", [prev], out, hw, hw, in_ch,
                             stride=stride)
            else:
                skip = prev
            prev = _add(g, f"{tag}_add", [c3, skip], out, hw, hw)
            in_ch = out
    p = _pool(g, "avgpool", prev, 2048, 1, 1, stride=7)
    _fc(g, "fc", p, 1000, 2048)
    g.validate()
    return g


def resnet50() -> Graph:
    return _resnet_backbone("RN-50", groups=1, width=4)


def resnext50() -> Graph:
    return _resnet_backbone("RNX", groups=32, width=8)


# ---------------------------------------------------------------------------
def inception_resnet_v1() -> Graph:
    """Inception-ResNet-v1 (299x299): stem + 5xA + redA + 10xB + redB + 5xC."""
    g = Graph("IRes")
    # stem
    _conv(g, "stem1", None, 32, 149, 149, 3, R=3, stride=2)
    _conv(g, "stem2", ["stem1"], 32, 147, 147, 32, R=3)
    _conv(g, "stem3", ["stem2"], 64, 147, 147, 32, R=3)
    _pool(g, "stem_pool", "stem3", 64, 73, 73, stride=2)
    _conv(g, "stem4", ["stem_pool"], 80, 73, 73, 64)
    _conv(g, "stem5", ["stem4"], 192, 71, 71, 80, R=3)
    prev = _conv(g, "stem6", ["stem5"], 256, 35, 35, 192, R=3, stride=2)

    def block_a(i: int, src: str) -> str:  # 35x35, 256ch
        b0 = _conv(g, f"a{i}_b0", [src], 32, 35, 35, 256)
        b1a = _conv(g, f"a{i}_b1a", [src], 32, 35, 35, 256)
        b1b = _conv(g, f"a{i}_b1b", [b1a], 32, 35, 35, 32, R=3)
        b2a = _conv(g, f"a{i}_b2a", [src], 32, 35, 35, 256)
        b2b = _conv(g, f"a{i}_b2b", [b2a], 32, 35, 35, 32, R=3)
        b2c = _conv(g, f"a{i}_b2c", [b2b], 32, 35, 35, 32, R=3)
        up = _conv(g, f"a{i}_up", [b0, b1b, b2c], 256, 35, 35, 96)
        return _add(g, f"a{i}_add", [up, src], 256, 35, 35)

    for i in range(5):
        prev = block_a(i, prev)

    # reduction A -> 17x17, 896ch
    ra_p = _pool(g, "redA_pool", prev, 256, 17, 17, stride=2)
    ra_c = _conv(g, "redA_c", [prev], 384, 17, 17, 256, R=3, stride=2)
    ra_b1 = _conv(g, "redA_b1a", [prev], 192, 35, 35, 256)
    ra_b2 = _conv(g, "redA_b1b", [ra_b1], 192, 35, 35, 192, R=3)
    ra_b3 = _conv(g, "redA_b1c", [ra_b2], 256, 17, 17, 192, R=3, stride=2)
    prev = _conv(g, "redA_join", [ra_p, ra_c, ra_b3], 896, 17, 17, 896)

    def block_b(i: int, src: str) -> str:  # 17x17, 896ch
        b0 = _conv(g, f"b{i}_b0", [src], 128, 17, 17, 896)
        b1a = _conv(g, f"b{i}_b1a", [src], 128, 17, 17, 896)
        b1b = _conv(g, f"b{i}_b1b", [b1a], 128, 17, 17, 128, R=1, S=7)
        b1c = _conv(g, f"b{i}_b1c", [b1b], 128, 17, 17, 128, R=7, S=1)
        up = _conv(g, f"b{i}_up", [b0, b1c], 896, 17, 17, 256)
        return _add(g, f"b{i}_add", [up, src], 896, 17, 17)

    for i in range(10):
        prev = block_b(i, prev)

    # reduction B -> 8x8, 1792ch
    rb_p = _pool(g, "redB_pool", prev, 896, 8, 8, stride=2)
    rb_1a = _conv(g, "redB_1a", [prev], 256, 17, 17, 896)
    rb_1b = _conv(g, "redB_1b", [rb_1a], 384, 8, 8, 256, R=3, stride=2)
    rb_2a = _conv(g, "redB_2a", [prev], 256, 17, 17, 896)
    rb_2b = _conv(g, "redB_2b", [rb_2a], 256, 8, 8, 256, R=3, stride=2)
    rb_3a = _conv(g, "redB_3a", [prev], 256, 17, 17, 896)
    rb_3b = _conv(g, "redB_3b", [rb_3a], 256, 17, 17, 256, R=3)
    rb_3c = _conv(g, "redB_3c", [rb_3b], 256, 8, 8, 256, R=3, stride=2)
    prev = _conv(g, "redB_join", [rb_p, rb_1b, rb_2b, rb_3c], 1792, 8, 8, 1792)

    def block_c(i: int, src: str) -> str:  # 8x8, 1792ch
        b0 = _conv(g, f"c{i}_b0", [src], 192, 8, 8, 1792)
        b1a = _conv(g, f"c{i}_b1a", [src], 192, 8, 8, 1792)
        b1b = _conv(g, f"c{i}_b1b", [b1a], 192, 8, 8, 192, R=1, S=3)
        b1c = _conv(g, f"c{i}_b1c", [b1b], 192, 8, 8, 192, R=3, S=1)
        up = _conv(g, f"c{i}_up", [b0, b1c], 1792, 8, 8, 384)
        return _add(g, f"c{i}_add", [up, src], 1792, 8, 8)

    for i in range(5):
        prev = block_c(i, prev)

    p = _pool(g, "avgpool", prev, 1792, 1, 1, stride=8)
    _fc(g, "fc", p, 1000, 1792)
    g.validate()
    return g


# ---------------------------------------------------------------------------
def pnasnet(n_cells: int = 9) -> Graph:
    """PNASNet-style five-branch separable-conv cells with skip inputs."""
    g = Graph("PNas")
    _conv(g, "stem", None, 96, 112, 112, 3, R=3, stride=2)
    hw, ch = 56, 270
    prev = _conv(g, "stem_red", ["stem"], ch, hw, hw, 96, R=3, stride=2)
    prev2 = "stem"

    def sep(name: str, src: str, K: int, C: int, H: int, W: int,
            R: int, stride: int = 1) -> str:
        d = g.add(Layer(name=f"{name}_dw", kind="depthwise", K=C, H=H, W=W,
                        R=R, S=R, stride=stride), [src]).name
        return _conv(g, f"{name}_pw", [d], K, H, W, C)

    for cell in range(n_cells):
        red = cell in (n_cells // 3, 2 * n_cells // 3)
        if red:
            hw //= 2
            ch *= 2
        tag = f"cell{cell}"
        s = 2 if red else 1
        # five branches, PNASNet-5 cell op mix (sep5, sep3, sep7, pool, iden)
        b1 = sep(f"{tag}_s5", prev, ch // 5, ch // (2 if red else 1), hw, hw, 5, s)
        b2 = sep(f"{tag}_s3", prev, ch // 5, ch // (2 if red else 1), hw, hw, 3, s)
        b3 = sep(f"{tag}_s7", prev2, ch // 5, g.layers[prev2].K, hw, hw, 7,
                 max(1, (g.layers[prev2].H // hw)))
        b4 = _pool(g, f"{tag}_mp", prev, g.layers[prev].K, hw, hw,
                   stride=max(1, g.layers[prev].H // hw))
        b4 = _conv(g, f"{tag}_mp_pw", [b4], ch // 5, hw, hw, g.layers[prev].K)
        b5 = sep(f"{tag}_s3b", prev, ch - 4 * (ch // 5), ch // (2 if red else 1),
                 hw, hw, 3, s)
        join = _conv(g, f"{tag}_join", [b1, b2, b3, b4, b5], ch, hw, hw, ch)
        prev2, prev = prev, join
    p = _pool(g, "avgpool", prev, ch, 1, 1, stride=hw)
    _fc(g, "fc", p, 1000, ch)
    g.validate()
    return g
