"""Workload DAGs: the paper's five DNNs + the assigned LM architectures."""

from .cnn import inception_resnet_v1, pnasnet, resnet50, resnext50
from .transformer import transformer

PAPER_WORKLOADS = {
    "RN-50": resnet50,
    "RNX": resnext50,
    "IRes": inception_resnet_v1,
    "PNas": pnasnet,
    "TF": transformer,
}

__all__ = ["resnet50", "resnext50", "inception_resnet_v1", "pnasnet",
           "transformer", "PAPER_WORKLOADS"]
