"""Workload DAGs: the paper's five DNNs, the assigned LM architectures, and
the expected-traffic MoE/MLA graphs — plus the by-name registry every CLI
(``launch/realize.py --workload``, ``benchmarks/table1_dse.py``) resolves
specs through.
"""

from __future__ import annotations

from typing import Callable, Dict, Union

from ..workload import Graph
from .cnn import inception_resnet_v1, pnasnet, resnet50, resnext50
from .mla import add_mla_attention, mla_transformer
from .moe import add_moe_ffn, moe_transformer
from .transformer import transformer

PAPER_WORKLOADS = {
    "RN-50": resnet50,
    "RNX": resnext50,
    "IRes": inception_resnet_v1,
    "PNas": pnasnet,
    "TF": transformer,
}

# ---------------------------------------------------------------------------
# By-name registry (presets) + spec grammar
# ---------------------------------------------------------------------------

WORKLOAD_SPECS: Dict[str, Callable[[], Graph]] = {
    # the table1 --quick grid's workload (and the CI realize smoke's)
    "tf-quick": lambda: transformer(n_layers=2, d_model=128, d_ff=256,
                                    seq=64, name="tf-s"),
    # the full Table-I workload
    "tf-paper": lambda: transformer(),
    # routed-MoE encoder stacks (expected-traffic expert branches)
    "moe-quick": lambda: moe_transformer(n_layers=2, d_model=128, d_ff=128,
                                         n_experts=4, top_k=2, n_shared=1,
                                         seq=64, name="moe-s"),
    "moe-paper": lambda: moe_transformer(),
    # multi-head latent attention stacks (low-rank KV compression cubes)
    "mla-quick": lambda: mla_transformer(n_layers=2, d_model=128, n_heads=4,
                                         q_rank=32, kv_rank=16, d_ff=256,
                                         seq=64, name="mla-s"),
    "mla-paper": lambda: mla_transformer(),
}

_GRAMMARS = ("transformer:k=v,...", "moe:k=v,...", "mla:k=v,...",
             "lm:<config>[:seq=S,n_layers=L]")


def _kwargs(rest: str) -> Dict[str, Union[int, str]]:
    kw: Dict[str, Union[int, str]] = {}
    for item in filter(None, rest.split(",")):
        k, _, v = item.partition("=")
        kw[k] = v if k == "name" else int(v)
    return kw


def make_workload(spec: str) -> Graph:
    """Build a workload graph from a by-name preset or a CLI spec.

    Presets are the keys of :data:`WORKLOAD_SPECS`; parameterized specs use
    ``<kind>:k=v,...`` with kinds ``transformer`` / ``moe`` / ``mla``
    (builder kwargs, ints except ``name``) or
    ``lm:<config>[:seq=S,n_layers=L]`` for an assigned LM architecture's
    layer DAG.  Unknown names raise listing what is registered.
    """
    if spec in WORKLOAD_SPECS:
        return WORKLOAD_SPECS[spec]()
    kind, _, rest = spec.partition(":")
    if kind == "transformer" and rest:
        return transformer(**_kwargs(rest))
    if kind == "moe" and rest:
        return moe_transformer(**_kwargs(rest))
    if kind == "mla" and rest:
        kw = _kwargs(rest)
        if "moe_ffn" in kw:
            kw["moe_ffn"] = bool(kw["moe_ffn"])
        return mla_transformer(**kw)
    if kind == "lm" and rest:
        from ...configs import get_config
        from .lm_graph import lm_graph
        name, _, params = rest.partition(":")
        kw2 = {k: int(v) for k, v in
               (item.partition("=")[::2] for item in
                filter(None, params.split(",")))}
        return lm_graph(get_config(name), **kw2)
    raise ValueError(
        f"unknown workload spec {spec!r}; registered presets: "
        f"{', '.join(sorted(WORKLOAD_SPECS))}; or a parameterized spec: "
        f"{'; '.join(_GRAMMARS)}")


__all__ = ["resnet50", "resnext50", "inception_resnet_v1", "pnasnet",
           "transformer", "moe_transformer", "mla_transformer",
           "add_moe_ffn", "add_mla_attention", "PAPER_WORKLOADS",
           "WORKLOAD_SPECS", "make_workload"]
