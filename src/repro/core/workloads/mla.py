"""Multi-head Latent Attention (MLA) blocks in the cube IR.

DeepSeek-style MLA replaces the dense QKV projection with low-rank
compressions: queries project down to ``q_rank`` and back up; keys/values
share ONE compressed ``kv_rank`` cube (the latent KV cache) from which
per-head K and V are re-expanded.  In the cube IR that is a chain of thin
``fc`` layers — the ``kv_down`` cube with ``K = kv_rank`` is the low-rank
KV compression cube whose small ofmap is exactly why MLA shrinks KV traffic
— followed by the usual activation-activation score/context matmuls.

All layers are dense (``traffic_scale = 1.0``): MLA changes the *shape* of
the traffic, not its data-dependence, so it exercises the workload zoo's
coverage of skinny-cube mappings rather than the expected-traffic scales.
Pair with :func:`repro.core.workloads.moe.add_moe_ffn` for a
DeepSeek-shaped block (``moe_ffn=True``).
"""

from __future__ import annotations

from ..workload import Graph, Layer
from .moe import add_moe_ffn


def add_mla_attention(g: Graph, t: str, src: str, d_model: int,
                      n_heads: int, q_rank: int, kv_rank: int, seq: int,
                      head_dim: int = 0, bpe: int = 2) -> str:
    """Append one MLA attention block (+ residual add); returns its output."""
    hd = head_dim or max(1, d_model // n_heads)
    dh = n_heads * hd
    inputs = [src] if src else ()
    qd = g.add(Layer(name=f"{t}_qdown", kind="fc", K=q_rank, H=seq,
                     C=d_model, bytes_per_elem=bpe), inputs).name
    qu = g.add(Layer(name=f"{t}_qup", kind="fc", K=dh, H=seq, C=q_rank,
                     bytes_per_elem=bpe), [qd]).name
    # the latent KV cube: one shared low-rank compression for K and V
    kvd = g.add(Layer(name=f"{t}_kvdown", kind="fc", K=kv_rank, H=seq,
                      C=d_model, bytes_per_elem=bpe), inputs).name
    ku = g.add(Layer(name=f"{t}_kup", kind="fc", K=dh, H=seq, C=kv_rank,
                     bytes_per_elem=bpe), [kvd]).name
    vu = g.add(Layer(name=f"{t}_vup", kind="fc", K=dh, H=seq, C=kv_rank,
                     bytes_per_elem=bpe), [kvd]).name
    qk = g.add(Layer(name=f"{t}_qk", kind="matmul", K=seq, H=seq, C=dh,
                     bytes_per_elem=bpe), [qu, ku]).name
    av = g.add(Layer(name=f"{t}_av", kind="matmul", K=dh, H=seq, C=seq,
                     bytes_per_elem=bpe), [qk, vu]).name
    o = g.add(Layer(name=f"{t}_o", kind="fc", K=d_model, H=seq, C=dh,
                    bytes_per_elem=bpe), [av]).name
    out = g.add(Layer(name=f"{t}_add1", kind="eltwise", K=d_model, H=seq,
                      n_inputs=2, bytes_per_elem=bpe),
                [o, src] if src else [o]).name
    return out


def mla_transformer(n_layers: int = 2, d_model: int = 512, n_heads: int = 8,
                    q_rank: int = 0, kv_rank: int = 0, d_ff: int = 1024,
                    seq: int = 512, name: str = "MLA", bpe: int = 2,
                    moe_ffn: bool = False, n_experts: int = 8,
                    top_k: int = 2) -> Graph:
    """MLA transformer stack; ``moe_ffn=True`` makes it DeepSeek-shaped
    (MLA attention + routed-MoE FFN).  Default ranks follow the published
    proportions: ``q_rank ~ d/4``, ``kv_rank ~ d/8``.
    """
    q_rank = q_rank or max(1, d_model // 4)
    kv_rank = kv_rank or max(1, d_model // 8)
    g = Graph(name)
    prev = None
    for i in range(n_layers):
        t = f"l{i}"
        a1 = add_mla_attention(g, t, prev, d_model, n_heads, q_rank,
                               kv_rank, seq, bpe=bpe)
        if moe_ffn:
            prev = add_moe_ffn(g, t, a1, d_model, d_ff, n_experts, top_k,
                               seq, n_shared=1, bpe=bpe)
        else:
            up = g.add(Layer(name=f"{t}_up", kind="fc", K=2 * d_ff, H=seq,
                             C=d_model, bytes_per_elem=bpe), [a1]).name
            down = g.add(Layer(name=f"{t}_down", kind="fc", K=d_model,
                               H=seq, C=d_ff, bytes_per_elem=bpe), [up]).name
            prev = g.add(Layer(name=f"{t}_add2", kind="eltwise", K=d_model,
                               H=seq, n_inputs=2, bytes_per_elem=bpe),
                         [down, a1]).name
    g.validate()
    return g
