"""Transformer workload (the paper's default DSE workload, [Vaswani'17]).

Encoder stack at inference, int8 feature maps.  Attention score/context
matmuls are activation-activation ``matmul`` layers; projections and FFN are
``fc`` layers with H = sequence length.
"""

from __future__ import annotations

from ..workload import Graph, Layer


def transformer(n_layers: int = 6, d_model: int = 512, d_ff: int = 2048,
                seq: int = 512, name: str = "TF") -> Graph:
    g = Graph(name)
    prev = None
    for i in range(n_layers):
        t = f"l{i}"
        inputs = [prev] if prev else None
        q = g.add(Layer(name=f"{t}_q", kind="fc", K=d_model, H=seq, C=d_model),
                  inputs or ()).name
        k = g.add(Layer(name=f"{t}_k", kind="fc", K=d_model, H=seq, C=d_model),
                  [prev] if prev else ()).name
        v = g.add(Layer(name=f"{t}_v", kind="fc", K=d_model, H=seq, C=d_model),
                  [prev] if prev else ()).name
        # scores = Q K^T : ofmap (seq x seq), contraction over d_model
        s = g.add(Layer(name=f"{t}_qk", kind="matmul", K=seq, H=seq,
                        C=d_model), [q, k]).name
        # context = scores V : ofmap (seq x d_model), contraction over seq
        c = g.add(Layer(name=f"{t}_av", kind="matmul", K=d_model, H=seq,
                        C=seq), [s, v]).name
        o = g.add(Layer(name=f"{t}_o", kind="fc", K=d_model, H=seq,
                        C=d_model), [c]).name
        a1 = g.add(Layer(name=f"{t}_add1", kind="eltwise", K=d_model, H=seq,
                         n_inputs=2), [o, prev] if prev else [o]).name
        f1 = g.add(Layer(name=f"{t}_ff1", kind="fc", K=d_ff, H=seq,
                         C=d_model), [a1]).name
        f2 = g.add(Layer(name=f"{t}_ff2", kind="fc", K=d_model, H=seq,
                         C=d_ff), [f1]).name
        prev = g.add(Layer(name=f"{t}_add2", kind="eltwise", K=d_model, H=seq,
                           n_inputs=2), [f2, a1]).name
    g.validate()
    return g
