"""Export the assigned LM architectures into the Gemini mapping IR.

Each transformer block becomes fc/matmul/eltwise layers with H = sequence
length (the paper's Transformer treatment, Sec. VI-A); Mamba2 blocks map to
in/out projections plus an SSD mixing layer whose contraction dim
approximates the SSD arithmetic (2*d_state state I/O + chunk-local quadratic
— exact MAC counts within a few %, noted here as the one approximation).
bf16 serving feature maps (bytes_per_elem=2).

MoE blocks (``family="moe"``) export the *real* routed structure via
:func:`repro.core.workloads.moe.add_moe_ffn`: a router, ``n_experts``
expert branches carrying ``traffic_scale = top_k / n_experts``, optional
shared experts, and an expected-active-width combine.  The historical
approximation — one dense FFN of the active width ``top_k * d_ff`` — is
kept reachable as the explicit legacy spec ``family="moe-dense"``
(``dataclasses.replace(cfg, family="moe-dense")``): it matches the routed
graph's *expected* FFN MACs by construction but hides the E-way branch
structure and the dense-resident expert weights, so it under-counts weight
capacity/traffic by ``n_experts / top_k``.  Kept only for A/B tests and
old-result reproduction; see ``tests/test_expected_traffic.py`` for the
regression pinning the two graphs' relative totals.
"""

from __future__ import annotations

from ...configs.base import ModelConfig
from ..workload import Graph, Layer
from .moe import add_moe_ffn


def _fc(g, name, src, K, C, seq, bpe=2):
    g.add(Layer(name=name, kind="fc", K=K, H=seq, C=C, bytes_per_elem=bpe),
          [src] if src else ())
    return name


def lm_graph(cfg: ModelConfig, seq: int = 4096, n_layers: int = 0) -> Graph:
    """Layer DAG of one LM architecture (optionally truncated depth)."""
    L = n_layers or cfg.n_layers
    g = Graph(cfg.name)
    d = cfg.d_model
    prev = None
    for i in range(L):
        t = f"l{i}"
        if cfg.family in ("ssm", "hybrid"):
            d_in = cfg.ssm_expand * d
            gn = 2 * cfg.ssm_groups * cfg.ssm_state
            nh = d_in // cfg.ssm_headdim
            inp = _fc(g, f"{t}_in", prev, 2 * d_in + gn + nh, d, seq)
            c_eff = 2 * cfg.ssm_state + cfg.ssm_chunk
            g.add(Layer(name=f"{t}_ssd", kind="matmul", K=d_in, H=seq,
                        C=c_eff, bytes_per_elem=2), [inp])
            out = _fc(g, f"{t}_out", f"{t}_ssd", d, d_in, seq)
            prev = g.add(Layer(name=f"{t}_add", kind="eltwise", K=d, H=seq,
                               n_inputs=2, bytes_per_elem=2),
                         [out, prev] if prev else [out]).name
            is_attn = (cfg.family == "hybrid" and cfg.attn_every
                       and i % cfg.attn_every == 0)
            if not is_attn:
                continue
        # attention block (dense/moe/hybrid-shared)
        hd = cfg.hd
        qkv = _fc(g, f"{t}_qkv", prev, (cfg.n_heads + 2 * cfg.n_kv) * hd,
                  d, seq)
        g.add(Layer(name=f"{t}_qk", kind="matmul", K=seq, H=seq,
                    C=cfg.n_heads * hd, bytes_per_elem=2), [qkv])
        g.add(Layer(name=f"{t}_av", kind="matmul", K=cfg.n_heads * hd, H=seq,
                    C=seq, bytes_per_elem=2), [f"{t}_qk"])
        o = _fc(g, f"{t}_o", f"{t}_av", d, cfg.n_heads * hd, seq)
        a1 = g.add(Layer(name=f"{t}_add1", kind="eltwise", K=d, H=seq,
                         n_inputs=2, bytes_per_elem=2),
                   [o, prev] if prev else [o]).name
        if cfg.family == "moe":
            # real routed MoE: expected-traffic expert branches
            prev = add_moe_ffn(g, t, a1, d, cfg.d_ff, cfg.n_experts,
                               cfg.top_k, seq,
                               n_shared=getattr(cfg, "n_shared_experts", 0))
            continue
        # legacy "moe-dense": collapse routing into one dense FFN of the
        # active width (see module docstring)
        ff = (cfg.top_k * cfg.d_ff) if cfg.family == "moe-dense" else cfg.d_ff
        if ff:
            up = _fc(g, f"{t}_up", a1, 2 * ff, d, seq)
            down = _fc(g, f"{t}_down", up, d, ff, seq)
            prev = g.add(Layer(name=f"{t}_add2", kind="eltwise", K=d, H=seq,
                               n_inputs=2, bytes_per_elem=2),
                         [down, a1]).name
        else:
            prev = a1
    g.validate()
    return g
