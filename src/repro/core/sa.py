"""Simulated-Annealing LP-SPM exploration engine (paper Sec. V-B1).

Five operators, verbatim from the paper:
  OP1  re-factor one layer's Part (product preserved, dim caps respected)
  OP2  swap two cores inside one layer's CG (reorders the Correspondence Rule)
  OP3  swap one core of layer A with one core of layer B
  OP4  move a core from layer A's CG to layer B's CG, re-factor both Parts
  OP5  re-point one explicit FD entry to a random DRAM (0 = interleaved)

The controller picks a layer group with probability proportional to its
optimization-space size (log-domain to avoid overflow), then an applicable
operator uniformly.  Acceptance is Metropolis with geometric cooling.  Only
the touched group is re-evaluated per iteration (the others' costs are
cached), which is what makes large DSEs feasible on one CPU core.

Extension over the paper (noted in DESIGN.md): OP4 may also move a core
to/from the idle pool, so mappings that deliberately leave cores unused are
reachable even though the stripe initialization uses every core.
"""

from __future__ import annotations

import math
import warnings
from dataclasses import dataclass, field, replace
from functools import lru_cache
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .. import obs as _obs
from .encoding import (LMS, MS, factor_parts, space_size_lower_bound)
from .evaluator import CachedEvaluator, Evaluator, GroupEval
from .hw import ArchConfig
from .tangram import tangram_map
from .workload import Graph, LayerGroup

Mapping = List[Tuple[LayerGroup, LMS]]


@dataclass
class SAConfig:
    iters: int = 6000
    t0: float = 0.01              # initial temperature, relative to cost
    t_end: float = 1e-5
    seed: int = 0
    beta: float = 1.0             # energy exponent in the objective
    gamma: float = 1.0            # delay exponent
    n_chains: int = 1             # >1 = replica exchange (core/explore.py)
    log_every: int = 0            # 0 = silent
    # replica-exchange knobs (used only when n_chains > 1).  Defaults set
    # by the `misc_bench --retune` sweep over the quick Table-I grid:
    # (2.0, 25) holds ~24% per-pair swap acceptance — inside the healthy
    # 20-40% tempering band — with 2x the exchange events of the old
    # conservative (3.0, 50) at equal-or-better geomean cost.
    swap_every: int = 25          # iterations between adjacent-chain swaps
    t_ladder: float = 2.0         # temperature ratio between adjacent chains
    # n_chains > 1 only: step all chains in lockstep, evaluating the
    # iteration's proposals through one vectorized batch per touched layer
    # group.  Trajectories are bit-identical either way (per-chain RNG
    # streams are consumed in the same order and the batched evaluator is
    # bit-identical to the scalar one) — False keeps the serial per-chain
    # loop for A/B tests and benchmarks.
    lockstep: bool = True
    # "numpy" (default) = exact engine, trajectories bit-identical between
    # lockstep and serial stepping.  "jax" = the fused jitted
    # construct->replay->eval pass for lockstep proposal scoring: float32
    # parity-grade (~1e-4), so trajectories may diverge from the exact
    # engine's — but every chain's BEST mapping is still re-scored by the
    # exact engine in finalize(), so reported costs are always exact
    # (the rescore-winners contract, DESIGN.md).
    backend: str = "numpy"


@dataclass
class SAResult:
    mapping: Mapping
    cost: float
    energy_j: float
    delay_s: float
    history: List[float] = field(default_factory=list)
    accepted: int = 0
    proposed: int = 0
    # replica-exchange diagnostics (n_chains > 1): attempted / executed
    # state swaps per adjacent ladder pair, index k = (ladder chain k,
    # k+1).  Healthy tempering targets ~20-40% acceptance per pair.
    swap_attempts: List[int] = field(default_factory=list)
    swap_accepts: List[int] = field(default_factory=list)

    def swap_rates(self) -> List[float]:
        return [a / t for a, t in zip(self.swap_accepts, self.swap_attempts)
                if t > 0]


def _group_weights(group_sizes: Sequence[int], n_cores: int) -> np.ndarray:
    logs = []
    for n in group_sizes:
        try:
            # log of the paper's lower bound, via lgamma to stay in float
            from math import comb, lgamma
            s = 0
            for i in range(n):
                s += comb(n, i) * comb(max(0, n_cores - n - 1), n - i - 1) \
                    * 4 ** (n - i)
            logs.append(lgamma(n_cores + 1) + math.log(max(s, 1)))
        except (OverflowError, ValueError):
            logs.append(float(n_cores))
    w = np.array(logs)
    w = np.maximum(w, 1e-6)
    return w / w.sum()


class _Op:
    """Applies one operator to (a copy of) a group LMS.  Returns None if N/A."""

    def __init__(self, g: Graph, arch: ArchConfig, rng: np.random.Generator):
        self.g = g
        self.arch = arch
        self.rng = rng

    def _dims(self, name: str, grp: LayerGroup) -> Tuple[int, int, int, int]:
        l = self.g.layers[name]
        return (l.H, l.W, grp.batch_unit, l.K)

    def _pick(self, seq):
        # index draw: rng.choice() converts the sequence to an ndarray on
        # every call, which dominates proposal cost in tight SA loops
        return seq[int(self.rng.integers(len(seq)))]

    def _pick2(self, n: int) -> Tuple[int, int]:
        """Two distinct indices in [0, n), uniform over ordered pairs."""
        i = int(self.rng.integers(n))
        j = int(self.rng.integers(n - 1))
        return i, j + (j >= i)

    def op1(self, grp: LayerGroup, lms: LMS) -> Optional[LMS]:
        name = self._pick(grp.names)
        ms = lms.ms[name]
        try:
            part = factor_parts(ms.nc, self._dims(name, grp), self.rng)
        except ValueError:
            return None
        if part == ms.part:
            return None
        new = dict(lms.ms)
        new[name] = replace(ms, part=part)
        return LMS(ms=new)

    def op2(self, grp: LayerGroup, lms: LMS) -> Optional[LMS]:
        cands = [n for n in grp.names if lms.ms[n].nc >= 2]
        if not cands:
            return None
        name = self._pick(cands)
        ms = lms.ms[name]
        i, j = self._pick2(ms.nc)
        cg = list(ms.cg)
        cg[i], cg[j] = cg[j], cg[i]
        new = dict(lms.ms)
        new[name] = replace(ms, cg=tuple(cg))
        return LMS(ms=new)

    def op3(self, grp: LayerGroup, lms: LMS) -> Optional[LMS]:
        if len(grp.names) < 2:
            return None
        a, b = self._pick2(len(grp.names))
        na, nb = grp.names[a], grp.names[b]
        ma, mb = lms.ms[na], lms.ms[nb]
        ia = int(self.rng.integers(ma.nc))
        ib = int(self.rng.integers(mb.nc))
        cga, cgb = list(ma.cg), list(mb.cg)
        cga[ia], cgb[ib] = cgb[ib], cga[ia]
        new = dict(lms.ms)
        new[na] = replace(ma, cg=tuple(cga))
        new[nb] = replace(mb, cg=tuple(cgb))
        return LMS(ms=new)

    def op4(self, grp: LayerGroup, lms: LMS,
            idle: Sequence[int]) -> Optional[Tuple[LMS, List[int]]]:
        """Move a core between layers (or to/from the idle pool).  Pure:
        returns (new_lms, new_idle) without mutating the inputs."""
        names = list(grp.names)
        new_idle = list(idle)
        donors = [n for n in names if lms.ms[n].nc >= 2]
        use_idle_donor = bool(new_idle) and self.rng.random() < 0.25
        if not donors and not use_idle_donor:
            return None
        new = dict(lms.ms)
        if use_idle_donor:
            core = new_idle.pop(int(self.rng.integers(len(new_idle))))
            donor = None
        else:
            donor = self._pick(donors)
            md = new[donor]
            di = int(self.rng.integers(md.nc))
            core = md.cg[di]
            cgd = md.cg[:di] + md.cg[di + 1:]
            try:
                pd = factor_parts(len(cgd), self._dims(donor, grp), self.rng)
            except ValueError:
                return None
            new[donor] = MS(part=pd, cg=cgd, fd=md.fd)
        # receiver: another layer, or (rarely) the idle pool
        recv_idle = donor is not None and self.rng.random() < 0.10
        recv_cands = [n for n in names if n != donor]
        if recv_idle or not recv_cands:
            if donor is None:
                return None              # idle -> idle is a no-op
            new_idle.append(core)
        else:
            recv = self._pick(recv_cands)
            mr = new[recv]
            pos = int(self.rng.integers(mr.nc + 1))
            cgr = mr.cg[:pos] + (core,) + mr.cg[pos:]
            try:
                pr = factor_parts(len(cgr), self._dims(recv, grp), self.rng)
            except ValueError:
                return None
            new[recv] = MS(part=pr, cg=cgr, fd=mr.fd)
        return LMS(ms=new), new_idle

    def op5(self, grp: LayerGroup, lms: LMS) -> Optional[LMS]:
        cands = [(n, i) for n in grp.names
                 for i, v in enumerate(lms.ms[n].fd) if v >= 0]
        if not cands:
            return None
        name, i = cands[int(self.rng.integers(len(cands)))]
        ms = lms.ms[name]
        v = int(self.rng.integers(0, self.arch.n_dram + 1))
        if v == ms.fd[i]:
            return None
        fd = list(ms.fd)
        fd[i] = v
        new = dict(lms.ms)
        new[name] = replace(ms, fd=tuple(fd))
        return LMS(ms=new)


@lru_cache(maxsize=4096)
def _group_cdf_cached(group_sizes: Tuple[int, ...], n_cores: int) -> np.ndarray:
    """One CDF per (group-size vector, core count), computed once per
    process.  ``_group_weights`` reads nothing but each group's layer
    count, so every chain, every candidate of a sweep and every re-anneal
    over the same (graph partition, arch) shares this array instead of
    re-deriving the log-space weights per ``sa_optimize`` call.  The array
    is shared read-only (chains only ``searchsorted`` it)."""
    cum_w = np.cumsum(_group_weights(group_sizes, n_cores))
    cum_w[-1] = 1.0
    cum_w.setflags(write=False)
    return cum_w


def group_draw_cdf(groups: Sequence[LayerGroup], n_cores: int) -> np.ndarray:
    """Cumulative group-pick distribution shared by all chains of one run.

    Inverse-CDF group draw: ``rng.choice(..., p=weights)`` re-normalizes and
    allocates on every call, so chains draw via ``np.searchsorted`` instead.
    Cached per (group sizes, n_cores) — the only inputs the weights read.
    """
    return _group_cdf_cached(tuple(len(grp.names) for grp in groups),
                             n_cores)


class SAChain:
    """One Metropolis chain over the LP-SPM space, advanced one iteration at
    a time so an orchestrator (``core/explore.py``) can interleave chains and
    exchange their states (parallel tempering).

    ``step()`` consumes RNG draws in exactly the order of the original
    monolithic loop (group pick, operator pick, operator-internal draws,
    acceptance draw), so a single chain's trajectory for a given seed is
    unchanged by this refactor.
    """

    def __init__(self, g: Graph, arch: ArchConfig, groups: Sequence[LayerGroup],
                 total_batch: int, cfg: SAConfig, init: Optional[Mapping],
                 ev: Evaluator, seed: int, cum_w: np.ndarray,
                 t_scale: float = 1.0):
        self.cfg = cfg
        self.ev = ev
        self.total_batch = total_batch
        self.rng = np.random.default_rng(seed)
        self.mapping: Mapping = [
            (grp, lms) for grp, lms in
            (init if init is not None else tangram_map(groups, g, arch))]
        # idle cores per group
        self.idle: List[List[int]] = []
        for grp, lms in self.mapping:
            used = set(lms.cores_used())
            self.idle.append([c for c in range(arch.n_cores) if c not in used])
        self.evals: List[GroupEval] = []
        for grp, lms in self.mapping:
            ge, _ = ev.eval_group(grp, lms, total_batch)
            self.evals.append(ge)
        self.E = sum(e.energy_j for e in self.evals)
        self.D = sum(e.delay_s for e in self.evals)
        self.cost = (self.E ** cfg.beta) * (self.D ** cfg.gamma)
        self.best_cost = self.cost
        self.best_map: Mapping = list(self.mapping)
        self.cum_w = cum_w
        self.ops = _Op(g, arch, self.rng)
        self.T = cfg.t0 * self.cost * t_scale
        self.alpha = (cfg.t_end / cfg.t0) ** (1.0 / max(1, cfg.iters))
        self.accepted = 0
        self.proposed = 0

    def propose(self) -> Optional[Tuple[int, LayerGroup, LMS,
                                        Optional[List[int]]]]:
        """Draw one proposal and apply cooling — the head of the original
        monolithic ``step()``, consuming RNG draws in exactly its order
        (group pick, operator pick, operator-internal draws).  Returns
        ``None`` when the drawn operator is inapplicable, else
        ``(gi, grp, cand, new_idle)`` for :meth:`accept`."""
        rng, ops = self.rng, self.ops
        gi = int(np.searchsorted(self.cum_w, rng.random(), side="right"))
        grp, lms = self.mapping[gi]
        op = int(rng.integers(1, 6))
        new_idle: Optional[List[int]] = None
        if op == 1:
            cand = ops.op1(grp, lms)
        elif op == 2:
            cand = ops.op2(grp, lms)
        elif op == 3:
            cand = ops.op3(grp, lms)
        elif op == 4:
            r4 = ops.op4(grp, lms, self.idle[gi])
            cand, new_idle = r4 if r4 is not None else (None, None)
        else:
            cand = ops.op5(grp, lms)
        self.T *= self.alpha
        if cand is None:
            return None
        self.proposed += 1
        return gi, grp, cand, new_idle

    def accept(self, gi: int, grp: LayerGroup, cand: LMS,
               new_idle: Optional[List[int]], ge: GroupEval) -> None:
        """Metropolis acceptance of an evaluated proposal — the tail of the
        original ``step()`` (the acceptance draw is this chain's next RNG
        use after the proposal draws, evaluation consumes none)."""
        cfg, rng = self.cfg, self.rng
        old = self.evals[gi]
        newE = self.E - old.energy_j + ge.energy_j
        newD = self.D - old.delay_s + ge.delay_s
        new_cost = (newE ** cfg.beta) * (newD ** cfg.gamma)
        if new_cost <= self.cost or rng.random() < math.exp(
                min(0.0, -(new_cost - self.cost) / max(self.T, 1e-30))):
            self.mapping[gi] = (grp, cand)
            self.evals[gi] = ge
            if new_idle is not None:
                self.idle[gi] = new_idle
            self.cost, self.E, self.D = new_cost, newE, newD
            self.accepted += 1
            self._track_best()

    def step(self) -> None:
        """One proposal + cooling step (Metropolis acceptance)."""
        prop = self.propose()
        if prop is None:
            return
        gi, grp, cand, new_idle = prop
        ge, _ = self.ev.eval_group(grp, cand, self.total_batch)
        self.accept(gi, grp, cand, new_idle, ge)

    def _track_best(self) -> None:
        if self.cost < self.best_cost:
            self.best_cost = self.cost
            self.best_map = list(self.mapping)

    def exchange_state(self, other: "SAChain") -> None:
        """Swap the *configurations* of two chains (replica exchange).

        Temperatures, RNG streams and per-chain bests stay put — only the
        walker (mapping, idle pools, incremental cost terms) moves between
        temperature rungs.  Both chains re-check their best afterwards so a
        state arriving from a hotter rung is never lost.
        """
        for attr in ("mapping", "idle", "evals", "cost", "E", "D"):
            mine, theirs = getattr(self, attr), getattr(other, attr)
            setattr(self, attr, theirs)
            setattr(other, attr, mine)
        self._track_best()
        other._track_best()

    def finalize(self, history: List[float]) -> SAResult:
        """Exact re-evaluation of the best mapping found by this chain."""
        final = self.ev.evaluate(self.best_map, self.total_batch)
        return SAResult(mapping=self.best_map,
                        cost=final.cost(self.cfg.beta, self.cfg.gamma),
                        energy_j=final.energy_j, delay_s=final.delay_s,
                        history=history, accepted=self.accepted,
                        proposed=self.proposed)


def step_chains_lockstep(chains: Sequence[SAChain],
                         backend: str = "numpy") -> None:
    """Advance every chain one iteration with ONE batched evaluation.

    Phase 1 draws each chain's proposal with its own RNG (same per-chain
    draw order as serial ``step()``).  Phase 2 evaluates all drawn
    candidates through the shared evaluator's batch path — deduplicated
    and grouped by the touched layer group, one vectorized analyzer replay
    per group.  Phase 3 runs the Metropolis acceptances in chain order,
    each consuming only its own chain's RNG.  Because evaluation consumes
    no randomness and the batched evaluator is bit-identical to the scalar
    one, every chain's trajectory equals the serial per-chain loop's.

    ``backend="jax"`` scores the iteration's proposals through the fused
    jitted construct->replay->eval pass instead: parity-grade float32
    objectives (trajectories may diverge from the exact engine's), with
    each chain's best re-scored exactly at finalize().
    """
    props = [ch.propose() for ch in chains]
    live = [(i, p) for i, p in enumerate(props) if p is not None]
    if not live:
        return
    ev = chains[0].ev
    total_batch = chains[0].total_batch
    results = ev.eval_groups_batched(
        [(p[1], p[2]) for _, p in live], total_batch, backend=backend)
    for (i, (gi, grp, cand, new_idle)), (ge, _) in zip(live, results):
        chains[i].accept(gi, grp, cand, new_idle, ge)


def sa_optimize(g: Graph, arch: ArchConfig, groups: Sequence[LayerGroup],
                total_batch: int, cfg: SAConfig,
                init: Optional[Mapping] = None,
                evaluator: Optional[Evaluator] = None) -> SAResult:
    """Run the SA engine; returns the best mapping found.

    ``n_chains == 1`` runs the classic single chain.  ``n_chains > 1`` runs
    replica-exchange SA (parallel tempering) over a temperature ladder with
    one shared content-addressed evaluator cache — see
    :func:`repro.core.explore.replica_exchange_sa`.

    ``n_chains == 2`` is a degenerate ladder: chain 0 is the unswapped
    reference, leaving a one-chain ladder with nothing to exchange with —
    two independent seeds plus elitism, not tempering.  Asking for 2 warns
    and runs the documented minimum useful ladder (3) instead.
    """
    if cfg.n_chains <= 1:
        return _sa_chain(g, arch, groups, total_batch, cfg, init, evaluator)
    if cfg.n_chains == 2:
        warnings.warn(
            "SAConfig(n_chains=2) degenerates to independent seeds + "
            "elitism (chain 0 is the unswapped reference, so the tempering "
            "ladder has one chain and no swaps can occur); running "
            "n_chains=3, the minimum useful ladder",
            RuntimeWarning, stacklevel=2)
        cfg = replace(cfg, n_chains=3)
    from .explore import replica_exchange_sa   # lazy: avoids import cycle
    return replica_exchange_sa(g, arch, groups, total_batch, cfg,
                               init=init, evaluator=evaluator)


def _sa_chain(g: Graph, arch: ArchConfig, groups: Sequence[LayerGroup],
              total_batch: int, cfg: SAConfig, init: Optional[Mapping],
              evaluator: Optional[Evaluator]) -> SAResult:
    # content-addressed GroupEval cache: re-proposals, repeated chains and
    # the final exact re-evaluation hit it; results are identical either way
    ev = evaluator or CachedEvaluator(arch, g)
    chain = SAChain(g, arch, groups, total_batch, cfg, init, ev,
                    seed=cfg.seed, cum_w=group_draw_cdf(groups, arch.n_cores))
    history: List[float] = []
    for it in range(cfg.iters):
        chain.step()
        # unconditional: history length depends only on iters/log_every,
        # not on how many proposals happened to be applicable
        if cfg.log_every and it % cfg.log_every == 0:
            history.append(chain.cost)
    res = chain.finalize(history)
    if _obs.enabled():                     # once per SA run, post-result
        _obs.metrics.counter("sa.runs").inc()
        _obs.metrics.counter("sa.proposed").inc(res.proposed)
        _obs.metrics.counter("sa.accepted").inc(res.accepted)
        if res.proposed:
            _obs.metrics.histogram("sa.acceptance_rate").observe(
                res.accepted / res.proposed)
    return res
