"""Simulated-Annealing LP-SPM exploration engine (paper Sec. V-B1).

Five operators, verbatim from the paper:
  OP1  re-factor one layer's Part (product preserved, dim caps respected)
  OP2  swap two cores inside one layer's CG (reorders the Correspondence Rule)
  OP3  swap one core of layer A with one core of layer B
  OP4  move a core from layer A's CG to layer B's CG, re-factor both Parts
  OP5  re-point one explicit FD entry to a random DRAM (0 = interleaved)

The controller picks a layer group with probability proportional to its
optimization-space size (log-domain to avoid overflow), then an applicable
operator uniformly.  Acceptance is Metropolis with geometric cooling.  Only
the touched group is re-evaluated per iteration (the others' costs are
cached), which is what makes large DSEs feasible on one CPU core.

Extension over the paper (noted in DESIGN.md): OP4 may also move a core
to/from the idle pool, so mappings that deliberately leave cores unused are
reachable even though the stripe initialization uses every core.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .encoding import (LMS, MS, factor_parts, space_size_lower_bound)
from .evaluator import CachedEvaluator, Evaluator, GroupEval
from .hw import ArchConfig
from .tangram import tangram_map
from .workload import Graph, LayerGroup

Mapping = List[Tuple[LayerGroup, LMS]]


@dataclass
class SAConfig:
    iters: int = 6000
    t0: float = 0.01              # initial temperature, relative to cost
    t_end: float = 1e-5
    seed: int = 0
    beta: float = 1.0             # energy exponent in the objective
    gamma: float = 1.0            # delay exponent
    n_chains: int = 1
    log_every: int = 0            # 0 = silent


@dataclass
class SAResult:
    mapping: Mapping
    cost: float
    energy_j: float
    delay_s: float
    history: List[float] = field(default_factory=list)
    accepted: int = 0
    proposed: int = 0


def _group_weights(groups: Sequence[LayerGroup], n_cores: int) -> np.ndarray:
    logs = []
    for grp in groups:
        n = len(grp.names)
        try:
            # log of the paper's lower bound, via lgamma to stay in float
            total = 0.0
            from math import comb, lgamma
            s = 0
            for i in range(n):
                s += comb(n, i) * comb(max(0, n_cores - n - 1), n - i - 1) \
                    * 4 ** (n - i)
            logs.append(lgamma(n_cores + 1) + math.log(max(s, 1)))
        except (OverflowError, ValueError):
            logs.append(float(n_cores))
    w = np.array(logs)
    w = np.maximum(w, 1e-6)
    return w / w.sum()


class _Op:
    """Applies one operator to (a copy of) a group LMS.  Returns None if N/A."""

    def __init__(self, g: Graph, arch: ArchConfig, rng: np.random.Generator):
        self.g = g
        self.arch = arch
        self.rng = rng

    def _dims(self, name: str, grp: LayerGroup) -> Tuple[int, int, int, int]:
        l = self.g.layers[name]
        return (l.H, l.W, grp.batch_unit, l.K)

    def _pick(self, seq):
        # index draw: rng.choice() converts the sequence to an ndarray on
        # every call, which dominates proposal cost in tight SA loops
        return seq[int(self.rng.integers(len(seq)))]

    def _pick2(self, n: int) -> Tuple[int, int]:
        """Two distinct indices in [0, n), uniform over ordered pairs."""
        i = int(self.rng.integers(n))
        j = int(self.rng.integers(n - 1))
        return i, j + (j >= i)

    def op1(self, grp: LayerGroup, lms: LMS) -> Optional[LMS]:
        name = self._pick(grp.names)
        ms = lms.ms[name]
        try:
            part = factor_parts(ms.nc, self._dims(name, grp), self.rng)
        except ValueError:
            return None
        if part == ms.part:
            return None
        new = dict(lms.ms)
        new[name] = replace(ms, part=part)
        return LMS(ms=new)

    def op2(self, grp: LayerGroup, lms: LMS) -> Optional[LMS]:
        cands = [n for n in grp.names if lms.ms[n].nc >= 2]
        if not cands:
            return None
        name = self._pick(cands)
        ms = lms.ms[name]
        i, j = self._pick2(ms.nc)
        cg = list(ms.cg)
        cg[i], cg[j] = cg[j], cg[i]
        new = dict(lms.ms)
        new[name] = replace(ms, cg=tuple(cg))
        return LMS(ms=new)

    def op3(self, grp: LayerGroup, lms: LMS) -> Optional[LMS]:
        if len(grp.names) < 2:
            return None
        a, b = self._pick2(len(grp.names))
        na, nb = grp.names[a], grp.names[b]
        ma, mb = lms.ms[na], lms.ms[nb]
        ia = int(self.rng.integers(ma.nc))
        ib = int(self.rng.integers(mb.nc))
        cga, cgb = list(ma.cg), list(mb.cg)
        cga[ia], cgb[ib] = cgb[ib], cga[ia]
        new = dict(lms.ms)
        new[na] = replace(ma, cg=tuple(cga))
        new[nb] = replace(mb, cg=tuple(cgb))
        return LMS(ms=new)

    def op4(self, grp: LayerGroup, lms: LMS,
            idle: Sequence[int]) -> Optional[Tuple[LMS, List[int]]]:
        """Move a core between layers (or to/from the idle pool).  Pure:
        returns (new_lms, new_idle) without mutating the inputs."""
        names = list(grp.names)
        new_idle = list(idle)
        donors = [n for n in names if lms.ms[n].nc >= 2]
        use_idle_donor = bool(new_idle) and self.rng.random() < 0.25
        if not donors and not use_idle_donor:
            return None
        new = dict(lms.ms)
        if use_idle_donor:
            core = new_idle.pop(int(self.rng.integers(len(new_idle))))
            donor = None
        else:
            donor = self._pick(donors)
            md = new[donor]
            di = int(self.rng.integers(md.nc))
            core = md.cg[di]
            cgd = md.cg[:di] + md.cg[di + 1:]
            try:
                pd = factor_parts(len(cgd), self._dims(donor, grp), self.rng)
            except ValueError:
                return None
            new[donor] = MS(part=pd, cg=cgd, fd=md.fd)
        # receiver: another layer, or (rarely) the idle pool
        recv_idle = donor is not None and self.rng.random() < 0.10
        recv_cands = [n for n in names if n != donor]
        if recv_idle or not recv_cands:
            if donor is None:
                return None              # idle -> idle is a no-op
            new_idle.append(core)
        else:
            recv = self._pick(recv_cands)
            mr = new[recv]
            pos = int(self.rng.integers(mr.nc + 1))
            cgr = mr.cg[:pos] + (core,) + mr.cg[pos:]
            try:
                pr = factor_parts(len(cgr), self._dims(recv, grp), self.rng)
            except ValueError:
                return None
            new[recv] = MS(part=pr, cg=cgr, fd=mr.fd)
        return LMS(ms=new), new_idle

    def op5(self, grp: LayerGroup, lms: LMS) -> Optional[LMS]:
        cands = [(n, i) for n in grp.names
                 for i, v in enumerate(lms.ms[n].fd) if v >= 0]
        if not cands:
            return None
        name, i = cands[int(self.rng.integers(len(cands)))]
        ms = lms.ms[name]
        v = int(self.rng.integers(0, self.arch.n_dram + 1))
        if v == ms.fd[i]:
            return None
        fd = list(ms.fd)
        fd[i] = v
        new = dict(lms.ms)
        new[name] = replace(ms, fd=tuple(fd))
        return LMS(ms=new)


def sa_optimize(g: Graph, arch: ArchConfig, groups: Sequence[LayerGroup],
                total_batch: int, cfg: SAConfig,
                init: Optional[Mapping] = None,
                evaluator: Optional[Evaluator] = None) -> SAResult:
    """Run the SA chain(s); returns the best mapping found."""
    best: Optional[SAResult] = None
    for chain in range(cfg.n_chains):
        res = _sa_chain(g, arch, groups, total_batch,
                        replace(cfg, seed=cfg.seed + chain), init, evaluator)
        if best is None or res.cost < best.cost:
            best = res
    assert best is not None
    return best


def _sa_chain(g: Graph, arch: ArchConfig, groups: Sequence[LayerGroup],
              total_batch: int, cfg: SAConfig, init: Optional[Mapping],
              evaluator: Optional[Evaluator]) -> SAResult:
    rng = np.random.default_rng(cfg.seed)
    # content-addressed GroupEval cache: re-proposals, repeated chains and
    # the final exact re-evaluation hit it; results are identical either way
    ev = evaluator or CachedEvaluator(arch, g)
    mapping: Mapping = [(grp, lms) for grp, lms in
                        (init if init is not None else tangram_map(groups, g, arch))]
    # idle cores per group
    idle: List[List[int]] = []
    for grp, lms in mapping:
        used = set(lms.cores_used())
        idle.append([c for c in range(arch.n_cores) if c not in used])

    evals: List[GroupEval] = []
    for grp, lms in mapping:
        ge, _ = ev.eval_group(grp, lms, total_batch)
        evals.append(ge)

    def total_cost() -> Tuple[float, float, float]:
        E = sum(e.energy_j for e in evals)
        D = sum(e.delay_s for e in evals)
        return (E ** cfg.beta) * (D ** cfg.gamma), E, D

    cost, E, D = total_cost()
    best_cost, best_map = cost, [(grp, lms) for grp, lms in mapping]
    weights = _group_weights(groups, arch.n_cores)
    # inverse-CDF group draw: rng.choice(..., p=weights) re-normalizes and
    # allocates on every call
    cum_w = np.cumsum(weights)
    cum_w[-1] = 1.0
    ops = _Op(g, arch, rng)
    t0 = cfg.t0 * cost
    alpha = (cfg.t_end / cfg.t0) ** (1.0 / max(1, cfg.iters))
    T = t0
    history: List[float] = []
    accepted = proposed = 0

    for it in range(cfg.iters):
        gi = int(np.searchsorted(cum_w, rng.random(), side="right"))
        grp, lms = mapping[gi]
        op = int(rng.integers(1, 6))
        new_idle: Optional[List[int]] = None
        if op == 1:
            cand = ops.op1(grp, lms)
        elif op == 2:
            cand = ops.op2(grp, lms)
        elif op == 3:
            cand = ops.op3(grp, lms)
        elif op == 4:
            r4 = ops.op4(grp, lms, idle[gi])
            cand, new_idle = r4 if r4 is not None else (None, None)
        else:
            cand = ops.op5(grp, lms)
        T *= alpha
        if cand is None:
            continue
        proposed += 1
        ge, _ = ev.eval_group(grp, cand, total_batch)
        old = evals[gi]
        newE = E - old.energy_j + ge.energy_j
        newD = D - old.delay_s + ge.delay_s
        new_cost = (newE ** cfg.beta) * (newD ** cfg.gamma)
        if new_cost <= cost or rng.random() < math.exp(
                min(0.0, -(new_cost - cost) / max(T, 1e-30))):
            mapping[gi] = (grp, cand)
            evals[gi] = ge
            if new_idle is not None:
                idle[gi] = new_idle
            cost, E, D = new_cost, newE, newD
            accepted += 1
            if cost < best_cost:
                best_cost = cost
                best_map = [(gg, ll) for gg, ll in mapping]
        if cfg.log_every and it % cfg.log_every == 0:
            history.append(cost)

    # final exact numbers for the best mapping
    final = ev.evaluate(best_map, total_batch)
    return SAResult(mapping=best_map, cost=final.cost(cfg.beta, cfg.gamma),
                    energy_j=final.energy_j, delay_s=final.delay_s,
                    history=history, accepted=accepted, proposed=proposed)
