"""Stripe-based heuristic LP SPM (the paper's baseline, "T-Map").

Tangram-style: each layer of a group gets a *contiguous rectangle* of cores,
sized proportionally to its MAC share; the layer's ofmap is partitioned over
the rectangle along spatial dims (H across rectangle rows, W/K across
columns).  FDs are interleaved (0) wherever explicit.  This is also the SA
engine's initial scheme (paper Sec. V-B1).
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from .encoding import LMS, MS, default_fd, factor_parts
from .hw import ArchConfig
from .workload import Graph, LayerGroup


def _rect_cores(arch: ArchConfig, x0: int, x1: int) -> List[int]:
    """Cores of the column stripe [x0, x1), row-major, snake order."""
    out: List[int] = []
    for y in range(arch.y_cores):
        cols = range(x0, x1) if y % 2 == 0 else range(x1 - 1, x0 - 1, -1)
        for x in cols:
            out.append(y * arch.x_cores + x)
    return out


def _best_2d_part(n: int, dims: Tuple[int, int, int, int]) -> Tuple[int, int, int, int]:
    """Deterministic near-square factorization of n over (H, W, B, K)."""
    H, W, B, K = dims
    best = None
    for ph in range(1, min(n, H) + 1):
        if n % ph:
            continue
        rest = n // ph
        for pk in range(1, min(rest, K) + 1):
            if rest % pk:
                continue
            rest2 = rest // pk
            for pb in range(1, min(rest2, B) + 1):
                if rest2 % pb:
                    continue
                pw = rest2 // pb
                if pw > W:
                    continue
                # prefer balanced spatial/channel splits
                score = abs(ph - pk) + pw + pb
                if best is None or score < best[0]:
                    best = (score, (ph, pw, pb, pk))
    if best is None:
        raise ValueError(f"no factorization of {n} over {dims}")
    return best[1]


def stripe_lms(group: LayerGroup, g: Graph, arch: ArchConfig,
               n_dram: int) -> LMS:
    """Allocate column stripes proportional to MACs; partition inside each."""
    names = list(group.names)
    # expected MACs: a routed MoE expert at top_k/E share gets a
    # proportionally thinner stripe (dense layers see the exact old ints)
    macs = np.array([max(1, g.layers[n].expected_macs(group.batch_unit))
                     for n in names], dtype=float)
    share = macs / macs.sum()
    # stripe widths in columns, each layer >= 1 column, total == x_cores
    X = arch.x_cores
    if len(names) > X:
        # fall back to core-level stripes over the flattened snake order
        return _core_stripe_lms(group, g, arch, n_dram)
    cols = np.maximum(1, np.floor(share * X).astype(int))
    while cols.sum() > X:
        cols[int(np.argmax(cols))] -= 1
    while cols.sum() < X:
        cols[int(np.argmax(share - cols / X))] += 1
    ms: Dict[str, MS] = {}
    x0 = 0
    for name, w in zip(names, cols):
        lyr = g.layers[name]
        cores = _rect_cores(arch, x0, x0 + int(w))
        x0 += int(w)
        nc = len(cores)
        dims = (lyr.H, lyr.W, group.batch_unit, lyr.K)
        nc_eff = nc
        while nc_eff > 1:
            try:
                part = _best_2d_part(nc_eff, dims)
                break
            except ValueError:
                nc_eff -= 1
        else:
            part = (1, 1, 1, 1)
        ms[name] = MS(part=part, cg=tuple(cores[:int(np.prod(part))]),
                      fd=default_fd(lyr, g, group, n_dram))
    return LMS(ms=ms)


def _core_stripe_lms(group: LayerGroup, g: Graph, arch: ArchConfig,
                     n_dram: int) -> LMS:
    """Stripe at core granularity when there are more layers than columns."""
    names = list(group.names)
    # expected MACs: a routed MoE expert at top_k/E share gets a
    # proportionally thinner stripe (dense layers see the exact old ints)
    macs = np.array([max(1, g.layers[n].expected_macs(group.batch_unit))
                     for n in names], dtype=float)
    share = macs / macs.sum()
    M = arch.n_cores
    sizes = np.maximum(1, np.floor(share * M).astype(int))
    while sizes.sum() > M:
        sizes[int(np.argmax(sizes))] -= 1
    while sizes.sum() < M:
        sizes[int(np.argmax(share - sizes / M))] += 1
    snake = _rect_cores(arch, 0, arch.x_cores)
    ms: Dict[str, MS] = {}
    off = 0
    for name, nc in zip(names, sizes):
        lyr = g.layers[name]
        cores = snake[off:off + int(nc)]
        off += int(nc)
        dims = (lyr.H, lyr.W, group.batch_unit, lyr.K)
        nc_eff = len(cores)
        while nc_eff > 1:
            try:
                part = _best_2d_part(nc_eff, dims)
                break
            except ValueError:
                nc_eff -= 1
        else:
            part = (1, 1, 1, 1)
        ms[name] = MS(part=part, cg=tuple(cores[:int(np.prod(part))]),
                      fd=default_fd(lyr, g, group, n_dram))
    return LMS(ms=ms)


def tangram_map(groups: Sequence[LayerGroup], g: Graph,
                arch: ArchConfig) -> List[Tuple[LayerGroup, LMS]]:
    """T-Map for a whole partitioned DNN."""
    return [(grp, stripe_lms(grp, g, arch, arch.n_dram)) for grp in groups]
