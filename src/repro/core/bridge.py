"""Bridge: Gemini LMS mappings -> JAX device placements.

The paper's encoding is hardware-agnostic: ``CG_i`` is an ordered set of
*cores*.  On the TPU side cores are chips of a mesh.  ``lms_to_plan`` turns
an explored LMS into a ``MeshPlan``: contiguous pipeline *stages* (groups of
layers sharing a device set) with each stage's device list and a per-layer
``PartitionSpec``-style factorization derived from ``Part``.

``plan_for_model`` runs the whole Gemini engine (DP graph partition + SA)
on an LM architecture's layer graph against an abstract accelerator whose
geometry mirrors the mesh (chips = cores, pods = chiplets, ICI = NoC,
DCI = D2D), then bridges the result.  runtime/pipeline.py executes a plan on
real devices.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .encoding import LMS
from .evaluator import Evaluator
from .graph_partition import partition_graph
from .hw import ArchConfig, Tech, TECH_12NM
from .sa import Mapping, SAConfig, sa_optimize
from .workload import Graph, LayerGroup


# TPU-flavored constants for the abstract model (chips as cores).  Energies
# are per byte moved on ICI/DCI links; silicon-cost fields are reused to
# price chips+hosts (MC in $ still, but per-chip).
TECH_TPUPOD = Tech(
    name="tpu-pod",
    e_mac=0.15e-12, e_glb_byte=0.8e-12, e_noc_hop_byte=0.4e-12,
    e_d2d_byte=6.0e-12, e_dram_byte=25e-12,
    a_mac=0.0, a_glb_kb=0.0, a_core_fixed=0.0, a_d2d_fixed=0.0,
    a_d2d_per_gbps=0.0, a_io_die_fixed=0.0, a_dram_phy_per_gbps=0.0,
    c_silicon_mm2=0.0, yield_unit=1.0, area_unit_mm2=1.0,
    c_dram_die=0.0, dram_die_bw=1.0, f_scale=1.0, yield_package=1.0,
    c_package_mono_mm2=0.0)


def mesh_as_arch(x_chips: int = 16, y_chips: int = 16, pods_x: int = 1,
                 ici_gbps: float = 50.0, dci_gbps: float = 6.25,
                 hbm_gbps: float = 819.0) -> ArchConfig:
    """An ArchConfig whose geometry mirrors a TPU mesh: chips as cores,
    pods as chiplets, ICI as NoC links, inter-pod DCI as D2D."""
    return ArchConfig(
        x_cores=x_chips * pods_x, y_cores=y_chips, xcut=pods_x, ycut=1,
        noc_bw=ici_gbps, d2d_bw=dci_gbps, dram_bw=hbm_gbps * 2,
        glb_kb=16 * 1024 * 1024 // 1024,   # 16 GB HBM as the "GLB"
        macs_per_core=98_500,              # 197 TFLOP/s bf16 @ 1 GHz, 2 op/MAC
        freq_ghz=1.0, n_dram=2, tech=TECH_TPUPOD)


@dataclass
class StagePlan:
    layers: Tuple[str, ...]
    devices: Tuple[int, ...]          # flat device indices into the mesh
    # per-layer Part factors: dict layer -> (ph, pw, pb, pk)
    parts: Dict[str, Tuple[int, int, int, int]] = field(default_factory=dict)
    # per-layer CG in correspondence order (the Rule's row-major (h,w,b,k)
    # nesting) — the realization subsystem reshapes the dominant layer's CG
    # into the stage's device mesh, so the order must survive the collapse
    cgs: Dict[str, Tuple[int, ...]] = field(default_factory=dict)

    def dominant_layer(self) -> str:
        """Layer with the largest core group: its ``Part`` is the stage's
        sharding skeleton (mesh shape + PartitionSpec axes)."""
        return max(self.layers, key=lambda n: (len(self.cgs.get(n, ())), n))


@dataclass
class MeshPlan:
    stages: List[StagePlan]
    batch_unit: int
    cost_delay_s: float = 0.0
    cost_energy_j: float = 0.0

    def stage_of(self, layer: str) -> int:
        for i, st in enumerate(self.stages):
            if layer in st.layers:
                return i
        raise KeyError(layer)

    @property
    def n_devices_needed(self) -> int:
        """1 + highest flat device index any stage references."""
        return 1 + max((max(st.devices) for st in self.stages
                        if st.devices), default=-1)


def lms_to_plan(mapping: Mapping, delay_s: float = 0.0,
                energy_j: float = 0.0) -> MeshPlan:
    """Collapse an LMS mapping into contiguous stages.

    Layers of one layer group run concurrently on disjoint core sets — each
    layer group becomes one pipeline stage whose device set is the union of
    its CGs; Part factors ride along for intra-stage sharding.
    """
    stages: List[StagePlan] = []
    bu = 1
    for group, lms in mapping:
        devs: List[int] = []
        parts: Dict[str, Tuple[int, int, int, int]] = {}
        cgs: Dict[str, Tuple[int, ...]] = {}
        for name in group.names:
            ms = lms.ms[name]
            devs.extend(ms.cg)
            parts[name] = ms.part
            cgs[name] = ms.cg
        stages.append(StagePlan(layers=tuple(group.names),
                                devices=tuple(sorted(set(devs))),
                                parts=parts, cgs=cgs))
        bu = group.batch_unit
    return MeshPlan(stages=stages, batch_unit=bu, cost_delay_s=delay_s,
                    cost_energy_j=energy_j)


def plan_for_graph(g: Graph, arch: ArchConfig, total_batch: int,
                   sa_iters: int = 2000, seed: int = 0) -> MeshPlan:
    """Full Gemini flow on an arbitrary layer graph -> MeshPlan."""
    groups = partition_graph(g, arch, total_batch)
    res = sa_optimize(g, arch, groups, total_batch,
                      SAConfig(iters=sa_iters, seed=seed))
    return lms_to_plan(res.mapping, res.delay_s, res.energy_j)
