"""Intra-core dataflow exploration (paper Sec. V-B1, last stage).

For the partitioned workload landing on one core, we exhaustively search
NVDLA-style tilings: tile sizes (tk, tc, th, tw) over a power-of-two grid and
three loop orders (weight- / output- / input-stationary).  The PE array is
modeled as the classic NVDLA Kvec x Cvec MAC tree (16 x 64 by default for
1024 MACs), which fixes the register-level reuse; the search decides the
GLB-level reuse, i.e. how many times each operand is re-read from the GLB
and how often partial sums bounce.

Outputs per workload: GLB traffic in bytes (for energy), the achieved MAC
utilization (array padding loss), and the chosen tile.  Results are memoized
on the workload signature — the SA engine hits the same shapes constantly.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Tuple


@dataclass(frozen=True)
class CoreDataflow:
    tile: Tuple[int, int, int, int]       # (tk, tc, th, tw)
    order: str                            # ws | os | is
    glb_read_bytes: float
    glb_write_bytes: float
    utilization: float                    # MAC array utilization in [0,1]


def _pow2_tiles(dim: int, cap: int) -> Tuple[int, ...]:
    out = []
    t = 1
    while t < min(dim, cap):
        out.append(t)
        t *= 2
    out.append(min(dim, cap))
    return tuple(sorted(set(out)))


@lru_cache(maxsize=200_000)
def explore_intra_core(K: int, C: int, HW: int, R: int, S: int,
                       bytes_per_elem: int, glb_bytes: int,
                       macs_per_core: int, kind: str) -> CoreDataflow:
    """Exhaustive tiling/loop-order search for one per-core workload.

    K: ofmap channels on this core; C: contraction channels; HW: spatial
    positions (H*W*B collapsed — they are fully parallel); RxS kernel.
    """
    kvec = 16
    cvec = max(1, macs_per_core // kvec)
    if kind in ("eltwise", "pool", "depthwise"):
        # streaming ops: one read + one write per element, trivially tiled
        vol = K * HW * bytes_per_elem
        return CoreDataflow((K, 1, HW, 1), "stream",
                            glb_read_bytes=float(vol * (2 if kind == "eltwise" else 1)),
                            glb_write_bytes=float(vol),
                            utilization=1.0)

    C_eff = max(1, C)
    w_elems = K * C_eff * R * S if kind in ("conv", "fc") else 0
    if_elems = C_eff * HW * (R * S if kind == "conv" else 1)
    of_elems = K * HW
    psum_bytes = 4                      # 32-bit partial sums

    best: CoreDataflow | None = None
    for tk in _pow2_tiles(K, 512):
        for tc in _pow2_tiles(C_eff, 512):
            for thw in _pow2_tiles(HW, 4096):
                # buffer need: weights tile + ifmap tile + psum tile (dbl buf fmaps)
                buf = (tk * tc * R * S * bytes_per_elem
                       + tc * thw * bytes_per_elem * 2
                       + tk * thw * psum_bytes)
                if buf > glb_bytes:
                    continue
                nk = -(-K // tk)
                nc = -(-C_eff // tc)
                nhw = -(-HW // thw)
                for order in ("ws", "os", "is"):
                    if order == "ws":      # weights resident per (tk,tc) tile
                        rd = (w_elems * 1.0
                              + if_elems * nk            # ifmap re-read per k tile
                              ) * bytes_per_elem \
                            + of_elems * (nc - 1) * psum_bytes  # psum re-read
                        wr = of_elems * nc * psum_bytes
                    elif order == "os":    # outputs resident, operands stream
                        rd = (w_elems * nhw + if_elems * nk) * bytes_per_elem
                        wr = of_elems * psum_bytes
                    else:                  # is: ifmap resident per (tc,thw) tile
                        rd = (w_elems * nhw + if_elems * 1.0) * bytes_per_elem \
                            + of_elems * (nc - 1) * psum_bytes
                        wr = of_elems * nc * psum_bytes
                    # MAC array padding loss on the vectorized dims
                    uk = K / (-(-K // kvec) * kvec)
                    uc = C_eff / (-(-C_eff // cvec) * cvec)
                    util = uk * uc
                    cand = CoreDataflow((tk, tc, thw, 1), order, rd, wr, util)
                    if best is None or (cand.glb_read_bytes + cand.glb_write_bytes
                                        < best.glb_read_bytes + best.glb_write_bytes):
                        best = cand
    if best is None:
        # nothing fits: fall back to minimum tiles with spill multipliers
        tk, tc, thw = 1, 1, 1
        rd = (w_elems * HW + if_elems * K) * bytes_per_elem
        wr = of_elems * C_eff * psum_bytes
        best = CoreDataflow((tk, tc, thw, 1), "spill", float(rd), float(wr),
                            utilization=1.0 / (kvec * cvec))
    return best


def core_workload_signature(layer_K: int, layer_C: int, region_elems: int,
                            region_k: int, R: int, S: int) -> Tuple[int, int, int, int, int]:
    """Collapse a Region into the intra-core search signature."""
    hwb = max(1, region_elems // max(1, region_k))
    return (region_k, layer_C, hwb, R, S)
