"""Intra-core dataflow exploration (paper Sec. V-B1, last stage).

For the partitioned workload landing on one core, we exhaustively search
NVDLA-style tilings: tile sizes (tk, tc, th, tw) over a power-of-two grid and
three loop orders (weight- / output- / input-stationary).  The PE array is
modeled as the classic NVDLA Kvec x Cvec MAC tree (16 x 64 by default for
1024 MACs), which fixes the register-level reuse; the search decides the
GLB-level reuse, i.e. how many times each operand is re-read from the GLB
and how often partial sums bounce.

Outputs per workload: GLB traffic in bytes (for energy), the achieved MAC
utilization (array padding loss), and the chosen tile.

The production path (``explore_intra_core``) enumerates the whole
``(tk, tc, thw, order)`` candidate grid as NumPy arrays, masks candidates
whose buffer need exceeds the GLB, and argmins total GLB traffic in one
shot — ~30x faster than the scalar triple loop, which is kept verbatim as
``explore_intra_core_reference`` for the regression tests.  Both paths pick
the same candidate: ``np.argmin`` returns the first minimum in C order,
matching the scalar loop's strict-< first-winner over the same nesting
(tk, tc, thw, order).  Results are memoized on the workload signature — the
SA engine hits the same shapes constantly — and ``explore_intra_core_many``
batches lookups, deduping signatures before dispatch.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import List, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class CoreDataflow:
    tile: Tuple[int, int, int, int]       # (tk, tc, thw, tw)
    order: str                            # ws | os | is
    glb_read_bytes: float
    glb_write_bytes: float
    utilization: float                    # MAC array utilization in [0,1]


# A full workload signature, in ``explore_intra_core`` argument order.
Signature = Tuple[int, int, int, int, int, int, int, int, str]


def _pow2_tiles(dim: int, cap: int) -> Tuple[int, ...]:
    out = []
    t = 1
    while t < min(dim, cap):
        out.append(t)
        t *= 2
    out.append(min(dim, cap))
    return tuple(sorted(set(out)))


_ORDERS = ("ws", "os", "is")
_PSUM_BYTES = 4                           # 32-bit partial sums


def _stream_dataflow(K: int, HW: int, bytes_per_elem: int,
                     kind: str) -> CoreDataflow:
    # streaming ops: one read + one write per element, trivially tiled
    vol = K * HW * bytes_per_elem
    return CoreDataflow((K, 1, HW, 1), "stream",
                        glb_read_bytes=float(vol * (2 if kind == "eltwise" else 1)),
                        glb_write_bytes=float(vol),
                        utilization=1.0)


def _spill_dataflow(w_elems: int, if_elems: int, of_elems: int, K: int,
                    C_eff: int, HW: int, bytes_per_elem: int,
                    kvec: int, cvec: int) -> CoreDataflow:
    # nothing fits: fall back to minimum tiles with spill multipliers
    rd = (w_elems * HW + if_elems * K) * bytes_per_elem
    wr = of_elems * C_eff * _PSUM_BYTES
    return CoreDataflow((1, 1, 1, 1), "spill", float(rd), float(wr),
                        utilization=1.0 / (kvec * cvec))


@lru_cache(maxsize=200_000)
def explore_intra_core(K: int, C: int, HW: int, R: int, S: int,
                       bytes_per_elem: int, glb_bytes: int,
                       macs_per_core: int, kind: str) -> CoreDataflow:
    """Vectorized tiling/loop-order search for one per-core workload.

    K: ofmap channels on this core; C: contraction channels; HW: spatial
    positions (H*W*B collapsed — they are fully parallel); RxS kernel.
    """
    kvec = 16
    cvec = max(1, macs_per_core // kvec)
    if kind in ("eltwise", "pool", "depthwise"):
        return _stream_dataflow(K, HW, bytes_per_elem, kind)

    C_eff = max(1, C)
    w_elems = K * C_eff * R * S if kind in ("conv", "fc") else 0
    if_elems = C_eff * HW * (R * S if kind == "conv" else 1)
    of_elems = K * HW
    bpe = bytes_per_elem

    tk = np.asarray(_pow2_tiles(K, 512), dtype=np.int64)[:, None, None]
    tc = np.asarray(_pow2_tiles(C_eff, 512), dtype=np.int64)[None, :, None]
    thw = np.asarray(_pow2_tiles(HW, 4096), dtype=np.int64)[None, None, :]

    # buffer need: weights tile + ifmap tile + psum tile (dbl buf fmaps)
    buf = (tk * tc * (R * S * bpe)
           + tc * thw * (bpe * 2)
           + tk * thw * _PSUM_BYTES)
    feasible = buf <= glb_bytes
    if not feasible.any():
        return _spill_dataflow(w_elems, if_elems, of_elems, K, C_eff, HW,
                               bpe, kvec, cvec)

    nk = -(-K // tk)
    nc = -(-C_eff // tc)
    nhw = -(-HW // thw)

    # same expressions (and the same int->float promotion points) as the
    # scalar reference, evaluated over the whole grid at once
    rd_ws = (w_elems * 1.0 + if_elems * nk) * bpe \
        + of_elems * (nc - 1) * _PSUM_BYTES
    wr_ws = (of_elems * nc * _PSUM_BYTES).astype(np.float64)
    rd_os = ((w_elems * nhw + if_elems * nk) * bpe).astype(np.float64)
    wr_os = np.float64(of_elems * _PSUM_BYTES)
    rd_is = (w_elems * nhw + if_elems * 1.0) * bpe \
        + of_elems * (nc - 1) * _PSUM_BYTES
    wr_is = wr_ws

    shape = np.broadcast_shapes(tk.shape, tc.shape, thw.shape)
    total = np.empty(shape + (3,), dtype=np.float64)
    total[..., 0] = rd_ws + wr_ws
    total[..., 1] = rd_os + wr_os
    total[..., 2] = rd_is + wr_is
    total[~feasible, :] = np.inf

    flat_i = int(np.argmin(total.reshape(-1)))
    i, j, k, o = np.unravel_index(flat_i, total.shape)
    rd = (rd_ws, rd_os, rd_is)[o]
    wr = (wr_ws, wr_os, wr_is)[o]
    rd_v = float(np.broadcast_to(rd, shape)[i, j, k])
    wr_v = float(np.broadcast_to(wr, shape)[i, j, k])

    # MAC array padding loss on the vectorized dims (tile-independent)
    uk = K / (-(-K // kvec) * kvec)
    uc = C_eff / (-(-C_eff // cvec) * cvec)
    return CoreDataflow((int(tk[i, 0, 0]), int(tc[0, j, 0]),
                         int(thw[0, 0, k]), 1),
                        _ORDERS[o], rd_v, wr_v, uk * uc)


def explore_intra_core_many(signatures: Sequence[Signature]
                            ) -> List[CoreDataflow]:
    """Batch API: dedupe signatures, dispatch each unique one once.

    Returns one ``CoreDataflow`` per input signature, aligned with the
    input order.  The SA evaluator collects every per-core signature of a
    layer group and resolves them through this single call.
    """
    uniq: dict = {}
    for sig in signatures:
        if sig not in uniq:
            uniq[sig] = explore_intra_core(*sig)
    return [uniq[sig] for sig in signatures]


def explore_intra_core_reference(K: int, C: int, HW: int, R: int, S: int,
                                 bytes_per_elem: int, glb_bytes: int,
                                 macs_per_core: int, kind: str) -> CoreDataflow:
    """Scalar triple-loop search — the pre-vectorization seed implementation,
    kept as the oracle for tests/test_vectorized_engine.py."""
    kvec = 16
    cvec = max(1, macs_per_core // kvec)
    if kind in ("eltwise", "pool", "depthwise"):
        return _stream_dataflow(K, HW, bytes_per_elem, kind)

    C_eff = max(1, C)
    w_elems = K * C_eff * R * S if kind in ("conv", "fc") else 0
    if_elems = C_eff * HW * (R * S if kind == "conv" else 1)
    of_elems = K * HW
    psum_bytes = _PSUM_BYTES

    best: CoreDataflow | None = None
    for tk in _pow2_tiles(K, 512):
        for tc in _pow2_tiles(C_eff, 512):
            for thw in _pow2_tiles(HW, 4096):
                # buffer need: weights tile + ifmap tile + psum tile (dbl buf fmaps)
                buf = (tk * tc * R * S * bytes_per_elem
                       + tc * thw * bytes_per_elem * 2
                       + tk * thw * psum_bytes)
                if buf > glb_bytes:
                    continue
                nk = -(-K // tk)
                nc = -(-C_eff // tc)
                nhw = -(-HW // thw)
                for order in _ORDERS:
                    if order == "ws":      # weights resident per (tk,tc) tile
                        rd = (w_elems * 1.0
                              + if_elems * nk            # ifmap re-read per k tile
                              ) * bytes_per_elem \
                            + of_elems * (nc - 1) * psum_bytes  # psum re-read
                        wr = of_elems * nc * psum_bytes
                    elif order == "os":    # outputs resident, operands stream
                        rd = (w_elems * nhw + if_elems * nk) * bytes_per_elem
                        wr = of_elems * psum_bytes
                    else:                  # is: ifmap resident per (tc,thw) tile
                        rd = (w_elems * nhw + if_elems * 1.0) * bytes_per_elem \
                            + of_elems * (nc - 1) * psum_bytes
                        wr = of_elems * nc * psum_bytes
                    # MAC array padding loss on the vectorized dims
                    uk = K / (-(-K // kvec) * kvec)
                    uc = C_eff / (-(-C_eff // cvec) * cvec)
                    util = uk * uc
                    cand = CoreDataflow((tk, tc, thw, 1), order, rd, wr, util)
                    if best is None or (cand.glb_read_bytes + cand.glb_write_bytes
                                        < best.glb_read_bytes + best.glb_write_bytes):
                        best = cand
    if best is None:
        return _spill_dataflow(w_elems, if_elems, of_elems, K, C_eff, HW,
                               bytes_per_elem, kvec, cvec)
    return best


def core_workload_signature(layer_K: int, layer_C: int, region_elems: int,
                            region_k: int, R: int, S: int) -> Tuple[int, int, int, int, int]:
    """Collapse a Region into the intra-core search signature."""
    hwb = max(1, region_elems // max(1, region_k))
    return (region_k, layer_C, hwb, R, S)
