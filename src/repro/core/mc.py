"""Monetary Cost Evaluator (paper Sec. V-C).

  silicon cost = sum_dies Area_die / Yield_die * C_silicon,
                 Yield_die = Yield_unit ^ (Area_die / Area_unit)
  DRAM cost    = ceil(DRAM_bw / Unit_bw) * C_dram_die          (GDDR6: 32 GB/s, $3.5)
  packaging    = (Area_tot * f_scale) / Yield_package^n_dies * C_package(area)

Chiplet areas follow the hardware template: per-core logic (MACs, GLB,
router/DMA/control) plus the D2D interfaces actually instantiated on that
chiplet's boundaries; IO dies carry DDR PHYs, PCIe and their D2D column.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from math import ceil
from typing import Dict

from .hw import ArchConfig, Tech


@dataclass(frozen=True)
class MCBreakdown:
    silicon: float
    dram: float
    packaging: float
    compute_die_area: float       # one computing chiplet, mm^2
    io_die_area: float            # one IO chiplet, mm^2
    total_silicon_area: float
    d2d_area_fraction: float      # of computing-chiplet area

    @property
    def total(self) -> float:
        return self.silicon + self.dram + self.packaging


def core_logic_area(arch: ArchConfig) -> float:
    t = arch.tech
    return (arch.macs_per_core * t.a_mac
            + arch.glb_kb * t.a_glb_kb
            + t.a_core_fixed)


def d2d_interface_area(arch: ArchConfig) -> float:
    t = arch.tech
    return t.a_d2d_fixed + t.a_d2d_per_gbps * arch.d2d_bw


def _package_rate(tech: Tech, substrate_area: float, n_chiplets: int) -> float:
    if n_chiplets <= 1:
        return tech.c_package_mono_mm2
    for cap, rate in tech.c_package_tiers:
        if substrate_area <= cap:
            return rate
    return tech.c_package_tiers[-1][1]


@lru_cache(maxsize=65536)
def evaluate_mc(arch: ArchConfig) -> MCBreakdown:
    """Monetary cost of one architecture point.

    Pure in the frozen ``ArchConfig``, so results are memoized: the DSE grid
    scorer and ``joint_reuse_dse`` (which revisits each base chiplet once per
    scale factor) pay for each architecture exactly once."""
    t = arch.tech
    cores_per_chiplet = arch.n_cores // arch.n_chiplets
    ifaces_per_chiplet = arch.d2d_interfaces_per_chiplet
    a_d2d = d2d_interface_area(arch) * ifaces_per_chiplet \
        if (arch.n_chiplets > 1 or True) else 0.0
    # monolithic accelerators still need the IO-die boundary D2D unless the
    # IO functions are folded on-die; the template keeps separate IO dies.
    compute_die = core_logic_area(arch) * cores_per_chiplet + a_d2d

    n_io = 2
    io_die = (t.a_io_die_fixed
              + t.a_dram_phy_per_gbps * arch.dram_bw / n_io
              + d2d_interface_area(arch) * arch.y_cores)   # boundary column

    def die_cost(area: float) -> float:
        yld = t.yield_unit ** (area / t.area_unit_mm2)
        return area / yld * t.c_silicon_mm2

    silicon = arch.n_chiplets * die_cost(compute_die) + n_io * die_cost(io_die)
    dram = ceil(arch.dram_bw / t.dram_die_bw) * t.c_dram_die

    area_tot = arch.n_chiplets * compute_die + n_io * io_die
    n_dies = arch.n_chiplets + n_io
    substrate = area_tot * t.f_scale
    rate = _package_rate(t, substrate, arch.n_chiplets)
    pkg_yield = t.yield_package ** n_dies
    packaging = substrate / pkg_yield * rate

    return MCBreakdown(
        silicon=silicon, dram=dram, packaging=packaging,
        compute_die_area=compute_die, io_die_area=io_die,
        total_silicon_area=area_tot,
        d2d_area_fraction=a_d2d / compute_die if compute_die else 0.0)
