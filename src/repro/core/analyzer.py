"""LP-SPM Analyzer: parse an LMS into core workloads + link/DRAM traffic.

This is the paper's "LP SPM Analyzer" box (Fig. 4).  Given a layer group, an
``LMS`` and an ``ArchConfig`` it produces:

  * per-core compute work (MACs) and buffer footprints,
  * per-directed-link feature-map traffic (bytes per pipeline pass) under XY
    routing with multicast trees (cores needing *identical* data — e.g. the
    K-partitioned consumers of one producer part — share one tree),
  * per-DRAM-port traffic, split by interleaving when FD == 0,
  * weight-load traffic (amortized over passes).

Everything is vectorized with numpy; the router paths for all node pairs are
precomputed per ``ArchConfig`` and cached, because the SA engine calls this
millions of times.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .encoding import LMS, MS, Region, ifmap_region, parse_regions
from .hw import ArchConfig
from .workload import Graph, Layer, LayerGroup


# ---------------------------------------------------------------------------
# Router geometry, cached per arch signature
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RouterGrid:
    n_nodes: int
    n_edges: int
    edge_is_d2d: np.ndarray          # (n_edges,) bool
    paths: np.ndarray                # (n_nodes, n_nodes, max_len) edge ids, -1 pad
    path_len: np.ndarray             # (n_nodes, n_nodes)
    hops_d2d: np.ndarray             # (n_nodes, n_nodes) number of D2D edges


def _build_grid(arch: ArchConfig) -> RouterGrid:
    gw, gh = arch.grid_w, arch.grid_h
    n_nodes = gw * gh
    # directed edges: id layout [east | west | south(+y) | north(-y)]
    n_h = (gw - 1) * gh
    n_v = gw * (gh - 1)
    n_edges = 2 * n_h + 2 * n_v

    def east_id(x, y):  return y * (gw - 1) + x            # (x,y)->(x+1,y)
    def west_id(x, y):  return n_h + y * (gw - 1) + (x - 1)  # (x,y)->(x-1,y)
    def south_id(x, y): return 2 * n_h + y * gw + x        # (x,y)->(x,y+1)
    def north_id(x, y): return 2 * n_h + n_v + (y - 1) * gw + x

    is_d2d = np.zeros(n_edges, dtype=bool)
    for y in range(gh):
        for x in range(gw - 1):
            d2d = arch.node_chiplet(y * gw + x) != arch.node_chiplet(y * gw + x + 1)
            is_d2d[east_id(x, y)] = d2d
            is_d2d[west_id(x + 1, y)] = d2d
    for y in range(gh - 1):
        for x in range(gw):
            d2d = arch.node_chiplet(y * gw + x) != arch.node_chiplet((y + 1) * gw + x)
            is_d2d[south_id(x, y)] = d2d
            is_d2d[north_id(x, y + 1)] = d2d

    max_len = (gw - 1) + (gh - 1)
    paths = np.full((n_nodes, n_nodes, max(max_len, 1)), -1, dtype=np.int32)
    plen = np.zeros((n_nodes, n_nodes), dtype=np.int32)
    hops_d2d = np.zeros((n_nodes, n_nodes), dtype=np.int32)
    for a in range(n_nodes):
        ay, ax = divmod(a, gw)
        for b in range(n_nodes):
            if a == b:
                continue
            by, bx = divmod(b, gw)
            e: List[int] = []
            x, y = ax, ay
            while x < bx:
                e.append(east_id(x, y)); x += 1
            while x > bx:
                e.append(west_id(x, y)); x -= 1
            while y < by:
                e.append(south_id(x, y)); y += 1
            while y > by:
                e.append(north_id(x, y)); y -= 1
            paths[a, b, :len(e)] = e
            plen[a, b] = len(e)
            hops_d2d[a, b] = int(is_d2d[e].sum()) if e else 0
    return RouterGrid(n_nodes, n_edges, is_d2d, paths, plen, hops_d2d)


_GRID_CACHE: Dict[Tuple, RouterGrid] = {}


def router_grid(arch: ArchConfig) -> RouterGrid:
    key = (arch.x_cores, arch.y_cores, arch.xcut, arch.ycut)
    if key not in _GRID_CACHE:
        _GRID_CACHE[key] = _build_grid(arch)
    return _GRID_CACHE[key]


# ---------------------------------------------------------------------------
# Analysis result
# ---------------------------------------------------------------------------

@dataclass
class GroupAnalysis:
    """Traffic/compute for ONE pipeline pass of one layer group."""
    arch: ArchConfig
    batch_unit: int
    core_macs: np.ndarray            # (n_cores,) MACs per pass
    edge_bytes: np.ndarray           # (n_edges,) NoC/D2D bytes per pass
    edge_bytes_amortized: np.ndarray  # weight loads etc., already / n_passes
    dram_bytes: np.ndarray           # (n_dram,) bytes per pass (fmap flows)
    dram_bytes_amortized: np.ndarray  # (n_dram,) weight loads / n_passes
    core_glb_need: np.ndarray        # (n_cores,) resident footprint bytes
    core_in_bytes: np.ndarray        # (n_cores,) fmap bytes received per pass
    core_out_bytes: np.ndarray       # (n_cores,) fmap bytes sent per pass
    weight_dram_bytes_total: float   # unamortized (for energy, counted once)
    # per-layer part tables for the intra-core engine
    layer_parts: Dict[str, Dict[int, Region]] = field(default_factory=dict)

    @property
    def total_hops_bytes(self) -> float:
        return float(self.edge_bytes.sum())

    @property
    def d2d_bytes(self) -> float:
        g = router_grid(self.arch)
        return float(self.edge_bytes[g.edge_is_d2d].sum())


def _regions_to_array(regions: Dict[int, Region]) -> Tuple[np.ndarray, np.ndarray]:
    cores = np.array(sorted(regions), dtype=np.int64)
    arr = np.array([[regions[c].h0, regions[c].h1, regions[c].w0, regions[c].w1,
                     regions[c].b0, regions[c].b1, regions[c].k0, regions[c].k1]
                    for c in cores], dtype=np.int64)
    return cores, arr


def _overlap_matrix(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """(P,8) x (Q,8) region arrays -> (P,Q) overlap element counts."""
    def axis(i):
        lo = np.maximum(a[:, None, 2 * i], b[None, :, 2 * i])
        hi = np.minimum(a[:, None, 2 * i + 1], b[None, :, 2 * i + 1])
        return np.clip(hi - lo, 0, None)
    return axis(0) * axis(1) * axis(2) * axis(3)


class Analyzer:
    """Stateful per-(arch, graph) analyzer; reused across SA iterations."""

    def __init__(self, arch: ArchConfig, g: Graph):
        self.arch = arch
        self.g = g
        self.grid = router_grid(arch)
        self._core_nodes = np.array(
            [arch.core_node(c) for c in range(arch.n_cores)], dtype=np.int64)
        self._dram_nodes = np.array(
            [arch.dram_node(d) for d in range(1, arch.n_dram + 1)], dtype=np.int64)

    # -- routing helpers -----------------------------------------------------
    def _route(self, edge_bytes: np.ndarray, src_nodes: np.ndarray,
               dst_nodes: np.ndarray, vols: np.ndarray) -> None:
        """Accumulate unicast volumes onto edge loads (vectorized)."""
        mask = vols > 0
        if not mask.any():
            return
        s, d, v = src_nodes[mask], dst_nodes[mask], vols[mask]
        paths = self.grid.paths[s, d]            # (n, max_len)
        flat = paths.reshape(-1)
        keep = flat >= 0
        np.add.at(edge_bytes, flat[keep],
                  np.repeat(v, paths.shape[1])[keep])

    def _route_multicast(self, edge_bytes: np.ndarray, src_node: int,
                         dst_nodes: Sequence[int], vol: float) -> None:
        """One producer datum to many consumers: union of XY paths, counted once."""
        if vol <= 0 or not len(dst_nodes):
            return
        paths = self.grid.paths[src_node, np.asarray(dst_nodes, dtype=np.int64)]
        edges = np.unique(paths[paths >= 0])
        edge_bytes[edges] += vol

    # -- main entry ------------------------------------------------------------
    def analyze(self, group: LayerGroup, lms: LMS, total_batch: int) -> GroupAnalysis:
        arch, g = self.arch, self.g
        bu = group.batch_unit
        n_passes = max(1, -(-total_batch // bu))
        in_group = set(group.names)

        core_macs = np.zeros(arch.n_cores)
        edge_bytes = np.zeros(self.grid.n_edges)
        edge_amort = np.zeros(self.grid.n_edges)
        dram_bytes = np.zeros(arch.n_dram)
        dram_amort = np.zeros(arch.n_dram)
        glb_need = np.zeros(arch.n_cores)
        core_in = np.zeros(arch.n_cores)
        core_out = np.zeros(arch.n_cores)
        weight_total = 0.0

        regions_of: Dict[str, Dict[int, Region]] = {}
        for name in group.names:
            regions_of[name] = parse_regions(lms.ms[name], g.layers[name], bu)

        for name in group.names:
            lyr = g.layers[name]
            ms = lms.ms[name]
            regs = regions_of[name]
            cores, rarr = _regions_to_array(regs)
            nodes = self._core_nodes[cores]
            bpe = lyr.bytes_per_elem

            # compute: MACs proportional to ofmap share
            elems = (rarr[:, 1] - rarr[:, 0]) * (rarr[:, 3] - rarr[:, 2]) \
                * (rarr[:, 5] - rarr[:, 4]) * (rarr[:, 7] - rarr[:, 6])
            mac_per_elem = lyr.macs(1) / max(1, lyr.ofmap_elems)
            np.add.at(core_macs, cores, elems * mac_per_elem)

            # GLB footprint: weight slice + ofmap part (double-buffered fmaps)
            w_share = lyr.weight_bytes() / max(1, ms.part[3]) if lyr.has_weight else 0
            np.add.at(glb_need, cores, elems * bpe * 2 + w_share)

            # ---- weights: DRAM -> core, amortized over passes ----------------
            if lyr.has_weight:
                w_bytes_core = np.full(len(cores), 0.0)
                # each core holds the K-slice of its region (C,R,S full)
                k_span = (rarr[:, 7] - rarr[:, 6])
                w_bytes_core = k_span / max(1, lyr.K) * lyr.weight_bytes()
                weight_total += float(w_bytes_core.sum())
                self._dram_flow(edge_amort, dram_amort, ms.fd[1], nodes,
                                w_bytes_core / n_passes, to_core=True)

            # ---- ifmaps ------------------------------------------------------
            preds = [p for p in g.preds(name)]
            internal = [p for p in preds if p in in_group]
            external = (not preds) or any(p not in in_group for p in preds)
            for p in internal:
                self._dep_traffic(edge_bytes, core_in, core_out,
                                  g.layers[p], regions_of[p], lyr, regs, bu)
            if external and ms.fd[0] >= 0:
                # full needed ifmap from DRAM (input of DNN or previous group)
                if_bytes = self._external_ifmap_bytes(lyr, rarr, bu) * bpe
                self._dram_flow(edge_bytes, dram_bytes, ms.fd[0], nodes,
                                if_bytes, to_core=True)
                np.add.at(core_in, cores, if_bytes)

            # ---- ofmaps ------------------------------------------------------
            if ms.fd[2] >= 0:
                of_bytes = elems * bpe
                self._dram_flow(edge_bytes, dram_bytes, ms.fd[2], nodes,
                                of_bytes.astype(float), to_core=False)
                np.add.at(core_out, cores, of_bytes)

        return GroupAnalysis(
            arch=arch, batch_unit=bu, core_macs=core_macs,
            edge_bytes=edge_bytes, edge_bytes_amortized=edge_amort,
            dram_bytes=dram_bytes, dram_bytes_amortized=dram_amort,
            core_glb_need=glb_need, core_in_bytes=core_in,
            core_out_bytes=core_out, weight_dram_bytes_total=weight_total,
            layer_parts=regions_of)

    # -- pieces ---------------------------------------------------------------
    def _external_ifmap_bytes(self, lyr: Layer, rarr: np.ndarray,
                              bu: int) -> np.ndarray:
        """Elements of DNN-level input each core must fetch (halo included)."""
        s = lyr.stride
        dh = (rarr[:, 1] - rarr[:, 0]) * s + (lyr.R - 1)
        dw = (rarr[:, 3] - rarr[:, 2]) * s + (lyr.S - 1)
        db = rarr[:, 5] - rarr[:, 4]
        if lyr.kind in ("eltwise", "pool", "depthwise"):
            dk = (rarr[:, 7] - rarr[:, 6]) * (lyr.n_inputs if lyr.kind == "eltwise" else 1)
        elif lyr.kind == "matmul":
            # both operands streamed: rows of A for H-range + full B operand share
            dk = np.full(len(rarr), lyr.C, dtype=np.int64)
            return (rarr[:, 1] - rarr[:, 0]) * db * lyr.C \
                + (rarr[:, 7] - rarr[:, 6]) * db * lyr.C
        else:
            dk = np.full(len(rarr), max(1, lyr.C), dtype=np.int64)
        return dh * dw * db * dk

    def _dram_flow(self, edge_bytes: np.ndarray, dram_bytes: np.ndarray,
                   fd: int, nodes: np.ndarray, vols: np.ndarray,
                   to_core: bool) -> None:
        """Route core<->DRAM volumes.  fd==0 interleaves over all ports."""
        vols = np.asarray(vols, dtype=float)
        if np.ndim(vols) == 0:
            vols = np.full(len(nodes), float(vols))
        if fd == 0:
            share = vols / self.arch.n_dram
            for d in range(self.arch.n_dram):
                dn = np.full(len(nodes), self._dram_nodes[d])
                if to_core:
                    self._route(edge_bytes, dn, nodes, share)
                else:
                    self._route(edge_bytes, nodes, dn, share)
                dram_bytes[d] += float(share.sum())
        else:
            d = fd - 1
            dn = np.full(len(nodes), self._dram_nodes[d])
            if to_core:
                self._route(edge_bytes, dn, nodes, vols)
            else:
                self._route(edge_bytes, nodes, dn, vols)
            dram_bytes[d] += float(vols.sum())

    def _dep_traffic(self, edge_bytes: np.ndarray, core_in: np.ndarray,
                     core_out: np.ndarray, prod: Layer,
                     prod_regs: Dict[int, Region], cons: Layer,
                     cons_regs: Dict[int, Region], bu: int) -> None:
        """Producer->consumer on-chip flow with K-multicast grouping.

        Consumers whose needed region is identical (K-partition siblings for
        channel-contracting layers) form one multicast set per producer part.
        """
        p_cores, p_arr = _regions_to_array(prod_regs)
        c_cores, c_arr = _regions_to_array(cons_regs)
        bpe = prod.bytes_per_elem

        # needed region of each consumer part, in producer-ofmap coordinates
        need = np.empty_like(c_arr)
        for i, cc in enumerate(c_cores):
            r = cons_regs[cc]
            nr = ifmap_region(cons, r, prod.K)
            need[i] = [nr.h0, nr.h1, nr.w0, nr.w1, nr.b0, nr.b1, nr.k0, nr.k1]

        ov = _overlap_matrix(p_arr, need)        # (P, Q) elems
        if not ov.any():
            return
        p_nodes = self._core_nodes[p_cores]
        c_nodes = self._core_nodes[c_cores]

        contracting = cons.kind in ("conv", "fc", "matmul")
        if contracting:
            # group consumer parts by identical 'need' signature -> multicast
            sig = [tuple(row) for row in need]
            groups: Dict[Tuple, List[int]] = {}
            for qi, s in enumerate(sig):
                groups.setdefault(s, []).append(qi)
            for s, qis in groups.items():
                vols = ov[:, qis[0]].astype(float) * bpe   # same for all members
                for pi in np.nonzero(vols)[0]:
                    dsts = [int(c_nodes[q]) for q in qis
                            if c_nodes[q] != p_nodes[pi]]
                    self._route_multicast(edge_bytes, int(p_nodes[pi]),
                                          dsts, float(vols[pi]))
                    core_out[p_cores[pi]] += vols[pi] * (1 if dsts else 0)
                    for q in qis:
                        if c_nodes[q] != p_nodes[pi]:
                            core_in[c_cores[q]] += vols[pi]
        else:
            vols = ov.astype(float) * bpe
            same = p_nodes[:, None] == c_nodes[None, :]
            vols_off = np.where(same, 0.0, vols)
            P, Q = vols.shape
            self._route(edge_bytes,
                        np.repeat(p_nodes, Q), np.tile(c_nodes, P),
                        vols_off.reshape(-1))
            np.add.at(core_out, p_cores, vols_off.sum(axis=1))
            np.add.at(core_in, c_cores, vols_off.sum(axis=0))


def d2d_hop_stats(arch: ArchConfig, analyses: Sequence[GroupAnalysis]) -> Dict[str, float]:
    """Totals used by the Fig. 9 style reporting."""
    grid = router_grid(arch)
    tot = sum(float(a.edge_bytes.sum()) for a in analyses)
    d2d = sum(float(a.edge_bytes[grid.edge_is_d2d].sum()) for a in analyses)
    return {"total_hop_bytes": tot, "d2d_hop_bytes": d2d,
            "d2d_fraction": d2d / tot if tot else 0.0}
