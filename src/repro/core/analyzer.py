"""LP-SPM Analyzer: parse an LMS into core workloads + link/DRAM traffic.

This is the paper's "LP SPM Analyzer" box (Fig. 4).  Given a layer group, an
``LMS`` and an ``ArchConfig`` it produces:

  * per-core compute work (MACs) and buffer footprints,
  * per-directed-link feature-map traffic (bytes per pipeline pass) under XY
    routing with multicast trees (cores needing *identical* data — e.g. the
    K-partitioned consumers of one producer part — share one tree),
  * per-DRAM-port traffic, split by interleaving when FD == 0,
  * weight-load traffic (amortized over passes).

Everything is vectorized with numpy; the router paths for all node pairs are
precomputed per ``ArchConfig`` and cached, because the SA engine calls this
millions of times.

Incremental evaluation: the analysis decomposes into per-layer contributions
(MACs, GLB footprint, weight/ifmap/ofmap DRAM flows) and per-dependency-edge
contributions (producer->consumer NoC flows), each a pure function of the
involved layers' frozen ``MS`` entries.  Both are recorded as scatter-add
streams and memoized, so when an SA operator touches one layer only that
layer's contribution and its incident edges are recomputed — every other
stream replays from cache.  Replaying a stream with ``np.add.at`` (unbuffered,
applied in index order) reproduces the exact float-add sequence of a direct
computation, keeping cached and uncached results bit-identical.

Expected-traffic formulation (PR 6): per-layer ``traffic_scale`` /
``weight_traffic_scale`` and per-edge multiplicities multiply the recorded
contributions (MACs, compute time, GLB fmap footprint/traffic, DRAM flows,
dependency-edge volumes) at the recording sites in ``_layer_contribs`` and
``_dep_traffic``.  Both the scalar and the batched path share
``_gather_stream``, so they inherit the scaling identically, and every
multiplication is guarded behind ``scale != 1.0`` — graphs with all scales
at 1.0 replay the byte-for-byte pre-refactor streams.  The evaluator needs
no change: its energy/delay math only reads the (already scaled)
``GroupAnalysis`` arrays.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..obs import metrics as _obs_metrics
from .encoding import (LMS, LMSBatch, MS, Region, parse_regions_arrays,
                       unpack_lms_batch)
from .hw import ArchConfig
from .intra_core import explore_intra_core_many
from .workload import Graph, Layer, LayerGroup


# jitted segment-sum replay of the opt-in ``backend="jax"`` batch path;
# built lazily so importing the analyzer never pulls in jax
_JAX_REPLAY_FN = None


def _jax_replay(idx: np.ndarray, vals: np.ndarray, n: int) -> np.ndarray:
    # refuse silently-wrong inputs up front: jax's x32 default would
    # truncate int64/float64 streams without complaint, so a caller handing
    # us the wrong dtypes gets a TypeError, not a quietly lossy replay
    if idx.dtype != np.int64:
        raise TypeError(
            f"jax replay needs an int64 index stream, got {idx.dtype}")
    if vals.dtype != np.float64:
        raise TypeError(
            f"jax replay needs a float64 value stream, got {vals.dtype}")
    global _JAX_REPLAY_FN
    if _JAX_REPLAY_FN is None:
        from functools import partial

        import jax
        import jax.numpy as jnp

        @partial(jax.jit, static_argnums=2)
        def _replay(i, v, length):
            return jax.ops.segment_sum(v, i, num_segments=length)

        def fn(i, v, length):
            return np.asarray(_replay(jnp.asarray(i), jnp.asarray(v), length),
                              dtype=np.float64)

        _JAX_REPLAY_FN = fn
    return _JAX_REPLAY_FN(idx, vals, n)


# ---------------------------------------------------------------------------
# Router geometry, cached per arch signature
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RouterGrid:
    n_nodes: int
    n_edges: int
    edge_is_d2d: np.ndarray          # (n_edges,) bool
    paths: np.ndarray                # (n_nodes, n_nodes, max_len) edge ids, -1 pad
    path_len: np.ndarray             # (n_nodes, n_nodes)
    hops_d2d: np.ndarray             # (n_nodes, n_nodes) number of D2D edges


def _build_grid(arch: ArchConfig) -> RouterGrid:
    gw, gh = arch.grid_w, arch.grid_h
    n_nodes = gw * gh
    # directed edges: id layout [east | west | south(+y) | north(-y)]
    n_h = (gw - 1) * gh
    n_v = gw * (gh - 1)
    n_edges = 2 * n_h + 2 * n_v

    def east_id(x, y):  return y * (gw - 1) + x            # (x,y)->(x+1,y)
    def west_id(x, y):  return n_h + y * (gw - 1) + (x - 1)  # (x,y)->(x-1,y)
    def south_id(x, y): return 2 * n_h + y * gw + x        # (x,y)->(x,y+1)
    def north_id(x, y): return 2 * n_h + n_v + (y - 1) * gw + x

    is_d2d = np.zeros(n_edges, dtype=bool)
    for y in range(gh):
        for x in range(gw - 1):
            d2d = arch.node_chiplet(y * gw + x) != arch.node_chiplet(y * gw + x + 1)
            is_d2d[east_id(x, y)] = d2d
            is_d2d[west_id(x + 1, y)] = d2d
    for y in range(gh - 1):
        for x in range(gw):
            d2d = arch.node_chiplet(y * gw + x) != arch.node_chiplet((y + 1) * gw + x)
            is_d2d[south_id(x, y)] = d2d
            is_d2d[north_id(x, y + 1)] = d2d

    max_len = (gw - 1) + (gh - 1)
    # int64 so gathered edge ids feed Contribution.add's fast path directly
    paths = np.full((n_nodes, n_nodes, max(max_len, 1)), -1, dtype=np.int64)
    plen = np.zeros((n_nodes, n_nodes), dtype=np.int32)
    hops_d2d = np.zeros((n_nodes, n_nodes), dtype=np.int32)
    for a in range(n_nodes):
        ay, ax = divmod(a, gw)
        for b in range(n_nodes):
            if a == b:
                continue
            by, bx = divmod(b, gw)
            e: List[int] = []
            x, y = ax, ay
            while x < bx:
                e.append(east_id(x, y)); x += 1
            while x > bx:
                e.append(west_id(x, y)); x -= 1
            while y < by:
                e.append(south_id(x, y)); y += 1
            while y > by:
                e.append(north_id(x, y)); y -= 1
            paths[a, b, :len(e)] = e
            plen[a, b] = len(e)
            hops_d2d[a, b] = int(is_d2d[e].sum()) if e else 0
    return RouterGrid(n_nodes, n_edges, is_d2d, paths, plen, hops_d2d)


_GRID_CACHE: Dict[Tuple, RouterGrid] = {}


def router_grid(arch: ArchConfig) -> RouterGrid:
    key = (arch.x_cores, arch.y_cores, arch.xcut, arch.ycut)
    if key not in _GRID_CACHE:
        _GRID_CACHE[key] = _build_grid(arch)
    return _GRID_CACHE[key]


# ---------------------------------------------------------------------------
# Analysis result
# ---------------------------------------------------------------------------

@dataclass
class GroupAnalysis:
    """Traffic/compute for ONE pipeline pass of one layer group."""
    arch: ArchConfig
    batch_unit: int
    core_macs: np.ndarray            # (n_cores,) MACs per pass
    edge_bytes: np.ndarray           # (n_edges,) NoC/D2D bytes per pass
    edge_bytes_amortized: np.ndarray  # weight loads etc., already / n_passes
    dram_bytes: np.ndarray           # (n_dram,) bytes per pass (fmap flows)
    dram_bytes_amortized: np.ndarray  # (n_dram,) weight loads / n_passes
    core_glb_need: np.ndarray        # (n_cores,) resident footprint bytes
    core_in_bytes: np.ndarray        # (n_cores,) fmap bytes received per pass
    core_out_bytes: np.ndarray       # (n_cores,) fmap bytes sent per pass
    weight_dram_bytes_total: float   # unamortized (for energy, counted once)
    # per-layer part tables for the intra-core engine
    layer_parts: Dict[str, Dict[int, Region]] = field(default_factory=dict)
    # filled by the incremental analyzer (None from the seed-reference path):
    # per-core intra-core compute seconds and the (GLB read, GLB write)
    # byte totals of the group's chosen core dataflows
    core_time_s: Optional[np.ndarray] = None    # (n_cores,)
    glb_rw_bytes: Optional[np.ndarray] = None   # (2,) read, write

    @property
    def total_hops_bytes(self) -> float:
        return float(self.edge_bytes.sum())

    @property
    def d2d_bytes(self) -> float:
        g = router_grid(self.arch)
        return float(self.edge_bytes[g.edge_is_d2d].sum())


@dataclass
class GroupAnalysisBatch:
    """B :class:`GroupAnalysis` rows sharing one flat ``(B, buf_len)``
    accumulator buffer.  ``analyses[b]``'s arrays are views of ``buf[b]``,
    so the batched evaluator can run its math once over the 2-D slices
    (``target``) while every row remains a full, cache-storable
    ``GroupAnalysis``."""
    analyses: List[GroupAnalysis]
    buf: np.ndarray                  # (B, buf_len)
    layout: List[Tuple[int, int]]    # per-T_* target (lo, hi) columns
    weight_totals: np.ndarray        # (B,)

    def target(self, t: int) -> np.ndarray:
        lo, hi = self.layout[t]
        return self.buf[:, lo:hi]


def _regions_to_array(regions: Dict[int, Region]) -> Tuple[np.ndarray, np.ndarray]:
    cores = np.array(sorted(regions), dtype=np.int64)
    arr = np.array([[regions[c].h0, regions[c].h1, regions[c].w0, regions[c].w1,
                     regions[c].b0, regions[c].b1, regions[c].k0, regions[c].k1]
                    for c in cores], dtype=np.int64)
    return cores, arr


def _overlap_matrix(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """(P,8) x (Q,8) region arrays -> (P,Q) overlap element counts."""
    lo = np.maximum(a[:, None, 0::2], b[None, :, 0::2])
    hi = np.minimum(a[:, None, 1::2], b[None, :, 1::2])
    d = hi - lo
    np.clip(d, 0, None, out=d)
    return d[..., 0] * d[..., 1] * d[..., 2] * d[..., 3]


def _shape_chunks(jobs: List, shape_fn, max_cells: int):
    """Split ``jobs`` into runs whose PADDED batch volume stays bounded.

    Jobs are sorted by shape first (similar shapes cluster, minimizing
    padding waste); a chunk of J jobs padded to the elementwise max of
    their ``shape_fn`` tuples costs ``J * prod(max_dims)`` cells, and the
    greedy scan cuts before that cost crosses the cap.  A single
    oversized job still forms its own chunk (it pads to itself, so the
    batched path degenerates to the scalar footprint, never worse).
    """
    if not jobs:
        return
    jobs = sorted(jobs, key=lambda j: tuple(shape_fn(j)))
    chunk: List = []
    dims: Tuple[int, ...] = ()
    for job in jobs:
        s = tuple(shape_fn(job))
        nd = tuple(map(max, dims, s)) if chunk else s
        cost = (len(chunk) + 1) * int(np.prod(nd))
        if chunk and cost > max_cells:
            yield chunk
            chunk, dims = [job], s
        else:
            chunk.append(job)
            dims = nd
    yield chunk


# ---------------------------------------------------------------------------
# Recorded scatter-add contributions
# ---------------------------------------------------------------------------

# accumulation targets a contribution may write (int-indexed: stream
# dispatch happens hundreds of thousands of times per SA run).  CORE_TIME
# and GLB_RW carry the intra-core engine's per-core compute seconds and
# the (read, write) GLB byte totals, so one cached stream replay yields
# the full GroupEval input.
(T_CORE_MACS, T_EDGE, T_EDGE_AM, T_DRAM, T_DRAM_AM,
 T_GLB, T_CORE_IN, T_CORE_OUT, T_CORE_TIME, T_GLB_RW) = range(10)
_N_TARGETS = 10


class Contribution:
    """A recorded sequence of scatter-adds onto the analysis accumulators.

    ``add`` records (target, indices, values) in call order; ``seal``
    shifts the indices by the per-target offsets into the analyzer's one
    flat accumulator buffer and concatenates everything into a single
    (idx, vals) stream.  Replaying with ``np.add.at`` — unbuffered,
    repeated indices applied in order — reproduces the exact float-add
    sequence of the recording computation: targets never share a buffer
    cell, and per-cell add order is the add-call order either way.
    """

    __slots__ = ("_parts", "flat_idx", "flat_vals", "weight_total")

    _EMPTY_I = np.empty(0, dtype=np.int64)
    _EMPTY_V = np.empty(0, dtype=np.float64)

    def __init__(self) -> None:
        self._parts: List[Tuple[int, np.ndarray, np.ndarray]] = []
        self.flat_idx: np.ndarray = self._EMPTY_I
        self.flat_vals: np.ndarray = self._EMPTY_V
        self.weight_total = 0.0

    def add(self, target: int, idx, vals) -> None:
        # fast path: well-formed arrays (the overwhelming majority of the
        # call sites) skip the conversion checks — this method runs tens of
        # thousands of times per SA second
        if not (type(idx) is np.ndarray and idx.dtype == np.int64
                and idx.ndim == 1):
            idx = np.asarray(idx, dtype=np.int64)
            if idx.ndim != 1:
                idx = idx.reshape(-1)
        if idx.size == 0:
            return
        if not (type(vals) is np.ndarray and vals.dtype == np.float64
                and vals.ndim == 1 and vals.size == idx.size):
            vals = np.asarray(vals, dtype=np.float64)
            if vals.ndim == 0:
                vals = np.broadcast_to(vals, idx.shape)
            elif vals.ndim != 1:
                vals = vals.reshape(-1)
        self._parts.append((target, idx, vals))

    def seal(self, offsets: Sequence[int]) -> "Contribution":
        if self._parts:
            idxs = [i if offsets[t] == 0 else i + offsets[t]
                    for t, i, _ in self._parts]
            self.flat_idx = idxs[0] if len(idxs) == 1 else np.concatenate(idxs)
            self.flat_vals = self._parts[0][2] if len(self._parts) == 1 \
                else np.concatenate([v for _, _, v in self._parts])
        self._parts = []
        return self

    def collect(self, out_i: List[np.ndarray],
                out_v: List[np.ndarray]) -> None:
        """Append this contribution's flat stream to the gather lists; the
        caller concatenates once and replays with one ``np.add.at``."""
        if self.flat_idx.size:
            out_i.append(self.flat_idx)
            out_v.append(self.flat_vals)

    @classmethod
    def from_flat(cls, idx: np.ndarray, vals: np.ndarray,
                  weight_total: float = 0.0) -> "Contribution":
        """Wrap an ALREADY-SEALED stream (offsets applied, chunks
        concatenated in add order) without the add/seal machinery — the
        batched builders construct whole streams as slices of one pooled
        array, and per-piece add/seal dispatch would dominate their
        runtime."""
        c = cls.__new__(cls)
        c._parts = []
        c.flat_idx = idx
        c.flat_vals = vals
        c.weight_total = weight_total
        return c


class _LRU(dict):
    """Tiny bounded LRU dict for memoizing contributions and geometry.

    ``get`` refreshes recency (a plain dict keeps insertion order, so a
    hit re-inserts its entry at the end); ``put`` evicts the least
    recently used entry at the cap.  The refresh costs one delete + one
    re-insert per hit — noise next to the array work a hit saves — and
    it is what keeps hot shared geometry (``_GEO_CACHE``) resident across
    large multi-candidate sweeps instead of being FIFO-evicted by
    one-shot entries.
    """

    __slots__ = ("maxsize",)
    _MISS = object()

    def __init__(self, maxsize: int):
        super().__init__()
        self.maxsize = maxsize

    def get(self, key, default=None):
        val = dict.get(self, key, _LRU._MISS)
        if val is _LRU._MISS:
            return default
        # recency order only matters once eviction is in sight; below
        # half-fill a hit skips the refresh entirely, keeping the hot
        # all-hits path at plain-dict cost
        if len(self) * 2 >= self.maxsize:
            del self[key]
            dict.__setitem__(self, key, val)
        return val

    def put(self, key, value):
        if key not in self and len(self) >= self.maxsize:
            self.pop(next(iter(self)))
        self[key] = value
        return value


class _StatLRU(_LRU):
    """:class:`_LRU` + native hit/miss/eviction counters.

    Used only for the process-wide ``_GEO_CACHE``: that table is consulted
    on *first-level* cache misses, so the extra integer increments sit off
    the hot all-hits path.  The per-analyzer first-level caches stay plain
    ``_LRU`` — instrumenting them would tax every analyze call.  The obs
    layer harvests these through a collector at snapshot time; nothing
    here ever checks the ``REPRO_OBS`` switch.
    """

    __slots__ = ("hits", "misses", "evictions")

    def __init__(self, maxsize: int):
        super().__init__(maxsize)
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key, default=None):
        val = dict.get(self, key, _LRU._MISS)
        if val is _LRU._MISS:
            self.misses += 1
            return default
        self.hits += 1
        if len(self) * 2 >= self.maxsize:
            del self[key]
            dict.__setitem__(self, key, val)
        return val

    def put(self, key, value):
        if key not in self and len(self) >= self.maxsize:
            self.pop(next(iter(self)))
            self.evictions += 1
        self[key] = value
        return value


def _geo_cache_cap(default: int = 262_144) -> int:
    """Size cap of the process-wide geometry cache.

    Overridable via ``REPRO_GEO_CACHE_CAP`` (entries, not bytes) so
    memory-constrained sweeps can shrink it; evicted entries rebuild
    bit-identically (pure geometry), so the cap only trades memory for
    recompute time.
    """
    raw = os.environ.get("REPRO_GEO_CACHE_CAP", "")
    try:
        cap = int(raw)
    except ValueError:
        return default
    return cap if cap > 0 else default


# Process-wide second-level cache for PURE LAYER GEOMETRY artifacts (region
# tables, needed-ifmap rows, sibling labels, overlap counts, intra-core
# dataflow stats).  These depend only on frozen Layer content + Part (+ the
# few arch constants in their keys), never on the graph or the core
# binding, so every Analyzer — across SA chains, sweep candidates and
# fresh evaluators — shares one copy.  Per-analyzer first-level caches
# keep the hot hit path on small-int keys; this table is consulted (and
# filled) only on a first-level miss, paying one frozen-dataclass hash.
# Entries are read-only by contract.  Bounded (true LRU, cap overridable
# via REPRO_GEO_CACHE_CAP) so unbounded multi-candidate sweeps cannot grow
# it without limit; evictions only ever cost recompute time.
_GEO_CACHE = _StatLRU(_geo_cache_cap())

# Batched-vs-scalar contribution construction counts: how much of the
# stream building went through the vectorized prefetch builders
# (``_prefetch_contribs``) vs the scalar fallbacks — the ratio the
# ROADMAP's in-jit-construction work needs to watch.  Native increments
# (one per *built* piece, i.e. per first-level cache miss), harvested by
# the obs collector below.
PREFETCH_STATS: Dict[str, int] = {
    "prefetch.batched_builds": 0,
    "prefetch.scalar_builds": 0,
}

_obs_metrics.register_collector(lambda: {
    "geo_cache.hits": _GEO_CACHE.hits,
    "geo_cache.misses": _GEO_CACHE.misses,
    "geo_cache.evictions": _GEO_CACHE.evictions,
    **PREFETCH_STATS,
})
_obs_metrics.register_collector(lambda: {
    "geo_cache.size": len(_GEO_CACHE),
    "geo_cache.cap": _GEO_CACHE.maxsize,
}, kind="gauge")


class Analyzer:
    """Stateful per-(arch, graph) analyzer; reused across SA iterations."""

    def __init__(self, arch: ArchConfig, g: Graph, cache_size: int = 50_000):
        self.arch = arch
        self.g = g
        self.grid = router_grid(arch)
        self._core_nodes = np.array(
            [arch.core_node(c) for c in range(arch.n_cores)], dtype=np.int64)
        self._dram_nodes = np.array(
            [arch.dram_node(d) for d in range(1, arch.n_dram + 1)], dtype=np.int64)
        # (src, dst) -> PACKED edge membership of the XY path (uint64
        # bitsets, bit e of word e // 64 = edge e): turns the per-multicast
        # path-union into a gather + bitwise-OR reduce at 1/8th the memory
        # traffic of a boolean mask.  Bit order relies on little-endian
        # uint64 <-> uint8 views (every supported target); gate on size
        # (fall back to sorting above on absurd grids).
        grid = self.grid
        import sys as _sys
        n_words = -(-grid.n_edges // 64)
        if (_sys.byteorder == "little"
                and grid.n_nodes * grid.n_nodes * n_words * 8 <= 64_000_000):
            bits = np.zeros((grid.n_nodes, grid.n_nodes, n_words),
                            dtype=np.uint64)
            ii, jj, kk = np.nonzero(grid.paths >= 0)
            ee = grid.paths[ii, jj, kk]
            np.bitwise_or.at(bits, (ii, jj, ee // 64),
                             np.uint64(1) << (ee % 64).astype(np.uint64))
            self._path_bits: Optional[np.ndarray] = bits
        else:
            self._path_bits = None
        # intern small ints for layers/groups: cache keys hash ints, not
        # string tuples
        self._layer_idx = {name: i for i, name in enumerate(g.layers)}
        self._group_ids: Dict[Tuple[str, ...], int] = {}
        # one flat accumulator buffer; analyze() zero-fills and slices it,
        # in T_* target order
        nc, ne, nd = arch.n_cores, self.grid.n_edges, arch.n_dram
        bounds = np.cumsum([0, nc, ne, ne, nd, nd, nc, nc, nc, nc, 2])
        self._layout = [(int(bounds[i]), int(bounds[i + 1]))
                        for i in range(_N_TARGETS)]
        self._offsets = [lo for lo, _ in self._layout]
        self._buf_len = int(bounds[-1])
        # memo tables for the incremental path
        self._table_cache = _LRU(cache_size)      # region geometry (per Part)
        self._regions_cache = _LRU(cache_size)
        self._rarr_cache = _LRU(cache_size)       # regions as (cores, array)
        self._node_cache = _LRU(cache_size)       # region cores -> grid nodes
        self._needgeo_cache = _LRU(cache_size)    # need rows (per Part)
        self._needgrp_cache = _LRU(cache_size)    # sibling labels (per Part)
        self._ov_cache = _LRU(cache_size)         # overlap counts (per Part)
        self._intra_cache = _LRU(cache_size)      # intra-core t/rd/wr (per Part)
        self._need_cache = _LRU(cache_size)       # consumer need regions
        self._layer_cache = _LRU(cache_size)      # (pre, post) contributions
        self._dep_cache = _LRU(cache_size)
        self._topo_cache = _LRU(cache_size)       # per-group internal preds
        self._row_cache = _LRU(cache_size)        # fused path: f32 row streams
        self._lmath_cache = _LRU(cache_size)      # per-layer value math (per Part)
        # pre-offset DRAM accumulator indices for the batched builders
        self._dram_iota = np.arange(nd, dtype=np.int64) + self._offsets[T_DRAM]
        self._dram_iota_am = np.arange(nd, dtype=np.int64) \
            + self._offsets[T_DRAM_AM]

    # -- routing helpers -----------------------------------------------------
    def _route(self, contrib: Contribution, target: int, src_nodes: np.ndarray,
               dst_nodes: np.ndarray, vols: np.ndarray) -> None:
        """Record unicast volumes onto edge loads (vectorized).

        Zero-volume rows are routed too (their edge cells receive exact
        ``+0.0`` no-ops, so the replayed sums are bit-identical to
        filtering them out) — dropping the positivity filter saves four
        array ops on a path hot enough for that to matter."""
        paths = self.grid.paths[src_nodes, dst_nodes]   # (n, max_len)
        flat = paths.reshape(-1)
        keep = flat >= 0
        contrib.add(target, flat[keep], np.repeat(vols, paths.shape[1])[keep])

    def _route_multicast(self, contrib: Contribution, target: int,
                         src_node: int, dst_nodes: Sequence[int],
                         vol: float) -> None:
        """One producer datum to many consumers: union of XY paths, counted once."""
        if vol <= 0 or not len(dst_nodes):
            return
        paths = self.grid.paths[src_node, np.asarray(dst_nodes, dtype=np.int64)]
        edges = np.unique(paths[paths >= 0])
        contrib.add(target, edges, vol)

    # -- cached pieces ---------------------------------------------------------
    # Region GEOMETRY (the rows of the Correspondence-Rule table, the needed
    # ifmap regions, the producerxconsumer overlap counts) depends only on a
    # layer's Part, never on its CG — core swaps (SA OP2/OP3) reuse it all.
    # Only the core BINDING (which core holds which row) involves the CG.

    def region_geometry(self, name: str, part: Tuple[int, ...],
                        bu: int) -> np.ndarray:
        """Region rows (N, 8) in correspondence order; row i -> CG[i]."""
        key = (self._layer_idx[name], part, bu)
        hit = self._table_cache.get(key)
        if hit is None:
            lyr = self.g.layers[name]
            gkey = ("rg", lyr, part, bu)
            hit = _GEO_CACHE.get(gkey)
            if hit is None:
                ms = MS(part=part, cg=tuple(range(int(np.prod(part)))),
                        fd=(-1, -1, -1))
                _, rarr = parse_regions_arrays(ms, lyr, bu)
                hit = _GEO_CACHE.put(gkey, rarr)
            self._table_cache.put(key, hit)
        return hit

    def region_table(self, name: str, ms: MS, bu: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """(cores, region rows) in correspondence order (unsorted)."""
        return (np.asarray(ms.cg, dtype=np.int64),
                self.region_geometry(name, ms.part, bu))

    def regions(self, name: str, ms: MS, bu: int) -> Dict[int, Region]:
        key = (self._layer_idx[name], ms.geo, bu)
        hit = self._regions_cache.get(key)
        if hit is None:
            cores, rarr = self.region_table(name, ms, bu)
            hit = self._regions_cache.put(
                key, {c: Region(*row)
                      for c, row in zip(cores.tolist(), rarr.tolist())})
        return hit

    def _region_arrays(self, name: str, ms: MS, bu: int
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(cores sorted, region rows sorted by core, correspondence->sorted
        permutation)."""
        key = (self._layer_idx[name], ms.geo, bu)
        hit = self._rarr_cache.get(key)
        if hit is None:
            cores, rarr = self.region_table(name, ms, bu)
            order = np.argsort(cores)
            hit = self._rarr_cache.put(key,
                                       (cores[order], rarr[order], order))
        return hit

    def _region_nodes(self, name: str, ms: MS, bu: int) -> np.ndarray:
        key = (self._layer_idx[name], ms.geo, bu)
        hit = self._node_cache.get(key)
        if hit is None:
            cores, _, _ = self._region_arrays(name, ms, bu)
            hit = self._node_cache.put(key, self._core_nodes[cores])
        return hit

    def _need_geometry(self, cname: str, c_part: Tuple[int, ...], bu: int,
                       prod_K: int) -> np.ndarray:
        """Needed producer-ofmap regions (correspondence order)."""
        key = (self._layer_idx[cname], c_part, bu, prod_K)
        hit = self._needgeo_cache.get(key)
        if hit is None:
            cons = self.g.layers[cname]
            gkey = ("need", cons, c_part, bu, prod_K)
            hit = _GEO_CACHE.get(gkey)
            if hit is None:
                hit = _GEO_CACHE.put(
                    gkey, self._ifmap_regions(cons,
                                              self.region_geometry(
                                                  cname, c_part, bu), prod_K))
            self._needgeo_cache.put(key, hit)
        return hit

    def _intra_geometry(self, name: str, part: Tuple[int, ...], bu: int
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-region (compute seconds, GLB read bytes, GLB write bytes) of
        the chosen intra-core dataflows, in correspondence order.  Geometry
        only: row i belongs to whatever core CG[i] names."""
        key = (self._layer_idx[name], part, bu)
        hit = self._intra_cache.get(key)
        if hit is not None:
            return hit
        arch, lyr = self.arch, self.g.layers[name]
        gkey = ("intra", lyr, part, bu, arch.core_glb_bytes,
                arch.macs_per_core, arch.freq_ghz)
        hit = _GEO_CACHE.get(gkey)
        if hit is None:
            rarr = self.region_geometry(name, part, bu)
            spans = rarr[:, 1::2] - rarr[:, 0::2]       # (N, 4): h, w, b, k
            elems = spans[:, 0] * spans[:, 1] * spans[:, 2] * spans[:, 3]
            rk = spans[:, 3]
            hwb = np.maximum(1, elems // np.maximum(1, rk))
            bpe = lyr.bytes_per_elem
            sigs = [(int(rk[i]), lyr.C, int(hwb[i]), lyr.R, lyr.S, bpe,
                     arch.core_glb_bytes, arch.macs_per_core, lyr.kind)
                    for i in range(len(rarr))]
            dfs = explore_intra_core_many(sigs)
            n = len(dfs)
            util = np.fromiter((df.utilization for df in dfs), np.float64, n)
            rd = np.fromiter((df.glb_read_bytes for df in dfs), np.float64, n)
            wr = np.fromiter((df.glb_write_bytes for df in dfs), np.float64, n)
            mac_per_elem = lyr.macs(1) / max(1, lyr.ofmap_elems)
            peak = arch.macs_per_core * arch.freq_ghz * 1e9
            t = (elems * mac_per_elem) / (peak * np.maximum(util, 1e-3))
            hit = _GEO_CACHE.put(gkey, (t, rd, wr))
        self._intra_cache.put(key, hit)
        return hit

    def _overlap_geometry(self, pname: str, p_part: Tuple[int, ...],
                          cname: str, c_part: Tuple[int, ...], bu: int,
                          prod_K: int) -> Tuple[np.ndarray, bool]:
        """(overlap counts in correspondence order, any-nonzero flag)."""
        key = (self._layer_idx[pname], p_part,
               self._layer_idx[cname], c_part, bu, prod_K)
        hit = self._ov_cache.get(key)
        if hit is None:
            gkey = ("ov", self.g.layers[pname], p_part,
                    self.g.layers[cname], c_part, bu, prod_K)
            hit = _GEO_CACHE.get(gkey)
            if hit is None:
                ov = _overlap_matrix(self.region_geometry(pname, p_part, bu),
                                     self._need_geometry(cname, c_part, bu,
                                                         prod_K))
                hit = _GEO_CACHE.put(gkey, (ov, bool(ov.any())))
            self._ov_cache.put(key, hit)
        return hit

    @staticmethod
    def _ifmap_regions(cons: Layer, c_arr: np.ndarray,
                       prod_K: int) -> np.ndarray:
        """Vectorized :func:`repro.core.encoding.ifmap_region` over the rows
        of a consumer region table — same integer arithmetic per kind."""
        need = c_arr.copy()
        if cons.kind in ("eltwise",):
            return need
        s = cons.stride
        if cons.kind in ("pool", "depthwise"):
            need[:, 0] = c_arr[:, 0] * s
            need[:, 1] = np.minimum(c_arr[:, 1] * s + cons.R - 1, cons.H * s)
            need[:, 2] = c_arr[:, 2] * s
            need[:, 3] = np.minimum(c_arr[:, 3] * s + cons.S - 1, cons.W * s)
            return need
        # conv / fc / matmul: full channel contraction
        h_in = cons.H * s
        w_in = cons.W * s
        need[:, 0] = np.minimum(c_arr[:, 0] * s, h_in - 1)
        need[:, 1] = np.minimum(c_arr[:, 1] * s + cons.R - 1, h_in)
        need[:, 2] = np.minimum(c_arr[:, 2] * s, w_in - 1)
        need[:, 3] = np.minimum(c_arr[:, 3] * s + cons.S - 1, w_in)
        need[:, 6] = 0
        need[:, 7] = prod_K
        return need

    def _need_labels(self, cname: str, c_part: Tuple[int, ...], bu: int,
                     prod_K: int) -> np.ndarray:
        """Sibling-equivalence label per correspondence-order need row
        (rows with identical content share a label).  Pure geometry —
        cached per Part, so the per-CG grouping below reduces to integer
        ops on a permutation of these labels."""
        key = (self._layer_idx[cname], c_part, bu, prod_K)
        hit = self._needgrp_cache.get(key)
        if hit is None:
            gkey = ("lbl", self.g.layers[cname], c_part, bu, prod_K)
            hit = _GEO_CACHE.get(gkey)
            if hit is None:
                need_geo = self._need_geometry(cname, c_part, bu, prod_K)
                _, inv = np.unique(need_geo, axis=0, return_inverse=True)
                hit = _GEO_CACHE.put(gkey, inv.reshape(-1).astype(np.int64))
            self._needgrp_cache.put(key, hit)
        return hit

    def _need_arrays(self, cname: str, cms: MS, bu: int, prod_K: int
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Needed producer-ofmap region per consumer part (sorted-core order),
        plus the multicast grouping: consumer parts with identical need rows
        (K-partition siblings) as a padded member matrix.

        Returns (need (Q,8), first (G,) first member of each sibling group in
        first-seen order, members (G,Qmax) member indices padded with -1).

        The grouping reproduces the historical dict-of-lists scan exactly
        — groups enumerate in first-seen order over the sorted-core
        positions, members ascending within a group — but runs as a
        handful of integer-array ops on the cached per-Part sibling
        labels instead of a Python loop over row tuples."""
        key = (self._layer_idx[cname], cms.geo, bu, prod_K)
        hit = self._need_cache.get(key)
        if hit is None:
            c_cores, _, c_ord = self._region_arrays(cname, cms, bu)
            need = self._need_geometry(cname, cms.part, bu, prod_K)[c_ord]
            labels = self._need_labels(cname, cms.part, bu, prod_K)[c_ord]
            uniq, first_pos = np.unique(labels, return_index=True)
            order = np.argsort(first_pos, kind="stable")   # first-seen order
            G = len(uniq)
            rank = np.empty(int(uniq.max()) + 1 if G else 1, dtype=np.int64)
            rank[uniq[order]] = np.arange(G)
            r = rank[labels]                   # group row per position
            counts = np.bincount(r, minlength=G).astype(np.int64)
            qmax = int(counts.max()) if G else 0
            ordered = np.argsort(r, kind="stable")   # grouped, qi ascending
            off = np.concatenate(([0], np.cumsum(counts)[:-1])).astype(
                np.int64) if G else np.zeros(0, np.int64)
            members = np.full((G, qmax), -1, dtype=np.int64)
            rr = r[ordered]
            members[rr, np.arange(len(rr)) - off[rr]] = ordered
            first = members[:, 0].copy() if qmax else np.zeros(0, np.int64)
            pad = members < 0
            c_nodes = self._region_nodes(cname, cms, bu)
            cn = np.where(pad, -1, c_nodes[members])
            hit = self._need_cache.put(key, (need, first, members, cn, ~pad))
        return hit

    def _layer_contribs(self, name: str, ms: MS, bu: int, n_passes: int,
                        group: LayerGroup,
                        gid: int) -> Tuple[Contribution, Contribution]:
        """(pre, post) contributions of one layer: pre = MACs + GLB footprint +
        weight loads; post = external-ifmap and ofmap DRAM flows.  The split
        preserves the accumulation order of the monolithic loop, where
        dependency traffic sits between the two."""
        key = (self._layer_idx[name], ms, bu, n_passes, gid)
        hit = self._layer_cache.get(key)
        if hit is not None:
            return hit
        PREFETCH_STATS["prefetch.scalar_builds"] += 1
        g, in_group = self.g, set(group.names)
        lyr = g.layers[name]
        cores, rarr, _ = self._region_arrays(name, ms, bu)
        nodes = self._core_nodes[cores]
        bpe = lyr.bytes_per_elem
        # expected-traffic scales: activations/compute (ts) and weight
        # loads (ws).  Every application below is guarded behind != 1.0,
        # so a dense layer's float-op sequence is exactly the pre-scale
        # one — the bit-identity contract of the expected-traffic IR.
        ts = lyr.traffic_scale
        ws = lyr.weight_traffic_scale

        pre = Contribution()
        post = Contribution()

        # compute: MACs proportional to (expected) ofmap share
        elems = (rarr[:, 1] - rarr[:, 0]) * (rarr[:, 3] - rarr[:, 2]) \
            * (rarr[:, 5] - rarr[:, 4]) * (rarr[:, 7] - rarr[:, 6])
        mac_per_elem = lyr.macs(1) / max(1, lyr.ofmap_elems)
        macs_v = elems * mac_per_elem
        if ts != 1.0:
            macs_v = macs_v * ts
        pre.add(T_CORE_MACS, cores, macs_v)

        # GLB footprint: weight slice + ofmap part (double-buffered fmaps);
        # the fmap share is expected-resident, the weight slice stays dense
        # (it must be held regardless of routing)
        w_share = lyr.weight_bytes() / max(1, ms.part[3]) if lyr.has_weight else 0
        fmap_foot = elems * bpe * 2
        if ts != 1.0:
            fmap_foot = fmap_foot * ts
        pre.add(T_GLB, cores, fmap_foot + w_share)

        # intra-core engine: per-core compute time + GLB traffic of the
        # chosen dataflows, in correspondence order (the order the scalar
        # engine iterated regions in); pure geometry, cached per Part —
        # the expected scale multiplies outside the cache, so equal-dims
        # layers with different scales share the geometry entry content
        t_arr, rd, wr = self._intra_geometry(name, ms.part, bu)
        if ts != 1.0:
            t_arr = t_arr * ts
            rd = rd * ts
            wr = wr * ts
        u_cores = np.asarray(ms.cg, dtype=np.int64)
        pre.add(T_CORE_TIME, u_cores, t_arr)
        zeros = np.zeros(len(rd), dtype=np.int64)
        pre.add(T_GLB_RW, zeros, rd)
        pre.add(T_GLB_RW, zeros + 1, wr)

        # ---- weights: DRAM -> core, amortized over passes ----------------
        if lyr.has_weight:
            # each core holds the K-slice of its region (C,R,S full)
            k_span = (rarr[:, 7] - rarr[:, 6])
            w_bytes_core = k_span / max(1, lyr.K) * lyr.weight_bytes()
            if ws != 1.0:
                w_bytes_core = w_bytes_core * ws
            pre.weight_total = float(w_bytes_core.sum())
            self._dram_flow(pre, T_EDGE_AM, T_DRAM_AM, ms.fd[1], nodes,
                            w_bytes_core / n_passes, to_core=True)

        # ---- ifmaps (external only; internal deps are edge contributions) --
        preds = [p for p in g.preds(name)]
        external = (not preds) or any(p not in in_group for p in preds)
        if external and ms.fd[0] >= 0:
            # expected needed ifmap from DRAM (input of DNN or previous
            # group): the layer only fetches the tokens it processes
            if_bytes = self._external_ifmap_bytes(lyr, rarr, bu) * bpe
            if ts != 1.0:
                if_bytes = if_bytes * ts
            self._dram_flow(post, T_EDGE, T_DRAM, ms.fd[0], nodes,
                            if_bytes, to_core=True)
            post.add(T_CORE_IN, cores, if_bytes)

        # ---- ofmaps ------------------------------------------------------
        if ms.fd[2] >= 0:
            of_bytes = elems * bpe
            if ts != 1.0:
                of_bytes = of_bytes * ts
            self._dram_flow(post, T_EDGE, T_DRAM, ms.fd[2], nodes,
                            of_bytes.astype(float), to_core=False)
            post.add(T_CORE_OUT, cores, of_bytes)

        return self._layer_cache.put(
            key, (pre.seal(self._offsets), post.seal(self._offsets)))

    def _dep_contrib(self, pname: str, pms: MS, cname: str, cms: MS,
                     bu: int) -> Contribution:
        key = (self._layer_idx[pname], pms.geo,
               self._layer_idx[cname], cms.geo, bu)
        hit = self._dep_cache.get(key)
        if hit is None:
            PREFETCH_STATS["prefetch.scalar_builds"] += 1
            contrib = Contribution()
            self._dep_traffic(contrib, pname, pms, cname, cms, bu)
            hit = self._dep_cache.put(key, contrib.seal(self._offsets))
        return hit

    # -- batched construction (one vectorized pass over many cache misses) ----
    #
    # ``analyze_requests`` prefetches every contribution piece the batch
    # will need and builds the MISSING ones here, batched across requests:
    # ragged per-piece geometry is padded to rectangular index tables whose
    # pad cells are routed to provably-empty paths (the (n, n) diagonal /
    # self-routed pairs), so they emit no stream entries at all.  Per-piece
    # float reductions stay on exact per-piece slices — padding a float
    # reduction would change numpy's pairwise-summation tree.  The sealed
    # streams are BIT-IDENTICAL to the scalar builders' (same entries, same
    # order), which the scalar path remains the reference for.

    def _prefetch_contribs(self, requests: Sequence[Tuple[LayerGroup, LMS]],
                           total_batch: int) -> None:
        """Batch-build every layer/dependency piece the requests will miss."""
        layer_jobs: Dict[Tuple, Tuple] = {}
        dep_jobs: Dict[Tuple, Tuple] = {}
        for group, lms in requests:
            bu = group.batch_unit
            n_passes = max(1, -(-total_batch // bu))
            gid = self._group_ids.setdefault(group.names,
                                             len(self._group_ids))
            for name, internal_preds in self._group_topology(group):
                ms = lms.ms[name]
                lkey = (self._layer_idx[name], ms, bu, n_passes, gid)
                if lkey not in layer_jobs \
                        and self._layer_cache.get(lkey) is None:
                    layer_jobs[lkey] = (name, ms, bu, n_passes, group, gid)
                for p in internal_preds:
                    pms = lms.ms[p]
                    dkey = (self._layer_idx[p], pms.geo,
                            self._layer_idx[name], ms.geo, bu)
                    if dkey not in dep_jobs \
                            and self._dep_cache.get(dkey) is None:
                        dep_jobs[dkey] = (p, pms, name, ms, bu)
        if layer_jobs or dep_jobs:
            PREFETCH_STATS["prefetch.batched_builds"] \
                += len(layer_jobs) + len(dep_jobs)
        if layer_jobs:
            self._layer_contribs_batched(layer_jobs)
        if dep_jobs:
            self._dep_traffic_batched(dep_jobs)

    def _layer_math(self, name: str, part: Tuple[int, ...], bu: int,
                    n_passes: int) -> Dict[str, object]:
        """Per-layer value arrays depending only on (layer, Part, bu,
        n_passes) — computed with the scalar builder's exact pre-scale op
        sequence, so scale-1.0 jobs (the dense common case) reuse them
        verbatim and scaled jobs apply the same guarded multiplies the
        scalar path would."""
        key = (self._layer_idx[name], part, bu, n_passes)
        hit = self._lmath_cache.get(key)
        if hit is not None:
            return hit
        lyr = self.g.layers[name]
        # correspondence order — every array below is elementwise per
        # region row, so callers permute through their CG's sort order and
        # land on the exact values the scalar builder computes from the
        # sorted table (elementwise ops commute with permutation)
        rarr = self.region_geometry(name, part, bu)
        elems = (rarr[:, 1] - rarr[:, 0]) * (rarr[:, 3] - rarr[:, 2]) \
            * (rarr[:, 5] - rarr[:, 4]) * (rarr[:, 7] - rarr[:, 6])
        mac_per_elem = lyr.macs(1) / max(1, lyr.ofmap_elems)
        bpe = lyr.bytes_per_elem
        w_share = lyr.weight_bytes() / max(1, part[3]) if lyr.has_weight else 0
        fmap = elems * bpe * 2
        n = len(rarr)
        off_rw = self._offsets[T_GLB_RW]
        if lyr.has_weight:
            k_span = rarr[:, 7] - rarr[:, 6]
            w_core = k_span / max(1, lyr.K) * lyr.weight_bytes()
        else:
            w_core = None
        hit = {
            "macs": elems * mac_per_elem,
            "fmap": fmap,
            "w_share": w_share,
            # float64 up front: Contribution.add's dtype conversion is
            # value-exact, so pre-converting preserves bit-identity
            "glb1": np.asarray(fmap + w_share, dtype=np.float64),
            "rw0": np.full(n, off_rw, dtype=np.int64),
            "rw1": np.full(n, off_rw + 1, dtype=np.int64),
            "w_core": w_core,
            "if": np.asarray(self._external_ifmap_bytes(lyr, rarr, bu) * bpe,
                             dtype=np.float64),
            "of": np.asarray(elems * bpe, dtype=np.float64),
        }
        return self._lmath_cache.put(key, hit)

    def _layer_contribs_batched(self, jobs: Dict[Tuple, Tuple]) -> None:
        """Build many missing ``_layer_contribs`` pieces in one pass.

        Value math is memoized per (layer, Part, bu, passes) in
        ``_layer_math`` (the scalar builder's exact op sequence), every
        queued DRAM flow's XY-path gather runs as ONE fancy index into
        ``grid.paths``, and the sealed streams assemble as slices of one
        pooled (idx, vals) pair via ``from_flat`` — chunk content and
        order match the scalar add/seal output entry for entry.
        """
        offsets = self._offsets
        off_glb = offsets[T_GLB]
        off_time = offsets[T_CORE_TIME]
        off_in = offsets[T_CORE_IN]
        off_out = offsets[T_CORE_OUT]
        off_e = offsets[T_EDGE]
        off_eam = offsets[T_EDGE_AM]
        route_srcs: List[np.ndarray] = []
        route_dsts: List[np.ndarray] = []
        route_vols: List[np.ndarray] = []
        route_offs: List[int] = []

        def queue_route(chunks, eoff, srcs, dsts, vols):
            chunks.append(len(route_srcs))   # placeholder -> route id
            route_srcs.append(srcs)
            route_dsts.append(dsts)
            route_vols.append(vols)
            route_offs.append(eoff)

        def queue_dram_flow(chunks, eoff, diota, fd, nodes, vols, to_core):
            # mirrors _dram_flow exactly (vols arrive as float64 arrays);
            # the _route path gather is deferred to the bulk gather below
            if fd == 0:
                nd = self.arch.n_dram
                share = vols / nd
                dn = np.repeat(self._dram_nodes[:nd], len(nodes))
                cn = np.concatenate([nodes] * nd)
                sh = np.concatenate([share] * nd)
                if to_core:
                    queue_route(chunks, eoff, dn, cn, sh)
                else:
                    queue_route(chunks, eoff, cn, dn, sh)
                chunks.append((diota, np.full(nd, float(share.sum()))))
            else:
                d = fd - 1
                dn = np.full(len(nodes), self._dram_nodes[d])
                if to_core:
                    queue_route(chunks, eoff, dn, nodes, vols)
                else:
                    queue_route(chunks, eoff, nodes, dn, vols)
                chunks.append((diota[d:d + 1],
                               np.asarray([float(vols.sum())])))

        staged: List[Tuple[Tuple, List, List, float]] = []
        g = self.g
        for key, (name, ms, bu, n_passes, group, gid) in jobs.items():
            lyr = g.layers[name]
            cores, _, order = self._region_arrays(name, ms, bu)
            nodes = self._core_nodes[cores]
            m = self._layer_math(name, ms.part, bu, n_passes)
            ts = lyr.traffic_scale
            ws = lyr.weight_traffic_scale
            pre: List = []
            post: List = []
            weight_total = 0.0

            # cached arrays are correspondence-order; [order] lands on the
            # scalar builder's sorted-table values exactly
            pre.append((cores,
                        m["macs"][order] if ts == 1.0
                        else m["macs"][order] * ts))
            pre.append((cores + off_glb, m["glb1"][order] if ts == 1.0
                        else m["fmap"][order] * ts + m["w_share"]))
            t_arr, rd, wr = self._intra_geometry(name, ms.part, bu)
            if ts != 1.0:
                t_arr = t_arr * ts
                rd = rd * ts
                wr = wr * ts
            pre.append((np.asarray(ms.cg, dtype=np.int64) + off_time, t_arr))
            pre.append((m["rw0"], rd))
            pre.append((m["rw1"], wr))

            if lyr.has_weight:
                wc = m["w_core"][order]
                if ws != 1.0:
                    wc = wc * ws
                weight_total = float(wc.sum())
                queue_dram_flow(pre, off_eam, self._dram_iota_am, ms.fd[1],
                                nodes, wc / n_passes, to_core=True)

            preds = g.preds(name)
            in_group = group.names
            external = (not preds) or any(p not in in_group for p in preds)
            if external and ms.fd[0] >= 0:
                ifb = m["if"][order] if ts == 1.0 else m["if"][order] * ts
                queue_dram_flow(post, off_e, self._dram_iota, ms.fd[0],
                                nodes, ifb, to_core=True)
                post.append((cores + off_in, ifb))

            if ms.fd[2] >= 0:
                ofb = m["of"][order] if ts == 1.0 else m["of"][order] * ts
                queue_dram_flow(post, off_e, self._dram_iota, ms.fd[2],
                                nodes, ofb, to_core=False)
                post.append((cores + off_out, ofb))

            staged.append((key, pre, post, weight_total))

        # ONE bulk path gather over every queued flow of every job; the
        # per-target edge offsets ride along as a repeated offset vector,
        # so per-route chunks are pure slice views afterwards
        e_all = v_all = r_bounds = None
        if route_srcs:
            R = len(route_srcs)
            lens = np.fromiter((s.size for s in route_srcs), np.int64, R)
            roffs = np.concatenate(([0], np.cumsum(lens)))
            paths_all = self.grid.paths[np.concatenate(route_srcs),
                                        np.concatenate(route_dsts)]
            L = paths_all.shape[1]
            keep = paths_all >= 0
            per_route = np.add.reduceat(keep.sum(axis=1), roffs[:-1])
            flat_keep = keep.reshape(-1)
            e_all = paths_all.reshape(-1)[flat_keep] \
                + np.repeat(np.asarray(route_offs, dtype=np.int64), per_route)
            v_all = np.repeat(np.concatenate(route_vols), L)[flat_keep]
            r_bounds = np.concatenate(([0], np.cumsum(per_route)))

        ci: List[np.ndarray] = []
        cv: List[np.ndarray] = []

        def emit(chunks) -> int:
            n = 0
            for chunk in chunks:
                if type(chunk) is int:
                    s, e = r_bounds[chunk], r_bounds[chunk + 1]
                    ci.append(e_all[s:e])
                    cv.append(v_all[s:e])
                    n += int(e - s)
                else:
                    ci.append(chunk[0])
                    cv.append(chunk[1])
                    n += chunk[0].size
            return n

        spans: List[Tuple[Tuple, float, int, int]] = []
        for key, pre, post, weight_total in staged:
            n_pre = emit(pre)
            n_post = emit(post)
            spans.append((key, weight_total, n_pre, n_post))
        mega_i = np.concatenate(ci)
        mega_v = np.concatenate(cv)
        pos = 0
        for key, wt, n_pre, n_post in spans:
            mid = pos + n_pre
            end = mid + n_post
            self._layer_cache.put(
                key, (Contribution.from_flat(mega_i[pos:mid],
                                             mega_v[pos:mid], wt),
                      Contribution.from_flat(mega_i[mid:end],
                                             mega_v[mid:end])))
            pos = end

    # padded-volume cap per batched dependency chunk: bounds the peak
    # gather size (uint64 words / path cells) when jobs of very different
    # shapes co-occur; chunking changes nothing but peak memory
    _DEP_CHUNK_CELLS = 2_000_000

    def _dep_traffic_batched(self, jobs: Dict[Tuple, Tuple]) -> None:
        """Build many missing ``_dep_contrib`` pieces in one pass each for
        the contracting (multicast-grouped) and plain (unicast) families."""
        contracting: List[Tuple] = []
        plain: List[Tuple] = []
        for key, (pname, pms, cname, cms, bu) in jobs.items():
            prod, cons = self.g.layers[pname], self.g.layers[cname]
            ov_geo, any_ov = self._overlap_geometry(pname, pms.part, cname,
                                                    cms.part, bu, prod.K)
            if not any_ov:
                self._dep_cache.put(key, Contribution().seal(self._offsets))
                continue
            p_cores, _, p_ord = self._region_arrays(pname, pms, bu)
            c_cores, _, c_ord = self._region_arrays(cname, cms, bu)
            bpe = prod.bytes_per_elem
            escale = prod.traffic_scale * self.g.edge_mult(pname, cname)
            p_nodes = self._region_nodes(pname, pms, bu)
            c_nodes = self._region_nodes(cname, cms, bu)
            if cons.kind in ("conv", "fc", "matmul"):
                if self._path_bits is None:
                    # absurd grids fall back to the scalar sort-dedup path
                    contrib = Contribution()
                    self._dep_traffic(contrib, pname, pms, cname, cms, bu)
                    self._dep_cache.put(key, contrib.seal(self._offsets))
                    continue
                need, mc_first, mc_members, mc_cn, mc_live = \
                    self._need_arrays(cname, cms, bu, prod.K)
                contracting.append((key, ov_geo, p_ord, c_ord, p_cores,
                                    c_cores, p_nodes, bpe, escale, mc_first,
                                    mc_members, mc_cn, mc_live))
            else:
                plain.append((key, ov_geo, p_ord, c_ord, p_cores, c_cores,
                              p_nodes, c_nodes, bpe, escale))
        # pad jobs to chunk-max shapes: lockstep-iteration job shapes are
        # tiny (G*P is tens of cells), so padding waste is noise while the
        # chunk count — hence the numpy dispatch count, the actual cost on
        # these shapes — drops to O(1) per family per iteration
        W = self._path_bits.shape[2] if self._path_bits is not None else 1
        for chunk in _shape_chunks(
                contracting,
                lambda j: (len(j[9]), len(j[2]), max(1, j[10].shape[1]), W),
                self._DEP_CHUNK_CELLS):
            self._dep_contracting_chunk(chunk)
        L = self.grid.paths.shape[2]
        for chunk in _shape_chunks(plain,
                                   lambda j: (len(j[2]), len(j[3]), L),
                                   self._DEP_CHUNK_CELLS):
            self._dep_plain_chunk(chunk)

    def _dep_contracting_chunk(self, jobs: List[Tuple]) -> None:
        """Batched contracting-dependency construction (packed bitsets).

        Jobs pad to the chunk's max (G, P, Q).  Pad cells index row/col 0
        of the job's own pooled overlap block — in-bounds garbage — but
        are dead by construction: pad members carry a False live mask, pad
        producer columns get their volumes zeroed, so ``act`` is False and
        pads route to the empty ``(p, p)`` bitset diagonal, emitting no
        stream entries; the out/in value chunks slice exact (G, P[, Q])
        sub-blocks.  Every expensive stage — the bitset gather, the member
        OR-reduce, the unpack, the nonzero scan — runs ONCE per chunk, and
        per-job streams become slice views of one pooled (idx, vals) pair
        via ``from_flat``.
        """
        J = len(jobs)
        Gs = [len(j[9]) for j in jobs]
        Ps = [len(j[2]) for j in jobs]
        Qs = [j[10].shape[1] for j in jobs]
        Gm, Pm, Qm = max(Gs), max(Ps), max(max(Qs), 1)
        p_idx = np.zeros((J, Pm), dtype=np.int64)
        gfirst = np.zeros((J, Gm), dtype=np.int64)
        p_nodes_pad = np.zeros((J, Pm), dtype=np.int64)
        cn_pad = np.zeros((J, Gm, Qm), dtype=np.int64)
        live_pad = np.zeros((J, Gm, Qm), dtype=bool)
        scal = np.empty((J, 2), dtype=np.float64)
        sizes = np.fromiter((j[1].size for j in jobs), np.int64, J)
        offs = np.concatenate(([0], np.cumsum(sizes)))
        pool = np.concatenate([j[1].reshape(-1) for j in jobs])
        ncols = np.fromiter((j[1].shape[1] for j in jobs), np.int64, J)
        for jj, j in enumerate(jobs):
            G, P, Q = Gs[jj], Ps[jj], Qs[jj]
            p_idx[jj, :P] = j[2]
            gfirst[jj, :G] = j[3][j[9]]
            p_nodes_pad[jj, :P] = j[6]
            cn_pad[jj, :G, :Q] = j[11]
            live_pad[jj, :G, :Q] = j[12]
            scal[jj, 0] = j[7]
            scal[jj, 1] = j[8]
        flat_ov = offs[:-1, None, None] \
            + p_idx[:, :, None] * ncols[:, None, None] \
            + gfirst[:, None, :]                             # (J, Pm, Gm)
        vols = pool[flat_ov].transpose(0, 2, 1) * scal[:, :1, None]
        vols = vols * scal[:, 1:, None]                      # *1.0 bit-exact
        # zero pad producer columns: their garbage volumes must not trip
        # the (vols > 0) activity gate (real cells pass through verbatim)
        valid_p = np.arange(Pm)[None, :] < np.asarray(Ps)[:, None]
        vols = np.where(valid_p[:, None, :], vols, 0.0)      # (J, Gm, Pm)
        off_node = (p_nodes_pad[:, None, :, None] != cn_pad[:, :, None, :]) \
            & live_pad[:, :, None, :]                        # (J, G, P, Q)
        act = off_node & (vols > 0)[:, :, :, None]
        # Sparse union: gather path bitsets only at ACTIVE member cells
        # (typically <10% of the padded lattice), OR-reduce per (j, g, p)
        # row with reduceat, then unpack/scan only the surviving rows.
        # Inactive cells previously OR'd in the empty (p, p) diagonal —
        # the OR identity — so dropping them leaves every union word
        # bit-identical, and rows with no active member produce no stream
        # entries either way.
        flat_act = np.flatnonzero(act.reshape(-1))
        if flat_act.size:
            rowq, q_of = np.divmod(flat_act, Qm)             # row = (j,g,p)
            jj_of, gp_of = np.divmod(rowq, Gm * Pm)
            g_of, p_of = np.divmod(gp_of, Pm)
            srcs = p_nodes_pad.reshape(-1)[jj_of * Pm + p_of]
            dsts = cn_pad.reshape(-1)[(jj_of * Gm + g_of) * Qm + q_of]
            pb = self._path_bits[srcs, dsts]                 # (n_act, W)
            seg_starts = np.concatenate(
                ([0], np.flatnonzero(rowq[1:] != rowq[:-1]) + 1))
            union_small = np.bitwise_or.reduceat(pb, seg_starts, axis=0)
            live_rows = rowq[seg_starts]
            ub = np.unpackbits(union_small.view(np.uint8), axis=1,
                               bitorder="little")
            rr, e_idx = np.divmod(np.flatnonzero(ub.reshape(-1)), ub.shape[1])
            r_idx = live_rows[rr]
        else:
            r_idx = np.empty(0, dtype=np.int64)
            e_idx = np.empty(0, dtype=np.int64)
        off_e = self._offsets[T_EDGE]
        if off_e:
            e_idx = e_idx + off_e
        e_vals = vols.reshape(-1)[r_idx]
        bnd = np.searchsorted(r_idx, np.arange(1, J) * (Gm * Pm))
        starts = np.concatenate(([0], bnd))
        ends = np.concatenate((bnd, [len(r_idx)]))
        has_dst = off_node.any(axis=3)                       # (J, G, P)
        out_vals = vols * has_dst
        in_vals = vols[:, :, :, None] * act
        off_out = self._offsets[T_CORE_OUT]
        off_in = self._offsets[T_CORE_IN]
        ci: List[np.ndarray] = []
        cv: List[np.ndarray] = []
        lens: List[int] = []
        for jj, job in enumerate(jobs):
            G, P, Q = Gs[jj], Ps[jj], Qs[jj]
            p_cores, c_cores, members = job[4], job[5], job[10]
            s, e = starts[jj], ends[jj]
            ci.append(e_idx[s:e])
            cv.append(e_vals[s:e])
            ci.append(np.broadcast_to(p_cores + off_out, (G, P)).reshape(-1))
            cv.append(out_vals[jj, :G, :P].reshape(-1))
            ci.append(np.broadcast_to((c_cores + off_in)[members][:, None, :],
                                      (G, P, Q)).reshape(-1))
            cv.append(in_vals[jj, :G, :P, :Q].reshape(-1))
            lens.append(int(e - s) + G * P + G * P * Q)
        mega_i = np.concatenate(ci)
        mega_v = np.concatenate(cv)
        pos = 0
        for job, n in zip(jobs, lens):
            nxt = pos + n
            self._dep_cache.put(job[0], Contribution.from_flat(
                mega_i[pos:nxt], mega_v[pos:nxt]))
            pos = nxt

    def _dep_plain_chunk(self, jobs: List[Tuple]) -> None:
        """Batched non-contracting (unicast) dependency construction.

        Jobs pad to the chunk's max (P, Q); pad pairs self-route (dst :=
        src, whose path row is all ``-1``), so the keep mask drops them
        and pads emit no edge entries — their garbage volumes never
        surface, because the core in/out sums reduce exact per-job
        sub-block slices (padding a float reduction would change numpy's
        pairwise-summation tree).  One path gather + one keep scan per
        chunk; per-job streams are slice views of one pooled (idx, vals)
        pair via ``from_flat``.
        """
        J = len(jobs)
        Ps = [len(j[2]) for j in jobs]
        Qs = [len(j[3]) for j in jobs]
        Pm, Qm = max(Ps), max(Qs)
        p_idx = np.zeros((J, Pm), dtype=np.int64)
        c_idx = np.zeros((J, Qm), dtype=np.int64)
        p_nodes_pad = np.zeros((J, Pm), dtype=np.int64)
        c_nodes_pad = np.zeros((J, Qm), dtype=np.int64)
        scal = np.empty((J, 2), dtype=np.float64)
        sizes = np.fromiter((j[1].size for j in jobs), np.int64, J)
        offs = np.concatenate(([0], np.cumsum(sizes)))
        pool = np.concatenate([j[1].reshape(-1) for j in jobs])
        ncols = np.fromiter((j[1].shape[1] for j in jobs), np.int64, J)
        for jj, j in enumerate(jobs):
            P, Q = Ps[jj], Qs[jj]
            p_idx[jj, :P] = j[2]
            c_idx[jj, :Q] = j[3]
            p_nodes_pad[jj, :P] = j[6]
            c_nodes_pad[jj, :Q] = j[7]
            scal[jj, 0] = j[8]
            scal[jj, 1] = j[9]
        valid = (np.arange(Pm)[None, :, None] < np.asarray(Ps)[:, None, None]) \
            & (np.arange(Qm)[None, None, :] < np.asarray(Qs)[:, None, None])
        flat_ov = offs[:-1, None, None] \
            + p_idx[:, :, None] * ncols[:, None, None] \
            + c_idx[:, None, :]                              # (J, Pm, Qm)
        vols = pool[flat_ov].astype(float) * scal[:, :1, None]
        vols = vols * scal[:, 1:, None]                      # *1.0 bit-exact
        same = p_nodes_pad[:, :, None] == c_nodes_pad[:, None, :]
        vols_off = np.where(same, 0.0, vols)
        srcs = np.broadcast_to(p_nodes_pad[:, :, None], (J, Pm, Qm))
        dsts = np.where(valid,
                        np.broadcast_to(c_nodes_pad[:, None, :], (J, Pm, Qm)),
                        srcs)
        paths = self.grid.paths[srcs, dsts]                  # (J, Pm, Qm, L)
        L = paths.shape[3]
        flat = paths.reshape(J, -1)
        keep = flat >= 0
        e_all = flat[keep]
        off_e = self._offsets[T_EDGE]
        if off_e:
            e_all = e_all + off_e
        v_all = np.repeat(vols_off.reshape(J, -1), L, axis=1)[keep]
        cnt = keep.sum(axis=1)
        ends = np.cumsum(cnt)
        starts = ends - cnt
        off_out = self._offsets[T_CORE_OUT]
        off_in = self._offsets[T_CORE_IN]
        ci: List[np.ndarray] = []
        cv: List[np.ndarray] = []
        lens: List[int] = []
        for jj, job in enumerate(jobs):
            P, Q = Ps[jj], Qs[jj]
            s, e = starts[jj], ends[jj]
            vo = vols_off[jj, :P, :Q]
            ci.append(e_all[s:e])
            cv.append(v_all[s:e])
            ci.append(job[4] + off_out)
            cv.append(vo.sum(axis=1))
            ci.append(job[5] + off_in)
            cv.append(vo.sum(axis=0))
            lens.append(int(e - s) + P + Q)
        mega_i = np.concatenate(ci)
        mega_v = np.concatenate(cv)
        pos = 0
        for job, n in zip(jobs, lens):
            nxt = pos + n
            self._dep_cache.put(job[0], Contribution.from_flat(
                mega_i[pos:nxt], mega_v[pos:nxt]))
            pos = nxt

    def _group_topology(self, group: LayerGroup) -> List[Tuple[str, List[str]]]:
        """Per layer, its in-group predecessors (graph scans done once)."""
        key = group.names
        hit = self._topo_cache.get(key)
        if hit is None:
            in_group = set(group.names)
            hit = self._topo_cache.put(
                key, [(n, [p for p in self.g.preds(n) if p in in_group])
                      for n in group.names])
        return hit

    # -- main entry ------------------------------------------------------------
    def _gather_stream(self, group: LayerGroup, lms: LMS, bu: int,
                       n_passes: int, gid: int, chunks_i: List[np.ndarray],
                       chunks_v: List[np.ndarray]) -> float:
        """Append one mapping's contribution chunks in the canonical replay
        order (per layer: pre, internal-dep edges, post); returns the
        mapping's weight-DRAM total.  Shared by the scalar and batched
        paths, so both replay the exact same per-buffer add sequence."""
        weight_total = 0.0
        for name, internal_preds in self._group_topology(group):
            pre, post = self._layer_contribs(name, lms.ms[name], bu,
                                             n_passes, group, gid)
            pre.collect(chunks_i, chunks_v)
            weight_total += pre.weight_total
            for p in internal_preds:
                self._dep_contrib(p, lms.ms[p], name,
                                  lms.ms[name], bu).collect(chunks_i,
                                                            chunks_v)
            post.collect(chunks_i, chunks_v)
        return weight_total

    def _wrap_analysis(self, buf: np.ndarray, group: LayerGroup, lms: LMS,
                       bu: int, weight_total: float) -> GroupAnalysis:
        """View one replayed accumulator buffer as a :class:`GroupAnalysis`.

        ``layer_parts`` is left empty: only the seed reference engine
        consumes it (from its own analyses); eagerly materializing the
        Region dicts cost a measurable slice of every SA iteration.
        Callers that want the tables use :meth:`regions` directly.
        """
        arrays = [buf[lo:hi] for lo, hi in self._layout]
        return GroupAnalysis(
            arch=self.arch, batch_unit=bu, core_macs=arrays[T_CORE_MACS],
            edge_bytes=arrays[T_EDGE], edge_bytes_amortized=arrays[T_EDGE_AM],
            dram_bytes=arrays[T_DRAM], dram_bytes_amortized=arrays[T_DRAM_AM],
            core_glb_need=arrays[T_GLB], core_in_bytes=arrays[T_CORE_IN],
            core_out_bytes=arrays[T_CORE_OUT],
            weight_dram_bytes_total=weight_total,
            core_time_s=arrays[T_CORE_TIME], glb_rw_bytes=arrays[T_GLB_RW])

    def analyze(self, group: LayerGroup, lms: LMS, total_batch: int) -> GroupAnalysis:
        bu = group.batch_unit
        n_passes = max(1, -(-total_batch // bu))
        gid = self._group_ids.setdefault(group.names, len(self._group_ids))

        # gather every contribution's flat stream, concatenate once, replay
        # with a single np.bincount — which accumulates elements in array
        # order exactly like unbuffered np.add.at (per cell, the adds land
        # in the same sequence), so this is bit-identical to applying the
        # contributions one by one, at a fraction of ufunc.at's dispatch
        # cost
        chunks_i: List[np.ndarray] = []
        chunks_v: List[np.ndarray] = []
        weight_total = self._gather_stream(group, lms, bu, n_passes, gid,
                                           chunks_i, chunks_v)
        if chunks_i:
            buf = np.bincount(np.concatenate(chunks_i),
                              weights=np.concatenate(chunks_v),
                              minlength=self._buf_len)
        else:
            buf = np.zeros(self._buf_len)
        return self._wrap_analysis(buf, group, lms, bu, weight_total)

    def analyze_requests(self, requests: Sequence[Tuple[LayerGroup, LMS]],
                         total_batch: int,
                         backend: str = "numpy") -> GroupAnalysisBatch:
        """Analyze a mixed batch of (group, lms) requests in ONE replay.

        Row ``b`` of the result is bit-identical to
        ``analyze(requests[b][0], requests[b][1], total_batch)``: every
        request's contribution chunks are gathered in the scalar order,
        offset into its own ``buf_len`` window of one flat
        ``(B * buf_len,)`` buffer, and the whole batch replays through one
        ``np.bincount`` — rows never share a cell and per-row add order is
        the concatenation order, so the float-add sequence of each row is
        exactly the scalar one.  Requests may mix layer groups (the buffer
        layout is per-arch, shared by all groups), which is what lets the
        lockstep SA evaluate one whole iteration in a single pass.

        ``backend="jax"`` replays via a jitted ``segment_sum`` instead
        (accelerator runs).  Segment reduction does NOT preserve the add
        order (and runs float32 under jax's default x64-disabled config),
        so it is parity-grade (~1e-4), never bit-identical, and never the
        default.
        """
        if backend not in ("numpy", "jax"):
            raise ValueError(f"unknown analyze batch backend {backend!r}")
        # build every cache-missing piece batched across the whole request
        # list before the scalar gather walk (which then runs all-hits);
        # the batched builders seal bit-identical streams, so this is a
        # pure construction-cost optimization
        self._prefetch_contribs(requests, total_batch)
        B = len(requests)
        chunks_i: List[np.ndarray] = []
        chunks_v: List[np.ndarray] = []
        bases: List[int] = []
        weight_totals = np.empty(B)
        for b, (group, lms) in enumerate(requests):
            bu = group.batch_unit
            n_passes = max(1, -(-total_batch // bu))
            gid = self._group_ids.setdefault(group.names,
                                             len(self._group_ids))
            n0 = len(chunks_i)
            weight_totals[b] = self._gather_stream(group, lms, bu, n_passes,
                                                   gid, chunks_i, chunks_v)
            bases.extend([b * self._buf_len] * (len(chunks_i) - n0))
        if chunks_i:
            idx = np.concatenate(chunks_i)
            lens = np.fromiter((c.size for c in chunks_i), np.int64,
                               len(chunks_i))
            idx += np.repeat(np.asarray(bases, dtype=np.int64), lens)
            vals = np.concatenate(chunks_v)
            if backend == "jax":
                buf = _jax_replay(idx, vals, B * self._buf_len)
            else:
                buf = np.bincount(idx, weights=vals,
                                  minlength=B * self._buf_len)
        else:
            buf = np.zeros(B * self._buf_len)
        buf2 = buf.reshape(B, self._buf_len)
        analyses = [self._wrap_analysis(buf2[b], group, lms,
                                        group.batch_unit,
                                        float(weight_totals[b]))
                    for b, (group, lms) in enumerate(requests)]
        return GroupAnalysisBatch(analyses=analyses, buf=buf2,
                                  layout=self._layout,
                                  weight_totals=weight_totals)

    def row_stream(self, group: LayerGroup, lms: LMS, total_batch: int
                   ) -> Tuple[np.ndarray, np.ndarray, float]:
        """One request row's full contribution stream, downcast for the
        fused jax path: (int32 idx, float32 vals, weight_total).

        The stream is the same canonical gather order the exact replay
        uses; the downcast (and jax's segment reduction order) is why the
        fused path is parity-grade, never bit-exact.  Cached per
        (group, mapping, pass count) so lockstep SA pays construction
        once per novel proposal.
        """
        bu = group.batch_unit
        n_passes = max(1, -(-total_batch // bu))
        gid = self._group_ids.setdefault(group.names, len(self._group_ids))
        key = (gid, lms.cache_key(), bu, n_passes)
        hit = self._row_cache.get(key)
        if hit is None:
            chunks_i: List[np.ndarray] = []
            chunks_v: List[np.ndarray] = []
            wt = self._gather_stream(group, lms, bu, n_passes, gid,
                                     chunks_i, chunks_v)
            if chunks_i:
                idx = np.concatenate(chunks_i).astype(np.int32)
                vals = np.concatenate(chunks_v).astype(np.float32)
            else:
                idx = np.empty(0, np.int32)
                vals = np.empty(0, np.float32)
            hit = self._row_cache.put(key, (idx, vals, wt))
        return hit

    def analyze_batch(self, group: LayerGroup,
                      lms_batch: "Union[Sequence[LMS], LMSBatch]",
                      total_batch: int,
                      backend: str = "numpy") -> GroupAnalysisBatch:
        """Analyze B mappings of ONE layer group in a single replay pass
        (:meth:`analyze_requests` with a constant group; accepts either a
        sequence of ``LMS`` or a packed SoA :class:`LMSBatch`)."""
        if isinstance(lms_batch, LMSBatch):
            lms_list: Sequence[LMS] = unpack_lms_batch(lms_batch)
        else:
            lms_list = list(lms_batch)
        return self.analyze_requests([(group, lms) for lms in lms_list],
                                     total_batch, backend=backend)

    # -- pieces ---------------------------------------------------------------
    def _external_ifmap_bytes(self, lyr: Layer, rarr: np.ndarray,
                              bu: int) -> np.ndarray:
        """Elements of DNN-level input each core must fetch (halo included)."""
        s = lyr.stride
        dh = (rarr[:, 1] - rarr[:, 0]) * s + (lyr.R - 1)
        dw = (rarr[:, 3] - rarr[:, 2]) * s + (lyr.S - 1)
        db = rarr[:, 5] - rarr[:, 4]
        if lyr.kind in ("eltwise", "pool", "depthwise"):
            dk = (rarr[:, 7] - rarr[:, 6]) * (lyr.n_inputs if lyr.kind == "eltwise" else 1)
        elif lyr.kind == "matmul":
            # both operands streamed: rows of A for H-range + full B operand share
            dk = np.full(len(rarr), lyr.C, dtype=np.int64)
            return (rarr[:, 1] - rarr[:, 0]) * db * lyr.C \
                + (rarr[:, 7] - rarr[:, 6]) * db * lyr.C
        else:
            dk = np.full(len(rarr), max(1, lyr.C), dtype=np.int64)
        return dh * dw * db * dk

    def _dram_flow(self, contrib: Contribution, etarget: int, dtarget: int,
                   fd: int, nodes: np.ndarray, vols: np.ndarray,
                   to_core: bool) -> None:
        """Record core<->DRAM volumes.  fd==0 interleaves over all ports."""
        vols = np.asarray(vols, dtype=float)
        if np.ndim(vols) == 0:
            vols = np.full(len(nodes), float(vols))
        if fd == 0:
            # one route call covering every port: concatenating the
            # per-port (src, dst, vol) rows in port order preserves the
            # per-edge-cell add sequence of the historical per-port loop
            # (cross-target chunk order is free — edge and DRAM cells
            # never share a buffer cell), so the stream is bit-identical
            nd = self.arch.n_dram
            share = vols / nd
            dn = np.repeat(self._dram_nodes[:nd], len(nodes))
            cn = np.concatenate([nodes] * nd)
            sh = np.concatenate([share] * nd)
            if to_core:
                self._route(contrib, etarget, dn, cn, sh)
            else:
                self._route(contrib, etarget, cn, dn, sh)
            s = float(share.sum())
            contrib.add(dtarget, np.arange(nd, dtype=np.int64),
                        np.full(nd, s))
        else:
            d = fd - 1
            dn = np.full(len(nodes), self._dram_nodes[d])
            if to_core:
                self._route(contrib, etarget, dn, nodes, vols)
            else:
                self._route(contrib, etarget, nodes, dn, vols)
            contrib.add(dtarget, d, float(vols.sum()))

    def _dep_traffic(self, contrib: Contribution, pname: str, pms: MS,
                     cname: str, cms: MS, bu: int) -> None:
        """Producer->consumer on-chip flow with K-multicast grouping.

        Consumers whose needed region is identical (K-partition siblings for
        channel-contracting layers) form one multicast set per producer part.

        Expected-traffic scaling: the flow is the dense overlap volume times
        the producer's ``traffic_scale`` times the edge's multiplicity (the
        producer only emits its expected share; a routed consumer reading a
        fraction of a dense producer carries that fraction as edge
        multiplicity).  The guard keeps dense graphs bit-identical.
        """
        prod, cons = self.g.layers[pname], self.g.layers[cname]
        p_cores, _, p_ord = self._region_arrays(pname, pms, bu)
        c_cores, _, c_ord = self._region_arrays(cname, cms, bu)
        bpe = prod.bytes_per_elem
        escale = prod.traffic_scale * self.g.edge_mult(pname, cname)

        # needed region of each consumer part, in producer-ofmap coordinates,
        # with its multicast grouping (consumer parts sharing a need row)
        need, mc_first, mc_members, mc_cn, mc_live = \
            self._need_arrays(cname, cms, bu, prod.K)

        # overlap counts are pure geometry (cached per Part pair); permute
        # rows/columns from correspondence order into sorted-core order
        ov_geo, any_ov = self._overlap_geometry(pname, pms.part, cname,
                                                cms.part, bu, prod.K)
        if not any_ov:
            return
        p_nodes = self._region_nodes(pname, pms, bu)
        c_nodes = self._region_nodes(cname, cms, bu)

        contracting = cons.kind in ("conv", "fc", "matmul")
        if contracting:
            # one 3-d batch over (sibling group g, producer part p, member q);
            # the accumulation order is (g, p, q) — the order of the
            # historical nested loop.  Only sibling-first columns of the
            # overlap table are needed (identical need rows have identical
            # overlaps), so the permute gathers (P, G), not (P, Q).
            G, Qmax = mc_members.shape
            P = len(p_cores)
            vols = ov_geo[p_ord[:, None],
                          c_ord[mc_first][None, :]].T * np.float64(bpe)
            if escale != 1.0:
                vols = vols * escale
            cn = mc_cn                                        # (G, Qmax)
            off_node = (p_nodes[None, :, None] != cn[:, None, :]) \
                & mc_live[:, None, :]                         # (G, P, Qmax)
            live = vols > 0                                   # (G, P)
            act = off_node & live[:, :, None]                 # (G, P, Qmax)
            # union of XY paths per (g, p) over its off-node members; both
            # forms produce the edge ids ascending per (g, p) row — the
            # sorted-unique set np.unique would give
            if self._path_bits is not None:
                # packed-bitset union: redirect inactive members to the
                # (p, p) diagonal — whose XY path, hence bitset, is empty —
                # gather (G, P, Q, W) uint64 words and OR-reduce over
                # members, then unpack once.  Little-endian uint64 -> uint8
                # views keep bit j of word w at unpacked position 64 * w +
                # 8 * byte + bit == edge id, so nonzero yields edges
                # ascending per (g, p) row exactly like a boolean path
                # mask would.
                p_broad = np.broadcast_to(p_nodes[None, :, None], act.shape)
                cn_eff = np.where(act, cn[:, None, :], p_broad)
                pb = self._path_bits[p_broad, cn_eff]
                union_bits = np.bitwise_or.reduce(pb, axis=2)  # (G, P, W)
                ub = np.unpackbits(
                    union_bits.reshape(G * P, -1).view(np.uint8),
                    axis=1, bitorder="little")
                gp_idx, e_idx = np.nonzero(ub)
                contrib.add(T_EDGE, e_idx,
                            vols.reshape(-1)[gp_idx])
            else:
                paths = self.grid.paths[
                    np.broadcast_to(p_nodes[None, :, None], off_node.shape),
                    np.broadcast_to(cn[:, None, :], off_node.shape)]
                paths = np.where(act[..., None], paths, -1)
                srt = np.sort(paths.reshape(G * P, -1), axis=1)
                first = np.empty_like(srt, dtype=bool)
                first[:, 0] = True
                first[:, 1:] = srt[:, 1:] != srt[:, :-1]
                keep = (srt >= 0) & first
                contrib.add(T_EDGE, srt[keep],
                            np.repeat(vols.reshape(-1), keep.sum(axis=1)))
            # full-form records: dead (g, p[, q]) rows land exact +0.0
            # no-ops on valid cells (pad members index c_cores[-1], a real
            # core, with volume 0), which leaves every per-cell float sum
            # bit-identical to the filtered form while skipping two
            # nonzero scans and their gathers
            has_dst = off_node.any(axis=2)                    # (G, P)
            contrib.add(T_CORE_OUT,
                        np.broadcast_to(p_cores[None, :],
                                        vols.shape).reshape(-1),
                        (vols * has_dst).reshape(-1))
            # each off-node member receives the full volume
            contrib.add(T_CORE_IN,
                        np.broadcast_to(c_cores[mc_members][:, None, :],
                                        act.shape).reshape(-1),
                        (vols[:, :, None] * act).reshape(-1))
        else:
            ov = ov_geo[p_ord[:, None], c_ord[None, :]]   # (P, Q) elems
            vols = ov.astype(float) * bpe
            if escale != 1.0:
                vols = vols * escale
            same = p_nodes[:, None] == c_nodes[None, :]
            vols_off = np.where(same, 0.0, vols)
            P, Q = vols.shape
            self._route(contrib, T_EDGE,
                        np.repeat(p_nodes, Q), np.tile(c_nodes, P),
                        vols_off.reshape(-1))
            contrib.add(T_CORE_OUT, p_cores, vols_off.sum(axis=1))
            contrib.add(T_CORE_IN, c_cores, vols_off.sum(axis=0))


def d2d_hop_stats(arch: ArchConfig, analyses: Sequence[GroupAnalysis]) -> Dict[str, float]:
    """Totals used by the Fig. 9 style reporting."""
    grid = router_grid(arch)
    tot = sum(float(a.edge_bytes.sum()) for a in analyses)
    d2d = sum(float(a.edge_bytes[grid.edge_is_d2d].sum()) for a in analyses)
    return {"total_hop_bytes": tot, "d2d_hop_bytes": d2d,
            "d2d_fraction": d2d / tot if tot else 0.0}
