"""Architecture x Mapping co-exploration (paper Sec. V-A, Table I).

Enumerate architecture candidates exhaustively; run the mapping engine
(DP graph partition + SA LP-SPM) once per (candidate, workload) **task**
— the unit of work the exploration engine fans out and checkpoints —
then reduce geometric-mean E and D across workloads and score
``MC^alpha * E^beta * D^gamma`` (:func:`reduce_tasks`).  Supports joint
DSE across several compute-power targets built from one chiplet (paper
Sec. VII-B) and sharded sweeps merged via
``explore.merge_checkpoints``.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field, replace
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

from .evaluator import evaluator_for
from .graph_partition import partition_graph
from .hw import ArchConfig, TECH_12NM
from .mc import evaluate_mc
from .sa import Mapping, SAConfig, SAResult, sa_optimize
from .tangram import tangram_map
from .workload import Graph

# module object (not names): explore imports this module back, so names may
# not exist yet at import time — attributes resolve at call time instead
from . import explore as _explore


@dataclass
class DSEPoint:
    arch: ArchConfig
    mc: float
    energy_j: float          # geometric mean across workloads
    delay_s: float           # geometric mean across workloads
    objective: float
    per_workload: Dict[str, Tuple[float, float]] = field(default_factory=dict)
    mappings: Dict[str, Mapping] = field(default_factory=dict)
    # predicted serving metrics (objective="slo" sweeps only): p50/95/99
    # TTFT + e2e seconds, throughput, occupancy — see repro.serve.slo
    slo: Optional[Dict[str, float]] = None

    @property
    def edp(self) -> float:
        return self.energy_j * self.delay_s


@dataclass
class DSEConfig:
    alpha: float = 1.0        # MC exponent
    beta: float = 1.0         # E exponent
    gamma: float = 1.0        # D exponent
    batch: int = 64
    sa: SAConfig = field(default_factory=lambda: SAConfig(iters=1500))
    keep_mappings: bool = False
    # portfolio co-exploration: traffic-share weight per workload name
    # (weighted geometric mean in reduce_tasks).  None — and ONLY None —
    # takes the historical unweighted path; explicit all-1.0 weights are
    # bit-identical to it but stamp a ``:w=`` segment into the sweep
    # fingerprint.  Missing names default to weight 1.0.
    workload_weights: Optional[Dict[str, float]] = None
    # scoring mode: "geomean" (historical MC^a * E^b * D^g; the default
    # keeps every existing sweep bit-identical) or "slo", which replaces
    # the raw delay term with the predicted p99 end-to-end latency of the
    # candidate serving ``traffic`` (a repro.serve.slo.TrafficModel, a
    # registered model name, or a raw trace spec).  Queueing over the
    # traffic's arrival process makes p99 convex in D, so E/D trade-offs
    # rank differently than under the raw-delay objective.  Tasks (E, D)
    # are computed identically in both modes — only the reduction differs
    # — but the engine stamps an ``obj=`` fingerprint segment so
    # differently-scored sweep artifacts are never conflated.
    objective: str = "geomean"
    traffic: Optional[object] = None


@dataclass
class TaskResult:
    """Result of one (candidate, workload) task — the engine's unit of
    work and the payload of one schema-v2 checkpoint record."""
    energy_j: float
    delay_s: float
    mapping: Optional[Mapping] = None


def grid_candidates(tops: float,
                    mac_options: Sequence[int] = (512, 1024, 2048, 4096),
                    cut_options: Sequence[int] = (1, 2, 3, 6),
                    dram_per_tops: Sequence[float] = (0.5, 1.0, 2.0),
                    noc_options: Sequence[float] = (8, 16, 32, 64),
                    d2d_ratio: Sequence[float] = (0.25, 0.5, 1.0),
                    glb_options: Sequence[int] = (256, 512, 1024, 2048, 4096),
                    ) -> List[ArchConfig]:
    """The paper's Table-I grid for a given total TOPS (int8, 2 ops/MAC)."""
    out: List[ArchConfig] = []
    for macs in mac_options:
        n_cores = int(round(tops * 1e3 / (2 * macs)))
        if n_cores < 1:
            continue
        # near-square arrangement
        x = int(math.isqrt(n_cores))
        while n_cores % x:
            x -= 1
        y, xc = n_cores // x, x
        x_cores, y_cores = max(xc, y), min(xc, y)
        if x_cores * y_cores != n_cores:
            continue
        for xcut, ycut in itertools.product(cut_options, cut_options):
            if x_cores % xcut or y_cores % ycut:
                continue
            for dpt, noc, dr, glb in itertools.product(
                    dram_per_tops, noc_options, d2d_ratio, glb_options):
                out.append(ArchConfig(
                    x_cores=x_cores, y_cores=y_cores, xcut=xcut, ycut=ycut,
                    noc_bw=float(noc), d2d_bw=float(noc * dr),
                    dram_bw=float(dpt * tops), glb_kb=glb,
                    macs_per_core=macs))
    return out


def evaluate_task(arch: ArchConfig, g: Graph, cfg: DSEConfig,
                  use_sa: bool = True,
                  seed: Optional[int] = None) -> TaskResult:
    """Score one (architecture, workload) pair — the engine's unit of work.

    ``seed`` overrides ``cfg.sa.seed`` for this task's SA chains; the
    engine passes a per-task seed derived from ``(cfg.sa.seed, candidate
    index, workload index)`` so serial, parallel and sharded sweeps are
    bit-identical.
    """
    sa_cfg = cfg.sa if seed is None else replace(cfg.sa, seed=seed)
    groups = partition_graph(g, arch, cfg.batch)
    # per-process LRU registry: re-scoring this (arch, graph) soon after
    # (small screen-then-refine sweeps, same-arch loops) reuses the
    # analyzer + GroupEval cache; within this call, SA chains and the
    # final exact re-evaluation share ev by argument passing
    ev = evaluator_for(arch, g)
    if use_sa:
        res = sa_optimize(g, arch, groups, cfg.batch, sa_cfg, evaluator=ev)
        return TaskResult(energy_j=res.energy_j, delay_s=res.delay_s,
                          mapping=res.mapping)
    mapping = tangram_map(groups, g, arch)
    r = ev.evaluate(mapping, cfg.batch)
    return TaskResult(energy_j=r.energy_j, delay_s=r.delay_s, mapping=mapping)


def reduce_tasks(arch: ArchConfig, cfg: DSEConfig,
                 task_results: Dict[str, TaskResult]) -> DSEPoint:
    """Geometric-mean reduction of per-workload task results into one
    scored :class:`DSEPoint` (paper's ``MC^a * E^b * D^g`` objective).

    With ``cfg.workload_weights`` set this is the *weighted* geomean
    ``exp(sum_i w_i log E_i / sum_i w_i)`` — the portfolio co-exploration
    objective where ``w_i`` is workload ``i``'s traffic share.  Weights
    must be positive; names absent from the dict weigh 1.0.

    ``task_results`` must iterate in a deterministic workload order (the
    engine uses sorted names) — the log-domain accumulation is float
    arithmetic, so the order is part of the bit-identity contract.
    ``workload_weights=None`` reproduces the historical float-op sequence
    exactly; uniform explicit 1.0 weights are bit-identical to it because
    ``1.0 * x == x`` and a sum of ones equals the exact float count.
    """
    mc = evaluate_mc(arch).total
    w = cfg.workload_weights
    logE = logD = 0.0
    wsum = 0.0
    per: Dict[str, Tuple[float, float]] = {}
    maps: Dict[str, Mapping] = {}
    for name, tr in task_results.items():
        per[name] = (tr.energy_j, tr.delay_s)
        if cfg.keep_mappings and tr.mapping is not None:
            maps[name] = tr.mapping
        le = math.log(tr.energy_j)
        ld = math.log(tr.delay_s)
        if w is not None:
            wi = float(w.get(name, 1.0))
            if wi <= 0 or not math.isfinite(wi):
                raise ValueError(
                    f"workload_weights[{name!r}] = {wi} must be a positive "
                    f"finite traffic share")
            wsum += wi
            if wi != 1.0:
                le *= wi
                ld *= wi
        logE += le
        logD += ld
    n = (wsum if wsum > 0 else 1.0) if w is not None \
        else max(1, len(task_results))
    E = math.exp(logE / n)
    D = math.exp(logD / n)
    slo: Optional[Dict[str, float]] = None
    if cfg.objective == "slo":
        # tail-latency scoring: the geomean delay becomes a per-token
        # service model replayed over the traffic model's arrival process
        # (deterministic, cached); p99 e2e replaces D in the objective
        from ..serve.slo import SLO_SCALAR_KEY, predict_slo
        if cfg.traffic is None:
            raise ValueError(
                "objective='slo' needs cfg.traffic (a TrafficModel, a "
                "registered name, or a trace spec — see repro.serve.slo)")
        slo = predict_slo(D, cfg.traffic, cfg.batch)
        obj = (mc ** cfg.alpha) * (E ** cfg.beta) \
            * (slo[SLO_SCALAR_KEY] ** cfg.gamma)
    elif cfg.objective == "geomean":
        obj = (mc ** cfg.alpha) * (E ** cfg.beta) * (D ** cfg.gamma)
    else:
        raise ValueError(
            f"unknown DSE objective {cfg.objective!r}: 'geomean' or 'slo'")
    return DSEPoint(arch=arch, mc=mc, energy_j=E, delay_s=D, objective=obj,
                    per_workload=per, mappings=maps, slo=slo)


def evaluate_candidate(arch: ArchConfig, workloads: Dict[str, Graph],
                       cfg: DSEConfig, use_sa: bool = True,
                       seed: Optional[int] = None,
                       cand_idx: Optional[int] = None) -> DSEPoint:
    """Score one architecture over all workloads (sorted-name order).

    Standalone convenience over :func:`evaluate_task` +
    :func:`reduce_tasks` (the engine fans the tasks out itself):

    * ``seed`` — one SA seed shared by every workload (the pre-task-model
      behavior, kept for fig6/fig8-style single-candidate probes);
    * ``cand_idx`` — derive a per-(candidate, workload) seed from
      ``(cfg.sa.seed, cand_idx, workload index)``; matches bit-for-bit
      what ``run_dse`` computes for the candidate at that index.
    """
    if seed is not None and cand_idx is not None:
        raise ValueError("pass either seed= or cand_idx=, not both")
    results: Dict[str, TaskResult] = {}
    for wi, name in enumerate(sorted(workloads)):
        task_seed = seed
        if cand_idx is not None:
            task_seed = _explore.derive_task_seed(cfg.sa.seed, cand_idx, wi)
        results[name] = evaluate_task(arch, workloads[name], cfg,
                                      use_sa=use_sa, seed=task_seed)
    return reduce_tasks(arch, cfg, results)


def run_dse(candidates: Sequence[ArchConfig], workloads: Dict[str, Graph],
            cfg: DSEConfig, use_sa: bool = True, progress: bool = False,
            n_workers: int = 1, screen_keep: Union[float, str] = 1.0,
            checkpoint: Union[str, Path, None] = None,
            shard: Tuple[int, int] = (0, 1),
            mp_context: str = "spawn",
            objective: Optional[str] = None,
            traffic: Optional[object] = None,
            indices: Optional[Sequence[int]] = None,
            shard_label: Optional[str] = None) -> List[DSEPoint]:
    """Sweep ``candidates``; thin wrapper over the exploration engine.

    * ``n_workers > 1`` fans (candidate x workload) tasks out over worker
      processes; results are bit-identical to the serial path (per-task
      seeds derive from the candidate/workload indices, not scheduling).
    * ``screen_keep < 1.0`` first scores every candidate with the cheap
      T-Map pass and runs full SA only on the best fraction;
      ``screen_keep="auto"`` prunes adaptively instead — refinement stops
      once the T-Map gap to the best exceeds the largest SA improvement
      observed so far (unsharded sweeps only).
    * ``checkpoint`` names a JSON-lines file: completed tasks are skipped
      on re-run (resume after a crash / interrupted sweep).
    * ``shard=(i, n)`` evaluates only candidates with ``index % n == i``;
      give each shard its own checkpoint and reconstruct the full sweep
      with ``explore.merge_checkpoints`` — the merged result is
      bit-identical to an unsharded run.
    * ``objective="slo"`` with ``traffic=...`` scores candidates by
      predicted p99 end-to-end latency under the traffic model instead of
      the raw geomean delay (convenience overrides for
      ``cfg.objective``/``cfg.traffic``); left at ``None`` the sweep —
      and its checkpoint fingerprint — is untouched.
    * ``indices=[...]`` evaluates exactly the listed global candidate
      indices with no screening stage — the multi-host supervisor's
      screen-once dispatch form (``shard_label`` names the shard in
      heartbeats).  Mutually exclusive with stride ``shard``.
    """
    if objective is not None:
        cfg = replace(cfg, objective=objective)
    if traffic is not None:
        cfg = replace(cfg, traffic=traffic)
    with _explore.ExplorationEngine(workloads, cfg, n_workers=n_workers,
                                    checkpoint=checkpoint, progress=progress,
                                    mp_context=mp_context) as eng:
        return eng.run(candidates, use_sa=use_sa, screen_keep=screen_keep,
                       shard=shard, indices=indices, shard_label=shard_label)


def scaled_arch(base: ArchConfig, s: int) -> ArchConfig:
    """Tile ``s`` copies of a base chiplet in an as-square-as-possible grid."""
    sx = int(math.isqrt(s))
    while s % sx:
        sx -= 1
    sy = s // sx
    return base.replace(
        x_cores=base.x_cores * sx, y_cores=base.y_cores * sy,
        xcut=base.xcut * sx, ycut=base.ycut * sy,
        dram_bw=base.dram_bw * s)


def joint_reuse_dse(chiplet_grid: Sequence[ArchConfig],
                    scale_factors: Sequence[int],
                    workloads: Dict[str, Graph],
                    cfg: DSEConfig,
                    n_workers: int = 1,
                    objective: Optional[str] = None,
                    traffic: Optional[object] = None
                    ) -> List[Tuple[ArchConfig, float]]:
    """Paper Sec. VII-B: pick ONE chiplet; build each scale by tiling it.

    ``chiplet_grid`` holds base (single-chiplet) configs; ``scale_factors``
    multiplies the chiplet count (e.g. (1, 4) for 128/512 TOPs).  Returns
    (base_arch, product-of-objectives) sorted ascending.  The flattened
    (base x scale) grid is evaluated through the engine, so ``n_workers``
    parallelizes it with the same determinism guarantee as ``run_dse``.

    With ``cfg.workload_weights`` set this is *portfolio co-exploration*:
    each scale's objective is the weighted geomean over the workload
    portfolio (traffic shares), so the selected chiplet is the one whose
    tilings best serve the expected deployment mix — e.g. a 0.75/0.25
    dense/MoE portfolio — rather than an unweighted workload zoo.  The
    weights are stamped into the sweep fingerprint (schema-v2 checkpoint
    header ``:w=`` segment), so differently-weighted portfolios never
    share checkpoint records.

    ``objective="slo"`` with ``traffic=...`` scores each scale by
    predicted tail latency under the traffic model (see :func:`run_dse`);
    the product over scales then selects the chiplet whose tilings best
    keep the deployment's p99 in budget rather than its raw delay.
    """
    if objective is not None:
        cfg = replace(cfg, objective=objective)
    if traffic is not None:
        cfg = replace(cfg, traffic=traffic)
    scales = list(scale_factors)
    flat = [scaled_arch(base, s) for base in chiplet_grid for s in scales]
    with _explore.ExplorationEngine(workloads, cfg,
                                    n_workers=n_workers) as eng:
        pts = eng.map_archs(flat, use_sa=True)
    out: List[Tuple[ArchConfig, float]] = []
    for bi, base in enumerate(chiplet_grid):
        prod = 1.0
        for si in range(len(scales)):
            prod *= pts[bi * len(scales) + si].objective
        out.append((base, prod))
    out.sort(key=lambda t: t[1])
    return out
