"""DP-based graph partition into layer groups (Tangram-style, paper Sec. V-B).

The DAG is linearized topologically; a dynamic program over the linear order
chooses segment boundaries.  Segment cost is a fast proxy (the full mapping
engine runs afterwards per group): DRAM traffic saved by keeping dependencies
on-chip vs. pipeline fill/drain loss and GLB pressure.  The DP also picks the
``batch_unit`` per group — the largest power of two whose footprint fits the
aggregate GLB (the paper inherits this from Tangram).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from .hw import ArchConfig
from .workload import Graph, LayerGroup, edge_volume


def pick_batch_unit(g: Graph, names: Sequence[str], arch: ArchConfig,
                    total_batch: int, max_unit: int = 64) -> int:
    """Largest power-of-two batch unit whose fmap footprint fits aggregate GLB.

    Feature-map footprints use the *expected* ofmap volume (a routed MoE
    expert holds its expected token share resident); weights stay dense —
    the full weight slice must be resident regardless of routing.  Dense
    graphs see the exact integer arithmetic of the static-volume model.
    """
    glb_total = arch.core_glb_bytes * arch.n_cores
    weights = sum(g.layers[n].weight_bytes() for n in names)
    fmaps_1 = sum(g.layers[n].expected_ofmap_bytes(1) * 2 for n in names)
    bu = 1
    while (bu * 2 <= min(total_batch, max_unit)
           and weights + fmaps_1 * bu * 2 <= glb_total):
        bu *= 2
    return bu


def _segment_cost(g: Graph, names: Sequence[str], arch: ArchConfig,
                  total_batch: int) -> float:
    """Proxy cost of one candidate group: DRAM bytes + fill/drain penalty."""
    sset = set(names)
    bu = pick_batch_unit(g, names, arch, total_batch)
    n_passes = max(1, -(-total_batch // bu))
    # DRAM traffic: group-boundary fmaps (in and out) + weights once.
    # Boundary transfers go through edge_volume — the expected-traffic
    # volume (producer traffic_scale x edge multiplicity); graph-input
    # fetches scale by the consumer's traffic_scale; weight loads by
    # weight_traffic_scale.  All guards reduce to the exact dense integer
    # sums when no scale is set.
    boundary = 0
    for s, d in g.edges:
        if (s in sset) != (d in sset):
            boundary += edge_volume(g, s, d, total_batch)
    for n in names:
        preds = g.preds(n)
        if not preds and n in sset:
            lyr = g.layers[n]
            fetch = lyr.ifmap_elems * lyr.bytes_per_elem * total_batch
            if lyr.traffic_scale != 1.0:
                fetch = fetch * lyr.traffic_scale
            boundary += fetch
    weights = sum(g.layers[n].expected_weight_bytes() for n in names)
    dram = boundary + weights
    # fill/drain loss: depth extra passes, scaled by per-pass work share
    depth = len(names)
    work = sum(g.layers[n].expected_macs(bu) for n in names)
    fill = work * (depth - 1) / max(1, n_passes) / max(1, arch.n_cores)
    # GLB overcommit pressure (expected-resident fmaps, dense weights)
    glb_total = arch.core_glb_bytes * arch.n_cores
    dense_weights = sum(g.layers[n].weight_bytes() for n in names)
    foot = dense_weights \
        + sum(g.layers[n].expected_ofmap_bytes(bu) * 2 for n in names)
    pressure = max(0.0, foot - glb_total) * 4.0
    # core starvation: fewer cores than layers is infeasible
    if len(names) > arch.n_cores:
        return float("inf")
    return dram + fill * 0.05 + pressure


def partition_graph(g: Graph, arch: ArchConfig, total_batch: int,
                    max_group: int = 12) -> List[LayerGroup]:
    """DP over the topological linearization; returns layer groups in order."""
    order = g.topo_order()
    n = len(order)
    INF = float("inf")
    best = [INF] * (n + 1)
    best[0] = 0.0
    choice = [0] * (n + 1)
    for j in range(1, n + 1):
        for i in range(max(0, j - max_group), j):
            seg = order[i:j]
            c = best[i] + _segment_cost(g, seg, arch, total_batch)
            if c < best[j]:
                best[j] = c
                choice[j] = i
    # backtrack
    cuts: List[Tuple[int, int]] = []
    j = n
    while j > 0:
        i = choice[j]
        cuts.append((i, j))
        j = i
    cuts.reverse()
    groups: List[LayerGroup] = []
    for i, j in cuts:
        names = tuple(order[i:j])
        bu = pick_batch_unit(g, names, arch, total_batch)
        groups.append(LayerGroup(names=names, batch_unit=bu))
    return groups
