"""Unified parallel exploration engine (DSE + SA orchestration layer).

The outer search loops — Table-I architecture enumeration and the per-
candidate SA mapping runs — dominate Gemini's co-exploration wall time, not
the cost model.  This module owns everything *around* a candidate
evaluation:

* **Parallel DSE** — :class:`ExplorationEngine` fans candidates out over a
  ``ProcessPoolExecutor``.  Workload graphs and the ``DSEConfig`` are
  pickled once per worker (pool initializer); each worker then builds its
  own per-candidate ``CachedEvaluator`` (the GroupEval cache is pure
  memoization, so cache state never changes values — see DESIGN.md).
  Per-candidate SA seeds derive deterministically from
  ``(cfg.sa.seed, candidate index)``, so ``n_workers=1`` and
  ``n_workers=8`` produce bit-identical ``DSEPoint`` lists.
* **Two-stage screening** — a cheap T-Map pass (``tangram_map``, no SA)
  scores every candidate; only the top ``screen_keep`` fraction proceeds
  to full SA.  ``screen_keep=1.0`` (default) reproduces the exhaustive
  behavior exactly; the pruned count is logged.
* **Replica-exchange SA** — :func:`replica_exchange_sa` runs
  ``cfg.n_chains`` chains on a geometric temperature ladder with periodic
  Metropolis swaps of adjacent chains' states, all sharing one
  content-addressed evaluator cache.  ``sa_optimize`` dispatches here for
  ``n_chains > 1``.
* **Sweep artifacts** — :class:`ResumableSweep` (append-only JSON-lines
  checkpoint, skip-on-resume, crash-tolerant) and
  :func:`pareto_frontier` over (MC, E, D).
"""

from __future__ import annotations

import json
import math
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .evaluator import CachedEvaluator, Evaluator
from .hw import TECH_12NM, ArchConfig
from .sa import (Mapping, SAChain, SAConfig, SAResult, group_draw_cdf)
from .workload import Graph, LayerGroup

# resolved lazily through the module so tests can monkeypatch
# dse.evaluate_candidate and observe the engine's serial path
from . import dse as _dse


# ---------------------------------------------------------------------------
# Deterministic per-candidate seeds
# ---------------------------------------------------------------------------

def derive_seed(base_seed: int, index: int) -> int:
    """Deterministic per-candidate SA seed from ``(base seed, index)``.

    Routed through ``np.random.SeedSequence`` so neighbouring indices give
    statistically independent streams (``base_seed + index`` would make
    candidate ``i``'s chain 1 collide with candidate ``i+1``'s chain 0).
    Independent of worker count / scheduling by construction.
    """
    ss = np.random.SeedSequence([abs(int(base_seed)), int(index)])
    return int(ss.generate_state(1, np.uint32)[0])


# ---------------------------------------------------------------------------
# Replica-exchange SA (parallel tempering)
# ---------------------------------------------------------------------------

def replica_exchange_sa(g: Graph, arch: ArchConfig,
                        groups: Sequence[LayerGroup], total_batch: int,
                        cfg: SAConfig, init: Optional[Mapping] = None,
                        evaluator: Optional[Evaluator] = None) -> SAResult:
    """Parallel tempering over ``cfg.n_chains`` chains (paper Sec. V-B1 SA,
    upgraded from independent restarts).

    Chain 0 is an **unswapped reference chain**: same seed and cooling
    schedule as the single-chain engine and excluded from state exchanges,
    so its trajectory — and therefore its best — is bit-identical to
    ``n_chains=1``.  The returned global best can consequently never be
    worse than the single-chain result on the same seed (elitism), which
    turns the satellite invariant into a structural guarantee rather than
    a per-seed accident.

    Chains ``1..N-1`` form the tempering ladder: chain ``k`` anneals at
    ``t_ladder**(k-1)`` times the base temperature, and every
    ``swap_every`` iterations adjacent ladder chains attempt a Metropolis
    state swap ``P = min(1, exp((1/T_a - 1/T_b) * (cost_a - cost_b)))``,
    so good configurations found by hot (exploratory) chains percolate
    down while locally-refined cold states heat up to escape minima.  All
    chains share one content-addressed evaluator cache, so a state
    re-visited by any chain is never re-analyzed.  Chain ``k`` is seeded
    ``cfg.seed + k``; the best mapping over all chains is re-evaluated
    exactly.

    Note ``n_chains=2`` has a one-chain ladder and therefore no swaps —
    it degenerates to two independent seeds plus elitism (the pre-refactor
    restart behavior).  Tempering proper needs ``n_chains >= 3``.
    """
    ev = evaluator or CachedEvaluator(arch, g)
    cum_w = group_draw_cdf(groups, arch.n_cores)
    chains = [SAChain(g, arch, groups, total_batch, cfg, init, ev,
                      seed=cfg.seed + k, cum_w=cum_w,
                      t_scale=1.0 if k == 0 else cfg.t_ladder ** (k - 1))
              for k in range(cfg.n_chains)]
    ladder = chains[1:]
    swap_rng = np.random.default_rng(
        np.random.SeedSequence([abs(int(cfg.seed)), 0x52455853]))  # "REXS"
    swap_every = max(1, cfg.swap_every)
    history: List[float] = []
    for it in range(cfg.iters):
        for chain in chains:
            chain.step()
        if (it + 1) % swap_every == 0:
            for k in range(len(ladder) - 1):
                cold, hot = ladder[k], ladder[k + 1]
                t_cold = max(cold.T, 1e-30)
                t_hot = max(hot.T, 1e-30)
                delta = (1.0 / t_cold - 1.0 / t_hot) * (cold.cost - hot.cost)
                if delta >= 0 or swap_rng.random() < math.exp(max(delta, -700.0)):
                    cold.exchange_state(hot)
        if cfg.log_every and it % cfg.log_every == 0:
            history.append(chains[0].cost)      # reference-chain trace
    # pick the winner by *exact* re-evaluated cost (incremental best_cost
    # carries float accumulation error); ties prefer the reference chain,
    # keeping the never-worse-than-single-chain guarantee airtight
    finals = [c.finalize([]) for c in chains]
    res = min(finals, key=lambda r: r.cost)
    res.history = history
    res.accepted = sum(c.accepted for c in chains)
    res.proposed = sum(c.proposed for c in chains)
    return res


# ---------------------------------------------------------------------------
# DSEPoint / ArchConfig <-> JSON (checkpoint records)
# ---------------------------------------------------------------------------

_TECHS = {TECH_12NM.name: TECH_12NM}

_ARCH_FIELDS = ("x_cores", "y_cores", "xcut", "ycut", "noc_bw", "d2d_bw",
                "dram_bw", "glb_kb", "macs_per_core", "freq_ghz", "n_dram")


def register_tech(tech) -> None:
    """Make a non-default :class:`Tech` resumable from checkpoints (archs
    serialize their tech by name; deserialization refuses unknown names
    rather than silently substituting the wrong constants)."""
    _TECHS[tech.name] = tech


def arch_to_dict(arch: ArchConfig) -> Dict[str, Any]:
    d = {f: getattr(arch, f) for f in _ARCH_FIELDS}
    d["tech"] = arch.tech.name
    return d


def arch_from_dict(d: Dict[str, Any]) -> ArchConfig:
    kw = {f: d[f] for f in _ARCH_FIELDS}
    tech_name = d.get("tech", "")
    tech = _TECHS.get(tech_name)
    if tech is None:
        raise ValueError(
            f"unknown tech {tech_name!r} in checkpoint record; call "
            f"explore.register_tech() for non-default technologies")
    return ArchConfig(**kw, tech=tech)


def graph_fingerprint(g: Graph) -> str:
    """Stable content digest of a workload DAG (layers, edges, inputs).

    ``Layer`` is a frozen dataclass, so its ``repr`` enumerates every
    field; two graphs with equal structure hash equally regardless of
    insertion order.
    """
    import hashlib
    h = hashlib.sha1()
    for name in sorted(g.layers):
        h.update(repr((name, g.layers[name])).encode())
    h.update(repr(sorted(g.edges)).encode())
    h.update(repr(sorted(g.input_layers)).encode())
    return h.hexdigest()[:12]


def candidate_key(arch: ArchConfig) -> str:
    """Stable content identity of a candidate (checkpoint skip key)."""
    d = arch_to_dict(arch)
    return "/".join(f"{f}={d[f]:g}" if isinstance(d[f], float) else
                    f"{f}={d[f]}" for f in (*_ARCH_FIELDS, "tech"))


def point_to_dict(pt: "_dse.DSEPoint") -> Dict[str, Any]:
    return {"arch": arch_to_dict(pt.arch), "mc": pt.mc,
            "energy_j": pt.energy_j, "delay_s": pt.delay_s,
            "objective": pt.objective,
            "per_workload": {k: list(v) for k, v in pt.per_workload.items()}}


def point_from_dict(d: Dict[str, Any]) -> "_dse.DSEPoint":
    # mappings are not serialized: a resumed point carries metrics only
    return _dse.DSEPoint(
        arch=arch_from_dict(d["arch"]), mc=d["mc"], energy_j=d["energy_j"],
        delay_s=d["delay_s"], objective=d["objective"],
        per_workload={k: (v[0], v[1]) for k, v in d["per_workload"].items()})


# ---------------------------------------------------------------------------
# Resumable sweeps (JSON-lines checkpoint)
# ---------------------------------------------------------------------------

class ResumableSweep:
    """Append-only JSON-lines checkpoint for long sweeps.

    One ``{"_key": ..., **record}`` object per line; an optional first line
    ``{"_config": fingerprint}`` guards against resuming under a changed
    configuration (mismatch discards the stale file).  A truncated trailing
    line (process killed mid-write) is tolerated and dropped.  Duplicate
    keys are last-wins, so a forced re-run simply appends an overriding
    record.  Used by ``run_dse(..., checkpoint=...)`` and by the hillclimb
    driver (``launch/hillclimb.py``).
    """

    def __init__(self, path: Union[str, Path],
                 config_fingerprint: Optional[str] = None,
                 resume: bool = True):
        self.path = Path(path)
        self.fingerprint = config_fingerprint
        self._records: Dict[str, Dict[str, Any]] = {}
        fresh = True
        if self.path.exists():
            if resume:
                fresh = not self._load(readonly=False)
            if fresh:
                self._set_aside()
        if fresh:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            header = (json.dumps({"_config": self.fingerprint}) + "\n"
                      if self.fingerprint is not None else "")
            self.path.write_text(header)

    def _set_aside(self) -> None:
        """Move a rejected file (corrupt line / changed config /
        ``resume=False``) to a fresh ``.bakN`` name — recorded data is
        never destroyed, and existing backups are never clobbered."""
        n = 0
        while True:
            suffix = ".bak" if n == 0 else f".bak{n}"
            bak = self.path.with_name(self.path.name + suffix)
            if not bak.exists():
                break
            n += 1
        self.path.replace(bak)
        print(f"[sweep] previous file kept at {bak}")

    @classmethod
    def read(cls, path: Union[str, Path]) -> "ResumableSweep":
        """Read-only parse: never creates, repairs or resets the file.

        For consumers that only render recorded sweeps (``launch/report``);
        a corrupt or config-mismatched file yields whatever records parse
        instead of triggering the constructor's set-aside logic.
        """
        inst = cls.__new__(cls)
        inst.path = Path(path)
        inst.fingerprint = None
        inst._records = {}
        if inst.path.exists():
            inst._load(readonly=True)
        return inst

    def _load(self, readonly: bool) -> bool:
        """Parse the existing file; False if it must be discarded."""
        text = self.path.read_text()
        lines = text.splitlines()
        valid: List[str] = []
        saw_header = False
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                if i == len(lines) - 1:
                    continue                  # truncated final line: drop it
                print(f"[sweep] {self.path}: corrupt line {i + 1}; "
                      "discarding checkpoint")
                if readonly:
                    continue                  # salvage what parses
                self._records.clear()        # discard means ALL records
                return False
            if "_config" in rec:
                if self.fingerprint is not None \
                        and rec["_config"] != self.fingerprint:
                    print(f"[sweep] {self.path}: config changed; "
                          "discarding checkpoint")
                    return False
                saw_header = True
                valid.append(line)
                continue
            valid.append(line)
            key = rec.pop("_key", None)
            if key is not None:
                self._records[key] = rec
        if not readonly and self.fingerprint is not None and not saw_header \
                and self._records:
            # a fingerprinted sweep whose header is gone (e.g. killed while
            # writing it) can no longer prove the records match this config
            print(f"[sweep] {self.path}: missing config header; "
                  "discarding checkpoint")
            self._records.clear()
            return False
        # a killed-mid-write trailing fragment (or missing final newline)
        # would merge with the next append — repair the file first;
        # atomically (temp + replace), so a second kill mid-repair cannot
        # lose the already-recorded lines
        repaired = "".join(v + "\n" for v in valid)
        if not readonly and repaired != text:
            tmp = self.path.with_name(self.path.name + ".tmp")
            tmp.write_text(repaired)
            tmp.replace(self.path)
        return True

    def __contains__(self, key: str) -> bool:
        return key in self._records

    def __len__(self) -> int:
        return len(self._records)

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        return self._records.get(key)

    def add(self, key: str, record: Dict[str, Any]) -> None:
        self._records[key] = record
        with self.path.open("a") as f:
            f.write(json.dumps({"_key": key, **record}, default=float) + "\n")
            f.flush()

    def as_dict(self) -> Dict[str, Dict[str, Any]]:
        return dict(self._records)


# ---------------------------------------------------------------------------
# Pareto frontier over (MC, E, D)
# ---------------------------------------------------------------------------

def pareto_frontier(points: Sequence["_dse.DSEPoint"],
                    keys: Tuple[str, ...] = ("mc", "energy_j", "delay_s"),
                    ) -> List["_dse.DSEPoint"]:
    """Non-dominated subset under element-wise minimization of ``keys``.

    A point is dominated if some other point is <= on every key and < on at
    least one.  Ties (identical key vectors) are all kept.  Returned sorted
    by scalar objective, best first.
    """
    vals = [tuple(getattr(p, k) for k in keys) for p in points]
    out: List["_dse.DSEPoint"] = []
    for i, p in enumerate(points):
        vi = vals[i]
        dominated = any(
            all(a <= b for a, b in zip(vj, vi)) and vj != vi
            for j, vj in enumerate(vals) if j != i)
        if not dominated:
            out.append(p)
    out.sort(key=lambda p: p.objective)
    return out


# ---------------------------------------------------------------------------
# Worker-process plumbing
# ---------------------------------------------------------------------------

# populated once per worker by the pool initializer; workloads + cfg are
# pickled exactly once per worker instead of once per task
_WORKER_STATE: Dict[str, Any] = {}


def _worker_init(workloads: Dict[str, Graph], cfg: "_dse.DSEConfig") -> None:
    _WORKER_STATE["workloads"] = workloads
    _WORKER_STATE["cfg"] = cfg


def _worker_eval(task: Tuple[int, ArchConfig, int, bool]
                 ) -> Tuple[int, "_dse.DSEPoint"]:
    index, arch, seed, use_sa = task
    pt = _dse.evaluate_candidate(arch, _WORKER_STATE["workloads"],
                                 _WORKER_STATE["cfg"], use_sa=use_sa,
                                 seed=seed)
    return index, pt


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class ExplorationEngine:
    """Screened, parallel, resumable candidate evaluation.

    One engine instance owns (at most) one worker pool; ``screen()`` and
    ``run()`` share it, so the per-worker import + unpickle cost is paid
    once per sweep.  Use as a context manager (or call :meth:`close`).

    ``mp_context`` defaults to ``"spawn"``: the parent process may hold JAX
    thread pools (fork-unsafe), and spawned workers import only the NumPy
    cost-model stack.
    """

    def __init__(self, workloads: Dict[str, Graph], cfg: "_dse.DSEConfig",
                 n_workers: int = 1, checkpoint: Union[str, Path, None] = None,
                 progress: bool = False, mp_context: str = "spawn"):
        self.workloads = dict(workloads)
        self.cfg = cfg
        self.n_workers = max(1, int(n_workers))
        self.checkpoint = checkpoint
        self.progress = progress
        self.mp_context = mp_context
        self._pool: Optional[ProcessPoolExecutor] = None
        # screening scores of the last run() that screened (sorted best
        # first); lets callers report the screen stage without re-running it
        self.last_screen: Optional[List["_dse.DSEPoint"]] = None

    # -- lifecycle -----------------------------------------------------
    def __enter__(self) -> "ExplorationEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        if self._pool is not None:
            # queued-but-unstarted work is pointless once we're exiting
            # (normally the queue is already drained; after a worker error
            # it isn't, and waiting for it would stall the traceback)
            self._pool.shutdown(cancel_futures=True)
            self._pool = None

    def _get_pool(self) -> ProcessPoolExecutor:
        if self._pool is None:
            import multiprocessing as mp
            self._pool = ProcessPoolExecutor(
                max_workers=self.n_workers,
                mp_context=mp.get_context(self.mp_context),
                initializer=_worker_init,
                initargs=(self.workloads, self.cfg))
        return self._pool

    # -- fingerprint for checkpoint compatibility ----------------------
    def _fingerprint(self, use_sa: bool) -> str:
        c = self.cfg
        # workloads hash by *content*, not name: editing a graph while
        # keeping its dict key must invalidate the checkpoint
        wl = ",".join(f"{n}:{graph_fingerprint(g)}"
                      for n, g in sorted(self.workloads.items()))
        return (f"dse:v1:a{c.alpha:g}:b{c.beta:g}:g{c.gamma:g}:B{c.batch}:"
                f"sa({c.sa.iters},{c.sa.t0:g},{c.sa.t_end:g},{c.sa.seed},"
                f"{c.sa.beta:g},{c.sa.gamma:g},{c.sa.n_chains},"
                f"{c.sa.swap_every},{c.sa.t_ladder:g}):sa={int(use_sa)}:"
                f"wl={wl}")

    # -- evaluation fan-out --------------------------------------------
    def _map(self, tasks: List[Tuple[int, ArchConfig, int]], use_sa: bool,
             checkpoint: Union[str, Path, None], stage: str,
             ) -> List["_dse.DSEPoint"]:
        """Evaluate ``(index, arch, seed)`` tasks; returns points in task
        order regardless of completion order (determinism)."""
        results: Dict[int, "_dse.DSEPoint"] = {}
        sweep: Optional[ResumableSweep] = None
        if checkpoint is not None:
            sweep = ResumableSweep(checkpoint, self._fingerprint(use_sa))
            for idx, arch, seed in tasks:
                rec = sweep.get(candidate_key(arch))
                # a record is only valid for the seed this sweep would use:
                # editing the candidate grid shifts indices (and therefore
                # derived seeds), and those candidates must recompute or
                # resume would silently mix seeds (SA-less records are
                # seed-independent)
                if rec is not None and (not use_sa
                                        or rec.get("seed") == seed):
                    try:
                        results[idx] = point_from_dict(rec)
                    except (KeyError, ValueError, TypeError) as e:
                        print(f"[{stage}] checkpoint record for "
                              f"{arch.label()} unusable ({e}); recomputing")
            if results:
                if self.cfg.keep_mappings:
                    print(f"[{stage}] note: {len(results)} resumed points "
                          "carry metrics only (mappings are not checkpointed)")
                if self.progress:
                    print(f"[{stage}] resumed {len(results)}/{len(tasks)} "
                          f"candidates from {sweep.path}", flush=True)
        pending = [t for t in tasks if t[0] not in results]
        done_n = len(results)

        seed_of = {idx: seed for idx, _arch, seed in tasks}

        def _record(idx: int, arch: ArchConfig, pt: "_dse.DSEPoint") -> None:
            nonlocal done_n
            results[idx] = pt
            done_n += 1
            if sweep is not None:
                sweep.add(candidate_key(arch),
                          {"seed": seed_of[idx], **point_to_dict(pt)})
            if self.progress:
                print(f"[{stage} {done_n}/{len(tasks)}] {arch.label()} "
                      f"MC=${pt.mc:.0f} E={pt.energy_j:.3e}J "
                      f"D={pt.delay_s:.3e}s obj={pt.objective:.3e}",
                      flush=True)

        if self.n_workers <= 1 or len(pending) <= 1:
            for idx, arch, seed in pending:
                pt = _dse.evaluate_candidate(arch, self.workloads, self.cfg,
                                             use_sa=use_sa, seed=seed)
                _record(idx, arch, pt)
        else:
            pool = self._get_pool()
            futs = {pool.submit(_worker_eval, (idx, arch, seed, use_sa)):
                    (idx, arch) for idx, arch, seed in pending}
            not_done = set(futs)
            try:
                while not_done:
                    done, not_done = wait(not_done,
                                          return_when=FIRST_COMPLETED)
                    for fut in done:
                        idx, pt = fut.result()
                        _record(idx, futs[fut][1], pt)
            except BaseException:
                # surface the failure now, not after the queue drains
                for fut in not_done:
                    fut.cancel()
                raise
        return [results[idx] for idx, _arch, _seed in tasks]

    # -- public API ----------------------------------------------------
    def map_archs(self, archs: Sequence[ArchConfig], use_sa: bool = True,
                  ) -> List["_dse.DSEPoint"]:
        """Evaluate ``archs`` (parallel, deterministic), *preserving input
        order* — for callers that reduce positionally (``joint_reuse_dse``)
        rather than rank by objective."""
        tasks = [(i, arch, derive_seed(self.cfg.sa.seed, i))
                 for i, arch in enumerate(archs)]
        return self._map(tasks, use_sa=use_sa, checkpoint=self.checkpoint,
                         stage="map")

    def screen(self, candidates: Sequence[ArchConfig]
               ) -> List["_dse.DSEPoint"]:
        """T-Map-only scoring pass (no SA), sorted best-objective first."""
        tasks = [(i, arch, derive_seed(self.cfg.sa.seed, i))
                 for i, arch in enumerate(candidates)]
        pts = self._map(tasks, use_sa=False, checkpoint=None, stage="screen")
        return sorted(pts, key=lambda p: p.objective)

    def run(self, candidates: Sequence[ArchConfig], use_sa: bool = True,
            screen_keep: float = 1.0) -> List["_dse.DSEPoint"]:
        """Full sweep: optional screening stage, then (parallel) evaluation.

        Per-candidate seeds derive from the candidate's index in
        ``candidates``, so results are independent of ``n_workers``,
        completion order, screening of *other* candidates, and resume.
        """
        candidates = list(candidates)
        tasks = [(i, arch, derive_seed(self.cfg.sa.seed, i))
                 for i, arch in enumerate(candidates)]
        self.last_screen = None
        if use_sa and screen_keep < 1.0 and len(candidates) > 1:
            screen_pts = self._map(tasks, use_sa=False, checkpoint=None,
                                   stage="screen")
            order = sorted(range(len(tasks)),
                           key=lambda i: screen_pts[i].objective)
            # epsilon guard: fraction-derived keeps like 6/n can float up
            # (6/187*187 == 6.000000000000001) and must not round to 7
            keep = max(1, min(len(tasks),
                              math.ceil(screen_keep * len(tasks) - 1e-9)))
            kept = sorted(order[:keep])
            print(f"[explore] screening kept {keep}/{len(tasks)} candidates "
                  f"(pruned {len(tasks) - keep})", flush=True)
            self.last_screen = [screen_pts[i] for i in order]
            tasks = [tasks[i] for i in kept]
        pts = self._map(tasks, use_sa=use_sa, checkpoint=self.checkpoint,
                        stage="dse")
        return sorted(pts, key=lambda p: p.objective)
