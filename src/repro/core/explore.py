"""Unified parallel exploration engine (DSE + SA orchestration layer).

The outer search loops — Table-I architecture enumeration and the per-
candidate SA mapping runs — dominate Gemini's co-exploration wall time, not
the cost model.  This module owns everything *around* a candidate
evaluation:

* **(candidate x workload) task fan-out** — the engine's unit of work is
  one ``(candidate, workload)`` pair, not one candidate.
  :class:`ExplorationEngine` fans tasks out over a ``ProcessPoolExecutor``
  (workload graphs and the ``DSEConfig`` are pickled once per worker via
  the pool initializer); the executor's queue gives natural work stealing,
  so a candidate whose SA finishes early frees its worker for another
  candidate's remaining workloads.  Per-task SA seeds derive
  deterministically from ``(cfg.sa.seed, candidate index, workload
  index)``, so any worker count, any completion order and any sharding
  produce bit-identical ``DSEPoint`` lists.  Per-candidate geometric means
  are reduced in the parent (:func:`repro.core.dse.reduce_tasks`).
* **Sharded sweeps** — ``run(..., shard=(i, n))`` evaluates only the
  candidates with ``index % n == i`` (after the screening stage, which is
  deterministic and therefore replicated per shard), each shard writing an
  independent checkpoint; :func:`merge_checkpoints` reconstructs the full
  sweep from the shard artifacts (fingerprint-checked, last-wins on
  duplicate keys, corrupt shards set aside).  This is what lets a sweep
  span CI matrix jobs or multiple hosts.
* **Two-stage screening** — a cheap T-Map pass (``tangram_map``, no SA)
  scores every candidate; only the top ``screen_keep`` fraction proceeds
  to full SA.  ``screen_keep=1.0`` (default) reproduces the exhaustive
  behavior exactly; the pruned count is logged.
* **Replica-exchange SA** — :func:`replica_exchange_sa` runs
  ``cfg.n_chains`` chains on a geometric temperature ladder with periodic
  Metropolis swaps of adjacent chains' states, all sharing one
  content-addressed evaluator cache.  ``sa_optimize`` dispatches here for
  ``n_chains > 1`` (and bumps the degenerate ``n_chains=2`` to 3).
* **Sweep artifacts** — :class:`ResumableSweep` (append-only JSON-lines
  checkpoint, schema v2: one record per task, with transparent migration
  of schema-v1 per-candidate records), an opt-in LMS mapping
  (de)serializer (:func:`mapping_to_jsonable`) so ``keep_mappings``
  sweeps survive resume/merge, and :func:`pareto_frontier` over
  (MC, E, D).
"""

from __future__ import annotations

import json
import math
import os as _os
import time as _time
from collections import OrderedDict
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
from dataclasses import dataclass, field
from pathlib import Path
from typing import (Any, Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from .. import obs as _obs
from .encoding import LMS, MS
from .evaluator import (CachedEvaluator, Evaluator, analysis_signature,
                        evaluator_for)
from .graph_partition import partition_graph
from .hw import TECH_12NM, ArchConfig
from .sa import (Mapping, SAChain, SAConfig, SAResult, group_draw_cdf,
                 step_chains_lockstep)
from .tangram import tangram_map
from .workload import Graph, LayerGroup

# resolved lazily through the module so tests can monkeypatch
# dse.evaluate_task and observe the engine's serial path
from . import dse as _dse


# ---------------------------------------------------------------------------
# Deterministic per-candidate / per-task seeds
# ---------------------------------------------------------------------------

def derive_seed(base_seed: int, index: int) -> int:
    """Deterministic per-candidate SA seed from ``(base seed, index)``.

    Routed through ``np.random.SeedSequence`` so neighbouring indices give
    statistically independent streams (``base_seed + index`` would make
    candidate ``i``'s chain 1 collide with candidate ``i+1``'s chain 0).
    Independent of worker count / scheduling by construction.
    """
    ss = np.random.SeedSequence([abs(int(base_seed)), int(index)])
    return int(ss.generate_state(1, np.uint32)[0])


def derive_task_seed(base_seed: int, cand_idx: int, wl_idx: int) -> int:
    """Per-(candidate, workload) task seed — the engine's unit of work.

    Workload index 0 reduces to :func:`derive_seed`, so single-workload
    sweeps (and workload 0 of multi-workload sweeps) keep the exact seeds
    of the per-candidate schema — which is what makes schema-v1 checkpoint
    records reusable after migration.  Later workloads append their index
    to the ``SeedSequence`` entropy key, giving every task an independent
    stream regardless of worker count, sharding or completion order.
    """
    if wl_idx == 0:
        return derive_seed(base_seed, cand_idx)
    ss = np.random.SeedSequence(
        [abs(int(base_seed)), int(cand_idx), int(wl_idx)])
    return int(ss.generate_state(1, np.uint32)[0])


def parse_shard_spec(spec: str) -> Tuple[int, int]:
    """Parse an ``"i/n"`` shard argument into a validated ``(i, n)``."""
    try:
        i_s, n_s = spec.split("/")
        i, n = int(i_s), int(n_s)
    except ValueError:
        raise ValueError(f"shard spec {spec!r} is not of the form i/n")
    if n < 1 or not 0 <= i < n:
        raise ValueError(f"shard spec {spec!r} needs 0 <= i < n")
    return i, n


# ---------------------------------------------------------------------------
# Replica-exchange SA (parallel tempering)
# ---------------------------------------------------------------------------

def replica_exchange_sa(g: Graph, arch: ArchConfig,
                        groups: Sequence[LayerGroup], total_batch: int,
                        cfg: SAConfig, init: Optional[Mapping] = None,
                        evaluator: Optional[Evaluator] = None) -> SAResult:
    """Parallel tempering over ``cfg.n_chains`` chains (paper Sec. V-B1 SA,
    upgraded from independent restarts).

    Chain 0 is an **unswapped reference chain**: same seed and cooling
    schedule as the single-chain engine and excluded from state exchanges,
    so its trajectory — and therefore its best — is bit-identical to
    ``n_chains=1``.  The returned global best can consequently never be
    worse than the single-chain result on the same seed (elitism), which
    turns the satellite invariant into a structural guarantee rather than
    a per-seed accident.

    Chains ``1..N-1`` form the tempering ladder: chain ``k`` anneals at
    ``t_ladder**(k-1)`` times the base temperature, and every
    ``swap_every`` iterations adjacent ladder chains attempt a Metropolis
    state swap ``P = min(1, exp((1/T_a - 1/T_b) * (cost_a - cost_b)))``,
    so good configurations found by hot (exploratory) chains percolate
    down while locally-refined cold states heat up to escape minima.  All
    chains share one content-addressed evaluator cache, so a state
    re-visited by any chain is never re-analyzed.  Chain ``k`` is seeded
    ``cfg.seed + k``; the best mapping over all chains is re-evaluated
    exactly.

    With ``cfg.lockstep`` (the default) the chains advance through
    :func:`repro.core.sa.step_chains_lockstep`: each iteration draws every
    chain's proposal, batch-evaluates them in one vectorized analyzer
    replay per touched layer group, then runs the acceptances in chain
    order.  Per-chain RNG streams are consumed in the serial order and the
    batched evaluator is bit-identical to the scalar one, so trajectories
    — including the reference chain's, and therefore the single-chain
    guarantee — are unchanged; only the per-iteration overhead drops.

    Note ``n_chains=2`` has a one-chain ladder and therefore no swaps —
    it degenerates to two independent seeds plus elitism (the pre-refactor
    restart behavior).  Tempering proper needs ``n_chains >= 3``;
    ``sa_optimize`` warns and substitutes 3 when handed 2.
    """
    ev = evaluator or CachedEvaluator(arch, g)
    cum_w = group_draw_cdf(groups, arch.n_cores)
    chains = [SAChain(g, arch, groups, total_batch, cfg, init, ev,
                      seed=cfg.seed + k, cum_w=cum_w,
                      t_scale=1.0 if k == 0 else cfg.t_ladder ** (k - 1))
              for k in range(cfg.n_chains)]
    ladder = chains[1:]
    swap_rng = np.random.default_rng(
        np.random.SeedSequence([abs(int(cfg.seed)), 0x52455853]))  # "REXS"
    swap_every = max(1, cfg.swap_every)
    history: List[float] = []
    n_pairs = max(0, len(ladder) - 1)
    swap_attempts = [0] * n_pairs
    swap_accepts = [0] * n_pairs
    for it in range(cfg.iters):
        if cfg.lockstep:
            step_chains_lockstep(chains, backend=cfg.backend)
        else:
            for chain in chains:
                chain.step()
        if (it + 1) % swap_every == 0:
            for k in range(n_pairs):
                cold, hot = ladder[k], ladder[k + 1]
                t_cold = max(cold.T, 1e-30)
                t_hot = max(hot.T, 1e-30)
                delta = (1.0 / t_cold - 1.0 / t_hot) * (cold.cost - hot.cost)
                swap_attempts[k] += 1
                if delta >= 0 or swap_rng.random() < math.exp(max(delta, -700.0)):
                    swap_accepts[k] += 1
                    cold.exchange_state(hot)
        if cfg.log_every and it % cfg.log_every == 0:
            history.append(chains[0].cost)      # reference-chain trace
    # pick the winner by *exact* re-evaluated cost (incremental best_cost
    # carries float accumulation error); ties prefer the reference chain,
    # keeping the never-worse-than-single-chain guarantee airtight
    finals = [c.finalize([]) for c in chains]
    res = min(finals, key=lambda r: r.cost)
    res.history = history
    res.accepted = sum(c.accepted for c in chains)
    res.proposed = sum(c.proposed for c in chains)
    res.swap_attempts = swap_attempts
    res.swap_accepts = swap_accepts
    if _obs.enabled():
        # once per SA run, strictly after the result is fixed: the obs
        # layer observes counters the chains already kept, it never adds
        # RNG draws or float ops to the trajectory (bit-identity contract)
        m = _obs.metrics
        m.counter("sa.runs").inc()
        m.counter("sa.proposed").inc(res.proposed)
        m.counter("sa.accepted").inc(res.accepted)
        m.counter("sa.swap_attempts").inc(sum(swap_attempts))
        m.counter("sa.swap_accepts").inc(sum(swap_accepts))
        for c in chains:
            if c.proposed:
                m.histogram("sa.acceptance_rate").observe(
                    c.accepted / c.proposed)
        for a, s in zip(swap_attempts, swap_accepts):
            if a:
                m.histogram("sa.swap_rate").observe(s / a)
    return res


# ---------------------------------------------------------------------------
# ArchConfig <-> JSON (checkpoint records)
# ---------------------------------------------------------------------------

_TECHS = {TECH_12NM.name: TECH_12NM}

_ARCH_FIELDS = ("x_cores", "y_cores", "xcut", "ycut", "noc_bw", "d2d_bw",
                "dram_bw", "glb_kb", "macs_per_core", "freq_ghz", "n_dram")


def register_tech(tech) -> None:
    """Make a non-default :class:`Tech` resumable from checkpoints (archs
    serialize their tech by name; deserialization refuses unknown names
    rather than silently substituting the wrong constants)."""
    _TECHS[tech.name] = tech


def arch_to_dict(arch: ArchConfig) -> Dict[str, Any]:
    d = {f: getattr(arch, f) for f in _ARCH_FIELDS}
    d["tech"] = arch.tech.name
    return d


def arch_from_dict(d: Dict[str, Any]) -> ArchConfig:
    kw = {f: d[f] for f in _ARCH_FIELDS}
    tech_name = d.get("tech", "")
    tech = _TECHS.get(tech_name)
    if tech is None:
        raise ValueError(
            f"unknown tech {tech_name!r} in checkpoint record; call "
            f"explore.register_tech() for non-default technologies")
    return ArchConfig(**kw, tech=tech)


def graph_fingerprint(g: Graph) -> str:
    """Stable content digest of a workload DAG (layers, edges, inputs).

    ``Layer`` is a frozen dataclass, so its ``repr`` enumerates every
    field; two graphs with equal structure hash equally regardless of
    insertion order.  Expected-traffic scales and edge multiplicities are
    ``repr=False`` (they would otherwise churn every dense fingerprint),
    so they hash explicitly here — but only when non-default, keeping
    dense graphs' digests byte-identical to pre-scale checkpoints.
    """
    import hashlib
    h = hashlib.sha1()
    for name in sorted(g.layers):
        lyr = g.layers[name]
        h.update(repr((name, lyr)).encode())
        if lyr.traffic_scale != 1.0 or lyr.weight_traffic_scale != 1.0:
            h.update(repr((name, "scale", lyr.traffic_scale,
                           lyr.weight_traffic_scale)).encode())
    h.update(repr(sorted(g.edges)).encode())
    if g.edge_mults:
        h.update(repr(("mults", sorted(g.edge_mults.items()))).encode())
    h.update(repr(sorted(g.input_layers)).encode())
    return h.hexdigest()[:12]


def candidate_key(arch: ArchConfig) -> str:
    """Stable content identity of a candidate (checkpoint skip key)."""
    d = arch_to_dict(arch)
    return "/".join(f"{f}={d[f]:g}" if isinstance(d[f], float) else
                    f"{f}={d[f]}" for f in (*_ARCH_FIELDS, "tech"))


def task_checkpoint_key(arch: ArchConfig, workload: str) -> str:
    """Checkpoint key of one (candidate, workload) task (schema v2)."""
    return f"{candidate_key(arch)}|wl={workload}"


# ---------------------------------------------------------------------------
# LMS mapping <-> JSON (opt-in; checkpointed when cfg.keep_mappings)
# ---------------------------------------------------------------------------

def mapping_to_jsonable(mapping: Mapping) -> List[Dict[str, Any]]:
    """Serialize a full LP-SPM mapping (list of (LayerGroup, LMS)) to plain
    JSON types.  Inverse of :func:`mapping_from_jsonable`; round-trips
    exactly (all fields are ints/strings)."""
    out: List[Dict[str, Any]] = []
    for grp, lms in mapping:
        out.append({
            "group": {"names": list(grp.names),
                      "batch_unit": int(grp.batch_unit)},
            "lms": {name: {"part": list(ms.part), "cg": list(ms.cg),
                           "fd": list(ms.fd)}
                    for name, ms in lms.ms.items()}})
    return out


def mapping_from_jsonable(data: Sequence[Dict[str, Any]]) -> Mapping:
    """Rebuild a mapping from :func:`mapping_to_jsonable` output.

    ``MS.__post_init__`` re-validates the structural invariants (Part
    product == |CG|, no duplicate cores), so a hand-edited or damaged
    record raises instead of producing a silently-wrong mapping.
    """
    mapping: Mapping = []
    for entry in data:
        grp = LayerGroup(names=tuple(entry["group"]["names"]),
                         batch_unit=int(entry["group"]["batch_unit"]))
        ms = {name: MS(part=tuple(int(v) for v in m["part"]),
                       cg=tuple(int(v) for v in m["cg"]),
                       fd=tuple(int(v) for v in m["fd"]))
              for name, m in entry["lms"].items()}
        mapping.append((grp, LMS(ms=ms)))
    return mapping


def task_to_dict(tr: "_dse.TaskResult", arch: ArchConfig, workload: str,
                 seed: int, keep_mapping: bool) -> Dict[str, Any]:
    """Schema-v2 checkpoint record of one completed task."""
    d: Dict[str, Any] = {"seed": seed, "workload": workload,
                         "arch": arch_to_dict(arch),
                         "energy_j": tr.energy_j, "delay_s": tr.delay_s}
    if keep_mapping and tr.mapping is not None:
        d["mapping"] = mapping_to_jsonable(tr.mapping)
    return d


def task_from_dict(d: Dict[str, Any]) -> "_dse.TaskResult":
    mapping = (mapping_from_jsonable(d["mapping"])
               if "mapping" in d else None)
    return _dse.TaskResult(energy_j=float(d["energy_j"]),
                           delay_s=float(d["delay_s"]), mapping=mapping)


def migrate_v1_record(key: str, rec: Dict[str, Any]
                      ) -> List[Tuple[str, Dict[str, Any]]]:
    """Split a schema-v1 per-candidate record into schema-v2 task records.

    v1 stored one record per candidate (keyed ``candidate_key``) with a
    ``per_workload`` map and a single shared SA seed.  Each workload's
    (E, D) becomes its own task record carrying that seed; on resume the
    engine reuses a record only when its seed matches the v2 task seed —
    true for workload 0 by construction (see :func:`derive_task_seed`),
    so single-workload v1 sweeps resume in full, while extra workloads of
    multi-workload sweeps recompute under their now-independent seeds.
    Mappings were never serialized in v1, so migrated records are
    metrics-only.
    """
    out: List[Tuple[str, Dict[str, Any]]] = []
    per = rec.get("per_workload") or {}
    for name in sorted(per):
        ed = per[name]
        # v1 ran every workload under the one candidate seed, so that seed
        # is the true provenance of each split record; the resume-time seed
        # gate then reuses a record exactly when v2 derives the same seed
        out.append((f"{key}|wl={name}",
                    {"seed": rec.get("seed"),
                     "workload": name, "arch": rec.get("arch"),
                     "energy_j": ed[0], "delay_s": ed[1]}))
    return out


# ---------------------------------------------------------------------------
# Resumable sweeps (JSON-lines checkpoint)
# ---------------------------------------------------------------------------

# checkpoint durability switch: records fsync on append and every atomic
# rewrite fsyncs before rename (crash between write and rename can
# otherwise lose the repair).  On by default; REPRO_CKPT_FSYNC=0 opts
# hot single-host sweeps out of the per-record fsync cost.
def _fsync_enabled() -> bool:
    return _os.environ.get("REPRO_CKPT_FSYNC", "1").lower() not in (
        "0", "false", "off", "no")


def _fsync_file(f) -> None:
    if not _fsync_enabled():
        return
    try:
        _os.fsync(f.fileno())
    except OSError:
        pass


def _replace_durable(dst: Path, text: str) -> None:
    """Atomic replace that survives a crash at any point: write to a
    sibling temp file, fsync it, rename over ``dst``, fsync the
    directory (the rename itself must be on disk before we report the
    repair/merge done)."""
    tmp = dst.with_name(dst.name + ".tmp")
    with tmp.open("w") as f:
        f.write(text)
        f.flush()
        _fsync_file(f)
    tmp.replace(dst)
    if _fsync_enabled():
        try:
            dfd = _os.open(str(dst.parent), _os.O_RDONLY)
            try:
                _os.fsync(dfd)
            finally:
                _os.close(dfd)
        except OSError:
            pass


def _hb_collision(lines: List[str], i: int) -> bool:
    """Is corrupt line ``i`` attributable to a concurrent heartbeat
    writer?

    Task records have exactly one sanctioned class of concurrent
    appender: heartbeat lines (a supervisor-era shard child heartbeats
    the same file its task loop appends to, and a duplicate dispatch may
    briefly share a file).  A torn line that carries an ``"_hb"`` marker
    itself, or sits adjacent to a line that parses as a pure heartbeat,
    is that collision: the damaged record halves are dropped (the
    per-task seed gate recomputes them on resume) instead of poisoning
    the whole checkpoint.
    """
    if '"_hb"' in lines[i]:
        return True
    for j in (i - 1, i + 1):
        if 0 <= j < len(lines) and lines[j].strip():
            try:
                rec = json.loads(lines[j])
            except json.JSONDecodeError:
                continue
            if isinstance(rec, dict) and "_hb" in rec:
                return True
    return False


class ResumableSweep:
    """Append-only JSON-lines checkpoint for long sweeps.

    One ``{"_key": ..., **record}`` object per line; an optional first line
    ``{"_config": fingerprint}`` guards against resuming under a changed
    configuration (mismatch discards the stale file).  A truncated trailing
    line (process killed mid-write) is tolerated and dropped.  Duplicate
    keys are last-wins, so a forced re-run simply appends an overriding
    record.  ``legacy`` maps superseded fingerprints to record-migration
    functions ``(key, rec) -> [(new_key, new_rec), ...]``: a file written
    under an old schema is converted in memory and rewritten atomically
    under the current fingerprint instead of being discarded.  Used by
    ``run_dse(..., checkpoint=...)`` and by the hillclimb driver
    (``launch/hillclimb.py``).
    """

    def __init__(self, path: Union[str, Path],
                 config_fingerprint: Optional[str] = None,
                 resume: bool = True,
                 legacy: Optional[Dict[str, Callable[
                     [str, Dict[str, Any]],
                     Iterable[Tuple[str, Dict[str, Any]]]]]] = None):
        self.path = Path(path)
        self.fingerprint = config_fingerprint
        self._legacy = legacy or {}
        self._records: Dict[str, Dict[str, Any]] = {}
        fresh = True
        if self.path.exists():
            if resume:
                fresh = not self._load(readonly=False)
            if fresh:
                self._set_aside()
        if fresh:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            header = (json.dumps({"_config": self.fingerprint}) + "\n"
                      if self.fingerprint is not None else "")
            self.path.write_text(header)

    def _set_aside(self) -> None:
        """Move a rejected file (corrupt line / changed config /
        ``resume=False``) to a fresh ``.bakN`` name — recorded data is
        never destroyed, and existing backups are never clobbered."""
        n = 0
        while True:
            suffix = ".bak" if n == 0 else f".bak{n}"
            bak = self.path.with_name(self.path.name + suffix)
            if not bak.exists():
                break
            n += 1
        self.path.replace(bak)
        _obs.vlog("sweep", f"previous file kept at {bak}")

    @classmethod
    def read(cls, path: Union[str, Path]) -> "ResumableSweep":
        """Read-only parse: never creates, repairs or resets the file.

        For consumers that only render recorded sweeps (``launch/report``);
        a corrupt or config-mismatched file yields whatever records parse
        instead of triggering the constructor's set-aside logic.
        """
        inst = cls.__new__(cls)
        inst.path = Path(path)
        inst.fingerprint = None
        inst._legacy = {}
        inst._records = {}
        if inst.path.exists():
            inst._load(readonly=True)
        return inst

    def _load(self, readonly: bool) -> bool:
        """Parse the existing file; False if it must be discarded."""
        text = self.path.read_text()
        lines = text.splitlines()
        valid: List[str] = []
        saw_header = False
        migrate = None
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                if i == len(lines) - 1:
                    continue                  # truncated final line: drop it
                if _hb_collision(lines, i):
                    # torn by a concurrent heartbeat writer: drop just the
                    # damaged line(s); the repair rewrite below heals the
                    # file and the seed gate recomputes the lost record
                    _obs.vlog("sweep", f"{self.path}: line {i + 1} torn by "
                              "a concurrent heartbeat writer; dropped")
                    continue
                _obs.vlog("sweep", f"{self.path}: corrupt line {i + 1}; "
                          "discarding checkpoint")
                if readonly:
                    continue                  # salvage what parses
                self._records.clear()        # discard means ALL records
                return False
            if "_config" in rec:
                if self.fingerprint is not None \
                        and rec["_config"] != self.fingerprint:
                    if rec["_config"] in self._legacy:
                        # superseded schema: convert records, rewrite below
                        migrate = self._legacy[rec["_config"]]
                        saw_header = True
                        continue
                    _obs.vlog("sweep", f"{self.path}: config changed; "
                              "discarding checkpoint")
                    return False
                saw_header = True
                valid.append(line)
                continue
            valid.append(line)
            key = rec.pop("_key", None)
            if key is not None:
                self._records[key] = rec
        if not readonly and self.fingerprint is not None and not saw_header \
                and self._records:
            # a fingerprinted sweep whose header is gone (e.g. killed while
            # writing it) can no longer prove the records match this config
            _obs.vlog("sweep", f"{self.path}: missing config header; "
                      "discarding checkpoint")
            self._records.clear()
            return False
        if migrate is not None and not readonly:
            old = self._records
            self._records = {}
            for key, rec in old.items():
                for k2, r2 in migrate(key, rec):
                    self._records[k2] = r2
            _obs.vlog("sweep", f"{self.path}: migrated {len(old)} legacy "
                      f"records -> {len(self._records)} under the current "
                      "schema")
            self._rewrite()
            return True
        # a killed-mid-write trailing fragment (or missing final newline)
        # would merge with the next append — repair the file first;
        # atomically (temp + fsync + replace), so a crash at any point
        # mid-repair cannot lose the already-recorded lines
        repaired = "".join(v + "\n" for v in valid)
        if not readonly and repaired != text:
            _replace_durable(self.path, repaired)
        return True

    def _rewrite(self) -> None:
        """Atomically replace the file with the in-memory records."""
        header = (json.dumps({"_config": self.fingerprint}) + "\n"
                  if self.fingerprint is not None else "")
        body = "".join(json.dumps({"_key": k, **r}, default=float) + "\n"
                       for k, r in self._records.items())
        _replace_durable(self.path, header + body)

    def __contains__(self, key: str) -> bool:
        return key in self._records

    def __len__(self) -> int:
        return len(self._records)

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        return self._records.get(key)

    def add(self, key: str, record: Dict[str, Any]) -> None:
        self._records[key] = record
        with self.path.open("a") as f:
            f.write(json.dumps({"_key": key, **record}, default=float) + "\n")
            f.flush()
            # records are the durable artifact: fsync before returning, so
            # a host losing power right after a task completes never loses
            # work the supervisor believes is checkpointed
            _fsync_file(f)

    def heartbeat(self, payload: Dict[str, Any]) -> None:
        """Append a ``{"_hb": ...}`` liveness line (shard id, tasks
        done/total, wall time — see ``ExplorationEngine``).

        Heartbeats are *not* records: they carry no ``_key``, so
        :meth:`_load`, :meth:`read` and :func:`merge_checkpoints` all skip
        them (and any rewrite/merge drops them), while a multi-host driver
        polling the file tail can tell a slow shard from a dead one.
        Heartbeats flush but do not fsync — losing one to a crash only
        ages the liveness view, never data.
        """
        with self.path.open("a") as f:
            f.write(json.dumps({"_hb": payload}, default=float) + "\n")
            f.flush()

    def as_dict(self) -> Dict[str, Dict[str, Any]]:
        return dict(self._records)


# ---------------------------------------------------------------------------
# Shard merging
# ---------------------------------------------------------------------------

@dataclass
class MergeReport:
    """Outcome of :func:`merge_checkpoints`."""
    fingerprint: Optional[str]
    records: Dict[str, Dict[str, Any]]
    merged: List[Path]                    # shards that contributed
    skipped: List[Tuple[Path, str]]       # (path, reason) set aside
    out: Optional[Path] = None
    # task keys where two shards recorded *different* results — the
    # symptom of a fingerprint or seed-gate bug (duplicate dispatch of a
    # deterministic task must reproduce the identical record); last-wins
    # still applies, but silently so no longer
    conflicts: List[str] = field(default_factory=list)

    @property
    def n_records(self) -> int:
        return len(self.records)


def _parse_checkpoint_shard(path: Path
                            ) -> Tuple[Optional[str], Dict[str, Dict]]:
    """Strict parse of one shard file: (fingerprint, ordered records).

    A truncated *final* line (shard killed mid-write) is tolerated and
    dropped, exactly as on resume; any other parse failure marks the whole
    shard corrupt — a mid-file hole means unknown records were lost, and a
    partial merge would silently present itself as complete.
    """
    text = path.read_text()
    lines = text.splitlines()
    fingerprint: Optional[str] = None
    records: Dict[str, Dict] = {}
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                continue                      # killed mid-write: drop it
            if _hb_collision(lines, i):
                continue        # torn by a concurrent heartbeat writer
            raise ValueError(f"corrupt line {i + 1}")
        if "_config" in rec:
            if fingerprint is not None and rec["_config"] != fingerprint:
                raise ValueError("conflicting _config headers")
            fingerprint = rec["_config"]
            continue
        key = rec.pop("_key", None)
        if key is not None:
            records[key] = rec                # in-file duplicates: last wins
    return fingerprint, records


def _records_conflict(a: Dict[str, Any], b: Dict[str, Any]) -> bool:
    """Do two same-key records *disagree*?

    A metrics-only record and its ``keep_mappings`` upgrade (identical
    metrics, one extra ``mapping`` field) are the one sanctioned way
    records legitimately differ, so mappings compare only when both
    records carry one; every other field difference is a conflict.
    """
    ka, kb = set(a) - {"mapping"}, set(b) - {"mapping"}
    if ka != kb or any(a[k] != b[k] for k in ka):
        return True
    return ("mapping" in a and "mapping" in b
            and a["mapping"] != b["mapping"])


def merge_checkpoints(shards: Sequence[Union[str, Path]],
                      out: Union[str, Path, None] = None,
                      expect_fingerprint: Optional[str] = None,
                      verbose: bool = True,
                      on_conflict: str = "report") -> MergeReport:
    """Merge per-shard :class:`ResumableSweep` checkpoints into one.

    * every usable shard must carry the **same** config fingerprint (and
      match ``expect_fingerprint`` when given) — a mismatch refuses the
      whole merge rather than mixing incompatible sweeps;
    * duplicate keys are **last-wins** in ``shards`` order (within a
      shard, in line order), mirroring the sweep's own append semantics —
      overlapping shard ranges are therefore safe; but two shards
      recording *different* results for the same task key is the symptom
      of a fingerprint or seed-gate bug (the supervisor's duplicate
      dispatch can trigger it), so such keys are collected in
      ``MergeReport.conflicts`` and reported (``on_conflict="report"``,
      the default) or refused (``on_conflict="error"`` — what the
      supervisor passes: a conflicted merge can never be bit-identical
      to the clean run);
    * a corrupt or unreadable shard is **set aside** (skipped, reported in
      ``MergeReport.skipped``) instead of poisoning the others; source
      files are never modified.

    With ``out`` set, the merged checkpoint is written atomically (with a
    ``_merged_from`` provenance line) and is directly resumable:
    ``run_dse(candidates, ..., checkpoint=out)`` reconstructs the full
    sweep, recomputing only tasks no shard covered.
    """
    if on_conflict not in ("report", "error"):
        raise ValueError(
            f"on_conflict must be 'report' or 'error', got {on_conflict!r}")
    parsed: List[Tuple[Path, Optional[str], Dict[str, Dict]]] = []
    skipped: List[Tuple[Path, str]] = []
    for p in (Path(s) for s in shards):
        try:
            fp, recs = _parse_checkpoint_shard(p)
        except (ValueError, OSError) as e:
            if verbose:
                _obs.vlog("merge", f"{p}: {e}; shard set aside")
            skipped.append((p, str(e)))
            continue
        parsed.append((p, fp, recs))
    if not parsed:
        raise ValueError(
            f"merge_checkpoints: no usable shards among {list(shards)}")
    fps = {fp for _, fp, _ in parsed}
    if expect_fingerprint is not None and fps != {expect_fingerprint}:
        raise ValueError(
            f"merge_checkpoints: shard fingerprints {sorted(map(repr, fps))} "
            f"!= expected {expect_fingerprint!r}")
    if len(fps) > 1:
        raise ValueError(
            "merge_checkpoints: refusing to merge shards with mismatched "
            f"fingerprints: {sorted(map(repr, fps))}")
    fingerprint = next(iter(fps))
    records: Dict[str, Dict] = {}
    conflicts: List[str] = []
    for _p, _fp, recs in parsed:
        for k, r in recs.items():             # later shards win duplicates
            prev = records.get(k)
            if prev is not None and _records_conflict(prev, r):
                conflicts.append(k)
            records[k] = r
    conflicts = sorted(set(conflicts))
    if conflicts:
        sample = ", ".join(conflicts[:3])
        msg = (f"{len(conflicts)} task key(s) have conflicting records "
               f"across shards (e.g. {sample}) — a fingerprint or "
               f"seed-gate bug; duplicate dispatch of a deterministic "
               f"task must reproduce identical records")
        if on_conflict == "error":
            raise ValueError(f"merge_checkpoints: {msg}")
        _obs.vlog("merge", f"WARNING: {msg}", n_conflicts=len(conflicts))
        _obs.metrics.counter("merge.conflicts").inc(len(conflicts))
    report = MergeReport(fingerprint=fingerprint, records=records,
                         merged=[p for p, _, _ in parsed], skipped=skipped,
                         conflicts=conflicts)
    if out is not None:
        out = Path(out)
        out.parent.mkdir(parents=True, exist_ok=True)
        header = (json.dumps({"_config": fingerprint}) + "\n"
                  if fingerprint is not None else "")
        prov = json.dumps(
            {"_merged_from": [p.name for p in report.merged]}) + "\n"
        body = "".join(json.dumps({"_key": k, **r}, default=float) + "\n"
                       for k, r in records.items())
        _replace_durable(out, header + prov + body)
        report.out = out
    if verbose:
        note = f" ({len(skipped)} shard(s) set aside)" if skipped else ""
        _obs.vlog(
            "merge",
            f"{len(records)} records from {len(report.merged)} "
            f"shard(s){' -> ' + str(out) if out is not None else ''}{note}",
            n_records=len(records), n_shards=len(report.merged),
            n_skipped=len(skipped))
    return report


# ---------------------------------------------------------------------------
# Supervisor-facing sweep introspection (multi-host re-sharding)
# ---------------------------------------------------------------------------

def sweep_fingerprint(workloads: Dict[str, Graph], cfg: "_dse.DSEConfig",
                      use_sa: bool = True) -> str:
    """The checkpoint fingerprint a sweep of ``(workloads, cfg)`` stamps.

    Public wrapper over the engine's internal fingerprint so the
    multi-host supervisor (``repro.dist``) can assert every shard
    artifact — and the final merge — against the one expected header
    without running anything.
    """
    with ExplorationEngine(workloads, cfg) as eng:
        return eng._fingerprint(use_sa)


def remaining_candidate_indices(candidates: Sequence[ArchConfig],
                                workloads: Dict[str, Graph],
                                cfg: "_dse.DSEConfig",
                                checkpoint: Union[str, Path],
                                use_sa: bool = True,
                                indices: Optional[Iterable[int]] = None,
                                ) -> List[int]:
    """Candidate indices whose (candidate x workload) tasks are NOT all
    resumable from ``checkpoint`` — the re-shard unit of the multi-host
    supervisor.

    Mirrors the engine's resume gate exactly: a task counts as done only
    when its record exists under the sweep's fingerprint, carries the
    seed this sweep would derive (``use_sa`` sweeps), and has a mapping
    when ``cfg.keep_mappings`` asks for one.  The checkpoint is parsed
    tolerantly (a dead shard's torn tail or heartbeat-collision damage
    just leaves those tasks "remaining"), and a missing / foreign-
    fingerprint file leaves *everything* remaining — re-sharding is
    always safe because reassigned tasks recompute bit-identically.
    """
    wl_names = sorted(workloads)
    fingerprint = sweep_fingerprint(workloads, cfg, use_sa)
    want = sorted(set(int(i) for i in indices)) if indices is not None \
        else list(range(len(candidates)))
    for i in want:
        if not 0 <= i < len(candidates):
            raise ValueError(f"candidate index {i} outside the grid "
                             f"(0..{len(candidates) - 1})")
    path = Path(checkpoint)
    records: Dict[str, Dict[str, Any]] = {}
    if path.exists():
        try:
            fp, records = _parse_checkpoint_shard(path)
        except (ValueError, OSError):
            # strict parse refused the file (mid-file hole): salvage what
            # the tolerant reader can — lost records simply stay remaining
            fp = None
            sweep = ResumableSweep.read(path)
            records = sweep.as_dict()
            head = path.read_text().splitlines()[:1]
            if head:
                try:
                    fp = json.loads(head[0]).get("_config")
                except (json.JSONDecodeError, AttributeError):
                    fp = None
        if fp != fingerprint:
            records = {}                      # foreign sweep: nothing reusable
    out: List[int] = []
    keep = cfg.keep_mappings
    for ci in want:
        arch = candidates[ci]
        for wi, name in enumerate(wl_names):
            rec = records.get(task_checkpoint_key(arch, name))
            if rec is None \
                    or (use_sa and rec.get("seed")
                        != derive_task_seed(cfg.sa.seed, ci, wi)) \
                    or (keep and "mapping" not in rec):
                out.append(ci)
                break
    return out


# ---------------------------------------------------------------------------
# Pareto frontier over (MC, E, D)
# ---------------------------------------------------------------------------

def _pareto_mask_quadratic(vals: List[Tuple]) -> List[bool]:
    """Reference O(n^2) all-pairs dominance check (kept for arbitrary key
    counts and as the property-test oracle for the sweep below)."""
    out = []
    for i, vi in enumerate(vals):
        out.append(not any(
            all(a <= b for a, b in zip(vj, vi)) and vj != vi
            for j, vj in enumerate(vals) if j != i))
    return out


def _pareto_mask_sweep(vals: List[Tuple]) -> List[bool]:
    """Sort-based sweep for 2-3 keys: O(n log n) instead of all-pairs.

    Points are processed in lexicographic order (any dominator of ``v``
    is lex-<= ``v``; lex-equal vectors never dominate each other, so
    groups of identical vectors are decided together).  A staircase of
    non-dominated ``(y, z)`` pairs — ``y`` strictly ascending, ``z``
    strictly descending — answers "does any earlier point have y' <= y
    and z' <= z" with one bisect; 2-key inputs use a constant third
    coordinate.  Exactly equivalent to the all-pairs rule, including tie
    handling (identical vectors are all kept).
    """
    from bisect import bisect_left, bisect_right
    order = sorted(range(len(vals)), key=lambda i: vals[i])
    keep = [False] * len(vals)
    ys: List = []
    zs: List = []
    i = 0
    while i < len(order):
        j = i
        v = vals[order[i]]
        while j < len(order) and vals[order[j]] == v:
            j += 1
        y, z = (v[1], v[2]) if len(v) == 3 else (v[1], 0)
        pos = bisect_right(ys, y) - 1
        if not (pos >= 0 and zs[pos] <= z):      # not dominated
            for t in range(i, j):
                keep[order[t]] = True
            # insert (y, z); drop staircase entries the new pair dominates
            # (y'' >= y with z'' >= z form a prefix of the tail, since z
            # is descending)
            ip = bisect_left(ys, y)
            q = ip
            while q < len(ys) and zs[q] >= z:
                q += 1
            ys[ip:q] = [y]
            zs[ip:q] = [z]
        i = j
    return keep


def pareto_frontier(points: Sequence["_dse.DSEPoint"],
                    keys: Tuple[str, ...] = ("mc", "energy_j", "delay_s"),
                    ) -> List["_dse.DSEPoint"]:
    """Non-dominated subset under element-wise minimization of ``keys``.

    A point is dominated if some other point is <= on every key and < on at
    least one.  Ties (identical key vectors) are all kept.  Returned sorted
    by scalar objective, best first.  The default 2-3 key case runs a sort
    + staircase sweep (O(n log n)); other key counts fall back to the
    all-pairs scan.
    """
    vals = [tuple(getattr(p, k) for k in keys) for p in points]
    if vals and len(vals[0]) in (2, 3):
        mask = _pareto_mask_sweep(vals)
    else:
        mask = _pareto_mask_quadratic(vals)
    out = [p for p, m in zip(points, mask) if m]
    out.sort(key=lambda p: p.objective)
    return out


# ---------------------------------------------------------------------------
# Worker-process plumbing
# ---------------------------------------------------------------------------

# populated once per worker by the pool initializer; workloads + cfg are
# pickled exactly once per worker instead of once per task
_WORKER_STATE: Dict[str, Any] = {}


def _worker_init(workloads: Dict[str, Graph], cfg: "_dse.DSEConfig",
                 obs_state: Optional[Dict[str, Any]] = None) -> None:
    _WORKER_STATE["workloads"] = workloads
    _WORKER_STATE["cfg"] = cfg
    # spawned workers don't inherit a programmatic obs.enable(); the
    # parent ships its switch + run dir through the initializer so worker
    # trace streams land in the same run directory
    _obs.import_state(obs_state)


def _worker_eval(task: Tuple[int, int, ArchConfig, str, int, bool]
                 ) -> Tuple[int, int, "_dse.TaskResult",
                            Optional[Dict[str, Any]]]:
    ci, wi, arch, wl_name, seed, use_sa = task
    obs_on = _obs.enabled()
    t_start = _time.time() if obs_on else 0.0
    cfg = _WORKER_STATE["cfg"]
    tr = _dse.evaluate_task(arch, _WORKER_STATE["workloads"][wl_name], cfg,
                            use_sa=use_sa, seed=seed)
    if not cfg.keep_mappings:
        tr.mapping = None       # don't pickle mappings nobody asked for
    payload: Optional[Dict[str, Any]] = None
    if obs_on:
        # piggyback this worker's metrics delta on the result: counters +
        # collector harvest since the previous task, plus wall-clock task
        # bounds the parent turns into queue-wait/wall-time telemetry
        _obs.flush()
        payload = {"pid": _os.getpid(), "t_start": t_start,
                   "t_end": _time.time(), "metrics": _obs.metrics.drain()}
    return ci, wi, tr, payload


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

# a task is (cand_idx, wl_idx, arch, workload name, derived seed)
_Task = Tuple[int, int, ArchConfig, str, int]


class ExplorationEngine:
    """Screened, parallel, sharded, resumable (candidate x workload) sweeps.

    One engine instance owns (at most) one worker pool; ``screen()`` and
    ``run()`` share it, so the per-worker import + unpickle cost is paid
    once per sweep.  Use as a context manager (or call :meth:`close`).

    Workloads are indexed in **sorted-name order** for seed derivation and
    reduction, so results never depend on dict insertion order (shards
    built by different drivers stay merge-compatible).

    ``mp_context`` defaults to ``"spawn"``: the parent process may hold JAX
    thread pools (fork-unsafe), and spawned workers import only the NumPy
    cost-model stack.
    """

    def __init__(self, workloads: Dict[str, Graph], cfg: "_dse.DSEConfig",
                 n_workers: int = 1, checkpoint: Union[str, Path, None] = None,
                 progress: bool = False, mp_context: str = "spawn",
                 batched_screen: bool = True,
                 verbosity: Optional[int] = None,
                 hb_every: Optional[float] = None):
        self.workloads = dict(workloads)
        self._wl_names = sorted(self.workloads)
        self.cfg = cfg
        ww = getattr(cfg, "workload_weights", None)
        if ww is not None:
            unknown = sorted(set(ww) - set(self.workloads))
            if unknown:
                raise ValueError(
                    f"workload_weights name(s) {unknown} not in this "
                    f"sweep's workloads {self._wl_names} — a typo here "
                    f"would silently weigh the portfolio uniformly")
        obj = getattr(cfg, "objective", "geomean")
        if obj not in ("geomean", "slo"):
            raise ValueError(
                f"unknown DSE objective {obj!r}: 'geomean' or 'slo'")
        if obj == "slo":
            # resolve eagerly: a typo'd traffic name must fail before the
            # sweep burns hours of SA, not in the final reduction
            from ..serve.slo import resolve_traffic
            if cfg.traffic is None:
                raise ValueError(
                    "objective='slo' needs cfg.traffic (a TrafficModel, "
                    "registered name, or trace spec — see repro.serve.slo)")
            resolve_traffic(cfg.traffic)
        self.n_workers = max(1, int(n_workers))
        self.checkpoint = checkpoint
        self.progress = progress
        self.mp_context = mp_context
        # batched T-Map screening (bit-identical to the per-candidate
        # loop); False keeps the per-task path for A/B tests + benchmarks
        self.batched_screen = batched_screen
        self._pool: Optional[ProcessPoolExecutor] = None
        # diagnostics verbosity: the kwarg overrides REPRO_VERBOSITY
        # (default 1 — historical output); 0 silences the [stage] lines
        self.verbosity = verbosity
        # shard-heartbeat period in seconds (liveness lines in the
        # checkpoint; see ResumableSweep.heartbeat).  None reads
        # REPRO_HB_EVERY (default 15s); 0 emits one per completed task.
        if hb_every is None:
            try:
                hb_every = float(_os.environ.get("REPRO_HB_EVERY", "15"))
            except ValueError:
                hb_every = 15.0
        self.hb_every = hb_every
        self._shard_label = "0/1"
        # screening scores of the last run() that screened (sorted best
        # first); lets callers report the screen stage without re-running it
        self.last_screen: Optional[List["_dse.DSEPoint"]] = None

    # -- lifecycle -----------------------------------------------------
    def __enter__(self) -> "ExplorationEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        if self._pool is not None:
            # queued-but-unstarted work is pointless once we're exiting
            # (normally the queue is already drained; after a worker error
            # it isn't, and waiting for it would stall the traceback)
            self._pool.shutdown(cancel_futures=True)
            self._pool = None

    def _get_pool(self) -> ProcessPoolExecutor:
        if self._pool is None:
            import multiprocessing as mp
            self._pool = ProcessPoolExecutor(
                max_workers=self.n_workers,
                mp_context=mp.get_context(self.mp_context),
                initializer=_worker_init,
                initargs=(self.workloads, self.cfg, _obs.export_state()))
        return self._pool

    def _log(self, tag: str, msg: str, **fields: Any) -> None:
        _obs.vlog(tag, msg, verbosity=self.verbosity, **fields)

    # -- fingerprint for checkpoint compatibility ----------------------
    def _fingerprint(self, use_sa: bool, schema: int = 2,
                     re_knobs: Optional[Tuple[int, float]] = None) -> str:
        c = self.cfg
        # workloads hash by *content*, not name: editing a graph while
        # keeping its dict key must invalidate the checkpoint.
        # keep_mappings is deliberately NOT part of the fingerprint: a
        # metrics-only sweep resumed with keep_mappings=True recomputes
        # just the tasks whose records lack a mapping.
        wl = ",".join(f"{n}:{graph_fingerprint(self.workloads[n])}"
                      for n in self._wl_names)
        swap, ladder = re_knobs or (c.sa.swap_every, c.sa.t_ladder)
        # portfolio weights join the fingerprint ONLY when set: weightless
        # sweeps keep their historical header and stay resumable, while a
        # re-weighted portfolio never silently reuses old records.  Note
        # the segment sits BEFORE :wl= (realize's header parser partitions
        # on ':wl=' and must keep seeing the workload list last).
        w = ""
        if getattr(c, "workload_weights", None) is not None:
            ww = c.workload_weights
            w = "w=" + ",".join(f"{n}:{float(ww.get(n, 1.0)):g}"
                                for n in self._wl_names) + ":"
        # non-default objective modes stamp their own segment (also before
        # :wl=): an SLO-scored sweep under one traffic model never shares
        # artifacts with the raw-delay sweep or a re-trafficked one, while
        # the default mode keeps the historical header byte-identical
        obj = ""
        if getattr(c, "objective", "geomean") != "geomean":
            from ..serve.slo import resolve_traffic
            tfp = (resolve_traffic(c.traffic).fingerprint()
                   if c.traffic is not None else "none")
            obj = f"obj={c.objective}({tfp}):"
        return (f"dse:v{schema}:a{c.alpha:g}:b{c.beta:g}:g{c.gamma:g}:"
                f"B{c.batch}:"
                f"sa({c.sa.iters},{c.sa.t0:g},{c.sa.t_end:g},{c.sa.seed},"
                f"{c.sa.beta:g},{c.sa.gamma:g},{c.sa.n_chains},"
                f"{swap},{ladder:g}):sa={int(use_sa)}:"
                f"{obj}{w}wl={wl}")

    def _open_sweep(self, checkpoint: Union[str, Path],
                    use_sa: bool) -> ResumableSweep:
        """Open a checkpoint under the current fingerprint, accepting
        superseded-but-equivalent ones via the legacy migration map."""
        keep_rec = lambda k, r: [(k, r)]           # identity migration
        legacy = {self._fingerprint(use_sa, schema=1): migrate_v1_record}
        if self.cfg.sa.n_chains == 1:
            # single-chain sweeps never consult the replica-exchange
            # knobs, yet the fingerprint embeds them — checkpoints
            # written under the pre-retune defaults (50, 3.0) are
            # value-identical and must survive the default change
            legacy[self._fingerprint(use_sa, re_knobs=(50, 3.0))] = keep_rec
            legacy[self._fingerprint(use_sa, schema=1,
                                     re_knobs=(50, 3.0))] = migrate_v1_record
        return ResumableSweep(checkpoint, self._fingerprint(use_sa),
                              legacy=legacy)

    # -- task construction / reduction ---------------------------------
    def _tasks(self, indexed: Sequence[Tuple[int, ArchConfig]]
               ) -> List[_Task]:
        return [(ci, wi, arch, name,
                 derive_task_seed(self.cfg.sa.seed, ci, wi))
                for ci, arch in indexed
                for wi, name in enumerate(self._wl_names)]

    def _reduce(self, indexed: Sequence[Tuple[int, ArchConfig]],
                results: Dict[Tuple[int, int], "_dse.TaskResult"]
                ) -> List["_dse.DSEPoint"]:
        pts = []
        for ci, arch in indexed:
            per = {name: results[(ci, wi)]
                   for wi, name in enumerate(self._wl_names)}
            pts.append(_dse.reduce_tasks(arch, self.cfg, per))
        return pts

    # -- evaluation fan-out --------------------------------------------
    def _map_tasks(self, tasks: List[_Task], use_sa: bool,
                   checkpoint: Union[str, Path, "ResumableSweep", None],
                   stage: str,
                   ) -> Dict[Tuple[int, int], "_dse.TaskResult"]:
        """Evaluate tasks (any order); the returned dict is keyed
        ``(cand_idx, wl_idx)``, so callers reduce deterministically
        regardless of completion order.  ``checkpoint`` may be an
        already-open :class:`ResumableSweep` (the adaptive path calls
        this once per kept candidate and must not re-parse the file
        each time)."""
        results: Dict[Tuple[int, int], "_dse.TaskResult"] = {}
        keep = self.cfg.keep_mappings
        sweep: Optional[ResumableSweep] = None
        if isinstance(checkpoint, ResumableSweep):
            sweep = checkpoint
        elif checkpoint is not None:
            sweep = self._open_sweep(checkpoint, use_sa)
        if sweep is not None:
            n_nomap = 0
            for ci, wi, arch, wl, seed in tasks:
                rec = sweep.get(task_checkpoint_key(arch, wl))
                if rec is None:
                    continue
                # a record is only valid for the seed this sweep would
                # use: editing the candidate grid shifts indices (and
                # therefore derived seeds), and those tasks must recompute
                # or resume would silently mix seeds (SA-less records are
                # seed-independent)
                if use_sa and rec.get("seed") != seed:
                    continue
                if keep and "mapping" not in rec:
                    n_nomap += 1        # metrics-only record, mapping asked
                    continue
                try:
                    results[(ci, wi)] = task_from_dict(rec)
                except (KeyError, ValueError, TypeError) as e:
                    self._log(stage, f"checkpoint record for "
                              f"{arch.label()} x {wl} unusable ({e}); "
                              "recomputing")
            if n_nomap:
                self._log(stage, f"{n_nomap} checkpointed tasks lack "
                          "serialized mappings (metrics-only records, "
                          "keep_mappings sweep); recomputing them")
            if results and self.progress:
                self._log(stage, f"resumed {len(results)}/{len(tasks)} "
                          f"tasks from {sweep.path}")
            _obs.metrics.counter("engine.tasks_resumed").inc(len(results))
        pending = [t for t in tasks if (t[0], t[1]) not in results]
        done_n = len(results)
        t_stage0 = _time.time()
        hb_last = t_stage0

        def _record(ci: int, wi: int, arch: ArchConfig, wl: str, seed: int,
                    tr: "_dse.TaskResult") -> None:
            nonlocal done_n, hb_last
            results[(ci, wi)] = tr
            done_n += 1
            if sweep is not None:
                sweep.add(task_checkpoint_key(arch, wl),
                          task_to_dict(tr, arch, wl, seed, keep))
                now = _time.time()
                if now - hb_last >= self.hb_every:
                    hb_last = now
                    sweep.heartbeat({
                        "shard": self._shard_label, "stage": stage,
                        "done": done_n, "total": len(tasks),
                        "wall_s": now - t_stage0, "t": now})
            if self.progress:
                print(f"[{stage} {done_n}/{len(tasks)}] {arch.label()} "
                      f"x {wl} E={tr.energy_j:.3e}J D={tr.delay_s:.3e}s",
                      flush=True)

        obs_on = _obs.enabled()
        if self.n_workers <= 1 or len(pending) <= 1:
            for ci, wi, arch, wl, seed in pending:
                with _obs.span("task", arch=arch.label(), wl=wl,
                               queue_s=0.0):
                    tr = _dse.evaluate_task(arch, self.workloads[wl],
                                            self.cfg, use_sa=use_sa,
                                            seed=seed)
                if not keep:
                    # mirror the worker path: results live for the whole
                    # sweep, so unrequested mappings must not accumulate
                    tr.mapping = None
                _obs.metrics.counter("engine.tasks").inc()
                _record(ci, wi, arch, wl, seed, tr)
        else:
            pool = self._get_pool()
            submit_t = _time.time() if obs_on else 0.0
            futs = {pool.submit(_worker_eval, (*t, use_sa)): t
                    for t in pending}
            not_done = set(futs)
            try:
                while not_done:
                    done, not_done = wait(not_done,
                                          return_when=FIRST_COMPLETED)
                    for fut in done:
                        ci, wi, tr, payload = fut.result()
                        t = futs[fut]
                        if obs_on and payload is not None:
                            self._absorb_task_payload(t, payload, stage,
                                                      submit_t)
                        _record(ci, wi, t[2], t[3], t[4], tr)
            except BaseException:
                # surface the failure now, not after the queue drains
                for fut in not_done:
                    fut.cancel()
                raise
            finally:
                if obs_on:
                    _obs.metrics.histogram("engine.pool_batch_s").observe(
                        _time.time() - submit_t)
        return results

    def _absorb_task_payload(self, task: _Task, payload: Dict[str, Any],
                             stage: str, submit_t: float) -> None:
        """Fold one worker's piggybacked telemetry into the parent: merge
        its metrics delta, emit a ``task`` span on the worker's behalf
        (wall-clock bounds measured in the worker; queue-wait derived from
        the submit stamp), and feed the queue-wait/wall-time histograms."""
        _obs.metrics.absorb(payload.get("metrics"))
        _obs.metrics.counter("engine.tasks").inc()
        t_start = float(payload.get("t_start", 0.0))
        t_end = float(payload.get("t_end", t_start))
        queue_s = max(0.0, t_start - submit_t)
        dur = max(0.0, t_end - t_start)
        _obs.metrics.histogram("engine.task_wall_s").observe(dur)
        _obs.metrics.histogram("engine.queue_wait_s").observe(queue_s)
        _obs.metrics.histogram("phase.task").observe(dur)
        _obs.emit({"ev": "span", "name": "task",
                   "pid": payload.get("pid"), "t0": t_start, "dur": dur,
                   "attrs": {"arch": task[2].label(), "wl": task[3],
                             "stage": stage, "queue_s": queue_s}})

    # -- batched T-Map screening ---------------------------------------
    def _screen_tasks(self, indexed: Sequence[Tuple[int, ArchConfig]]
                      ) -> Dict[Tuple[int, int], "_dse.TaskResult"]:
        """T-Map-score every candidate in one batched pass per
        bandwidth-sibling signature group.

        The traffic/compute analysis of a T-Map mapping depends on every
        ArchConfig field EXCEPT the three bandwidths
        (:func:`repro.core.evaluator.analysis_signature`), and Table-I
        grids enumerate bandwidths densely — so candidates sharing a
        signature share ``partition_graph``, ``tangram_map`` and every
        ``GroupAnalysis`` bit-for-bit.  This path computes each signature's
        analysis once and re-derives only the per-candidate delay terms,
        vectorized over the signature's bandwidth columns
        (:meth:`repro.core.evaluator.Evaluator.eval_mapping_archs`);
        energies never read a bandwidth and are shared outright.  Results
        are bit-identical to the per-candidate ``evaluate_task`` loop
        (A/B-tested; ``batched_screen=False`` keeps that loop for the
        benchmark's reference leg).
        """
        if not self.batched_screen:
            return self._map_tasks(self._tasks(indexed), use_sa=False,
                                   checkpoint=None, stage="screen")
        keep = self.cfg.keep_mappings
        results: Dict[Tuple[int, int], "_dse.TaskResult"] = {}
        # the signature reads only the arch, so one grouping serves every
        # workload
        by_sig: "OrderedDict[Tuple, List[Tuple[int, ArchConfig]]]" \
            = OrderedDict()
        for ci, arch in indexed:
            by_sig.setdefault(analysis_signature(arch), []).append((ci, arch))
        n_sigs = len(by_sig)
        for wi, name in enumerate(self._wl_names):
            g = self.workloads[name]
            for members in by_sig.values():
                rep = members[0][1]
                groups = partition_graph(g, rep, self.cfg.batch)
                mapping = tangram_map(groups, g, rep)
                ev = evaluator_for(rep, g)
                E, D = ev.eval_mapping_archs(mapping, self.cfg.batch,
                                             [a for _, a in members])
                for (ci, arch), e_c, d_c in zip(members, E, D):
                    results[(ci, wi)] = _dse.TaskResult(
                        energy_j=float(e_c), delay_s=float(d_c),
                        mapping=mapping if keep else None)
        if self.progress:
            self._log("screen", f"batched: {len(indexed)} candidates x "
                      f"{len(self._wl_names)} workloads in {n_sigs} "
                      "signature group(s)")
        return results

    # -- public API ----------------------------------------------------
    def map_archs(self, archs: Sequence[ArchConfig], use_sa: bool = True,
                  ) -> List["_dse.DSEPoint"]:
        """Evaluate ``archs`` (parallel, deterministic), *preserving input
        order* — for callers that reduce positionally (``joint_reuse_dse``)
        rather than rank by objective."""
        indexed = list(enumerate(archs))
        with _obs.span("map", n_archs=len(indexed)):
            results = self._map_tasks(self._tasks(indexed), use_sa=use_sa,
                                      checkpoint=self.checkpoint,
                                      stage="map")
            out = self._reduce(indexed, results)
        self._finalize_obs()
        return out

    def screen(self, candidates: Sequence[ArchConfig]
               ) -> List["_dse.DSEPoint"]:
        """T-Map-only scoring pass (no SA), sorted best-objective first."""
        indexed = list(enumerate(candidates))
        with _obs.span("screen", n_candidates=len(indexed)):
            results = self._screen_tasks(indexed)
        return sorted(self._reduce(indexed, results),
                      key=lambda p: p.objective)

    def _finalize_obs(self) -> None:
        """Land the metrics snapshot + flush trace buffers (no-op while
        disabled); called at the end of every public sweep entry point so
        a killed-later process still leaves a parseable run dir."""
        if _obs.enabled():
            _obs.metrics.write_snapshot()
            _obs.flush()

    def run(self, candidates: Sequence[ArchConfig], use_sa: bool = True,
            screen_keep: Union[float, str] = 1.0,
            shard: Tuple[int, int] = (0, 1),
            indices: Optional[Sequence[int]] = None,
            shard_label: Optional[str] = None,
            ) -> List["_dse.DSEPoint"]:
        """Full sweep: optional screening stage, then (parallel) evaluation
        of this shard's (candidate x workload) tasks.

        Per-task seeds derive from the candidate's index in ``candidates``
        and the workload's sorted-name index, so results are independent of
        ``n_workers``, completion order, screening of *other* candidates,
        sharding and resume.

        ``screen_keep`` selects the screening mode: a fraction in (0, 1)
        keeps the best fixed fraction of T-Map scores (the explicit
        override); ``"auto"`` applies the **adaptive gap rule** — refine
        candidates in screened order and stop as soon as the next
        candidate's T-Map objective gap vs the best screened score exceeds
        the largest SA improvement observed so far in this sweep (a
        heuristic: see :meth:`_run_adaptive`); ``1.0`` (default) is
        exhaustive.

        ``shard=(i, n)`` evaluates only the candidates with
        ``index % n == i``.  The screening stage (deterministic, no SA)
        runs over the FULL grid in every shard so all shards agree on the
        global keep set — merging the n shard checkpoints and resuming is
        then bit-identical to the unsharded sweep.  Adaptive mode is
        incompatible with sharding: the gap rule consumes SA results as
        they arrive, which independent shards cannot agree on.

        ``indices`` is the supervisor-style alternative to stride
        sharding: evaluate exactly the listed global candidate indices
        and run NO screening stage — the caller (``repro.dist``'s
        supervisor) has already screened once and ships each shard an
        explicit slice of the keep set.  Seeds still derive from the
        *global* index, so any partition of the keep set across shards
        merges bit-identically.  ``shard_label`` names this shard in
        heartbeats/manifests when the ``i/n`` stride form doesn't apply.
        """
        candidates = list(candidates)
        si, sn = shard
        if sn < 1 or not 0 <= si < sn:
            raise ValueError(f"bad shard {si}/{sn}: need 0 <= i < n")
        if indices is not None:
            if sn > 1:
                raise ValueError("indices= is an explicit task list; "
                                 "combining it with stride sharding "
                                 f"({si}/{sn}) is ambiguous")
            if screen_keep != 1.0:
                raise ValueError(
                    "indices= means screening already happened upstream; "
                    "pass screen_keep=1.0 (the supervisor ships the keep "
                    "set explicitly)")
            idx = sorted(set(int(i) for i in indices))
            for i in idx:
                if not 0 <= i < len(candidates):
                    raise ValueError(f"candidate index {i} outside the "
                                     f"grid (0..{len(candidates) - 1})")
        self._shard_label = shard_label or f"{si}/{sn}"
        indexed = list(enumerate(candidates))
        self.last_screen = None
        if _obs.enabled():
            _obs.manifest.write_manifest({
                "stage": "run", "fingerprint": self._fingerprint(use_sa),
                "seed": self.cfg.sa.seed, "grid": len(candidates),
                "n_workloads": len(self._wl_names),
                "shard": self._shard_label, "n_workers": self.n_workers,
                "screen_keep": screen_keep,
                "checkpoint": (str(self.checkpoint)
                               if self.checkpoint is not None else None)})
        if use_sa and screen_keep == "auto" and len(candidates) > 1:
            if sn > 1:
                raise ValueError(
                    "adaptive screening (screen_keep='auto') decides the "
                    "keep set from SA results as they arrive, which "
                    "independent shards cannot agree on; pass a fixed "
                    "screen_keep fraction for sharded sweeps")
            return self._run_adaptive(indexed)
        if screen_keep == "auto":
            screen_keep = 1.0          # nothing to screen (or no SA stage)
        if isinstance(screen_keep, str):
            raise ValueError(
                f"screen_keep must be a fraction or 'auto', "
                f"got {screen_keep!r}")
        if use_sa and screen_keep < 1.0 and len(candidates) > 1:
            with _obs.span("screen", n_candidates=len(indexed)):
                screen_results = self._screen_tasks(indexed)
                screen_pts = self._reduce(indexed, screen_results)
            order = sorted(range(len(indexed)),
                           key=lambda i: screen_pts[i].objective)
            # epsilon guard: fraction-derived keeps like 6/n can float up
            # (6/187*187 == 6.000000000000001) and must not round to 7
            keep = max(1, min(len(indexed),
                              math.ceil(screen_keep * len(indexed) - 1e-9)))
            kept = sorted(order[:keep])
            self._log("explore", f"screening kept {keep}/{len(indexed)} "
                      f"candidates (pruned {len(indexed) - keep})")
            _obs.metrics.counter("screen.kept").inc(keep)
            _obs.metrics.counter("screen.pruned").inc(len(indexed) - keep)
            self.last_screen = [screen_pts[i] for i in order]
            indexed = [indexed[i] for i in kept]
        if indices is not None:
            want = set(idx)
            indexed = [(ci, arch) for ci, arch in indexed if ci in want]
            self._log("explore",
                      f"shard {self._shard_label}: {len(indexed)} assigned "
                      f"candidates ({len(indexed) * len(self._wl_names)} "
                      "tasks)")
        if sn > 1:
            mine = [(ci, arch) for ci, arch in indexed if ci % sn == si]
            self._log("explore",
                      f"shard {si}/{sn}: {len(mine)}/{len(indexed)} "
                      f"candidates ({len(mine) * len(self._wl_names)} tasks)")
            indexed = mine
        with _obs.span("dse", shard=self._shard_label,
                       n_candidates=len(indexed)):
            results = self._map_tasks(self._tasks(indexed), use_sa=use_sa,
                                      checkpoint=self.checkpoint,
                                      stage="dse")
            out = sorted(self._reduce(indexed, results),
                         key=lambda p: p.objective)
        self._finalize_obs()
        return out

    def _run_adaptive(self, indexed: List[Tuple[int, ArchConfig]]
                      ) -> List["_dse.DSEPoint"]:
        """Gap-rule screening (``screen_keep="auto"``), ROADMAP item.

        After the T-Map screen, candidates are refined best-screened-first.
        Let ``gain_max`` be the largest log-objective improvement SA has
        delivered over its own candidate's T-Map score so far; a candidate
        whose T-Map gap to the *best* screened score exceeds ``gain_max``
        is pruned, and so is everything behind it (screened order is
        monotone in the gap).  This is a *heuristic* stopping rule, not a
        bound: it assumes no pruned candidate's achievable SA gain exceeds
        the largest gain observed on the refined ones — a candidate whose
        T-Map mapping is unusually far from its optimum can still be
        missed (the fixed-fraction override exists for exactly that
        doubt).  Huge grids prune hard; tight grids degrade to
        exhaustive.  Fully deterministic (screened order + per-task
        seeds), so resume replays identically.
        """
        screen_results = self._screen_tasks(indexed)
        screen_pts = self._reduce(indexed, screen_results)
        order = sorted(range(len(indexed)),
                       key=lambda i: screen_pts[i].objective)
        self.last_screen = [screen_pts[i] for i in order]
        # one sweep for the whole refine loop: re-opening per candidate
        # would re-parse the growing checkpoint O(kept^2) times
        sweep: Union[ResumableSweep, None] = None
        if self.checkpoint is not None:
            sweep = self._open_sweep(self.checkpoint, use_sa=True)
        best_log = math.log(screen_pts[order[0]].objective)
        gain_max = 0.0
        kept: List[Tuple[int, ArchConfig]] = []
        results: Dict[Tuple[int, int], "_dse.TaskResult"] = {}
        for rank, oi in enumerate(order):
            gap = math.log(screen_pts[oi].objective) - best_log
            if rank > 0 and gap > gain_max:
                break
            ci, arch = indexed[oi]
            res = self._map_tasks(self._tasks([(ci, arch)]), use_sa=True,
                                  checkpoint=sweep, stage="dse")
            results.update(res)
            kept.append((ci, arch))
            pt = self._reduce([(ci, arch)], res)[0]
            gain_max = max(gain_max, math.log(screen_pts[oi].objective)
                           - math.log(pt.objective))
        self._log("explore",
                  f"adaptive screening kept {len(kept)}/{len(indexed)}"
                  f" candidates (largest SA gain {gain_max:.3g} in "
                  f"log-objective; pruned {len(indexed) - len(kept)})")
        _obs.metrics.counter("screen.kept").inc(len(kept))
        _obs.metrics.counter("screen.pruned").inc(len(indexed) - len(kept))
        out = sorted(self._reduce(sorted(kept), results),
                     key=lambda p: p.objective)
        self._finalize_obs()
        return out
