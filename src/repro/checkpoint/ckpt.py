"""Checkpointing: atomic, resumable, elastic.

Format: one ``.npz`` with '/'-joined tree paths as keys + a json sidecar
(step, tree structure, dtypes).  Writes go to a temp file then ``os.replace``
(atomic on POSIX) so a crash mid-write never corrupts the latest checkpoint.
``restore`` device_puts onto whatever shardings the *current* mesh wants —
that is the elastic-rescale path (save on 8 devices, restore on 4: the host
round-trip re-shards automatically).

``CheckpointManager`` adds keep-K retention, latest-step discovery and an
optional async writer thread (training never blocks on disk).
"""

from __future__ import annotations

import json
import os
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Pytree = Any
SEP = "/"


def _flatten(tree: Pytree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(path: str | Path, tree: Pytree, step: int = 0) -> Path:
    """Atomic save; returns the final path."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    tmp = path.with_suffix(".tmp.npz")
    np.savez(tmp, **flat)
    meta = {"step": int(step), "keys": sorted(flat),
            "treedef": str(jax.tree_util.tree_structure(tree))}
    tmp_meta = path.with_suffix(".tmp.json")
    tmp_meta.write_text(json.dumps(meta))
    os.replace(tmp, path)
    os.replace(tmp_meta, path.with_suffix(".json"))
    return path


def restore(path: str | Path, like: Pytree,
            shardings: Optional[Pytree] = None) -> Pytree:
    """Restore into the structure of ``like``; device_put with ``shardings``
    if given (elastic re-shard happens here)."""
    path = Path(path)
    data = np.load(path)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = [SEP.join(_path_str(q) for q in p)
             for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    leaves = []
    for key, ref in zip(paths, leaves_like):
        if key not in data:
            raise KeyError(f"checkpoint missing key {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {ref.shape}")
        leaves.append(arr.astype(ref.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s),
                            tree, shardings)
    return tree


def load_step(path: str | Path) -> int:
    meta = Path(path).with_suffix(".json")
    return int(json.loads(meta.read_text())["step"])


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3,
                 async_write: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._pending: Optional[threading.Thread] = None

    def _path(self, step: int) -> Path:
        return self.dir / f"ckpt_{step:08d}.npz"

    def steps(self) -> List[int]:
        return sorted(int(p.stem.split("_")[1]) for p in
                      self.dir.glob("ckpt_*.npz"))

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def save(self, tree: Pytree, step: int) -> None:
        # snapshot to host BEFORE handing to the writer thread (donated
        # buffers may be reused by the next step otherwise)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _write():
            save(self._path(step), host_tree, step)
            self._gc()

        self.wait()
        if self.async_write:
            self._pending = threading.Thread(target=_write, daemon=True)
            self._pending.start()
        else:
            _write()

    def restore_latest(self, like: Pytree,
                       shardings: Optional[Pytree] = None
                       ) -> Tuple[Optional[Pytree], int]:
        step = self.latest_step()
        if step is None:
            return None, 0
        return restore(self._path(step), like, shardings), step

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            self._path(s).unlink(missing_ok=True)
            self._path(s).with_suffix(".json").unlink(missing_ok=True)
