"""AdamW with decoupled weight decay, global-norm clipping, microbatch
gradient accumulation and optional int8 error-feedback gradient compression.

No optax in this environment — implemented directly on pytrees.  Optimizer
state can be ZeRO-1 sharded (see ``zero1_axes`` — the m/v trees get an extra
mesh-axis sharding on their largest dim where divisible), which is one of the
§Perf hillclimb knobs.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params: Pytree) -> Dict[str, Pytree]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Pytree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree: Pytree, max_norm: float) -> Tuple[Pytree, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: x * scale, tree), norm


def adamw_update(cfg: AdamWConfig, params: Pytree, grads: Pytree,
                 opt: Dict[str, Pytree]) -> Tuple[Pytree, Dict[str, Pytree],
                                                  Dict[str, jax.Array]]:
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = opt["step"] + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p - (lr * delta).astype(p.dtype)), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# int8 error-feedback gradient compression (beyond-paper distributed trick)
# ---------------------------------------------------------------------------

def compress_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads: Pytree, error: Pytree
                     ) -> Tuple[Pytree, Pytree, Pytree]:
    """Error-feedback int8: returns (quantized, scales, new_error)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = compress_int8(gf)
        deq = decompress_int8(q, s)
        return q, s, gf - deq
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]),
            jax.tree.unflatten(tdef, [o[2] for o in outs]))


def init_error_state(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# ZeRO-1 sharding helper
# ---------------------------------------------------------------------------

def zero1_axes(param_axes: Pytree, shapes: Pytree, shard_axis: str = "data",
               mesh_size: int = 16) -> Pytree:
    """Optimizer-state logical axes: add ``opt_shard`` on the largest
    unsharded divisible dim of each param (maps to the data axis)."""
    def one(axes, shape):
        axes = tuple(axes)
        best, best_dim = None, -1
        for i, (a, d) in enumerate(zip(axes, shape.shape)):
            if a is None and d % mesh_size == 0 and d > best_dim:
                best, best_dim = i, d
        if best is None:
            return axes
        return axes[:best] + ("opt_shard",) + axes[best + 1:]
    return jax.tree.map(
        one, param_axes, shapes,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x))
