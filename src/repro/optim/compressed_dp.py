"""Compressed data-parallel gradient synchronization (beyond-paper).

Instead of letting GSPMD emit fp32 all-reduces for the DP gradient sum,
``compressed_grad_sync`` runs the sync explicitly inside ``shard_map``:
each leaf is scaled by a globally-agreed power-of-two-free scale
(pmax of |g| / 127), quantized to int8, summed over the axis in int32
(hardware-exact), and dequantized.  Error feedback carries the
quantization residual into the next step, so the scheme is unbiased over
time (tests/test_compressed_dp.py).

Wire-format accounting: a ring all-reduce moves ~2·n bytes/element-width
per device; the int16 wire format halves the gradient-sync collective
bytes vs fp32 (verified from compiled HLO in the test).  On the roofline this attacks the
collective term of DP-dominated training cells.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def _sync_leaf(g: jax.Array, e: jax.Array, axis: str
               ) -> Tuple[jax.Array, jax.Array]:
    """One leaf: error-feedback int8 quantize -> exact int32 psum -> deq."""
    gf = g.astype(jnp.float32) + e
    local_max = jnp.max(jnp.abs(gf))
    scale = jax.lax.pmax(local_max, axis) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_e = gf - q.astype(jnp.float32) * scale
    # int16 wire format: exact for <= 256 summands (127*256 < 2^15) — the
    # per-pod DP degree; hierarchical sync would chunk beyond that
    total = jax.lax.psum(q.astype(jnp.int16), axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    mean = total.astype(jnp.float32) * scale / n
    return mean, new_e


def compressed_grad_sync(grads: Pytree, error: Pytree, axis: str
                         ) -> Tuple[Pytree, Pytree]:
    """Mean-reduce ``grads`` over mesh axis ``axis`` in int8 wire format.

    Must be called inside shard_map/pmap with ``axis`` bound.  Returns
    (synced grads, new error-feedback state).
    """
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    outs = [_sync_leaf(g, e, axis) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]))


def make_compressed_dp_step(loss_fn, opt_update, mesh, axis: str = "data"):
    """Build a shard_map DP train step with compressed gradient sync.

    ``loss_fn(params, batch) -> scalar``; ``opt_update(params, grads, opt)
    -> (params, opt, metrics)``.  Params/opt replicated over ``axis``;
    batch sharded on its leading dim.  Returns a jitted step:
    ``step(params, opt, err, batch) -> (params, opt, err, metrics)``.
    """
    from jax.sharding import PartitionSpec as P

    from ..compat import shard_map

    def local_step(params, opt, err, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, err = compressed_grad_sync(grads, err, axis)
        loss = jax.lax.pmean(loss, axis)
        params, opt, metrics = opt_update(params, grads, opt)
        return params, opt, err, {"loss": loss, **metrics}

    rep = P()
    return jax.jit(shard_map(
        local_step, mesh=mesh,
        in_specs=(rep, rep, rep, P(axis)),
        out_specs=(rep, rep, rep, rep),
        check_vma=False))
