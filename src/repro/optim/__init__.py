from .adamw import (AdamWConfig, adamw_update, clip_by_global_norm,
                    compress_int8, decompress_int8, ef_compress_tree,
                    global_norm, init_error_state, init_opt_state,
                    lr_schedule, zero1_axes)
