"""Low-overhead span tracing + structured logging (the ``REPRO_OBS`` switch).

One process == one append-only JSONL event stream (``trace-<pid>.jsonl``
under the run directory); pool workers each write their own stream and the
report merges them, so no cross-process locking ever happens on the hot
path.  Three event kinds:

* ``{"ev": "proc", ...}`` — stream header: pid, role, wall-clock and
  ``perf_counter`` anchors (pairs of anchors let a reader align the
  monotonic span timestamps of different processes onto one wall axis);
* ``{"ev": "span", "name": ..., "t0": ..., "dur": ..., "attrs": {...}}`` —
  one timed region, emitted on exit of ``with span("phase", k=v):``;
* ``{"ev": "log", "tag": ..., "msg": ...}`` — a structured copy of a
  ``vlog()`` diagnostic line.

**Hard contract** (property-tested in ``tests/test_obs.py``): nothing in
this module draws randomness or performs float arithmetic that feeds back
into engine results — spans only *read* ``perf_counter`` — so a sweep with
tracing on is bit-identical to tracing off.  The disabled path is a single
module-global bool check returning a shared no-op context manager (no
allocation, no clock read), so ``REPRO_OBS`` unset cannot move the
``--check-floor`` benchmark.

Besides the event stream, every span feeds a ``phase.<name>`` histogram in
:mod:`repro.obs.metrics` — the report's time-in-phase table reads those, so
per-iteration hot paths can use :func:`timed` (histogram only, no event
line) without flooding the trace file.
"""

from __future__ import annotations

import atexit
import json
import os
import time
from pathlib import Path
from time import perf_counter
from typing import Any, Dict, Optional

_TRUTHY = ("1", "true", "on", "yes")

_ENABLED: bool = os.environ.get("REPRO_OBS", "").lower() in _TRUTHY
_RUN_DIR: Optional[Path] = None
_FILE = None                      # this process's open trace stream
_VERBOSITY: int = int(os.environ.get("REPRO_VERBOSITY", "1") or "1")


def enabled() -> bool:
    return _ENABLED


def verbosity() -> int:
    return _VERBOSITY


def set_verbosity(level: int) -> None:
    global _VERBOSITY
    _VERBOSITY = int(level)


def _default_run_dir() -> Path:
    env = os.environ.get("REPRO_OBS_DIR")
    if env:
        return Path(env)
    stamp = time.strftime("%Y%m%d-%H%M%S")
    return Path("results") / "obs" / f"run-{stamp}-{os.getpid()}"


def run_dir() -> Optional[Path]:
    """The active run directory (created on first use; None if disabled)."""
    global _RUN_DIR
    if not _ENABLED:
        return None
    if _RUN_DIR is None:
        _RUN_DIR = _default_run_dir()
    _RUN_DIR.mkdir(parents=True, exist_ok=True)
    return _RUN_DIR


def enable(directory: Optional[os.PathLike] = None) -> Path:
    """Programmatically turn tracing on (tests / CLIs; the env switch
    ``REPRO_OBS=1`` is read once at import).  Idempotent; returns the run
    directory."""
    global _ENABLED, _RUN_DIR
    _close_stream()
    _ENABLED = True
    _RUN_DIR = Path(directory) if directory is not None else None
    from . import metrics as _metrics
    _metrics.rebase_collectors()
    return run_dir()


def disable() -> None:
    """Flush + close this process's stream and turn tracing off."""
    global _ENABLED, _RUN_DIR
    _close_stream()
    _ENABLED = False
    _RUN_DIR = None


def _close_stream() -> None:
    global _FILE
    if _FILE is not None:
        try:
            _FILE.flush()
            _FILE.close()
        except (OSError, ValueError):
            pass
        _FILE = None


def _stream():
    global _FILE
    if _FILE is None:
        d = run_dir()
        assert d is not None
        _FILE = (d / f"trace-{os.getpid()}.jsonl").open("a")
        _FILE.write(json.dumps({
            "ev": "proc", "pid": os.getpid(),
            "t_wall": time.time(), "t_perf": perf_counter(),
        }) + "\n")
    return _FILE


def emit(event: Dict[str, Any]) -> None:
    """Append one event to this process's stream (no-op when disabled)."""
    if not _ENABLED:
        return
    _stream().write(json.dumps(event, default=str) + "\n")


def flush() -> None:
    if _FILE is not None:
        _FILE.flush()


atexit.register(_close_stream)


# ---------------------------------------------------------------------------
# Spans
# ---------------------------------------------------------------------------

class _NullSpan:
    """Shared do-nothing context manager returned while disabled."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("name", "attrs", "t0")

    def __init__(self, name: str, attrs: Dict[str, Any]):
        self.name = name
        self.attrs = attrs

    def __enter__(self) -> "_Span":
        self.t0 = perf_counter()
        return self

    def __exit__(self, et, ev, tb) -> bool:
        dur = perf_counter() - self.t0
        from . import metrics as _metrics
        _metrics.histogram("phase." + self.name).observe(dur)
        e: Dict[str, Any] = {"ev": "span", "name": self.name,
                             "pid": os.getpid(), "t0": self.t0, "dur": dur}
        if self.attrs:
            e["attrs"] = self.attrs
        if et is not None:
            e["err"] = getattr(et, "__name__", str(et))
        emit(e)
        return False


class _Timed:
    """Histogram-only timer — for regions executed thousands of times per
    task (e.g. one lockstep SA iteration), where a span event per call
    would flood the trace stream."""
    __slots__ = ("name", "t0")

    def __init__(self, name: str):
        self.name = name

    def __enter__(self) -> "_Timed":
        self.t0 = perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        from . import metrics as _metrics
        _metrics.histogram("phase." + self.name).observe(
            perf_counter() - self.t0)
        return False


def span(name: str, **attrs: Any):
    """``with span("dse", shard="0/3"):`` — timed region + trace event."""
    if not _ENABLED:
        return _NULL_SPAN
    return _Span(name, attrs)


def timed(name: str):
    """Like :func:`span` but feeds only the ``phase.<name>`` histogram."""
    if not _ENABLED:
        return _NULL_SPAN
    return _Timed(name)


# ---------------------------------------------------------------------------
# Structured logging (the [tag] diagnostics)
# ---------------------------------------------------------------------------

def vlog(tag: str, msg: str, *, level: int = 1,
         verbosity: Optional[int] = None, **fields: Any) -> None:
    """Structured replacement for the ad-hoc ``print(f"[sweep] ...")``
    diagnostics.

    Prints ``[tag] msg`` — byte-identical to the historical output — when
    the effective verbosity (the ``verbosity`` argument if given, else the
    ``REPRO_VERBOSITY`` env, default 1) is >= ``level``; additionally
    emits a structured ``log`` event when tracing is on, regardless of
    verbosity (a silenced console does not blind the trace).
    """
    if _ENABLED:
        e: Dict[str, Any] = {"ev": "log", "tag": tag, "msg": str(msg),
                             "t": time.time()}
        if fields:
            e["fields"] = fields
        emit(e)
    v = _VERBOSITY if verbosity is None else verbosity
    if v >= level:
        print(f"[{tag}] {msg}", flush=True)


# ---------------------------------------------------------------------------
# Worker propagation (spawned pool workers don't inherit programmatic
# enable(); the pool initializer ships this state across)
# ---------------------------------------------------------------------------

def export_state() -> Optional[Dict[str, Any]]:
    """Picklable snapshot of the obs switch for a spawned worker."""
    if not _ENABLED:
        return None
    return {"run_dir": str(run_dir()), "verbosity": _VERBOSITY}


def import_state(state: Optional[Dict[str, Any]]) -> None:
    """Adopt a parent's :func:`export_state` inside a pool worker."""
    if not state:
        return
    set_verbosity(state.get("verbosity", _VERBOSITY))
    enable(state["run_dir"])
