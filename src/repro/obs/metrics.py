"""Process-local metrics registry: counters, gauges, histograms, collectors.

Two ways numbers get here:

* **Explicit instruments** — ``counter("engine.tasks").inc()`` at sites
  executed at most once per task / wave / sweep stage.  Every mutator
  checks the module-global obs switch first, so with ``REPRO_OBS`` unset
  each call is one attribute load and a falsy branch (a true no-op as far
  as the ``--check-floor`` benchmark can measure).
* **Collectors** — hot structures (the GroupEval caches, ``_GEO_CACHE``,
  the analyzer's batched/scalar build counters) keep their own cheap
  native counters *unconditionally* (the pre-existing
  ``CachedEvaluator.hits/misses`` pattern) and register a harvest callback
  here; values are read only at snapshot/drain time, so the hot path is
  never touched by the obs layer at all.  Counter-kind collectors report
  cumulative values and are baselined at :func:`repro.obs.enable` time
  (``rebase_collectors``), so a snapshot reflects activity *since enable*;
  gauge-kind collectors (cache size / capacity) report current values.

**Worker aggregation**: a pool worker calls :func:`drain` once per task —
returning its counters + histograms + collector *deltas* and resetting
them — and the payload rides back piggybacked on the task result tuple;
the parent :func:`absorb`\\ s it into its own registry (counters add,
histograms merge, gauges keep the max across processes).  ``snapshot()``
in the parent therefore covers the whole sweep, and
:func:`write_snapshot` lands it as ``metrics.json`` in the run dir.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

from . import trace as _trace


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if _trace._ENABLED:
            self.value += n


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None

    def set(self, v: float) -> None:
        if _trace._ENABLED:
            self.value = v


class Histogram:
    """Streaming summary (n, total, min, max) — enough for mean/extremes;
    per-event detail lives in the trace stream, not here."""
    __slots__ = ("name", "n", "total", "min", "max")

    def __init__(self, name: str):
        self.name = name
        self.reset()

    def reset(self) -> None:
        self.n = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, x: float) -> None:
        if not _trace._ENABLED:
            return
        self.n += 1
        self.total += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x

    def merge_raw(self, raw: Dict[str, float]) -> None:
        self.n += int(raw.get("n", 0))
        self.total += float(raw.get("total", 0.0))
        self.min = min(self.min, float(raw.get("min", float("inf"))))
        self.max = max(self.max, float(raw.get("max", float("-inf"))))

    def as_dict(self) -> Dict[str, float]:
        d: Dict[str, float] = {"n": self.n, "total": self.total}
        if self.n:
            d["min"] = self.min
            d["max"] = self.max
            d["mean"] = self.total / self.n
        return d


_COUNTERS: Dict[str, Counter] = {}
_GAUGES: Dict[str, Gauge] = {}
_HISTOGRAMS: Dict[str, Histogram] = {}
# (fn, kind); fn() -> {metric name: value}.  kind "counter" values are
# cumulative-since-process-start; "gauge" values are instantaneous.
_COLLECTORS: List[Tuple[Callable[[], Dict[str, float]], str]] = []
# per-metric baseline for counter-kind collectors: snapshot() reports
# cur - base ("since enable"); drain() additionally advances it so worker
# payloads are deltas-since-last-drain
_COLLECT_BASE: Dict[str, float] = {}


def counter(name: str) -> Counter:
    c = _COUNTERS.get(name)
    if c is None:
        c = _COUNTERS[name] = Counter(name)
    return c


def gauge(name: str) -> Gauge:
    g = _GAUGES.get(name)
    if g is None:
        g = _GAUGES[name] = Gauge(name)
    return g


def histogram(name: str) -> Histogram:
    h = _HISTOGRAMS.get(name)
    if h is None:
        h = _HISTOGRAMS[name] = Histogram(name)
    return h


def register_collector(fn: Callable[[], Dict[str, float]],
                       kind: str = "counter") -> None:
    """Register a harvest callback (module import time; idempotent per
    callable)."""
    if kind not in ("counter", "gauge"):
        raise ValueError(f"collector kind {kind!r}: 'counter' or 'gauge'")
    if any(f is fn for f, _ in _COLLECTORS):
        return
    _COLLECTORS.append((fn, kind))


def rebase_collectors() -> None:
    """Snapshot current collector values as the zero point (called by
    ``obs.enable``), so process-lifetime caches warmed before enable don't
    pollute the run's numbers."""
    _COLLECT_BASE.clear()
    for fn, kind in _COLLECTORS:
        if kind != "counter":
            continue
        for k, v in fn().items():
            _COLLECT_BASE[k] = float(v)


def _collect(advance_base: bool) -> Tuple[Dict[str, float], Dict[str, float]]:
    """(counter deltas vs base, current gauges) over all collectors."""
    counters: Dict[str, float] = {}
    gauges: Dict[str, float] = {}
    for fn, kind in _COLLECTORS:
        cur = fn()
        if kind == "gauge":
            gauges.update(cur)
            continue
        for k, v in cur.items():
            v = float(v)
            counters[k] = counters.get(k, 0.0) + v - _COLLECT_BASE.get(k, 0.0)
            if advance_base:
                _COLLECT_BASE[k] = v
    return counters, gauges


def snapshot() -> Dict[str, Any]:
    """Merged view: explicit instruments + collector harvest (cumulative
    since enable / last drain; does not reset anything)."""
    ccol, gcol = _collect(advance_base=False)
    counters: Dict[str, float] = {
        n: c.value for n, c in _COUNTERS.items() if c.value}
    for k, v in ccol.items():
        if v:
            counters[k] = counters.get(k, 0) + v
    gauges: Dict[str, float] = {
        n: g.value for n, g in _GAUGES.items() if g.value is not None}
    gauges.update(gcol)
    hists = {n: h.as_dict() for n, h in _HISTOGRAMS.items() if h.n}
    return {"counters": counters, "gauges": gauges, "histograms": hists}


def drain() -> Dict[str, Any]:
    """Worker-side: return everything accumulated since the last drain and
    reset (the per-task piggyback payload)."""
    ccol, gcol = _collect(advance_base=True)
    counters: Dict[str, float] = {
        n: c.value for n, c in _COUNTERS.items() if c.value}
    for k, v in ccol.items():
        if v:
            counters[k] = counters.get(k, 0) + v
    gauges: Dict[str, float] = {
        n: g.value for n, g in _GAUGES.items() if g.value is not None}
    gauges.update(gcol)
    hists = {n: {"n": h.n, "total": h.total, "min": h.min, "max": h.max}
             for n, h in _HISTOGRAMS.items() if h.n}
    for c in _COUNTERS.values():
        c.value = 0
    for h in _HISTOGRAMS.values():
        h.reset()
    return {"counters": counters, "gauges": gauges, "histograms": hists}


def absorb(payload: Optional[Dict[str, Any]]) -> None:
    """Parent-side: fold one worker's :func:`drain` payload in."""
    if not payload:
        return
    for k, v in payload.get("counters", {}).items():
        c = counter(k)
        c.value += v
    for k, v in payload.get("gauges", {}).items():
        g = gauge(k)
        g.value = v if g.value is None else max(g.value, v)
    for k, raw in payload.get("histograms", {}).items():
        histogram(k).merge_raw(raw)


def write_snapshot(directory: Optional[Path] = None) -> Optional[Path]:
    """Land ``metrics.json`` in the run dir (no-op while disabled)."""
    if not _trace._ENABLED:
        return None
    d = Path(directory) if directory is not None else _trace.run_dir()
    if d is None:
        return None
    d.mkdir(parents=True, exist_ok=True)
    path = d / "metrics.json"
    path.write_text(json.dumps(snapshot(), indent=1, sort_keys=True,
                               default=float) + "\n")
    return path


def reset() -> None:
    """Zero every instrument in place and re-baseline collectors (tests)."""
    for c in _COUNTERS.values():
        c.value = 0
    for g in _GAUGES.values():
        g.value = None
    for h in _HISTOGRAMS.values():
        h.reset()
    rebase_collectors()
