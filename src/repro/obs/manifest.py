"""Per-run manifests: config fingerprint + seed + grid + provenance.

The provenance block mirrors ``bench_dse/v2`` (``benchmarks/run.py``) —
cpu count, platform, python, jax, short git commit, UTC date — so a sweep
trace and a bench trajectory row measured in the same container are
directly comparable.  :func:`git_head` is the shared commit-stamp helper:
``git rev-parse --short HEAD`` with a ``REPRO_GIT_COMMIT`` env override
for containers that ship the tree without ``.git`` (the bench
trajectory's ``"commit": "unknown"`` failure mode).
"""

from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Dict, Optional, Union

from . import trace as _trace

GIT_COMMIT_ENV = "REPRO_GIT_COMMIT"


def git_head(repo: Union[str, Path, None] = None) -> str:
    """Short HEAD commit of ``repo`` (default: this package's tree).

    Resolution order: the ``REPRO_GIT_COMMIT`` env override (gitless
    containers stamp their build commit through it), then ``git
    rev-parse --short HEAD``, then ``"unknown"``.
    """
    override = os.environ.get(GIT_COMMIT_ENV)
    if override:
        return override
    import subprocess
    if repo is None:
        repo = Path(__file__).resolve().parents[3]
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=repo,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def provenance(repo: Union[str, Path, None] = None) -> Dict[str, Any]:
    """The bench_dse/v2-shaped provenance block + commit/date stamps."""
    import os as _os
    import platform as _platform
    import sys as _sys
    from datetime import datetime, timezone
    try:
        import jax
        jax_ver = getattr(jax, "__version__", None)
    except Exception:
        jax_ver = None
    return {
        "cpu_count": _os.cpu_count(),
        "platform": _platform.platform(),
        "python": _sys.version.split()[0],
        "jax": jax_ver,
        "commit": git_head(repo),
        "date": datetime.now(timezone.utc).strftime("%Y-%m-%dT%H:%M:%SZ"),
    }


def write_manifest(fields: Dict[str, Any],
                   directory: Union[str, Path, None] = None,
                   ) -> Optional[Path]:
    """Write ``manifest.json`` into the run dir (no-op while disabled
    unless an explicit ``directory`` is given).  ``fields`` comes from the
    caller (fingerprint, seed, grid size, shard, worker count, ...);
    provenance is stamped here.  Last write wins — a process running
    several sweeps into one run dir keeps the most recent manifest, and
    each sweep's start is also visible as a ``log`` event in the trace.
    """
    if directory is None:
        d = _trace.run_dir()
        if d is None:
            return None
    else:
        d = Path(directory)
        d.mkdir(parents=True, exist_ok=True)
    doc = {"schema": "obs_manifest/v1", "provenance": provenance(), **fields}
    path = d / "manifest.json"
    path.write_text(json.dumps(doc, indent=1, sort_keys=True,
                               default=str) + "\n")
    return path
