"""Structured telemetry for the DSE/serve stack (``REPRO_OBS=1``).

Three pillars (see DESIGN.md "Observability"):

* :mod:`repro.obs.trace` — span tracer + structured ``vlog`` logging,
  append-only JSONL event stream per process;
* :mod:`repro.obs.metrics` — counters/gauges/histograms + collector
  harvest of the engine's native cache counters, worker payloads
  piggybacked on task results;
* :mod:`repro.obs.manifest` / :mod:`repro.obs.report` — per-run manifest
  and the ``launch/obs_report.py`` sweep post-mortem.

Telemetry never draws randomness and never reorders float math: sweeps
are bit-identical with tracing on or off, and the disabled path is a
bool check.
"""

from . import manifest, metrics  # noqa: F401
from .trace import (disable, emit, enable, enabled, export_state, flush,
                    import_state, run_dir, set_verbosity, span, timed,
                    verbosity, vlog)

__all__ = [
    "disable", "emit", "enable", "enabled", "export_state", "flush",
    "import_state", "manifest", "metrics", "run_dir", "set_verbosity",
    "span", "timed", "verbosity", "vlog",
]
