"""Sweep post-mortem: render a run dir + shard checkpoints as text tables.

Input artifacts (all optional — sections render from whatever exists):

* a **run dir** written under ``REPRO_OBS=1`` — ``manifest.json``,
  ``metrics.json``, and the per-process ``trace-*.jsonl`` event streams;
* **shard checkpoint** files (``ResumableSweep`` JSONL) — record counts +
  the ``{"_hb": ...}`` heartbeat lines give per-shard liveness/progress,
  and the task records themselves give a Pareto-frontier snapshot of the
  running (or finished) sweep.

Everything here is a pure function of its inputs (the only clock read is
the ``now`` parameter of :func:`shard_progress`), so the report output is
byte-stable — ``tests/test_obs.py`` keeps a golden rendering of a
checked-in mini run.  CLI wrapper: ``python -m repro.launch.obs_report``.
"""

from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

# ---------------------------------------------------------------------------
# Loading
# ---------------------------------------------------------------------------


def load_run(run_dir: Union[str, Path]) -> Dict[str, Any]:
    """Parse one obs run dir: manifest, metrics, merged event list.

    Events from all ``trace-*.jsonl`` streams are concatenated in sorted
    stream-name order (per-stream line order preserved); unparseable lines
    are skipped — a stream truncated by a dying worker must not take the
    post-mortem down with it.
    """
    d = Path(run_dir)
    out: Dict[str, Any] = {"manifest": None, "metrics": None, "events": []}
    man = d / "manifest.json"
    if man.exists():
        try:
            out["manifest"] = json.loads(man.read_text())
        except ValueError:
            pass
    met = d / "metrics.json"
    if met.exists():
        try:
            out["metrics"] = json.loads(met.read_text())
        except ValueError:
            pass
    for p in sorted(d.glob("trace-*.jsonl")):
        for line in p.read_text().splitlines():
            if not line.strip():
                continue
            try:
                out["events"].append(json.loads(line))
            except ValueError:
                continue
    return out


# ---------------------------------------------------------------------------
# Sections (pure projections)
# ---------------------------------------------------------------------------

def phase_rows(metrics: Optional[Dict[str, Any]]
               ) -> List[Tuple[str, int, float, float, float]]:
    """Time-in-phase from the ``phase.*`` histograms: (name, n calls,
    total s, mean ms, max ms), largest total first."""
    if not metrics:
        return []
    rows = []
    for name, h in (metrics.get("histograms") or {}).items():
        if not name.startswith("phase.") or not h.get("n"):
            continue
        total = float(h["total"])
        rows.append((name[len("phase."):], int(h["n"]), total,
                     1e3 * total / h["n"], 1e3 * float(h["max"])))
    rows.sort(key=lambda r: (-r[2], r[0]))
    return rows


def top_tasks(events: Sequence[Dict[str, Any]], k: int = 10
              ) -> List[Dict[str, Any]]:
    """The k slowest ``task`` spans (one per (candidate, workload) SA run),
    with their queue-wait where the parent recorded one."""
    tasks = [e for e in events
             if e.get("ev") == "span" and e.get("name") == "task"]
    tasks.sort(key=lambda e: (-float(e.get("dur", 0.0)),
                              str(e.get("attrs", {}))))
    return tasks[:k]


_CACHE_GROUPS = (
    ("group_eval", "GroupEval exact"),
    ("group_eval_fused", "GroupEval fused"),
    ("geo_cache", "_GEO_CACHE"),
)


def cache_rows(metrics: Optional[Dict[str, Any]]
               ) -> List[Tuple[str, int, int, float, int]]:
    """Cache economics: (cache, hits, misses, hit rate, evictions)."""
    if not metrics:
        return []
    c = metrics.get("counters") or {}
    rows = []
    for prefix, label in _CACHE_GROUPS:
        hits = int(c.get(f"{prefix}.hits", 0))
        misses = int(c.get(f"{prefix}.misses", 0))
        ev = int(c.get(f"{prefix}.evictions", 0))
        if hits or misses or ev:
            rate = hits / (hits + misses) if hits + misses else 0.0
            rows.append((label, hits, misses, rate, ev))
    return rows


def parse_heartbeats(path: Union[str, Path]
                     ) -> Tuple[int, Optional[Dict[str, Any]]]:
    """(task-record count, last heartbeat) of one checkpoint shard.

    Tolerant by design: corrupt lines are skipped — this is the liveness
    probe a multi-host driver polls against files being appended to
    *right now*.
    """
    n_records = 0
    last_hb: Optional[Dict[str, Any]] = None
    p = Path(path)
    if not p.exists():
        return 0, None
    for line in p.read_text().splitlines():
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except ValueError:
            continue
        if "_key" in rec:
            n_records += 1
        elif "_hb" in rec:
            last_hb = rec["_hb"]
    return n_records, last_hb


def shard_progress(paths: Sequence[Union[str, Path]],
                   now: Optional[float] = None
                   ) -> List[Dict[str, Any]]:
    """Per-shard liveness rows from heartbeat records.

    ``now`` (wall clock) turns the last heartbeat's timestamp into an age;
    pass a fixed value for reproducible output (the golden test does),
    None to read the real clock.
    """
    if now is None:
        import time
        now = time.time()
    rows = []
    for p in paths:
        n_rec, hb = parse_heartbeats(p)
        row: Dict[str, Any] = {"shard": Path(p).name, "records": n_rec,
                               "done": None, "total": None,
                               "wall_s": None, "hb_age_s": None}
        if hb:
            row["shard"] = str(hb.get("shard", row["shard"]))
            row["done"] = hb.get("done")
            row["total"] = hb.get("total")
            row["wall_s"] = hb.get("wall_s")
            if hb.get("t") is not None:
                row["hb_age_s"] = max(0.0, now - float(hb["t"]))
        rows.append(row)
    return rows


_FP_OBJ_RE = re.compile(r"^dse:v\d+:a([0-9.eE+-]+):b([0-9.eE+-]+)"
                        r":g([0-9.eE+-]+):")


def pareto_snapshot(paths: Sequence[Union[str, Path]], top: int = 10
                    ) -> List[Dict[str, Any]]:
    """Pareto frontier of the (possibly still-running) sweep recorded in
    ``paths``: merge task records last-wins, geomean (E, D) per candidate
    over its recorded workloads, re-derive MC from the arch dict, mask by
    (MC, E, D) dominance.

    Candidates whose task set is still incomplete contribute whatever
    workloads they have — this is a *snapshot*, not the final reduction
    (the objective column uses the fingerprint's alpha/beta/gamma and the
    plain geomean, i.e. the default-objective view).
    """
    import math

    from ..core.explore import _pareto_mask_sweep, arch_from_dict
    from ..core.mc import evaluate_mc

    fingerprint: Optional[str] = None
    records: Dict[str, Dict[str, Any]] = {}
    for p in (Path(s) for s in paths):
        if not p.exists():
            continue
        for line in p.read_text().splitlines():
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                continue
            if "_config" in rec:
                fingerprint = rec["_config"]
                continue
            key = rec.pop("_key", None)
            if key is not None and "energy_j" in rec:
                records[key] = rec
    alpha = beta = gamma = 1.0
    if fingerprint:
        m = _FP_OBJ_RE.match(fingerprint)
        if m:
            alpha, beta, gamma = (float(m.group(i)) for i in (1, 2, 3))
    by_cand: Dict[str, Dict[str, Dict[str, Any]]] = {}
    for key, rec in records.items():
        cand, _, wl = key.rpartition("|wl=")
        if not cand:
            continue
        by_cand.setdefault(cand, {})[wl] = rec
    pts = []
    for cand in sorted(by_cand):
        per = by_cand[cand]
        try:
            arch = arch_from_dict(per[sorted(per)[0]]["arch"])
            mc = evaluate_mc(arch).total
        except (KeyError, TypeError, ValueError):
            continue
        logE = logD = 0.0
        for wl in sorted(per):
            logE += math.log(float(per[wl]["energy_j"]))
            logD += math.log(float(per[wl]["delay_s"]))
        n = max(1, len(per))
        E, D = math.exp(logE / n), math.exp(logD / n)
        pts.append({"arch": arch.label(), "mc": mc, "energy_j": E,
                    "delay_s": D, "n_workloads": len(per),
                    "objective": (mc ** alpha) * (E ** beta) * (D ** gamma)})
    mask = _pareto_mask_sweep(
        [(p["mc"], p["energy_j"], p["delay_s"]) for p in pts])
    front = [p for p, m in zip(pts, mask) if m]
    front.sort(key=lambda p: p["objective"])
    return front[:top]


# ---------------------------------------------------------------------------
# Rendering
# ---------------------------------------------------------------------------

def _table(headers: Sequence[str], rows: Sequence[Sequence[str]]) -> str:
    cols = [list(col) for col in zip(headers, *rows)] if rows else \
        [[h] for h in headers]
    widths = [max(len(c) for c in col) for col in cols]
    def fmt(cells):
        return "  ".join(str(c).ljust(w) for c, w in zip(cells, widths)) \
            .rstrip()
    lines = [fmt(headers), fmt(["-" * w for w in widths])]
    lines += [fmt(r) for r in rows]
    return "\n".join(lines)


def render_report(run: Union[str, Path, None] = None,
                  ckpts: Sequence[Union[str, Path]] = (),
                  top: int = 10, now: Optional[float] = None) -> str:
    """The full post-mortem as one text blob (CLI prints it verbatim)."""
    out: List[str] = []
    data = load_run(run) if run is not None else \
        {"manifest": None, "metrics": None, "events": []}
    man = data["manifest"]
    if man:
        out.append("== run manifest ==")
        prov = man.get("provenance") or {}
        for k in ("fingerprint", "seed", "grid", "shard", "n_workers",
                  "stage"):
            if man.get(k) is not None:
                out.append(f"  {k:<12} {man[k]}")
        out.append(f"  {'commit':<12} {prov.get('commit', '?')} "
                   f"@ {prov.get('date', '?')} "
                   f"(cpus={prov.get('cpu_count', '?')})")
        out.append("")
    ph = phase_rows(data["metrics"])
    if ph:
        out.append("== time in phase ==")
        out.append(_table(
            ("phase", "calls", "total_s", "mean_ms", "max_ms"),
            [(n, str(c), f"{t:.3f}", f"{mean:.2f}", f"{mx:.2f}")
             for n, c, t, mean, mx in ph]))
        out.append("")
    tt = top_tasks(data["events"], k=top)
    if tt:
        out.append(f"== top {len(tt)} slowest tasks ==")
        rows = []
        for e in tt:
            a = e.get("attrs", {})
            rows.append((str(a.get("arch", "?")), str(a.get("wl", "?")),
                         f"{float(e.get('dur', 0.0)):.3f}",
                         f"{float(a.get('queue_s', 0.0)):.3f}",
                         str(e.get("pid", "?"))))
        out.append(_table(("arch", "workload", "wall_s", "queue_s", "pid"),
                          rows))
        out.append("")
    cr = cache_rows(data["metrics"])
    if cr:
        out.append("== cache economics ==")
        out.append(_table(
            ("cache", "hits", "misses", "hit_rate", "evictions"),
            [(n, str(h), str(m), f"{r:.1%}", str(ev))
             for n, h, m, r, ev in cr]))
        out.append("")
    if data["metrics"]:
        c = data["metrics"].get("counters") or {}
        extras = []
        for key, label in (
                ("screen.kept", "screening kept"),
                ("screen.pruned", "screening pruned"),
                ("prefetch.batched_builds", "prefetch batched builds"),
                ("prefetch.scalar_builds", "prefetch scalar builds"),
                ("sa.proposed", "SA proposals"),
                ("sa.accepted", "SA accepts"),
                ("sa.swap_attempts", "RE swap attempts"),
                ("sa.swap_accepts", "RE swap accepts"),
                ("engine.tasks", "tasks evaluated"),
                ("engine.tasks_resumed", "tasks resumed"),
                ("serve.requests", "serve requests replayed"),
                ("supervisor.launches", "supervisor launches"),
                ("supervisor.retries", "supervisor retries"),
                ("supervisor.deaths", "hosts declared dead"),
                ("supervisor.reshards", "re-shard events"),
                ("retry.attempts", "retried transient failures"),
                ("merge.conflicts", "merge conflicts")):
            if c.get(key):
                extras.append((label, f"{int(c[key])}"))
        if c.get("sa.proposed"):
            extras.append(("SA acceptance rate",
                           f"{c.get('sa.accepted', 0) / c['sa.proposed']:.1%}"))
        if c.get("sa.swap_attempts"):
            extras.append((
                "RE swap rate",
                f"{c.get('sa.swap_accepts', 0) / c['sa.swap_attempts']:.1%}"))
        if extras:
            out.append("== engine counters ==")
            out.append(_table(("counter", "value"), extras))
            out.append("")
    if ckpts:
        rows = shard_progress(ckpts, now=now)
        out.append("== shard progress ==")
        def cell(v, fmt="{}"):
            return "?" if v is None else fmt.format(v)
        out.append(_table(
            ("shard", "records", "done/total", "wall_s", "hb_age_s"),
            [(r["shard"], str(r["records"]),
              f"{cell(r['done'])}/{cell(r['total'])}",
              cell(r["wall_s"], "{:.1f}"), cell(r["hb_age_s"], "{:.1f}"))
             for r in rows]))
        out.append("")
        front = pareto_snapshot(ckpts, top=top)
        if front:
            out.append(f"== Pareto snapshot (top {len(front)}) ==")
            out.append(_table(
                ("arch", "MC", "E_J", "D_s", "objective", "wls"),
                [(p["arch"], f"{p['mc']:.4g}", f"{p['energy_j']:.4g}",
                  f"{p['delay_s']:.4g}", f"{p['objective']:.6g}",
                  str(p["n_workloads"])) for p in front]))
            out.append("")
    if not out:
        out.append("(no obs artifacts found — run with REPRO_OBS=1 and/or "
                   "pass --ckpt shard files)")
    return "\n".join(out).rstrip() + "\n"
