"""Traffic-replay harness: discrete-event serving simulation + SLO report.

The harness replays a :class:`repro.serve.trace.Trace` against a served
program and reports per-request SLO metrics — p50/p95/p99 time-to-first-
token and end-to-end latency, throughput, per-wave occupancy — plus a
saturation-throughput estimate from an arrival-rate sweep.

Two scheduling modes share one timeline/report format:

* ``mode="wave"`` — the policy ``runtime/serve_loop.py`` actually
  executes: up to ``max_batch`` *ready* requests are packed into a wave,
  the wave runs to completion (prefill once, decode until every slot is
  done), then the next wave forms.  Works with ANY
  :class:`WaveExecutor` — the real-model executor, a realized-program
  executor, or the analytical one.
* ``mode="continuous"`` — continuous batch slotting in the
  MaxText-offline-inference style: the machine serializes prefill and
  decode-step operations; whenever a slot frees and a request is ready,
  a prefill op admits it (prefill-prioritized), otherwise a decode-step
  op advances every active slot by one token.  Requires a
  :class:`ServiceModel` (analytical executors), because a mid-wave
  admission cannot be replayed against the real wave-batched model path.

All time is **virtual**: arrival times come from the trace and service
times from the executor's :class:`WaveCost` (measured wall seconds for
real executors, model-predicted seconds for analytical ones).  With an
analytical executor the whole replay — and therefore the report — is
deterministic for a fixed trace seed.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import (Callable, Dict, List, Optional, Protocol, Sequence,
                    Tuple, Union, runtime_checkable)

import numpy as np

from .. import obs as _obs
from .trace import Trace, TraceRequest

PCTS = (50.0, 95.0, 99.0)


# ---------------------------------------------------------------------------
# Executor protocol
# ---------------------------------------------------------------------------

@dataclass
class WaveCost:
    """What one wave execution cost, in the executor's time base.

    ``prefill_s`` covers prompt ingestion for every slot; each slot's
    first token is available at ``start + prefill_s`` (greedy decode
    emits it from the prefill logits).  ``step_s[t]`` is the duration of
    the wave's ``t``-th decode step; ``slot_tokens[i]`` is how many
    tokens slot ``i`` actually produced (1 from prefill + one per decode
    step it was active in), so slot ``i`` finishes at
    ``start + prefill_s + sum(step_s[:slot_tokens[i] - 1])``.
    """
    prefill_s: float
    step_s: List[float]
    slot_tokens: List[int]
    tokens: Optional[List[np.ndarray]] = None     # real ids, if executed

    @property
    def total_s(self) -> float:
        return self.prefill_s + float(sum(self.step_s))


@runtime_checkable
class WaveExecutor(Protocol):
    """Transport-agnostic serving backend: execute one wave, report cost.

    Structural protocol — implementors need no import of this module.
    ``runtime.serve_loop.ModelWaveExecutor`` (real JAX model, measured
    wall clock) and :class:`AnalyticalWaveExecutor` (cost model, virtual
    clock) both satisfy it.
    """
    max_batch: int

    def execute(self, wave: Sequence[TraceRequest]) -> WaveCost: ...


# ---------------------------------------------------------------------------
# Analytical service model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ServiceModel:
    """Throughput-normalized token-cost model of a served program.

    Every processed token costs a fixed machine time: prompt tokens
    ``prefill_s_per_token``, generated tokens ``decode_s_per_token`` per
    active slot per step, plus ``overhead_s`` per machine operation
    (prefill or decode step — dispatch, collectives fan-in).  Decode
    steps being latency- rather than throughput-bound is absorbed by
    ``decode_s_per_token``'s calibration factor (DESIGN.md: serving
    harness, queueing-model assumptions).
    """
    prefill_s_per_token: float
    decode_s_per_token: float
    overhead_s: float = 0.0

    def prefill_s(self, prompt_tokens: int) -> float:
        return self.overhead_s + self.prefill_s_per_token * prompt_tokens

    def decode_step_s(self, active_slots: int) -> float:
        return self.overhead_s + self.decode_s_per_token * active_slots

    def request_unloaded_s(self, prompt_len: int, max_new: int) -> float:
        """End-to-end service time of one request on an idle machine."""
        return (self.prefill_s(prompt_len)
                + (max_new - 1) * self.decode_step_s(1))


def service_model_from_delay(delay_s: float, batch: int, seq_ref: int,
                             decode_mult: float = 1.0,
                             overhead_s: float = 0.0) -> ServiceModel:
    """Derive the token-cost model from the evaluator's delay prediction.

    The DSE scores a full forward of ``batch`` sequences x ``seq_ref``
    tokens at ``delay_s`` seconds, so the throughput-normalized per-token
    cost is ``delay_s / (batch * seq_ref)``.  ``decode_mult`` scales the
    decode-token cost relative to prefill (decode steps re-read the KV
    cache and underfill the MACs; calibration fits it from measured
    replays, default 1.0 = pure throughput normalization).
    """
    if delay_s <= 0 or batch < 1 or seq_ref < 1:
        raise ValueError(
            f"service model needs delay_s > 0, batch >= 1, seq_ref >= 1; "
            f"got {delay_s}, {batch}, {seq_ref}")
    c = delay_s / (batch * seq_ref)
    return ServiceModel(prefill_s_per_token=c,
                        decode_s_per_token=c * decode_mult,
                        overhead_s=overhead_s)


class AnalyticalWaveExecutor:
    """Deterministic executor predicting wave costs from a ServiceModel.

    No EOS modeling: every slot runs to its ``max_new`` budget (the trace
    already draws the decode-length distribution, so budgets ARE the
    modeled response lengths).
    """

    def __init__(self, model: ServiceModel, max_batch: int = 8):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.model = model
        self.max_batch = max_batch

    def execute(self, wave: Sequence[TraceRequest]) -> WaveCost:
        budgets = [r.max_new for r in wave]
        n_steps = max(budgets) - 1
        step_s = [self.model.decode_step_s(
                      sum(1 for b in budgets if b - 1 > t))
                  for t in range(n_steps)]
        return WaveCost(
            prefill_s=self.model.prefill_s(sum(r.prompt_len for r in wave)),
            step_s=step_s, slot_tokens=list(budgets))


# ---------------------------------------------------------------------------
# Timelines + report
# ---------------------------------------------------------------------------

@dataclass
class RequestTimeline:
    """Per-request SLO timeline; the invariant ``enqueue <= start <=
    first_token <= finish`` is what the monotonicity test pins."""
    rid: int
    prompt_len: int
    n_tokens: int
    enqueue_t: float
    start_t: float                 # admitted to the machine (wave/prefill)
    first_token_t: float
    finish_t: float

    @property
    def ttft_s(self) -> float:
        return self.first_token_t - self.enqueue_t

    @property
    def latency_s(self) -> float:
        return self.finish_t - self.enqueue_t

    @property
    def queue_s(self) -> float:
        return self.start_t - self.enqueue_t

    def to_json(self) -> Dict[str, float]:
        return {"rid": self.rid, "prompt_len": self.prompt_len,
                "n_tokens": self.n_tokens, "enqueue_t": self.enqueue_t,
                "start_t": self.start_t, "first_token_t": self.first_token_t,
                "finish_t": self.finish_t, "ttft_s": self.ttft_s,
                "latency_s": self.latency_s}


def _pcts(xs: Sequence[float]) -> Dict[str, float]:
    arr = np.asarray(xs, dtype=np.float64)
    return {f"p{p:g}": float(np.percentile(arr, p)) for p in PCTS}


@dataclass
class ServeReport:
    """SLO summary of one replay (+ per-request timelines)."""
    mode: str
    trace_name: str
    trace_spec: str
    trace_seed: int
    max_batch: int
    requests: List[RequestTimeline] = field(default_factory=list)
    n_waves: int = 0
    occupancy: List[float] = field(default_factory=list)   # per wave/step
    timing: str = "virtual"        # "virtual" (model) or "measured" (wall)

    def summary(self) -> Dict[str, object]:
        ttft = [r.ttft_s for r in self.requests]
        e2e = [r.latency_s for r in self.requests]
        makespan = (max(r.finish_t for r in self.requests)
                    - min(r.enqueue_t for r in self.requests)) \
            if self.requests else 0.0
        n_tok = sum(r.n_tokens for r in self.requests)
        return {
            "mode": self.mode,
            "timing": self.timing,
            "trace": {"name": self.trace_name, "spec": self.trace_spec,
                      "seed": self.trace_seed, "n": len(self.requests)},
            "max_batch": self.max_batch,
            "n_waves": self.n_waves,
            "makespan_s": makespan,
            "throughput_rps": len(self.requests) / makespan
                              if makespan > 0 else 0.0,
            "throughput_tok_s": n_tok / makespan if makespan > 0 else 0.0,
            "mean_occupancy": float(np.mean(self.occupancy))
                              if self.occupancy else 0.0,
            "ttft_s": _pcts(ttft) if ttft else {},
            "e2e_s": _pcts(e2e) if e2e else {},
        }

    @property
    def p99_e2e_s(self) -> float:
        return float(np.percentile([r.latency_s for r in self.requests], 99))

    @property
    def p99_ttft_s(self) -> float:
        return float(np.percentile([r.ttft_s for r in self.requests], 99))

    def to_json(self, per_request: bool = True) -> str:
        doc = dict(self.summary())
        if per_request:
            doc["requests"] = [r.to_json() for r in self.requests]
        return json.dumps(doc, sort_keys=True)


# ---------------------------------------------------------------------------
# Replay
# ---------------------------------------------------------------------------

def _wave_timelines(wave: Sequence[TraceRequest], cost: WaveCost,
                    start: float) -> Tuple[List[RequestTimeline], float]:
    """Per-slot timelines of one executed wave; returns (timelines, end)."""
    first = start + cost.prefill_s
    cum = np.concatenate([[0.0], np.cumsum(cost.step_s)])
    out = []
    for i, req in enumerate(wave):
        nt = cost.slot_tokens[i]
        out.append(RequestTimeline(
            rid=req.rid, prompt_len=req.prompt_len, n_tokens=nt,
            enqueue_t=req.arrival_s, start_t=start,
            first_token_t=first,
            finish_t=first + float(cum[min(nt - 1, len(cost.step_s))])))
    return out, first + float(cum[-1])


def _replay_waves(trace: Trace, executor: WaveExecutor) -> ServeReport:
    rep = ServeReport(mode="wave", trace_name=trace.name,
                      trace_spec=trace.spec, trace_seed=trace.seed,
                      max_batch=executor.max_batch)
    pending = sorted(trace.requests, key=lambda r: (r.arrival_s, r.rid))
    now = 0.0
    i = 0
    while i < len(pending):
        if pending[i].arrival_s > now:
            now = pending[i].arrival_s        # idle until the next arrival
        wave = []
        while (i < len(pending) and len(wave) < executor.max_batch
               and pending[i].arrival_s <= now):
            wave.append(pending[i])
            i += 1
        cost = executor.execute(wave)
        tls, end = _wave_timelines(wave, cost, now)
        rep.requests.extend(tls)
        rep.n_waves += 1
        rep.occupancy.append(len(wave) / executor.max_batch)
        if _obs.enabled():
            # queue depth = arrived-but-unadmitted backlog at wave launch;
            # simulated time, so the timeline is deterministic per trace
            depth = 0
            j = i
            while j < len(pending) and pending[j].arrival_s <= now:
                depth += 1
                j += 1
            _obs.metrics.counter("serve.requests").inc(len(wave))
            _obs.metrics.histogram("serve.queue_depth").observe(depth)
            _obs.metrics.histogram("serve.occupancy").observe(
                rep.occupancy[-1])
            _obs.emit({"ev": "serve", "mode": "wave", "t_sim": now,
                       "wave": rep.n_waves, "batch": len(wave),
                       "queue_depth": depth,
                       "occupancy": rep.occupancy[-1]})
        now = end
    rep.requests.sort(key=lambda r: r.rid)
    return rep


def _replay_continuous(trace: Trace, model: ServiceModel,
                       max_batch: int) -> ServeReport:
    """Continuous batch slotting over a serialized prefill/decode machine.

    The machine executes one operation at a time: ``prefill(req)`` when a
    slot is free and a request has arrived (admission emits the first
    token at op completion), else ``decode_step`` advancing every active
    slot by one token.  Occupancy is recorded per decode step.
    """
    rep = ServeReport(mode="continuous", trace_name=trace.name,
                      trace_spec=trace.spec, trace_seed=trace.seed,
                      max_batch=max_batch)
    pending = sorted(trace.requests, key=lambda r: (r.arrival_s, r.rid))
    i = 0
    now = 0.0
    # slot -> [req, remaining_tokens, timeline]
    active: List[List] = []
    while i < len(pending) or active:
        can_admit = (len(active) < max_batch and i < len(pending)
                     and pending[i].arrival_s <= now)
        if can_admit:
            req = pending[i]
            i += 1
            dt = model.prefill_s(req.prompt_len)
            tl = RequestTimeline(
                rid=req.rid, prompt_len=req.prompt_len,
                n_tokens=req.max_new, enqueue_t=req.arrival_s,
                start_t=now, first_token_t=now + dt, finish_t=now + dt)
            now += dt
            if req.max_new <= 1:
                rep.requests.append(tl)
            else:
                active.append([req, req.max_new - 1, tl])
        elif active:
            dt = model.decode_step_s(len(active))
            now += dt
            rep.n_waves += 1                   # machine ops, here: steps
            rep.occupancy.append(len(active) / max_batch)
            if _obs.enabled():
                _obs.metrics.histogram("serve.occupancy").observe(
                    rep.occupancy[-1])
                depth = 0
                j = i
                while j < len(pending) and pending[j].arrival_s <= now:
                    depth += 1
                    j += 1
                _obs.metrics.histogram("serve.queue_depth").observe(depth)
                # decode steps are plentiful (one per generated token
                # across the batch); thin the timeline to every 32nd op
                if rep.n_waves % 32 == 1:
                    _obs.emit({"ev": "serve", "mode": "continuous",
                               "t_sim": now, "step": rep.n_waves,
                               "active": len(active), "queue_depth": depth,
                               "occupancy": rep.occupancy[-1]})
            still = []
            for ent in active:
                ent[1] -= 1
                if ent[1] <= 0:
                    ent[2].finish_t = now
                    rep.requests.append(ent[2])
                else:
                    still.append(ent)
            active = still
        else:
            now = pending[i].arrival_s         # idle until the next arrival
    rep.requests.sort(key=lambda r: r.rid)
    return rep


def replay(trace: Trace, executor: Union[WaveExecutor, ServiceModel],
           mode: str = "wave", max_batch: Optional[int] = None
           ) -> ServeReport:
    """Replay ``trace`` against ``executor`` and report SLO metrics.

    ``mode="wave"`` accepts any :class:`WaveExecutor`;
    ``mode="continuous"`` needs a :class:`ServiceModel` (pass one
    directly with ``max_batch``, or an :class:`AnalyticalWaveExecutor`
    whose model+max_batch are used).
    """
    if mode == "wave":
        if isinstance(executor, ServiceModel):
            executor = AnalyticalWaveExecutor(executor,
                                              max_batch=max_batch or 8)
        with _obs.span("serve.replay", mode=mode,
                       n_requests=len(trace.requests)):
            return _replay_waves(trace, executor)
    if mode == "continuous":
        if isinstance(executor, ServiceModel):
            model, mb = executor, max_batch or 8
        elif isinstance(executor, AnalyticalWaveExecutor):
            model, mb = executor.model, executor.max_batch
        else:
            raise ValueError(
                "mode='continuous' simulates mid-wave admissions, which "
                "only a ServiceModel (or AnalyticalWaveExecutor) supports; "
                f"got {type(executor).__name__} — use mode='wave' for real "
                "executors")
        with _obs.span("serve.replay", mode=mode,
                       n_requests=len(trace.requests)):
            rep = _replay_continuous(trace, model, mb)
        _obs.metrics.counter("serve.requests").inc(len(rep.requests))
        return rep
    raise ValueError(f"unknown replay mode {mode!r}: 'wave' or 'continuous'")


# ---------------------------------------------------------------------------
# Saturation sweep
# ---------------------------------------------------------------------------

def saturation_sweep(trace_at: Callable[[float], Trace],
                     executor_at: Callable[[], Union[WaveExecutor,
                                                     ServiceModel]],
                     rates: Sequence[float], mode: str = "wave",
                     max_batch: Optional[int] = None,
                     slo_mult: float = 5.0) -> Dict[str, object]:
    """Find saturation throughput by sweeping the arrival rate.

    Replays ``trace_at(rate)`` for each rate (ascending) and declares the
    system saturated once p99 end-to-end latency exceeds ``slo_mult`` x
    the lowest rate's p99 (the unloaded reference).  Returns the sweep
    table plus the saturation estimate: the highest rate still inside the
    SLO, with its measured request and token throughput.  Deterministic
    for analytical executors (same traces, same model).
    """
    rates = sorted(rates)
    if not rates:
        raise ValueError("saturation_sweep needs at least one rate")
    table: List[Dict[str, float]] = []
    ref_p99: Optional[float] = None
    sat: Optional[Dict[str, float]] = None
    saturated = False
    for rate in rates:
        rep = replay(trace_at(rate), executor_at(), mode=mode,
                     max_batch=max_batch)
        s = rep.summary()
        row = {"rate_rps": rate, "p99_e2e_s": rep.p99_e2e_s,
               "p99_ttft_s": rep.p99_ttft_s,
               "throughput_rps": s["throughput_rps"],
               "throughput_tok_s": s["throughput_tok_s"],
               "mean_occupancy": s["mean_occupancy"]}
        table.append(row)
        if ref_p99 is None:
            ref_p99 = rep.p99_e2e_s
        if rep.p99_e2e_s <= slo_mult * ref_p99:
            sat = row
        else:
            saturated = True
            break
    return {
        "slo_mult": slo_mult,
        "ref_p99_e2e_s": ref_p99,
        "saturated": saturated,
        "sat_rate_rps": sat["rate_rps"] if sat else None,
        "sat_throughput_rps": sat["throughput_rps"] if sat else None,
        "sat_throughput_tok_s": sat["throughput_tok_s"] if sat else None,
        "sweep": table,
    }
