"""Traffic-replay serving subsystem.

The ROADMAP's "millions of users" axis: deterministic synthetic traffic
traces (:mod:`repro.serve.trace`), a discrete-event replay harness with
continuous batch slotting and SLO reporting (:mod:`repro.serve.harness`),
and the analytical queueing predictor + traffic-model registry that feeds
tail latency back into the DSE as an objective (:mod:`repro.serve.slo`).

The package is deliberately NumPy-pure: executors that touch JAX (the
real-model wave executor, the realized-program path) live in
``runtime/serve_loop.py`` and ``launch/serve.py`` and plug in through the
structural :class:`repro.serve.harness.WaveExecutor` protocol.
"""

from .harness import (AnalyticalWaveExecutor, RequestTimeline, ServeReport,
                      ServiceModel, WaveCost, WaveExecutor, replay,
                      saturation_sweep, service_model_from_delay)
from .slo import (TrafficModel, register_traffic_model, resolve_traffic,
                  predict_slo)
from .trace import (Trace, TraceRequest, diurnal_trace, make_trace,
                    poisson_trace, respec)

__all__ = [
    "Trace", "TraceRequest", "poisson_trace", "diurnal_trace", "make_trace",
    "respec",
    "WaveExecutor", "WaveCost", "ServiceModel", "AnalyticalWaveExecutor",
    "RequestTimeline", "ServeReport", "replay", "saturation_sweep",
    "service_model_from_delay",
    "TrafficModel", "register_traffic_model", "resolve_traffic",
    "predict_slo",
]
