"""Analytical SLO prediction + traffic-model registry for the DSE loop.

Closes the serving loop: ``run_dse(objective="slo", traffic=...)`` scores
each candidate by its predicted tail latency under a registered traffic
model instead of the raw forward-pass delay.  The prediction is fully
analytical — the evaluator's delay maps to a per-token
:class:`~repro.serve.harness.ServiceModel`, which the harness replays
over the traffic model's (deterministic, seeded) arrival process.
Queueing over that process is what makes p99 a *convex* function of the
delay: a candidate whose service rate sits near the trace's offered load
pays super-linear waiting time, so the MC^a * E^b * p99^g objective can
rank candidates differently from MC^a * E^b * D^g even though p99 is
monotone in D for a fixed traffic model.  The replay harness's measured
percentiles (``launch/serve.py --measure``) validate/calibrate the
prediction the same way ``realize/measure.py`` validates traffic bytes —
they never replace it inside the sweep, which must stay deterministic.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, replace
from functools import lru_cache
from typing import Dict, Optional, Tuple, Union

from .harness import replay, service_model_from_delay
from .trace import Trace, make_trace

__all__ = ["TrafficModel", "register_traffic_model", "resolve_traffic",
           "predict_slo", "SLO_SCALAR_KEY"]

# The report key reduce_tasks() folds into the objective.
SLO_SCALAR_KEY = "p99_e2e_s"


@dataclass(frozen=True)
class TrafficModel:
    """A named, replayable load pattern the DSE can optimize against.

    ``trace_spec`` uses the :func:`repro.serve.trace.make_trace` grammar;
    ``seq_ref`` is the tokens-per-sequence the evaluator's delay is
    normalized over when deriving the per-token cost (64 matches the
    quick workloads; register a model with the deployment's seq for
    paper-scale runs).  ``mode`` picks the harness scheduling policy
    ("continuous" slotting by default — the wave policy is available for
    A/B against the real serve_loop path).
    """
    name: str
    trace_spec: str
    max_batch: int = 8
    mode: str = "continuous"
    seq_ref: int = 64
    decode_mult: float = 1.0

    def fingerprint(self) -> str:
        """Short stable id stamped into the sweep fingerprint."""
        blob = (f"{self.trace_spec}|b{self.max_batch}|{self.mode}"
                f"|s{self.seq_ref}|d{self.decode_mult:g}")
        h = hashlib.sha1(blob.encode("utf-8")).hexdigest()[:8]
        return f"{self.name}.{h}"


_REGISTRY: Dict[str, TrafficModel] = {}


def register_traffic_model(model: TrafficModel,
                           overwrite: bool = False) -> TrafficModel:
    """Register ``model`` under its name; returns it for chaining."""
    if not overwrite and model.name in _REGISTRY \
            and _REGISTRY[model.name] != model:
        raise ValueError(
            f"traffic model {model.name!r} already registered with a "
            "different definition (pass overwrite=True to replace)")
    _REGISTRY[model.name] = model
    return model


def registered_traffic_models() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_traffic(spec: Union[str, TrafficModel],
                    **overrides) -> TrafficModel:
    """Resolve a TrafficModel, registered name, or raw trace spec.

    A string containing ``":"`` is treated as an ad-hoc
    :func:`make_trace` spec (validated eagerly so typos fail at resolve
    time, with the generator's own listing); anything else must be a
    registered name.  Keyword overrides (``max_batch=…``, ``mode=…``,
    ``seq_ref=…``) are applied on top.
    """
    if isinstance(spec, TrafficModel):
        model = spec
    elif spec in _REGISTRY:
        model = _REGISTRY[spec]
    elif isinstance(spec, str) and ":" in spec:
        make_trace(spec)          # eager validation — raises on bad specs
        model = TrafficModel(name="adhoc", trace_spec=spec)
    else:
        raise KeyError(
            f"unknown traffic model {spec!r}: not a registered name "
            f"{registered_traffic_models()} and not a trace spec "
            "(kind:k=v,... — see repro.serve.trace.make_trace)")
    return replace(model, **overrides) if overrides else model


# -- defaults ---------------------------------------------------------------
# Quick models sized for reduced/CI runs: short traces, mixed prompt and
# decode lengths.  Rates here are placeholders for interactive use; a DSE
# caller who wants the queueing knee to bite should register a model whose
# rate sits near the candidates' service capacity (see tests).
register_traffic_model(TrafficModel(
    name="chat-quick",
    trace_spec="poisson:rate=4,n=48,seed=0,plen=4..32,new=8..32"))
register_traffic_model(TrafficModel(
    name="diurnal-quick",
    trace_spec="diurnal:rate=4,n=48,seed=0,period=60,peak=3,"
               "plen=4..32,new=8..32"))


# -- prediction -------------------------------------------------------------

@lru_cache(maxsize=64)
def _trace_for(trace_spec: str) -> Trace:
    return make_trace(trace_spec)


@lru_cache(maxsize=4096)
def _predict_cached(delay_s: float, traffic: TrafficModel,
                    batch: int) -> Tuple[Tuple[str, float], ...]:
    trace = _trace_for(traffic.trace_spec)
    model = service_model_from_delay(delay_s, batch, traffic.seq_ref,
                                     decode_mult=traffic.decode_mult)
    rep = replay(trace, model, mode=traffic.mode,
                 max_batch=traffic.max_batch)
    s = rep.summary()
    out = {"makespan_s": s["makespan_s"],
           "throughput_rps": s["throughput_rps"],
           "throughput_tok_s": s["throughput_tok_s"],
           "mean_occupancy": s["mean_occupancy"]}
    for pfx, key in (("ttft", "ttft_s"), ("e2e", "e2e_s")):
        for p, v in s[key].items():
            out[f"{p}_{pfx}_s"] = v
    return tuple(sorted(out.items()))


def predict_slo(delay_s: float, traffic: Union[str, TrafficModel],
                batch: int) -> Dict[str, float]:
    """Predicted SLO metrics for a candidate with forward delay ``delay_s``.

    ``batch`` is the DSE batch the delay was evaluated at (together with
    the traffic model's ``seq_ref`` it normalizes the delay to a
    per-token cost).  Returns a dict with ``p50/p95/p99`` TTFT and
    end-to-end latency seconds plus throughput/occupancy; the DSE folds
    ``p99_e2e_s`` (:data:`SLO_SCALAR_KEY`) into its objective.
    Deterministic and cached — safe to call per (candidate x workload)
    task inside a sweep.
    """
    model = resolve_traffic(traffic)
    return dict(_predict_cached(float(delay_s), model, int(batch)))
