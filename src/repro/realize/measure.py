"""Measured-vs-predicted extraction (realization stage 3).

For every compiled stage program this module pulls the *measured* side from
the XLA artifacts — trip-count-aware FLOPs and HBM bytes plus collective
bytes from the compiled HLO (``launch/hlo_analysis``, the same walker the
512-device dry-run trusts), compile-time memory from
``compiled.memory_analysis()``, and the inter-stage activation bytes the
executor actually moved — and the *predicted* side from the analytical
evaluator for the exact same LMS: per-group MACs, NoC bytes, D2D bytes and
DRAM bytes out of ``GroupAnalysis`` (``Evaluator.traffic_summary``).

Axis correspondence (the bridge contract of ``core/bridge.mesh_as_arch``):

  measured intra-stage collective bytes  <->  predicted NoC-link bytes (ICI)
  measured inter-stage transfer bytes    <->  predicted D2D bytes      (DCI)
  measured HLO HBM bytes                 <->  predicted DRAM bytes
  measured HLO FLOPs                     <->  2 x predicted MACs

Absolute agreement is not expected — the realized program runs f32 on the
XLA CPU backend while the cost model prices int8/bf16 dataflows — but the
*ratios* are stable per technology, which is exactly what
:mod:`.calibrate` fits.  Everything is per ONE pipeline pass (batch-unit
batch), matching ``GroupAnalysis``'s per-pass convention.

Expected-traffic graphs (MoE / routed workloads, ``graph.is_scaled``)
lower to *dense-equivalent* programs — XLA executes the full cubes, while
the analytical prediction carries the expected-traffic scales.  To keep
the measured/predicted ratios comparable to the dense case (one stable
factor per technology axis), each stage's measured numbers are multiplied
by the per-axis expected-traffic factor ``pred_scaled / pred_dense``
recovered from a :func:`repro.core.workload.dense_twin` evaluation of the
identical LMS.  Dense graphs take the exact historical path (the twin IS
the graph; no extra evaluation, no float ops).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Tuple

import numpy as np

from .. import obs as _obs
from ..core.evaluator import evaluator_for
from ..core.workload import dense_twin
from ..launch.hlo_analysis import analyze_hlo_text
from .plan import RealizeCandidate
from .program import RealizedProgram, StageProgram


@dataclass
class StageReport:
    """Measured and predicted traffic of one realized pipeline stage."""
    index: int
    layers: Tuple[str, ...]
    n_devices: int
    routes: Dict[str, str]
    # measured (global across the stage mesh, one pass)
    flops: float = 0.0
    hbm_bytes: float = 0.0
    ici_bytes: float = 0.0             # intra-stage collective bytes
    dci_bytes: float = 0.0             # inter-stage activation transfer
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    temp_bytes: float = 0.0            # compile-time scratch per device
    arg_bytes: float = 0.0
    compile_s: float = 0.0
    wall_s: float = 0.0
    # predicted (analytical, one pass)
    pred_flops: float = 0.0
    pred_dram_bytes: float = 0.0
    pred_noc_bytes: float = 0.0
    pred_d2d_bytes: float = 0.0
    pred_delay_s: float = 0.0
    pred_energy_j: float = 0.0
    pred_glb_overflow: float = 0.0
    # expected-traffic factors applied to the measured side (scaled graphs
    # only; empty for dense graphs — see module docstring)
    expected_scale: Dict[str, float] = field(default_factory=dict)

    def ratios(self) -> Dict[str, float]:
        """measured / predicted per axis; only well-defined pairs appear."""
        out: Dict[str, float] = {}
        for key, meas, pred in (
                ("flops", self.flops, self.pred_flops),
                ("dram_bytes", self.hbm_bytes, self.pred_dram_bytes),
                ("noc_bytes", self.ici_bytes, self.pred_noc_bytes),
                ("d2d_bytes", self.dci_bytes, self.pred_d2d_bytes)):
            if pred > 0 and meas > 0:
                out[key] = meas / pred
        return out

    def to_record(self) -> Dict[str, Any]:
        d = {k: getattr(self, k) for k in (
            "index", "n_devices", "flops", "hbm_bytes", "ici_bytes",
            "dci_bytes", "temp_bytes", "arg_bytes", "compile_s", "wall_s",
            "pred_flops", "pred_dram_bytes", "pred_noc_bytes",
            "pred_d2d_bytes", "pred_delay_s", "pred_energy_j")}
        d["layers"] = list(self.layers)
        d["routes"] = dict(self.routes)
        d["coll_by_kind"] = dict(self.coll_by_kind)
        d["ratios"] = self.ratios()
        if self.expected_scale:        # dense records keep their old shape
            d["expected_scale"] = dict(self.expected_scale)
        return d


@dataclass
class RealizationReport:
    """Full measured-vs-predicted record of one realized candidate."""
    key: str
    workload: str
    arch_label: str
    tech: str
    batch_unit: int
    stages: List[StageReport]
    pred_energy_j: float = 0.0         # checkpoint's analytical prediction
    pred_delay_s: float = 0.0

    def totals(self) -> Dict[str, float]:
        t: Dict[str, float] = {}
        for f in ("flops", "hbm_bytes", "ici_bytes", "dci_bytes",
                  "pred_flops", "pred_dram_bytes", "pred_noc_bytes",
                  "pred_d2d_bytes", "wall_s", "compile_s"):
            t[f] = sum(getattr(s, f) for s in self.stages)
        return t

    def ratio_summary(self) -> Dict[str, float]:
        """Geometric-mean measured/predicted ratio per traffic axis."""
        acc: Dict[str, List[float]] = {}
        for s in self.stages:
            for k, v in s.ratios().items():
                acc.setdefault(k, []).append(v)
        return {k: float(np.exp(np.mean(np.log(v))))
                for k, v in acc.items()}

    def to_record(self) -> Dict[str, Any]:
        return {"workload": self.workload, "arch": self.arch_label,
                "tech": self.tech, "batch_unit": self.batch_unit,
                "pred_energy_j": self.pred_energy_j,
                "pred_delay_s": self.pred_delay_s,
                "totals": self.totals(),
                "ratio_summary": self.ratio_summary(),
                "stages": [s.to_record() for s in self.stages]}


def _measure_stage(sp: StageProgram) -> Dict[str, float]:
    """Measured traffic of one compiled stage, scaled mesh-global."""
    compiled = sp.compiled
    n_dev = sp.n_devices
    costs = analyze_hlo_text(compiled.as_text())
    out = {"flops": costs.flops * n_dev,
           "hbm_bytes": costs.bytes * n_dev,
           "ici_bytes": costs.coll_bytes * n_dev,
           "coll_by_kind": {k: v * n_dev
                            for k, v in costs.coll_by_kind.items()},
           "temp_bytes": 0.0, "arg_bytes": 0.0}
    try:
        ma = compiled.memory_analysis()
        out["temp_bytes"] = float(getattr(ma, "temp_size_in_bytes", 0))
        out["arg_bytes"] = float(getattr(ma, "argument_size_in_bytes", 0))
    except Exception:          # backend without memory analysis
        pass
    return out


def measure_candidate(cand: RealizeCandidate, prog: RealizedProgram,
                      execute: bool = True, seed: int = 0
                      ) -> RealizationReport:
    """Compile (if needed), measure and optionally execute one candidate.

    The predicted side re-runs the analytical evaluator on the candidate's
    own (arch, graph, LMS) — the identical code path the DSE scored it
    with, so the diff isolates model-vs-measurement error, not drift."""
    ev = evaluator_for(cand.arch, cand.graph)
    # scaled graphs execute their dense-equivalent cubes; recover the
    # per-axis expected-traffic factor from a dense-twin evaluation of the
    # same LMS (dense graphs: twin IS the graph, no second evaluator)
    twin = dense_twin(cand.graph)
    ev_dense = ev if twin is cand.graph else evaluator_for(cand.arch, twin)
    reports: List[StageReport] = []
    for sp, (grp, lms) in zip(prog.stages, cand.mapping):
        with _obs.span("realize.measure_stage", key=cand.key,
                       stage=sp.index, n_devices=sp.n_devices):
            if sp.compiled is None:
                sp.lower_and_compile()
            # total_batch = batch_unit: ONE pipeline pass, with weight
            # loads unamortized — exactly what the realized stage executes
            pred = ev.traffic_summary(grp, lms, grp.batch_unit)
            meas = _measure_stage(sp)
            esc: Dict[str, float] = {}
            if ev_dense is not ev:
                dense = ev_dense.traffic_summary(grp, lms, grp.batch_unit)
                esc = {k: (pred[k] / dense[k]) if dense[k] > 0 else 1.0
                       for k in ("flops", "dram_bytes", "noc_bytes",
                                 "d2d_bytes")}
                meas["flops"] *= esc["flops"]
                meas["hbm_bytes"] *= esc["dram_bytes"]
                meas["ici_bytes"] *= esc["noc_bytes"]
            reports.append(StageReport(
                index=sp.index, layers=sp.stage.layers,
                n_devices=sp.n_devices,
                routes=dict(sp.routes),
                flops=meas["flops"], hbm_bytes=meas["hbm_bytes"],
                ici_bytes=meas["ici_bytes"],
                coll_by_kind=meas["coll_by_kind"],
                temp_bytes=meas["temp_bytes"], arg_bytes=meas["arg_bytes"],
                compile_s=sp.compile_s,
                pred_flops=pred["flops"],
                pred_dram_bytes=pred["dram_bytes"],
                pred_noc_bytes=pred["noc_bytes"],
                pred_d2d_bytes=pred["d2d_bytes"],
                pred_delay_s=pred["delay_s"],
                pred_energy_j=pred["energy_j"],
                pred_glb_overflow=pred["glb_overflow_bytes"],
                expected_scale=esc))
    if execute:
        with _obs.span("realize.execute", key=cand.key,
                       n_stages=len(reports)):
            run = prog.execute(seed=seed)
        for sr, wall, dci in zip(reports, run["wall_s"], run["dci_bytes"]):
            sr.wall_s = wall
            sr.dci_bytes = float(dci) * sr.expected_scale.get("d2d_bytes",
                                                              1.0)
    return RealizationReport(
        key=cand.key, workload=cand.workload, arch_label=cand.arch.label(),
        tech=cand.arch.tech.name, batch_unit=prog.batch_unit,
        stages=reports, pred_energy_j=cand.energy_j,
        pred_delay_s=cand.delay_s)
