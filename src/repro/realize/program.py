"""MeshPlan -> executable sharded JAX program (realization stage 2).

Each plan stage becomes one jit-compiled, sharded stage function:

* the stage **mesh** is the dominant layer's ``CG`` reshaped to its
  ``Part = (ph, pw, pb, pk)`` with axes ``("h", "w", "b", "k")`` — the
  Correspondence Rule's row-major (h, w, b, k) nesting IS the device
  order, so the realized placement matches the placement the analytical
  router priced;
* every layer's ofmap is materialized as the paper's 4-D cube
  ``(B, H, W, K)`` with ``PartitionSpec("b", "h", "w", "k")`` — the
  cube partitioning the ``Part`` describes;
* compute routes through the Pallas kernels of :mod:`repro.kernels`
  (interpret/auto mode, so the same program runs on CPU):
  ``fc``/``matmul`` -> the tiled GEMM, detected (qk, av) score/context
  pairs -> flash attention (scores never materialized, as on real TPU),
  ``*_ssd`` layers -> the chunked SSD kernel, eltwise -> VPU adds.
  ``use_pallas=False`` swaps in the jnp oracles of ``kernels/ref.py``
  (the parity target for tests);
* stage-to-stage activation hops are explicit ``device_put`` resharding
  onto the next stage's mesh — the realized analogue of the D2D/DCI
  transfers the evaluator priced (``runtime/pipeline.py`` is the
  microbatched production form of the same schedule).

Operand tensors whose producers live outside the stage arrive as program
inputs; where an abstract Gemini operand has no exact runtime tensor (a
matmul's weight-side activations, SSD's dt/B/C streams) it is derived
deterministically from the producer's output via ``jnp.resize`` — the MAC
count and operand sizes the cost model priced are preserved exactly, which
is what the measurement stage diffs against.

Expected-traffic graphs (routed MoE: ``graph.is_scaled``) lower to their
**dense-equivalent** programs: every expert branch executes its full cube
(fc layers take their first in-stage predecessor as the activation operand
— the dispatch/router edges are modeling-only — and a many-producer
combine eltwise sums all expert outputs, which is the dense execution of
the routed reduction).  The expected-traffic correction happens on the
measurement side (``measure.py`` dense-twin factors), not here; MLA graphs
are plain dense cubes and need nothing special.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.bridge import MeshPlan, StagePlan
from ..core.workload import Graph, Layer

STAGE_AXES = ("h", "w", "b", "k")
# cube dim order (B, H, W, K) -> mesh axis carrying it
CUBE_DIM_AXES = ("b", "h", "w", "k")


def cube_spec_for(shape: Tuple[int, ...], mesh: Mesh,
                  dim_axes: Tuple[Optional[str], ...] = CUBE_DIM_AXES) -> P:
    """PartitionSpec for ``shape`` on ``mesh``, sharding only dims the mesh
    axis divides evenly (jit argument shardings require divisibility; an
    indivisible dim is replicated, mirroring the analytical model's
    approximately-equal ``split_points`` with the remainder broadcast)."""
    spec = []
    for dim, ax in zip(shape, dim_axes):
        n = mesh.shape[ax] if ax is not None else 1
        spec.append(ax if ax is not None and n > 1 and dim % n == 0
                    else None)
    return P(*spec)


def _fit(x: jax.Array, shape: Tuple[int, ...]) -> jax.Array:
    """Deterministic tile/truncate of ``x`` onto ``shape`` (jnp.resize).

    Bridges abstract Gemini operands to concrete runtime tensors without
    changing the contraction sizes the cost model priced."""
    return jnp.resize(x.astype(jnp.float32), shape)


def _cube(layer: Layer, bu: int) -> Tuple[int, int, int, int]:
    return (bu, layer.H, layer.W, layer.K)


def _heads_for(d: int) -> Tuple[int, int]:
    """(heads, head_dim) factorization of a model width for the MXU kernels."""
    for hd in (128, 64, 32):
        if d % hd == 0:
            return d // hd, hd
    return 1, d


# ---------------------------------------------------------------------------
# Kernel routing
# ---------------------------------------------------------------------------

def _route_layers(g: Graph, st: StagePlan) -> Dict[str, str]:
    """layer -> route tag.  Attention (qk, av) pairs fuse into one flash
    call at the av layer's position when the scores layer has no other
    consumer (flash never materializes the score matrix, so another reader
    would see nothing)."""
    routes: Dict[str, str] = {}
    in_stage = set(st.layers)
    for name in st.layers:
        lyr = g.layers[name]
        if lyr.kind == "eltwise":
            routes[name] = "add"
        elif lyr.kind in ("pool", "depthwise"):
            routes[name] = "jnp"
        elif lyr.kind == "matmul" and name.endswith("_ssd"):
            routes[name] = "ssd"
        else:
            routes[name] = "matmul"
    for name in st.layers:
        lyr = g.layers[name]
        if lyr.kind != "matmul" or lyr.K != lyr.H:
            continue                       # not a square score matrix
        succs = g.succs(name)
        if len(succs) != 1 or succs[0] not in in_stage:
            continue
        av = succs[0]
        av_l = g.layers[av]
        if av_l.kind != "matmul" or av_l.C != lyr.K:
            continue                       # consumer doesn't contract scores
        routes[name] = f"flash-scores:{av}"
        routes[av] = f"flash:{name}"
    return routes


# ---------------------------------------------------------------------------
# Stage programs
# ---------------------------------------------------------------------------

@dataclass
class StageProgram:
    index: int
    stage: StagePlan
    mesh: Mesh
    routes: Dict[str, str]
    ext_inputs: Tuple[str, ...]        # producer layers feeding this stage
    src_inputs: Tuple[str, ...]        # graph-input layers synthesized here
    out_layers: Tuple[str, ...]        # cubes later stages / callers need
    jfn: Any = None                    # jitted stage function
    arg_structs: List[Any] = field(default_factory=list)
    in_shardings: List[Any] = field(default_factory=list)
    compiled: Any = None
    compile_s: float = 0.0

    @property
    def n_devices(self) -> int:
        return int(self.mesh.devices.size)

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def lower_and_compile(self) -> Any:
        t0 = time.time()
        self.compiled = self.jfn.lower(*self.arg_structs).compile()
        self.compile_s = time.time() - t0
        return self.compiled


@dataclass
class RealizedProgram:
    graph: Graph
    plan: MeshPlan
    stages: List[StageProgram]
    batch_unit: int
    interpret: Optional[bool]

    def compile_all(self) -> None:
        for sp in self.stages:
            sp.lower_and_compile()

    def execute(self, seed: int = 0) -> Dict[str, Any]:
        """Run the pipeline once (one batch-unit pass).

        Returns per-stage wall seconds, the DCI bytes moved between stage
        meshes, and every stage's exported cubes (``out_layers``)."""
        rng = np.random.default_rng(seed)
        outputs: Dict[str, jax.Array] = {}
        wall: List[float] = []
        dci_bytes: List[float] = []
        for sp in self.stages:
            args = []
            moved = 0.0
            for i, name in enumerate(sp.ext_inputs):
                x = outputs[name]
                shd = sp.in_shardings[i]
                # an already-identically-sharded cube (adjacent stages on
                # one device set) moves nothing — don't bill it as DCI
                if not x.sharding.is_equivalent_to(shd, x.ndim):
                    moved += x.size * x.dtype.itemsize
                args.append(jax.device_put(x, shd))
            # source ifmaps + weights: synthesized deterministically
            for struct, shd in zip(sp.arg_structs[len(sp.ext_inputs):],
                                   sp.in_shardings[len(sp.ext_inputs):]):
                a = rng.normal(size=struct.shape).astype(struct.dtype)
                args.append(jax.device_put(jnp.asarray(a), shd))
            fn = sp.compiled if sp.compiled is not None else sp.jfn
            t0 = time.time()
            outs = fn(*args)
            jax.block_until_ready(outs)
            wall.append(time.time() - t0)
            dci_bytes.append(moved)
            outputs.update(zip(sp.out_layers, outs))
        return {"wall_s": wall, "dci_bytes": dci_bytes, "outputs": outputs}


def _stage_mesh(st: StagePlan, devices: Sequence) -> Mesh:
    dom = st.dominant_layer()
    ph, pw, pb, pk = st.parts[dom]
    cg = st.cgs[dom]
    devs = np.asarray([devices[c] for c in cg], dtype=object)
    return Mesh(devs.reshape(ph, pw, pb, pk), STAGE_AXES)


def build_program(g: Graph, plan: MeshPlan, devices: Optional[Sequence] = None,
                  interpret: Optional[bool] = None,
                  use_pallas: bool = True) -> RealizedProgram:
    """Compile-ready realization of ``plan`` over ``devices``.

    ``devices`` defaults to ``jax.devices()``; Gemini core id ``c`` maps to
    ``devices[c]`` (the plan must already be validated against the pool —
    see ``realize.plan.validate_plan``).  ``interpret=None`` lets the
    kernels auto-select (interpret off-TPU).  ``use_pallas=False`` routes
    through the jnp oracles instead — same program structure, reference
    numerics (the parity target)."""
    from ..kernels import ops, ref

    devices = list(devices) if devices is not None else jax.devices()
    bu = plan.batch_unit
    stage_of: Dict[str, int] = {}
    for i, st in enumerate(plan.stages):
        for n in st.layers:
            stage_of[n] = i

    stages: List[StageProgram] = []
    for si, st in enumerate(plan.stages):
        routes = _route_layers(g, st)
        in_stage = set(st.layers)
        ext: List[str] = []
        src: List[str] = []
        for name in st.layers:
            for p in g.preds(name):
                if p not in in_stage and p not in ext:
                    if stage_of.get(p, si) >= si:
                        raise ValueError(
                            f"stage {si} layer {name} depends on {p} of a "
                            f"later stage — plan stages are not topological")
                    ext.append(p)
            if not g.preds(name):
                src.append(name)
        # outputs: cubes needed by later stages, plus graph outputs
        outs = [n for n in st.layers
                if any(stage_of.get(s2, -1) > si for s2 in g.succs(n))
                or not g.succs(n)]
        mesh = _stage_mesh(st, devices)
        sp = StageProgram(index=si, stage=st, mesh=mesh, routes=routes,
                          ext_inputs=tuple(ext), src_inputs=tuple(src),
                          out_layers=tuple(outs))

        def shd(shape: Tuple[int, ...],
                dim_axes: Tuple[Optional[str], ...] = CUBE_DIM_AXES
                ) -> NamedSharding:
            return sp.sharding(cube_spec_for(shape, mesh, dim_axes))

        # per-layer output cube shardings (the Part-derived constraint)
        lay_shd = {name: shd(_cube(g.layers[name], bu))
                   for name in st.layers}

        # argument structs: ext cubes, then source-layer ifmaps, then weights
        arg_structs: List[jax.ShapeDtypeStruct] = []
        in_shardings: List[NamedSharding] = []
        for name in ext:
            shape = _cube(g.layers[name], bu)
            arg_structs.append(jax.ShapeDtypeStruct(shape, jnp.float32))
            in_shardings.append(shd(shape))
        for name in src:
            lyr = g.layers[name]
            cin = max(lyr.C, 1) if lyr.kind in ("conv", "fc", "matmul") \
                else lyr.K
            shape = (bu, lyr.H * lyr.stride, lyr.W * lyr.stride, cin)
            arg_structs.append(jax.ShapeDtypeStruct(shape, jnp.float32))
            in_shardings.append(shd(shape))
        weighted = [n for n in st.layers if g.layers[n].has_weight]
        for name in weighted:
            lyr = g.layers[name]
            cin = max(1, (lyr.C // lyr.groups)) * lyr.R * lyr.S
            arg_structs.append(jax.ShapeDtypeStruct((cin, lyr.K),
                                                    jnp.float32))
            in_shardings.append(shd((cin, lyr.K), (None, "k")))

        def stage_fn(*args, _st=st, _routes=routes, _ext=tuple(ext),
                     _src=tuple(src), _weighted=tuple(weighted),
                     _outs=tuple(outs), _lay_shd=lay_shd):
            vals: Dict[str, jax.Array] = {}
            na, ns = len(_ext), len(_src)
            for i2, name in enumerate(_ext):
                vals[name] = args[i2]
            srcs = {name: args[na + i2] for i2, name in enumerate(_src)}
            wts = {name: args[na + ns + i2]
                   for i2, name in enumerate(_weighted)}

            def operand(name: str, lyr: Layer) -> jax.Array:
                """The layer's activation operand, from preds or source."""
                preds = [p for p in g.preds(name) if p in vals]
                if preds:
                    return vals[preds[0]]
                return srcs[name]

            def mm(a2: jax.Array, b2: jax.Array) -> jax.Array:
                if use_pallas:
                    return ops.matmul(a2, b2, interpret=interpret)
                return ref.matmul_ref(a2, b2)

            for name in _st.layers:
                lyr = g.layers[name]
                route = _routes[name]
                shape = _cube(lyr, bu)
                if route.startswith("flash-scores:"):
                    continue            # materialized inside the av layer
                if route.startswith("flash:"):
                    qk = route.split(":", 1)[1]
                    qk_l = g.layers[qk]
                    S = qk_l.H
                    heads, hd = _heads_for(lyr.K)
                    qk_preds = [p for p in g.preds(qk) if p in vals] \
                        or [qk]
                    q_src = vals.get(qk_preds[0], srcs.get(qk))
                    k_src = vals.get(qk_preds[-1], q_src)
                    v_pr = [p for p in g.preds(name)
                            if p != qk and p in vals]
                    v_src = vals[v_pr[0]] if v_pr else k_src
                    q = _fit(q_src, (bu, S, heads, hd))
                    k = _fit(k_src, (bu, S, heads, hd))
                    v = _fit(v_src, (bu, S, heads, hd))
                    if use_pallas:
                        o = ops.flash_attention(q, k, v, interpret=interpret,
                                                bq=min(512, S),
                                                bk=min(512, S))
                    else:
                        t = lambda x: x.transpose(0, 2, 1, 3)
                        o = t(ref.attention_ref(t(q), t(k), t(v)))
                    out = o.reshape(bu, S, 1, heads * hd)
                    out = _fit(out, shape) if out.shape != shape else out
                elif route == "ssd":
                    heads, hd = _heads_for(lyr.K)
                    S = lyr.H
                    a_in = operand(name, lyr)
                    x = _fit(a_in, (bu, S, heads, hd))
                    dt = jax.nn.softplus(_fit(a_in, (bu, S, heads)) * 0.1)
                    A = -0.5 * jnp.ones((heads,), jnp.float32)
                    N = max(16, min(64, lyr.C))
                    Bm = _fit(a_in, (bu, S, 1, N)) * 0.1
                    Cm = _fit(a_in * 0.5 + 1.0, (bu, S, 1, N)) * 0.1
                    y, _ = ops.ssd_forward(x, dt, A, Bm, Cm,
                                           chunk=min(128, S),
                                           interpret=interpret)
                    out = y.reshape(bu, S, 1, heads * hd)
                    out = _fit(out, shape) if out.shape != shape else out
                elif route == "matmul":
                    a2 = _fit(operand(name, lyr),
                              (bu * lyr.H * lyr.W, max(lyr.C, 1)))
                    if lyr.has_weight:
                        b2 = wts[name]
                    else:
                        preds = [p for p in g.preds(name) if p in vals]
                        b_src = vals[preds[-1]] if preds else a2
                        b2 = _fit(b_src, (max(lyr.C, 1), lyr.K))
                    out = mm(a2, b2).reshape(shape) / np.sqrt(max(lyr.C, 1))
                elif route == "add":
                    preds = [p for p in g.preds(name) if p in vals]
                    if preds:
                        out = sum(_fit(vals[p], shape) for p in preds)
                    else:
                        out = _fit(srcs[name], shape)
                else:  # "jnp": pool / depthwise — VPU-style reduction
                    out = _fit(operand(name, lyr), shape) \
                        / (lyr.R * lyr.S)
                vals[name] = jax.lax.with_sharding_constraint(
                    out.astype(jnp.float32), _lay_shd[name])
            return tuple(vals[n] for n in _outs)

        sp.jfn = jax.jit(stage_fn,
                         in_shardings=tuple(in_shardings),
                         out_shardings=tuple(lay_shd[n] for n in outs))
        sp.arg_structs = arg_structs
        sp.in_shardings = in_shardings
        stages.append(sp)
    return RealizedProgram(graph=g, plan=plan, stages=stages,
                           batch_unit=bu, interpret=interpret)
