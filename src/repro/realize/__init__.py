"""Mapping realization subsystem: DSE checkpoint -> executable sharded JAX
program -> measured-cost calibration loop.

The four modules close the loop the ROADMAP called the "Pallas/TPU bridge":

* :mod:`.plan`      — load serialized ``keep_mappings`` checkpoint records
  and lower each through ``core/bridge.lms_to_plan`` into a validated
  :class:`~repro.core.bridge.MeshPlan`;
* :mod:`.program`   — compile a plan into a sharded JAX program on the
  host-platform dry-run mesh, routing matmul/attention/SSD layers through
  the Pallas kernels (interpret mode on CPU);
* :mod:`.measure`   — extract per-stage FLOPs / ICI / DCI / HBM traffic
  from the compiled HLO and diff them against the analytical evaluator's
  predictions for the same LMS;
* :mod:`.calibrate` — fit per-:class:`~repro.core.hw.Tech` correction
  factors from those diffs and emit a ``Tech`` overlay that ``run_dse``
  can consume for a measured-calibrated second search pass.

``launch/realize.py`` is the CLI driver; ``examples/realize_demo.py`` runs
the whole loop on CPU.
"""
