"""Measured-cost calibration (realization stage 4).

Fits per-:class:`~repro.core.hw.Tech` correction factors from the
measured-vs-predicted ratios of one or more realization reports and emits
a **Tech overlay**: a scaling of the technology's traffic energy constants
(D2D bytes, NoC hop bytes, DRAM bytes) that ``run_dse`` consumes by simply
searching over overlay-applied candidates — the second DSE pass then ranks
architectures under measured-calibrated costs.

Invariants (tested):

* an **identity overlay changes nothing** — ``apply`` returns the original
  ``Tech`` object untouched (same name, same ``candidate_key``, same
  checkpoint fingerprints), so calibration off is bit-identical to the
  pre-realization engine by construction, not by luck;
* a non-identity overlay registers its derived ``Tech`` with
  ``explore.register_tech`` so calibrated sweeps stay resumable;
* factors are fitted in log space (geometric mean over stages and
  candidates) and clamped to ``[f_min, f_max]`` — a single degenerate
  stage cannot fling the cost model by orders of magnitude.
"""

from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Dict, List, Sequence, Union

import numpy as np

from ..core.explore import register_tech
from ..core.hw import ArchConfig, Tech
from .measure import RealizationReport

# ratio key (measure.StageReport.ratios) -> Tech energy field it calibrates
_FACTOR_FIELDS = {
    "d2d_bytes": "e_d2d_byte",
    "noc_bytes": "e_noc_hop_byte",
    "dram_bytes": "e_dram_byte",
}


@dataclass(frozen=True)
class TechOverlay:
    """Multiplicative corrections to a Tech's traffic energy constants."""
    f_d2d: float = 1.0                 # scales e_d2d_byte
    f_noc: float = 1.0                 # scales e_noc_hop_byte
    f_dram: float = 1.0                # scales e_dram_byte
    source: str = ""                   # provenance (ckpt/mesh description)
    n_stages: int = 0                  # evidence size behind the fit

    _FIELDS = ("f_d2d", "f_noc", "f_dram")

    def is_identity(self) -> bool:
        return all(getattr(self, f) == 1.0 for f in self._FIELDS)

    def tag(self) -> str:
        """Content hash of the factors — two different overlays must
        never produce same-named Techs (checkpoints identify techs by
        name only, so a name collision would let a sweep calibrated
        under overlay A silently resume with overlay B's constants)."""
        import hashlib
        h = hashlib.sha1(repr(tuple(getattr(self, f)
                                    for f in self._FIELDS)).encode())
        return h.hexdigest()[:8]

    def apply(self, tech: Tech) -> Tech:
        """Overlay-corrected Tech.

        Identity overlays return ``tech`` itself — same object, same name
        — so "calibration off" cannot perturb anything downstream (keys,
        fingerprints, float values)."""
        if self.is_identity():
            return tech
        new = dataclasses.replace(
            tech,
            name=f"{tech.name}+cal{self.tag()}",
            e_d2d_byte=tech.e_d2d_byte * self.f_d2d,
            e_noc_hop_byte=tech.e_noc_hop_byte * self.f_noc,
            e_dram_byte=tech.e_dram_byte * self.f_dram)
        register_tech(new)             # calibrated sweeps stay resumable
        return new

    def apply_arch(self, arch: ArchConfig) -> ArchConfig:
        t = self.apply(arch.tech)
        return arch if t is arch.tech else arch.replace(tech=t)

    def to_dict(self) -> Dict[str, Any]:
        return {f: getattr(self, f) for f in
                (*self._FIELDS, "source", "n_stages")}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TechOverlay":
        return cls(**{k: d[k] for k in
                      (*cls._FIELDS, "source", "n_stages") if k in d})


def _stage_ratio_dicts(rep: Union[RealizationReport, Dict[str, Any]]
                       ) -> List[Dict[str, float]]:
    """Per-stage ratio dicts from a live report OR a realize.jsonl record
    (resumed sweeps feed the fit from disk without re-measuring)."""
    if isinstance(rep, dict):
        return [dict(st.get("ratios", {})) for st in rep.get("stages", [])]
    return [st.ratios() for st in rep.stages]


def fit_overlay(reports: Sequence[Union[RealizationReport, Dict[str, Any]]],
                source: str = "",
                f_min: float = 0.1, f_max: float = 10.0) -> TechOverlay:
    """Fit the overlay from realization reports (log-space geomean).

    Only stages where both sides of a ratio are positive contribute (a
    monolithic candidate has no D2D edges to calibrate, a stage without
    collectives no NoC ratio).  An axis with no evidence stays at 1.0."""
    logs: Dict[str, List[float]] = {k: [] for k in _FACTOR_FIELDS}
    n_stages = 0
    for rep in reports:
        for ratios in _stage_ratio_dicts(rep):
            n_stages += 1
            for k, v in ratios.items():
                if k in logs and v > 0:
                    logs[k].append(math.log(v))
    factors = {}
    for k, vals in logs.items():
        f = math.exp(float(np.mean(vals))) if vals else 1.0
        factors[k] = min(f_max, max(f_min, f))
    return TechOverlay(f_d2d=factors["d2d_bytes"],
                       f_noc=factors["noc_bytes"],
                       f_dram=factors["dram_bytes"],
                       source=source, n_stages=n_stages)


def calibrated_candidates(cands: Sequence[ArchConfig],
                          overlay: TechOverlay) -> List[ArchConfig]:
    """Candidate grid under the overlay (what the second DSE pass sweeps).

    With an identity overlay this returns the input architectures
    *unchanged* (same objects), so ``run_dse(calibrated_candidates(c, id),
    ...)`` is bit-identical to ``run_dse(c, ...)``."""
    return [overlay.apply_arch(a) for a in cands]


def save_overlay(overlay: TechOverlay, path: Union[str, Path]) -> Path:
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(overlay.to_dict(), indent=1) + "\n")
    return p


def load_overlay(path: Union[str, Path]) -> TechOverlay:
    return TechOverlay.from_dict(json.loads(Path(path).read_text()))
