"""Checkpoint -> validated :class:`MeshPlan` lowering (realization stage 1).

A PR-3 schema-v2 checkpoint written with ``DSEConfig(keep_mappings=True)``
carries one record per (candidate, workload) task whose ``mapping`` field is
the full serialized LP-SPM mapping.  This module

* parses those records back into :class:`RealizeCandidate` objects
  (``arch_from_dict`` + ``mapping_from_jsonable``, with the LMS structural
  invariants re-validated against the workload graph),
* verifies the supplied workload graph *content-matches* the checkpoint's
  config fingerprint (the sweep hashed its graphs; realizing a mapping
  against a different graph would silently measure the wrong program),
* lowers each mapping through :func:`repro.core.bridge.lms_to_plan` and
  validates the resulting plan against a device budget (core ids are flat
  mesh device indices — a plan needing more devices than the mesh has is
  refused with the dry-run env fix named in the error).

No jax import here: planning is pure bookkeeping and stays usable from
processes that must not initialize a backend.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from ..core.bridge import MeshPlan, lms_to_plan
from ..core.explore import (ResumableSweep, arch_from_dict, graph_fingerprint,
                            mapping_from_jsonable)
from ..core.hw import ArchConfig
from ..core.sa import Mapping
from ..core.workload import Graph


@dataclass
class RealizeCandidate:
    """One checkpointed (candidate, workload) task selected for realization."""
    key: str                      # schema-v2 checkpoint key (resume identity)
    workload: str                 # workload dict key in the sweep
    arch: ArchConfig
    mapping: Mapping
    graph: Graph
    energy_j: float               # analytical prediction from the sweep
    delay_s: float
    seed: Optional[int] = None

    @property
    def edp(self) -> float:
        return self.energy_j * self.delay_s

    def lower(self) -> MeshPlan:
        """Lower the LMS mapping into a MeshPlan (bridge collapse)."""
        return lms_to_plan(self.mapping, delay_s=self.delay_s,
                           energy_j=self.energy_j)


# ---------------------------------------------------------------------------
# Workload resolution (checkpoints store graph fingerprints, not graphs)
# ---------------------------------------------------------------------------

from ..core.workloads import WORKLOAD_SPECS as WORKLOAD_PRESETS
from ..core.workloads import make_workload


def graph_from_spec(spec: str) -> Graph:
    """Build a workload graph from a by-name preset or CLI spec.

    Thin alias of :func:`repro.core.workloads.make_workload` — the single
    registry every CLI resolves ``--workload NAME=SPEC`` through.
    """
    return make_workload(spec)


_WL_FP = re.compile(r"(?:^|,)([^,:]+):([0-9a-f]{12})")


def checkpoint_workload_fingerprints(path: Union[str, Path]
                                     ) -> Dict[str, str]:
    """``{workload name: graph fingerprint}`` from a checkpoint's header.

    Empty when the file has no parseable ``_config`` header (e.g. a
    hand-built record file) — callers then skip the content check.
    """
    p = Path(path)
    if not p.exists():
        return {}
    with p.open() as f:              # header is the first line; don't
        for line in f:               # slurp a whole mapping checkpoint
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                return {}
            if "_config" not in rec:
                return {}
            cfg = rec["_config"]
            _, _, wl = cfg.partition(":wl=")
            return dict(_WL_FP.findall(wl))
    return {}


# ---------------------------------------------------------------------------
# Loading + validation
# ---------------------------------------------------------------------------

def load_realize_candidates(ckpt: Union[str, Path],
                            workloads: Dict[str, Graph],
                            top: int = 0,
                            verbose: bool = True,
                            sweep: Optional[ResumableSweep] = None
                            ) -> List[RealizeCandidate]:
    """Parse a schema-v2 checkpoint into realization candidates.

    Only records carrying a serialized mapping qualify (metrics-only records
    are counted and reported — they come from ``keep_mappings=False``
    sweeps and cannot be realized).  Each mapping is re-validated against
    the supplied graph (``LMS.validate``: Part/CG/FD structural rules), and
    the graph itself is checked against the checkpoint header's content
    fingerprint.  Results are sorted best analytical EDP first; ``top > 0``
    truncates.  Pass an already-parsed ``sweep`` (``ResumableSweep.read``)
    to avoid re-reading a large mapping checkpoint.
    """
    if sweep is None:
        sweep = ResumableSweep.read(ckpt)
    fps = checkpoint_workload_fingerprints(ckpt)
    for wl, g in workloads.items():
        if wl in fps and graph_fingerprint(g) != fps[wl]:
            raise ValueError(
                f"workload {wl!r}: supplied graph (fingerprint "
                f"{graph_fingerprint(g)}) does not content-match the "
                f"checkpoint's ({fps[wl]}); realizing a mapping against a "
                f"different graph would measure the wrong program")
    usable: List[Tuple[float, str, Dict]] = []
    n_nomap = n_badwl = 0
    for key, rec in sweep.as_dict().items():
        if "mapping" not in rec:
            n_nomap += 1
            continue
        if rec.get("workload") not in workloads:
            n_badwl += 1
            continue
        usable.append((float(rec["energy_j"]) * float(rec["delay_s"]),
                       key, rec))
    if verbose and (n_nomap or n_badwl):
        print(f"[realize] skipped {n_nomap} metrics-only records "
              f"(keep_mappings was off) and {n_badwl} records with no "
              f"supplied workload graph")
    if not usable:
        raise ValueError(
            f"{ckpt}: no realizable records (need a keep_mappings=True "
            f"sweep checkpoint and matching --workload graphs)")
    # rank on the raw record metrics and truncate BEFORE deserializing:
    # mappings are the bulky part of a keep_mappings checkpoint, and
    # --top K only ever needs K of them parsed + validated
    usable.sort(key=lambda t: (t[0], t[1]))
    if top > 0:
        usable = usable[:top]
    out: List[RealizeCandidate] = []
    for _edp, key, rec in usable:
        wl = rec["workload"]
        g = workloads[wl]
        arch = arch_from_dict(rec["arch"])
        mapping = mapping_from_jsonable(rec["mapping"])
        for grp, lms in mapping:
            lms.validate(grp, g, arch.n_cores, arch.n_dram)
        out.append(RealizeCandidate(
            key=key, workload=wl, arch=arch, mapping=mapping, graph=g,
            energy_j=float(rec["energy_j"]), delay_s=float(rec["delay_s"]),
            seed=rec.get("seed")))
    return out


def validate_plan(plan: MeshPlan, n_devices: int,
                  arch: Optional[ArchConfig] = None) -> None:
    """Refuse plans the target mesh cannot host.

    Core ids in a Gemini mapping are flat device indices on the runtime
    side; every stage's device set must fit the mesh, and (when the arch is
    given) the plan must not reference cores the architecture doesn't have
    — a corrupted or hand-edited record fails here, not inside XLA.
    """
    need = plan.n_devices_needed
    if arch is not None and need > arch.n_cores:
        raise ValueError(
            f"plan references core {need - 1} but the checkpointed arch "
            f"has only {arch.n_cores} cores — corrupt mapping record")
    if need > n_devices:
        from ..launch.mesh import DRYRUN_ENV_FIX
        raise ValueError(
            f"plan needs {need} devices, mesh/pool has {n_devices}; "
            f"on a CPU host, {DRYRUN_ENV_FIX}")
    for i, st in enumerate(plan.stages):
        for name in st.layers:
            part = st.parts[name]
            cg = st.cgs[name]
            p = part[0] * part[1] * part[2] * part[3]
            if p != len(cg):
                raise ValueError(
                    f"stage {i} layer {name}: Part {part} product {p} != "
                    f"|CG| {len(cg)}")


def plans_for(cands: Sequence[RealizeCandidate], n_devices: int
              ) -> List[Tuple[RealizeCandidate, MeshPlan]]:
    """Lower + validate every candidate against a device budget."""
    out = []
    for c in cands:
        plan = c.lower()
        validate_plan(plan, n_devices, c.arch)
        out.append((c, plan))
    return out
