"""Deterministic, seeded fault injection for chaos-testing the supervisor.

A chaos run is reproducible from a single seed: :func:`plan_faults`
derives, from ``(seed, n_shards, kind)``, which shard misbehaves and how
hard, and the supervisor ships the resulting :class:`FaultSpec` to the
shard child through two environment variables:

* ``REPRO_FAULT`` — ``kind[:k[:param]]``, e.g. ``kill:2`` (exit hard
  after 2 checkpoint records), ``stall:1`` (stop heartbeating after 1
  record and hang), ``corrupt:2`` (append a torn half-record to the
  checkpoint tail and die), ``slow:0.05`` (sleep 50 ms per record);
* ``REPRO_FAULT_ATTEMPT`` — the dispatch attempt number; faults fire
  only on attempt 0, so the supervisor's retry/re-shard recovery path
  gets a clean second run (the failure mode under test is the *first*
  crash, not an unrecoverable host).

``dup`` is the one supervisor-side fault: the same shard is dispatched
twice into separate attempt checkpoints, exercising the last-wins merge
and the conflict detector (identical records are the only correct
outcome — the per-task seed gate makes both attempts compute the same
numbers).

The hooks install inside the dedicated shard-child process only
(class-level wrappers on ``ResumableSweep``), never in the parent or the
library import path.
"""

from __future__ import annotations

import os
import sys
import time
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

FAULT_ENV = "REPRO_FAULT"
FAULT_ATTEMPT_ENV = "REPRO_FAULT_ATTEMPT"

# child exit code for an injected crash — distinguishable from real
# failures (tracebacks exit 1) in supervisor logs and CI artifacts
FAULT_EXIT_CODE = 73

# every injectable fault class; "dup" is handled by the supervisor
# (duplicate dispatch), the rest by the shard-child hooks below
FAULT_KINDS = ("kill", "stall", "corrupt", "dup", "slow")

# SeedSequence domain tag ("FALT") — disjoint from SA/task/retry streams
_FAULT_TAG = 0x46414C54


@dataclass(frozen=True)
class FaultSpec:
    """One planned fault: ``kind`` + after-how-many-records ``k`` +
    optional float ``param`` (per-record sleep for ``slow``)."""
    kind: str
    k: int = 1
    param: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}")

    def encode(self) -> str:
        return f"{self.kind}:{self.k}:{self.param:g}"

    @classmethod
    def parse(cls, spec: str) -> "FaultSpec":
        """``kind[:k[:param]]`` — the CLI / env grammar."""
        parts = spec.split(":")
        kind = parts[0]
        k = int(parts[1]) if len(parts) > 1 and parts[1] else 1
        param = float(parts[2]) if len(parts) > 2 and parts[2] else 0.0
        if kind == "slow" and param == 0.0:
            param = 0.05
        return cls(kind=kind, k=k, param=param)


def plan_faults(seed: int, n_shards: int, kind: str,
                k: Optional[int] = None) -> Dict[int, FaultSpec]:
    """Deterministically pick the victim shard (and ``k``) for ``kind``.

    One seeded draw decides which of the ``n_shards`` first-generation
    shards misbehaves and after how many completed records, so a chaos
    matrix re-run with the same seed replays the identical failure.
    """
    rng = np.random.default_rng(
        np.random.SeedSequence([abs(int(seed)), _FAULT_TAG]))
    victim = int(rng.integers(0, max(1, n_shards)))
    kk = int(rng.integers(1, 3)) if k is None else int(k)
    spec = FaultSpec(kind=kind, k=kk,
                     param=0.05 if kind == "slow" else 0.0)
    return {victim: spec}


def env_for(spec: Optional[FaultSpec], attempt: int) -> Dict[str, str]:
    """The environment overrides a host launch ships to the child."""
    env = {FAULT_ATTEMPT_ENV: str(int(attempt))}
    if spec is not None:
        env[FAULT_ENV] = spec.encode()
    return env


def _active_spec() -> Optional[FaultSpec]:
    raw = os.environ.get(FAULT_ENV)
    if not raw:
        return None
    if os.environ.get(FAULT_ATTEMPT_ENV, "0") != "0":
        return None                 # faults fire on the first attempt only
    return FaultSpec.parse(raw)


def install_fault_hooks() -> Optional[FaultSpec]:
    """Arm the planned fault inside the shard-child process.

    Wraps ``ResumableSweep.add``/``heartbeat`` at class level — safe
    because the shard child is a dedicated process whose only sweep is
    its own shard checkpoint.  Returns the armed spec (None = clean run).
    """
    spec = _active_spec()
    if spec is None or spec.kind == "dup":
        return None
    from ..core.explore import ResumableSweep

    state = {"records": 0, "fired": False}
    real_add = ResumableSweep.add
    real_hb = ResumableSweep.heartbeat

    def add(self, key, record):
        if spec.kind == "slow":
            time.sleep(spec.param)
            return real_add(self, key, record)
        if state["fired"]:
            return real_add(self, key, record)
        real_add(self, key, record)
        state["records"] += 1
        if state["records"] < spec.k:
            return
        state["fired"] = True
        if spec.kind == "kill":
            sys.stderr.write(f"[fault] kill after {spec.k} record(s)\n")
            sys.stderr.flush()
            os._exit(FAULT_EXIT_CODE)
        if spec.kind == "corrupt":
            # torn half-record with NO trailing newline: the classic
            # killed-mid-write tail every resume/merge path must drop
            sys.stderr.write(f"[fault] corrupt tail after {spec.k} "
                             "record(s)\n")
            sys.stderr.flush()
            corrupt_tail(self.path)
            os._exit(FAULT_EXIT_CODE)
        if spec.kind == "stall":
            sys.stderr.write(f"[fault] heartbeat stall after {spec.k} "
                             "record(s)\n")
            sys.stderr.flush()
            time.sleep(3600.0)      # hang until the supervisor kills us

    def heartbeat(self, payload):
        if spec.kind == "stall" and state["fired"]:
            return                  # liveness silenced, work "continues"
        return real_hb(self, payload)

    ResumableSweep.add = add
    ResumableSweep.heartbeat = heartbeat
    return spec


def corrupt_tail(path, fragment: str = '{"_key": "torn-by-fault", "ener'
                 ) -> None:
    """Append a torn, newline-less half-record — the truncated-tail
    injector the durability tests and the ``corrupt`` fault share."""
    with open(path, "a") as f:
        f.write(fragment)
        f.flush()
        os.fsync(f.fileno())
