"""Fault-tolerant multi-host sweep orchestration (the ROADMAP's
"multi-host DSE orchestration as a service").

Layers, bottom up:

* :mod:`repro.dist.retrying` — the reusable retry/timeout/exponential-
  backoff-with-jitter utility every dispatch path goes through
  (deterministic under a seeded RNG, so chaos runs replay exactly);
* :mod:`repro.dist.hosts` — the :class:`Host` launch protocol with a
  :class:`LocalProcessHost` (subprocess ``--shard`` children) and a
  :class:`ShellCommandHost` (SSH/SLURM-style ``{cmd}`` templates);
* :mod:`repro.dist.faults` — the deterministic, seeded fault-injection
  harness (kill-after-k, heartbeat stall, corrupt checkpoint tail,
  duplicate dispatch, slow-host skew) hooked into the shard child via
  environment variables;
* :mod:`repro.dist.supervisor` — the sweep supervisor proper: dispatch
  the shard set, poll shard checkpoints' ``_hb`` heartbeat lines for
  liveness, declare hosts dead after a missed-heartbeat deadline,
  re-shard a dead host's *remaining* tasks onto live hosts, merge with a
  fingerprint assertion, all while journaling its own state to an
  append-only resumable JSONL;
* :mod:`repro.dist.shard_child` — the ``python -m`` entry point a host
  launches for one shard.

The CLI front end is ``python -m repro.launch.sweep_ctl``
(launch / status / resume / merge).  The headline invariant, enforced by
the chaos tests and the ``chaos-dse`` CI job: under every injected fault
class the supervised sweep's merged checkpoint is bit-identical to a
failure-free unsharded run of the same grid and seed.
"""

from .faults import FaultSpec, plan_faults
from .hosts import Host, LocalProcessHost, ShellCommandHost
from .retrying import RetryPolicy, retry_call
from .supervisor import (ShardJob, Supervisor, SupervisorError, SweepSpec,
                         quick_spec)

__all__ = [
    "FaultSpec", "plan_faults",
    "Host", "LocalProcessHost", "ShellCommandHost",
    "RetryPolicy", "retry_call",
    "ShardJob", "Supervisor", "SupervisorError", "SweepSpec", "quick_spec",
]
