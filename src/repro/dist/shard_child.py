"""``python -m repro.dist.shard_child`` — one supervised shard.

The supervisor launches this module through a :class:`~repro.dist.hosts.
Host` with an explicit candidate-index list (screening already happened
upstream) and a dedicated checkpoint path.  Fault hooks install FIRST,
before any sweep machinery is touched, so an armed chaos fault governs
the entire run.

Exit code 0 means the child believes its checkpoint is complete; the
supervisor re-verifies against the engine's resume gate either way (a
lying or killed child is indistinguishable from a crashed one, and both
are handled by retry/re-shard).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def main(argv=None) -> int:
    # arm the chaos fault before importing anything that could be hooked
    from .faults import install_fault_hooks
    spec_armed = install_fault_hooks()

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--spec", required=True,
                    help="path to the supervisor's spec.json")
    ap.add_argument("--indices", required=True,
                    help="comma-separated global candidate indices")
    ap.add_argument("--checkpoint", required=True)
    ap.add_argument("--shard-label", default=None)
    ap.add_argument("--n-workers", type=int, default=1)
    args = ap.parse_args(argv)

    from ..core.dse import run_dse
    from .supervisor import SweepSpec

    spec = SweepSpec.from_json(Path(args.spec).read_text())
    indices = [int(i) for i in args.indices.split(",") if i.strip()]
    if spec_armed is not None:
        print(f"[shard_child] fault armed: {spec_armed.encode()}",
              file=sys.stderr)
    if not indices:
        return 0
    pts = run_dse(spec.build_candidates(), spec.build_workloads(),
                  spec.build_cfg(), use_sa=spec.use_sa,
                  n_workers=args.n_workers, checkpoint=args.checkpoint,
                  indices=indices, shard_label=args.shard_label)
    print(json.dumps({"shard": args.shard_label, "n_points": len(pts)}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
