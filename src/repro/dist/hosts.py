"""Host abstraction for dispatching shard children.

A :class:`Host` turns an argv (``python -m repro.dist.shard_child ...``)
into a running process and hands back a :class:`Handle` the supervisor
polls/kills.  Two implementations:

* :class:`LocalProcessHost` — plain subprocesses on this machine (the CI
  chaos harness and single-box multi-core sweeps);
* :class:`ShellCommandHost` — a ``{cmd}`` template wrapped around the
  command line, covering SSH/SLURM-style dispatch (``"ssh dse-03
  {cmd}"``, ``"srun -p batch {cmd}"``) without this module knowing
  anything about the transport.  Environment overrides are folded into
  the command as POSIX ``K=V`` prefixes so they survive the remote hop.

Launches go through :func:`repro.dist.retrying.retry_call` — a transient
spawn failure (fork pressure, ssh connection reset) retries with
deterministic jittered backoff instead of failing the whole sweep.

Note the kill asymmetry the supervisor's re-shard protocol is designed
around: ``LocalProcessHost`` kills reach the child, but a
``ShellCommandHost`` kill only reaches the *local* wrapper — the remote
process may linger and keep appending to its checkpoint.  That is why a
declared-dead shard's replacement jobs always write **fresh** checkpoint
files (see ``supervisor.py``): a zombie writer can race the merge only
with records the per-task seed gate makes identical anyway.
"""

from __future__ import annotations

import os
import shlex
import subprocess
import sys
from pathlib import Path
from typing import Dict, List, Optional, Protocol, Sequence, Union

from .retrying import RetryPolicy, retry_call

# spawn-time policy: quick, bounded — a host that cannot spawn after 4
# tries is genuinely sick and should surface as a launch failure
LAUNCH_RETRY = RetryPolicy(max_attempts=4, base_s=0.05, factor=2.0,
                           max_s=2.0, retryable=(OSError,))


class Handle(Protocol):
    """A launched shard process, as seen by the supervisor."""

    def poll(self) -> Optional[int]:
        """Exit code, or None while still running."""
        ...

    def kill(self) -> None:
        ...

    def wait(self, timeout: Optional[float] = None) -> Optional[int]:
        ...


class Host(Protocol):
    """Anything that can run a shard child and report liveness."""

    name: str

    def launch(self, argv: Sequence[str], env: Dict[str, str],
               log_path: Union[str, Path, None] = None) -> Handle:
        """Start ``argv`` with ``env`` overrides; stdout+stderr to
        ``log_path`` when given."""
        ...


class _PopenHandle:
    """Thin adapter closing the log file with the process."""

    def __init__(self, proc: subprocess.Popen, log_file=None):
        self._proc = proc
        self._log = log_file

    @property
    def pid(self) -> int:
        return self._proc.pid

    def poll(self) -> Optional[int]:
        rc = self._proc.poll()
        if rc is not None:
            self._close_log()
        return rc

    def kill(self) -> None:
        try:
            self._proc.kill()
            self._proc.wait(timeout=10)
        except (OSError, subprocess.TimeoutExpired):
            pass
        self._close_log()

    def wait(self, timeout: Optional[float] = None) -> Optional[int]:
        try:
            rc = self._proc.wait(timeout=timeout)
        except subprocess.TimeoutExpired:
            return None
        self._close_log()
        return rc

    def _close_log(self) -> None:
        if self._log is not None:
            try:
                self._log.close()
            except OSError:
                pass
            self._log = None


def _child_env(env: Dict[str, str]) -> Dict[str, str]:
    """Full child environment: inherited, PYTHONPATH guaranteed to reach
    this repo's ``src`` (the child is ``python -m repro...``), overrides
    last."""
    full = dict(os.environ)
    src = str(Path(__file__).resolve().parents[2])
    pp = full.get("PYTHONPATH", "")
    if src not in pp.split(os.pathsep):
        full["PYTHONPATH"] = f"{src}{os.pathsep}{pp}" if pp else src
    full.update(env)
    return full


class LocalProcessHost:
    """Launch shard children as subprocesses of this machine."""

    def __init__(self, name: str = "local", python: Optional[str] = None,
                 retry_seed: int = 0):
        self.name = name
        self.python = python or sys.executable
        self.retry_seed = retry_seed

    def launch(self, argv: Sequence[str], env: Dict[str, str],
               log_path: Union[str, Path, None] = None) -> _PopenHandle:
        cmd = [self.python, *argv]
        log = None
        if log_path is not None:
            Path(log_path).parent.mkdir(parents=True, exist_ok=True)
            log = open(log_path, "ab")

        def spawn() -> subprocess.Popen:
            return subprocess.Popen(
                cmd, env=_child_env(env),
                stdout=log or subprocess.DEVNULL,
                stderr=subprocess.STDOUT if log else subprocess.DEVNULL)

        try:
            proc = retry_call(spawn, policy=LAUNCH_RETRY,
                              seed=self.retry_seed,
                              label=f"launch@{self.name}")
        except BaseException:
            if log is not None:
                log.close()
            raise
        return _PopenHandle(proc, log)

    def __repr__(self) -> str:
        return f"LocalProcessHost({self.name!r})"


class ShellCommandHost:
    """Dispatch through a shell-command template (SSH/SLURM style).

    ``template`` must contain ``{cmd}``; the child's command line —
    ``K=V`` env prefixes included — is quoted and substituted, then the
    whole thing runs under ``sh -c`` locally.  ``"{cmd}"`` is therefore
    a LocalProcessHost-equivalent loopback, which is what the tests and
    the CI chaos job use; real deployments pass ``"ssh <host> {cmd}"``.
    """

    def __init__(self, template: str, name: Optional[str] = None,
                 python: str = "python", retry_seed: int = 0):
        if "{cmd}" not in template:
            raise ValueError(
                f"host template {template!r} must contain '{{cmd}}'")
        self.template = template
        self.name = name or template.replace("{cmd}", "").strip() or "shell"
        self.python = python
        self.retry_seed = retry_seed

    def launch(self, argv: Sequence[str], env: Dict[str, str],
               log_path: Union[str, Path, None] = None) -> _PopenHandle:
        # POSIX `K=V cmd` prefixes ride the template to the remote side
        prefix = " ".join(f"{k}={shlex.quote(v)}"
                          for k, v in sorted(env.items()))
        src = str(Path(__file__).resolve().parents[2])
        prefix = f"PYTHONPATH={shlex.quote(src)} {prefix}".strip()
        cmd = " ".join([prefix, self.python,
                        *(shlex.quote(a) for a in argv)]).strip()
        full = self.template.format(cmd=cmd)
        log = None
        if log_path is not None:
            Path(log_path).parent.mkdir(parents=True, exist_ok=True)
            log = open(log_path, "ab")

        def spawn() -> subprocess.Popen:
            return subprocess.Popen(
                ["/bin/sh", "-c", full],
                stdout=log or subprocess.DEVNULL,
                stderr=subprocess.STDOUT if log else subprocess.DEVNULL)

        try:
            proc = retry_call(spawn, policy=LAUNCH_RETRY,
                              seed=self.retry_seed,
                              label=f"launch@{self.name}")
        except BaseException:
            if log is not None:
                log.close()
            raise
        return _PopenHandle(proc, log)

    def __repr__(self) -> str:
        return f"ShellCommandHost({self.template!r})"


def parse_hosts(specs: Sequence[str], n_local: int = 0) -> List[Host]:
    """CLI helper: ``--host`` template strings + ``--hosts N`` local
    process slots into a host list (at least one)."""
    hosts: List[Host] = [ShellCommandHost(s, name=f"shell{i}",
                                          retry_seed=i)
                         for i, s in enumerate(specs)]
    hosts += [LocalProcessHost(name=f"local{i}", retry_seed=100 + i)
              for i in range(n_local)]
    if not hosts:
        hosts = [LocalProcessHost()]
    return hosts
