"""Reusable retry/timeout/exponential-backoff-with-jitter utility.

Every dispatch path in the multi-host stack (host launches, supervisor
re-dispatch, the realize driver's checkpoint open) funnels through
:func:`retry_call`, so transient-failure policy lives in exactly one
place.  Two properties matter for the chaos harness:

* **Determinism** — the jitter stream derives from a seeded
  ``np.random.SeedSequence``, never the global RNG, so a chaos run's
  backoff schedule (and therefore its event ordering) replays exactly
  from the run seed.  Telemetry-grade randomness must not leak into
  anything bit-identity-tested.
* **Typed retry surface** — only exception types listed in
  ``RetryPolicy.retryable`` are retried; anything else passes straight
  through to the caller (a programming error must never be masked by a
  backoff loop).
"""

from __future__ import annotations

import time as _time
from dataclasses import dataclass
from typing import (Any, Callable, Iterator, Optional, Tuple, Type,
                    TypeVar)

import numpy as np

from .. import obs as _obs

T = TypeVar("T")

# SeedSequence domain tag ("RTRY") keeping retry jitter streams disjoint
# from every other seeded stream in the repo (SA chains, swap RNG, faults)
_JITTER_TAG = 0x52545259


@dataclass(frozen=True)
class RetryPolicy:
    """Backoff schedule + retry surface for :func:`retry_call`.

    ``attempt k`` (0-based) failing sleeps
    ``min(max_s, base_s * factor**k)`` scaled by a jitter factor drawn
    uniformly from ``[1 - jitter, 1 + jitter]``; ``deadline_s`` bounds
    the total time budget (measured on the injected clock) — a retry
    whose sleep would overrun the deadline re-raises instead of sleeping.
    """
    max_attempts: int = 3
    base_s: float = 0.1
    factor: float = 2.0
    max_s: float = 30.0
    jitter: float = 0.5
    deadline_s: Optional[float] = None
    retryable: Tuple[Type[BaseException], ...] = (OSError,)

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError("jitter must be in [0, 1)")


def backoff_delays(policy: RetryPolicy, seed: int = 0) -> Iterator[float]:
    """The policy's infinite jittered delay sequence for ``seed``.

    Exposed for tests and for callers that pace their own loop (the
    supervisor's re-dispatch path sleeps inside its poll loop rather
    than blocking in :func:`retry_call`).
    """
    rng = np.random.default_rng(
        np.random.SeedSequence([abs(int(seed)), _JITTER_TAG]))
    k = 0
    while True:
        base = min(policy.max_s, policy.base_s * policy.factor ** k)
        scale = 1.0 + policy.jitter * (2.0 * rng.random() - 1.0)
        yield base * scale
        k += 1


def retry_call(fn: Callable[..., T], *args: Any,
               policy: RetryPolicy = RetryPolicy(),
               seed: int = 0,
               label: str = "call",
               on_retry: Optional[Callable[[int, float, BaseException],
                                           None]] = None,
               sleep: Callable[[float], None] = _time.sleep,
               clock: Callable[[], float] = _time.monotonic,
               **kwargs: Any) -> T:
    """Call ``fn(*args, **kwargs)``; retry retryable failures with
    deterministic jittered exponential backoff.

    * a **non-retryable** exception propagates immediately, untouched;
    * exhausting ``policy.max_attempts`` (or the deadline) re-raises the
      *last* retryable exception — callers keep seeing the original
      type, with the retry history in the obs counters/log;
    * ``sleep``/``clock`` are injectable so tests (and the supervisor's
      virtual pacing) never wait on the wall clock.
    """
    t0 = clock()
    delays = backoff_delays(policy, seed)
    for attempt in range(policy.max_attempts):
        try:
            return fn(*args, **kwargs)
        except policy.retryable as e:
            if attempt + 1 >= policy.max_attempts:
                raise
            delay = next(delays)
            if policy.deadline_s is not None and \
                    clock() - t0 + delay > policy.deadline_s:
                _obs.vlog("retry", f"{label}: deadline exhausted after "
                          f"{attempt + 1} attempt(s): {e}", level=2)
                raise
            _obs.metrics.counter("retry.attempts").inc()
            _obs.vlog("retry", f"{label}: attempt {attempt + 1}/"
                      f"{policy.max_attempts} failed ({e}); retrying in "
                      f"{delay:.3g}s", level=2)
            if on_retry is not None:
                on_retry(attempt, delay, e)
            sleep(delay)
    raise AssertionError("unreachable")  # loop always returns or raises
