"""Fault-tolerant sweep supervisor: dispatch, liveness, re-shard, merge.

The supervisor owns one sweep end to end:

1. **Screen once, ship the keep set.**  The two-stage screen (cheap
   T-Map pass) runs in the supervisor process with the exact keep rule
   the engine applies, then each shard child receives an *explicit*
   candidate-index list (``run_dse(..., indices=...)``) — stride-sharded
   children would each re-screen the full grid for nothing.  Per-task
   seeds derive from the global candidate index, so any partition of the
   keep set merges bit-identically.
2. **Liveness from checkpoint heartbeats.**  Children append ``_hb``
   lines to their shard checkpoints; the supervisor polls each file's
   progress signature ``(record count, last heartbeat payload)`` and
   tracks *its own monotonic receipt time* of the last change.  The
   heartbeat's wall-clock ``t`` is deliberately not trusted — a skewed
   or frozen remote clock must not look like death (or worse, mask it).
   A shard whose signature hasn't changed within ``hb_timeout`` seconds
   is declared dead.
3. **Re-shard the dead shard's remaining work.**  Remaining = candidates
   whose records the engine's own resume gate would not accept
   (:func:`repro.core.explore.remaining_candidate_indices`).  The
   replacement jobs land on live hosts and write **fresh** checkpoint
   files: a ShellCommandHost kill only reaches the local wrapper, so an
   unkillable remote zombie may keep appending to the old file — which
   is safe precisely because records are seed-gated and deterministic
   (duplicates merge last-wins to identical values; the merge's conflict
   detector would catch anything else).
4. **Merge with a fingerprint assertion.**  Every shard artifact — dead
   shards' partial files included — merges under the sweep fingerprint
   with ``on_conflict="error"``, then the merged file must leave zero
   remaining candidates.

Supervisor state is an append-only, fsync'd JSONL journal (``plan`` /
``launch`` / ``exit`` / ``retry`` / ``dead`` / ``reshard`` /
``shard_done`` / ``merged`` events): a killed supervisor resumes
mid-sweep with :meth:`Supervisor.resume` by replaying the journal,
recomputing what remains from the shard checkpoints on disk, and
dispatching only that.
"""

from __future__ import annotations

import json
import math
import os
import time
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from .. import obs as _obs
from ..core.dse import DSEConfig, grid_candidates, run_dse
from ..core.explore import (ExplorationEngine, merge_checkpoints,
                            remaining_candidate_indices, sweep_fingerprint)
from ..core.sa import SAConfig
from ..core.workload import Graph
from ..core.workloads import make_workload
from ..obs.report import parse_heartbeats
from .faults import FaultSpec, env_for, plan_faults
from .hosts import Handle, Host, LocalProcessHost


class SupervisorError(RuntimeError):
    """The sweep cannot make progress (hosts exhausted, merge refused,
    or the merged checkpoint is incomplete)."""


# ---------------------------------------------------------------------------
# SweepSpec — the JSON-serializable sweep description shipped to children
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SweepSpec:
    """Everything needed to rebuild the sweep in any process.

    The spec is deliberately plain JSON data — workload *spec strings*
    (``repro.core.workloads.make_workload`` grammar), the Table-I grid's
    ``grid_candidates`` kwargs, and ``DSEConfig``/``SAConfig`` kwarg
    overrides — so the supervisor journal, the shard children and a
    resuming supervisor all reconstruct the identical sweep (same
    fingerprint, same seeds) from one artifact.
    """
    workloads: Dict[str, str]             # name -> make_workload spec
    grid: Dict[str, Any]                  # grid_candidates kwargs
    sa: Dict[str, Any] = field(default_factory=dict)     # SAConfig kwargs
    cfg: Dict[str, Any] = field(default_factory=dict)    # DSEConfig kwargs
    n_shards: int = 2
    screen_keep: float = 1.0
    use_sa: bool = True

    def __post_init__(self):
        if not self.workloads:
            raise ValueError("spec needs at least one workload")
        if self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if isinstance(self.screen_keep, str):
            raise ValueError(
                "adaptive screening (screen_keep='auto') consumes SA "
                "results as they arrive and cannot be dispatched as an "
                "up-front keep set; supervised sweeps need a fixed "
                "fraction")
        if "sa" in self.cfg or "traffic" in self.cfg:
            raise ValueError("put SAConfig kwargs in spec.sa; traffic "
                             "models are not JSON-serializable")

    # -- builders ----------------------------------------------------------
    def build_workloads(self) -> Dict[str, Graph]:
        return {name: make_workload(s) for name, s in self.workloads.items()}

    def build_candidates(self) -> List[Any]:
        return grid_candidates(**self.grid)

    def build_cfg(self) -> DSEConfig:
        return DSEConfig(sa=SAConfig(**self.sa), **self.cfg)

    def fingerprint(self) -> str:
        return sweep_fingerprint(self.build_workloads(), self.build_cfg(),
                                 use_sa=self.use_sa)

    # -- (de)serialization -------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SweepSpec":
        return cls(**d)

    @classmethod
    def from_json(cls, text: str) -> "SweepSpec":
        return cls.from_dict(json.loads(text))


def quick_spec(seed: int = 3, n_shards: int = 2,
               screen_keep: float = 1.0) -> SweepSpec:
    """The CI-sized sweep (6 candidates x 1 workload, 40-iteration SA) —
    small enough that the whole chaos matrix runs in seconds."""
    return SweepSpec(
        workloads={"tf": "tf-quick"},
        grid=dict(tops=72.0, mac_options=[512, 1024], cut_options=[1, 2],
                  dram_per_tops=[2.0], noc_options=[16, 32],
                  d2d_ratio=[0.5], glb_options=[1024]),
        sa=dict(iters=40, seed=seed),
        cfg=dict(batch=8),
        n_shards=n_shards, screen_keep=screen_keep)


# ---------------------------------------------------------------------------
# ShardJob — one dispatched child
# ---------------------------------------------------------------------------

@dataclass
class ShardJob:
    """One launched shard child, as the supervisor tracks it."""
    shard_id: int
    attempt: int
    indices: List[int]
    checkpoint: Path
    host: Host
    fault: Optional[FaultSpec] = None
    dup: bool = False                       # duplicate-dispatch twin
    handle: Optional[Handle] = None
    launched_t: float = 0.0                 # monotonic, supervisor-local
    progress: Tuple[int, Optional[str]] = (0, None)
    progress_t: float = 0.0                 # monotonic receipt of last change
    state: str = "pending"      # pending|running|done|failed

    @property
    def label(self) -> str:
        tag = "d" if self.dup else "a"
        return f"s{self.shard_id}{tag}{self.attempt}"


# ---------------------------------------------------------------------------
# Supervisor
# ---------------------------------------------------------------------------

def _append_event(path: Path, event: Dict[str, Any]) -> None:
    """Durable append: one JSON line, flushed and fsync'd — the journal
    must survive the supervisor dying right after a state transition."""
    with path.open("a") as f:
        f.write(json.dumps(event, sort_keys=True) + "\n")
        f.flush()
        try:
            os.fsync(f.fileno())
        except OSError:
            pass


def read_state(state_path: Union[str, Path]) -> Dict[str, Any]:
    """Replay a supervisor journal into a summary dict (tolerant of a
    torn final line — the supervisor may have died mid-append)."""
    events: List[Dict[str, Any]] = []
    p = Path(state_path)
    if p.exists():
        lines = p.read_text().splitlines()
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError:
                if i == len(lines) - 1:
                    continue
                raise ValueError(f"corrupt journal line {i + 1} in {p}")
    plan = next((e for e in events if e["ev"] == "plan"), None)
    ckpts: List[str] = []
    for e in events:
        if e["ev"] == "launch" and e["checkpoint"] not in ckpts:
            ckpts.append(e["checkpoint"])
    merged = next((e for e in reversed(events) if e["ev"] == "merged"), None)
    return {"plan": plan, "checkpoints": ckpts, "merged": merged,
            "events": events}


class Supervisor:
    """Run one supervised sweep; see the module docstring for the
    protocol.  ``hosts`` defaults to a single :class:`LocalProcessHost`.

    ``fault_kind``/``fault_seed`` arm the deterministic chaos harness
    (:mod:`repro.dist.faults`): the seeded plan picks a victim
    first-generation shard and the supervisor ships the fault to that
    child's *first* attempt only, so recovery must succeed.
    """

    def __init__(self, spec: SweepSpec, out_dir: Union[str, Path],
                 hosts: Optional[Sequence[Host]] = None,
                 state_path: Union[str, Path, None] = None,
                 hb_timeout: float = 60.0, poll_s: float = 0.5,
                 max_attempts: int = 3, hb_every: float = 0.0,
                 fault_kind: Optional[str] = None, fault_seed: int = 0,
                 fault_k: Optional[int] = None):
        self.spec = spec
        self.out_dir = Path(out_dir)
        self.out_dir.mkdir(parents=True, exist_ok=True)
        self.hosts: List[Host] = list(hosts) if hosts else [LocalProcessHost()]
        self.state_path = Path(state_path) if state_path is not None \
            else self.out_dir / "supervisor_state.jsonl"
        self.hb_timeout = float(hb_timeout)
        self.poll_s = float(poll_s)
        self.max_attempts = int(max_attempts)
        self.hb_every = float(hb_every)
        self.faults: Dict[int, FaultSpec] = {}
        self.fault_kind = fault_kind
        if fault_kind is not None:
            self.faults = plan_faults(fault_seed, spec.n_shards, fault_kind,
                                      k=fault_k)
        self._dead_hosts: set = set()
        self._next_shard = spec.n_shards
        self._jobs: List[ShardJob] = []
        self._spec_path = self.out_dir / "spec.json"
        self.merged_path = self.out_dir / "merged.jsonl"
        # materialized once; identical in every process by construction
        self._candidates = spec.build_candidates()
        self._workloads = spec.build_workloads()
        self._cfg = spec.build_cfg()
        self.fingerprint = sweep_fingerprint(self._workloads, self._cfg,
                                             use_sa=spec.use_sa)

    # -- keep set (screen once) -------------------------------------------
    def _keep_set(self) -> List[int]:
        """The exact keep set an unsharded ``engine.run`` would screen to
        (same stable order, same epsilon-guarded ceil) — computed here
        once instead of once per shard."""
        n = len(self._candidates)
        if not (self.spec.use_sa and self.spec.screen_keep < 1.0 and n > 1):
            return list(range(n))
        with ExplorationEngine(self._workloads, self._cfg) as eng:
            indexed = list(enumerate(self._candidates))
            with _obs.span("supervisor.screen", n_candidates=n):
                pts = eng._reduce(indexed, eng._screen_tasks(indexed))
        order = sorted(range(n), key=lambda i: pts[i].objective)
        keep = max(1, min(n, math.ceil(self.spec.screen_keep * n - 1e-9)))
        return sorted(order[:keep])

    @staticmethod
    def _partition(keep: Sequence[int], n_shards: int) -> List[List[int]]:
        shards: List[List[int]] = [[] for _ in range(n_shards)]
        for i, ci in enumerate(keep):
            shards[i % n_shards].append(ci)
        return [s for s in shards if s]

    # -- events ------------------------------------------------------------
    def _event(self, ev: str, **fields: Any) -> None:
        _append_event(self.state_path, {"ev": ev, "t": time.time(),
                                        **fields})
        _obs.vlog("supervisor", f"{ev}: " + json.dumps(fields, default=str),
                  level=2)

    # -- dispatch ----------------------------------------------------------
    def _live_hosts(self) -> List[Host]:
        return [h for h in self.hosts if h.name not in self._dead_hosts]

    def _launch(self, job: ShardJob) -> None:
        env = {"REPRO_HB_EVERY": str(self.hb_every)}
        env.update(env_for(job.fault, job.attempt))
        argv = ["-m", "repro.dist.shard_child",
                "--spec", str(self._spec_path),
                "--indices", ",".join(map(str, job.indices)),
                "--checkpoint", str(job.checkpoint),
                "--shard-label", job.label]
        log = self.out_dir / f"{job.label}.log"
        job.handle = job.host.launch(argv, env, log_path=log)
        now = time.monotonic()
        job.launched_t = job.progress_t = now
        job.progress = parse_heartbeats_signature(job.checkpoint)
        job.state = "running"
        _obs.metrics.counter("supervisor.launches").inc()
        self._event("launch", shard=job.shard_id, attempt=job.attempt,
                    dup=job.dup, host=job.host.name,
                    checkpoint=str(job.checkpoint),
                    indices=job.indices,
                    fault=(job.fault.encode() if job.fault else None))
        self._jobs.append(job)

    def _new_job(self, shard_id: int, attempt: int, indices: List[int],
                 host: Host, fault: Optional[FaultSpec] = None,
                 dup: bool = False) -> ShardJob:
        tag = "d" if dup else "a"
        ckpt = self.out_dir / f"shard{shard_id}_{tag}{attempt}.jsonl"
        return ShardJob(shard_id=shard_id, attempt=attempt,
                        indices=list(indices), checkpoint=ckpt, host=host,
                        fault=fault, dup=dup)

    # -- failure handling --------------------------------------------------
    def _remaining(self, job: ShardJob) -> List[int]:
        return remaining_candidate_indices(
            self._candidates, self._workloads, self._cfg, job.checkpoint,
            use_sa=self.spec.use_sa, indices=job.indices)

    def _retry_or_reshard(self, job: ShardJob, remaining: List[int],
                          reason: str) -> None:
        if not remaining:
            # the crash landed after the last record (e.g. a corrupt-tail
            # fault appended its torn line post-completion): the work is
            # all on disk, nothing to redo
            job.state = "done"
            self._event("shard_done", shard=job.shard_id,
                        attempt=job.attempt, dup=job.dup, note=reason)
            return
        job.state = "failed"
        alive = job.host.name not in self._dead_hosts
        if alive and job.attempt + 1 < self.max_attempts:
            _obs.metrics.counter("supervisor.retries").inc()
            self._event("retry", shard=job.shard_id,
                        attempt=job.attempt + 1, remaining=remaining,
                        reason=reason)
            nxt = self._new_job(job.shard_id, job.attempt + 1, remaining,
                                job.host, fault=job.fault, dup=job.dup)
            self._launch(nxt)
            return
        if alive:
            self._mark_dead(job.host, f"shard {job.shard_id}: {reason}; "
                            "retries exhausted")
        self._reshard(remaining, origin=job.shard_id)

    def _mark_dead(self, host: Host, reason: str) -> None:
        if host.name in self._dead_hosts:
            return
        self._dead_hosts.add(host.name)
        _obs.metrics.counter("supervisor.deaths").inc()
        self._event("dead", host=host.name, reason=reason)
        # reap every other running job on the dead host: its work is
        # re-sharded the same way (poll loop sees state=="failed" no more)
        for other in self._jobs:
            if other.state == "running" and other.host is host:
                if other.handle is not None:
                    other.handle.kill()
                other.state = "failed"
                rem = self._remaining(other)
                if rem:
                    self._reshard(rem, origin=other.shard_id)

    def _reshard(self, indices: List[int], origin: int) -> None:
        if not indices:
            return
        live = self._live_hosts()
        if not live:
            raise SupervisorError(
                f"no live hosts left to re-shard {len(indices)} "
                f"candidate(s) from shard {origin}")
        parts = self._partition(indices, len(live))
        _obs.metrics.counter("supervisor.reshards").inc()
        self._event("reshard", origin=origin, remaining=indices,
                    n_new=len(parts))
        for part, host in zip(parts, live):
            job = self._new_job(self._next_shard, 0, part, host)
            self._next_shard += 1
            self._launch(job)

    # -- poll loop ---------------------------------------------------------
    def _poll_once(self) -> bool:
        """One pass over running jobs; True while any job still runs."""
        busy = False
        for job in list(self._jobs):
            if job.state != "running":
                continue
            rc = job.handle.poll() if job.handle is not None else 1
            if rc is not None:
                self._event("exit", shard=job.shard_id, attempt=job.attempt,
                            dup=job.dup, rc=rc)
                remaining = self._remaining(job)
                if rc == 0 and not remaining:
                    job.state = "done"
                    self._event("shard_done", shard=job.shard_id,
                                attempt=job.attempt, dup=job.dup)
                    continue
                self._retry_or_reshard(
                    job, remaining,
                    reason=(f"exit rc={rc}" if rc != 0
                            else "exit 0 with incomplete checkpoint"))
                busy = True
                continue
            busy = True
            sig = parse_heartbeats_signature(job.checkpoint)
            now = time.monotonic()
            if sig != job.progress:
                job.progress, job.progress_t = sig, now
            elif now - max(job.progress_t, job.launched_t) > self.hb_timeout:
                if job.handle is not None:
                    job.handle.kill()
                job.state = "failed"
                self._event("hb_timeout", shard=job.shard_id,
                            attempt=job.attempt,
                            silent_s=round(now - job.progress_t, 3))
                self._mark_dead(job.host,
                                f"shard {job.shard_id}: no heartbeat "
                                f"progress for {self.hb_timeout:g}s")
                rem = self._remaining(job)
                if rem:
                    self._reshard(rem, origin=job.shard_id)
        return busy

    # -- public entry points ----------------------------------------------
    def run(self) -> Path:
        """Screen, dispatch, supervise, merge; returns the merged path."""
        self._spec_path.write_text(self.spec.to_json() + "\n")
        keep = self._keep_set()
        parts = self._partition(keep, self.spec.n_shards)
        self._event("plan", fingerprint=self.fingerprint,
                    n_candidates=len(self._candidates), keep=keep,
                    shards=[list(p) for p in parts],
                    spec=self.spec.to_dict(),
                    fault_kind=self.fault_kind,
                    faults={str(k): v.encode()
                            for k, v in self.faults.items()})
        hosts = self._live_hosts()
        for sid, part in enumerate(parts):
            fault = self.faults.get(sid)
            dup = fault is not None and fault.kind == "dup"
            job = self._new_job(sid, 0, part, hosts[sid % len(hosts)],
                                fault=None if dup else fault)
            self._launch(job)
            if dup:
                # duplicate dispatch: the same indices race into a second
                # checkpoint on another host; last-wins merge + the
                # conflict detector prove both computed identical records
                twin_host = hosts[(sid + 1) % len(hosts)]
                self._launch(self._new_job(sid, 0, part, twin_host,
                                           dup=True))
        return self._supervise_and_merge(keep)

    def resume(self) -> Path:
        """Resume a killed supervisor from its journal: re-dispatch only
        the candidates no on-disk checkpoint completes, then merge every
        artifact (old attempts included)."""
        state = read_state(self.state_path)
        if state["plan"] is None:
            return self.run()
        if state["plan"]["fingerprint"] != self.fingerprint:
            raise SupervisorError(
                "journal belongs to a different sweep: fingerprint "
                f"{state['plan']['fingerprint']!r} != {self.fingerprint!r}")
        if not self._spec_path.exists():
            self._spec_path.write_text(self.spec.to_json() + "\n")
        keep = list(state["plan"]["keep"])
        done: set = set()
        old_ckpts: List[Path] = []
        for c in state["checkpoints"]:
            p = Path(c)
            old_ckpts.append(p)
            if p.exists():
                rem = set(remaining_candidate_indices(
                    self._candidates, self._workloads, self._cfg, p,
                    use_sa=self.spec.use_sa, indices=keep))
                done |= set(keep) - rem
        remaining = [ci for ci in keep if ci not in done]
        self._next_shard = max(
            [self.spec.n_shards] + [e["shard"] + 1 for e in state["events"]
                                    if e["ev"] == "launch"])
        self._event("resume", remaining=remaining,
                    prior_checkpoints=[str(p) for p in old_ckpts])
        self._prior_ckpts = old_ckpts
        if remaining:
            live = self._live_hosts()
            for part, host in zip(self._partition(remaining, len(live)),
                                  live):
                job = self._new_job(self._next_shard, 0, part, host)
                self._next_shard += 1
                self._launch(job)
        return self._supervise_and_merge(keep)

    def _supervise_and_merge(self, keep: List[int]) -> Path:
        while self._poll_once():
            time.sleep(self.poll_s)
        # merge EVERY artifact ever written (prior runs, dead shards'
        # partials, duplicate twins): records are seed-gated so overlap
        # is harmless, and partial files may hold work nothing else has
        ckpts = list(getattr(self, "_prior_ckpts", []))
        for job in self._jobs:
            if job.checkpoint not in ckpts:
                ckpts.append(job.checkpoint)
        ckpts = [p for p in ckpts if Path(p).exists()]
        if not ckpts:
            raise SupervisorError("nothing to merge: no shard checkpoint "
                                  "was ever written")
        report = merge_checkpoints(ckpts, out=self.merged_path,
                                   expect_fingerprint=self.fingerprint,
                                   verbose=False, on_conflict="error")
        left = remaining_candidate_indices(
            self._candidates, self._workloads, self._cfg, self.merged_path,
            use_sa=self.spec.use_sa, indices=keep)
        if left:
            raise SupervisorError(
                f"merged checkpoint incomplete: {len(left)} candidate(s) "
                f"missing ({left[:8]}{'...' if len(left) > 8 else ''})")
        self._event("merged", out=str(self.merged_path),
                    n_records=report.n_records,
                    shards=[str(p) for p in ckpts],
                    skipped=[[str(p), why] for p, why in report.skipped])
        return self.merged_path

    # -- results -----------------------------------------------------------
    def results(self) -> List[Any]:
        """The sweep's DSEPoints, reconstructed from the merged
        checkpoint through the engine's own resume path — bit-identical
        to a failure-free unsharded run by the seed-gate contract."""
        return supervised_results(self.spec, self.merged_path)


def supervised_results(spec: SweepSpec,
                       merged: Union[str, Path]) -> List[Any]:
    """Load a supervised sweep's results by resuming the engine from the
    merged checkpoint (every task is recorded, so nothing recomputes)."""
    return run_dse(spec.build_candidates(), spec.build_workloads(),
                   spec.build_cfg(), use_sa=spec.use_sa,
                   screen_keep=spec.screen_keep, checkpoint=merged)


def parse_heartbeats_signature(path: Union[str, Path]
                               ) -> Tuple[int, Optional[str]]:
    """A shard checkpoint's progress signature: (record count, last
    heartbeat JSON).  Any change — new record, new heartbeat — counts as
    liveness; the supervisor timestamps changes on ITS monotonic clock."""
    n, hb = parse_heartbeats(path)
    return n, (json.dumps(hb, sort_keys=True) if hb else None)
