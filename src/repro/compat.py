"""Version bridges for the pinned toolchain.

The repo is developed against the newer jax surface (top-level
``jax.shard_map`` taking ``check_vma=``) but must run on the baked-in
jax 0.4.x, which only ships ``jax.experimental.shard_map.shard_map``
taking ``check_rep=``.  ``shard_map`` below accepts either spelling and
dispatches to whatever the installed jax provides;
``install_jax_compat`` aliases it onto the ``jax`` module so third-party
code (and test subprocesses) doing ``from jax import shard_map`` keeps
working.  ``src/sitecustomize.py`` calls the installer lazily the first
time jax is imported in any process launched with ``PYTHONPATH=src``.
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Tuple


def _resolve_native() -> Tuple[Callable, bool]:
    """Return (native shard_map, is_new_api)."""
    import jax

    native = jax.__dict__.get("shard_map")
    if native is not None and native is not shard_map:
        return native, True
    from jax.experimental.shard_map import shard_map as native  # type: ignore

    return native, False


def shard_map(f: Callable, *, mesh: Any, in_specs: Any, out_specs: Any,
              check_vma: Optional[bool] = None,
              check_rep: Optional[bool] = None,
              **kwargs: Any) -> Callable:
    """``jax.shard_map`` that accepts both ``check_vma`` and ``check_rep``."""
    native, is_new = _resolve_native()
    flag = check_vma if check_vma is not None else check_rep
    if is_new:
        if flag is not None:
            kwargs["check_vma"] = flag
    else:
        if flag is not None:
            kwargs["check_rep"] = flag
    return native(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  **kwargs)


def install_jax_compat(jax_module: Any = None) -> None:
    """Alias :func:`shard_map` onto the ``jax`` module when it lacks one."""
    if jax_module is None:
        import jax as jax_module  # type: ignore
    if getattr(jax_module, "shard_map", None) is None:
        jax_module.shard_map = shard_map
