from .base import (SHAPES, ModelConfig, ShapeConfig, all_archs, cells_for,
                   get_config, register)

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "get_config", "register",
           "all_archs", "cells_for"]
