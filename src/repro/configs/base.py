"""Model/run configuration: one ``ModelConfig`` covers all ten assigned
architectures (dense / moe / ssm / hybrid / encdec) plus reduced smoke
variants.  Shapes (the four assigned input-shape cells) live here too.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "swiglu"          # swiglu | gelu
    tie_embeddings: bool = True
    # ssm / hybrid
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_chunk: int = 256
    attn_every: int = 0          # hybrid: shared attn+mlp block period
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_dispatch_groups: int = 16  # group-local dispatch (nn.moe); 0 = flat
                                   # (flat = the naive scatter baseline)
    # modality frontend (STUB: input_specs provides embeddings)
    frontend: str = "none"       # none | patch | audio
    n_enc_layers: int = 0        # encdec only
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    source: str = ""             # provenance note

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab dim shards on
        any mesh axis (standard MaxText-style padding).  Labels stay < vocab;
        padded rows just participate in the softmax."""
        return -(-self.vocab // 256) * 256

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_decode(self) -> bool:
        """long_500k runs only for O(1)-state decode families."""
        return self.family in ("ssm", "hybrid")

    def n_shared_attn(self) -> int:
        if self.family != "hybrid" or not self.attn_every:
            return 0
        return -(-self.n_layers // self.attn_every)

    def param_count(self) -> int:
        """Analytic parameter count (matches init_params; used for 6ND)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        hd = self.hd
        emb = V * d * (1 if self.tie_embeddings else 2)
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv * hd) \
            + (self.n_heads * hd) * d
        mlp = 3 * d * self.d_ff if self.act == "swiglu" else 2 * d * self.d_ff
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            H = d_in // self.ssm_headdim
            gn = self.ssm_groups * self.ssm_state
            blk = d * (2 * d_in + 2 * gn + H) + d_in * d \
                + 4 * (d_in + 2 * gn) + 3 * H + d_in
            return emb + L * (blk + d)
        if self.family == "hybrid":
            d_in = self.ssm_expand * d
            H = d_in // self.ssm_headdim
            gn = self.ssm_groups * self.ssm_state
            blk = d * (2 * d_in + 2 * gn + H) + d_in * d \
                + 4 * (d_in + 2 * gn) + 3 * H + d_in
            return emb + L * (blk + d) + (attn + mlp + 3 * d)
        if self.family == "moe":
            expert = 3 * d * self.d_ff
            return emb + L * (attn + self.n_experts * expert
                              + d * self.n_experts + 2 * d)
        if self.family == "encdec":
            enc = self.n_enc_layers * (attn + mlp + 2 * d)
            dec = L * (2 * attn + mlp + 3 * d)
            return emb + enc + dec
        return emb + L * (attn + mlp + 2 * d)

    def active_param_count(self) -> int:
        """MoE: params touched per token (for 6*N_active*D)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        hd = self.hd
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv * hd) \
            + (self.n_heads * hd) * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        expert = 3 * d * self.d_ff
        return emb + L * (attn + self.top_k * expert
                          + d * self.n_experts + 2 * d)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw: Dict = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2 if self.family != "hybrid" else 4),
            d_model=128,
            n_heads=max(2, min(self.n_heads, 4)),
            n_kv=1 if self.n_kv == 1 else 2,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            head_dim=32 if self.head_dim else None,
            n_enc_layers=min(self.n_enc_layers, 2),
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_chunk=32,
            attn_every=2 if self.attn_every else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            remat=False,
        )
        return dataclasses.replace(self, **kw)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


_REGISTRY: Dict[str, "ModelConfig"] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        from . import archs  # noqa: F401  (populates the registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_archs() -> Tuple[str, ...]:
    if not _REGISTRY:
        from . import archs  # noqa: F401
    return tuple(sorted(_REGISTRY))


def cells_for(cfg: ModelConfig) -> Tuple[str, ...]:
    """The assigned (arch x shape) cells that are defined for this arch."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_decode:
        out.append("long_500k")
    return tuple(out)
