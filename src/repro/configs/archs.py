"""The ten assigned architectures, exact configs from the assignment table.

Each is importable as ``repro.configs.get_config("<id>")`` and selectable in
launchers via ``--arch <id>``.  Sources are annotated per entry.
"""

from .base import ModelConfig, register

mamba2_370m = register(ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=32, n_kv=32, d_ff=0, vocab=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2,
    source="arXiv:2405.21060 (SSD); attn-free"))

llava_next_34b = register(ModelConfig(
    name="llava-next-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv=8, d_ff=20480, vocab=64000,
    frontend="patch", tie_embeddings=False,
    source="hf:llava-hf/llava-v1.6 (anyres tiling frontend stubbed)"))

zamba2_1p2b = register(ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv=32, d_ff=8192, vocab=32000,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, attn_every=6,
    source="arXiv:2411.15242; Mamba2 trunk + shared attn/mlp blocks"))

qwen15_110b = register(ModelConfig(
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv=8, d_ff=49152, vocab=152064,
    qkv_bias=True, tie_embeddings=False,
    source="hf:Qwen/Qwen1.5 series; QKV bias"))

smollm_135m = register(ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv=3, d_ff=1536, vocab=49152,
    source="hf:HuggingFaceTB/SmolLM-135M; llama-arch small"))

qwen3_0p6b = register(ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv=8, d_ff=3072, vocab=151936,
    qk_norm=True, head_dim=128,
    source="hf:Qwen/Qwen3; qk_norm + GQA"))

qwen3_32b = register(ModelConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv=8, d_ff=25600, vocab=151936,
    qk_norm=True, head_dim=128, tie_embeddings=False,
    source="hf:Qwen/Qwen3; qk_norm + GQA"))

phi35_moe = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=6400, vocab=32064,
    n_experts=16, top_k=2, tie_embeddings=False,
    source="hf:microsoft/Phi-3.5-MoE-instruct; 16e top-2"))

granite_moe = register(ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv=8, d_ff=512, vocab=49155,
    n_experts=40, top_k=8,
    source="hf:ibm-granite/granite-3.0 series; 40e top-8"))

whisper_small = register(ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv=12,
    d_ff=3072, vocab=51865, norm="layernorm", act="gelu", frontend="audio",
    tie_embeddings=True,
    source="arXiv:2212.04356; conv frontend stubbed (frame embeddings)"))

# the paper's own default workload (Vaswani'17 base Transformer), selectable
# like the assigned archs so the Gemini-mapped pipeline demos run on it too
paper_transformer = register(ModelConfig(
    name="paper-transformer", family="dense",
    n_layers=6, d_model=512, n_heads=8, n_kv=8, d_ff=2048, vocab=37000,
    norm="layernorm", act="gelu",
    source="arXiv:1706.03762; the paper's Sec. VI-A default DSE workload"))

ALL = [mamba2_370m, llava_next_34b, zamba2_1p2b, qwen15_110b, smollm_135m,
       qwen3_0p6b, qwen3_32b, phi35_moe, granite_moe, whisper_small,
       paper_transformer]
