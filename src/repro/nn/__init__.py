"""Functional NN substrate: params-as-pytrees, logical sharding axes."""

from .attention import (attention_block, init_attention, init_kv_cache,
                        kv_cache_axes, multihead_attention)
from .layers import (embed, gelu, init_embedding, init_layernorm, init_linear,
                     init_rmsnorm, layernorm, linear, rmsnorm,
                     softmax_cross_entropy, swiglu, unembed)
from .mamba2 import (init_mamba2, init_ssm_cache, mamba2_block, ssd_chunked,
                     ssd_decode_step, ssm_cache_axes)
from .moe import init_moe, moe_block
from .params import (ShardingRules, count_params, default_rules, param_bytes,
                     shard_constraint, tree_shape_structs, tree_sharding,
                     tree_spec)
from .rope import apply_rope
