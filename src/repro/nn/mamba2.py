"""Mamba-2 (SSD — state-space duality) block in pure JAX.

Chunked SSD algorithm (Dao & Gu 2024): within a chunk the dual quadratic
form computes token mixing; across chunks a small (H, N, P) state is carried
by an associative recurrence (lax.scan).  Decode is the O(1) recurrent
update.  The per-chunk quadratic form is the compute hot-spot and has a
Pallas twin in ``repro.kernels.mamba_ssd`` (validated in interpret mode).

Shapes: x (B, L, H, P) heads x headdim; B/C (B, L, G, N) groups x state;
dt (B, L, H); A (H,) negative reals.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import init_linear, init_rmsnorm, linear, rmsnorm
from .params import Pytree


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, *, chunk: int = 256,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,L,H,P), final_state (B,H,N,P))."""
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // chunk

    f32 = jnp.float32
    xb = (x * dt[..., None]).astype(f32)                   # discretized input
    dA = dt.astype(f32) * A.astype(f32)                    # (B, Lp, H), <= 0
    xc = xb.reshape(Bsz, nc, chunk, H, P)
    dAc = dA.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, G, N).astype(f32)
    Cc = Cm.reshape(Bsz, nc, chunk, G, N).astype(f32)

    cum = jnp.cumsum(dAc, axis=2)                          # (B,nc,Q,H)
    tot = cum[:, :, -1]                                    # (B,nc,H)

    # ---- intra-chunk (dual quadratic form) --------------------------------
    # Lmat[b,c,h,i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Qi,Qj,H)
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    # mask BEFORE the exp: non-causal entries have diff > 0 (cum is
    # decreasing) and exp(diff) overflows to inf, which the where() would
    # hide in the forward pass but turns 0*inf into NaN in the VJP
    diff = jnp.where(causal, diff, -jnp.inf)
    Lmat = jnp.exp(diff)                                   # (B,nc,Qi,Qj,H)
    # scores[b,c,i,j,g] = C_i . B_j
    scores = jnp.einsum("bcign,bcjgn->bcijg", Cc, Bc)
    scores = jnp.repeat(scores, rep, axis=-1)              # -> (B,nc,Qi,Qj,H)
    y_intra = jnp.einsum("bcijh,bcijh,bcjhp->bcihp", scores, Lmat, xc)

    # ---- chunk states ------------------------------------------------------
    decay_to_end = jnp.exp(tot[:, :, None, :] - cum)       # (B,nc,Q,H)
    Bh = jnp.repeat(Bc, rep, axis=3)                       # (B,nc,Q,H,N)
    S = jnp.einsum("bcqhn,bcqh,bcqhp->bchnp", Bh, decay_to_end, xc)

    # ---- inter-chunk recurrence -------------------------------------------
    s0 = (jnp.zeros((Bsz, H, N, P), f32) if init_state is None
          else init_state.astype(f32))

    def step(h, inp):
        tot_c, S_c = inp                                   # (B,H), (B,H,N,P)
        h_next = h * jnp.exp(tot_c)[..., None, None] + S_c
        return h_next, h                                   # emit state BEFORE chunk

    (h_final, h_before) = jax.lax.scan(
        step, s0, (tot.transpose(1, 0, 2), S.transpose(1, 0, 2, 3, 4)))
    h_before = h_before.transpose(1, 0, 2, 3, 4)           # (B,nc,H,N,P)

    Ch = jnp.repeat(Cc, rep, axis=3)                       # (B,nc,Q,H,N)
    y_inter = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp", Ch, h_before,
                         jnp.exp(cum))
    y = (y_intra + y_inter).reshape(Bsz, Lp, H, P)[:, :L]
    return y.astype(x.dtype), h_final


def ssd_decode_step(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                    Cm: jax.Array, state: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """One-token recurrent update.  x (B,H,P), dt (B,H), Bm/Cm (B,G,N),
    state (B,H,N,P)."""
    H = x.shape[1]
    G = Bm.shape[1]
    rep = H // G
    f32 = jnp.float32
    dA = jnp.exp(dt.astype(f32) * A.astype(f32))           # (B,H)
    Bh = jnp.repeat(Bm.astype(f32), rep, axis=1)           # (B,H,N)
    Ch = jnp.repeat(Cm.astype(f32), rep, axis=1)
    xb = (x * dt[..., None]).astype(f32)                   # (B,H,P)
    new_state = state * dA[..., None, None] \
        + Bh[..., None] * xb[:, :, None, :]                # (B,H,N,P)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Full Mamba-2 block
# ---------------------------------------------------------------------------

def init_mamba2(key: jax.Array, d_model: int, *, d_state: int = 128,
                headdim: int = 64, expand: int = 2, n_groups: int = 1,
                d_conv: int = 4, dtype=jnp.float32) -> Tuple[Pytree, Pytree]:
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    conv_ch = d_inner + 2 * n_groups * d_state
    ks = jax.random.split(key, 5)
    d_in_proj = 2 * d_inner + 2 * n_groups * d_state + n_heads
    p: Dict = {}
    a: Dict = {}
    p["in_proj"], a["in_proj"] = init_linear(ks[0], d_model, d_in_proj,
                                             out_axis="mlp", dtype=dtype)
    p["conv_w"] = (jax.random.normal(ks[1], (d_conv, conv_ch), jnp.float32)
                   * (1.0 / d_conv ** 0.5)).astype(dtype)
    a["conv_w"] = ("conv", "mlp")
    p["conv_b"] = jnp.zeros((conv_ch,), dtype=dtype)
    a["conv_b"] = ("mlp",)
    p["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(dtype)
    a["A_log"] = (None,)
    p["D"] = jnp.ones((n_heads,), dtype=dtype)
    a["D"] = (None,)
    p["dt_bias"] = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(ks[2], (n_heads,), jnp.float32,
                                   jnp.log(1e-3), jnp.log(1e-1))))).astype(dtype)
    a["dt_bias"] = (None,)
    p["norm"], a["norm"] = init_rmsnorm(d_inner, dtype=dtype, axis="mlp")
    p["out_proj"], a["out_proj"] = init_linear(ks[3], d_inner, d_model,
                                               in_axis="mlp", out_axis="embed",
                                               dtype=dtype)
    return p, a


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 prev: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.  x (B,L,C), w (K,C).  Returns (y, tail)."""
    K = w.shape[0]
    ctx = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype) \
        if prev is None else prev.astype(x.dtype)
    xp = jnp.concatenate([ctx, x], axis=1)                 # (B, L+K-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K))
    tail = xp[:, -(K - 1):] if K > 1 else xp[:, :0]
    return jax.nn.silu(y + b[None, None]), tail


def mamba2_block(p: Pytree, x: jax.Array, *, d_state: int, headdim: int = 64,
                 expand: int = 2, n_groups: int = 1, d_conv: int = 4,
                 chunk: int = 256,
                 cache: Optional[Dict[str, jax.Array]] = None,
                 update_cache: bool = False,
                 compute_dtype=jnp.bfloat16
                 ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: (B, S, d_model).  Cache: {"conv": (B,K-1,Cc), "state": (B,H,N,P)}."""
    B, S, d = x.shape
    d_inner = expand * d
    H = d_inner // headdim
    GN = n_groups * d_state

    zxbcdt = linear(p["in_proj"], x, compute_dtype)
    z, xin, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + GN, 2 * d_inner + 2 * GN],
        axis=-1)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_prev = cache["conv"] if cache is not None else None
    conv_out, conv_tail = _causal_conv(conv_in, p["conv_w"].astype(compute_dtype),
                                       p["conv_b"].astype(compute_dtype),
                                       conv_prev)
    xin, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + GN], axis=-1)
    xh = xin.reshape(B, S, H, headdim)
    Bm = Bm.reshape(B, S, n_groups, d_state)
    Cm = Cm.reshape(B, S, n_groups, d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    new_cache = None
    if cache is not None and S == 1:
        y1, new_state = ssd_decode_step(xh[:, 0], dt[:, 0], A, Bm[:, 0],
                                        Cm[:, 0], cache["state"])
        y = y1[:, None]
    else:
        init_state = cache["state"] if cache is not None else None
        y, new_state = ssd_chunked(xh, dt, A, Bm, Cm, chunk=chunk,
                                   init_state=init_state)
    if update_cache:
        new_cache = {"conv": conv_tail.astype(jnp.bfloat16),
                     "state": new_state.astype(jnp.float32)}

    y = y + xh * p["D"].astype(compute_dtype)[None, None, :, None]
    y = y.reshape(B, S, d_inner) * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y)
    return linear(p["out_proj"], y, compute_dtype), new_cache


def init_ssm_cache(batch: int, d_model: int, *, d_state: int,
                   headdim: int = 64, expand: int = 2, n_groups: int = 1,
                   d_conv: int = 4) -> Dict[str, jax.Array]:
    d_inner = expand * d_model
    H = d_inner // headdim
    conv_ch = d_inner + 2 * n_groups * d_state
    return {"conv": jnp.zeros((batch, d_conv - 1, conv_ch), jnp.bfloat16),
            "state": jnp.zeros((batch, H, d_state, headdim), jnp.float32)}


def ssm_cache_axes() -> Dict[str, Tuple]:
    return {"conv": ("batch", None, "mlp"),
            "state": ("batch", "heads", None, None)}
