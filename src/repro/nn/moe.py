"""Mixture-of-Experts with capacity-based scatter dispatch (GSPMD-friendly).

Top-k routing -> position-in-expert via cumulative sums -> scatter tokens
into an (E, C, d) buffer -> batched expert SwiGLU -> combine with router
weights.  Tokens beyond capacity are dropped (weights renormalized), the
standard capacity-factor scheme.  Expert weights are stacked on a leading
``experts`` axis; the dispatch buffer shards tokens on ``batch`` and the
expert FFN hidden dim on ``mlp`` so any expert count works on any mesh.
Router runs in fp32 with an auxiliary load-balancing loss (Switch-style).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import init_linear, swiglu
from .params import Pytree


def init_moe(key: jax.Array, d_model: int, d_ff: int, n_experts: int,
             dtype=jnp.float32) -> Tuple[Pytree, Pytree]:
    ks = jax.random.split(key, 4)
    s_in = 1.0 / (d_model ** 0.5)
    s_out = 1.0 / (d_ff ** 0.5)

    def stack(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)

    p = {
        "router": init_linear(ks[0], d_model, n_experts, out_axis=None,
                              dtype=jnp.float32)[0],
        "w_gate": stack(ks[1], (n_experts, d_model, d_ff), s_in),
        "w_up": stack(ks[2], (n_experts, d_model, d_ff), s_in),
        "w_down": stack(ks[3], (n_experts, d_ff, d_model), s_out),
    }
    a = {
        "router": {"w": ("embed", None)},
        "w_gate": ("experts", "embed", "expert_mlp"),
        "w_up": ("experts", "embed", "expert_mlp"),
        "w_down": ("experts", "expert_mlp", "embed"),
    }
    return p, a


def moe_block(p: Pytree, x: jax.Array, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25, dispatch_groups: int = 0,
              rules=None, compute_dtype=jnp.bfloat16
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    ``dispatch_groups > 1`` switches to group-local dispatch: tokens are
    split into G groups aligned with the data shards, each group fills its
    OWN (E, C/G, d) buffer slice (local cumsums, local scatter).  Under
    GSPMD this removes the all-reduce of the whole dispatch buffer across
    the data axis that the flat scatter requires — the dominant collective
    for many-expert models (EXPERIMENTS.md §Perf, granite-moe).  Capacity
    is per group, so drop behavior matches a data-parallel Switch/GShard
    deployment.
    """
    B, S, d = x.shape
    T = B * S
    G = dispatch_groups if dispatch_groups and T % dispatch_groups == 0 \
        and (T // dispatch_groups) >= top_k else 0
    if G > 1:
        return _moe_grouped(p, x, n_experts=n_experts, top_k=top_k,
                            capacity_factor=capacity_factor, groups=G,
                            rules=rules, compute_dtype=compute_dtype)
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"]["w"])       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)          # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    capacity = max(1, int(capacity_factor * top_k * T / n_experts))

    # position of each (token, slot) within its expert, priority by slot then
    # token order (Switch Transformer scheme)
    onehot = jax.nn.one_hot(gate_idx, n_experts, dtype=jnp.int32)  # (T,k,E)
    slot_rank = jnp.cumsum(onehot.reshape(T * top_k, n_experts), axis=0) \
        .reshape(T, top_k, n_experts) - 1
    pos = (slot_rank * onehot).sum(-1)                         # (T, k)
    expert = gate_idx                                          # (T, k)
    keep = pos < capacity
    gate_vals = gate_vals * keep

    # scatter tokens into (E, C, d)
    buf = jnp.zeros((n_experts, capacity, d), dtype=compute_dtype)
    flat_e = expert.reshape(-1)
    flat_p = jnp.where(keep, pos, capacity).reshape(-1)        # OOB drops
    tok_rep = jnp.repeat(jnp.arange(T), top_k)
    buf = buf.at[flat_e, flat_p].set(
        xt[tok_rep].astype(compute_dtype), mode="drop")

    # batched expert SwiGLU: (E, C, d) x (E, d, f)
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(compute_dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(compute_dtype))
    h = swiglu(g, u)
    eo = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(compute_dtype))

    # gather back and combine
    out = (eo[flat_e, jnp.minimum(flat_p, capacity - 1)]      # (T*k, d)
           * gate_vals.reshape(-1, 1).astype(compute_dtype))
    out = jax.ops.segment_sum(out, tok_rep, num_segments=T)

    # Switch aux loss: E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean((onehot.sum(axis=1)).astype(jnp.float32), axis=0)
    aux = n_experts * jnp.sum(me * ce)
    return out.reshape(B, S, d).astype(x.dtype), aux


def _moe_grouped(p: Pytree, x: jax.Array, *, n_experts: int, top_k: int,
                 capacity_factor: float, groups: int, rules,
                 compute_dtype) -> Tuple[jax.Array, jax.Array]:
    """Group-local capacity dispatch (see moe_block docstring)."""
    from .params import shard_constraint
    B, S, d = x.shape
    T = B * S
    Tl = T // groups
    xt = x.reshape(groups, Tl, d)
    if rules is not None:
        xt = shard_constraint(xt, rules, ("batch", None, "embed"))

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)       # (G, Tl, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    capacity = max(1, int(capacity_factor * top_k * Tl / n_experts))
    onehot = jax.nn.one_hot(gate_idx, n_experts, dtype=jnp.int32)
    slot_rank = jnp.cumsum(
        onehot.reshape(groups, Tl * top_k, n_experts), axis=1) \
        .reshape(groups, Tl, top_k, n_experts) - 1          # group-LOCAL
    pos = (slot_rank * onehot).sum(-1)                      # (G, Tl, k)
    keep = pos < capacity
    gate_vals = gate_vals * keep

    def dispatch_one(xg, eg, pg):
        buf = jnp.zeros((n_experts, capacity, d), dtype=compute_dtype)
        tok_rep = jnp.repeat(jnp.arange(Tl), top_k)
        return buf.at[eg.reshape(-1), pg.reshape(-1)].set(
            xg[tok_rep].astype(compute_dtype), mode="drop")

    flat_p = jnp.where(keep, pos, capacity)
    buf = jax.vmap(dispatch_one)(xt, gate_idx, flat_p)      # (G, E, C, d)
    if rules is not None:
        buf = shard_constraint(buf, rules,
                               ("batch", "experts", None, "embed"))

    g = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(compute_dtype))
    u = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(compute_dtype))
    h = swiglu(g, u)
    eo = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(compute_dtype))

    def combine_one(eog, eg, pg, gv):
        tok_rep = jnp.repeat(jnp.arange(Tl), top_k)
        out = eog[eg.reshape(-1), jnp.minimum(pg.reshape(-1), capacity - 1)] \
            * gv.reshape(-1, 1).astype(compute_dtype)
        return jax.ops.segment_sum(out, tok_rep, num_segments=Tl)

    out = jax.vmap(combine_one)(eo, gate_idx, flat_p, gate_vals)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(onehot.sum(axis=2).astype(jnp.float32), axis=(0, 1))
    aux = n_experts * jnp.sum(me * ce)
    return out.reshape(B, S, d).astype(x.dtype), aux
