"""Core functional layers: linear, norms, embedding.

Convention: ``init_x(key, ...) -> (params, axes)`` where ``axes`` mirrors the
params pytree with logical axis tuples (see params.py).  Apply functions are
pure; compute happens in ``cfg.compute_dtype`` while params are stored in
``cfg.param_dtype``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .params import Axes, Pytree


def init_linear(key: jax.Array, d_in: int, d_out: int, *, bias: bool = False,
                in_axis: Optional[str] = "embed", out_axis: Optional[str] = "mlp",
                dtype=jnp.float32, scale: Optional[float] = None
                ) -> Tuple[Pytree, Pytree]:
    scale = (1.0 / (d_in ** 0.5)) if scale is None else scale
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
    p = {"w": w.astype(dtype)}
    a = {"w": (in_axis, out_axis)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
        a["b"] = (out_axis,)
    return p, a


def linear(p: Pytree, x: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    y = x.astype(compute_dtype) @ p["w"].astype(compute_dtype)
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


def init_rmsnorm(d: int, dtype=jnp.float32,
                 axis: Optional[str] = "embed") -> Tuple[Pytree, Pytree]:
    return {"scale": jnp.ones((d,), dtype=dtype)}, {"scale": (axis,)}


def rmsnorm(p: Pytree, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype=jnp.float32,
                   axis: Optional[str] = "embed") -> Tuple[Pytree, Pytree]:
    p = {"scale": jnp.ones((d,), dtype=dtype),
         "bias": jnp.zeros((d,), dtype=dtype)}
    return p, {"scale": (axis,), "bias": (axis,)}


def layernorm(p: Pytree, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


def init_embedding(key: jax.Array, vocab: int, d: int,
                   dtype=jnp.float32) -> Tuple[Pytree, Pytree]:
    e = jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02
    return {"embedding": e.astype(dtype)}, {"embedding": ("vocab", "embed")}


def embed(p: Pytree, tokens: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    return p["embedding"].astype(compute_dtype)[tokens]


def unembed(p: Pytree, x: jax.Array,
            compute_dtype=jnp.bfloat16) -> jax.Array:
    """Tied logits: (..., d) @ (vocab, d)^T -> (..., vocab), fp32 logits."""
    return jnp.einsum("...d,vd->...v", x.astype(compute_dtype),
                      p["embedding"].astype(compute_dtype)
                      ).astype(jnp.float32)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token NLL; logits (..., V) fp32, labels int (...)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
