"""Parameter pytrees with logical sharding axes (no flax).

Every ``init_*`` function returns a pytree of ``jnp`` arrays; a parallel
pytree of *logical axis tuples* describes how each array dim shards.  Logical
axes resolve to mesh axes through ``ShardingRules`` — swap the rules, not the
model, to change the parallelism layout (this is how §Perf hillclimbing
iterates shardings).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any
Axes = Tuple[Optional[str], ...]


# Default logical->mesh rules.  None = replicated dim.
# Parameters are 2-D sharded: FSDP over "data" (the `embed` axis) x TP over
# "model" (heads / mlp / vocab) — the MaxText-style default.  GSPMD inserts
# the FSDP all-gathers; they show up in the roofline collective term.
DEFAULT_RULES: Dict[str, Union[None, str, Tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "embed": "data",            # d_model dim of weights -> FSDP shard
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",             # FFN hidden
    "experts": "model",
    "expert_mlp": None,
    "seq": None,
    "kv_seq": "model",          # decode KV-cache sequence dim
    "layers": None,             # stacked-scan leading dim
    "conv": None,
    "state": None,
    "stage": None,
    # attention activation layout (derived per arch x mesh in launch/steps):
    #   act_kv='model'  when (repeated) head count divides the model axis,
    #   act_seq='model' (context parallel) otherwise.
    "act_seq": None,
    "act_kv": "model",
    "act_kv_seq": None,         # decode: KV-cache seq dim inside attention
    "act_group": None,
}


@dataclass(frozen=True)
class ShardingRules:
    rules: Mapping[str, Union[None, str, Tuple[str, ...]]]
    repeat_kv: bool = False     # materialize GQA kv->H heads in attention
                                # (Megatron-style TP trick; transient only)

    def spec(self, axes: Axes, mesh: Optional[Mesh] = None) -> P:
        out = []
        used: set = set()
        for a in axes:
            if a is None:
                out.append(None)
                continue
            m = self.rules.get(a)
            if m is None:
                out.append(None)
                continue
            names = (m,) if isinstance(m, str) else tuple(m)
            if mesh is not None:
                names = tuple(n for n in names if n in mesh.axis_names)
            names = tuple(n for n in names if n not in used)
            used.update(names)
            if not names:
                out.append(None)
            elif len(names) == 1:
                out.append(names[0])
            else:
                out.append(names)
        return P(*out)

    def replace_rules(self, **kw) -> "ShardingRules":
        d = dict(self.rules)
        repeat = kw.pop("repeat_kv", self.repeat_kv)
        d.update(kw)
        return ShardingRules(d, repeat_kv=repeat)


def default_rules(**overrides) -> ShardingRules:
    d = dict(DEFAULT_RULES)
    repeat = overrides.pop("repeat_kv", False)
    d.update(overrides)
    return ShardingRules(d, repeat_kv=repeat)


def tree_spec(axes_tree: Pytree, rules: ShardingRules,
              mesh: Optional[Mesh] = None) -> Pytree:
    """Logical-axes pytree -> PartitionSpec pytree."""
    return jax.tree.map(
        lambda axes: rules.spec(axes, mesh),
        axes_tree, is_leaf=lambda x: isinstance(x, tuple) and
        all(isinstance(e, (str, type(None))) for e in x))


def tree_sharding(axes_tree: Pytree, rules: ShardingRules,
                  mesh: Mesh) -> Pytree:
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                        tree_spec(axes_tree, rules, mesh),
                        is_leaf=lambda x: isinstance(x, P))


def shard_constraint(x: jax.Array, rules: ShardingRules, axes: Axes,
                     mesh: Optional[Mesh] = None) -> jax.Array:
    """with_sharding_constraint via logical axes (no-op outside jit/mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, rules.spec(axes, mesh))
    except (ValueError, RuntimeError):
        return x


def count_params(tree: Pytree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def param_bytes(tree: Pytree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(tree))


def tree_shape_structs(tree: Pytree) -> Pytree:
    """Array pytree -> ShapeDtypeStruct pytree (for .lower without data)."""
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def abstract_init(init_fn: Callable[..., Pytree], *args, **kw) -> Pytree:
    """Evaluate an init function abstractly (no memory) -> ShapeDtypeStructs."""
    return jax.eval_shape(init_fn, *args, **kw)
