"""GQA attention with rope, qk-norm, KV cache, and a flash-style
memory-efficient jnp path (online softmax over KV blocks).

The jnp block-scan path is the mathematical twin of the Pallas kernel in
``repro.kernels.flash_attention`` and is what the 512-device dry-run lowers
(Pallas TPU kernels cannot compile on the CPU backend); the Pallas kernel is
validated against it in interpret mode.  GQA never materializes repeated KV
heads: queries are reshaped to (KV, G) groups instead.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import init_linear, linear, rmsnorm
from .params import Pytree
from .rope import apply_rope

NEG_INF = -2.0e38


def init_attention(key: jax.Array, d_model: int, n_heads: int, n_kv: int,
                   head_dim: Optional[int] = None, *, qkv_bias: bool = False,
                   qk_norm: bool = False, dtype=jnp.float32
                   ) -> Tuple[Pytree, Pytree]:
    hd = head_dim or d_model // n_heads
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    a: Dict[str, Any] = {}
    p["wq"], a["wq"] = init_linear(ks[0], d_model, n_heads * hd,
                                   bias=qkv_bias, out_axis="heads", dtype=dtype)
    p["wk"], a["wk"] = init_linear(ks[1], d_model, n_kv * hd,
                                   bias=qkv_bias, out_axis="heads", dtype=dtype)
    p["wv"], a["wv"] = init_linear(ks[2], d_model, n_kv * hd,
                                   bias=qkv_bias, out_axis="heads", dtype=dtype)
    p["wo"], a["wo"] = init_linear(ks[3], n_heads * hd, d_model,
                                   in_axis="heads", out_axis="embed", dtype=dtype)
    if qk_norm:
        p["q_norm"] = {"scale": jnp.ones((hd,), dtype=dtype)}
        p["k_norm"] = {"scale": jnp.ones((hd,), dtype=dtype)}
        a["q_norm"] = {"scale": ("head_dim",)}
        a["k_norm"] = {"scale": ("head_dim",)}
    return p, a


# ---------------------------------------------------------------------------
# Attention math
# ---------------------------------------------------------------------------

def _gqa_scores_path(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: Optional[jax.Array], scale: float
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Plain einsum attention (small seqs / decode).  q:(B,Sq,KV,G,D).
    Returns (out, running-max m, denominator l), both (B,KV,G,Sq)."""
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v) \
        / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return out, m, l


def merge_attention(o1: jax.Array, m1: jax.Array, l1: jax.Array,
                    o2: jax.Array, m2: jax.Array, l2: jax.Array
                    ) -> jax.Array:
    """Online-softmax merge of two partial attentions over disjoint KV sets.

    o: (B,Sq,H,D); m/l: (B,H,Sq) [flattened (KV,G)].  Lets decode attend
    the old cache pages and the new segment separately, so the stacked
    cache buffer is WRITE-ONLY within a scan iteration (no copy insertion).
    """
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m) * l1
    a2 = jnp.exp(m2 - m) * l2
    denom = jnp.maximum(a1 + a2, 1e-30)
    w1 = (a1 / denom).transpose(0, 2, 1)[..., None]       # (B,Sq,H,1)
    w2 = (a2 / denom).transpose(0, 2, 1)[..., None]
    return o1 * w1.astype(o1.dtype) + o2 * w2.astype(o2.dtype)


def _flash_path(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
                q_offset: jax.Array, kv_len: Optional[jax.Array],
                scale: float, block: int) -> jax.Array:
    """Online-softmax scan over KV blocks; never materializes (Sq, Sk)."""
    B, Sq, KV, G, D = q.shape
    Sk = k.shape[1]
    n_blocks = -(-Sk // block)
    pad = n_blocks * block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, n_blocks, block, KV, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blocks, block, KV, D).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(Sq)                    # (Sq,)

    def step(carry, inputs):
        m, l, acc = carry
        bi, kblk, vblk = inputs
        s = jnp.einsum("bqkgd,bskd->bkgqs", q, kblk).astype(jnp.float32) * scale
        kv_pos = bi * block + jnp.arange(block)          # (block,)
        msk = jnp.ones((Sq, block), dtype=bool)
        if causal:
            msk &= q_pos[:, None] >= kv_pos[None, :]
        if kv_len is not None:
            msk &= kv_pos[None, :] < kv_len
        msk &= kv_pos[None, :] < Sk                      # padding
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(vblk.dtype), vblk).astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), dtype=jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, D), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(n_blocks), kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype), m, l  # (B,Sq,KV,G,D)


def multihead_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        n_kv: int, causal: bool = True,
                        q_offset: jax.Array | int = 0,
                        kv_len: Optional[jax.Array] = None,
                        block: int = 1024,
                        force_flash: Optional[bool] = None,
                        rules=None, return_stats: bool = False):
    """q: (B,Sq,H,D); k,v: (B,Sk,KV,D).  Returns (B,Sq,H,D).

    With ``rules.repeat_kv`` the GQA groups are materialized to full heads
    (Megatron TP layout — transient tensors only, the KV cache stays GQA) so
    the head dim shards when n_kv doesn't divide the model axis.  Activation
    sharding constraints use the ``act_seq`` / ``act_kv`` rules.
    """
    from .params import shard_constraint
    B, Sq, H, D = q.shape
    if rules is not None and rules.repeat_kv and n_kv != H:
        k = jnp.repeat(k, H // n_kv, axis=2)
        v = jnp.repeat(v, H // n_kv, axis=2)
        n_kv = H
    Sk = k.shape[1]
    G = H // n_kv
    qg = q.reshape(B, Sq, n_kv, G, D)
    if rules is not None:
        qg = shard_constraint(qg, rules,
                              ("batch", "act_seq", "act_kv", "act_group", None))
        k = shard_constraint(k, rules, ("batch", "act_kv_seq", "act_kv", None))
        v = shard_constraint(v, rules, ("batch", "act_kv_seq", "act_kv", None))
    scale = 1.0 / (D ** 0.5)
    use_flash = (Sq * Sk > 256 * 2048) if force_flash is None else force_flash
    if use_flash and Sq > 1:
        out, m, l = _flash_path(qg, k, v, causal=causal,
                                q_offset=jnp.asarray(q_offset), kv_len=kv_len,
                                scale=scale, block=block)
    else:
        q_pos = jnp.asarray(q_offset) + jnp.arange(Sq)
        kv_pos = jnp.arange(Sk)
        msk = jnp.ones((Sq, Sk), dtype=bool)
        if causal:
            msk &= q_pos[:, None] >= kv_pos[None, :]
        if kv_len is not None:
            msk &= kv_pos[None, :] < kv_len
        out, m, l = _gqa_scores_path(qg, k, v, msk[None, None, None], scale)
    out = out.reshape(B, Sq, H, D)
    if return_stats:
        return out, m.reshape(B, H, Sq), l.reshape(B, H, Sq)
    return out


# ---------------------------------------------------------------------------
# Full block: project -> rope -> attend -> out-project, with KV cache
# ---------------------------------------------------------------------------

def attention_block(p: Pytree, x: jax.Array, *, n_heads: int, n_kv: int,
                    head_dim: Optional[int] = None, positions: jax.Array,
                    cache: Optional[Dict[str, jax.Array]] = None,
                    cache_stack: Optional[Tuple] = None,
                    update_cache: bool = False,
                    rope_theta: float = 10000.0,
                    qk_norm_eps: float = 1e-6,
                    causal: bool = True,
                    compute_dtype=jnp.bfloat16,
                    block: int = 1024,
                    rules=None
                    ) -> Tuple[jax.Array, Optional[Any]]:
    """x: (B, S, d).

    Two cache modes:
      * ``cache``       — per-layer dict {"k","v","pos"}; the segment is
        appended into a copy (legacy path, used by tests/small models).
      * ``cache_stack`` — ``(k_stack, v_stack, layer_idx, pos)`` where the
        stacks are (L, B, S_max, KV, D) scan-carry buffers.  The new
        segment is written straight into the stacked buffer (one
        token/segment-sized dynamic-update-slice — NOT a whole-layer-cache
        round trip), then the layer's page is read for attention.  This is
        the decode-bandwidth fix measured in EXPERIMENTS.md §Perf.
    """
    B, S, d = x.shape
    hd = head_dim or d // n_heads
    q = linear(p["wq"], x, compute_dtype).reshape(B, S, n_heads, hd)
    k = linear(p["wk"], x, compute_dtype).reshape(B, S, n_kv, hd)
    v = linear(p["wv"], x, compute_dtype).reshape(B, S, n_kv, hd)
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q, qk_norm_eps)
        k = rmsnorm(p["k_norm"], k, qk_norm_eps)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)

    new_cache = None
    if cache_stack is not None:
        # Attend the OLD cache pages and the new segment separately, then
        # merge with online-softmax stats.  The stacked buffer is read
        # (old content) before its only write, so XLA keeps it in place —
        # no whole-buffer copy per scan iteration (§Perf iteration 3).
        k_stack, v_stack, li, pos = cache_stack
        ck = jax.lax.dynamic_index_in_dim(k_stack, li, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(v_stack, li, 0, keepdims=False)
        o_old, m_old, l_old = multihead_attention(
            q, ck.astype(compute_dtype), cv.astype(compute_dtype),
            n_kv=n_kv, causal=False, kv_len=pos, block=block, rules=rules,
            return_stats=True)
        o_new, m_new, l_new = multihead_attention(
            q, k, v, n_kv=n_kv, causal=causal, block=block, rules=rules,
            return_stats=True)
        out = merge_attention(o_old, m_old, l_old, o_new, m_new, l_new)
        k_stack = jax.lax.dynamic_update_slice(
            k_stack, k[None].astype(k_stack.dtype), (li, 0, pos, 0, 0))
        v_stack = jax.lax.dynamic_update_slice(
            v_stack, v[None].astype(v_stack.dtype), (li, 0, pos, 0, 0))
        new_cache = (k_stack, v_stack)
    elif cache is not None:
        idx = cache["pos"]                                 # scalar int32
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, idx, 0, 0))
        kv_len = idx + S
        out = multihead_attention(q, ck.astype(compute_dtype),
                                  cv.astype(compute_dtype), n_kv=n_kv,
                                  causal=causal, q_offset=idx, kv_len=kv_len,
                                  block=block, rules=rules)
        if update_cache:
            new_cache = {"k": ck, "v": cv, "pos": idx + S}
    else:
        out = multihead_attention(q, k, v, n_kv=n_kv, causal=causal,
                                  block=block, rules=rules)
    y = linear(p["wo"], out.reshape(B, S, n_heads * hd), compute_dtype)
    return y, new_cache


def init_kv_cache(batch: int, max_seq: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    return {"k": jnp.zeros((batch, max_seq, n_kv, head_dim), dtype=dtype),
            "v": jnp.zeros((batch, max_seq, n_kv, head_dim), dtype=dtype),
            "pos": jnp.zeros((), dtype=jnp.int32)}


def kv_cache_axes() -> Dict[str, Any]:
    return {"k": ("batch", "kv_seq", "kv_heads", "head_dim"),
            "v": ("batch", "kv_seq", "kv_heads", "head_dim"),
            "pos": ()}
