"""Vendored pure-Python stand-ins for optional third-party packages.

Served by the fallback import finder in ``src/sitecustomize.py`` only when
the real package is not installed (see ``minihypothesis.py``).
"""
