"""Minimal, dependency-free stand-in for the ``hypothesis`` API we use.

The container image cannot install packages, but the property-test suite
must collect and run.  This module implements the subset of hypothesis
used by ``tests/test_property_hypothesis.py`` — ``given``, ``settings``,
``HealthCheck`` and the ``integers`` / ``floats`` / ``tuples`` / ``lists``
/ ``sampled_from`` strategies — as a real property-test runner: every test
executes ``max_examples`` times against deterministic pseudo-random draws
(seeded per test so failures reproduce), with the first two examples
pinned to the all-minimal and all-maximal corners of the strategy space.

It is only served when the real package is missing:
``src/sitecustomize.py`` registers a fallback import finder that maps
``import hypothesis`` to this file *after* the normal import machinery
fails to find an installed hypothesis.  ``requirements.txt`` still
declares the real dependency; environments that install it never see this
shim.  No shrinking, no database, no health checks — a falsifying example
is reported as-is.
"""

from __future__ import annotations

import enum
import zlib
from typing import Any, Callable, List, Optional, Sequence, Tuple

__version__ = "0.mini"


class HealthCheck(enum.Enum):
    data_too_large = 1
    filter_too_much = 2
    too_slow = 3
    return_value = 5
    large_base_example = 7
    not_a_test_method = 8

    @classmethod
    def all(cls) -> List["HealthCheck"]:
        return list(cls)


class UnsatisfiedAssumption(Exception):
    pass


class _HypothesisHandle:
    """What pytest's hypothesis integration expects at ``test.hypothesis``."""

    def __init__(self, inner_test: Callable):
        self.inner_test = inner_test


def assume(condition: Any) -> bool:
    """Abort the current example (not the test) when ``condition`` is falsy."""
    if not condition:
        raise UnsatisfiedAssumption()
    return True


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------

class _Rng:
    """Tiny deterministic PRNG (xorshift64*); avoids importing numpy here."""

    def __init__(self, seed: int):
        self._s = (seed or 1) & 0xFFFFFFFFFFFFFFFF

    def next_u64(self) -> int:
        x = self._s
        x ^= (x >> 12) & 0xFFFFFFFFFFFFFFFF
        x = (x ^ (x << 25)) & 0xFFFFFFFFFFFFFFFF
        x ^= x >> 27
        self._s = x
        return (x * 0x2545F4914F6CDD1D) & 0xFFFFFFFFFFFFFFFF

    def randint(self, lo: int, hi: int) -> int:
        if hi <= lo:
            return lo
        return lo + self.next_u64() % (hi - lo + 1)

    def uniform(self, lo: float, hi: float) -> float:
        return lo + (self.next_u64() / 2.0 ** 64) * (hi - lo)


class SearchStrategy:
    def draw(self, rng: _Rng) -> Any:
        raise NotImplementedError

    def minimal(self) -> Any:
        raise NotImplementedError

    def maximal(self) -> Any:
        raise NotImplementedError


class _Integers(SearchStrategy):
    def __init__(self, min_value: int, max_value: int):
        self.min_value, self.max_value = int(min_value), int(max_value)

    def draw(self, rng: _Rng) -> int:
        return rng.randint(self.min_value, self.max_value)

    def minimal(self) -> int:
        return self.min_value

    def maximal(self) -> int:
        return self.max_value


class _Floats(SearchStrategy):
    def __init__(self, min_value: float, max_value: float):
        self.min_value, self.max_value = float(min_value), float(max_value)

    def draw(self, rng: _Rng) -> float:
        return rng.uniform(self.min_value, self.max_value)

    def minimal(self) -> float:
        return self.min_value

    def maximal(self) -> float:
        return self.max_value


class _Tuples(SearchStrategy):
    def __init__(self, elems: Tuple[SearchStrategy, ...]):
        self.elems = elems

    def draw(self, rng: _Rng) -> Tuple:
        return tuple(s.draw(rng) for s in self.elems)

    def minimal(self) -> Tuple:
        return tuple(s.minimal() for s in self.elems)

    def maximal(self) -> Tuple:
        return tuple(s.maximal() for s in self.elems)


class _Lists(SearchStrategy):
    def __init__(self, elem: SearchStrategy, min_size: int, max_size: int):
        self.elem = elem
        self.min_size, self.max_size = min_size, max_size

    def draw(self, rng: _Rng) -> List:
        n = rng.randint(self.min_size, self.max_size)
        return [self.elem.draw(rng) for _ in range(n)]

    def minimal(self) -> List:
        return [self.elem.minimal() for _ in range(self.min_size)]

    def maximal(self) -> List:
        return [self.elem.maximal() for _ in range(self.max_size)]


class _SampledFrom(SearchStrategy):
    def __init__(self, elements: Sequence):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from requires a non-empty sequence")

    def draw(self, rng: _Rng):
        return self.elements[rng.randint(0, len(self.elements) - 1)]

    def minimal(self):
        return self.elements[0]

    def maximal(self):
        return self.elements[-1]


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (``st.`` in tests)."""

    @staticmethod
    def integers(min_value: int = 0, max_value: int = 2 ** 31 - 1) -> _Integers:
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0) -> _Floats:
        return _Floats(min_value, max_value)

    @staticmethod
    def tuples(*elems: SearchStrategy) -> _Tuples:
        return _Tuples(elems)

    @staticmethod
    def lists(elem: SearchStrategy, min_size: int = 0,
              max_size: int = 10) -> _Lists:
        return _Lists(elem, min_size, max_size)

    @staticmethod
    def sampled_from(elements: Sequence) -> _SampledFrom:
        return _SampledFrom(elements)


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

class settings:
    """Usable both as ``settings(...)`` decorator and global default."""

    def __init__(self, max_examples: int = 100, deadline: Optional[Any] = None,
                 suppress_health_check: Sequence[HealthCheck] = (),
                 **_ignored: Any):
        self.max_examples = max_examples
        self.deadline = deadline
        self.suppress_health_check = list(suppress_health_check)

    def __call__(self, fn: Callable) -> Callable:
        fn._mh_settings = self  # read by the given() wrapper at call time
        return fn


def given(*arg_strategies: SearchStrategy, **kw_strategies: SearchStrategy):
    if arg_strategies:
        raise TypeError("mini-hypothesis supports keyword strategies only")

    def decorate(fn: Callable) -> Callable:
        def wrapper(*outer_args, **outer_kwargs):
            cfg: settings = getattr(wrapper, "_mh_settings", None) or settings()
            seed = zlib.crc32(
                f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = _Rng(seed)
            names = sorted(kw_strategies)
            for ex in range(max(1, cfg.max_examples)):
                if ex == 0:
                    drawn = {n: kw_strategies[n].minimal() for n in names}
                elif ex == 1:
                    drawn = {n: kw_strategies[n].maximal() for n in names}
                else:
                    drawn = {n: kw_strategies[n].draw(rng) for n in names}
                try:
                    fn(*outer_args, **dict(outer_kwargs, **drawn))
                except UnsatisfiedAssumption:
                    continue
                except Exception as err:
                    raise AssertionError(
                        f"falsifying example ({ex + 1}/{cfg.max_examples}): "
                        f"{fn.__qualname__}({drawn!r})") from err
            return None

        # pytest must not see the strategy parameters as fixtures, so no
        # functools.wraps (it sets __wrapped__, which exposes the original
        # signature); copy identity attributes by hand instead
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        wrapper.hypothesis = _HypothesisHandle(fn)
        return wrapper

    return decorate
