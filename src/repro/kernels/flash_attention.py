"""Pallas TPU flash attention: blocked online-softmax, MXU-aligned tiles.

Grid (B, H, nq, nk); the kv dim is the innermost ("arbitrary") grid axis so
the f32 accumulator/max/denominator live in VMEM scratch across kv steps and
the output tile is written once on the last step.  BlockSpecs keep one
(bq, d) query tile + one (bk, d) kv tile resident — the VMEM working set is
bq*d + 2*bk*d + bq*bk floats, tuned so bq=bk=512, d<=256 stays well under
VMEM while the (bq, bk) matmuls are 128-aligned for the MXU.

This is the TPU adaptation of the paper's intra-core dataflow search: the
BlockSpec tile choice plays exactly the role of the chosen NVDLA tiling.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, bq: int, bk: int, nk: int,
                  seq_k: int):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq,bk)

    i = pl.program_id(2)
    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < seq_k
    if causal:
        mask &= q_pos >= k_pos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]                             # (bq,)
    l_prev = l_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])                  # (bq, bk)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] \
        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new[:, None]
    l_ref[...] = l_new[:, None]

    @pl.when(j == nk - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_mha(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, bq: int = 512, bk: int = 512,
                        interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, H, Sk, D) — MHA layout (GQA is expanded
    by ops.flash_attention).  Returns (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    nq = -(-Sq // bq)
    nk = -(-Sk // bk)
    pad_q = nq * bq - Sq
    pad_k = nk * bk - Sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk, nk=nk, seq_k=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, D), q.dtype),
        scratch_shapes=[
            pl_scratch((bq, D)),        # f32 accumulator
            pl_scratch((bq, 1)),        # running max
            pl_scratch((bq, 1)),        # running denominator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]


def pl_scratch(shape):
    """VMEM f32 scratch allocation (portable across pallas versions)."""
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, jnp.float32)
    except Exception:  # pragma: no cover - older pallas
        return pl.VMEM(shape, jnp.float32)
