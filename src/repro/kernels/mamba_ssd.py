"""Pallas TPU kernel for the Mamba-2 SSD per-chunk quadratic form.

One grid step processes one (batch, chunk) cell: it computes the intra-chunk
dual attention ``y_intra = ((C B^T) .* L) X`` and the chunk state
``S = (B .* decay)^T X`` in a single VMEM residency of the chunk tensors.
The O(chunk^2) decay matrix L never leaves VMEM — that is the kernel's whole
point (the HBM-streamed version would move Q*Q*H floats per chunk).

The inter-chunk recurrence (tiny (H, N, P) state) stays in jnp/lax.scan in
ops.py — it is O(L/Q) sequential steps and bandwidth-trivial.  n_groups == 1
(our configs); grouped B/C would add a leading G index to the same layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .flash_attention import pl_scratch


def _ssd_kernel(x_ref, cum_ref, b_ref, c_ref, y_ref, state_ref):
    # blocks: x (1, Q, H, P); cum (1, Q, H); b/c (1, Q, N)
    x = x_ref[0].astype(jnp.float32)               # (Q, H, P)
    cum = cum_ref[0].astype(jnp.float32)           # (Q, H)
    B = b_ref[0].astype(jnp.float32)               # (Q, N)
    C = c_ref[0].astype(jnp.float32)               # (Q, N)
    Q = x.shape[0]

    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))  # (Qi, Qj)
    diff = cum[:, None, :] - cum[None, :, :]       # (Qi, Qj, H)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where((ii >= jj)[..., None], jnp.exp(diff), 0.0)  # (Qi, Qj, H)
    y = jnp.einsum("ij,ijh,jhp->ihp", scores, L, x,
                   preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    decay_end = jnp.exp(cum[-1, :][None, :] - cum)  # (Q, H)
    state = jnp.einsum("jn,jh,jhp->hnp", B, decay_end, x,
                       preferred_element_type=jnp.float32)
    state_ref[0] = state.astype(state_ref.dtype)


def ssd_chunk_dual(x: jax.Array, cum: jax.Array, Bm: jax.Array,
                   Cm: jax.Array, *, interpret: bool = False
                   ) -> tuple[jax.Array, jax.Array]:
    """Per-chunk SSD quadratic form.

    x (BC, Q, H, P) discretized inputs per flattened (batch*chunk);
    cum (BC, Q, H) cumulative log-decay within the chunk;
    Bm/Cm (BC, Q, N) input/output projections (n_groups=1).
    Returns (y_intra (BC, Q, H, P), chunk_state (BC, H, N, P)).
    """
    BC, Q, H, P = x.shape
    N = Bm.shape[-1]
    out = pl.pallas_call(
        _ssd_kernel,
        grid=(BC,),
        in_specs=[
            pl.BlockSpec((1, Q, H, P), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, Q, H), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, H, P), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, H, N, P), lambda i: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BC, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((BC, H, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(x, cum, Bm, Cm)
    return out[0], out[1]
