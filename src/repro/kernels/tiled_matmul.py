"""Pallas TPU tiled GEMM — the paper's PE-array (NVDLA) analogue.

Grid (m/bm, n/bn, k/bk) with the contraction axis innermost; a f32 VMEM
accumulator persists across k steps (output-stationary dataflow — the same
loop-order/tiling decision the paper's intra-core engine searches, here
fixed to the TPU-optimal choice: 128-aligned MXU tiles, psum in VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .flash_attention import pl_scratch


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())))

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def tiled_matmul(a: jax.Array, b: jax.Array, *, bm: int = 256, bn: int = 256,
                 bk: int = 512, out_dtype=None,
                 interpret: bool = False) -> jax.Array:
    """a (M, K) @ b (K, N) -> (M, N) with explicit VMEM tiling."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    nm, nn, nk = -(-M // bm), -(-N // bn), -(-K // bk)
    pm, pn, pk = nm * bm - M, nn * bn - N, nk * bk - K
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    out_dtype = out_dtype or a.dtype
    out = pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nm * bm, nn * bn), out_dtype),
        scratch_shapes=[pl_scratch((bm, bn))],
        interpret=interpret,
    )(a, b)
    return out[:M, :N]
