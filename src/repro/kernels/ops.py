"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to auto: real TPU lowering on TPU backends, Pallas
interpret mode elsewhere (this CPU container).  GQA inputs are expanded to
MHA layout here so the kernels stay MXU-simple.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_mha
from .mamba_ssd import ssd_chunk_dual
from .tiled_matmul import tiled_matmul


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "n_kv", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    n_kv: Optional[int] = None, causal: bool = True,
                    bq: int = 512, bk: int = 512,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q (B, Sq, H, D); k/v (B, Sk, KV, D) -> (B, Sq, H, D).

    GQA (KV < H) is expanded to MHA by repeating kv heads — transient only,
    mirrors nn.attention's repeat_kv TP layout."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    out = flash_attention_mha(qh, kh, vh, causal=causal, bq=bq, bk=bk,
                              interpret=_auto_interpret(interpret))
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_forward(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, *, chunk: int = 128,
                interpret: Optional[bool] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Full chunked SSD using the Pallas per-chunk kernel + jnp recurrence.

    Same contract as nn.mamba2.ssd_chunked with n_groups=1:
    x (B,L,H,P), dt (B,L,H), A (H,), Bm/Cm (B,L,1,N)."""
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // chunk
    xb = (x * dt[..., None]).astype(jnp.float32)
    dA = dt.astype(jnp.float32) * A.astype(jnp.float32)
    cum = jnp.cumsum(dA.reshape(Bsz, nc, chunk, H), axis=2)

    flat = lambda t, s: t.reshape((Bsz * nc,) + s)
    y_intra, S = ssd_chunk_dual(
        flat(xb.reshape(Bsz, nc, chunk, H, P), (chunk, H, P)),
        flat(cum, (chunk, H)),
        flat(Bm.reshape(Bsz, nc, chunk, N), (chunk, N)),
        flat(Cm.reshape(Bsz, nc, chunk, N), (chunk, N)),
        interpret=_auto_interpret(interpret))
    y_intra = y_intra.reshape(Bsz, nc, chunk, H, P)
    S = S.reshape(Bsz, nc, H, N, P)

    tot = cum[:, :, -1]                                  # (B, nc, H)

    def step(h, inp):
        tot_c, S_c = inp
        return h * jnp.exp(tot_c)[..., None, None] + S_c, h

    h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    _, h_before = jax.lax.scan(step, h0,
                               (tot.transpose(1, 0, 2),
                                S.transpose(1, 0, 2, 3, 4)))
    h_before = h_before.transpose(1, 0, 2, 3, 4)         # (B,nc,H,N,P)
    Cc = Cm.reshape(Bsz, nc, chunk, N).astype(jnp.float32)
    y_inter = jnp.einsum("bcqn,bchnp,bcqh->bcqhp", Cc, h_before,
                         jnp.exp(cum))
    y = (y_intra + y_inter).reshape(Bsz, Lp, H, P)[:, :L]
    return y.astype(x.dtype), None


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(a: jax.Array, b: jax.Array, *, bm: int = 256, bn: int = 256,
           bk: int = 512, interpret: Optional[bool] = None) -> jax.Array:
    return tiled_matmul(a, b, bm=bm, bn=bn, bk=bk,
                        interpret=_auto_interpret(interpret))
