"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """Naive softmax attention.  q/k/v: (B, H, S, D), MHA layout."""
    D = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    if causal:
        Sq, Sk = s.shape[-2], s.shape[-1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_chunk_ref(x: jax.Array, cum: jax.Array, Bm: jax.Array,
                  Cm: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels.mamba_ssd.ssd_chunk_dual (all f32 math).

    x (BC,Q,H,P); cum (BC,Q,H); Bm/Cm (BC,Q,N)."""
    xf = x.astype(jnp.float32)
    cumf = cum.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    Q = x.shape[1]
    scores = jnp.einsum("cin,cjn->cij", Cf, Bf)
    diff = cumf[:, :, None, :] - cumf[:, None, :, :]
    ii = jnp.arange(Q)
    L = jnp.where((ii[:, None] >= ii[None, :])[None, :, :, None],
                  jnp.exp(diff), 0.0)
    y = jnp.einsum("cij,cijh,cjhp->cihp", scores, L, xf)
    decay_end = jnp.exp(cumf[:, -1:, :] - cumf)
    state = jnp.einsum("cjn,cjh,cjhp->chnp", Bf, decay_end, xf)
    return y, state


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return (a.astype(jnp.float32) @ b.astype(jnp.float32)).astype(out_dtype)
