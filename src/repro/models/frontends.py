"""Modality frontend STUBS (per assignment: [vlm]/[audio] entries specify the
transformer backbone only; input_specs() provides precomputed frame/patch
embeddings).  These helpers exist so examples can fabricate deterministic
embeddings shaped like a real frontend's output."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fake_patch_embeddings(key: jax.Array, batch: int, seq: int,
                          d_model: int) -> jax.Array:
    """Stands in for the LLaVA-NeXT anyres vision tower + projector."""
    return jax.random.normal(key, (batch, seq, d_model), jnp.float32) * 0.02


def fake_audio_frames(key: jax.Array, batch: int, frames: int,
                      d_model: int) -> jax.Array:
    """Stands in for whisper's log-mel + conv1d stem (stride-2 conv)."""
    return jax.random.normal(key, (batch, frames, d_model), jnp.float32) * 0.02
