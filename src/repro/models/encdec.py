"""Encoder-decoder model (whisper-small backbone).

Encoder: pre-LN transformer over precomputed frame embeddings (the conv
frontend is a STUB per the assignment — ``input_specs()`` supplies frame
embeddings directly).  Decoder: self-attention (causal, KV-cached) +
cross-attention to the final encoder states + GELU MLP.  Sinusoidal absolute
positions are added to both streams (adaptation from whisper's
learned/sinusoidal split, noted in DESIGN.md); layers are scan-stacked like
the decoder-only models.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..nn.attention import (attention_block, init_attention, init_kv_cache,
                            kv_cache_axes, multihead_attention)
from ..nn.layers import (embed, init_embedding, init_layernorm, init_linear,
                         layernorm, linear, softmax_cross_entropy, unembed)
from ..nn.params import (Pytree, ShardingRules, default_rules,
                         shard_constraint)
from .lm import _dtype, apply_mlp, init_mlp

Params = Pytree
Cache = Dict[str, Any]


def sinusoidal(seq: int, d: int, offset: jax.Array | int = 0) -> jax.Array:
    pos = offset + jnp.arange(seq)[:, None].astype(jnp.float32)
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def _init_enc_block(key, cfg: ModelConfig) -> Tuple[Pytree, Pytree]:
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 2)
    p, a = {}, {}
    p["norm1"], a["norm1"] = init_layernorm(cfg.d_model, dtype=dt)
    p["attn"], a["attn"] = init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                          cfg.n_kv, cfg.hd, dtype=dt)
    p["norm2"], a["norm2"] = init_layernorm(cfg.d_model, dtype=dt)
    p["mlp"], a["mlp"] = init_mlp(ks[1], cfg, dt)
    return p, a


def _init_dec_block(key, cfg: ModelConfig) -> Tuple[Pytree, Pytree]:
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    p["norm1"], a["norm1"] = init_layernorm(cfg.d_model, dtype=dt)
    p["self_attn"], a["self_attn"] = init_attention(
        ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd, dtype=dt)
    p["norm_x"], a["norm_x"] = init_layernorm(cfg.d_model, dtype=dt)
    p["cross_attn"], a["cross_attn"] = init_attention(
        ks[1], cfg.d_model, cfg.n_heads, cfg.n_heads, cfg.hd, dtype=dt)
    p["norm2"], a["norm2"] = init_layernorm(cfg.d_model, dtype=dt)
    p["mlp"], a["mlp"] = init_mlp(ks[2], cfg, dt)
    return p, a


def init_params(cfg: ModelConfig, key: jax.Array) -> Tuple[Params, Pytree]:
    dt = _dtype(cfg.param_dtype)
    k_emb, k_enc, k_dec = jax.random.split(key, 3)
    p: Dict[str, Any] = {}
    a: Dict[str, Any] = {}
    p["embed"], a["embed"] = init_embedding(k_emb, cfg.padded_vocab,
                                            cfg.d_model, dtype=dt)
    ek = jax.random.split(k_enc, cfg.n_enc_layers)
    p["enc_blocks"] = jax.vmap(lambda k: _init_enc_block(k, cfg)[0])(ek)
    _, ea = _init_enc_block(ek[0], cfg.reduced())
    a["enc_blocks"] = _stack_axes(ea)
    dk = jax.random.split(k_dec, cfg.n_layers)
    p["dec_blocks"] = jax.vmap(lambda k: _init_dec_block(k, cfg)[0])(dk)
    _, da = _init_dec_block(dk[0], cfg.reduced())
    a["dec_blocks"] = _stack_axes(da)
    p["enc_norm"], a["enc_norm"] = init_layernorm(cfg.d_model, dtype=dt)
    p["dec_norm"], a["dec_norm"] = init_layernorm(cfg.d_model, dtype=dt)
    return p, a


def _stack_axes(axes: Pytree) -> Pytree:
    return jax.tree.map(lambda ax: ("layers",) + tuple(ax), axes,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and all(isinstance(e, (str, type(None))) for e in x))


def encode(cfg: ModelConfig, params: Params, embeds: jax.Array,
           rules: Optional[ShardingRules] = None) -> jax.Array:
    """embeds: (B, S_enc, d) frame embeddings (frontend stub output)."""
    rules = rules or default_rules()
    cdt = _dtype(cfg.compute_dtype)
    h = embeds.astype(cdt) + sinusoidal(embeds.shape[1],
                                        cfg.d_model).astype(cdt)[None]
    h = shard_constraint(h, rules, ("batch", "seq", "embed"))
    positions = jnp.arange(embeds.shape[1])[None, :]

    def body(h, bp):
        y, _ = attention_block(bp["attn"], layernorm(bp["norm1"], h),
                               n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                               head_dim=cfg.hd, positions=positions,
                               causal=False, compute_dtype=cdt, rules=rules)
        h = h + y
        h = h + apply_mlp(cfg, bp["mlp"], layernorm(bp["norm2"], h), cdt)
        return shard_constraint(h, rules, ("batch", "seq", "embed")), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, h, params["enc_blocks"])
    return layernorm(params["enc_norm"], h)


def _cross_attend(cfg: ModelConfig, bp: Pytree, h: jax.Array,
                  enc_out: jax.Array, cdt, rules=None) -> jax.Array:
    """Cross-attention: queries from decoder h, keys/values from enc_out."""
    B, S, d = h.shape
    hd = cfg.hd
    q = linear(bp["cross_attn"]["wq"], h, cdt).reshape(B, S, cfg.n_heads, hd)
    k = linear(bp["cross_attn"]["wk"], enc_out, cdt).reshape(
        B, enc_out.shape[1], cfg.n_heads, hd)
    v = linear(bp["cross_attn"]["wv"], enc_out, cdt).reshape(
        B, enc_out.shape[1], cfg.n_heads, hd)
    out = multihead_attention(q, k, v, n_kv=cfg.n_heads, causal=False,
                              rules=rules)
    return linear(bp["cross_attn"]["wo"], out.reshape(B, S, cfg.n_heads * hd),
                  cdt)


def decode(cfg: ModelConfig, params: Params, tokens: jax.Array,
           enc_out: jax.Array, *, cache: Optional[Cache] = None,
           update_cache: bool = False,
           rules: Optional[ShardingRules] = None
           ) -> Tuple[jax.Array, Optional[Cache]]:
    """Decoder forward.  tokens (B, S); enc_out (B, S_enc, d)."""
    rules = rules or default_rules()
    cdt = _dtype(cfg.compute_dtype)
    B, S = tokens.shape
    pos0 = cache["pos"] if cache is not None else jnp.zeros((), jnp.int32)
    h = embed(params["embed"], tokens, cdt) \
        + sinusoidal(S, cfg.d_model, pos0).astype(cdt)[None]
    h = shard_constraint(h, rules, ("batch", "seq", "embed"))
    positions = pos0 + jnp.arange(S)[None, :]

    def body(carry, xs):
        h = carry
        bp, kv_c = xs
        y, new_kv = attention_block(
            bp["self_attn"], layernorm(bp["norm1"], h),
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
            positions=positions, cache=kv_c, update_cache=update_cache,
            compute_dtype=cdt, rules=rules)
        h = h + y
        h = h + _cross_attend(cfg, bp, layernorm(bp["norm_x"], h), enc_out,
                              cdt, rules)
        h = h + apply_mlp(cfg, bp["mlp"], layernorm(bp["norm2"], h), cdt)
        h = shard_constraint(h, rules, ("batch", "seq", "embed"))
        return h, (new_kv if new_kv is not None else kv_c)

    body_fn = jax.checkpoint(body) if (cfg.remat and cache is None) else body
    if cache is None:
        h, _ = jax.lax.scan(lambda c, bp: body_fn(c, (bp, None)), h,
                            params["dec_blocks"])
        new_cache = None
    else:
        h, new_kv = jax.lax.scan(body_fn, h,
                                 (params["dec_blocks"], cache["kv"]))
        new_cache = {"kv": new_kv, "pos": pos0 + S,
                     "enc_out": cache.get("enc_out", enc_out)} \
            if update_cache else None
    h = layernorm(params["dec_norm"], h)
    logits = unembed(params["embed"], h, cdt)
    return shard_constraint(logits, rules, ("batch", "seq", "vocab")), new_cache


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            rules: Optional[ShardingRules] = None) -> Tuple[jax.Array, Dict]:
    enc_out = encode(cfg, params, batch["embeds"], rules)
    logits, _ = decode(cfg, params, batch["tokens"], enc_out, rules=rules)
    loss = softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss, {"nll": loss, "aux": jnp.zeros(())}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               enc_len: int) -> Tuple[Cache, Pytree]:
    kv = init_kv_cache(batch, max_seq, cfg.n_kv, cfg.hd)
    c = {"kv": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), kv),
         "pos": jnp.zeros((), jnp.int32),
         "enc_out": jnp.zeros((batch, enc_len, cfg.d_model), jnp.bfloat16)}
    a = {"kv": jax.tree.map(lambda ax: ("layers",) + tuple(ax),
                            kv_cache_axes(),
                            is_leaf=lambda x: isinstance(x, tuple)),
         "pos": (),
         "enc_out": ("batch", "seq", "embed")}
    a["kv"]["pos"] = ("layers",)
    return c, a


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                cache: Cache, rules: Optional[ShardingRules] = None
                ) -> Tuple[jax.Array, Cache]:
    cdt = _dtype(cfg.compute_dtype)
    logits, new_cache = decode(cfg, params, tokens,
                               cache["enc_out"].astype(cdt), cache=cache,
                               update_cache=True, rules=rules)
    return logits[:, -1], new_cache
