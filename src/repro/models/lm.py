"""Decoder LM covering the dense / moe / ssm / hybrid families.

Layers are stacked on a leading ``layers`` axis and driven by
``jax.lax.scan`` so the lowered HLO is O(1) in depth (critical for the
512-device dry-run compile budget).  ``jax.checkpoint`` wraps the block body
for training when ``cfg.remat``.  The hybrid family (zamba2) carries ONE
shared attention+MLP block applied every ``cfg.attn_every`` layers via
``lax.cond`` inside the scan, with per-application KV caches stacked in the
carry.

Batch dicts:
  train   {"tokens"|"embeds", "labels", optional "mask"} -> scalar loss
  prefill {"tokens"|"embeds"}                  -> (last-token logits, cache)
  decode  {"tokens": (B,1)} + cache            -> (logits, new cache)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..nn.attention import (attention_block, init_attention, init_kv_cache,
                            kv_cache_axes)
from ..nn.layers import (embed, gelu, init_embedding, init_layernorm,
                         init_linear, init_rmsnorm, layernorm, linear,
                         rmsnorm, softmax_cross_entropy, swiglu, unembed)
from ..nn.mamba2 import (init_mamba2, init_ssm_cache, mamba2_block,
                         ssm_cache_axes)
from ..nn.moe import init_moe, moe_block
from ..nn.params import (Pytree, ShardingRules, default_rules,
                         shard_constraint)

Params = Pytree
Cache = Dict[str, Any]

AUX_LOSS_WEIGHT = 0.01


def _dtype(s: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[s]


def _norm_init(cfg: ModelConfig, d: int):
    return (init_rmsnorm(d, dtype=_dtype(cfg.param_dtype))
            if cfg.norm == "rmsnorm"
            else init_layernorm(d, dtype=_dtype(cfg.param_dtype)))


def _norm_apply(cfg: ModelConfig, p, x):
    return rmsnorm(p, x) if cfg.norm == "rmsnorm" else layernorm(p, x)


def init_mlp(key, cfg: ModelConfig, dtype) -> Tuple[Pytree, Pytree]:
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    if cfg.act == "swiglu":
        p["gate"], a["gate"] = init_linear(ks[0], cfg.d_model, cfg.d_ff,
                                           out_axis="mlp", dtype=dtype)
        p["up"], a["up"] = init_linear(ks[1], cfg.d_model, cfg.d_ff,
                                       out_axis="mlp", dtype=dtype)
    else:
        p["up"], a["up"] = init_linear(ks[1], cfg.d_model, cfg.d_ff,
                                       out_axis="mlp", dtype=dtype)
    p["down"], a["down"] = init_linear(ks[2], cfg.d_ff, cfg.d_model,
                                       in_axis="mlp", out_axis="embed",
                                       dtype=dtype)
    return p, a


def apply_mlp(cfg: ModelConfig, p, x, compute_dtype):
    if cfg.act == "swiglu":
        return linear(p["down"], swiglu(linear(p["gate"], x, compute_dtype),
                                        linear(p["up"], x, compute_dtype)),
                      compute_dtype)
    return linear(p["down"], gelu(linear(p["up"], x, compute_dtype)),
                  compute_dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig) -> Tuple[Pytree, Pytree]:
    """One layer's params (unstacked)."""
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    a: Dict[str, Any] = {}
    if cfg.family in ("ssm", "hybrid"):
        p["norm1"], a["norm1"] = _norm_init(cfg, cfg.d_model)
        p["mamba"], a["mamba"] = init_mamba2(
            ks[0], cfg.d_model, d_state=cfg.ssm_state, headdim=cfg.ssm_headdim,
            expand=cfg.ssm_expand, n_groups=cfg.ssm_groups, dtype=dt)
        return p, a
    p["norm1"], a["norm1"] = _norm_init(cfg, cfg.d_model)
    p["attn"], a["attn"] = init_attention(
        ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd,
        qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm, dtype=dt)
    p["norm2"], a["norm2"] = _norm_init(cfg, cfg.d_model)
    if cfg.family == "moe":
        p["moe"], a["moe"] = init_moe(ks[1], cfg.d_model, cfg.d_ff,
                                      cfg.n_experts, dtype=dt)
    else:
        p["mlp"], a["mlp"] = init_mlp(ks[1], cfg, dt)
    return p, a


def init_params(cfg: ModelConfig, key: jax.Array) -> Tuple[Params, Pytree]:
    dt = _dtype(cfg.param_dtype)
    k_emb, k_blocks, k_shared, k_head = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    a: Dict[str, Any] = {}
    p["embed"], a["embed"] = init_embedding(k_emb, cfg.padded_vocab,
                                            cfg.d_model, dtype=dt)
    # stacked blocks
    keys = jax.random.split(k_blocks, cfg.n_layers)
    p["blocks"] = jax.vmap(lambda k: _init_block(k, cfg)[0])(keys)
    a["blocks"] = _init_block_axes(cfg)
    if cfg.family == "hybrid":
        ks = jax.random.split(k_shared, 3)
        sp: Dict[str, Any] = {}
        sa: Dict[str, Any] = {}
        sp["norm1"], sa["norm1"] = _norm_init(cfg, cfg.d_model)
        sp["attn"], sa["attn"] = init_attention(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd,
            qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm, dtype=dt)
        sp["norm2"], sa["norm2"] = _norm_init(cfg, cfg.d_model)
        sp["mlp"], sa["mlp"] = init_mlp(ks[1], cfg, dt)
        p["shared"] = sp
        a["shared"] = sa
    p["final_norm"], a["final_norm"] = _norm_init(cfg, cfg.d_model)
    if not cfg.tie_embeddings:
        p["lm_head"], a["lm_head"] = init_linear(
            k_head, cfg.d_model, cfg.padded_vocab, in_axis="embed", out_axis="vocab",
            dtype=dt)
    return p, a


def _init_block_axes(cfg: ModelConfig) -> Pytree:
    """Axes for one block, with the stacked 'layers' dim prepended.

    Built from the *reduced* config — axis structure depends only on the
    family/flags, never on dims — so no full-size allocation happens here.
    """
    _, axes = _init_block(jax.random.PRNGKey(0), cfg.reduced())
    return jax.tree.map(
        lambda ax: ("layers",) + tuple(ax),
        axes, is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x))


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Tuple[Cache, Pytree]:
    """Stacked decode caches + their logical axes."""
    c: Dict[str, Any] = {}
    a: Dict[str, Any] = {}
    if cfg.family in ("ssm", "hybrid"):
        one = init_ssm_cache(batch, cfg.d_model, d_state=cfg.ssm_state,
                             headdim=cfg.ssm_headdim, expand=cfg.ssm_expand,
                             n_groups=cfg.ssm_groups)
        c["ssm"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), one)
        a["ssm"] = jax.tree.map(lambda ax: ("layers",) + tuple(ax),
                                ssm_cache_axes(),
                                is_leaf=lambda x: isinstance(x, tuple))
        if cfg.family == "hybrid":
            napp = cfg.n_shared_attn()
            kv = init_kv_cache(batch, max_seq, cfg.n_kv, cfg.hd)
            c["kv"] = {k: jnp.broadcast_to(kv[k], (napp,) + kv[k].shape)
                       for k in ("k", "v")}
            kv_ax = kv_cache_axes()
            a["kv"] = {k: ("stage",) + tuple(kv_ax[k]) for k in ("k", "v")}
        c["pos"] = jnp.zeros((), jnp.int32)
        a["pos"] = ()
    else:
        kv = init_kv_cache(batch, max_seq, cfg.n_kv, cfg.hd)
        c["kv"] = {k: jnp.broadcast_to(kv[k], (cfg.n_layers,) + kv[k].shape)
                   for k in ("k", "v")}
        kv_ax = kv_cache_axes()
        a["kv"] = {k: ("layers",) + tuple(kv_ax[k]) for k in ("k", "v")}
        c["pos"] = jnp.zeros((), jnp.int32)
        a["pos"] = ()
    return c, a


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _inputs_to_h(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
                 rules: ShardingRules, compute_dtype) -> jax.Array:
    if "embeds" in batch:
        h = batch["embeds"].astype(compute_dtype)
    else:
        h = embed(params["embed"], batch["tokens"], compute_dtype)
    return shard_constraint(h, rules, ("batch", "seq", "embed"))


def _logits(cfg: ModelConfig, params: Params, h: jax.Array,
            rules: ShardingRules) -> jax.Array:
    cdt = _dtype(cfg.compute_dtype)
    h = _norm_apply(cfg, params["final_norm"], h)
    if cfg.tie_embeddings:
        lg = unembed(params["embed"], h, cdt)
    else:
        lg = linear(params["lm_head"], h, cdt).astype(jnp.float32)
    return shard_constraint(lg, rules, ("batch", "seq", "vocab"))


def forward(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            *, rules: Optional[ShardingRules] = None,
            cache: Optional[Cache] = None, update_cache: bool = False,
            mode: str = "train"
            ) -> Tuple[jax.Array, jax.Array, Optional[Cache]]:
    """Returns (logits, aux_loss, new_cache)."""
    rules = rules or default_rules()
    cdt = _dtype(cfg.compute_dtype)
    h = _inputs_to_h(cfg, params, batch, rules, cdt)
    B, S = h.shape[:2]
    pos0 = cache["pos"] if cache is not None else jnp.zeros((), jnp.int32)
    positions = pos0 + jnp.arange(S)[None, :]            # (1, S) broadcast

    def block_fn(carry, xs):
        h, aux, kvs = carry
        if cfg.family in ("ssm", "hybrid"):
            li, bp, ssm_c = xs
            hin = _norm_apply(cfg, bp["norm1"], h)
            y, new_ssm = mamba2_block(
                bp["mamba"], hin, d_state=cfg.ssm_state,
                headdim=cfg.ssm_headdim, expand=cfg.ssm_expand,
                n_groups=cfg.ssm_groups, chunk=cfg.ssm_chunk,
                cache=None if ssm_c is None else dict(ssm_c),
                update_cache=update_cache or ssm_c is not None,
                compute_dtype=cdt)
            h = h + y
            ys = new_ssm if new_ssm is not None else ssm_c
            if cfg.family == "hybrid":
                def with_attn(op):
                    h, kvs = op
                    sp = params["shared"]
                    app = li // cfg.attn_every
                    # page round trip per application: index this app's
                    # (B, S, KV, D) page, update it, write it back.  A
                    # carried stacked buffer measured worse (GSPMD lowers
                    # dynamic-pos writes into the seq-sharded dim as
                    # full-stack masked selects; EXPERIMENTS.md §Perf).
                    page = None
                    if kvs is not None:
                        page = {"k": jax.lax.dynamic_index_in_dim(
                                    kvs["k"], app, 0, keepdims=False),
                                "v": jax.lax.dynamic_index_in_dim(
                                    kvs["v"], app, 0, keepdims=False),
                                "pos": pos0}
                    y, new_kv = attention_block(
                        sp["attn"], _norm_apply(cfg, sp["norm1"], h),
                        n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
                        positions=positions, cache=page,
                        update_cache=update_cache, rope_theta=cfg.rope_theta,
                        compute_dtype=cdt, rules=rules)
                    h = h + y
                    h = h + apply_mlp(cfg, sp["mlp"],
                                      _norm_apply(cfg, sp["norm2"], h), cdt)
                    if page is not None and new_kv is not None:
                        kvs = {k: jax.lax.dynamic_update_index_in_dim(
                                   kvs[k], new_kv[k].astype(kvs[k].dtype),
                                   app, 0) for k in ("k", "v")}
                    return h, kvs

                h, kvs = jax.lax.cond(li % cfg.attn_every == 0,
                                      with_attn, lambda op: op, (h, kvs))
            h = shard_constraint(h, rules, ("batch", "seq", "embed"))
            return (h, aux, kvs), ys

        li, bp, kv_page = xs
        page = None if kv_page is None else {"k": kv_page["k"],
                                             "v": kv_page["v"], "pos": pos0}
        y, new_kv = attention_block(
            bp["attn"], _norm_apply(cfg, bp["norm1"], h),
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
            positions=positions, cache=page, update_cache=update_cache,
            rope_theta=cfg.rope_theta, compute_dtype=cdt, rules=rules)
        h = h + y
        hin = _norm_apply(cfg, bp["norm2"], h)
        if cfg.family == "moe":
            y2, a = moe_block(bp["moe"], hin, n_experts=cfg.n_experts,
                              top_k=cfg.top_k,
                              capacity_factor=cfg.capacity_factor,
                              dispatch_groups=cfg.moe_dispatch_groups,
                              rules=rules, compute_dtype=cdt)
            aux = aux + a
        else:
            y2 = apply_mlp(cfg, bp["mlp"], hin, cdt)
        h = h + y2
        h = shard_constraint(h, rules, ("batch", "seq", "embed"))
        ys = None if new_kv is None else {"k": new_kv["k"], "v": new_kv["v"]}
        return (h, aux, kvs), ys

    body = jax.checkpoint(block_fn) if (cfg.remat and mode == "train") \
        else block_fn

    layer_ids = jnp.arange(cfg.n_layers)
    aux0 = jnp.zeros((), jnp.float32)
    if cfg.family in ("ssm", "hybrid"):
        kvs0 = cache.get("kv") if (cache is not None
                                   and cfg.family == "hybrid") else None
        if cache is None:
            def body2(carry, xs2):
                li, bp = xs2
                return body(carry, (li, bp, None))
            (h, aux, kvs), _ = jax.lax.scan(
                body2, (h, aux0, kvs0), (layer_ids, params["blocks"]))
            new_cache = None
            if update_cache:
                raise ValueError("update_cache requires an initialized cache")
        else:
            (h, aux, kvs), new_ssm = jax.lax.scan(
                body, (h, aux0, kvs0),
                (layer_ids, params["blocks"], cache["ssm"]))
            new_cache = None
            if update_cache:
                new_cache = {"ssm": new_ssm, "pos": pos0 + S}
                if cfg.family == "hybrid":
                    new_cache["kv"] = kvs
    else:
        if cache is None:
            def body2(carry, xs2):
                li, bp = xs2
                return body(carry, (li, bp, None))
            (h, aux, _), _ = jax.lax.scan(
                body2, (h, aux0, None), (layer_ids, params["blocks"]))
            new_cache = None
        else:
            # page-streaming cache: each layer's (B, S, KV, D) page flows
            # through scan xs -> ys.  Measured better than a carried
            # stacked buffer, whose dynamic-pos write into the seq-sharded
            # dim lowers to full-buffer masked selects (EXPERIMENTS §Perf).
            (h, aux, _), new_kv = jax.lax.scan(
                body, (h, aux0, None),
                (layer_ids, params["blocks"],
                 {"k": cache["kv"]["k"], "v": cache["kv"]["v"]}))
            new_cache = {"kv": new_kv, "pos": pos0 + S} \
                if update_cache else None

    logits = _logits(cfg, params, h, rules)
    return logits, aux, new_cache


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            rules: Optional[ShardingRules] = None) -> Tuple[jax.Array, Dict]:
    logits, aux, _ = forward(cfg, params, batch, rules=rules, mode="train")
    loss = softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))
    total = loss + AUX_LOSS_WEIGHT * aux
    return total, {"nll": loss, "aux": aux}


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            cache: Cache, rules: Optional[ShardingRules] = None
            ) -> Tuple[jax.Array, Cache]:
    logits, _, new_cache = forward(cfg, params, batch, rules=rules,
                                   cache=cache, update_cache=True,
                                   mode="prefill")
    return logits[:, -1], new_cache


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                cache: Cache, rules: Optional[ShardingRules] = None
                ) -> Tuple[jax.Array, Cache]:
    """tokens: (B, 1) -> (logits (B, vocab), new cache)."""
    logits, _, new_cache = forward(cfg, params, {"tokens": tokens},
                                   rules=rules, cache=cache,
                                   update_cache=True, mode="decode")
    return logits[:, -1], new_cache
