"""Model zoo: one decoder-LM covering dense/moe/ssm/hybrid + an enc-dec.

``model_api(cfg)`` returns the family-appropriate (init, loss, prefill,
decode_step, init_cache) bundle so launchers never branch on family.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import encdec, lm


@dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init_params: Callable
    loss_fn: Callable
    init_cache: Callable
    prefill: Callable
    decode_step: Callable


def model_api(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "encdec":
        def init_cache(batch, max_seq, enc_len=None):
            return encdec.init_cache(cfg, batch, max_seq,
                                     enc_len or min(max_seq, 1500))

        def prefill(params, batch, cache, rules=None):
            enc_out = encdec.encode(cfg, params, batch["embeds"], rules)
            cache = dict(cache)
            cache["enc_out"] = enc_out.astype(cache["enc_out"].dtype)
            logits, new_cache = encdec.decode(
                cfg, params, batch["tokens"], enc_out, cache=cache,
                update_cache=True, rules=rules)
            return logits[:, -1], new_cache

        return ModelAPI(
            cfg=cfg,
            init_params=lambda key: encdec.init_params(cfg, key),
            loss_fn=lambda p, b, rules=None: encdec.loss_fn(cfg, p, b, rules),
            init_cache=init_cache,
            prefill=prefill,
            decode_step=lambda p, t, c, rules=None:
                encdec.decode_step(cfg, p, t, c, rules),
        )
    return ModelAPI(
        cfg=cfg,
        init_params=lambda key: lm.init_params(cfg, key),
        loss_fn=lambda p, b, rules=None: lm.loss_fn(cfg, p, b, rules),
        init_cache=lambda batch, max_seq, enc_len=None:
            lm.init_cache(cfg, batch, max_seq),
        prefill=lambda p, b, c, rules=None: lm.prefill(cfg, p, b, c, rules),
        decode_step=lambda p, t, c, rules=None:
            lm.decode_step(cfg, p, t, c, rules),
    )
