"""Import hooks bridging the pinned toolchain (see ``repro.compat``).

Installed two ways:
  * ``src/sitecustomize.py`` — auto-imported at interpreter startup for any
    process with ``PYTHONPATH=src`` (the tier-1 command and the subprocesses
    the tests spawn);
  * ``conftest.py`` — imports this module by its unique name, so a bare
    ``pytest`` works even in environments whose Python ships its own
    ``sitecustomize`` (where the name-based import would hit the cached
    system module and silently no-op).

Hooks:
  * lazy ``jax.shard_map`` alias for jax 0.4.x (disable with
    ``REPRO_NO_JAX_COMPAT=1``);
  * a FALLBACK finder serving vendored stand-ins for missing optional
    dependencies (``hypothesis`` -> ``repro/_vendor/minihypothesis.py``).
    Appended to ``sys.meta_path``, so an installed real package always
    wins.  Not affected by ``REPRO_NO_JAX_COMPAT``.
"""

from __future__ import annotations

import importlib
import importlib.abc
import importlib.util
import os
import sys


class _PatchingLoader(importlib.abc.Loader):
    def __init__(self, wrapped):
        self._wrapped = wrapped

    def create_module(self, spec):
        return self._wrapped.create_module(spec)

    def exec_module(self, module):
        self._wrapped.exec_module(module)
        try:
            from repro.compat import install_jax_compat
            install_jax_compat(module)
        except Exception:
            pass  # never break `import jax` over a missing/broken shim

    def __getattr__(self, name):
        return getattr(self._wrapped, name)


class _JaxCompatFinder(importlib.abc.MetaPathFinder):
    _busy = False

    def find_spec(self, fullname, path=None, target=None):
        if fullname != "jax" or _JaxCompatFinder._busy:
            return None
        _JaxCompatFinder._busy = True
        try:
            spec = importlib.util.find_spec(fullname)
        finally:
            _JaxCompatFinder._busy = False
        if spec is None or spec.loader is None:
            return None
        sys.meta_path.remove(self)
        spec.loader = _PatchingLoader(spec.loader)
        return spec


class _VendoredFallbackFinder(importlib.abc.MetaPathFinder):
    """Serve vendored stand-ins for missing optional deps.

    Appended to ``sys.meta_path``, so it is consulted only after the normal
    machinery fails — an installed real package always wins.
    """

    _vendored = {"hypothesis": "minihypothesis.py"}

    def find_spec(self, fullname, path=None, target=None):
        fname = self._vendored.get(fullname)
        if fname is None:
            return None
        shim = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "repro", "_vendor", fname)
        if not os.path.exists(shim):
            return None
        return importlib.util.spec_from_file_location(fullname, shim)


def install() -> None:
    """Idempotently register both hooks (jax hook honors the env gate)."""
    if not any(isinstance(f, _VendoredFallbackFinder) for f in sys.meta_path):
        sys.meta_path.append(_VendoredFallbackFinder())
    if os.environ.get("REPRO_NO_JAX_COMPAT"):
        return
    if any(isinstance(f, _JaxCompatFinder) for f in sys.meta_path):
        return
    if "jax" in sys.modules:  # someone imported jax before us (unlikely)
        try:
            from repro.compat import install_jax_compat
            install_jax_compat(sys.modules["jax"])
        except Exception:
            pass
    else:
        sys.meta_path.insert(0, _JaxCompatFinder())
