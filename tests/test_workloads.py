"""Workload DAG sanity: layer counts, MAC totals vs published numbers,
DAG validity, LM-graph export."""

import pytest

from repro.configs import get_config
from repro.core.workloads import (PAPER_WORKLOADS, inception_resnet_v1,
                                  pnasnet, resnet50, resnext50, transformer)
from repro.core.workloads.lm_graph import lm_graph


def test_resnet50_macs():
    g = resnet50()
    gmacs = g.total_macs(1) / 1e9
    assert 3.3 < gmacs < 4.5          # published ~3.9-4.1 GMACs @224
    assert 20e6 < g.total_weight_bytes() < 30e6   # ~25.5M params int8


def test_resnext50_macs():
    g = resnext50()
    gmacs = g.total_macs(1) / 1e9
    assert 3.5 < gmacs < 5.0          # published ~4.2 GMACs
    # grouped convs: fewer MACs than an ungrouped twin would have
    assert g.total_weight_bytes() < 30e6


def test_inception_resnet_structure():
    g = inception_resnet_v1()
    assert len(g.layers) > 120        # complex dependencies
    # residual adds exist with 2 inputs
    adds = [l for l in g.layers.values() if l.kind == "eltwise"]
    assert len(adds) >= 20
    g.validate()


def test_pnasnet_structure():
    g = pnasnet()
    # five-branch cells -> join conv with 5 producers
    joins = [n for n in g.layers if n.endswith("_join")]
    assert joins
    assert any(len(g.preds(j)) == 5 for j in joins)
    g.validate()


def test_transformer_attention_macs_scale_quadratically():
    g1 = transformer(n_layers=1, d_model=256, d_ff=512, seq=128, name="a")
    g2 = transformer(n_layers=1, d_model=256, d_ff=512, seq=256, name="b")
    qk1 = g1.layers["l0_qk"].macs(1)
    qk2 = g2.layers["l0_qk"].macs(1)
    assert qk2 == 4 * qk1


def test_all_paper_workloads_validate():
    for name, fn in PAPER_WORKLOADS.items():
        g = fn()
        g.validate()
        assert g.total_macs(1) > 1e9, name


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-370m", "zamba2-1.2b",
                                  "granite-moe-3b-a800m"])
def test_lm_graph_exports(arch):
    cfg = get_config(arch)
    g = lm_graph(cfg, seq=512, n_layers=4)
    g.validate()
    assert g.total_macs(1) > 0
    if cfg.family in ("ssm", "hybrid"):
        assert any("_ssd" in n for n in g.layers)
    if cfg.family == "hybrid":
        assert any("_qk" in n for n in g.layers)   # shared attn exported


def test_lm_graph_macs_close_to_analytic():
    """fc-layer MACs of the exported graph ~ 2*N*D forward estimate."""
    cfg = get_config("qwen3-0.6b")
    seq = 512
    g = lm_graph(cfg, seq=seq)
    macs = g.total_macs(1)
    approx = cfg.param_count() * seq        # 1 MAC per weight per token
    assert 0.5 * approx < macs < 2.5 * approx
