"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.mamba_ssd import ssd_chunk_dual
from repro.nn.mamba2 import ssd_chunked

RNG = np.random.default_rng(0)


def _randn(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,Sq,Sk,D", [
    (1, 2, 2, 64, 64, 32),
    (2, 4, 2, 96, 96, 64),      # GQA + non-multiple of block
    (1, 2, 1, 128, 256, 32),    # Sq != Sk
    (2, 8, 8, 64, 64, 128),     # MHA wide head
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_vs_ref(B, H, KV, Sq, Sk, D, causal):
    if causal and Sq != Sk:
        pytest.skip("causal requires aligned q/k starts in this harness")
    q = _randn((B, Sq, H, D))
    k = _randn((B, Sk, KV, D))
    v = _randn((B, Sk, KV, D))
    out = ops.flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    kr = jnp.repeat(k, H // KV, axis=2).transpose(0, 2, 1, 3)
    vr = jnp.repeat(v, H // KV, axis=2).transpose(0, 2, 1, 3)
    expected = ref.attention_ref(q.transpose(0, 2, 1, 3), kr, vr,
                                 causal=causal).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, atol):
    B, H, S, D = 1, 2, 64, 32
    q = _randn((B, S, H, D), dtype)
    k = _randn((B, S, H, D), dtype)
    v = _randn((B, S, H, D), dtype)
    out = ops.flash_attention(q, k, v, causal=True, bq=32, bk=32)
    expected = ref.attention_ref(q.transpose(0, 2, 1, 3),
                                 k.transpose(0, 2, 1, 3),
                                 v.transpose(0, 2, 1, 3),
                                 causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=atol, rtol=atol)


def test_flash_attention_matches_nn_path():
    """Kernel vs the model's jnp flash scan (the dry-run twin)."""
    from repro.nn.attention import multihead_attention
    B, H, KV, S, D = 2, 4, 2, 128, 32
    q = _randn((B, S, H, D))
    k = _randn((B, S, KV, D))
    v = _randn((B, S, KV, D))
    a = ops.flash_attention(q, k, v, causal=True, bq=64, bk=64)
    b = multihead_attention(q, k, v, n_kv=KV, causal=True,
                            force_flash=True, block=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# mamba SSD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("BC,Q,H,P,N", [
    (2, 16, 2, 8, 4),
    (4, 64, 4, 32, 16),
    (1, 128, 8, 64, 32),
])
def test_ssd_chunk_vs_ref(BC, Q, H, P, N):
    x = _randn((BC, Q, H, P))
    cum = jnp.cumsum(-jnp.abs(_randn((BC, Q, H))) * 0.1, axis=1)
    Bm = _randn((BC, Q, N))
    Cm = _randn((BC, Q, N))
    y, s = ssd_chunk_dual(x, cum, Bm, Cm, interpret=True)
    yr, sr = ref.ssd_chunk_ref(x, cum, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("L,chunk", [(64, 16), (96, 32), (70, 32)])
def test_ssd_forward_vs_model_chunked(L, chunk):
    B, H, P, N = 2, 4, 16, 8
    x = _randn((B, L, H, P))
    dt = jnp.abs(_randn((B, L, H))) * 0.1
    A = -jnp.abs(_randn((H,)))
    Bm = _randn((B, L, 1, N))
    Cm = _randn((B, L, 1, N))
    y1, _ = ops.ssd_forward(x, dt, A, Bm, Cm, chunk=chunk)
    y2, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-4, rtol=2e-4)


def test_ssd_chunked_matches_stepwise():
    """Chunked (train) path == token-by-token recurrence (decode path)."""
    from repro.nn.mamba2 import ssd_decode_step
    B, L, H, P, N = 1, 24, 2, 8, 4
    x = _randn((B, L, H, P))
    dt = jnp.abs(_randn((B, L, H))) * 0.1
    A = -jnp.abs(_randn((H,)))
    Bm = _randn((B, L, 1, N))
    Cm = _randn((B, L, 1, N))
    y_chunk, final_state = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    state = jnp.zeros((B, H, N, P))
    ys = []
    for t in range(L):
        y, state = ssd_decode_step(x[:, t], dt[:, t], A, Bm[:, t, 0][:, None],
                                   Cm[:, t, 0][:, None], state)
        ys.append(y)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(final_state), np.asarray(state),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# tiled matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N,bm,bn,bk", [
    (64, 64, 64, 32, 32, 32),
    (100, 300, 50, 64, 64, 64),     # ragged
    (256, 128, 512, 128, 128, 128),
])
def test_tiled_matmul(M, K, N, bm, bn, bk):
    a = _randn((M, K))
    b = _randn((K, N))
    out = ops.matmul(a, b, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.matmul_ref(a, b)),
                               atol=1e-3, rtol=1e-4)


def test_tiled_matmul_bf16():
    a = _randn((128, 128), jnp.bfloat16)
    b = _randn((128, 128), jnp.bfloat16)
    out = ops.matmul(a, b, bm=64, bn=64, bk=64)
    expected = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=0.5, rtol=5e-2)
