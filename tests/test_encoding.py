"""Unit tests for the LP-SPM encoding (paper Sec. IV)."""

import numpy as np
import pytest

from repro.core.encoding import (LMS, MS, Region, factor_parts, ifmap_region,
                                 parse_regions, random_lms, space_size_lower_bound,
                                 split_points, tangram_space_upper_bound)
from repro.core.workload import Graph, Layer, LayerGroup


def _mini_graph():
    g = Graph("mini")
    g.add(Layer(name="l1", kind="conv", K=4, H=6, W=6, C=3, R=3, S=3))
    g.add(Layer(name="l2", kind="conv", K=8, H=6, W=6, C=4), ["l1"])
    return g


def test_split_points_cover_exactly():
    sp = split_points(10, 3)
    assert sp[0] == 0 and sp[-1] == 10
    sizes = np.diff(sp)
    assert sizes.sum() == 10
    assert sizes.max() - sizes.min() <= 1


def test_split_points_rejects_oversplit():
    with pytest.raises(ValueError):
        split_points(3, 4)


def test_ms_validates_product():
    with pytest.raises(ValueError):
        MS(part=(2, 1, 1, 1), cg=(0, 1, 2), fd=(-1, 0, -1))
    with pytest.raises(ValueError):
        MS(part=(1, 1, 1, 2), cg=(0, 0), fd=(-1, 0, -1))


def test_correspondence_rule_row_major():
    # paper example: NID = h*W*B*K + w*B*K + b*K + k
    ms = MS(part=(1, 1, 2, 2), cg=(2, 1, 5, 4), fd=(1, 1, -1))
    assert ms.core_of(0, 0, 0, 0) == 2
    assert ms.core_of(0, 0, 0, 1) == 1
    assert ms.core_of(0, 0, 1, 0) == 5
    assert ms.core_of(0, 0, 1, 1) == 4


def test_parse_regions_partition_cube():
    lyr = Layer(name="x", kind="conv", K=8, H=5, W=7, C=3)
    ms = MS(part=(2, 2, 1, 2), cg=tuple(range(8)), fd=(0, 0, 0))
    regs = parse_regions(ms, lyr, batch_unit=1)
    total = sum(r.elems for r in regs.values())
    assert total == 8 * 5 * 7 * 1
    # disjoint
    for c1 in regs:
        for c2 in regs:
            if c1 != c2:
                assert regs[c1].overlap(regs[c2]) == 0


def test_ifmap_region_conv_halo():
    lyr = Layer(name="x", kind="conv", K=8, H=8, W=8, C=4, R=3, S=3)
    r = Region(2, 4, 0, 8, 0, 1, 0, 8)
    ir = ifmap_region(lyr, r, in_K=4)
    assert ir.h0 <= 2 and ir.h1 >= 4          # halo widens
    assert ir.k0 == 0 and ir.k1 == 4          # full channel contraction


def test_eltwise_ifmap_is_identity():
    lyr = Layer(name="x", kind="eltwise", K=8, H=8, W=8, n_inputs=2)
    r = Region(2, 4, 1, 3, 0, 1, 2, 6)
    assert ifmap_region(lyr, r, in_K=8) == r


def test_factor_parts_respects_caps():
    rng = np.random.default_rng(0)
    for _ in range(50):
        part = factor_parts(12, (4, 6, 2, 8), rng)
        assert np.prod(part) == 12
        assert part[0] <= 4 and part[1] <= 6 and part[2] <= 2 and part[3] <= 8


def test_random_lms_valid():
    g = _mini_graph()
    grp = LayerGroup(names=("l1", "l2"), batch_unit=2)
    rng = np.random.default_rng(1)
    for seed in range(10):
        lms = random_lms(grp, g, n_cores=6, n_dram=2,
                         rng=np.random.default_rng(seed))
        lms.validate(grp, g, n_cores=6, n_dram=2)


def test_space_size_dwarfs_tangram():
    ours = space_size_lower_bound(4, 16)
    theirs = tangram_space_upper_bound(4, 16)
    assert ours > theirs * 1000


def test_fd_structural_rules():
    g = _mini_graph()
    grp = LayerGroup(names=("l1", "l2"), batch_unit=1)
    # weighted layer with WGT=-1 must fail
    bad = LMS(ms={
        "l1": MS(part=(1, 1, 1, 1), cg=(0,), fd=(0, -1, -1)),
        "l2": MS(part=(1, 1, 1, 1), cg=(1,), fd=(-1, 0, 0)),
    })
    with pytest.raises(ValueError):
        bad.validate(grp, g, n_cores=6, n_dram=2)


# ---------------------------------------------------------------------------
# routing tables (rectangularized CG geometry for batched construction)
# ---------------------------------------------------------------------------

def _routing_batch(seed=7, n=6):
    from repro.core.graph_partition import partition_graph
    from repro.core.hw import ArchConfig
    from repro.core.workloads import transformer

    arch = ArchConfig(x_cores=4, y_cores=3, xcut=2, ycut=1,
                      noc_bw=16.0, d2d_bw=8.0, dram_bw=64.0,
                      glb_kb=512, macs_per_core=256)
    g = transformer(n_layers=1, d_model=64, d_ff=128, seq=32, name="tf-rt")
    grp = partition_graph(g, arch, 8)[0]
    rng = np.random.default_rng(seed)
    lms_list = [random_lms(grp, g, arch.n_cores, arch.n_dram, rng)
                for _ in range(n)]
    from repro.core.encoding import pack_lms_batch
    return pack_lms_batch(lms_list, names=grp.names), lms_list


def test_routing_tables_invariants():
    batch, lms_list = _routing_batch()
    rt = batch.routing_tables()
    B, L, cmax = batch.cg.shape
    for arr in (rt.slot_mask, rt.cg_safe, rt.order, rt.cg_sorted):
        assert arr.shape == (B, L, cmax)
    # pad cells are flagged off and routed to safe real values
    assert np.array_equal(rt.slot_mask, batch.cg >= 0)
    assert np.all(rt.cg_safe[~rt.slot_mask] == 0)
    assert np.array_equal(rt.cg_safe[rt.slot_mask],
                          batch.cg[rt.slot_mask])
    for b, lms in enumerate(lms_list):
        for li, name in enumerate(batch.names):
            cores = np.asarray(lms.ms[name].cg)
            k = len(cores)
            assert batch.cg_len[b, li] == k
            # sorted-order prefix == np.argsort of the valid CG prefix
            assert np.array_equal(rt.cg_sorted[b, li, :k], np.sort(cores))
            assert np.array_equal(rt.order[b, li, :k], np.argsort(cores))
            # pad slots: sorted view repeats the last real core (gathers
            # through pads stay in-bounds and never add new ids)
            assert np.all(rt.cg_sorted[b, li, k:] == np.sort(cores)[-1])
            # order is a permutation of all Cmax slots, pads last
            assert np.array_equal(np.sort(rt.order[b, li]),
                                  np.arange(cmax))
            assert np.all(rt.order[b, li, k:] >= k)


def test_routing_tables_memoized():
    batch, _ = _routing_batch(seed=9, n=3)
    assert batch.routing_tables() is batch.routing_tables()
