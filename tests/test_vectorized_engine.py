"""Vectorized/incremental evaluation engine vs the seed scalar oracle.

Three layers of protection for the cost model's rewrite:
  * the vectorized intra-core tiling search must return IDENTICAL
    ``CoreDataflow`` results to the scalar triple-loop reference over a
    sweep of conv/fc/depthwise/eltwise/pool/matmul signatures;
  * ``GroupEval`` from the incremental engine must match the seed engine
    (``repro.core.seed_reference``) bit-for-bit on full mappings, and a
    set of golden values pinned from the seed commit guards both against
    a correlated drift;
  * a CachedEvaluator SA run must reproduce the uncached cost trajectory
    exactly for a fixed seed.
"""

import numpy as np
import pytest

from repro.core.evaluator import CachedEvaluator, Evaluator
from repro.core.graph_partition import partition_graph
from repro.core.hw import simba_arch
from repro.core.intra_core import (explore_intra_core,
                                   explore_intra_core_many,
                                   explore_intra_core_reference)
from repro.core.sa import SAConfig, sa_optimize
from repro.core.seed_reference import ReferenceEvaluator
from repro.core.tangram import tangram_map
from repro.core.workloads import resnet50, transformer


# ---------------------------------------------------------------------------
# intra-core: vectorized vs scalar reference
# ---------------------------------------------------------------------------

def _signature_sweep(n):
    rng = np.random.default_rng(7)
    kinds = ["conv", "fc", "depthwise", "eltwise", "pool", "matmul"]
    for trial in range(n):
        yield (int(rng.integers(1, 2048)), int(rng.integers(0, 2048)),
               int(rng.integers(1, 8192)), int(rng.choice([1, 3, 5, 7])),
               int(rng.choice([1, 3, 5])), int(rng.choice([1, 2, 4])),
               int(rng.choice([64 * 1024, 512 * 1024, 2 * 1024 * 1024])),
               int(rng.choice([256, 1024, 4096])),
               kinds[trial % len(kinds)])


def test_vectorized_explore_matches_scalar_reference():
    for sig in _signature_sweep(200):
        vec = explore_intra_core.__wrapped__(*sig)   # bypass the lru cache
        ref = explore_intra_core_reference(*sig)
        assert vec == ref, sig


def test_explore_many_dedupes_and_aligns():
    sigs = list(_signature_sweep(40))
    batch = sigs + sigs[:10]                         # duplicates on purpose
    out = explore_intra_core_many(batch)
    assert len(out) == len(batch)
    for sig, df in zip(batch, out):
        assert df == explore_intra_core(*sig)
    # duplicated signatures return the same (cached) object
    for i in range(10):
        assert out[i] is out[len(sigs) + i]


def test_explore_tiny_and_spill_cases():
    # degenerate dims and a GLB too small for any tile (spill fallback)
    for sig in [(1, 1, 1, 1, 1, 1, 64, 256, "conv"),
                (512, 512, 4096, 3, 3, 1, 16, 1024, "conv"),
                (7, 0, 9, 1, 1, 2, 1 << 20, 1024, "fc"),
                (16, 16, 64, 1, 1, 1, 1 << 20, 1024, "eltwise")]:
        assert explore_intra_core.__wrapped__(*sig) == \
            explore_intra_core_reference(*sig)


# ---------------------------------------------------------------------------
# GroupEval: incremental engine vs seed oracle, plus pinned goldens
# ---------------------------------------------------------------------------

def _mapped(g, batch):
    arch = simba_arch()
    groups = partition_graph(g, arch, batch)
    return arch, tangram_map(groups, g, arch)


@pytest.mark.parametrize("workload,batch", [
    (transformer(n_layers=2, d_model=128, d_ff=256, seq=64, name="tf-s"), 8),
    (resnet50(), 4),
])
def test_group_eval_bit_identical_to_seed_engine(workload, batch):
    arch, mapping = _mapped(workload, batch)
    ref = ReferenceEvaluator(arch, workload)
    new = Evaluator(arch, workload)
    for grp, lms in mapping:
        a, _ = ref.eval_group(grp, lms, batch)
        b, _ = new.eval_group(grp, lms, batch)
        assert a == b                   # dataclass ==: every field, bitwise


# golden values recorded from the seed commit's evaluator on these fixed
# mappings — they guard ReferenceEvaluator itself against drift
GOLD_TF = [
    (0.000146448, 0.000122474496, 4.8816e-05, 1, 3, "d2d", 0.0),
    (0.000106496, 8.925150080000001e-05, 2.6624e-05, 1, 4, "d2d", 0.0),
    (6.0096e-05, 7.124474879999999e-05, 1.5024e-05, 1, 4, "d2d", 0.0),
    (0.00012632, 8.273904e-05, 2.5264e-05, 1, 5, "d2d", 0.0),
]
GOLD_RN50 = {
    0: (0.017354744, 0.0033603707104, 0.0014462286666666667, 2, 11,
        "compute", 4669440.0),
    16: (0.000555264, 0.000647145664, 0.000185088, 1, 3, "d2d", 0.0),
    32: (0.000516608, 0.0010142298, 0.000516608, 1, 1, "d2d", 0.0),
}


def _fields(ge):
    return (ge.delay_s, ge.energy_j, ge.stage_time_s, ge.n_passes,
            ge.depth, ge.bottleneck, ge.glb_overflow_bytes)


def test_golden_values_transformer():
    g = transformer(n_layers=2, d_model=128, d_ff=256, seq=64, name="tf-s")
    arch, mapping = _mapped(g, 8)
    ev = Evaluator(arch, g)
    for gi, (grp, lms) in enumerate(mapping):
        ge, _ = ev.eval_group(grp, lms, 8)
        assert _fields(ge) == GOLD_TF[gi]


def test_golden_values_resnet50():
    g = resnet50()
    arch, mapping = _mapped(g, 4)
    ev = Evaluator(arch, g)
    for gi, gold in GOLD_RN50.items():
        grp, lms = mapping[gi]
        ge, _ = ev.eval_group(grp, lms, 4)
        assert _fields(ge) == gold


# ---------------------------------------------------------------------------
# CachedEvaluator: content-addressed cache consistency
# ---------------------------------------------------------------------------

def test_cached_evaluator_reproduces_uncached_sa_trajectory():
    arch = simba_arch()
    g = transformer(n_layers=2, d_model=128, d_ff=256, seq=64, name="tf-s")
    groups = partition_graph(g, arch, 8)
    init = tangram_map(groups, g, arch)
    cfg = SAConfig(iters=400, seed=3)
    r_plain = sa_optimize(g, arch, groups, 8, cfg, init=init,
                          evaluator=Evaluator(arch, g))
    cached = CachedEvaluator(arch, g)
    r_cached = sa_optimize(g, arch, groups, 8, cfg, init=init,
                           evaluator=cached)
    assert r_plain.cost == r_cached.cost
    assert r_plain.energy_j == r_cached.energy_j
    assert r_plain.delay_s == r_cached.delay_s
    assert (r_plain.accepted, r_plain.proposed) == \
        (r_cached.accepted, r_cached.proposed)
    info = cached.cache_info()
    assert info["hits"] > 0             # final re-eval of best mapping hits


def test_cached_evaluator_hits_on_repeat_and_fd_independence():
    arch = simba_arch()
    g = transformer(n_layers=2, d_model=128, d_ff=256, seq=64, name="tf-s")
    groups = partition_graph(g, arch, 8)
    mapping = tangram_map(groups, g, arch)
    ev = CachedEvaluator(arch, g)
    r1 = ev.evaluate(mapping, 8)
    misses = ev.cache_info()["misses"]
    r2 = ev.evaluate(mapping, 8)
    assert ev.cache_info()["misses"] == misses      # all hits second time
    assert r1.delay_s == r2.delay_s and r1.energy_j == r2.energy_j
    # a different batch is a different key
    ev.evaluate(mapping, 16)
    assert ev.cache_info()["misses"] > misses
