"""Integration: training loop (loss decreases, crash-restart exactness,
straggler watchdog), serving loop, optimizer behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.optim.adamw import (AdamWConfig, adamw_update, init_opt_state,
                               lr_schedule)
from repro.runtime.train_loop import StragglerWatchdog, TrainConfig, Trainer


def _tiny_cfg():
    return get_config("smollm-135m").reduced().replace(
        n_layers=2, d_model=64, vocab=256, d_ff=128)


def _data(cfg, batch=4, seq=32):
    return DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch)


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=100, min_lr_ratio=1.0)
    params = {"x": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    for _ in range(60):
        g = {"x": 2 * params["x"]}
        params, opt, _ = adamw_update(cfg, params, g, opt)
    assert float(jnp.abs(params["x"]).max()) < 0.5


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(lr_schedule(cfg, jnp.asarray(0))) == 0.0
    assert float(lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr_schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1)


def test_training_loss_decreases(tmp_path):
    cfg = _tiny_cfg()
    tr = Trainer(cfg, _data(cfg), TrainConfig(
        steps=30, ckpt_every=100, ckpt_dir=str(tmp_path), log_every=100,
        opt=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=30)))
    out = tr.run(resume=False)
    losses = out["losses"]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05


def test_crash_restart_resumes_exactly(tmp_path):
    """10 straight steps == 5 steps + 'crash' + restart of 5 more."""
    cfg = _tiny_cfg()
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)

    tr_a = Trainer(cfg, _data(cfg), TrainConfig(
        steps=10, ckpt_every=100, ckpt_dir=str(tmp_path / "a"),
        log_every=100, opt=opt, async_ckpt=False))
    out_a = tr_a.run(resume=False)

    tr_b1 = Trainer(cfg, _data(cfg), TrainConfig(
        steps=5, ckpt_every=5, ckpt_dir=str(tmp_path / "b"),
        log_every=100, opt=opt, async_ckpt=False))
    tr_b1.run(resume=False)          # checkpoints at step 5, then "crashes"

    tr_b2 = Trainer(cfg, _data(cfg), TrainConfig(
        steps=10, ckpt_every=5, ckpt_dir=str(tmp_path / "b"),
        log_every=100, opt=opt, async_ckpt=False))
    out_b = tr_b2.run(resume=True)   # resumes from 5

    np.testing.assert_allclose(out_a["losses"][5:], out_b["losses"],
                               rtol=1e-5, atol=1e-6)


def test_straggler_watchdog():
    wd = StragglerWatchdog(factor=2.0)
    for _ in range(5):
        wd.observe(0.1)
    assert wd.observe(0.5) is True
    assert wd.slow_steps == 1
    assert wd.observe(0.1) is False


def test_serving_wave(tmp_path):
    from repro.models import model_api
    from repro.runtime.serve_loop import Request, Server
    cfg = _tiny_cfg()
    api = model_api(cfg)
    params, _ = api.init_params(jax.random.PRNGKey(0))
    srv = Server(cfg, params, max_batch=2, max_seq=64, eos_id=0)
    rng = np.random.default_rng(0)
    for i in range(3):
        srv.submit(Request(rid=i, prompt=rng.integers(
            1, cfg.vocab, size=5 + i).astype(np.int32), max_new=8))
    results = srv.run_until_empty()
    assert sorted(r.rid for r in results) == [0, 1, 2]
    for r in results:
        assert 1 <= len(r.tokens) <= 8
        assert (r.tokens >= 0).all() and (r.tokens < cfg.padded_vocab).all()


def test_gemini_bridge_and_pipeline():
    """Gemini SA plan -> MeshPlan -> pipelined forward == plain forward."""
    from repro.core.bridge import mesh_as_arch, plan_for_graph
    from repro.core.workloads.lm_graph import lm_graph
    from repro.models import lm, model_api
    from repro.runtime.pipeline import PipelineExec

    cfg = _tiny_cfg().replace(compute_dtype="float32")
    g = lm_graph(cfg, seq=16)
    arch = mesh_as_arch(x_chips=2, y_chips=2, pods_x=1)
    plan = plan_for_graph(g, arch, total_batch=4, sa_iters=150)
    assert len(plan.stages) >= 1
    assert plan.cost_delay_s > 0

    api = model_api(cfg)
    params, _ = api.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    expected, _, _ = lm.forward(cfg, params, {"tokens": toks}, mode="train")
    pipe = PipelineExec(cfg=cfg, params=params, plan=plan)
    got = pipe.forward(toks, n_micro=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expected),
                               atol=2e-3, rtol=2e-3)
    assert len(pipe.stage_times) == len(plan.stages)
