"""repro.dist.retrying: deterministic jittered backoff, deadline budget,
non-retryable passthrough, exhaustion semantics."""

import itertools

import pytest

from repro.dist.retrying import RetryPolicy, backoff_delays, retry_call


class Boom(OSError):
    pass


class NotRetryable(ValueError):
    pass


def _take(gen, n):
    return list(itertools.islice(gen, n))


# ---------------------------------------------------------------------------
# backoff_delays
# ---------------------------------------------------------------------------

def test_backoff_exponential_envelope():
    pol = RetryPolicy(base_s=0.1, factor=2.0, max_s=0.5, jitter=0.0)
    assert _take(backoff_delays(pol, seed=0), 5) == \
        pytest.approx([0.1, 0.2, 0.4, 0.5, 0.5])


def test_backoff_jitter_stays_in_band():
    pol = RetryPolicy(base_s=1.0, factor=1.0, max_s=10.0, jitter=0.25)
    for d in _take(backoff_delays(pol, seed=7), 50):
        assert 0.75 <= d <= 1.25


def test_backoff_jitter_deterministic_per_seed():
    pol = RetryPolicy(jitter=0.5)
    a = _take(backoff_delays(pol, seed=11), 8)
    b = _take(backoff_delays(pol, seed=11), 8)
    c = _take(backoff_delays(pol, seed=12), 8)
    assert a == b                      # same seed replays exactly
    assert a != c                      # different seed, different schedule


def test_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=1.0)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=-0.1)


# ---------------------------------------------------------------------------
# retry_call
# ---------------------------------------------------------------------------

def test_retry_recovers_after_transient_failures():
    calls = {"n": 0}
    slept = []

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise Boom("transient")
        return "ok"

    pol = RetryPolicy(max_attempts=5, retryable=(Boom,), jitter=0.0,
                      base_s=0.01)
    assert retry_call(flaky, policy=pol, sleep=slept.append) == "ok"
    assert calls["n"] == 3
    assert len(slept) == 2


def test_retry_sleep_schedule_is_seeded():
    def always(): raise Boom("no")
    pol = RetryPolicy(max_attempts=4, retryable=(Boom,), base_s=0.1,
                      jitter=0.5)
    runs = []
    for _ in range(2):
        slept = []
        with pytest.raises(Boom):
            retry_call(always, policy=pol, seed=5, sleep=slept.append)
        runs.append(slept)
    assert runs[0] == runs[1]
    assert len(runs[0]) == 3           # max_attempts - 1 sleeps
    slept2 = []
    with pytest.raises(Boom):
        retry_call(always, policy=pol, seed=6, sleep=slept2.append)
    assert slept2 != runs[0]


def test_non_retryable_propagates_immediately():
    calls = {"n": 0}

    def bad():
        calls["n"] += 1
        raise NotRetryable("logic bug")

    pol = RetryPolicy(max_attempts=5, retryable=(Boom,))
    with pytest.raises(NotRetryable):
        retry_call(bad, policy=pol, sleep=lambda s: None)
    assert calls["n"] == 1             # never retried


def test_exhaustion_reraises_last_original_exception():
    errs = [Boom("first"), Boom("second"), Boom("third")]

    def failing():
        raise errs.pop(0)

    pol = RetryPolicy(max_attempts=3, retryable=(Boom,), jitter=0.0)
    with pytest.raises(Boom, match="third"):
        retry_call(failing, policy=pol, sleep=lambda s: None)


def test_deadline_bounds_total_budget_on_injected_clock():
    now = {"t": 0.0}

    def clock():
        return now["t"]

    def sleep(s):
        now["t"] += s

    def always():
        now["t"] += 1.0                # each attempt costs 1s of "work"
        raise Boom("down")

    pol = RetryPolicy(max_attempts=100, retryable=(Boom,), base_s=1.0,
                      factor=1.0, jitter=0.0, deadline_s=4.5)
    with pytest.raises(Boom):
        retry_call(always, policy=pol, sleep=sleep, clock=clock)
    # attempts cost 1s work + 1s sleep each; the deadline stops the loop
    # instead of letting all 100 attempts run
    assert now["t"] < 10.0


def test_on_retry_observer_sees_each_failure():
    seen = []

    def flaky():
        if len(seen) < 2:
            raise Boom("x")
        return 42

    pol = RetryPolicy(max_attempts=5, retryable=(Boom,), jitter=0.0,
                      base_s=0.01)
    out = retry_call(flaky, policy=pol, sleep=lambda s: None,
                     on_retry=lambda a, d, e: seen.append((a, d)))
    assert out == 42
    assert [a for a, _ in seen] == [0, 1]


def test_args_and_kwargs_pass_through():
    pol = RetryPolicy(max_attempts=2, retryable=(Boom,))
    assert retry_call(lambda a, b=0: a + b, 2, policy=pol, b=3,
                      sleep=lambda s: None) == 5
