"""Expected-traffic IR: bit-identity, MoE/MLA graphs, portfolio weights.

The refactor's contract is that dense graphs (every ``traffic_scale`` 1.0,
no edge multiplicities) take the exact pre-refactor float-op sequence —
scalar and batched — and that explicit all-1.0 scales are indistinguishable
from the defaults.  The MoE/MLA builders then get structural + traffic
regressions, and the weighted (portfolio) reduction is pinned against the
unweighted path.
"""

import json
import math
import os
import subprocess
import sys
import textwrap
from dataclasses import replace
from pathlib import Path

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.core.dse import DSEConfig, TaskResult, reduce_tasks, run_dse
from repro.core.evaluator import Evaluator
from repro.core.explore import (ExplorationEngine, graph_fingerprint,
                                merge_checkpoints)
from repro.core.graph_partition import partition_graph
from repro.core.hw import ArchConfig
from repro.core.sa import SAConfig
from repro.core.tangram import tangram_map
from repro.core.workload import Graph, Layer, dense_twin, edge_volume
from repro.core.workloads import (WORKLOAD_SPECS, make_workload,
                                  mla_transformer, moe_transformer,
                                  transformer)
from repro.core.workloads.lm_graph import lm_graph

REPO = Path(__file__).resolve().parent.parent

SET = settings(max_examples=12, deadline=None,
               suppress_health_check=[HealthCheck.too_slow])


def _arch(glb_kb: int = 1024) -> ArchConfig:
    return ArchConfig(x_cores=4, y_cores=3, xcut=2, ycut=1, noc_bw=32.0,
                      d2d_bw=16.0, dram_bw=64.0, glb_kb=glb_kb,
                      macs_per_core=512)


# ---------------------------------------------------------------------------
# workload zoo: one tiny graph per family, built two ways
# ---------------------------------------------------------------------------

def _cnn(explicit: bool) -> Graph:
    """Small conv chain (the CNN corner of the zoo)."""
    kw = dict(traffic_scale=1.0, weight_traffic_scale=1.0) if explicit else {}
    g = Graph("cnn-t")
    g.add(Layer(name="c1", kind="conv", K=16, H=16, W=16, C=3, R=3, S=3,
                **kw), ())
    g.add(Layer(name="c2", kind="conv", K=32, H=8, W=8, C=16, R=3, S=3,
                stride=2, **kw), [("c1", 1.0)] if explicit else ["c1"])
    g.add(Layer(name="p", kind="pool", K=32, H=4, W=4, C=32, stride=2, **kw),
          [("c2", 1.0)] if explicit else ["c2"])
    g.add(Layer(name="fc", kind="fc", K=10, H=1, W=1, C=512, **kw),
          [("p", 1.0)] if explicit else ["p"])
    g.validate()
    return g


_M2_CFG = ModelConfig(name="m2-t", family="ssm", n_layers=1, d_model=64,
                      n_heads=2, n_kv=1, d_ff=0, vocab=64, ssm_state=16,
                      ssm_headdim=32, ssm_chunk=32)


def _zoo(which: str, explicit: bool) -> Graph:
    if which == "cnn":
        return _cnn(explicit)
    if which == "transformer":
        g = transformer(n_layers=1, d_model=64, d_ff=128, seq=32, name="tf-t")
    else:                                        # mamba2 (SSD block)
        g = lm_graph(_M2_CFG, seq=64)
    if explicit:
        # force the guarded code paths: explicit 1.0 scales on every layer
        # and a stored 1.0 multiplicity on every edge — both must be
        # no-ops down to the last bit
        g2 = Graph(g.name)
        g2.layers = {n: replace(l, traffic_scale=1.0,
                                weight_traffic_scale=1.0)
                     for n, l in g.layers.items()}
        g2.edges = list(g.edges)
        g2.edge_mults = {e: 1.0 for e in g.edges}
        g2.input_layers = list(g.input_layers)
        g2.validate()
        return g2
    return g


@SET
@given(which=st.sampled_from(["cnn", "transformer", "mamba2"]),
       glb_kb=st.sampled_from([256, 1024]),
       batch=st.sampled_from([2, 4]))
def test_all_one_scales_bit_identical_scalar_and_batched(which, glb_kb,
                                                         batch):
    """Explicit 1.0 scales/mults == defaults, scalar AND batched rows."""
    arch = _arch(glb_kb)
    g0 = _zoo(which, explicit=False)
    g1 = _zoo(which, explicit=True)
    assert not g0.is_scaled
    assert dense_twin(g0) is g0              # identity, not a copy
    groups = partition_graph(g0, arch, batch)
    assert partition_graph(g1, arch, batch) == groups
    m0 = tangram_map(groups, g0, arch)
    m1 = tangram_map(groups, g1, arch)
    ev0, ev1 = Evaluator(arch, g0), Evaluator(arch, g1)
    for (grp, lms0), (_, lms1) in zip(m0, m1):
        assert lms0 == lms1
        ge0, an0 = ev0.eval_group(grp, lms0, batch)
        ge1, an1 = ev1.eval_group(grp, lms1, batch)
        assert (ge0.energy_j, ge0.delay_s) == (ge1.energy_j, ge1.delay_s)
        assert ge0.energy_breakdown == ge1.energy_breakdown
        assert np.array_equal(an0.edge_bytes, an1.edge_bytes)
    reqs0 = [(grp, lms) for grp, lms in m0]
    reqs1 = [(grp, lms) for grp, lms in m1]
    rows0 = ev0.eval_requests_batch(reqs0, batch)
    rows1 = ev1.eval_requests_batch(reqs1, batch)
    for (ge0, an0), (ge1, an1) in zip(rows0, rows1):
        assert (ge0.energy_j, ge0.delay_s) == (ge1.energy_j, ge1.delay_s)
        assert np.array_equal(an0.edge_bytes, an1.edge_bytes)


@SET
@given(n=st.integers(1, 5), seed=st.integers(0, 10_000))
def test_uniform_weights_reduce_bit_identical(n, seed):
    """Explicit all-1.0 weights == weightless reduction, to the last bit."""
    rng = np.random.default_rng(seed)
    trs = {f"w{i}": TaskResult(energy_j=float(rng.uniform(1e-6, 1e-1)),
                               delay_s=float(rng.uniform(1e-6, 1e-1)))
           for i in range(n)}
    arch = _arch()
    cfg0 = DSEConfig(sa=SAConfig(iters=10, seed=0))
    cfg1 = replace(cfg0, workload_weights={k: 1.0 for k in trs})
    p0 = reduce_tasks(arch, cfg0, trs)
    p1 = reduce_tasks(arch, cfg1, trs)
    assert (p0.energy_j, p0.delay_s, p0.objective) \
        == (p1.energy_j, p1.delay_s, p1.objective)


def test_weighted_reduce_math_and_validation():
    arch = _arch()
    trs = {"A": TaskResult(1e-3, 2e-3), "B": TaskResult(3e-3, 4e-3)}
    cfg = DSEConfig(workload_weights={"A": 3.0, "B": 1.0})
    p = reduce_tasks(arch, cfg, trs)
    assert p.energy_j == pytest.approx(
        math.exp((3 * math.log(1e-3) + math.log(3e-3)) / 4), rel=1e-12)
    assert p.delay_s == pytest.approx(
        math.exp((3 * math.log(2e-3) + math.log(4e-3)) / 4), rel=1e-12)
    with pytest.raises(ValueError, match="positive"):
        reduce_tasks(arch, DSEConfig(workload_weights={"A": 0.0}), trs)
    with pytest.raises(ValueError, match="positive"):
        reduce_tasks(arch, DSEConfig(workload_weights={"A": -2.0}), trs)


# ---------------------------------------------------------------------------
# expected-traffic IR semantics
# ---------------------------------------------------------------------------

def test_scale_validation_and_edge_mults():
    with pytest.raises(ValueError):
        Layer(name="x", kind="fc", K=8, H=8, C=8, traffic_scale=0.0)
    with pytest.raises(ValueError):
        Layer(name="x", kind="fc", K=8, H=8, C=8, weight_traffic_scale=-1.0)
    g = Graph("t")
    g.add(Layer(name="a", kind="fc", K=8, H=8, C=8), ())
    with pytest.raises(ValueError):
        g.add(Layer(name="b", kind="fc", K=8, H=8, C=8), [("a", 0.0)])
    g.add(Layer(name="b", kind="fc", K=8, H=8, C=8), [("a", 0.25)])
    assert g.edge_mult("a", "b") == 0.25
    assert g.edge_mult("missing", "b") == 1.0
    a = g.layers["a"]
    assert edge_volume(g, "a", "b", 2) == a.ofmap_bytes(2) * 0.25


def test_expected_volumes_scale():
    l = Layer(name="e", kind="fc", K=64, H=32, C=64, traffic_scale=0.25,
              weight_traffic_scale=0.5)
    assert l.expected_macs(2) == l.macs(2) * 0.25
    assert l.expected_ofmap_bytes(2) == l.ofmap_bytes(2) * 0.25
    assert l.expected_weight_bytes() == l.weight_bytes() * 0.5
    assert l.is_scaled
    d = Layer(name="d", kind="fc", K=64, H=32, C=64)
    assert d.expected_macs(2) == d.macs(2)      # exact int, no float pass
    assert isinstance(d.expected_macs(2), int)


def test_analyzer_traffic_scales_linearly():
    """Halving traffic_scale halves a layer's compute/DRAM contributions."""
    arch = _arch()

    def _pair(scale):
        g = Graph(f"s{scale}")
        g.add(Layer(name="a", kind="fc", K=64, H=32, C=64), ())
        g.add(Layer(name="b", kind="fc", K=64, H=32, C=64,
                    traffic_scale=scale), [("a", scale)])
        g.validate()
        return g

    res = {}
    for s in (1.0, 0.5):
        g = _pair(s)
        groups = partition_graph(g, arch, 2)
        ev = Evaluator(arch, g)
        r = ev.evaluate(tangram_map(groups, g, arch), 2)
        res[s] = r
    # energy strictly decreases with the expected-traffic share, and the
    # MoE-style scaled graph stays finite/positive
    assert 0 < res[0.5].energy_j < res[1.0].energy_j
    assert 0 < res[0.5].delay_s <= res[1.0].delay_s


# ---------------------------------------------------------------------------
# MoE / MLA graphs
# ---------------------------------------------------------------------------

def test_moe_vs_moe_dense_relative_traffic():
    """The routed graph's expected MACs match the legacy dense-width
    collapse (family="moe-dense") to within 10% — the router gate is the
    only genuinely new work — while exposing n_experts real branches."""
    cfg = _M2_CFG.replace(name="moe-t", family="moe", d_ff=128, n_experts=8,
                          top_k=2, ssm_state=0)
    gm = lm_graph(cfg, seq=128, n_layers=1)
    gd = lm_graph(cfg.replace(family="moe-dense"), seq=128, n_layers=1)
    assert gm.is_scaled and not gd.is_scaled
    ratio = gm.total_expected_macs() / gd.total_expected_macs()
    assert 1.0 <= ratio < 1.10          # router overhead only
    # structure: E expert branches with dense-resident weights
    ups = [n for n in gm.layers if n.endswith("_up") and "_e" in n]
    assert len(ups) == cfg.n_experts
    up = gm.layers[ups[0]]
    assert up.traffic_scale == pytest.approx(cfg.top_k / cfg.n_experts)
    assert up.weight_traffic_scale == 1.0
    # weight capacity: the routed graph keeps ALL experts resident
    wm = sum(l.expected_weight_bytes() for l in gm.layers.values())
    wd = sum(l.expected_weight_bytes() for l in gd.layers.values())
    assert wm / wd > 2.0                 # n_experts/top_k = 4x on the FFN


def test_moe_builder_structure():
    g = moe_transformer(n_layers=1, d_model=64, d_ff=64, n_experts=4,
                        top_k=2, n_shared=1, seq=32, name="m")
    g.validate()
    comb = g.layers["l0_combine"]
    assert comb.n_inputs == 2 + 1 + 1            # top_k + shared + residual
    assert len([s for s, d in g.edges if d == "l0_combine"]) == 4 + 1 + 1
    assert g.edge_mult("l0_add1", "l0_e0_up") == pytest.approx(0.5)
    with pytest.raises(ValueError, match="top_k"):
        moe_transformer(n_experts=2, top_k=3)


def test_mla_builder_structure():
    g = mla_transformer(n_layers=1, d_model=64, n_heads=2, q_rank=16,
                        kv_rank=8, d_ff=64, seq=32, name="mla-t")
    g.validate()
    assert not g.is_scaled                       # MLA is dense, just thin
    kv = g.layers["l0_kvdown"]
    assert kv.K == 8                             # the latent KV cube
    assert set(g.succs("l0_kvdown")) == {"l0_kup", "l0_vup"}
    dsk = mla_transformer(n_layers=1, d_model=64, n_heads=2, seq=32,
                          moe_ffn=True, n_experts=4, top_k=2)
    assert dsk.is_scaled                         # DeepSeek-shaped variant


def test_workload_registry():
    for name in ("tf-quick", "moe-quick", "mla-quick"):
        g = make_workload(name)
        assert isinstance(g, Graph) and len(g.layers) > 0
    assert set(WORKLOAD_SPECS) >= {"tf-quick", "tf-paper", "moe-quick",
                                   "moe-paper", "mla-quick", "mla-paper"}
    g = make_workload("moe:n_layers=1,d_model=64,d_ff=64,n_experts=4,"
                      "top_k=1,seq=32,name=m")
    assert g.is_scaled
    with pytest.raises(ValueError, match="registered presets"):
        make_workload("no-such-workload")
    # realize's graph_from_spec is the same registry
    from repro.realize.plan import graph_from_spec
    assert graph_fingerprint(graph_from_spec("moe-quick")) \
        == graph_fingerprint(make_workload("moe-quick"))


def test_fingerprints_dense_stable_scaled_distinct():
    tf = transformer(n_layers=1, d_model=64, d_ff=128, seq=32, name="t")
    assert graph_fingerprint(tf) == graph_fingerprint(
        transformer(n_layers=1, d_model=64, d_ff=128, seq=32, name="t"))
    moe = WORKLOAD_SPECS["moe-quick"]()
    twin = dense_twin(moe)
    assert graph_fingerprint(moe) != graph_fingerprint(twin)
    # same structure at a different routing fraction must re-fingerprint
    a = moe_transformer(n_layers=1, d_model=64, d_ff=64, n_experts=4,
                        top_k=1, n_shared=0, seq=32)
    b = moe_transformer(n_layers=1, d_model=64, d_ff=64, n_experts=4,
                        top_k=2, n_shared=0, seq=32)
    # top_k changes combine n_inputs AND scales; isolate the scales via
    # the twin (identical dense cubes except combine) — the scaled graphs
    # must still differ
    assert graph_fingerprint(a) != graph_fingerprint(b)


# ---------------------------------------------------------------------------
# portfolio quick flow: screen -> SA -> checkpoint -> shard/merge -> realize
# ---------------------------------------------------------------------------

def test_portfolio_quick_flow(tmp_path):
    """MoE + MLA + dense through the weighted Table-I quick flow:
    checkpointed weighted sweep, 2-way shard + merge bit-identity, and
    plan-level realization of the winner's mappings."""
    from repro.core.bridge import lms_to_plan
    from repro.core.dse import grid_candidates
    from repro.realize.plan import (checkpoint_workload_fingerprints,
                                    load_realize_candidates, validate_plan)

    cands = grid_candidates(
        72.0, mac_options=(512,), cut_options=(1, 2), dram_per_tops=(2.0,),
        noc_options=(32,), d2d_ratio=(0.5,), glb_options=(1024,))[:2]
    assert len(cands) == 2
    wls = {"TF": transformer(n_layers=1, d_model=64, d_ff=128, seq=32,
                             name="tf-t"),
           "MOE": moe_transformer(n_layers=1, d_model=64, d_ff=64,
                                  n_experts=4, top_k=2, n_shared=0, seq=32,
                                  name="moe-t"),
           "MLA": mla_transformer(n_layers=1, d_model=64, n_heads=2,
                                  q_rank=16, kv_rank=8, d_ff=64, seq=32,
                                  name="mla-t")}
    cfg = DSEConfig(batch=4, sa=SAConfig(iters=60, seed=0),
                    keep_mappings=True,
                    workload_weights={"TF": 0.6, "MOE": 0.25, "MLA": 0.15})
    ck = tmp_path / "portfolio.ckpt.jsonl"
    pts = run_dse(cands, wls, cfg, screen_keep=1.0, checkpoint=ck)
    assert len(pts) == 2 and pts[0].objective <= pts[1].objective
    assert set(pts[0].per_workload) == {"TF", "MOE", "MLA"}
    # header carries the weights (before :wl=, so realize still parses it)
    header = json.loads(ck.read_text().splitlines()[0])["_config"]
    assert ":w=MLA:0.15,MOE:0.25,TF:0.6:" in header
    fps = checkpoint_workload_fingerprints(ck)
    assert set(fps) == {"TF", "MOE", "MLA"}
    # sharded portfolio sweep merges bit-identically
    shards = []
    for i in range(2):
        sck = tmp_path / f"shard{i}.jsonl"
        run_dse(cands, wls, cfg, shard=(i, 2), checkpoint=sck)
        shards.append(sck)
    merged = tmp_path / "merged.jsonl"
    merge_checkpoints(shards, merged)
    re_pts = run_dse(cands, wls, cfg, checkpoint=merged)
    assert [p.objective for p in re_pts] == [p.objective for p in pts]
    # realize (plan level): every checkpointed mapping lowers + validates,
    # including the scaled MoE graph's
    rcs = load_realize_candidates(ck, wls, verbose=False)
    assert {c.workload for c in rcs} == {"TF", "MOE", "MLA"}
    for c in rcs:
        plan = c.lower()
        validate_plan(plan, n_devices=c.arch.n_cores, arch=c.arch)


def test_engine_rejects_unknown_weight_names():
    wls = {"TF": transformer(n_layers=1, d_model=64, d_ff=128, seq=32,
                             name="t")}
    with pytest.raises(ValueError, match="TYPO"):
        ExplorationEngine(wls, DSEConfig(workload_weights={"TYPO": 1.0}))


def test_moe_realize_measured_scaling():
    """The dense-equivalent MoE program measures with expected-traffic
    factors applied (subprocess: forced host devices)."""
    code = textwrap.dedent("""
        import json
        from repro.core.bridge import lms_to_plan
        from repro.core.graph_partition import partition_graph
        from repro.core.hw import ArchConfig
        from repro.core.tangram import tangram_map
        from repro.core.workloads import moe_transformer
        from repro.realize.measure import measure_candidate
        from repro.realize.plan import RealizeCandidate
        from repro.realize.program import build_program

        arch = ArchConfig(x_cores=4, y_cores=3, xcut=2, ycut=1, noc_bw=32,
                          d2d_bw=16, dram_bw=64, glb_kb=1024,
                          macs_per_core=1024)
        g = moe_transformer(n_layers=1, d_model=64, d_ff=64, n_experts=4,
                            top_k=2, n_shared=0, seq=32, name="moe-rz")
        groups = partition_graph(g, arch, 2)
        mapping = tangram_map(groups, g, arch)
        plan = lms_to_plan(mapping)
        prog = build_program(g, plan, use_pallas=False)
        prog.compile_all()
        cand = RealizeCandidate(key="k", workload="MOE", arch=arch,
                                mapping=mapping, graph=g, energy_j=1.0,
                                delay_s=1.0)
        rep = measure_candidate(cand, prog, execute=True)
        out = {
            "ratios": rep.ratio_summary(),
            "scales": [s.expected_scale for s in rep.stages],
            "record_has_scale": any("expected_scale" in s.to_record()
                                    for s in rep.stages),
        }
        print(json.dumps(out))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=12"
    env["PYTHONPATH"] = str(REPO / "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600, env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    data = json.loads(out.stdout.splitlines()[-1])
    assert all(v > 0 for v in data["ratios"].values())
    assert data["record_has_scale"]
    # every stage carries factors; expert stages carry sub-1.0 ones
    assert all(data["scales"])
    assert any(f < 1.0 for sc in data["scales"] for f in sc.values())
