"""Checkpoint durability + liveness edge cases: fsync'd appends and
atomic repairs, truncated-tail recovery (via the chaos harness's
injector), heartbeat lines torn into records by concurrent writers,
stale heartbeat clocks, and merge conflict detection."""

import json
from pathlib import Path

import pytest

from repro.core.explore import (ResumableSweep, _hb_collision,
                                _records_conflict, merge_checkpoints)
from repro.dist.faults import corrupt_tail
from repro.obs.report import parse_heartbeats, shard_progress

FP = "dse:v2:test-fingerprint"


def _write(path: Path, lines):
    path.write_text("".join(json.dumps(l) + "\n" for l in lines))


def _rec(key, energy=1.0, **kw):
    return {"_key": key, "workload": "tf", "seed": 7, "energy_j": energy,
            "delay_s": 0.5, **kw}


def _fresh(tmp_path, name="sweep.jsonl", records=3):
    p = tmp_path / name
    sweep = ResumableSweep(p, FP)
    for i in range(records):
        sweep.add(f"k{i}", {"workload": "tf", "seed": 7,
                            "energy_j": float(i), "delay_s": 0.5})
    return p


# ---------------------------------------------------------------------------
# Durability: fsync paths + truncated-tail recovery
# ---------------------------------------------------------------------------

def test_truncated_tail_recovered_and_repaired(tmp_path):
    """The chaos injector's torn, newline-less tail (killed mid-write)
    must cost at most the torn line — and resume must heal the file."""
    p = _fresh(tmp_path)
    corrupt_tail(p)                    # same injector the 'corrupt' fault uses
    sweep = ResumableSweep(p, FP)
    assert len(sweep) == 3             # every completed record survived
    assert "torn-by-fault" not in p.read_text()   # repair rewrote the file
    assert not p.with_name(p.name + ".tmp").exists()
    # the repaired file ends in a newline, so the next append can't merge
    # into a fragment
    sweep.add("k3", {"workload": "tf", "seed": 7, "energy_j": 3.0,
                     "delay_s": 0.5})
    assert len(ResumableSweep(p, FP)) == 4


def test_truncated_tail_then_append_without_reopen(tmp_path):
    """A writer appending to a file with a torn tail (fault fired in a
    sibling attempt) merges the fragment into its first record; resume
    and merge both drop only the damaged line."""
    p = _fresh(tmp_path)
    corrupt_tail(p)
    with p.open("a") as f:             # raw append, no repair pass
        f.write(json.dumps(_rec("k9")) + "\n")
    sweep = ResumableSweep.read(p)
    assert set(sweep.as_dict()) == {"k0", "k1", "k2"}  # merged line dropped


def test_fsync_can_be_disabled(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CKPT_FSYNC", "0")
    p = _fresh(tmp_path)
    assert len(ResumableSweep(p, FP)) == 3


# ---------------------------------------------------------------------------
# _hb lines torn into records by concurrent writers
# ---------------------------------------------------------------------------

def _hb_line(done=1):
    return {"_hb": {"shard": "s0", "stage": "dse", "done": done,
                    "total": 4, "wall_s": 1.0, "t": 1e9}}


def test_hb_interleaved_mid_record_forgiven_on_resume(tmp_path):
    """A heartbeat writer racing a record append can tear one line in
    two; the damage is adjacent to a heartbeat, so ONLY the damaged line
    is dropped (the seed gate recomputes it) — not the whole file."""
    p = tmp_path / "s.jsonl"
    good = [{"_config": FP}, _rec("k0"), _hb_line(1), _rec("k1")]
    text = "".join(json.dumps(l) + "\n" for l in good)
    # a half-record jammed between the heartbeat and k1
    lines = text.splitlines()
    lines.insert(3, '{"_key": "k-torn", "energy_j": 1.')
    p.write_text("".join(l + "\n" for l in lines))
    sweep = ResumableSweep(p, FP)
    assert set(sweep.as_dict()) == {"k0", "k1"}
    assert "k-torn" not in p.read_text()          # repaired


def test_hb_marker_inside_torn_line_forgiven(tmp_path):
    p = tmp_path / "s.jsonl"
    lines = [json.dumps({"_config": FP}), json.dumps(_rec("k0")),
             '{"_hb": {"shard": "s0", "done":',      # torn heartbeat itself
             json.dumps(_rec("k1"))]
    p.write_text("".join(l + "\n" for l in lines))
    sweep = ResumableSweep(p, FP)
    assert set(sweep.as_dict()) == {"k0", "k1"}


def test_corrupt_line_far_from_heartbeats_still_discards(tmp_path):
    """The forgiveness is scoped: a mid-file hole NOT attributable to a
    heartbeat collision still means unknown records were lost, and the
    whole checkpoint is set aside."""
    p = tmp_path / "s.jsonl"
    lines = [json.dumps({"_config": FP}), json.dumps(_rec("k0")),
             "garbage not json", json.dumps(_rec("k1"))]
    p.write_text("".join(l + "\n" for l in lines))
    sweep = ResumableSweep(p, FP)
    assert len(sweep) == 0                         # discarded...
    assert p.with_name(p.name + ".bak").exists()   # ...but preserved


def test_hb_collision_helper_scoping():
    lines = ['{"_key": "a"}', "torn", json.dumps(_hb_line())]
    assert _hb_collision(lines, 1)                 # hb neighbor
    lines = ['{"_key": "a"}', "torn", '{"_key": "b"}']
    assert not _hb_collision(lines, 1)             # no hb anywhere near
    assert _hb_collision(['x {"_hb": 1}'], 0)      # marker in the line


def test_hb_interleave_forgiven_by_merge(tmp_path):
    """merge_checkpoints applies the same forgiveness — a shard torn by
    its own heartbeat writer contributes its surviving records instead
    of being set aside."""
    a = tmp_path / "a.jsonl"
    lines = [json.dumps({"_config": FP}), json.dumps(_rec("k0")),
             json.dumps(_hb_line()), '{"_key": "k-torn", "ene',
             json.dumps(_rec("k1"))]
    a.write_text("".join(l + "\n" for l in lines))
    b = tmp_path / "b.jsonl"
    _write(b, [{"_config": FP}, _rec("k2")])
    report = merge_checkpoints([a, b], verbose=False)
    assert not report.skipped
    assert set(report.records) == {"k0", "k1", "k2"}


# ---------------------------------------------------------------------------
# Heartbeat clock edge cases (liveness must not trust remote clocks)
# ---------------------------------------------------------------------------

def test_shard_progress_stale_past_clock(tmp_path):
    """A heartbeat stamped by a badly skewed (past) clock shows a huge
    age — the supervisor ignores it and uses its own receipt times."""
    p = tmp_path / "s.jsonl"
    hb = _hb_line()
    hb["_hb"]["t"] = 1000.0            # ancient wall clock
    _write(p, [{"_config": FP}, _rec("k0"), hb])
    (row,) = shard_progress([p], now=2000.0)
    assert row["hb_age_s"] == pytest.approx(1000.0)
    assert row["records"] == 1


def test_shard_progress_future_clock_clamps_to_zero(tmp_path):
    p = tmp_path / "s.jsonl"
    hb = _hb_line()
    hb["_hb"]["t"] = 5000.0            # "from the future"
    _write(p, [{"_config": FP}, _rec("k0"), hb])
    (row,) = shard_progress([p], now=2000.0)
    assert row["hb_age_s"] == 0.0      # clamped, never negative


def test_shard_progress_dead_before_first_heartbeat(tmp_path):
    """A shard that died before ever heartbeating (header-only file, or
    no file at all) must still render a row — liveness falls back to the
    launch time upstream."""
    header_only = tmp_path / "s0.jsonl"
    _write(header_only, [{"_config": FP}])
    missing = tmp_path / "s1.jsonl"
    rows = shard_progress([header_only, missing], now=2000.0)
    assert [r["records"] for r in rows] == [0, 0]
    assert all(r["hb_age_s"] is None for r in rows)
    assert rows[0]["shard"] == "s0.jsonl"          # falls back to filename
    assert parse_heartbeats(missing) == (0, None)


def test_parse_heartbeats_ignores_torn_lines(tmp_path):
    p = tmp_path / "s.jsonl"
    p.write_text(json.dumps(_rec("k0")) + "\n" + '{"_hb": torn')
    assert parse_heartbeats(p) == (1, None)


# ---------------------------------------------------------------------------
# Merge conflict detection (silent last-wins no more)
# ---------------------------------------------------------------------------

def test_merge_reports_conflicting_duplicates(tmp_path):
    a, b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    _write(a, [{"_config": FP}, _rec("k0", energy=1.0), _rec("k1")])
    _write(b, [{"_config": FP}, _rec("k0", energy=2.0)])   # different!
    report = merge_checkpoints([a, b], verbose=False)
    assert report.conflicts == ["k0"]
    assert report.records["k0"]["energy_j"] == 2.0         # still last-wins


def test_merge_on_conflict_error_raises(tmp_path):
    a, b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    _write(a, [{"_config": FP}, _rec("k0", energy=1.0)])
    _write(b, [{"_config": FP}, _rec("k0", energy=2.0)])
    with pytest.raises(ValueError, match="conflict"):
        merge_checkpoints([a, b], verbose=False, on_conflict="error")
    with pytest.raises(ValueError):
        merge_checkpoints([a], on_conflict="bogus")


def test_merge_identical_duplicates_are_not_conflicts(tmp_path):
    a, b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    _write(a, [{"_config": FP}, _rec("k0")])
    _write(b, [{"_config": FP}, _rec("k0")])
    report = merge_checkpoints([a, b], verbose=False, on_conflict="error")
    assert report.conflicts == []
    assert report.n_records == 1


def test_records_conflict_semantics():
    base = {"workload": "tf", "seed": 7, "energy_j": 1.0}
    assert not _records_conflict(base, dict(base))
    assert _records_conflict(base, {**base, "energy_j": 2.0})
    assert _records_conflict(base, {**base, "extra": 1})
    # a keep_mappings upgrade (same metrics, one side carries the
    # mapping) is NOT a conflict...
    assert not _records_conflict(base, {**base, "mapping": {"m": 1}})
    # ...but two different mappings for the same task are
    assert _records_conflict({**base, "mapping": {"m": 1}},
                             {**base, "mapping": {"m": 2}})
    assert not _records_conflict({**base, "mapping": {"m": 1}},
                                 {**base, "mapping": {"m": 1}})
